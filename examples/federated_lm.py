"""Beyond-paper application: the paper's clustered federated MTL protocol
on an ASSIGNED LLM architecture (reduced for CPU), with the sidelink-
efficiency knob (bf16 consensus messages) that the Eq.-(11) energy model
prices directly.

Run:  PYTHONPATH=src python examples/federated_lm.py
"""
import jax.numpy as jnp

from repro.configs import get_arch, reduced
from repro.launch.train import train_federated


def main():
    cfg = reduced(get_arch("granite-8b"), num_layers=2, d_model=128)
    print("== f32 consensus messages ==")
    _, hist32, E32 = train_federated(
        cfg, rounds=5, agents=4, tasks=2, local_steps=4, batch=2,
        seq=64, lr=1e-3)
    print("\n== bf16 consensus messages (half the sidelink bytes) ==")
    _, hist16, E16 = train_federated(
        cfg, rounds=5, agents=4, tasks=2, local_steps=4, batch=2,
        seq=64, lr=1e-3, consensus_dtype=jnp.bfloat16)
    print(f"\nloss f32 {hist32[-1]:.3f} vs bf16 {hist16[-1]:.3f}; "
          f"comm energy {E32/1e3:.2f} kJ -> {E16/1e3:.2f} kJ")


if __name__ == "__main__":
    main()
