"""Serve a (reduced) assigned architecture with batched requests:
prefill + greedy decode through the KV-cache serve path — including a
sliding-window arch whose cache is the circular window buffer.

Run:  PYTHONPATH=src python examples/serve_lm.py
"""
from repro.configs import get_arch, reduced
from repro.launch.serve import serve


def main():
    for arch in ("stablelm-3b", "h2o-danube-3-4b", "recurrentgemma-9b"):
        cfg = reduced(get_arch(arch))
        print(f"== {arch} (reduced: {cfg.num_layers}L d={cfg.d_model}"
              f"{', SWA ' + str(cfg.sliding_window) if cfg.sliding_window else ''}) ==")
        serve(cfg, batch=2, prompt_len=32, gen=8)


if __name__ == "__main__":
    main()
