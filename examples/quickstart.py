"""Quickstart: the paper's two-stage protocol on a toy LM, end to end,
in under a minute on CPU.

1. meta-train (MAML, Eqs. 3–5) a reduced stablelm-family decoder over 3
   related token tasks;
2. adapt to an UNSEEN 4th task with decentralized consensus FL (Eq. 6);
3. price both stages with the paper's energy model (Eqs. 8–12).

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, reduced
from repro.core import consensus, energy, federated, maml
from repro.data import TaskTokenDistribution
from repro.models.api import get_model, lm_loss


def main():
    cfg = reduced(get_arch("stablelm-3b"), num_layers=2, d_model=128)
    model = get_model(cfg)
    dist = TaskTokenDistribution(vocab_size=cfg.vocab_size, num_tasks=4)
    key = jax.random.PRNGKey(0)
    params = model.init(key, cfg)
    n_bytes = sum(x.size * x.dtype.itemsize
                  for x in jax.tree.leaves(params))
    print(f"model: {sum(x.size for x in jax.tree.leaves(params)):,} params")

    def loss_fn(p, batch):
        return lm_loss(p, cfg, batch["tokens"], batch["labels"],
                       model=model)

    def batch_for(k, task, n=1):
        def one(kk):
            t, l = dist.sample(kk, task, 4, 64)
            return {"tokens": t, "labels": l}
        if n == 1:
            return one(k)
        return jax.tree.map(lambda *xs: jnp.stack(xs),
                            *[one(kk) for kk in jax.random.split(k, n)])

    # ---- stage 1: MAML over tasks {0, 1, 2} ------------------------------
    def sample_tasks(k, _):
        ks = jax.random.split(k, 6)
        sup = jax.tree.map(lambda *xs: jnp.stack(xs),
                           *[batch_for(ks[i], i) for i in range(3)])
        qry = jax.tree.map(lambda *xs: jnp.stack(xs),
                           *[batch_for(ks[3 + i], i) for i in range(3)])
        return sup, qry

    t0 = 20
    meta, hist = maml.maml_train(loss_fn, params, sample_tasks, rounds=t0,
                                 inner_lr=0.05, outer_lr=0.02)
    print(f"MAML {t0} rounds: meta-loss {hist[0]:.3f} -> {hist[-1]:.3f}")

    # ---- stage 2: consensus FL on unseen task 3 --------------------------
    K = 2
    mix = consensus.mixing_weights(np.ones(K), consensus.full_adjacency(K),
                                   "paper")

    def adapt(init, rounds=8):
        stacked = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (K,) + x.shape), init)
        losses = []
        for r in range(rounds):
            k = jax.random.fold_in(key, 1000 + r)
            batches = jax.tree.map(
                lambda *xs: jnp.stack(xs),
                *[batch_for(jax.random.fold_in(k, a), 3, n=4)
                  for a in range(K)])
            stacked = federated.decentralized_fl_round(
                loss_fn, stacked, batches, mix, lr=0.05)
            p0 = jax.tree.map(lambda x: x[0], stacked)
            losses.append(float(loss_fn(p0, batch_for(k, 3))))
        return losses

    from_meta = adapt(meta)
    from_rand = adapt(params)
    print(f"FL adaptation loss (unseen task): "
          f"meta-init {from_meta[0]:.3f}->{from_meta[-1]:.3f} | "
          f"random-init {from_rand[0]:.3f}->{from_rand[-1]:.3f}")

    # ---- energy accounting ------------------------------------------------
    ep = dataclasses.replace(energy.paper_calibrated("fig3"),
                             model_bits=n_bytes * 8.0)
    E_ml = energy.maml_energy(ep, t0, 3)
    E_fl = energy.fl_energy(ep, len(from_meta))
    print(f"energy: E_ML({t0} rounds) = {E_ml/1e3:.2f} kJ, "
          f"E_FL = {E_fl/1e3:.2f} kJ, total {(E_ml+E_fl)/1e3:.2f} kJ")


if __name__ == "__main__":
    main()
