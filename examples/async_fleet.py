"""Staleness-tolerant asynchronous consensus under agent churn.

Real wireless fleets have duty-cycled radios, heavy-tail stragglers,
and agents that join or leave mid-protocol. This example attaches an
`AgentProcess` to a consensus engine and runs the SAME scanned round
loop the lockstep protocol uses — sleeping agents freeze bitwise,
awake receivers mix their neighbours' last-published params weighted
by staleness (`staleness_decay**age`, hard-dropped past `tau` rounds),
and the per-round telemetry ledger bills only the wires actually
DELIVERED, reconciling exactly with a host-side availability replay.

Run:  PYTHONPATH=src python examples/async_fleet.py
"""
import jax
import numpy as np

from repro import telemetry as telemetry_lib
from repro.core import topology as topo_lib
from repro.core.engine import ConsensusEngine

K, ROUNDS = 8, 12


def run(agents, label):
    kw = ({"agents": agents, "tau": 3, "staleness_decay": 0.9}
          if agents is not None else {})
    eng = ConsensusEngine(topo_lib.ring(K), codec="int8", **kw)
    tel = telemetry_lib.Telemetry()
    stacked = {"w": jax.random.normal(jax.random.PRNGKey(0), (K, 32))}
    mixed, _ = eng.scan_rounds(stacked, rounds=ROUNDS, telemetry=tel)
    ev = tel.events(driver="consensus")
    joules = sum(e["joules"] for e in ev)
    spread = float(np.std(np.asarray(mixed["w"]), axis=0).mean())
    print(f"{label:>22}: active/round "
          f"{[e['n_active'] for e in ev]}  max wire age "
          f"{max(e['max_age'] for e in ev)}  comm {joules:.1f} J  "
          f"disagreement {spread:.4f}")


def main():
    run(None, "lockstep (baseline)")
    run(topo_lib.AgentProcess.bernoulli(0.6, seed=1), "60% duty cycle")
    run(topo_lib.AgentProcess.straggler(K, scale=0.3, seed=1),
        "heavy-tail stragglers")
    run(topo_lib.AgentProcess.arrival(np.arange(K) * 2),
        "staggered arrivals")
    run(topo_lib.AgentProcess.departure(np.full(K, ROUNDS - 4)),
        "mass departure")


if __name__ == "__main__":
    main()
