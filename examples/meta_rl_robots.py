"""The paper's Sect. IV case study: crawling robots learning trajectory
tasks with MAML + decentralized FL, with full energy accounting.

This is the END-TO-END DRIVER for the reproduction (deliverable (b)):
it runs a (reduced-t0) version of the Fig. 3 experiment and prints the
per-task rounds t_i, the per-stage energies, and the MAML vs no-MAML
comparison. The full Monte-Carlo sweep lives in benchmarks/fig4_tradeoff.

Run:  PYTHONPATH=src python examples/meta_rl_robots.py [--t0 60]
"""
import argparse

import jax

from repro.rl.casestudy import CaseStudy


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--t0", type=int, default=60,
                    help="MAML rounds (paper's Fig.3 uses 210)")
    ap.add_argument("--max-rounds", type=int, default=250)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cs = CaseStudy(inner_steps=10, outer_lr=0.01)
    key = jax.random.PRNGKey(args.seed)

    print(f"== stage 1: MAML meta-training, t0={args.t0}, Q=3 tasks "
          f"{cs.network.meta_task_ids} ==")
    res = cs.run(key, args.t0, max_rounds=args.max_rounds)
    print(f"t_i per task: {res.rounds_per_task}")
    s = res.summary()
    print(f"E_ML = {s['E_ML_kJ']:.1f} kJ;  E_FL per task = "
          f"{[round(e, 2) for e in s['E_FL_kJ']]} kJ")
    print(f"TOTAL (MAML, t0={args.t0}) = {s['E_total_kJ']:.1f} kJ")

    print("\n== baseline: no inductive transfer (t0 = 0) ==")
    res0 = cs.run(jax.random.fold_in(key, 1), 0,
                  max_rounds=args.max_rounds)
    s0 = res0.summary()
    print(f"t_i per task: {res0.rounds_per_task}")
    print(f"TOTAL (FL only) = {s0['E_total_kJ']:.1f} kJ")
    print(f"\nenergy reduction: {s0['E_total_kJ'] / s['E_total_kJ']:.2f}x "
          f"(paper claims >= 2x at t0=210)")


if __name__ == "__main__":
    main()
