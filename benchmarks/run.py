"""Benchmark harness entrypoint (assignment deliverable (d)).

One function per paper table/figure + kernel microbenchmarks. Prints
``name,us_per_call,derived`` CSV rows (derived = the quantity the paper's
table reports, e.g. kJ or a ratio; blank when N/A).

Heavy sweeps (Monte-Carlo Fig.4, 512-device dry-runs) run separately
(benchmarks/fig4_tradeoff.py, repro.launch.dryrun) and are READ here if
their JSON results exist; otherwise the paper's published Table II rounds
are used for the energy rows so this entrypoint always completes in
minutes on 1 CPU.
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

RESULTS = "benchmarks/results"
ROWS = []


def row(name: str, us_per_call: float, derived: str = ""):
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.1f},{derived}")


def _time(fn, *args, reps=5, warmup=2):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


# ---------------------------------------------------------------------------
# kernel microbenchmarks (XLA oracle path = CPU production path; the Pallas
# interpret path is correctness-only and far slower, so we time a tiny one)
# ---------------------------------------------------------------------------


def bench_kernels():
    from repro.kernels import ops
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (2, 512, 8, 64), jnp.float32)
    k = jax.random.normal(key, (2, 512, 2, 64), jnp.float32)
    v = jax.random.normal(key, (2, 512, 2, 64), jnp.float32)
    us = _time(lambda: ops.flash_attention(q, k, v, impl="xla"))
    row("kernel.flash_attention.xla.512", us)
    us = _time(lambda: ops.flash_attention(q, k, v, window=128, impl="xla"))
    row("kernel.flash_attention.swa.xla.512", us)
    qs, ks_ = q[:1, :128, :4], k[:1, :128, :2]
    us = _time(lambda: ops.flash_attention(qs, ks_, ks_, impl="interpret",
                                           block_q=64, block_k=64))
    row("kernel.flash_attention.interpret.128", us)

    la = -jax.nn.softplus(jax.random.normal(key, (4, 1024, 256)))
    b = jax.random.normal(key, (4, 1024, 256))
    us = _time(lambda: ops.rglru_scan(la, b, impl="xla"))
    row("kernel.rglru_scan.xla.1024", us)

    x = jax.random.normal(key, (1_000_000,))
    nb = jax.random.normal(key, (2, 1_000_000))
    sig = jnp.array([0.3, 0.3])
    us = _time(lambda: ops.consensus_update(x, nb, sig, impl="xla"))
    row("kernel.consensus_update.xla.1M", us)


# ---------------------------------------------------------------------------
# core-protocol microbenchmarks
# ---------------------------------------------------------------------------


def bench_protocol():
    from repro.rl.casestudy import CaseStudy
    cs = CaseStudy()
    key = jax.random.PRNGKey(0)
    params = cs.init_params(key)
    us = _time(lambda: cs._meta_round(params, key)[0])
    row("protocol.maml_round.dqn", us)
    stacked = jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (2,) + x.shape), params)
    us = _time(lambda: cs._fl_rounds[0](stacked, key)[0])
    row("protocol.fl_round.dqn", us)


# ---------------------------------------------------------------------------
# paper tables/figures
# ---------------------------------------------------------------------------


def bench_fig3():
    from benchmarks.fig3_energy import report
    from benchmarks.fig4_tradeoff import PAPER_TABLE_II as T2
    path = os.path.join(RESULTS, "fig4.json")
    mr = {}
    if os.path.exists(path):
        with open(path) as f:
            mr = json.load(f)["mean_rounds"]
    r210 = mr.get("210", T2[210])
    r0 = mr.get("0", T2[0])
    src = "measured" if ("210" in mr and "0" in mr) else (
        "partial-measured" if mr else "paper-tableII")
    t0 = time.perf_counter()
    out = report(r210, r0)
    us = (time.perf_counter() - t0) * 1e6
    row(f"fig3.total_maml_kJ.{src}", us, f"{out['total_maml_kJ']:.1f}")
    row(f"fig3.total_fl_only_kJ.{src}", us, f"{out['total_fl_only_kJ']:.1f}")
    row(f"fig3.energy_reduction.{src}", us, f"{out['reduction']:.2f}x")


def bench_fig4():
    from repro.core import energy
    path = os.path.join(RESULTS, "fig4.json")
    if os.path.exists(path):
        with open(path) as f:
            d = json.load(f)
        for regime, r in d["energies"].items():
            row(f"fig4.optimal_t0.{regime}", 0.0, str(r["optimal_t0"]))
        return
    from benchmarks.fig4_tradeoff import PAPER_TABLE_II as T2
    p = energy.paper_calibrated("fig4")
    t0 = time.perf_counter()
    _, _, eb = energy.optimize_split(p, 3, {k: v for k, v in T2.items()
                                            if k})
    us = (time.perf_counter() - t0) * 1e6
    row("fig4.optimal_t0.black_SL500_UL200", us, str(min(eb, key=eb.get)))
    pr = energy.swap_ul_sl(p)
    _, _, er = energy.optimize_split(pr, 3, {k: v for k, v in T2.items()
                                             if k})
    row("fig4.optimal_t0.red_UL500_SL200", us, str(min(er, key=er.get)))


def bench_table2():
    path = os.path.join(RESULTS, "fig4.json")
    if not os.path.exists(path):
        row("table2.rounds_scaledown", 0.0, "pending(fig4 sweep)")
        return
    with open(path) as f:
        mr = json.load(f)["mean_rounds"]
    s0 = sum(mr["0"])
    best = min((k for k in mr if k != "0"), key=lambda k: sum(mr[k]))
    row("table2.rounds_scaledown", 0.0,
        f"{s0 / max(sum(mr[best]), 1e-9):.1f}x@t0={best}")


def bench_roofline():
    path = os.path.join(RESULTS, "roofline.json")
    if not os.path.exists(path):
        single = os.path.join(RESULTS, "dryrun_single_pod.json")
        if os.path.exists(single):
            from benchmarks.roofline import analyze
            with open(single) as f:
                rows_ = [analyze(r) for r in json.load(f)["reports"]]
            with open(path, "w") as f:
                json.dump(rows_, f, indent=1)
        else:
            row("roofline.pairs", 0.0, "pending(dryrun sweep)")
            return
    with open(path) as f:
        rows_ = json.load(f)
    bounds = {}
    for r in rows_:
        bounds[r["bottleneck"]] = bounds.get(r["bottleneck"], 0) + 1
        row(f"roofline.{r['arch']}.{r['shape']}.step_ms", 0.0,
            f"{r['step_ms']:.2f}({r['bottleneck'][:4]})")
    row("roofline.bottleneck_histogram", 0.0,
        ";".join(f"{k}:{v}" for k, v in sorted(bounds.items())))


def main() -> None:
    print("name,us_per_call,derived")
    bench_kernels()
    bench_protocol()
    bench_fig3()
    bench_fig4()
    bench_table2()
    bench_roofline()
    os.makedirs(RESULTS, exist_ok=True)
    with open(os.path.join(RESULTS, "bench.csv"), "w") as f:
        f.write("name,us_per_call,derived\n")
        for n, u, d in ROWS:
            f.write(f"{n},{u:.1f},{d}\n")


if __name__ == "__main__":
    main()
