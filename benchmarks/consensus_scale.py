"""Consensus-round scaling sweep: K × topology × dtype (Eq. 6 hot path),
plus the model-exchange CODEC sweep (bits-vs-joules axis) and the
SHARDED plan's K ≫ cores rows.

Every timed step goes through :class:`repro.core.engine.ConsensusEngine`
(the single consensus entry point). For each population size
K ∈ {12, 64, 256, 1024}, graph family, and dtype this times one round
under two plans —

* ``dense-xla``  — the reference (K, K) matmul, O(K²·N);
* ``auto``       — the payload-aware heuristic (sparse gather through the
  fused consensus kernel — Pallas on TPU, its bit-identical jnp oracle
  on CPU — O(K·H·N); dense fallback on dense graphs);

and prices the round's communication with the paper's Eq. (11) via the
topology's per-link classes, so the perf trajectory records wall-clock
AND modeled joules per topology. A bit-equivalence check (auto vs the
per-agent ``ref.consensus_update_reference`` oracle) runs at K=256 for
every family in the sweep.

The codec sweep (``codec_rows``) times one COMPRESSED consensus round
(:mod:`repro.comms` wire formats, error feedback on) per codec ×
topology and records the codec-priced Eq.-(11) joules; ``sharded_rows``
runs the engine's ``sharded`` plan — blocks of agents under an agent
axis, codec wires all_gathered, no (K, K) stack in any one program — at
K ∈ {4096, 16384} per codec, the K ≫ core-count regime no single-program
path reaches; ``casestudy_eq11`` reprices the paper's 12-robot
(6 clusters × 2) case study round at every compression level with the
paper-calibrated b(W) — the headline artifact entry: int8 cuts the
modeled round joules 4× vs the f32 exchange (2× vs bf16), int4 8×.

``rounds_loop`` times the protocol round LOOP itself: per-round host
dispatch + blocking sync (the legacy ``run_fl_until`` pattern, chunk=1)
vs the scanned drivers' per-chunk dispatch at chunk ∈ {1, 8, 32}, on
the 12-robot case-study round shape (clusters(6, 2), N_PARAMS models,
episode-resampled local SGD, in-loop target eval) — the wall-clock
lever of the chunked ``lax.scan`` drivers in µs/round.

``dropout_rows`` times TIME-VARYING graphs: per-round survival masks
generated IN-SCAN from the engine's ``GraphProcess.dropout`` folded key
(one compiled ``scan_rounds`` program for the whole loop) vs the
host-prefetch pattern it replaced (materialize each round's surviving
Topology on the host, one ``engine.step(mask=...)`` dispatch per round)
— bit-identical params by the shared fold-in convention, µs/round
apart.

``telemetry_rows`` prices OBSERVABILITY: the same 12-robot chunked
round loop with ``repro.telemetry`` off vs buffered (per-round rows
ride the scan ys, priced host-side once per chunk) vs streaming
(additionally ``jax.debug.callback`` per round) — the --smoke gate
asserts the buffered mode stays within 15% of telemetry-off.

``mask_scale_rows`` times the MASKED round itself at scale: the
per-lane survival path (O(K·H) per-edge draws over the baked lane
table, σ renormalized directly on the lanes) vs the (K, K)-rebuild
reference it replaced (dense survival grid → ``masked_mixing`` dense σ
rebuild → gather back to the lanes), both built from public engine
APIs, bit-identical outputs, at K ∈ {1024, 4096} — median-of-3, with
the full run asserting ≥ 5× at K=4096.

``async_rows`` times STALENESS-TOLERANT rounds: the same scanned loop
lockstep vs asynchronous (bernoulli availability, τ=3, decay 0.9 — the
per-agent draws, float staleness σ, freezes, and clock/age carry all
in-scan) — median-of-3 µs/round, reported not gated.

Writes ``BENCH_consensus_scale.json`` (CWD; --out to override).

Run: PYTHONPATH=src python -m benchmarks.consensus_scale [--quick|--smoke]
(``--smoke``: K=64 ring int8 codec + sharded rows + the scanned-vs-host
rounds_loop check — the CI tier-1 check.)
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import comms
from repro.core import consensus, energy
from repro.core import topology as topo_lib
from repro.core.engine import ConsensusEngine
from repro.kernels import ref

KS = (12, 64, 256, 1024)
FAMILIES = ("ring", "torus", "small_world", "star", "cluster",
            "hierarchical")
DTYPES = ("float32", "bfloat16")
N_PARAMS = 2048          # flat params per agent (CPU-tractable at K=1024)
EQUIV_K = 256
CODECS = comms.CODECS    # none / bf16 / int8 / int4 / topk:0.05
CODEC_KS = (12, 64)      # codec wall-clock sweep sizes
SHARDED_KS = (4096, 16384)           # K >> cores: sharded plan only
SHARDED_CODECS = (None, "bf16", "int8", "int4")
SHARDED_BLOCKS = 4


def _time(fn, *args, reps=3, warmup=1):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def _stacked(K, dtype):
    x = jax.random.normal(jax.random.PRNGKey(0), (K, N_PARAMS), jnp.float32)
    return {"w": x.astype(dtype)}


def _oracle(mix, x):
    """Per-agent kernel oracle over the same padded sparse structure."""
    idx, sig = consensus.sparse_structure(mix)
    xf = jnp.asarray(np.asarray(x, np.float32))
    rows = [ref.consensus_update_reference(xf[k], xf[idx[k]],
                                           jnp.asarray(sig[k]))
            for k in range(xf.shape[0])]
    return np.stack([np.asarray(r) for r in rows])


def sweep(ks, families, dtypes, *, equiv_k=EQUIV_K):
    p_cal = energy.paper_calibrated("fig3")
    rows = []
    for K in ks:
        for dtype_name in dtypes:
            dtype = jnp.dtype(dtype_name)
            x = _stacked(K, dtype)
            for fam in families:
                try:
                    topo = topo_lib.make(fam, K)
                except ValueError as e:       # e.g. K not tileable
                    print(f"skip {fam} K={K}: {e}")
                    continue
                mix = topo.mixing()
                bits = N_PARAMS * dtype.itemsize * 8        # b(W) per model
                joules = topo.round_comm_joules(p_cal, model_bits=bits)
                base = dict(K=K, topology=fam, dtype=dtype_name,
                            max_degree=topo.max_degree,
                            links=topo.links_per_round(),
                            model_bits=bits,
                            joules_eq11_per_round=joules)

                eng_xla = ConsensusEngine(topo, plan="dense-xla")
                eng_auto = ConsensusEngine(topo, plan="auto")
                step_xla = jax.jit(lambda s: eng_xla.step(s)[0])
                step_auto = jax.jit(lambda s: eng_auto.step(s)[0])
                us_xla = _time(step_xla, x)
                us_auto = _time(step_auto, x)
                rows.append({**base, "impl": "xla", "us_per_round": us_xla})
                rows.append({**base, "impl": "auto",
                             "plan": eng_auto.plan.kind,
                             "us_per_round": us_auto,
                             "speedup_vs_xla": us_xla / max(us_auto, 1e-9)})
                print(f"K={K:5d} {fam:12s} {dtype_name:8s} "
                      f"xla {us_xla:10.1f}us  auto {us_auto:10.1f}us  "
                      f"eq11 {joules:10.3f} J/round")

                if K == equiv_k and dtype == jnp.float32:
                    got = np.asarray(step_auto(x)["w"], np.float32)
                    want = _oracle(mix, x["w"]).astype(np.float32)
                    if consensus.auto_path(mix) == "sparse":
                        if not np.array_equal(got, want):
                            raise AssertionError(
                                f"auto path NOT bit-equal to the reference "
                                f"oracle at K={equiv_k} ({fam})")
                        rows[-1]["bit_equal_oracle_at_K"] = equiv_k
                        print(f"        {fam}: auto == oracle (bit-equal, "
                              f"K={equiv_k})")
                    else:   # dense fallback (star): fp-close to the oracle
                        np.testing.assert_allclose(got, want, rtol=1e-5,
                                                   atol=1e-5)
                        rows[-1]["allclose_oracle_at_K"] = equiv_k
                        print(f"        {fam}: auto (dense fallback) ≈ "
                              f"oracle (K={equiv_k})")
    return rows


def codec_sweep(ks, families, codecs):
    """Wall-clock + codec-priced Eq.-(11) joules of one COMPRESSED
    consensus round per codec × topology (error feedback on, auto plan).
    """
    p_cal = energy.paper_calibrated("fig3")
    rows = []
    for K in ks:
        x = _stacked(K, jnp.float32)
        for fam in families:
            try:
                topo = topo_lib.make(fam, K)
            except ValueError as e:
                print(f"skip {fam} K={K}: {e}")
                continue
            full_bits = N_PARAMS * 32
            for spec in codecs:
                eng = ConsensusEngine(topo, codec=spec)
                codec = eng.codec
                joules = eng.round_comm_joules(p_cal, model_bits=full_bits)
                step = jax.jit(lambda s, st, k, e=eng: e.step(s, st, k))
                state = eng.init_state(x)
                key = jax.random.PRNGKey(0)

                def run(s, st, k):
                    out, _ = step(s, st, k)
                    return out

                us = _time(run, x, state, key)
                name = codec.name if codec is not None else "none"
                rows.append(dict(
                    K=K, topology=fam, codec=name,
                    wire_bits_per_model=(codec.price_bits(full_bits)
                                         if codec is not None
                                         else float(full_bits)),
                    joules_eq11_per_round=joules,
                    us_per_round=us,
                    plan=eng.plan.kind))
                print(f"K={K:5d} {fam:12s} codec={name:10s} "
                      f"{us:10.1f}us  eq11 {joules:10.4f} J/round")
    return rows


def sharded_rows(ks=SHARDED_KS, families=("ring",),
                 codecs=SHARDED_CODECS, num_blocks=SHARDED_BLOCKS):
    """The engine's ``sharded`` plan at K >> core count: blocks of
    K/num_blocks agents per mesh position (vmap-emulated off a real
    mesh), codec WIRES all_gathered along the agent axis, no (K, K)
    stack in any single program. Wall-clock + codec-priced Eq.-(11)
    joules per codec — the compressed-exchange-at-scale regime."""
    p_cal = energy.paper_calibrated("fig3")
    rows = []
    for K in ks:
        x = _stacked(K, jnp.float32)
        for fam in families:
            try:
                topo = topo_lib.make(fam, K)
            except ValueError as e:
                print(f"skip {fam} K={K}: {e}")
                continue
            full_bits = N_PARAMS * 32
            for spec in codecs:
                eng = ConsensusEngine(topo, codec=spec, plan="sharded",
                                      num_blocks=num_blocks)
                joules = eng.round_comm_joules(p_cal, model_bits=full_bits)
                step = jax.jit(lambda s, st, k, e=eng: e.step(s, st, k)[0])
                state = eng.init_state(x)
                key = jax.random.PRNGKey(0)
                us = _time(step, x, state, key)
                name = eng.codec.name if eng.codec is not None else "none"
                rows.append(dict(
                    K=K, topology=fam, codec=name, plan="sharded",
                    num_blocks=num_blocks,
                    wire_bits_per_model=(eng.codec.price_bits(full_bits)
                                         if eng.codec is not None
                                         else float(full_bits)),
                    joules_eq11_per_round=joules,
                    us_per_round=us))
                print(f"K={K:5d} {fam:12s} sharded codec={name:10s} "
                      f"{us:12.1f}us  eq11 {joules:10.4f} J/round")
    return rows


ROUNDS_LOOP_CHUNKS = (1, 8, 32)


def rounds_loop_rows(chunks=ROUNDS_LOOP_CHUNKS, rounds: int = 128):
    """µs/round of the protocol round LOOP — the host pattern (one
    dispatch + one blocking reached-flag sync per ROUND, i.e. the legacy
    ``run_fl_until`` behaviour, chunk=1) vs the scanned drivers (one
    dispatch + sync per CHUNK) — on the paper's 12-robot case-study
    round shape: the Sect.-IV ``clusters(6, 2)`` graph, N_PARAMS-sized
    models, each robot resampling minibatches from one small per-round
    episode for its local SGD steps, Eq.-(6) cluster consensus, and an
    in-loop target evaluation every round.

    All chunk sizes dispatch the SAME compiled scan program
    (:func:`repro.core.federated._fl_scan_program` — exactly what the
    public drivers run, with bit-identical results across chunk sizes),
    so the sweep isolates the host-loop overhead the chunked drivers
    amortize. The local-SGD budget is kept small relative to Table I's
    B_i = 20 so the round sits in the dispatch-dominated regime this
    section measures — the regime every Monte-Carlo t0 × tasks × codecs
    sweep of small case-study models lives in.
    """
    from repro.core import federated

    K, B_i, FEAT, BATCH = 12, 2, 16, 4
    topo = topo_lib.clusters(6, 2)        # the paper's Sect.-IV graph
    eng = ConsensusEngine(topo)

    def loss_fn(p, b):
        return jnp.mean((p["w"][:FEAT] - b["tgt"]) ** 2)

    stacked = {"w": jax.random.normal(jax.random.PRNGKey(0),
                                      (K, N_PARAMS), jnp.float32)}

    def sample_batches(key, t):
        # one 20-step episode per robot per round, resampled into B_i
        # minibatches — the Sect. IV-A data budget in benchmark shape
        k1, k2 = jax.random.split(key)
        ep = jax.random.normal(k1, (K, 20, FEAT), jnp.float32) * 0.01
        idx = jax.random.randint(k2, (K, B_i, BATCH), 0, 20)
        return {"tgt": jax.vmap(lambda e, i: e[i])(ep, idx)}

    def target_fn(sp):
        m = jnp.mean(jnp.square(sp["w"]))
        return m < 0.0, m                 # unreachable: time full loops

    key = jax.random.PRNGKey(1)
    run_chunk = federated._fl_scan_program(
        loss_fn, eng, 0.05, sample_batches=sample_batches,
        target_fn=target_fn, stacked_params=stacked, key=key,
        max_rounds=1 << 30, eval_every=1)

    rows = []
    host_us = None
    for chunk in chunks:
        def drive(reps):
            # own(): the chunk program donates its params carry on
            # donating backends — never consume the shared `stacked`
            from repro.core import scanloop
            s, st, k, r = scanloop.own(stacked), None, key, jnp.asarray(False)
            for start in range(0, reps, chunk):
                (s, st, k, r), ys = run_chunk(
                    s, st, k, r,
                    jnp.arange(start, start + chunk, dtype=jnp.int32))
                if np.asarray(ys[0]).any():     # the per-chunk sync
                    break
            return s

        jax.block_until_ready(drive(chunk)["w"])          # compile
        # median-of-3: a single min-of-N is still hostage to one good
        # draw on shared CI machines whose scheduler noise swings the
        # per-round dispatch cost ~2x; the median is what the --smoke
        # scanned-no-slower assertion compares (with a 1.15x tolerance)
        times = []
        for _ in range(3):
            t0 = time.perf_counter()
            jax.block_until_ready(drive(rounds)["w"])
            times.append((time.perf_counter() - t0) / rounds * 1e6)
        med = float(np.median(times))
        if chunk == 1:
            host_us = med
        speedup = (host_us / med) if host_us else 1.0
        rows.append(dict(
            K=K, topology="cluster", n_params=N_PARAMS, local_steps=B_i,
            rounds=rounds, chunk=chunk,
            driver="host-loop" if chunk == 1 else "scanned",
            us_per_round=med, speedup_vs_host_loop=speedup))
        print(f"rounds_loop chunk={chunk:3d}  {med:9.1f} us/round  "
              f"({speedup:.2f}x vs host loop, median of 3)")
    return rows


def telemetry_rows(rounds: int = 128, chunk: int = 16):
    """µs/round of the chunked FL driver with telemetry off vs buffered
    vs streaming, on the same 12-robot case-study round shape as
    ``rounds_loop_rows`` (clusters(6, 2), N_PARAMS models, episode
    local SGD, in-loop target eval).

    All three modes dispatch through
    :func:`repro.core.federated._fl_scan_program` and produce
    bit-identical params; the delta is pure observability cost:

    * ``buffered``  — one fixed-shape row per round rides the scan ys
      (device work) and the whole chunk is priced host-side in the sync
      the driver already pays — this must stay within 1.75x of off (the
      --smoke gate; the ratio on this ~100 us/round shape swings
      1.2-1.6x on scheduler noise alone, while a real per-round host
      round-trip lands at 4-6x), or per-round metrics aren't free
      enough to leave on in sweeps;
    * ``streaming`` — additionally one ordered ``jax.debug.callback``
      per round (program built per call, uncached): the price of
      per-round liveness, reported but not gated (host round-trips are
      legitimately not free).
    """
    from repro import telemetry as telemetry_lib
    from repro.core import federated, scanloop

    K, B_i, FEAT, BATCH = 12, 2, 16, 4
    topo = topo_lib.clusters(6, 2)        # the paper's Sect.-IV graph

    def loss_fn(p, b):
        return jnp.mean((p["w"][:FEAT] - b["tgt"]) ** 2)

    stacked = {"w": jax.random.normal(jax.random.PRNGKey(0),
                                      (K, N_PARAMS), jnp.float32)}

    def sample_batches(key, t):
        k1, k2 = jax.random.split(key)
        ep = jax.random.normal(k1, (K, 20, FEAT), jnp.float32) * 0.01
        idx = jax.random.randint(k2, (K, B_i, BATCH), 0, 20)
        return {"tgt": jax.vmap(lambda e, i: e[i])(ep, idx)}

    def target_fn(sp):
        m = jnp.mean(jnp.square(sp["w"]))
        return m < 0.0, m                 # unreachable: time full loops

    key = jax.random.PRNGKey(1)
    rows, off_us = [], None
    for mode in ("off", "buffered", "streaming"):
        eng = ConsensusEngine(topo)
        tel = (None if mode == "off"
               else telemetry_lib.Telemetry(mode=mode, capacity=rounds))
        rec = tel.recorder_for(eng) if tel is not None else None
        run_chunk = federated._fl_scan_program(
            loss_fn, eng, 0.05, sample_batches=sample_batches,
            target_fn=target_fn, stacked_params=stacked, key=key,
            max_rounds=1 << 30, eval_every=1, telemetry=tel)

        def drive(reps):
            s, st, k, r = scanloop.own(stacked), None, key, jnp.asarray(False)
            for start in range(0, reps, chunk):
                (s, st, k, r), ys = run_chunk(
                    s, st, k, r,
                    jnp.arange(start, start + chunk, dtype=jnp.int32))
                if tel is not None:       # the host side of the contract
                    tel.record_rounds(rec, ys[3], start)
                if np.asarray(ys[0]).any():
                    break
            return s

        jax.block_until_ready(drive(chunk)["w"])          # compile
        # median-of-3, same rationale as rounds_loop_rows
        times = []
        for _ in range(3):
            if tel is not None:
                tel.reset()
            t0 = time.perf_counter()
            jax.block_until_ready(drive(rounds)["w"])
            times.append((time.perf_counter() - t0) / rounds * 1e6)
        med = float(np.median(times))
        if mode == "off":
            off_us = med
        rows.append(dict(
            K=K, topology="cluster", n_params=N_PARAMS, chunk=chunk,
            rounds=rounds, telemetry=mode, us_per_round=med,
            overhead_vs_off=med / max(off_us, 1e-9)))
        print(f"telemetry_rows {mode:10s} chunk={chunk:3d} "
              f"{med:9.1f} us/round  ({med / max(off_us, 1e-9):.2f}x "
              "vs telemetry off, median of 3)")
    return rows


DROPOUT_ROUNDS = 64


def dropout_rows(rounds: int = DROPOUT_ROUNDS, p: float = 0.2,
                 seed: int = 0, configs=None):
    """µs/round of a TIME-VARYING consensus round loop: in-scan masks
    (the engine's ``GraphProcess.dropout`` — each round's surviving
    graph drawn from the folded key INSIDE one compiled
    ``engine.scan_rounds`` program) vs the host-prefetch pattern the
    in-scan path replaced (per round: materialize the surviving
    :func:`topology.dropout` Topology on the host, hand its mask to a
    jitted ``engine.step(mask=...)``, one dispatch + sync per round).

    Both modes run the SAME engine plan and produce bit-identical
    params (the shared fold-in convention); the delta is pure host
    overhead — mask materialization plus O(rounds) dispatches, exactly
    what dropout Monte-Carlo sweeps used to pay per round.
    """
    if configs is None:
        configs = (("cluster", topo_lib.clusters(6, 2), "dense-xla", {}),
                   ("ring", topo_lib.ring(256), "sparse-pallas", {}))
    rows = []
    for fam, topo, plan, kw in configs:
        x = _stacked(topo.K, jnp.float32)
        eng = ConsensusEngine(
            topo, plan=plan,
            graph=topo_lib.GraphProcess.dropout(p, seed), **kw)
        run = jax.jit(
            lambda s, e=eng: e.scan_rounds(s, rounds=rounds, t0=0)[0])
        us_scan = _time(run, x) / rounds
        step = jax.jit(lambda s, m, e=eng: e.step(s, mask=m)[0])

        def host_drive(s):
            for rt in topo_lib.dropout(topo, p, seed, rounds=rounds):
                s = step(s, jnp.asarray(rt.adjacency))
            return s

        us_host = _time(host_drive, x) / rounds
        for mode, us in (("in-scan", us_scan),
                         ("host-prefetch", us_host)):
            rows.append(dict(
                K=topo.K, topology=fam, plan=plan, dropout_p=p,
                rounds=rounds, mode=mode, us_per_round=us,
                speedup_vs_host_prefetch=us_host / max(us, 1e-9)))
        print(f"dropout_rows {fam:10s} {plan:14s} in-scan "
              f"{us_scan:9.1f} us/round  host-prefetch {us_host:9.1f} "
              f"us/round  ({us_host / max(us_scan, 1e-9):.2f}x)")
    return rows


MASK_SCALE_KS = (1024, 4096)


def _median_us(fn, *args, reps=3):
    """Median-of-``reps`` wall-clock of one call, µs (R3: timing that
    feeds an assertion is never a single draw)."""
    jax.block_until_ready(fn(*args))               # compile + warm
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(times))


def mask_scale_rows(ks=MASK_SCALE_KS, p: float = 0.2, seed: int = 0,
                    n_params: int = 256, min_speedup_at_4096: float = 5.0):
    """µs of ONE masked consensus round at scale, two ways:

    * ``per-lane``      — the engine's live path: ``step(t=...)`` draws
      O(K·H) per-edge survivals over the baked (K, H) lane table and
      renormalizes σ directly on the lanes — no (K, K) buffer;
    * ``kk-rebuild``    — the reference pattern the per-lane path
      replaced, reconstructed from public APIs: the dense (K, K)
      survival grid (``round_mask``), the dense σ rebuild
      (``masked_mixing``), and a gather of the rebuilt matrix back to
      the same lanes.

    Outputs are BIT-IDENTICAL (one fold-in convention, association-free
    renormalization on uniform sizes) — asserted before timing — so the
    delta is pure masking machinery. Median-of-3 per mode; the K=4096
    row must come in ≥ ``min_speedup_at_4096`` x faster (the tentpole's
    acceptance bar; None skips the assertion for smoke runs)."""
    rows = []
    for K in ks:
        topo = topo_lib.ring(K)
        x = {"w": jax.random.normal(jax.random.PRNGKey(0),
                                    (K, n_params), jnp.float32)}
        eng = ConsensusEngine(topo, plan="sparse-pallas",
                              graph=topo_lib.GraphProcess.dropout(p, seed))
        idx, _valid = eng.lane_structure()
        idx_j = jnp.asarray(idx)
        rows_j = jnp.arange(K)[:, None]

        after = jax.jit(lambda s, t, e=eng: e.step(s, t=t)[0])

        def before_fn(s, t, e=eng, ij=idx_j, rj=rows_j):
            mask = e.round_mask(t)                 # dense (K, K) draws
            mix_t = e.masked_mixing(mask)          # dense σ rebuild
            sig_t = mix_t[rj, ij]                  # back to the lanes
            return consensus.consensus_step(
                s, e.mix, impl="sparse", structure=(ij, sig_t))

        before = jax.jit(before_fn)
        got = np.asarray(after(x, jnp.int32(3))["w"])
        want = np.asarray(before(x, jnp.int32(3))["w"])
        np.testing.assert_array_equal(
            got, want,
            err_msg=f"per-lane != kk-rebuild at K={K} (one convention)")

        us_after = _median_us(after, x, jnp.int32(3))
        us_before = _median_us(before, x, jnp.int32(3))
        speedup = us_before / max(us_after, 1e-9)
        for mode, us in (("per-lane", us_after),
                         ("kk-rebuild", us_before)):
            rows.append(dict(
                K=K, topology="ring", plan="sparse-pallas", dropout_p=p,
                n_params=n_params, mode=mode, us_per_round=us,
                speedup_vs_kk_rebuild=us_before / max(us, 1e-9)))
        print(f"mask_scale K={K:5d} per-lane {us_after:10.1f} us/round  "
              f"kk-rebuild {us_before:12.1f} us/round  "
              f"({speedup:.1f}x, median of 3)")
        if K == 4096 and min_speedup_at_4096 is not None:
            assert speedup >= min_speedup_at_4096, (
                f"masked round at K=4096: per-lane only {speedup:.1f}x "
                f"faster than the (K, K) rebuild (< "
                f"{min_speedup_at_4096}x)")
    return rows


def async_rows(rounds: int = 64, configs=None):
    """µs/round of the STALENESS-TOLERANT async round loop vs the
    lockstep loop on the same engine plan. The async path adds, per
    round and all in-scan: the per-agent availability draw (one
    fold-in per (agent, t) id), delivered/stale lane classification,
    float staleness σ (decay^age, hard τ drop, renormalized on the
    lanes), the per-agent bitwise freeze, and the clock/age AsyncState
    advance. Median-of-3 per mode (R3); reported, not gated — the
    delta is the measured price of churn-tolerance, and the lockstep
    row doubles as the baseline the reduction tests pin bitwise."""
    if configs is None:
        configs = (("cluster", topo_lib.clusters(6, 2), "dense-xla", {}),
                   ("ring", topo_lib.ring(256), "sparse-pallas", {}))
    rows = []
    for fam, topo, plan, kw in configs:
        x = _stacked(topo.K, jnp.float32)
        sync_eng = ConsensusEngine(topo, plan=plan, **kw)
        asyn_eng = ConsensusEngine(
            topo, plan=plan,
            agents=topo_lib.AgentProcess.bernoulli(0.6, seed=0),
            tau=3, staleness_decay=0.9, **kw)
        run_sync = jax.jit(
            lambda s, e=sync_eng: e.scan_rounds(s, rounds=rounds)[0])
        run_asyn = jax.jit(
            lambda s, e=asyn_eng: e.scan_rounds(s, rounds=rounds)[0])
        us_sync = _median_us(run_sync, x) / rounds
        us_asyn = _median_us(run_asyn, x) / rounds
        for mode, us in (("lockstep", us_sync), ("staleness", us_asyn)):
            rows.append(dict(
                K=topo.K, topology=fam, plan=plan, rounds=rounds,
                mode=mode, us_per_round=us,
                overhead_vs_lockstep=us / max(us_sync, 1e-9)))
        print(f"async_rows   {fam:10s} {plan:14s} lockstep "
              f"{us_sync:9.1f} us/round  staleness {us_asyn:9.1f} "
              f"us/round  ({us_asyn / max(us_sync, 1e-9):.2f}x, "
              "median of 3)")
    return rows


def casestudy_eq11(codecs):
    """Codec-priced Eq.-(11) joules of ONE consensus round of the paper's
    12-robot case study (6 clusters × 2 robots, calibrated b(W))."""
    p_cal = energy.paper_calibrated("fig3")
    topo = topo_lib.clusters(6, 2)        # the paper's Sect.-IV graph
    out = {}
    base = topo.round_comm_joules(p_cal)
    for spec in codecs:
        j = topo.round_comm_joules(p_cal, codec=spec)
        name = comms.resolve_codec(spec).name if spec is not None else "none"
        out[name] = {"joules_eq11_per_round": j,
                     "drop_vs_uncompressed": base / j}
        print(f"casestudy 12-robot  codec={name:10s} "
              f"eq11 {j:8.2f} J/round  ({base / j:.1f}x vs f32)")
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="K <= 256, f32 only (CI-sized)")
    ap.add_argument("--smoke", action="store_true",
                    help="codec smoke only: K=64, ring, int8 (tier-1 CI)")
    ap.add_argument("--codec", default=None,
                    help="comma list of codec specs for the codec sweep "
                         f"(default: {','.join(c or 'none' for c in CODECS)})")
    ap.add_argument("--out", default="BENCH_consensus_scale.json")
    args = ap.parse_args()

    codecs = (tuple(None if c in ("none", "") else c
                    for c in args.codec.split(","))
              if args.codec else (None,) + tuple(c for c in CODECS
                                                 if c != "none"))
    if args.smoke:
        ks, families, dtypes = (64,), ("ring",), ("float32",)
        rows, codec_rows = [], codec_sweep((64,), ("ring",), ("int8",))
        # one sharded row: the shard_map-plan path must stay runnable in CI
        shard_rows = sharded_rows((64,), ("ring",), ("int8",), num_blocks=4)
        assert shard_rows and shard_rows[0]["us_per_round"] > 0
        cs = casestudy_eq11((None, "int8"))
        assert cs["int8+ef"]["drop_vs_uncompressed"] >= 3.0
        # the scanned round-loop driver must not be slower per round
        # than the per-round host loop it replaces (chunk=32 typically
        # measures ~3-4x FASTER). Median-of-3 timings on both sides
        # with a 1.15x tolerance: slow shared-CI CPUs swing a single
        # timing ~2x on scheduler noise, which made the old
        # single-best comparison flaky — the median absorbs one bad
        # draw while a real regression still trips the assertion.
        loop_rows = rounds_loop_rows(chunks=(1, 32), rounds=64)
        assert (loop_rows[-1]["us_per_round"]
                <= 1.15 * loop_rows[0]["us_per_round"])
        # time-varying rows stay runnable in CI (tiny: one config)
        drop_rows = dropout_rows(
            rounds=16,
            configs=(("cluster", topo_lib.clusters(6, 2),
                      "dense-xla", {}),))
        # per-round telemetry must be cheap enough to leave ON: buffered
        # rows within 1.75x of telemetry-off (median-of-3 both sides).
        # Re-measured on an idle box: the ratio on this ~100 us/round
        # 12-robot shape swings 1.2-1.6x run to run (identically on the
        # tree BEFORE the per-lane mask work — the old 1.15x bound was
        # calibrated against a single lucky 0.93x draw and tripped on
        # scheduler noise ~half the time). The gate's real job is
        # catching an accidental per-round host round-trip sneaking into
        # the buffered path, and that failure mode lands at 4-6x (see
        # the streaming row), comfortably past 1.75x. Streaming is
        # reported, not gated — its per-round host callback round-trip
        # is the price of liveness, paid knowingly.
        tel_rows = telemetry_rows(rounds=64, chunk=16)
        assert (tel_rows[1]["us_per_round"]
                <= 1.75 * tel_rows[0]["us_per_round"])
        # masked-round scaling stays runnable in CI (tiny K, no gate —
        # the >= 5x acceptance assertion runs in the full sweep only)
        mask_rows = mask_scale_rows(ks=(256,), min_speedup_at_4096=None)
        # async staleness rounds stay runnable in CI (tiny: one config,
        # reported not gated)
        as_rows = async_rows(
            rounds=16,
            configs=(("cluster", topo_lib.clusters(6, 2),
                      "dense-xla", {}),))
    else:
        ks = tuple(k for k in KS if k <= 256) if args.quick else KS
        dtypes = ("float32",) if args.quick else DTYPES
        families = FAMILIES
        rows = sweep(ks, families, dtypes)
        codec_rows = codec_sweep(CODEC_KS, families, codecs)
        shard_rows = sharded_rows()
        cs = casestudy_eq11(codecs)
        loop_rows = rounds_loop_rows()
        drop_rows = dropout_rows()
        tel_rows = telemetry_rows()
        mask_rows = mask_scale_rows()
        as_rows = async_rows()
    payload = {
        "bench": "consensus_scale",
        "backend": jax.default_backend(),
        "n_params_per_agent": N_PARAMS,
        "ks": list(ks), "families": list(families),
        "dtypes": list(dtypes),
        "rows": rows,
        "codec_rows": codec_rows,
        "sharded_rows": shard_rows,
        "casestudy_eq11": cs,
        "rounds_loop": loop_rows,
        "dropout_rows": drop_rows,
        "telemetry_rows": tel_rows,
        "mask_scale_rows": mask_rows,
        "async_rows": as_rows,
    }
    if args.smoke:
        payload["smoke"] = True
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=1)
    print(f"wrote {args.out} ({len(rows)} rows, "
          f"{len(codec_rows)} codec rows)")


if __name__ == "__main__":
    main()
