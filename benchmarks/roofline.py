"""Roofline analysis (assignment deliverable (g)).

Reads the dry-run JSON (launch/dryrun.py --all --probe --out ...) and per
(arch × shape) on the single-pod mesh reports:
  * the three roofline terms in seconds (scan-corrected via probes),
  * the dominant bottleneck,
  * MODEL_FLOPS = 6·N·D (dense) / 6·N_active·D (MoE) — decode/prefill use
    the 2·N·D inference factor — and the MODEL_FLOPS / HLO_FLOPs ratio
    (how much compiled compute is "useful"),
  * one-line what-would-move-the-dominant-term-down notes.

CPU-only container: these are DERIVED from the compiled artifact, not
measured (TPU v5e constants: 197 TF/s bf16, 819 GB/s HBM, 50 GB/s/link).
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.configs import INPUT_SHAPES, get_arch
from repro.core.energy import RooflineTerms

MOVE_NOTES = {
    "compute": "increase arithmetic intensity (fuse, larger per-chip tiles)"
               " or accept: compute-bound is the roofline target",
    "memory": "cut HBM traffic: bf16 caches/params, fuse elementwise chains,"
              " larger attention blocks (see kernels/), ZeRO-shard opt state",
    "collective": "reshard to cut gather/reduce volume (stationary KV cache,"
                  " K-dim TP), overlap collectives with compute, bf16"
                  " consensus messages",
}


def model_flops(arch: str, shape_name: str) -> float:
    cfg = get_arch(arch)
    shape = INPUT_SHAPES[shape_name]
    n = cfg.active_param_count()
    if shape.mode == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.mode == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch      # one decoded token


def analyze(report: dict) -> dict:
    corr = report.get("corrected") or {}
    flops = corr.get("flops", report["flops"])
    hbm = corr.get("hbm_bytes", report["hbm_bytes"])
    coll = corr.get("collective_total",
                    float(sum(report["collectives"].values())))
    rt = RooflineTerms(flops=flops, hbm_bytes=hbm, collective_bytes=coll,
                       chips=report["chips"])
    mf = model_flops(report["arch"], report["shape"])
    return {
        "arch": report["arch"], "shape": report["shape"],
        "mesh": report["mesh"], "chips": report["chips"],
        "t_compute_ms": rt.t_compute * 1e3,
        "t_memory_ms": rt.t_memory * 1e3,
        "t_collective_ms": rt.t_collective * 1e3,
        "bottleneck": rt.bottleneck,
        "step_ms": rt.step_time * 1e3,
        "model_flops": mf,
        "hlo_flops": flops,
        "useful_ratio": mf / flops if flops else float("nan"),
        "energy_per_step_J": rt.energy_per_step(),
        "note": MOVE_NOTES[rt.bottleneck],
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--report", default="benchmarks/results/"
                                        "dryrun_single_pod.json")
    ap.add_argument("--out", default="benchmarks/results/roofline.json")
    args = ap.parse_args(argv)
    with open(args.report) as f:
        data = json.load(f)
    rows = [analyze(r) for r in data["reports"]]
    hdr = (f"{'arch':<18}{'shape':<12}{'comp ms':>9}{'mem ms':>9}"
           f"{'coll ms':>9} {'bound':<11}{'useful':>7}")
    print(hdr)
    print("-" * len(hdr))
    for r in rows:
        print(f"{r['arch']:<18}{r['shape']:<12}"
              f"{r['t_compute_ms']:>9.2f}{r['t_memory_ms']:>9.2f}"
              f"{r['t_collective_ms']:>9.2f} {r['bottleneck']:<11}"
              f"{r['useful_ratio']:>7.2f}")
    with open(args.out, "w") as f:
        json.dump(rows, f, indent=1)
    if data.get("failures"):
        print(f"\nWARNING: {len(data['failures'])} dry-run failures")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
