"""Fig. 3: energy footprints and rounds, MAML (t0=210) vs FL-only (t0=0).

Reads fig4.json if present (fig4's grid subsumes fig3); otherwise runs the
two points directly. Prints the per-task energy bars and validates the
paper's headline claims:
  * total E(MAML) ≤ E(no-MAML) / 2     (">= 2x" claim)
  * per-round data-center energy > per-round device energy
"""
from __future__ import annotations

import argparse
import json
import os

import numpy as np

from repro.core import energy

FIG4_PATH = "benchmarks/results/fig4.json"


def report(mean_rounds_210, mean_rounds_0, p=None):
    p = p or energy.paper_calibrated("fig3")
    E_ml = energy.maml_energy(p, 210, 3)
    E_fl = [energy.fl_energy(p, t) for t in mean_rounds_210]
    E_fl0 = [energy.fl_energy(p, t) for t in mean_rounds_0]
    total = E_ml + sum(E_fl)
    total0 = sum(E_fl0)
    print("=== Fig. 3 reproduction (paper values in brackets) ===")
    print(f"E_ML(t0=210, Q=3)       = {E_ml/1e3:7.1f} kJ   [74]")
    print(f"t_i (MAML)              = {[round(t,1) for t in mean_rounds_210]}"
          f"   [7..32]")
    print(f"t_i (no MAML)           = {[round(t,1) for t in mean_rounds_0]}"
          f"   [24..380]")
    print(f"sum E_FL (MAML)         = {sum(E_fl)/1e3:7.1f} kJ   [32]")
    print(f"TOTAL (MAML)            = {total/1e3:7.1f} kJ   [106]")
    print(f"TOTAL (no MAML)         = {total0/1e3:7.1f} kJ   [227]")
    ratio = total0 / total
    print(f"energy reduction        = {ratio:.2f}x   [>= 2x claim]")
    per_round_dc = (energy.maml_energy(p, 210, 3)
                    - energy.maml_energy(p, 209, 3))
    per_round_dev = energy.fl_energy(p, 1.0)
    print(f"per-round: data center {per_round_dc:.0f} J > device "
          f"{per_round_dev:.0f} J : {per_round_dc > per_round_dev}")
    return {"E_ML_kJ": E_ml / 1e3,
            "E_FL_kJ": [e / 1e3 for e in E_fl],
            "total_maml_kJ": total / 1e3,
            "total_fl_only_kJ": total0 / 1e3,
            "reduction": ratio}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--seeds", type=int, default=2)
    ap.add_argument("--max-rounds", type=int, default=400)
    a = ap.parse_args()
    if os.path.exists(FIG4_PATH):
        with open(FIG4_PATH) as f:
            data = json.load(f)
        mr = data["mean_rounds"]
        out = report(mr["210"], mr["0"])
    else:
        from benchmarks.fig4_tradeoff import run
        data = run(seeds=a.seeds, max_rounds=a.max_rounds,
                   t0_grid=(0, 210), verbose=True)
        mr = data["mean_rounds"]
        out = report(mr["210"], mr["0"])
    os.makedirs("benchmarks/results", exist_ok=True)
    with open("benchmarks/results/fig3.json", "w") as f:
        json.dump(out, f, indent=1)


if __name__ == "__main__":
    main()
