"""Fig. 4 (and Fig. 3, its t0=210/t0=0 slice): impact of MAML rounds t0 on
E_ML, ΣE_FL and total E, under both communication-efficiency regimes.

One meta-training trajectory per seed with parameter snapshots at every
t0 split point (42, 66, 90, 132, 210, 240), then per-task FL adaptation
from each snapshot measuring t_i. Energies from repro.core.energy with
the paper-calibrated constants. Results -> JSON (read by EXPERIMENTS.md
and table2_rounds.py).
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.core import energy
from repro.rl.casestudy import CaseStudy

T0_GRID = (0, 42, 66, 90, 132, 210, 240)

# the paper's own Table II (average FL rounds t_i), for side-by-side
PAPER_TABLE_II = {
    0: [380.1, 129.6, 93.7, 211.5, 24.2, 82.4],
    42: [29.7, 56.4, 70.9, 87.0, 70.4, 57.1],
    66: [178.8, 9.9, 14.3, 104.6, 9.8, 12.4],
    90: [84.9, 8.9, 15.6, 166.2, 11.3, 19.6],
    132: [11.6, 25.5, 25.1, 44.6, 23.1, 23.8],
    210: [6.7, 29.1, 16.5, 27.7, 32.0, 17.2],
    240: [2.7, 10.8, 9.1, 40.0, 21.8, 19.6],
}


def _save_partial(rounds, t0_grid, out):
    """Incremental snapshot so long sweeps are restart/deadline-safe."""
    import os
    os.makedirs(os.path.dirname(out) or ".", exist_ok=True)
    done = {t0: v for t0, v in rounds.items() if v}
    if not done:
        return
    partial = {
        "rounds": {str(k): v for k, v in done.items()},
        "mean_rounds": {str(k): np.mean(v, axis=0).tolist()
                        for k, v in done.items()},
        "paper_table_ii": {str(k): v for k, v in PAPER_TABLE_II.items()},
        "energies": {},
        "partial": True,
    }
    _add_energies(partial, done.keys())
    with open(out, "w") as f:
        json.dump(partial, f, indent=1)


def _add_energies(result, t0s):
    from repro.core import energy as E
    mean_rounds = {int(k): v for k, v in result["mean_rounds"].items()}
    for regime, p in (("black_SL500_UL200", E.paper_calibrated("fig4")),
                      ("red_UL500_SL200",
                       E.swap_ul_sl(E.paper_calibrated("fig4")))):
        en = {t0: E.total_energy(p, t0, 3, mean_rounds[t0])
              for t0 in mean_rounds}
        nonzero = [t0 for t0 in en if t0 > 0]
        best = min(nonzero, key=lambda t: en[t]) if nonzero else None
        result["energies"][regime] = {
            "E_kJ": {str(k): v / 1e3 for k, v in en.items()},
            "optimal_t0": best,
        }


def run(seeds: int = 3, max_rounds: int = 400, t0_grid=T0_GRID,
        out: str = "benchmarks/results/fig4.json", verbose=True):
    cs = CaseStudy(inner_steps=10, outer_lr=0.01)
    M = cs.network.num_tasks
    rounds = {t0: [] for t0 in t0_grid}   # lists of per-seed [t_1..t_M]

    for seed in range(seeds):
        key = jax.random.PRNGKey(seed)
        kmeta, kfl = jax.random.split(key)
        # one meta run with snapshots
        params = cs.init_params(kmeta)
        snaps = {0: params}
        kdata = kmeta
        hist = []
        t_start = time.time()
        for t in range(max(t0_grid)):
            kdata, sk = jax.random.split(kdata)
            params, m = cs._meta_round(params, sk)
            hist.append(float(m["meta_loss"]))
            if (t + 1) in t0_grid:
                snaps[t + 1] = params
        if verbose:
            print(f"[seed {seed}] meta-train {max(t0_grid)} rounds "
                  f"({time.time() - t_start:.0f}s)", flush=True)
        for t0 in t0_grid:
            tis = []
            for tid in range(M):
                kfl, kt = jax.random.split(kfl)
                _, t_i, _ = cs.adapt_task(kt, tid, snaps[t0],
                                          max_rounds=max_rounds)
                tis.append(t_i)
            rounds[t0].append(tis)
            if verbose:
                print(f"[seed {seed}] t0={t0:3d}: t_i={tis} "
                      f"sum={sum(tis)}", flush=True)
            _save_partial(rounds, t0_grid, out)

    mean_rounds = {t0: np.mean(rounds[t0], axis=0).tolist()
                   for t0 in t0_grid}

    result = {"rounds": {str(k): v for k, v in rounds.items()},
              "mean_rounds": {str(k): v for k, v in mean_rounds.items()},
              "paper_table_ii": {str(k): v
                                 for k, v in PAPER_TABLE_II.items()},
              "energies": {}}
    _add_energies(result, t0_grid)
    if verbose:
        for regime, r in result["energies"].items():
            print(f"{regime}: optimal t0 = {r['optimal_t0']}, "
                  f"E_kJ = { {k: round(v, 1) for k, v in r['E_kJ'].items()} }",
                  flush=True)
    import os
    os.makedirs("benchmarks/results", exist_ok=True)
    with open(out, "w") as f:
        json.dump(result, f, indent=1)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--seeds", type=int, default=3)
    ap.add_argument("--max-rounds", type=int, default=400)
    ap.add_argument("--out", default="benchmarks/results/fig4.json")
    a = ap.parse_args()
    run(seeds=a.seeds, max_rounds=a.max_rounds, out=a.out)


if __name__ == "__main__":
    main()
