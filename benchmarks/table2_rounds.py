"""Table II: average FL rounds t_i per task vs MAML rounds t0 — ours vs
the paper's published numbers (needs benchmarks/results/fig4.json)."""
from __future__ import annotations

import json
import sys

import numpy as np


def main(path: str = "benchmarks/results/fig4.json"):
    with open(path) as f:
        d = json.load(f)
    ours = d["mean_rounds"]
    paper = d["paper_table_ii"]
    print(f"{'t0':>5} | {'ours: t_1..t_6':^42} | sum | paper sum")
    for t0 in sorted(ours, key=int):
        o = ours[t0]
        ps = sum(paper.get(t0, [])) if t0 in paper else float("nan")
        print(f"{t0:>5} | {' '.join(f'{x:6.1f}' for x in o)} "
              f"| {sum(o):5.0f} | {ps:6.1f}")
    s0 = sum(ours["0"])
    best = min((t0 for t0 in ours if t0 != "0"),
               key=lambda t: sum(ours[t]))
    print(f"\nrounds scale-down vs t0=0: best t0={best} -> "
          f"{s0 / max(sum(ours[best]), 1e-9):.1f}x  [paper: up to 9x]")
    print("unseen tasks (3,4,5 idx 2,3,4) vs trained (1,2,6 idx 0,1,5):")
    for t0 in sorted(ours, key=int):
        if t0 == "0":
            continue
        o = ours[t0]
        tr = np.mean([o[0], o[1], o[5]])
        un = np.mean([o[2], o[3], o[4]])
        print(f"  t0={t0:>3}: trained {tr:6.1f} | unseen {un:6.1f}")


if __name__ == "__main__":
    main(*sys.argv[1:])
