"""§Perf P3 — the paper's technique on the production mesh: per-round
communication volume of decentralized consensus (Eq. 6) vs a FedAvg-style
all-reduce, and the bf16-message optimization (the Eq.-(11) E_SL knob).

Each of the 16 data-axis positions is an AGENT holding a full granite-8b
replica (tensor-parallel over the 16 "model" positions). One FL round
exchanges the model with both ring neighbours (2·b(W) per agent). The
lowering is analyzed exactly like the dry-runs — collective bytes parsed
from the SPMD module.

Run: PYTHONPATH=src python -m benchmarks.consensus_volume
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import functools

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_arch
from repro.core import consensus, energy
from repro.core import topology as topo_lib
from repro.launch.hlo_analysis import collective_bytes
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import abstract_params
from repro.sharding import rules


def build(cfg, mesh, *, msg_dtype=None, mode="ring"):
    """Lower one consensus/averaging round over agent-stacked params.

    params: leading agent axis K=16 sharded over 'data'; within an agent the
    replica is TP-sharded over 'model' (the per-leaf rules shifted by one).
    """
    K = mesh.shape["data"]
    p_abs = abstract_params(cfg)
    stacked = jax.tree.map(
        lambda l: jax.ShapeDtypeStruct((K,) + l.shape, l.dtype), p_abs)

    def stacked_sharding(path, leaf):
        inner = rules.param_spec(path, leaf, cfg,
                                 model_size=mesh.shape["model"])
        return NamedSharding(mesh, P("data", *inner))

    # param_spec sees the unstacked path (agent dim prepended manually)
    base_sh = rules.param_shardings(p_abs, cfg, mesh)
    st_sh = jax.tree.map(
        lambda s: NamedSharding(mesh, P("data", *s.spec)), base_sh)

    sizes = jnp.ones((K,), jnp.float32)

    if mode == "ring":
        def step(stacked_params, sz):
            def per_agent(p, s):
                return consensus.ring_consensus_step(
                    p, s[0], "data", message_dtype=msg_dtype)

            # partial-manual: in_specs name ONLY the manual axis ("data");
            # the per-replica tensor-parallel sharding over "model" flows
            # through GSPMD auto from the outer jit's in_shardings.
            fn = jax.shard_map(
                per_agent, mesh=mesh,
                in_specs=(jax.tree.map(lambda s: P("data"), st_sh),
                          P("data")),
                out_specs=jax.tree.map(lambda s: P("data"), st_sh),
                axis_names=frozenset({"data"}), check_vma=False)
            return fn(stacked_params, sizes)
    else:  # fedavg: global mean over agents (star topology all-reduce)
        def step(stacked_params, sz):
            def avg(x):
                m = jnp.mean(x.astype(jnp.float32), axis=0, keepdims=True)
                return jnp.broadcast_to(m, x.shape).astype(x.dtype)
            return jax.tree.map(avg, stacked_params)

    jitted = jax.jit(step, in_shardings=(st_sh, NamedSharding(mesh, P())),
                     out_shardings=st_sh)
    return jitted.lower(stacked, jax.ShapeDtypeStruct((K,), jnp.float32))


def main():
    cfg = get_arch("granite-8b")
    mesh = make_production_mesh()
    n_params = cfg.param_count()
    print(f"granite-8b replica: {n_params/1e9:.2f}B params "
          f"({n_params*4/1e9:.1f} GB f32)")
    # Eq.-(11) pricing of the SAME rounds the lowering ships: every wire
    # crossing a link is billed (repro.analysis rule R4 — no unpriced
    # transmissions), at the paper-calibrated radio parameters
    p_cal = energy.paper_calibrated("fig3")
    ring16 = topo_lib.ring(mesh.shape["data"])
    model_bits = n_params * 32.0
    for name, cc, kw, codec_spec in (
        ("fedavg_allreduce", cfg, dict(mode="fedavg"), None),
        ("ring_consensus_f32", cfg, dict(mode="ring"), None),
        ("ring_consensus_bf16", cfg, dict(mode="ring",
                                          msg_dtype=jnp.bfloat16), "bf16"),
    ):
        compiled = build(cc, mesh, **kw).compile()
        cb = collective_bytes(compiled.as_text())
        tot = sum(cb.values())
        per_agent = tot * 256 / 16 / 1e9      # per-device -> per-agent GB
        joules = ring16.round_comm_joules(p_cal, model_bits=model_bits,
                                          codec=codec_spec)
        print(f"{name:22s} {tot/1e9:8.2f} GB/device/round  "
              f"{ {k: round(v/1e9,2) for k, v in cb.items() if v} }  "
              f"Eq.(11) {joules:10.1f} J/round")


if __name__ == "__main__":
    main()
