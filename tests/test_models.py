"""Model-substrate unit tests: attention equivalences, MoE dispatch vs
dense reference, RG-LRU/mLSTM scan forms, rope/norm properties."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs import get_arch, reduced
from repro.models import layers as L
from repro.models import moe as moe_mod
from repro.models import rglru as rg
from repro.models import xlstm as xl


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------


def test_chunked_equals_reference(rng_key):
    ks = jax.random.split(rng_key, 3)
    q = jax.random.normal(ks[0], (2, 100, 4, 32))
    k = jax.random.normal(ks[1], (2, 100, 2, 32))
    v = jax.random.normal(ks[2], (2, 100, 2, 32))
    for window in (0, 24):
        a = L.attention_reference(q, k, v, window=window)
        b = L.attention_chunked(q, k, v, window=window, kv_chunk=32,
                                q_chunk=32)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-4)


def test_attention_klen_masks_future_cache(rng_key):
    """Entries past k_len (unwritten cache slots) must not affect output."""
    ks = jax.random.split(rng_key, 4)
    q = jax.random.normal(ks[0], (1, 1, 2, 16))
    k = jax.random.normal(ks[1], (1, 8, 2, 16))
    v = jax.random.normal(ks[2], (1, 8, 2, 16))
    kl = jnp.array([5])
    a = L.attention_reference(q, k, v, causal=False, k_len=kl)
    k2 = k.at[:, 5:].set(jax.random.normal(ks[3], (1, 3, 2, 16)))
    b = L.attention_reference(q, k2, v, causal=False, k_len=kl)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_swa_window_exact(rng_key):
    """SWA must equal full attention restricted to the last w keys."""
    ks = jax.random.split(rng_key, 3)
    S, w = 32, 8
    q = jax.random.normal(ks[0], (1, S, 2, 16))
    k = jax.random.normal(ks[1], (1, S, 2, 16))
    v = jax.random.normal(ks[2], (1, S, 2, 16))
    out = L.attention_reference(q, k, v, causal=True, window=w)
    # last row: manual softmax over keys (S-w, S-1]
    t = S - 1
    sel = slice(t - w + 1, t + 1)
    qf = q[0, t, 0] / np.sqrt(16)
    scores = np.asarray(k[0, sel, 0] @ qf)
    p = np.exp(scores - scores.max())
    p /= p.sum()
    want = p @ np.asarray(v[0, sel, 0])
    np.testing.assert_allclose(np.asarray(out[0, t, 0]), want,
                               rtol=1e-4, atol=1e-4)


@settings(deadline=None, max_examples=15)
@given(seed=st.integers(0, 2**16), S=st.integers(4, 24))
def test_rope_preserves_norm_and_relativity(seed, S):
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (1, S, 2, 16))
    pos = jnp.arange(S)[None]
    y = L.apply_rope(x, pos, 10000.0)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(y), axis=-1),
        np.linalg.norm(np.asarray(x), axis=-1), rtol=1e-4)
    # relative property: <rope(q,i), rope(k,j)> depends only on i-j
    q = jax.random.normal(jax.random.fold_in(key, 1), (1, 1, 1, 16))
    k = jax.random.normal(jax.random.fold_in(key, 2), (1, 1, 1, 16))
    def dot_at(i, j):
        qi = L.apply_rope(q, jnp.array([[i]]), 1e4)
        kj = L.apply_rope(k, jnp.array([[j]]), 1e4)
        return float(jnp.sum(qi * kj))
    assert abs(dot_at(3, 1) - dot_at(7, 5)) < 1e-3


def test_rms_norm_scale_invariance(rng_key):
    x = jax.random.normal(rng_key, (2, 8, 16))
    w = jnp.zeros(16)
    a = L.rms_norm(x, w)
    b = L.rms_norm(5.0 * x, w)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4,
                               atol=1e-5)


# ---------------------------------------------------------------------------
# MoE dispatch vs dense reference
# ---------------------------------------------------------------------------


def _dense_moe_reference(p, cfg, x):
    """Compute every expert for every token; combine with top-k gates."""
    m = cfg.moe
    B, S, d = x.shape
    xf = x.reshape(-1, d)
    logits = xf @ p["router"]
    gate_vals, topk_idx = jax.lax.top_k(logits, m.top_k)
    gates = jax.nn.softmax(gate_vals, axis=-1)
    act = jax.nn.silu
    y = jnp.zeros_like(xf)
    for e in range(m.num_experts):
        h = act(xf @ p["w_gate"][e]) * (xf @ p["w_up"][e])
        oe = h @ p["w_down"][e]
        w = jnp.sum(jnp.where(topk_idx == e, gates, 0.0), axis=-1)
        y = y + w[:, None] * oe
    if "shared" in p:
        sg = jax.nn.sigmoid(xf @ p["shared_gate"])
        h = act(xf @ p["shared"]["w_gate"]) * (xf @ p["shared"]["w_up"])
        y = y + (h @ p["shared"]["w_down"]) * sg
    return y.reshape(B, S, d)


@pytest.mark.parametrize("arch", ["mixtral-8x7b", "qwen2-moe-a2.7b"])
def test_moe_dispatch_matches_dense(arch, rng_key):
    cfg = reduced(get_arch(arch))
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    p = moe_mod.init_moe_mlp(rng_key, cfg)
    x = jax.random.normal(jax.random.fold_in(rng_key, 1), (2, 16,
                                                           cfg.d_model))
    y, aux = moe_mod.moe_block(p, cfg, x)
    want = _dense_moe_reference(p, cfg, x)
    # accumulation-order differences at f32 with ~1e2-magnitude logits
    np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                               rtol=1e-2, atol=1e-3)
    assert float(aux) > 0


def test_moe_capacity_drops_tokens(rng_key):
    """With capacity 1 token per expert most contributions are dropped
    (residual passthrough) — output must stay finite and not equal the
    full-capacity output."""
    cfg = reduced(get_arch("mixtral-8x7b"))
    p = moe_mod.init_moe_mlp(rng_key, cfg)
    x = jax.random.normal(jax.random.fold_in(rng_key, 2), (2, 16,
                                                           cfg.d_model))
    y_full, _ = moe_mod.moe_block(p, cfg, x, capacity=64)
    y_tight, _ = moe_mod.moe_block(p, cfg, x, capacity=1)
    assert np.isfinite(np.asarray(y_tight)).all()
    assert not np.allclose(np.asarray(y_full), np.asarray(y_tight))


# ---------------------------------------------------------------------------
# recurrences
# ---------------------------------------------------------------------------


def test_rglru_assoc_scan_matches_sequential(rng_key):
    ks = jax.random.split(rng_key, 3)
    la = -jax.nn.softplus(jax.random.normal(ks[0], (2, 40, 8)))
    b = jax.random.normal(ks[1], (2, 40, 8))
    h0 = jax.random.normal(ks[2], (2, 8))
    h, hl = rg.rglru_scan(la, b, h0)
    hc = h0
    outs = []
    for t in range(40):
        hc = jnp.exp(la[:, t]) * hc + b[:, t]
        outs.append(hc)
    np.testing.assert_allclose(np.asarray(h),
                               np.asarray(jnp.stack(outs, 1)),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(hl), np.asarray(hc), rtol=1e-5,
                               atol=1e-5)


def test_conv1d_causal_and_stateful(rng_key):
    p = rg.init_conv1d(rng_key, 8, 4, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(rng_key, 1), (1, 12, 8))
    full, _ = rg.conv1d_apply(p, x)
    # causality: output at t must not depend on inputs > t
    x2 = x.at[:, 6:].set(0.0)
    part, _ = rg.conv1d_apply(p, x2)
    np.testing.assert_allclose(np.asarray(full[:, :6]),
                               np.asarray(part[:, :6]), atol=1e-6)
    # streaming: two halves with state == full
    a, st = rg.conv1d_apply(p, x[:, :6])
    b, _ = rg.conv1d_apply(p, x[:, 6:], st)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([a, b], 1)),
                               np.asarray(full), atol=1e-6)


def test_mlstm_chunked_matches_recurrent(rng_key):
    ks = jax.random.split(rng_key, 5)
    B, H, T, hd = 2, 2, 37, 8
    q = jax.random.normal(ks[0], (B, H, T, hd))
    k = jax.random.normal(ks[1], (B, H, T, hd))
    v = jax.random.normal(ks[2], (B, H, T, hd))
    li = jax.random.normal(ks[3], (B, H, T))
    lf = jax.nn.log_sigmoid(jax.random.normal(ks[4], (B, H, T)) + 1.0)
    h1, s1 = xl.mlstm_recurrent(q, k, v, li, lf)
    h2, s2 = xl.mlstm_chunked(q, k, v, li, lf, chunk=8)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), rtol=3e-4,
                               atol=3e-4)
    for a, b in zip(s1, s2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=3e-4, atol=3e-4)


def test_slstm_state_streaming(rng_key):
    cfg = reduced(get_arch("xlstm-125m"))
    p = xl.init_slstm_block(rng_key, cfg)["cell"]
    x = jax.random.normal(jax.random.fold_in(rng_key, 1), (2, 16,
                                                           cfg.d_model))
    full, _ = xl.slstm_apply(p, x)
    a, st = xl.slstm_apply(p, x[:, :9])
    b, _ = xl.slstm_apply(p, x[:, 9:], st)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([a, b], 1)),
                               np.asarray(full), rtol=1e-4, atol=1e-4)
