"""Tier-1 smoke of the multi-chip dry-run harness
(``repro.launch.multichip``): runs it in a SUBPROCESS — the module must
pin ``--xla_force_host_platform_device_count=8`` before jax initializes,
which an in-process import can't do once the test session's jax is up —
and asserts the full report: 8 emulated devices, H1 (no square buffer in
the masked sharded module), wire-collective layout with s8 lanes, JX3
donation aliasing, and mesh-vs-emulation parity for sharded AND
distributed under dropout."""
import json
import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_multichip_dry_run_smoke(tmp_path):
    out = tmp_path / "multichip.json"
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("XLA_FLAGS", None)          # the module pins its own devices
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.multichip",
         "--k", "512", "--parity-k", "32", "--out", str(out)],
        capture_output=True, text=True, env=env, cwd=REPO, timeout=420)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    rep = json.loads(out.read_text())
    assert rep["devices"] == 8
    for section in ("sharded", "distributed", "parity"):
        assert rep[section]["violations"] == [], section
    assert rep["sharded"]["collectives"].get("all-gather", 0) > 0
    assert "s8" in rep["sharded"]["wire_dtypes"]
    assert rep["distributed"]["collectives"].get(
        "collective-permute", 0) > 0
    assert rep["distributed"]["schedule_slots"] > 0
