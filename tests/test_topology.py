"""Topology engine: graph-family invariants, Eq.-(6) mixing on each
family, Eq.-(11) link pricing (incl. the 4-agent cluster regression for
the old hard-coded 2-robot link count), and the sparse/Pallas consensus
paths vs the kernel oracle on every family."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import consensus, energy
from repro.core import topology as topo_lib
from repro.core.multitask import ClusterNetwork
from repro.kernels import ref


def _make(name, K=12):
    return topo_lib.make(name, K)


# ---------------------------------------------------------------------------
# structural invariants
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", topo_lib.FAMILIES)
def test_family_structure(name):
    t = _make(name)
    A = t.adjacency
    assert A.shape == (12, 12) and A.dtype == bool
    assert not A.diagonal().any()
    assert ((t.link_class != 0) == A).all()
    assert t.directed_links == int(A.sum())
    assert sum(t.links_per_round().values()) == t.directed_links
    # undirected support is symmetric for every family (star pairs UL/DL)
    assert ((A | A.T) == (A | A.T).T).all()
    # every agent has at least one neighbour
    assert (t.degrees >= 1).all()
    if name != "cluster":          # per-task clusters are disjoint on purpose
        assert t.is_connected()


def test_link_classes_by_family():
    assert _make("ring").links_per_round() == {"SL": 24, "UL": 0, "DL": 0}
    # star: K-1 uploads to the hub + K-1 downloads from it, zero sidelink
    assert _make("star").links_per_round() == {"SL": 0, "UL": 11, "DL": 11}
    # hierarchical 3×4: 3 clusters × 4·3 SL + gateway ring 3×2 UL
    h = topo_lib.hierarchical(3, 4)
    assert h.links_per_round() == {"SL": 36, "UL": 6, "DL": 0}
    # paper clusters: per-cluster all-to-all sidelink
    c = topo_lib.clusters(6, 2)
    assert c.links_per_round() == {"SL": 12, "UL": 0, "DL": 0}
    assert c.K == 12


def test_cluster_network_adapter():
    net = ClusterNetwork(num_tasks=6, devices_per_cluster=2,
                         meta_task_ids=(0, 1, 5))
    t = net.topology()
    np.testing.assert_array_equal(t.adjacency, net.adjacency())
    assert net.cluster_topology().K == 2


def test_torus_and_small_world_shapes():
    t = topo_lib.torus(3, 4)
    assert t.K == 12 and (t.degrees == 4).all()
    sw = topo_lib.small_world(16, k=4, rewire_p=0.3, seed=1)
    assert sw.is_symmetric and sw.is_connected()
    # same seed ⇒ same graph (deterministic rewiring)
    sw2 = topo_lib.small_world(16, k=4, rewire_p=0.3, seed=1)
    np.testing.assert_array_equal(sw.adjacency, sw2.adjacency)


# ---------------------------------------------------------------------------
# mixing + consensus on each family
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", topo_lib.FAMILIES)
def test_mixing_rows_substochastic(name):
    t = _make(name)
    M = np.asarray(t.mixing(np.arange(1.0, 13.0)))
    assert (M >= 0).all()
    assert (M.sum(axis=1) <= 1 + 1e-5).all()
    assert (np.diag(M) == 0).all()
    assert (M[~t.adjacency] == 0).all()


@pytest.mark.parametrize("name",
                         [n for n in topo_lib.FAMILIES if n != "cluster"])
def test_consensus_converges_on_family(name, rng_key):
    t = _make(name)
    s = {"w": jax.random.normal(rng_key, (t.K, 4, 3))}
    M = t.mixing(kind="metropolis")
    e0 = float(consensus.consensus_error(s))
    for _ in range(300):
        s = consensus.consensus_step(s, M)
    assert float(consensus.consensus_error(s)) < 1e-4 * max(e0, 1.0)


@pytest.mark.parametrize("name", topo_lib.FAMILIES)
def test_sparse_paths_match_oracle_per_family(name, rng_key):
    """The forced Pallas path (interpret on CPU) must match
    ref.consensus_update_reference on EVERY family; auto must be BIT-equal
    to the oracle wherever it takes the sparse route (on dense graphs —
    star, full — it falls back to the dense matmul, fp-close only); and
    all paths must agree with the dense matmul."""
    t = _make(name)
    mix = t.mixing(np.arange(1.0, t.K + 1.0))
    x = {"w": jax.random.normal(rng_key, (t.K, 5, 3)),
         "b": jax.random.normal(jax.random.fold_in(rng_key, 1), (t.K, 7))}
    dense = consensus.consensus_step(x, mix, impl="xla")
    auto = consensus.consensus_step(x, mix, impl="auto")
    pallas = consensus.consensus_step(x, mix, impl="pallas", block_n=64)
    idx, sig = consensus.sparse_structure(mix)
    for leaf in x:
        xf = np.asarray(x[leaf], np.float32).reshape(t.K, -1)
        want = np.stack([np.asarray(ref.consensus_update_reference(
            jnp.asarray(xf[k]), jnp.asarray(xf[idx[k]]),
            jnp.asarray(sig[k]))) for k in range(t.K)])
        got_auto = np.asarray(auto[leaf]).reshape(t.K, -1)
        if consensus.auto_path(mix) == "sparse":
            np.testing.assert_array_equal(got_auto, want)
        else:
            np.testing.assert_allclose(got_auto, want, rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(
            np.asarray(pallas[leaf]).reshape(t.K, -1), want, atol=1e-6)
        np.testing.assert_allclose(
            np.asarray(dense[leaf]).reshape(t.K, -1), want,
            rtol=1e-5, atol=1e-5)


def test_auto_path_density_heuristic():
    assert consensus.auto_path(topo_lib.ring(256).mixing()) == "sparse"
    assert consensus.auto_path(topo_lib.star(256).mixing()) == "dense"
    assert consensus.auto_path(topo_lib.full(16).mixing()) == "dense"
    assert consensus.auto_path(
        topo_lib.clusters(64, 4).mixing()) == "sparse"


def test_consensus_step_accepts_topology():
    t = topo_lib.ring(6)
    x = {"w": jnp.arange(18.0).reshape(6, 3)}
    via_topo = consensus.consensus_step(x, t)
    via_mix = consensus.consensus_step(x, t.mixing())
    np.testing.assert_allclose(np.asarray(via_topo["w"]),
                               np.asarray(via_mix["w"]))


# ---------------------------------------------------------------------------
# Eq.-(11) link pricing
# ---------------------------------------------------------------------------


def test_fl_comm_energy_four_agent_cluster_regression():
    """A 4-agent all-to-all cluster has 4·3 = 12 directed SL messages per
    round. The old hard-coded ``devices_per_cluster × neighbors_per_device``
    (= 4·1) under-priced it 3×."""
    p = dataclasses.replace(energy.paper_calibrated("fig3"),
                            devices_per_cluster=4)
    c4 = topo_lib.clusters(1, 4)
    t_i = 17
    want = p.model_bits * t_i * 12 / p.E_SL
    assert np.isclose(energy.fl_comm_energy(p, t_i, topology=c4), want)
    legacy = energy.fl_comm_energy(p, t_i)            # no topology supplied
    assert np.isclose(legacy, want / 3.0)
    # learning term follows the graph's population too
    assert np.isclose(energy.fl_learning_energy(p, t_i, topology=c4),
                      t_i * 4 * p.B_i * p.Ek_C)


def test_fl_comm_energy_two_robot_cluster_matches_legacy():
    """For the paper's own 2-robot clusters the topology pricing must agree
    with the legacy constants (2 directed SL messages per round)."""
    p = energy.paper_calibrated("fig3")
    c2 = topo_lib.clusters(1, 2)
    for t_i in (1, 17, 210):
        assert np.isclose(energy.fl_energy(p, t_i, topology=c2),
                          energy.fl_energy(p, t_i))


def test_star_priced_as_uplink_downlink():
    p = energy.paper_calibrated("fig3")
    s = topo_lib.star(5)
    want = p.model_bits * (4 / p.E_UL + 4 / p.E_DL)
    assert np.isclose(s.round_comm_joules(p), want)


def test_sidelink_fallback_applies_to_topology_pricing():
    p = dataclasses.replace(energy.paper_calibrated("fig3"),
                            sidelink_available=False)
    r = topo_lib.ring(6)
    want = p.model_bits * 12 * (1 / p.E_UL + p.gamma / p.E_DL)
    assert np.isclose(r.round_comm_joules(p), want)


def test_total_energy_threads_topology():
    p = energy.paper_calibrated("fig3")
    c4 = topo_lib.clusters(1, 4)
    tis = [10.0, 20.0]
    want = energy.maml_energy(p, 5, 3) + sum(
        energy.fl_energy(p, t, c4) for t in tis)
    assert np.isclose(energy.total_energy(p, 5, 3, tis, topology=c4), want)


# ---------------------------------------------------------------------------
# trainer integration: Eq.-(11) joules derived from the topology
# ---------------------------------------------------------------------------


def test_train_federated_prices_four_agent_cluster():
    from repro.configs import get_arch, reduced
    from repro.launch.train import train_federated
    cfg = reduced(get_arch("stablelm-3b"), num_layers=1, d_model=32)
    rounds, agents, tasks, local_steps = 1, 4, 1, 1
    stacked, hist, E = train_federated(
        cfg, rounds=rounds, agents=agents, tasks=tasks,
        local_steps=local_steps, batch=2, seq=16, lr=1e-3)
    n_bytes = sum(x.size // agents * x.dtype.itemsize
                  for x in jax.tree.leaves(stacked))
    ep = dataclasses.replace(
        energy.paper_calibrated("fig3"), model_bits=float(n_bytes) * 8,
        devices_per_cluster=agents // tasks, B_i=local_steps)
    want = tasks * energy.fl_energy(ep, rounds,
                                    topology=topo_lib.clusters(1, 4))
    assert np.isclose(E, want)
    # and the comm share reflects 12 links, not the legacy 4
    assert energy.fl_comm_energy(ep, rounds, topo_lib.clusters(1, 4)) \
        == pytest.approx(3 * energy.fl_comm_energy(ep, rounds))


# ---------------------------------------------------------------------------
# per-edge link efficiencies (heterogeneous bandwidth)
# ---------------------------------------------------------------------------


def test_edge_efficiency_uniform_matches_class_constant():
    p = energy.paper_calibrated("fig3")
    r = topo_lib.ring(6)
    het = r.with_edge_efficiency(p.E_SL)     # scalar: every edge at E_SL
    assert np.isclose(het.round_comm_joules(p), r.round_comm_joules(p))


def test_edge_efficiency_per_edge_sum():
    """Eq.-(11) must SUM per edge: one slow link dominates the round."""
    p = energy.paper_calibrated("fig3")
    r = topo_lib.ring(4)
    eff = np.where(r.adjacency, p.E_SL, 0.0)
    eff[0, 1] = eff[1, 0] = p.E_SL / 100.0   # one degraded pair
    het = r.with_edge_efficiency(eff)
    base = r.round_comm_joules(p)
    # 8 links: 6 at 1/E_SL, 2 at 100/E_SL ⇒ (6 + 200)/8 × the uniform cost
    assert np.isclose(het.round_comm_joules(p), base * (6 + 200) / 8)
    # codec pricing composes with per-edge efficiencies
    assert np.isclose(het.round_comm_joules(p, codec="int8"),
                      base * (6 + 200) / 8 / 4)


def test_edge_efficiency_partial_override_falls_back_to_class():
    p = energy.paper_calibrated("fig3")
    s = topo_lib.star(4)                     # 3 UL + 3 DL messages
    eff = np.zeros((4, 4))
    eff[0, 1] = 2 * p.E_UL                   # one upload twice as efficient
    het = s.with_edge_efficiency(eff)
    want = p.model_bits * (1 / (2 * p.E_UL) + 2 / p.E_UL + 3 / p.E_DL)
    assert np.isclose(het.round_comm_joules(p), want)


def test_edge_efficiency_validation():
    r = topo_lib.ring(4)
    with pytest.raises(ValueError):          # wrong shape
        r.with_edge_efficiency(np.ones((3, 3)))
    with pytest.raises(ValueError):          # efficiency off the edge set
        topo_lib.Topology("bad", r.adjacency, r.link_class,
                          edge_efficiency=np.ones((4, 4)))


# ---------------------------------------------------------------------------
# time-varying topologies: per-round link dropout
# ---------------------------------------------------------------------------


def test_dropout_sequence_structure():
    t = topo_lib.ring(12, hops=2)
    seq = topo_lib.dropout(t, 0.3, seed=7, rounds=20)
    assert len(seq) == 20
    for rt in seq:
        # dropped graphs are subgraphs with classes preserved on survivors
        assert not (rt.adjacency & ~t.adjacency).any()
        assert (rt.link_class[rt.adjacency]
                == t.link_class[rt.adjacency]).all()
        assert rt.is_symmetric                   # pairs drop together
    # deterministic in the seed, and p=0 is the identity
    seq2 = topo_lib.dropout(t, 0.3, seed=7, rounds=20)
    for a, b in zip(seq, seq2):
        np.testing.assert_array_equal(a.adjacency, b.adjacency)
    for rt in topo_lib.dropout(t, 0.0, seed=0, rounds=3):
        np.testing.assert_array_equal(rt.adjacency, t.adjacency)
    with pytest.raises(ValueError):
        topo_lib.dropout(t, 1.0)


def test_dropout_consensus_ring_reaches_oracle_mean(rng_key):
    """Consensus over a ring with 20% per-round link dropout still reaches
    the oracle mean of the initial models (metropolis weights are doubly
    stochastic on EVERY surviving subgraph, so the mean is invariant and
    the union graph's connectivity drives contraction)."""
    K = 8
    s = {"w": jax.random.normal(rng_key, (K, 4, 3))}
    mean0 = np.asarray(s["w"]).mean(axis=0)
    for rt in topo_lib.dropout(topo_lib.ring(K), 0.2, seed=11, rounds=400):
        s = consensus.consensus_step(s, rt.mixing(kind="metropolis"))
    np.testing.assert_allclose(np.asarray(s["w"][0]), mean0, atol=1e-4)
    assert float(consensus.consensus_error(s)) < 1e-9


def test_dropout_rounds_price_only_sent_messages():
    p = energy.paper_calibrated("fig3")
    t = topo_lib.ring(6)
    seq = topo_lib.dropout(t, 0.5, seed=1, rounds=50)
    per_round = [rt.round_comm_joules(p) for rt in seq]
    full = t.round_comm_joules(p)
    assert all(j <= full + 1e-9 for j in per_round)
    # ~half the links survive on average ⇒ mean cost well below the full
    assert np.mean(per_round) < 0.8 * full
