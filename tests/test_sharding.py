"""Sharding-rule contract tests (pure PartitionSpec logic, no multi-device
mesh needed) + the HLO collective-bytes parser + gridworld/DQN units +
optim/data/energy glue."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_arch, reduced
from repro.models.api import get_model
from repro.sharding import rules


def _specs_for(arch, model_size=16):
    cfg = get_arch(arch)
    rcfg = reduced(cfg)
    model = get_model(rcfg)
    params = jax.eval_shape(lambda k: model.init(k, rcfg),
                            jax.random.PRNGKey(0))
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    out = {}
    for path, leaf in flat:
        names = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                         for p in path)
        out[names] = rules.param_spec(path, leaf, rcfg,
                                      model_size=model_size)
    return out


def test_dense_param_specs():
    specs = _specs_for("granite-8b", model_size=2)
    assert specs["embed"] == P("model", None)       # vocab 512 % 2 == 0
    assert specs["blocks/attn/wq"] == P(None, None, "model", None)
    assert specs["blocks/mlp/w_gate"] == P(None, None, "model")
    assert specs["blocks/mlp/w_down"] == P(None, "model", None)
    assert specs["blocks/attn_norm"] == P(None, None)   # replicated


def test_moe_param_specs():
    specs = _specs_for("mixtral-8x7b", model_size=2)
    # stacked (L, E, d, f): shard f
    assert specs["blocks/mlp/w_gate"] == P(None, None, None, "model")
    assert specs["blocks/mlp/w_down"] == P(None, None, "model", None)
    assert specs["blocks/mlp/router"] == P(None, None, None)


def test_divisibility_fallback():
    """A model_size that divides nothing must yield full replication."""
    specs = _specs_for("granite-8b", model_size=7)
    for name, s in specs.items():
        assert all(x is None for x in s), (name, s)


def test_stack_vs_tuple_path_detection():
    # xlstm params are tuple-of-blocks (digit in path) -> no stack offset
    specs = _specs_for("xlstm-125m", model_size=2)
    keys = [k for k in specs if "w_up" in k]
    assert keys, "expected xlstm w_up leaves"
    for k in keys:
        assert any(part.isdigit() for part in k.split("/"))


# ---------------------------------------------------------------------------
# HLO collective parser
# ---------------------------------------------------------------------------


def test_collective_bytes_parser():
    from repro.launch.hlo_analysis import collective_bytes
    hlo = """
  %ag = f32[8,128]{1,0} all-gather(f32[1,128] %x), replica_groups={}
  %ar.1 = bf16[256]{0} all-reduce(bf16[256] %y), to_apply=%add
  %rs = f32[2,64]{1,0} reduce-scatter(f32[16,64] %z), dimensions={0}
  %aa = (f32[4,4]{1,0}, f32[4,4]{1,0}) all-to-all(f32[4,4] %a, f32[4,4] %b)
  %cp = u32[10]{0} collective-permute(u32[10] %c), source_target_pairs={{0,1}}
  %ags = f32[64]{0} all-gather-start(f32[8] %w)
  %agd = f32[64]{0} all-gather-done(f32[64] %ags)
  %not = f32[999]{0} add(f32[999] %p, f32[999] %q)
"""
    got = collective_bytes(hlo)
    assert got["all-gather"] == 8 * 128 * 4 + 64 * 4   # ag + ag-start
    assert got["all-reduce"] == 256 * 2
    assert got["reduce-scatter"] == 2 * 64 * 4
    assert got["all-to-all"] == 2 * 16 * 4
    assert got["collective-permute"] == 10 * 4


# ---------------------------------------------------------------------------
# gridworld / DQN
# ---------------------------------------------------------------------------


def test_gridworld_step_and_rewards():
    from repro.rl import gridworld as gw
    pos = jnp.array([0, 2])
    new, r = gw.step(pos, jnp.int32(0), 0)     # F from entry
    assert tuple(np.asarray(new)) == (1, 2)
    assert float(r) > 0                          # on task-0 trajectory
    # walls clamp
    new, _ = gw.step(jnp.array([0, 0]), jnp.int32(1), 0)  # B at edge
    assert tuple(np.asarray(new)) == (0, 0)
    # every task's trajectory is strictly positive reward on-path
    for tid in range(gw.NUM_TASKS):
        for (x, y) in gw.TRAJECTORIES[tid]:
            assert float(gw.REWARD_TABLES[tid, x, y]) >= 5.0


def test_running_reward_discounting():
    from repro.rl import gridworld as gw
    r = jnp.ones((1, 10))
    R = gw.running_reward(r, nu=0.5)
    assert abs(float(R[0]) - (1 - 0.5 ** 10) / 0.5 * 0.5 / (1 - 0.5) * (1 - 0.5)) < 2.1
    np.testing.assert_allclose(float(R[0]),
                               sum(0.5 ** h for h in range(10)), rtol=1e-5)


def test_double_dqn_loss_uses_target_net(rng_key):
    from repro.configs import get_arch
    from repro.models import dqn as qm
    from repro.rl import dqn as rl
    cfg = get_arch("paper-dqn")
    p = qm.init(rng_key, cfg)
    tp = qm.init(jax.random.fold_in(rng_key, 1), cfg)
    batch = {
        "state": jax.nn.one_hot(jnp.array([3, 7]), 40),
        "action": jnp.array([0, 2]),
        "reward": jnp.array([1.0, 0.0]),
        "next_state": jax.nn.one_hot(jnp.array([4, 8]), 40),
    }
    l_online = float(rl.td_loss(p, cfg, batch, target_params=p))
    l_target = float(rl.td_loss(p, cfg, batch, target_params=tp))
    assert l_online != pytest.approx(l_target)


# ---------------------------------------------------------------------------
# optim
# ---------------------------------------------------------------------------


def test_adam_beats_sgd_on_quadratic(rng_key):
    from repro.optim import adam, apply_updates, sgd
    target = jax.random.normal(rng_key, (16,))

    def loss(p):
        return jnp.sum((p["x"] - target) ** 2)

    for opt_name, opt in (("sgd", sgd(0.05)), ("adam", adam(0.1))):
        p = {"x": jnp.zeros(16)}
        st = opt.init(p)
        for _ in range(200):
            g = jax.grad(loss)(p)
            upd, st = opt.update(g, st, p)
            p = apply_updates(p, upd)
        assert float(loss(p)) < 1e-2, opt_name


def test_clip_by_global_norm():
    from repro.optim import clip_by_global_norm, global_norm
    t = {"a": jnp.full((4,), 10.0)}
    clipped, n = clip_by_global_norm(t, 1.0)
    assert float(n) == pytest.approx(20.0)
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)


def test_schedules():
    from repro.optim import warmup_cosine
    f = warmup_cosine(1.0, warmup=10, steps=110)
    assert float(f(jnp.int32(0))) == 0.0
    assert float(f(jnp.int32(10))) == pytest.approx(1.0)
    assert float(f(jnp.int32(110))) < 0.2


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------


def test_task_streams_are_learnably_different():
    """Per-task Markov chains must differ across tasks but be deterministic
    per (seed, task)."""
    from repro.data import TaskTokenDistribution
    d = TaskTokenDistribution(vocab_size=512, num_tasks=4)
    P0 = d.transition(0)
    P0b = d.transition(0)
    P1 = d.transition(1)
    np.testing.assert_array_equal(P0, P0b)
    assert np.abs(P0 - P1).max() > 1e-3
    np.testing.assert_allclose(P0.sum(1), 1.0, rtol=1e-6)
    x, y = d.sample(jax.random.PRNGKey(0), 0, 2, 16)
    np.testing.assert_array_equal(np.asarray(x[:, 1:]),
                                  np.asarray(y[:, :-1]))
