"""Degrade ``hypothesis`` to fixed-seed ``pytest.parametrize`` when absent.

The tier-1 suite must COLLECT and PASS with or without hypothesis
installed (the container image does not bake it in; the ``[test]`` extra
in pyproject.toml pins it for CI). When hypothesis is available the real
``@given`` runs untouched; otherwise each ``@given`` test is expanded to
``_EXAMPLES`` deterministic draws from the same strategy bounds, so the
property still gets exercised over a spread of inputs — just a fixed one.
"""
try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    import numpy as np
    import pytest

    _EXAMPLES = 5
    _SEED = 0xC0FFEE

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def example(self, rng):
            return self._draw(rng)

    class st:  # noqa: N801 — mirrors ``hypothesis.strategies`` usage
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                lambda rng: int(rng.integers(min_value, max_value + 1)))

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(
                lambda rng: float(rng.uniform(min_value, max_value)))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: bool(rng.integers(0, 2)))

        @staticmethod
        def sampled_from(elements):
            seq = list(elements)
            return _Strategy(lambda rng: seq[int(rng.integers(len(seq)))])

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn

        return deco

    def given(**strats):
        names = list(strats)

        def deco(fn):
            rng = np.random.default_rng(_SEED)
            cases = [tuple(strats[n].example(rng) for n in names)
                     for _ in range(_EXAMPLES)]
            return pytest.mark.parametrize(",".join(names), cases)(fn)

        return deco
