"""Consensus FL (Eq. 6) semantics + hypothesis property tests on the
mixing-matrix invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import consensus


def _stacked(key, K, shape=(5, 3)):
    return {"w": jax.random.normal(key, (K,) + shape),
            "b": jax.random.normal(jax.random.fold_in(key, 1), (K, 7))}


# ---------------------------------------------------------------------------
# property tests: mixing matrices
# ---------------------------------------------------------------------------


@settings(deadline=None, max_examples=30)
@given(K=st.integers(3, 12), hops=st.integers(1, 2),
       seed=st.integers(0, 2 ** 16))
def test_paper_weights_row_substochastic(K, hops, seed):
    rng = np.random.default_rng(seed)
    sizes = rng.uniform(0.5, 10.0, K)
    A = consensus.ring_adjacency(K, min(hops, (K - 1) // 2))
    M = np.asarray(consensus.mixing_weights(sizes, A, "paper"))
    assert (M >= 0).all()
    rows = M.sum(axis=1)
    assert (rows <= 1.0 + 1e-5).all()          # self weight >= 0
    assert (np.diag(M) == 0).all()             # σ only on neighbours


@settings(deadline=None, max_examples=30)
@given(K=st.integers(3, 12), seed=st.integers(0, 2 ** 16))
def test_metropolis_doubly_stochastic(K, seed):
    rng = np.random.default_rng(seed)
    sizes = rng.uniform(0.5, 10.0, K)
    A = consensus.ring_adjacency(K, 1)
    M = np.asarray(consensus.mixing_weights(sizes, A, "metropolis"))
    np.testing.assert_allclose(M.sum(axis=0), 1.0, atol=1e-5)
    np.testing.assert_allclose(M.sum(axis=1), 1.0, atol=1e-5)
    np.testing.assert_allclose(M, M.T, atol=1e-6)


@settings(deadline=None, max_examples=20)
@given(K=st.integers(2, 10), seed=st.integers(0, 2 ** 16))
def test_consensus_preserves_fixed_point(K, seed):
    """If all agents agree already, one round changes nothing."""
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(4, 3)).astype(np.float32)
    stacked = {"w": jnp.asarray(np.stack([x] * K))}
    sizes = rng.uniform(0.5, 5.0, K)
    M = consensus.mixing_weights(sizes, consensus.full_adjacency(K),
                                 "paper")
    out = consensus.consensus_step(stacked, M)
    np.testing.assert_allclose(np.asarray(out["w"]),
                               np.asarray(stacked["w"]), atol=1e-5)


# ---------------------------------------------------------------------------
# convergence
# ---------------------------------------------------------------------------


def test_consensus_converges_ring(rng_key):
    K = 8
    s = _stacked(rng_key, K)
    sizes = np.arange(1.0, K + 1)
    M = consensus.mixing_weights(sizes, consensus.ring_adjacency(K, 1),
                                 "paper")
    e0 = float(consensus.consensus_error(s))
    for _ in range(120):
        s = consensus.consensus_step(s, M)
    assert float(consensus.consensus_error(s)) < 1e-8 * max(e0, 1.0)


def test_literal_eq6_swaps_for_two_agents(rng_key):
    """The literal Eq. (6) reading (zero self-weight) is a pure swap for
    the paper's 2-robot clusters — documented non-convergent corner."""
    s = _stacked(rng_key, 2)
    M = consensus.mixing_weights(
        [1.0, 1.0], consensus.full_adjacency(2), "paper",
        include_self=False)
    out = consensus.consensus_step(s, M)
    np.testing.assert_allclose(np.asarray(out["w"][0]),
                               np.asarray(s["w"][1]), atol=1e-6)
    np.testing.assert_allclose(np.asarray(out["w"][1]),
                               np.asarray(s["w"][0]), atol=1e-6)


def test_metropolis_converges_to_mean(rng_key):
    K = 6
    s = _stacked(rng_key, K)
    mean0 = np.asarray(s["w"]).mean(axis=0)
    M = consensus.mixing_weights(np.ones(K),
                                 consensus.ring_adjacency(K, 1),
                                 "metropolis")
    for _ in range(300):
        s = consensus.consensus_step(s, M)
    np.testing.assert_allclose(np.asarray(s["w"][0]), mean0, atol=1e-4)


def test_cluster_ring_matches_dense_on_cluster_adjacency(rng_key):
    """The distributed cluster-ring path (ppermute collectives, here run
    under vmap-with-axis_name, which shares the shard_map collective
    semantics) must produce the SAME params as the dense consensus_step on
    the cluster adjacency after one round (K=4, cluster_size=2)."""
    from repro.core import topology as topo_lib
    K, cluster = 4, 2
    s = _stacked(rng_key, K)
    sizes = jnp.asarray([1.0, 2.0, 3.0, 4.0])

    ring_out = jax.vmap(
        lambda p, d: consensus.cluster_ring_consensus_step(
            p, d, "agents", cluster_size=cluster),
        axis_name="agents")(s, sizes)

    mix = topo_lib.clusters(K // cluster, cluster).mixing(np.asarray(sizes))
    dense_out = consensus.consensus_step(s, mix)

    for leaf in s:
        np.testing.assert_allclose(np.asarray(ring_out[leaf]),
                                   np.asarray(dense_out[leaf]),
                                   rtol=1e-6, atol=1e-6)


def test_consensus_impl_switch_rejects_unknown(rng_key):
    s = _stacked(rng_key, 4)
    M = consensus.mixing_weights(np.ones(4), consensus.full_adjacency(4),
                                 "paper")
    with pytest.raises(ValueError):
        consensus.consensus_step(s, M, impl="bogus")


def test_kernel_consensus_matches_dense(rng_key):
    """The fused Pallas consensus kernel == one row of consensus_step."""
    from repro.kernels import ops
    K = 4
    s = _stacked(rng_key, K)
    sizes = np.array([1.0, 2.0, 3.0, 4.0])
    M = consensus.mixing_weights(sizes, consensus.full_adjacency(K),
                                 "paper")
    dense = consensus.consensus_step(s, M)
    # agent 0 via the kernel
    flat = jnp.concatenate([s["w"][0].ravel(), s["b"][0].ravel()])
    nb = jnp.stack([jnp.concatenate([s["w"][h].ravel(), s["b"][h].ravel()])
                    for h in range(1, K)])
    out = ops.consensus_update(flat, nb, jnp.asarray(M)[0, 1:],
                               impl="interpret", block_n=64)
    want = jnp.concatenate([dense["w"][0].ravel(), dense["b"][0].ravel()])
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
