"""Chaos harness: availability fault injection swept over (mode x seed
x plan). Every configuration drives engine.scan_rounds with buffered
telemetry under agent churn (plus per-link dropout), then asserts the
graceful-degradation contract:

* no NaN/Inf anywhere in the mixed params, no shape divergence;
* activity observability: each round's ``n_active`` equals the host
  availability replay's count, bit for bit;
* the summed Eq.-(11) telemetry stream reconciles EXACTLY (``==``, not
  approx) with a host-side replay that bills only wires whose link
  survived AND whose both endpoints were awake.

The seed matrix widens via ``REPRO_CHAOS_SEEDS`` (comma-separated ints;
CI sets it explicitly, default "0,1")."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import telemetry as telemetry_lib
from repro.core import energy
from repro.core import topology as topo_lib
from repro.core.engine import ConsensusEngine

K, ROUNDS, DROP_P, DROP_SEED = 8, 10, 0.2, 3

SEEDS = [int(s) for s in
         os.environ.get("REPRO_CHAOS_SEEDS", "0,1").split(",")]

PLANS = [("dense-xla", {}),
         ("sparse-pallas", {}),
         ("sharded", {"num_blocks": 4}),
         ("distributed", {})]

MODES = {
    "bernoulli": lambda seed: topo_lib.AgentProcess.bernoulli(
        0.6, seed=seed),
    "straggler": lambda seed: topo_lib.AgentProcess.straggler(
        K, tail=1.1, scale=0.3, cap=0.9, seed=seed),
    "arrival": lambda seed: topo_lib.AgentProcess.arrival(
        np.arange(K, dtype=np.int64) * (1 + seed % 2)),
    "departure": lambda seed: topo_lib.AgentProcess.departure(
        ROUNDS - np.arange(K, dtype=np.int64)),
}


def _topo():
    return topo_lib.ring(K)


def _stacked(seed):
    k = jax.random.PRNGKey(100 + seed)
    return {"w": jax.random.normal(k, (K, 6)),
            "b": jax.random.normal(jax.random.fold_in(k, 1), (K, 3))}


def _host_replay_joules(topo, proc, codec, rounds):
    """The post-hoc bill: per round, a wire is priced iff its link
    survived the fade AND both endpoints were awake — summed
    left-to-right in float64 exactly like the stream."""
    ep = energy.paper_calibrated("fig3")
    drops = topo_lib.dropout(topo, DROP_P, seed=DROP_SEED, rounds=rounds)
    acts = topo_lib.availability_stream(proc, topo.K, rounds)
    total = 0.0
    for t_r, a in zip(drops, acts):
        m = (np.asarray(t_r.adjacency)
             & a[:, None] & a[None, :])
        billed = topo_lib.Topology(
            f"{topo.name}~billed", m,
            np.where(m, np.asarray(topo.link_class), topo_lib.NONE))
        total += billed.round_comm_joules(ep, codec=codec)
    return total


@pytest.mark.parametrize("plan,kw", PLANS, ids=[p for p, _ in PLANS])
@pytest.mark.parametrize("mode", sorted(MODES))
def test_chaos_sweep_no_divergence_and_exact_ledger(mode, plan, kw):
    topo = _topo()
    for seed in SEEDS:
        proc = MODES[mode](seed)
        eng = ConsensusEngine(
            topo, codec="int8", plan=plan,
            graph=topo_lib.GraphProcess.dropout(DROP_P, seed=DROP_SEED),
            agents=proc, tau=3, staleness_decay=0.9, **kw)
        tel = telemetry_lib.Telemetry()
        s = _stacked(seed)
        p, st = eng.scan_rounds(s, rounds=ROUNDS, telemetry=tel,
                                keys=jax.random.split(
                                    jax.random.PRNGKey(seed), ROUNDS))
        # no NaN/Inf, no shape divergence
        for ref, out in zip(jax.tree.leaves(s), jax.tree.leaves(p)):
            out = np.asarray(out)
            assert out.shape == ref.shape, f"{mode}/{plan}/seed={seed}"
            assert np.isfinite(out).all(), f"{mode}/{plan}/seed={seed}"
        events = tel.events(driver="consensus")
        assert len(events) == ROUNDS
        # activity observability: n_active replays bit for bit
        acts = topo_lib.availability_stream(proc, K, ROUNDS)
        for t, e in enumerate(events):
            assert e["n_active"] == int(acts[t].sum()), \
                f"{mode}/{plan}/seed={seed} t={t}"
            assert e["max_age"] >= 0
        # exact Eq.-(11) reconciliation: stream == host replay
        stream = 0.0
        for e in events:
            stream += e["joules"]
        replay = _host_replay_joules(topo, proc, eng.codec, ROUNDS)
        assert stream == replay, \
            f"{mode}/{plan}/seed={seed}: {stream!r} != {replay!r}"


def test_departure_of_everyone_goes_quiet_not_nan():
    """Total population death mid-run: once every agent has left, all
    remaining rounds bill zero and params freeze — no NaNs from the
    empty-neighbourhood σ renormalization."""
    proc = topo_lib.AgentProcess.departure(np.full(K, 3))
    eng = ConsensusEngine(_topo(), agents=proc, tau=2)
    tel = telemetry_lib.Telemetry()
    s = _stacked(0)
    p, _ = eng.scan_rounds(s, rounds=ROUNDS, telemetry=tel)
    assert all(np.isfinite(np.asarray(x)).all()
               for x in jax.tree.leaves(p))
    events = tel.events(driver="consensus")
    for e in events[3:]:
        assert e["n_active"] == 0
        assert e["joules"] == 0.0
        assert e["edges"] == 0
