"""Regression harness for ``repro.analysis`` — every rule must fire on a
seeded violation with the right rule ID and file:line, and stay silent on
the blessed counterpart."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.findings import (Finding, apply_allowlist,
                                     load_allowlist, parse_toml_min)
from repro.analysis.jaxpr_audit import (alias_param_indices,
                                        audit_registered_programs,
                                        check_donation,
                                        find_callbacks,
                                        find_decode_then_combine,
                                        has_int_lane_gather)
from repro.analysis.lint import lint_file, run_lint
from repro.core import scanloop

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
THIS_FILE = os.path.abspath(__file__)


def _lint_src(tmp_path, src: str, rel: str):
    p = tmp_path / os.path.basename(rel)
    p.write_text(src, encoding="utf-8")
    return lint_file(str(p), rel)


def _rules(findings):
    return {f.rule for f in findings}


# ---------------------------------------------------------------------------
# Layer 3: AST lint rules on seeded violations
# ---------------------------------------------------------------------------

class TestLintR1:
    SRC = (
        "import jax\n"
        "def edge_mask(key, t):\n"
        "    k = jax.random.fold_in(key, t)\n"
        "    return jax.random.uniform(jax.random.fold_in(k, 7), (4,))\n")

    def test_fires_with_line(self, tmp_path):
        out = _lint_src(tmp_path, self.SRC, "src/repro/core/fake_edges.py")
        hits = [f for f in out if f.rule == "R1"]
        assert len(hits) == 1
        assert hits[0].line == 4
        assert hits[0].file == "src/repro/core/fake_edges.py"
        assert "survival_mask" in hits[0].message

    def test_definition_site_exempt(self, tmp_path):
        src = self.SRC.replace("def edge_mask", "def survival_mask")
        out = _lint_src(tmp_path, src, "src/repro/core/topology.py")
        assert "R1" not in _rules(out)

    def test_bernoulli_counts(self, tmp_path):
        src = self.SRC.replace("jax.random.uniform", "jax.random.bernoulli")
        out = _lint_src(tmp_path, src, "benchmarks/fake_edges.py")
        assert "R1" in _rules(out)


class TestLintR2:
    SRC = (
        "import jax\n"
        "@jax.jit\n"
        "def step(p):\n"
        "    return p\n"
        "other = jax.jit(lambda x: x)\n")

    def test_fires_in_core_and_rl(self, tmp_path):
        for rel in ("src/repro/core/fake_mod.py", "src/repro/rl/fake_mod.py"):
            out = _lint_src(tmp_path, self.SRC, rel)
            hits = [f for f in out if f.rule == "R2"]
            assert sorted(h.line for h in hits) == [2, 5], rel

    def test_out_of_scope_and_gate_exempt(self, tmp_path):
        for rel in ("src/repro/launch/fake_mod.py",
                    "src/repro/core/scanloop.py"):
            out = _lint_src(tmp_path, self.SRC, rel)
            assert "R2" not in _rules(out), rel


class TestLintR3:
    SRC_BAD = (
        "rows = run()\n"
        "assert rows[-1]['us_per_round'] < 2.0\n")
    SRC_OK = (
        "import statistics\n"
        "rows = run()\n"
        "med = statistics.median(r['us_per_round'] for r in rows)\n"
        "assert med < 2.0 * 1.15\n")

    def test_single_shot_fires(self, tmp_path):
        out = _lint_src(tmp_path, self.SRC_BAD, "benchmarks/fake_bench.py")
        hits = [f for f in out if f.rule == "R3"]
        assert len(hits) == 1 and hits[0].line == 2

    def test_median_module_clean(self, tmp_path):
        out = _lint_src(tmp_path, self.SRC_OK, "benchmarks/fake_bench.py")
        assert "R3" not in _rules(out)

    def test_only_benchmarks_scope(self, tmp_path):
        out = _lint_src(tmp_path, self.SRC_BAD, "src/repro/core/fake.py")
        assert "R3" not in _rules(out)


class TestLintR4:
    SRC_BAD = (
        "def round(codec, leaf):\n"
        "    wire = codec.encode_leaf(leaf)\n"
        "    return wire\n")
    SRC_OK = SRC_BAD + (
        "def bill(topo, p, codec):\n"
        "    return topo.round_comm_joules(p, model_bits=32.0, codec=codec)\n")

    def test_unpriced_send_fires(self, tmp_path):
        out = _lint_src(tmp_path, self.SRC_BAD, "benchmarks/fake_vol.py")
        hits = [f for f in out if f.rule == "R4"]
        assert len(hits) == 1 and hits[0].line == 2
        assert "encode_leaf" in hits[0].message

    def test_billed_module_clean(self, tmp_path):
        out = _lint_src(tmp_path, self.SRC_OK, "benchmarks/fake_vol.py")
        assert "R4" not in _rules(out)

    def test_wire_format_layer_exempt(self, tmp_path):
        out = _lint_src(tmp_path, self.SRC_BAD, "src/repro/comms/codec.py")
        assert "R4" not in _rules(out)


class TestLintR5:
    SRC_BAD = (
        "from repro.core import scanloop\n"
        "prog = scanloop.donating_jit(step, donate_argnums=(0,))\n"
        "out = prog(params)\n")
    SRC_OK = (
        "from repro.core import scanloop\n"
        "prog = scanloop.donating_jit(step, donate_argnums=(0,))\n"
        "out = prog(scanloop.own(params))\n")

    def test_unowned_carry_fires(self, tmp_path):
        out = _lint_src(tmp_path, self.SRC_BAD, "src/repro/rl/fake_drv.py")
        hits = [f for f in out if f.rule == "R5"]
        assert len(hits) == 1 and hits[0].line == 2

    def test_owned_carry_clean(self, tmp_path):
        out = _lint_src(tmp_path, self.SRC_OK, "src/repro/rl/fake_drv.py")
        assert "R5" not in _rules(out)

    def test_no_donation_no_rule(self, tmp_path):
        src = self.SRC_BAD.replace(", donate_argnums=(0,)", "")
        out = _lint_src(tmp_path, src, "src/repro/rl/fake_drv.py")
        assert "R5" not in _rules(out)


def test_lint_syntax_error_is_reported_not_raised(tmp_path):
    out = _lint_src(tmp_path, "def broken(:\n", "src/repro/core/bad.py")
    assert [f.rule for f in out] == ["R0"]


def test_repo_tree_lint_is_allowlist_clean():
    """The lint half of `python -m repro.analysis --strict` on this tree."""
    findings = run_lint(REPO_ROOT)
    allow = load_allowlist(os.path.join(
        REPO_ROOT, "src", "repro", "analysis", "allowlist.toml"))
    open_f = [f for f in apply_allowlist(findings, allow)
              if not f.allowlisted]
    assert open_f == [], "\n".join(f.format() for f in open_f)


# ---------------------------------------------------------------------------
# allowlist machinery
# ---------------------------------------------------------------------------

ALLOW_TOML = """
# comment
[[allow]]
rule = "R4"
file = "src/repro/core/consensus.py"
note = "mechanism layer \\u2014 drivers bill"

[other_table]
rule = "IGNORED"

[[allow]]
rule = "JX2"
file = "*"
match = "topk"
note = "tracked"
"""


def test_parse_toml_min_subset():
    entries = parse_toml_min(ALLOW_TOML)["allow"]
    assert len(entries) == 2
    assert entries[0]["rule"] == "R4"
    assert entries[1]["match"] == "topk"
    assert "IGNORED" not in [e.get("rule") for e in entries]


def test_parse_toml_min_preserves_non_ascii():
    entries = parse_toml_min('[[allow]]\nrule = "X"\nnote = "em — dash"\n')
    assert entries["allow"][0]["note"] == "em — dash"


def test_apply_allowlist_rule_file_match():
    entries = parse_toml_min(ALLOW_TOML)["allow"]
    fs = [
        Finding("R4", "src/repro/core/consensus.py", 1, "ppermute send"),
        Finding("R4", "benchmarks/other.py", 2, "ppermute send"),
        Finding("JX2", "/abs/consensus.py", 3, "scan_rounds[x/topk:0.25]"),
        Finding("JX2", "/abs/consensus.py", 4, "scan_rounds[x/int8]"),
    ]
    out = apply_allowlist(fs, entries)
    assert [f.allowlisted for f in out] == [True, False, True, False]
    assert "drivers bill" in out[0].note


def test_repo_allowlist_every_entry_has_note():
    entries = load_allowlist(os.path.join(
        REPO_ROOT, "src", "repro", "analysis", "allowlist.toml"))
    assert len(entries) >= 4
    for e in entries:
        assert e.get("rule") and e.get("file") and e.get("note"), e


# ---------------------------------------------------------------------------
# Layer 1: jaxpr rules
# ---------------------------------------------------------------------------

def test_jx2_decode_then_combine_fires_with_location():
    def decoded(q, idx, scale):
        dense = q.astype(jnp.float32) * scale   # decode BEFORE the combine
        return jnp.take(dense, idx, axis=0)

    closed = jax.make_jaxpr(decoded)(
        jnp.zeros((8, 4), jnp.int8), jnp.arange(4), jnp.float32(0.1))
    hits = find_decode_then_combine(closed)
    assert hits and hits[0][0] == "gather-of-decoded-wire"
    f, ln = hits[0][1], hits[0][2]
    assert os.path.basename(f) == os.path.basename(THIS_FILE)
    assert ln > 0


def test_jx2_scatter_densification_fires():
    def topk_like(vals, idx, dest):
        dense = jnp.zeros((8,), jnp.float32).at[idx].set(vals)
        return jnp.take(dense, dest)

    closed = jax.make_jaxpr(topk_like)(
        jnp.ones((2,), jnp.float32), jnp.arange(2), jnp.arange(4))
    assert find_decode_then_combine(closed)


def test_jx2_fused_int_lane_gather_clean():
    def fused(q, idx, scale):
        lanes = jnp.take(q, idx, axis=0)        # gather WIRE lanes
        return lanes.astype(jnp.float32) * scale

    closed = jax.make_jaxpr(fused)(
        jnp.zeros((8, 4), jnp.int8), jnp.arange(4), jnp.float32(0.1))
    assert find_decode_then_combine(closed) == []
    assert has_int_lane_gather(closed)


def test_jx1_cached_callback_program_fires():
    def impure(x):
        return jax.pure_callback(
            lambda v: np.asarray(v),
            jax.ShapeDtypeStruct(x.shape, x.dtype), x)

    key = ("test-impure-prog", "sig")
    try:
        prog = scanloop.cached_program(
            key, lambda: scanloop.donating_jit(impure))
        prog(jnp.ones((4,), jnp.float32))       # bake abstract args
        findings = audit_registered_programs([prog._program_record])
    finally:
        scanloop._program_cache.pop(key, None)
    hits = [f for f in findings if f.rule == "JX1"]
    assert len(hits) == 1
    assert "test-impure-prog" in hits[0].message
    assert os.path.basename(hits[0].file) == os.path.basename(THIS_FILE)
    assert hits[0].line > 0


def test_jx1_uncached_callback_program_silent():
    def impure(x):
        return jax.pure_callback(
            lambda v: np.asarray(v),
            jax.ShapeDtypeStruct(x.shape, x.dtype), x)

    prog = scanloop.donating_jit(impure)        # never cache-admitted
    prog(jnp.ones((4,), jnp.float32))
    findings = audit_registered_programs([prog._program_record])
    assert [f for f in findings if f.rule == "JX1"] == []


def test_jx4_cached_streaming_program_fires():
    # a streaming-telemetry debug_callback smuggled into a CACHED program
    # must fire JX4 (and NOT JX1 — that rule now covers data callbacks)
    def streaming(x):
        jax.debug.callback(lambda v: None, x, ordered=True)
        return x * 2.0

    key = ("test-streaming-prog", "sig")
    try:
        prog = scanloop.cached_program(
            key, lambda: scanloop.donating_jit(streaming))
        prog(jnp.ones((4,), jnp.float32))       # bake abstract args
        findings = audit_registered_programs([prog._program_record])
    finally:
        scanloop._program_cache.pop(key, None)
    hits = [f for f in findings if f.rule == "JX4"]
    assert len(hits) == 1
    assert "test-streaming-prog" in hits[0].message
    assert os.path.basename(hits[0].file) == os.path.basename(THIS_FILE)
    assert hits[0].line > 0
    assert [f for f in findings if f.rule == "JX1"] == []


def test_jx4_uncached_streaming_program_silent():
    # the drivers' streaming path: program built per call, never admitted
    # to the cache — exactly what keeps the live tree JX4-clean
    def streaming(x):
        jax.debug.callback(lambda v: None, x, ordered=True)
        return x * 2.0

    prog = scanloop.donating_jit(streaming)
    prog(jnp.ones((4,), jnp.float32))
    findings = audit_registered_programs([prog._program_record])
    assert [f for f in findings if f.rule in ("JX1", "JX4")] == []


def test_find_callbacks_sees_through_scan():
    def body(c, x):
        y = jax.pure_callback(
            lambda v: np.asarray(v), jax.ShapeDtypeStruct((), c.dtype), c)
        return c + y, x

    def scanned(c, xs):
        return jax.lax.scan(body, c, xs)

    closed = jax.make_jaxpr(scanned)(jnp.float32(0.), jnp.zeros(3))
    assert any(p == "pure_callback" for p, _, _ in find_callbacks(closed))


def test_alias_param_indices_balanced_braces():
    txt = ("HloModule m, input_output_alias={ {}: (0, {}, may-alias), "
           "{1}: (2, {0}, may-alias) }, entry_computation_layout={...}")
    assert alias_param_indices(txt) == {0, 2}
    assert alias_param_indices("HloModule m") == set()


def test_jx3_honored_donation_clean():
    def step(p, g):
        return jax.tree.map(lambda a, b: a - 0.1 * b, p, g)

    sd = {"w": jax.ShapeDtypeStruct((4, 4), jnp.float32)}
    assert check_donation(step, (0,), (sd, sd), label="honored") == []


def test_jx3_dropped_donation_fires():
    def bad(p, big):
        return p + jnp.sum(big)                 # no (64,64) output: XLA
                                                # silently drops donation
    args = (jax.ShapeDtypeStruct((4,), jnp.float32),
            jax.ShapeDtypeStruct((64, 64), jnp.float32))
    with pytest.warns(UserWarning):
        findings = check_donation(bad, (1,), args, label="dropped")
    hits = [f for f in findings if f.rule == "JX3"]
    assert len(hits) == 1
    assert "donation dropped" in hits[0].message
    assert hits[0].file == "dropped"


# ---------------------------------------------------------------------------
# engine plan metadata the audits consume
# ---------------------------------------------------------------------------

def test_plan_audit_expectations_cover_all_plans():
    from repro.core.engine import PLAN_AUDIT_EXPECTATIONS, PLAN_KINDS
    assert set(PLAN_AUDIT_EXPECTATIONS) == set(PLAN_KINDS)
    for meta in PLAN_AUDIT_EXPECTATIONS.values():
        assert {"kk_buffer", "wire_collective",
                "int_lane_gather"} <= set(meta)


def test_audit_meta_reports_codec_and_plan():
    from repro.core import topology as topo_lib
    from repro.core.engine import ConsensusEngine
    eng = ConsensusEngine(topo_lib.ring(4), codec="int8")
    meta = eng.audit_meta()
    assert meta["plan"] == "dense-xla"
    assert meta["K"] == 4
    assert meta["qbits"] == 8
    assert meta["kk_buffer"] is True


# ---------------------------------------------------------------------------
# PR 10: R6 lint — error paths name the offending input
# ---------------------------------------------------------------------------

class TestLintR6:
    SRC_BAD = (
        "def combine(mix, mask):\n"
        "    if mask is None:\n"
        "        raise ValueError('mask is required')\n"
        "    return mix\n")
    SRC_OK = (
        "def combine(mix, mask):\n"
        "    if mask is None:\n"
        "        raise ValueError(\n"
        "            f'combine got mask=None with mix shape {mix.shape} — '\n"
        "            'pass survival_mask(key, t) or use the static path')\n"
        "    return mix\n")
    SRC_RERAISE = (
        "def fwd(x):\n"
        "    try:\n"
        "        return go(x)\n"
        "    except ValueError as err:\n"
        "        raise err\n"
        "    except TypeError:\n"
        "        raise\n")

    def test_constant_raise_fires_in_every_scope(self, tmp_path):
        for rel in ("src/repro/core/fake_r6.py",
                    "src/repro/rl/fake_r6.py",
                    "src/repro/launch/fake_r6.py"):
            out = _lint_src(tmp_path, self.SRC_BAD, rel)
            hits = [f for f in out if f.rule == "R6"]
            assert len(hits) == 1 and hits[0].line == 3, rel
            assert "offending input" in hits[0].message

    def test_interpolating_raise_clean(self, tmp_path):
        out = _lint_src(tmp_path, self.SRC_OK, "src/repro/core/fake_r6.py")
        assert "R6" not in _rules(out)

    def test_reraise_exempt(self, tmp_path):
        out = _lint_src(tmp_path, self.SRC_RERAISE,
                        "src/repro/core/fake_r6.py")
        assert "R6" not in _rules(out)

    def test_out_of_scope_silent(self, tmp_path):
        for rel in ("src/repro/comms/fake_r6.py", "benchmarks/fake_r6.py"):
            out = _lint_src(tmp_path, self.SRC_BAD, rel)
            assert "R6" not in _rules(out), rel


# ---------------------------------------------------------------------------
# PR 10: JX5 — the AsyncState carry must be donated
# ---------------------------------------------------------------------------

def _fake_record(abstract_args, donate_argnums, name="fake-async-prog"):
    import types
    return types.SimpleNamespace(name=name, abstract_args=abstract_args,
                                 donate_argnums=donate_argnums)


def test_jx5_undonated_async_state_fires():
    from repro.analysis.jaxpr_audit import check_async_state_donated
    from repro.core.engine import AsyncState
    ast = AsyncState(clock=jnp.zeros((4,), jnp.int32),
                     age=jnp.zeros((4, 4), jnp.int32))
    rec = _fake_record((jnp.zeros((2,)), jnp.zeros(()), ast), (0, 1))
    hits = check_async_state_donated(rec)
    assert len(hits) == 1 and hits[0].rule == "JX5"
    assert "arg 2" in hits[0].message
    assert "donate_argnums" in hits[0].message


def test_jx5_donated_async_state_clean():
    from repro.analysis.jaxpr_audit import check_async_state_donated
    from repro.core.engine import AsyncState
    ast = AsyncState(clock=jnp.zeros((4,), jnp.int32),
                     age=jnp.zeros((4, 4), jnp.int32))
    rec = _fake_record((jnp.zeros((2,)), ast), (0, 1))
    assert check_async_state_donated(rec) == []
    # nested containers still count as carrying the state
    rec = _fake_record((jnp.zeros((2,)), {"st": [ast]}), (0,))
    assert [f.rule for f in check_async_state_donated(rec)] == ["JX5"]


def test_jx5_live_async_fl_program_is_donated():
    """The driver fix this rule guards: the async fl chunk program must
    register with its AsyncState arg donated."""
    from repro.analysis.jaxpr_audit import (_tiny_drivers,
                                            check_async_state_donated)
    from repro.core.engine import AsyncState
    scanloop.clear_program_cache()
    try:
        _tiny_drivers()
        recs = [r for r in scanloop.registered_programs()
                if r.abstract_args is not None
                and any(_holds(a) for a in r.abstract_args)]
        assert recs, "no registered program carries an AsyncState"
        for r in recs:
            assert check_async_state_donated(r) == []
    finally:
        scanloop.clear_program_cache()


def _holds(tree):
    from repro.analysis.jaxpr_audit import _holds_async_state
    return _holds_async_state(tree)


# ---------------------------------------------------------------------------
# PR 10: H3 — int wire lanes stay int through the async combine
# ---------------------------------------------------------------------------

H3_UPCAST_HLO = """\
HloModule async_step
fused = f32[8,2,16]{2,1,0} gather(f32[8,16] %decoded, s32[8,2] %idx)
other = f32[8] gather(f32[8,8] %w, s32[8] %i)
"""

H3_FUSED_HLO = H3_UPCAST_HLO + """\
lanes = s8[8,2,16]{2,1,0} gather(s8[8,16] %wire, s32[8,2] %idx)
"""


def test_h3_upcast_module_fires():
    from repro.analysis.hlo_audit import check_wire_lane_dtype
    hits = check_wire_lane_dtype(H3_UPCAST_HLO, "engine:fake/int8/async")
    assert len(hits) == 1 and hits[0].rule == "H3"
    assert "upcast" in hits[0].message and "s8" in hits[0].message


def test_h3_gatherless_module_fires():
    from repro.analysis.hlo_audit import check_wire_lane_dtype
    hits = check_wire_lane_dtype("HloModule empty\n", "engine:fake")
    assert len(hits) == 1 and "vanished" in hits[0].message


def test_h3_fused_lane_gather_clean():
    from repro.analysis.hlo_audit import check_wire_lane_dtype
    assert check_wire_lane_dtype(H3_FUSED_HLO, "engine:fake") == []


# ---------------------------------------------------------------------------
# PR 10: C-layer — the static energy ledger
# ---------------------------------------------------------------------------

def test_c2_overpriced_round_fires():
    from repro.analysis.costmodel import (C2_RATIO, C2_SLACK_FLOPS,
                                          check_round_flops)
    expected = 20736.0
    bad = expected * C2_RATIO + C2_SLACK_FLOPS + 1
    hits = check_round_flops(bad, expected, "rl:case-study")
    assert len(hits) == 1 and hits[0].rule == "C2"
    assert "compute model" in hits[0].message
    # and the lower bracket: a round doing almost no work is as wrong
    assert check_round_flops(expected / C2_RATIO - 1, expected, "x")
    assert check_round_flops(expected * 1.02, expected, "x") == []


def test_c2_unmeasurable_backend_is_allowlisted_skip():
    from repro.analysis.costmodel import check_round_flops
    hits = check_round_flops(None, 100.0, "rl:case-study")
    assert len(hits) == 1 and hits[0].allowlisted
    assert "skipped" in hits[0].message


C3_META = {"plan": "sharded", "codec": "int8", "K": 8,
           "priced_collectives": {"all-gather": {"SL": 8}}}

C3_HLO = """\
HloModule step
wire = s8[8,1,16]{2,1,0} all-gather(s8[1,16] %lanes)
scales = f32[8,1]{1,0} all-gather(f32[1,1] %s)
rng = u32[16]{0} all-reduce(u32[16] %k)
leak = f32[8,64]{1,0} collective-permute(f32[8,64] %dense)
"""


def test_c3_unpriced_collective_fires_and_ledger_classifies():
    from repro.analysis.costmodel import collective_ledger
    ledger, findings = collective_ledger(C3_META, C3_HLO, "engine:fake")
    assert ledger.priced_bytes == {"all-gather": 128 + 32}
    assert ledger.control_bytes == 64          # u32 RNG plane
    assert ledger.unpriced_bytes == 8 * 64 * 4
    assert len(findings) == 1 and findings[0].rule == "C3"
    assert "collective-permute" in findings[0].message
    assert "outside the" in findings[0].message


def test_c3_empty_meta_prices_nothing():
    from repro.analysis.costmodel import collective_ledger
    ledger, findings = collective_ledger({}, C3_HLO, "prog:fake")
    assert ledger.priced_bytes == {}
    # without a K, only dtype-control transfers stay silent
    assert [f.rule for f in findings] == ["C3", "C3", "C3"]


def _chaos_engine(plan="dense-xla", codec="int8:b64", k=6, **kw):
    from repro.core import topology as topo_lib
    from repro.core.engine import ConsensusEngine
    return ConsensusEngine(
        topo_lib.ring(k), codec=codec, plan=plan,
        graph=topo_lib.GraphProcess.dropout(0.3, seed=2),
        agents=topo_lib.AgentProcess.bernoulli(0.6, seed=5),
        tau=2, staleness_decay=0.9, **kw)


def test_c1_mispriced_bits_fire():
    from repro.analysis.costmodel import reconcile_engine_run
    eng = _chaos_engine()
    hits = reconcile_engine_run(eng, rounds=2, label="engine:seeded",
                                expected_bits=1.0)   # absurd pricing
    assert hits and all(f.rule == "C1" for f in hits)
    assert any("wire bits" in f.message for f in hits)


def test_c1_static_rows_replay_chaos_convention():
    """A wire bills iff its link survived AND both endpoints were awake —
    the blessed chaos-harness convention, row by row."""
    import numpy as np
    from repro.analysis.costmodel import static_round_counts
    from repro.core import topology as topo_lib
    eng = _chaos_engine()
    rows = static_round_counts(eng, 4)
    topo = eng.topology
    adjs = topo_lib.dropout(topo, 0.3, seed=2, rounds=4)
    acts = np.asarray(topo_lib.availability_stream(eng.agents, 6, 4), bool)
    for t, row in enumerate(rows):
        m = (np.asarray(adjs[t].adjacency, bool)
             & acts[t][:, None] & acts[t][None, :])
        assert row["n_sl"] + row["n_ul"] + row["n_dl"] == int(m.sum())
        assert row["n_active"] == int(acts[t].sum())


@pytest.mark.slow
def test_c1_ledger_reconciles_all_plans_and_codecs():
    """Acceptance: C1 static bytes reconcile with the telemetry ledger
    for all four plans x {f32, int8:b64}, async configs included."""
    from repro.analysis.costmodel import audit_ledger_reconciliation
    findings = audit_ledger_reconciliation()
    assert findings == [], "\n".join(f.format() for f in findings)


def test_audit_meta_exposes_priced_collectives():
    from repro.core import topology as topo_lib
    from repro.core.engine import ConsensusEngine
    eng = ConsensusEngine(topo_lib.ring(4), codec="int8", plan="sharded",
                          num_blocks=2)
    meta = eng.audit_meta()
    assert meta["wire_collective"] == "all-gather"
    assert set(meta["priced_collectives"]) == {"all-gather"}
    classes = meta["priced_collectives"]["all-gather"]
    assert classes == meta["link_classes"]
    assert sum(classes.values()) == sum(
        eng.topology.links_per_round().values())


# ---------------------------------------------------------------------------
# PR 10: findings machinery — strict TOML, staleness, dedup, registry GC
# ---------------------------------------------------------------------------

def test_parse_toml_min_rejects_malformed_entries():
    from repro.analysis.findings import parse_toml_min
    cases = [
        ('[[allow]]\nrule = "R4" trailing\n', "line 2"),
        ('[[allow]]\nrule = "unterminated\n', "line 2"),
        ('rule = "R4"\n', "outside any table"),
        ('[[allow]]\njust a line\n', "line 2"),
        ('[bad header!]\nrule = "R4"\n', "line 1"),
        ('[[allow]]\nrule = naked\n', "line 2"),
    ]
    for src, needle in cases:
        with pytest.raises(ValueError, match=needle):
            parse_toml_min(src)


def test_stale_entries_flag_old_and_undated_debt():
    from repro.analysis.findings import stale_entries
    entries = [
        {"rule": "R4", "file": "a.py", "added_in": 6},    # 4 PRs old
        {"rule": "H2", "file": "b.py", "added_in": 9},    # fresh
        {"rule": "JX2", "file": "c.py"},                  # undated
    ]
    out = stale_entries(entries, current_pr=10, stale_after=4)
    assert [e.get("rule") for e, _w in out] == ["R4", "JX2"]
    assert "4 PRs old" in out[0][1]
    assert "undated" in out[1][1]


def test_repo_allowlist_every_entry_is_dated():
    entries = load_allowlist(os.path.join(
        REPO_ROOT, "src", "repro", "analysis", "allowlist.toml"))
    for e in entries:
        assert isinstance(e.get("added_in"), int), e


def test_dedup_findings_keeps_first_occurrence_order():
    from repro.analysis.findings import dedup_findings
    a = Finding("JX1", "x.py", 3, "callback")
    b = Finding("JX1", "x.py", 3, "callback")
    c = Finding("JX1", "x.py", 4, "callback")   # different line survives
    d = Finding("H2", "y", 0, "bytes")
    out = dedup_findings([a, d, b, c])
    assert out == [a, d, c]


def test_file_matches_glob_and_suffix():
    from repro.analysis.findings import _file_matches
    assert _file_matches("src/repro/core/consensus.py", "consensus.py")
    assert _file_matches("src/repro/core/consensus.py",
                         "src/repro/core/consensus.py")
    assert _file_matches("engine:sharded/bf16", "engine:sharded/*")
    assert _file_matches("anything", "*")
    assert not _file_matches("src/repro/core/topology.py", "consensus.py")
    # suffix matching must not cross a path component
    assert not _file_matches("src/repro/core/not_consensus.py",
                             "/consensus.py")


def test_registry_entry_collected_mid_audit_is_pruned():
    """A program GC'd between registration and the audit must vanish
    from registered_programs() (weakref pruning), never crash it."""
    import gc
    from repro.analysis.jaxpr_audit import audit_registered_programs

    def gc_prog_body(x):
        return x * 2.0

    key = ("test-gc-prog", "sig")
    prog = scanloop.cached_program(
        key, lambda: scanloop.donating_jit(gc_prog_body))
    prog(jnp.ones((4,), jnp.float32))
    assert any(r.name == "gc_prog_body"
               for r in scanloop.registered_programs())
    scanloop._program_cache.pop(key, None)
    del prog
    gc.collect()
    recs = scanloop.registered_programs()
    assert all(r.name != "gc_prog_body" for r in recs)
    audit_registered_programs(recs)             # must not raise


# ---------------------------------------------------------------------------
# PR 10: baseline diff + serialization
# ---------------------------------------------------------------------------

def test_findings_json_roundtrips_as_baseline(tmp_path):
    import json
    from repro.analysis.baseline import (finding_key, findings_to_json,
                                         load_baseline, new_findings)
    fs = [Finding("C1", "engine:x", 3, "drift", allowlisted=False),
          Finding("H2", "engine:y", 0, "bytes", allowlisted=True,
                  note="tracked")]
    p = tmp_path / "base.json"
    p.write_text(findings_to_json(fs))
    base = load_baseline(str(p))
    assert base == {finding_key(f) for f in fs}
    # both keys known -> nothing new; a fresh open finding -> reported
    assert new_findings(fs, base) == []
    novel = Finding("C3", "engine:z", 1, "unpriced permute")
    assert new_findings(fs + [novel], base) == [novel]
    # allowlisted findings never count as new, baselined or not
    tracked = Finding("H2", "engine:w", 0, "other", allowlisted=True)
    assert new_findings([tracked], set()) == []
    assert json.loads(findings_to_json(fs))[0]["rule"] == "C1"


def test_load_baseline_rejects_malformed(tmp_path):
    from repro.analysis.baseline import load_baseline
    p = tmp_path / "bad.json"
    p.write_text('{"not": "a list"}')
    with pytest.raises(ValueError, match="regenerate"):
        load_baseline(str(p))
    p.write_text('[{"rule": "C1"}]')
    with pytest.raises(ValueError, match="entry 0"):
        load_baseline(str(p))


def test_sarif_levels_follow_allowlisting():
    import json
    from repro.analysis.baseline import findings_to_sarif
    fs = [Finding("C1", "engine:x", 3, "drift"),
          Finding("H2", "engine:y", 0, "bytes", allowlisted=True,
                  note="tracked")]
    log = json.loads(findings_to_sarif(fs))
    assert log["version"] == "2.1.0"
    results = log["runs"][0]["results"]
    assert [r["level"] for r in results] == ["error", "note"]
    assert results[0]["locations"][0]["physicalLocation"][
        "region"]["startLine"] == 3
    rules = {r["id"] for r in log["runs"][0]["tool"]["driver"]["rules"]}
    assert rules == {"C1", "H2"}


def test_cli_baseline_gates_only_new_findings(tmp_path):
    """End-to-end CLI contract on the lint layer: a baselined strict run
    passes, and stays passing when the baseline covers everything."""
    from repro.analysis.__main__ import main
    base = tmp_path / "base.json"
    out = tmp_path / "findings.json"
    code = main(["--layer", "lint", "--format", "json",
                 "--json-out", str(base)])
    assert code == 0
    code = main(["--layer", "lint", "--strict",
                 "--baseline", str(base), "--json-out", str(out)])
    assert code == 0
    assert out.read_text() == base.read_text()
