"""Regression harness for ``repro.analysis`` — every rule must fire on a
seeded violation with the right rule ID and file:line, and stay silent on
the blessed counterpart."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.findings import (Finding, apply_allowlist,
                                     load_allowlist, parse_toml_min)
from repro.analysis.jaxpr_audit import (alias_param_indices,
                                        audit_registered_programs,
                                        check_donation,
                                        find_callbacks,
                                        find_decode_then_combine,
                                        has_int_lane_gather)
from repro.analysis.lint import lint_file, run_lint
from repro.core import scanloop

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
THIS_FILE = os.path.abspath(__file__)


def _lint_src(tmp_path, src: str, rel: str):
    p = tmp_path / os.path.basename(rel)
    p.write_text(src, encoding="utf-8")
    return lint_file(str(p), rel)


def _rules(findings):
    return {f.rule for f in findings}


# ---------------------------------------------------------------------------
# Layer 3: AST lint rules on seeded violations
# ---------------------------------------------------------------------------

class TestLintR1:
    SRC = (
        "import jax\n"
        "def edge_mask(key, t):\n"
        "    k = jax.random.fold_in(key, t)\n"
        "    return jax.random.uniform(jax.random.fold_in(k, 7), (4,))\n")

    def test_fires_with_line(self, tmp_path):
        out = _lint_src(tmp_path, self.SRC, "src/repro/core/fake_edges.py")
        hits = [f for f in out if f.rule == "R1"]
        assert len(hits) == 1
        assert hits[0].line == 4
        assert hits[0].file == "src/repro/core/fake_edges.py"
        assert "survival_mask" in hits[0].message

    def test_definition_site_exempt(self, tmp_path):
        src = self.SRC.replace("def edge_mask", "def survival_mask")
        out = _lint_src(tmp_path, src, "src/repro/core/topology.py")
        assert "R1" not in _rules(out)

    def test_bernoulli_counts(self, tmp_path):
        src = self.SRC.replace("jax.random.uniform", "jax.random.bernoulli")
        out = _lint_src(tmp_path, src, "benchmarks/fake_edges.py")
        assert "R1" in _rules(out)


class TestLintR2:
    SRC = (
        "import jax\n"
        "@jax.jit\n"
        "def step(p):\n"
        "    return p\n"
        "other = jax.jit(lambda x: x)\n")

    def test_fires_in_core_and_rl(self, tmp_path):
        for rel in ("src/repro/core/fake_mod.py", "src/repro/rl/fake_mod.py"):
            out = _lint_src(tmp_path, self.SRC, rel)
            hits = [f for f in out if f.rule == "R2"]
            assert sorted(h.line for h in hits) == [2, 5], rel

    def test_out_of_scope_and_gate_exempt(self, tmp_path):
        for rel in ("src/repro/launch/fake_mod.py",
                    "src/repro/core/scanloop.py"):
            out = _lint_src(tmp_path, self.SRC, rel)
            assert "R2" not in _rules(out), rel


class TestLintR3:
    SRC_BAD = (
        "rows = run()\n"
        "assert rows[-1]['us_per_round'] < 2.0\n")
    SRC_OK = (
        "import statistics\n"
        "rows = run()\n"
        "med = statistics.median(r['us_per_round'] for r in rows)\n"
        "assert med < 2.0 * 1.15\n")

    def test_single_shot_fires(self, tmp_path):
        out = _lint_src(tmp_path, self.SRC_BAD, "benchmarks/fake_bench.py")
        hits = [f for f in out if f.rule == "R3"]
        assert len(hits) == 1 and hits[0].line == 2

    def test_median_module_clean(self, tmp_path):
        out = _lint_src(tmp_path, self.SRC_OK, "benchmarks/fake_bench.py")
        assert "R3" not in _rules(out)

    def test_only_benchmarks_scope(self, tmp_path):
        out = _lint_src(tmp_path, self.SRC_BAD, "src/repro/core/fake.py")
        assert "R3" not in _rules(out)


class TestLintR4:
    SRC_BAD = (
        "def round(codec, leaf):\n"
        "    wire = codec.encode_leaf(leaf)\n"
        "    return wire\n")
    SRC_OK = SRC_BAD + (
        "def bill(topo, p, codec):\n"
        "    return topo.round_comm_joules(p, model_bits=32.0, codec=codec)\n")

    def test_unpriced_send_fires(self, tmp_path):
        out = _lint_src(tmp_path, self.SRC_BAD, "benchmarks/fake_vol.py")
        hits = [f for f in out if f.rule == "R4"]
        assert len(hits) == 1 and hits[0].line == 2
        assert "encode_leaf" in hits[0].message

    def test_billed_module_clean(self, tmp_path):
        out = _lint_src(tmp_path, self.SRC_OK, "benchmarks/fake_vol.py")
        assert "R4" not in _rules(out)

    def test_wire_format_layer_exempt(self, tmp_path):
        out = _lint_src(tmp_path, self.SRC_BAD, "src/repro/comms/codec.py")
        assert "R4" not in _rules(out)


class TestLintR5:
    SRC_BAD = (
        "from repro.core import scanloop\n"
        "prog = scanloop.donating_jit(step, donate_argnums=(0,))\n"
        "out = prog(params)\n")
    SRC_OK = (
        "from repro.core import scanloop\n"
        "prog = scanloop.donating_jit(step, donate_argnums=(0,))\n"
        "out = prog(scanloop.own(params))\n")

    def test_unowned_carry_fires(self, tmp_path):
        out = _lint_src(tmp_path, self.SRC_BAD, "src/repro/rl/fake_drv.py")
        hits = [f for f in out if f.rule == "R5"]
        assert len(hits) == 1 and hits[0].line == 2

    def test_owned_carry_clean(self, tmp_path):
        out = _lint_src(tmp_path, self.SRC_OK, "src/repro/rl/fake_drv.py")
        assert "R5" not in _rules(out)

    def test_no_donation_no_rule(self, tmp_path):
        src = self.SRC_BAD.replace(", donate_argnums=(0,)", "")
        out = _lint_src(tmp_path, src, "src/repro/rl/fake_drv.py")
        assert "R5" not in _rules(out)


def test_lint_syntax_error_is_reported_not_raised(tmp_path):
    out = _lint_src(tmp_path, "def broken(:\n", "src/repro/core/bad.py")
    assert [f.rule for f in out] == ["R0"]


def test_repo_tree_lint_is_allowlist_clean():
    """The lint half of `python -m repro.analysis --strict` on this tree."""
    findings = run_lint(REPO_ROOT)
    allow = load_allowlist(os.path.join(
        REPO_ROOT, "src", "repro", "analysis", "allowlist.toml"))
    open_f = [f for f in apply_allowlist(findings, allow)
              if not f.allowlisted]
    assert open_f == [], "\n".join(f.format() for f in open_f)


# ---------------------------------------------------------------------------
# allowlist machinery
# ---------------------------------------------------------------------------

ALLOW_TOML = """
# comment
[[allow]]
rule = "R4"
file = "src/repro/core/consensus.py"
note = "mechanism layer \\u2014 drivers bill"

[other_table]
rule = "IGNORED"

[[allow]]
rule = "JX2"
file = "*"
match = "topk"
note = "tracked"
"""


def test_parse_toml_min_subset():
    entries = parse_toml_min(ALLOW_TOML)["allow"]
    assert len(entries) == 2
    assert entries[0]["rule"] == "R4"
    assert entries[1]["match"] == "topk"
    assert "IGNORED" not in [e.get("rule") for e in entries]


def test_parse_toml_min_preserves_non_ascii():
    entries = parse_toml_min('[[allow]]\nrule = "X"\nnote = "em — dash"\n')
    assert entries["allow"][0]["note"] == "em — dash"


def test_apply_allowlist_rule_file_match():
    entries = parse_toml_min(ALLOW_TOML)["allow"]
    fs = [
        Finding("R4", "src/repro/core/consensus.py", 1, "ppermute send"),
        Finding("R4", "benchmarks/other.py", 2, "ppermute send"),
        Finding("JX2", "/abs/consensus.py", 3, "scan_rounds[x/topk:0.25]"),
        Finding("JX2", "/abs/consensus.py", 4, "scan_rounds[x/int8]"),
    ]
    out = apply_allowlist(fs, entries)
    assert [f.allowlisted for f in out] == [True, False, True, False]
    assert "drivers bill" in out[0].note


def test_repo_allowlist_every_entry_has_note():
    entries = load_allowlist(os.path.join(
        REPO_ROOT, "src", "repro", "analysis", "allowlist.toml"))
    assert len(entries) >= 4
    for e in entries:
        assert e.get("rule") and e.get("file") and e.get("note"), e


# ---------------------------------------------------------------------------
# Layer 1: jaxpr rules
# ---------------------------------------------------------------------------

def test_jx2_decode_then_combine_fires_with_location():
    def decoded(q, idx, scale):
        dense = q.astype(jnp.float32) * scale   # decode BEFORE the combine
        return jnp.take(dense, idx, axis=0)

    closed = jax.make_jaxpr(decoded)(
        jnp.zeros((8, 4), jnp.int8), jnp.arange(4), jnp.float32(0.1))
    hits = find_decode_then_combine(closed)
    assert hits and hits[0][0] == "gather-of-decoded-wire"
    f, ln = hits[0][1], hits[0][2]
    assert os.path.basename(f) == os.path.basename(THIS_FILE)
    assert ln > 0


def test_jx2_scatter_densification_fires():
    def topk_like(vals, idx, dest):
        dense = jnp.zeros((8,), jnp.float32).at[idx].set(vals)
        return jnp.take(dense, dest)

    closed = jax.make_jaxpr(topk_like)(
        jnp.ones((2,), jnp.float32), jnp.arange(2), jnp.arange(4))
    assert find_decode_then_combine(closed)


def test_jx2_fused_int_lane_gather_clean():
    def fused(q, idx, scale):
        lanes = jnp.take(q, idx, axis=0)        # gather WIRE lanes
        return lanes.astype(jnp.float32) * scale

    closed = jax.make_jaxpr(fused)(
        jnp.zeros((8, 4), jnp.int8), jnp.arange(4), jnp.float32(0.1))
    assert find_decode_then_combine(closed) == []
    assert has_int_lane_gather(closed)


def test_jx1_cached_callback_program_fires():
    def impure(x):
        return jax.pure_callback(
            lambda v: np.asarray(v),
            jax.ShapeDtypeStruct(x.shape, x.dtype), x)

    key = ("test-impure-prog", "sig")
    try:
        prog = scanloop.cached_program(
            key, lambda: scanloop.donating_jit(impure))
        prog(jnp.ones((4,), jnp.float32))       # bake abstract args
        findings = audit_registered_programs([prog._program_record])
    finally:
        scanloop._program_cache.pop(key, None)
    hits = [f for f in findings if f.rule == "JX1"]
    assert len(hits) == 1
    assert "test-impure-prog" in hits[0].message
    assert os.path.basename(hits[0].file) == os.path.basename(THIS_FILE)
    assert hits[0].line > 0


def test_jx1_uncached_callback_program_silent():
    def impure(x):
        return jax.pure_callback(
            lambda v: np.asarray(v),
            jax.ShapeDtypeStruct(x.shape, x.dtype), x)

    prog = scanloop.donating_jit(impure)        # never cache-admitted
    prog(jnp.ones((4,), jnp.float32))
    findings = audit_registered_programs([prog._program_record])
    assert [f for f in findings if f.rule == "JX1"] == []


def test_jx4_cached_streaming_program_fires():
    # a streaming-telemetry debug_callback smuggled into a CACHED program
    # must fire JX4 (and NOT JX1 — that rule now covers data callbacks)
    def streaming(x):
        jax.debug.callback(lambda v: None, x, ordered=True)
        return x * 2.0

    key = ("test-streaming-prog", "sig")
    try:
        prog = scanloop.cached_program(
            key, lambda: scanloop.donating_jit(streaming))
        prog(jnp.ones((4,), jnp.float32))       # bake abstract args
        findings = audit_registered_programs([prog._program_record])
    finally:
        scanloop._program_cache.pop(key, None)
    hits = [f for f in findings if f.rule == "JX4"]
    assert len(hits) == 1
    assert "test-streaming-prog" in hits[0].message
    assert os.path.basename(hits[0].file) == os.path.basename(THIS_FILE)
    assert hits[0].line > 0
    assert [f for f in findings if f.rule == "JX1"] == []


def test_jx4_uncached_streaming_program_silent():
    # the drivers' streaming path: program built per call, never admitted
    # to the cache — exactly what keeps the live tree JX4-clean
    def streaming(x):
        jax.debug.callback(lambda v: None, x, ordered=True)
        return x * 2.0

    prog = scanloop.donating_jit(streaming)
    prog(jnp.ones((4,), jnp.float32))
    findings = audit_registered_programs([prog._program_record])
    assert [f for f in findings if f.rule in ("JX1", "JX4")] == []


def test_find_callbacks_sees_through_scan():
    def body(c, x):
        y = jax.pure_callback(
            lambda v: np.asarray(v), jax.ShapeDtypeStruct((), c.dtype), c)
        return c + y, x

    def scanned(c, xs):
        return jax.lax.scan(body, c, xs)

    closed = jax.make_jaxpr(scanned)(jnp.float32(0.), jnp.zeros(3))
    assert any(p == "pure_callback" for p, _, _ in find_callbacks(closed))


def test_alias_param_indices_balanced_braces():
    txt = ("HloModule m, input_output_alias={ {}: (0, {}, may-alias), "
           "{1}: (2, {0}, may-alias) }, entry_computation_layout={...}")
    assert alias_param_indices(txt) == {0, 2}
    assert alias_param_indices("HloModule m") == set()


def test_jx3_honored_donation_clean():
    def step(p, g):
        return jax.tree.map(lambda a, b: a - 0.1 * b, p, g)

    sd = {"w": jax.ShapeDtypeStruct((4, 4), jnp.float32)}
    assert check_donation(step, (0,), (sd, sd), label="honored") == []


def test_jx3_dropped_donation_fires():
    def bad(p, big):
        return p + jnp.sum(big)                 # no (64,64) output: XLA
                                                # silently drops donation
    args = (jax.ShapeDtypeStruct((4,), jnp.float32),
            jax.ShapeDtypeStruct((64, 64), jnp.float32))
    with pytest.warns(UserWarning):
        findings = check_donation(bad, (1,), args, label="dropped")
    hits = [f for f in findings if f.rule == "JX3"]
    assert len(hits) == 1
    assert "donation dropped" in hits[0].message
    assert hits[0].file == "dropped"


# ---------------------------------------------------------------------------
# engine plan metadata the audits consume
# ---------------------------------------------------------------------------

def test_plan_audit_expectations_cover_all_plans():
    from repro.core.engine import PLAN_AUDIT_EXPECTATIONS, PLAN_KINDS
    assert set(PLAN_AUDIT_EXPECTATIONS) == set(PLAN_KINDS)
    for meta in PLAN_AUDIT_EXPECTATIONS.values():
        assert {"kk_buffer", "wire_collective",
                "int_lane_gather"} <= set(meta)


def test_audit_meta_reports_codec_and_plan():
    from repro.core import topology as topo_lib
    from repro.core.engine import ConsensusEngine
    eng = ConsensusEngine(topo_lib.ring(4), codec="int8")
    meta = eng.audit_meta()
    assert meta["plan"] == "dense-xla"
    assert meta["K"] == 4
    assert meta["qbits"] == 8
    assert meta["kk_buffer"] is True
