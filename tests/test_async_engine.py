"""Staleness-tolerant asynchronous consensus: the AgentProcess
availability contract (host/in-scan fold-in bit parity), the
lockstep-reduction invariant (always-on agents + tau=None reproduces the
synchronous engine bit for bit on all four plans and every chunking),
graceful degradation at the degenerate corners (fully-dead rounds are
exact no-ops, never-activating agents bill zero joules), and the async
error surface (every refusal names the offending input and the nearest
valid alternative)."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import energy, federated
from repro.core import topology as topo_lib
from repro.core.engine import (AsyncState, ConsensusEngine, where_active)

K = 8

PLANS = [("dense-xla", {}),
         ("sparse-pallas", {}),
         ("sharded", {"num_blocks": 4}),
         ("distributed", {})]


def _topo():
    return topo_lib.ring(K)


def _stacked(key):
    return {"w": jax.random.normal(key, (K, 6)),
            "b": jax.random.normal(jax.random.fold_in(key, 1), (K, 3))}


def _tree_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    return len(la) == len(lb) and all(
        np.array_equal(np.asarray(x), np.asarray(y))
        for x, y in zip(la, lb))


# ---------------------------------------------------------------------------
# the agent half of the fold-in convention
# ---------------------------------------------------------------------------


def test_availability_mask_bit_matches_host_stream():
    """jitted agent_availability(t) — as the scan bodies draw it —
    equals round t of the host availability_stream bit for bit, for
    every mode that draws randomness."""
    for proc in (topo_lib.AgentProcess.bernoulli(0.6, seed=3),
                 topo_lib.AgentProcess.straggler(K, seed=7)):
        traced = jax.jit(
            lambda t, p=proc: topo_lib.agent_availability(p, K, t))
        host = topo_lib.availability_stream(proc, K, 12)
        for t in range(12):
            np.testing.assert_array_equal(
                np.asarray(traced(jnp.int32(t))), host[t],
                err_msg=f"{proc.kind} t={t}")


def test_agent_availability_deterministic_kinds():
    """always_on (and None) is all-ones; arrival activates at exactly
    t_join; departure deactivates at exactly t_leave."""
    ones = np.ones(K, bool)
    np.testing.assert_array_equal(
        np.asarray(topo_lib.agent_availability(None, K, 5)), ones)
    np.testing.assert_array_equal(
        np.asarray(topo_lib.agent_availability(
            topo_lib.AgentProcess.always_on(), K, 5)), ones)
    t_join = np.arange(K, dtype=np.int64)
    arr = topo_lib.AgentProcess.arrival(t_join)
    dep = topo_lib.AgentProcess.departure(t_join)
    for t in range(K + 1):
        np.testing.assert_array_equal(
            np.asarray(topo_lib.agent_availability(arr, K, t)),
            t >= t_join, err_msg=f"arrival t={t}")
        np.testing.assert_array_equal(
            np.asarray(topo_lib.agent_availability(dep, K, t)),
            t < t_join, err_msg=f"departure t={t}")


def test_availability_edge_duty_cycles():
    """p_active=1 never sleeps, p_active=0 never wakes — the Bernoulli
    ends collapse to the deterministic processes."""
    on = topo_lib.AgentProcess.bernoulli(1.0, seed=0)
    off = topo_lib.AgentProcess.bernoulli(0.0, seed=0)
    assert topo_lib.availability_stream(on, K, 8).all()
    assert not topo_lib.availability_stream(off, K, 8).any()


# ---------------------------------------------------------------------------
# lockstep reduction: always-on + tau=None == the synchronous protocol
# ---------------------------------------------------------------------------


def _fl_loss(p, b):
    return jnp.mean((p["w"] - b["tgt"]) ** 2)


def _fl_sampler(key, t):
    return {"tgt": jax.random.normal(key, (K, 3, 1, 6)) * 0.1}


def _fl_target(sp):
    m = jnp.mean(jnp.square(sp["w"]))
    return m < -1.0, m                          # unreachable


@pytest.mark.parametrize("plan,kw", PLANS, ids=[p for p, _ in PLANS])
def test_always_on_reduces_to_lockstep_bitwise(plan, kw):
    """An async engine with always-on agents and tau=None runs the FULL
    staleness machinery (float σ weights, delivered masks, age clocks,
    per-agent freezes) yet reproduces the synchronous engine bit for
    bit — params, t_i, history, AND the EF codec state — on every plan,
    with per-link dropout active, across chunk sizes 1/7/32."""
    topo = _topo()
    graph = topo_lib.GraphProcess.dropout(0.3, seed=5)
    sync = ConsensusEngine(topo, codec="int8", graph=graph, plan=plan,
                           **kw)
    asyn = ConsensusEngine(topo, codec="int8", graph=graph, plan=plan,
                           agents=topo_lib.AgentProcess.always_on(),
                           tau=None, **kw)
    s = _stacked(jax.random.PRNGKey(1))
    runkw = dict(target_fn=_fl_target, max_rounds=9,
                 key=jax.random.PRNGKey(7), return_state=True)
    p_ref, t_ref, h_ref, st_ref = federated.run_fl_until_scan(
        _fl_loss, s, _fl_sampler, sync, 0.3, chunk=9, **runkw)
    for chunk in (1, 7, 32):
        p_a, t_a, h_a, st_a = federated.run_fl_until_scan(
            _fl_loss, s, _fl_sampler, asyn, 0.3, chunk=chunk, **runkw)
        assert (t_a, h_a) == (t_ref, h_ref), f"chunk={chunk}"
        assert _tree_equal(p_a, p_ref), f"chunk={chunk}"
        assert _tree_equal(st_a, st_ref), f"chunk={chunk}"


@pytest.mark.parametrize("plan,kw", PLANS, ids=[p for p, _ in PLANS])
def test_scan_rounds_lockstep_reduction(plan, kw):
    """Same reduction, directly on engine.scan_rounds (the benchmark /
    analysis surface): τ=∞ + always-on == the sync engine bitwise."""
    topo = _topo()
    sync = ConsensusEngine(topo, plan=plan, **kw)
    asyn = ConsensusEngine(topo, plan=plan,
                           agents=topo_lib.AgentProcess.always_on(),
                           staleness_decay=1.0, **kw)
    s = _stacked(jax.random.PRNGKey(2))
    p_ref, _ = sync.scan_rounds(s, rounds=5)
    p_a, _ = asyn.scan_rounds(s, rounds=5)
    assert _tree_equal(p_a, p_ref)


# ---------------------------------------------------------------------------
# graceful degradation at the degenerate corners
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("plan,kw", PLANS, ids=[p for p, _ in PLANS])
def test_fully_dead_round_is_a_bitwise_noop(plan, kw):
    """Rounds where NO agent is awake (arrival far in the future) leave
    params, EF residuals, and activity clocks untouched bitwise — on
    every plan — while wire ages keep counting up."""
    eng = ConsensusEngine(
        _topo(), codec="int8", plan=plan,
        agents=topo_lib.AgentProcess.arrival(np.full(K, 10**6)), **kw)
    s = _stacked(jax.random.PRNGKey(3))
    p, st = s, eng.init_state(s)
    ast = eng.init_async_state()
    for t in range(3):
        p, st, ast, ar = eng.async_step(p, st,
                                        jax.random.PRNGKey(10 + t),
                                        t=jnp.int32(t), state=ast)
        assert not np.asarray(ar.act).any(), f"t={t}"
        assert not np.asarray(ar.delivered).any(), f"t={t}"
    assert _tree_equal(p, s)
    assert _tree_equal(st, eng.init_state(s))
    np.testing.assert_array_equal(np.asarray(ast.clock), np.zeros(K))
    assert (np.asarray(ast.age) >= 3).all()     # staleness kept counting


def test_never_activating_agent_bills_zero_joules():
    """An agent that never joins ships nothing: every telemetry row
    reports K-1 active agents, and the summed Eq.-(11) stream equals
    rounds x the bill of the subgraph among the LIVE agents — exactly
    (==), the dead agent's wires priced at zero."""
    from repro import telemetry as telemetry_lib
    topo = topo_lib.clusters(1, 4)
    t_join = np.array([0, 0, 0, 10**6])
    eng = ConsensusEngine(topo, codec="int8",
                          agents=topo_lib.AgentProcess.arrival(t_join))
    tel = telemetry_lib.Telemetry()
    s = {"w": jax.random.normal(jax.random.PRNGKey(4), (4, 6))}
    rounds = 6
    eng.scan_rounds(s, rounds=rounds, telemetry=tel)
    events = tel.events(driver="consensus")
    assert len(events) == rounds
    assert all(e["n_active"] == 3 for e in events)
    a = np.asarray(topo_lib.agent_availability(eng.agents, 4, 0))
    m = np.asarray(topo.adjacency) & a[:, None] & a[None, :]
    live = topo_lib.Topology(
        "live", m, np.where(m, np.asarray(topo.link_class),
                            topo_lib.NONE))
    per_round = live.round_comm_joules(
        energy.paper_calibrated("fig3"), codec=eng.codec)
    stream = 0.0
    for e in events:
        stream += e["joules"]
    replay = 0.0
    for _ in range(rounds):
        replay += per_round
    assert stream == replay                     # EXACT, not approx
    # and strictly less than the full-graph bill (the dead agent's
    # wires are the difference)
    assert stream < rounds * topo.round_comm_joules(
        energy.paper_calibrated("fig3"), codec=eng.codec)


def test_stale_wires_drop_past_tau_and_sigma_renormalizes():
    """With one agent asleep forever and tau=1, its neighbours mix its
    frozen params only while age <= tau; past the bound the lane drops
    and σ renormalizes over the survivors — params stay finite and the
    awake agents keep consensus among themselves."""
    t_join = np.array([0, 0, 0, 0, 0, 0, 0, 10**6])
    eng = ConsensusEngine(
        _topo(), agents=topo_lib.AgentProcess.arrival(t_join), tau=1)
    s = _stacked(jax.random.PRNGKey(5))
    p, st = s, None
    ast = eng.init_async_state()
    for t in range(6):
        p, st, ast, ar = eng.async_step(p, st, t=jnp.int32(t),
                                        state=ast)
    leaves = [np.asarray(x) for x in jax.tree.leaves(p)]
    assert all(np.isfinite(x).all() for x in leaves)
    # the sleeper's params froze at their initial values
    assert np.array_equal(np.asarray(p["w"])[7], np.asarray(s["w"])[7])
    # the awake ring contracted towards consensus
    w0 = np.asarray(s["w"])[:7]
    wt = np.asarray(p["w"])[:7]
    assert np.std(wt, axis=0).sum() < np.std(w0, axis=0).sum()


def test_staleness_decay_downweights_stale_wires():
    """lambda < 1 shrinks a stale lane's σ mass: the sleeper's
    neighbours move strictly closer to the AWAKE average than under
    lambda = 1 (full stale weight)."""
    t_join = np.array([0, 0, 0, 0, 0, 0, 0, 10**6])
    proc = topo_lib.AgentProcess.arrival(t_join)
    s = _stacked(jax.random.PRNGKey(6))

    def run(decay):
        eng = ConsensusEngine(_topo(), agents=proc,
                              staleness_decay=decay)
        p, st = s, None
        ast = eng.init_async_state()
        for t in range(4):
            p, st, ast, _ = eng.async_step(p, st, t=jnp.int32(t),
                                           state=ast)
        return np.asarray(p["w"])

    awake_mean = np.mean(np.asarray(s["w"])[:7], axis=0)
    dist_full = np.abs(run(1.0)[:7] - awake_mean).sum()
    dist_decay = np.abs(run(0.5)[:7] - awake_mean).sum()
    assert dist_decay < dist_full


# ---------------------------------------------------------------------------
# the async error surface: refusals name the input and the alternative
# ---------------------------------------------------------------------------


def test_unknown_plan_names_nearest_alternative():
    with pytest.raises(ValueError, match="dense-xla"):
        ConsensusEngine(_topo(), plan="dense_xla")


def test_unknown_mix_kind_refused_at_construction():
    with pytest.raises(ValueError, match="metropolis"):
        ConsensusEngine(_topo(), mix_kind="metropolois")


def test_tau_without_agents_refused():
    with pytest.raises(ValueError,
                       match="only applies to async engines"):
        ConsensusEngine(_topo(), tau=3)


@pytest.mark.parametrize("bad", [float("nan"), -1.0])
def test_bad_tau_names_valid_choices(bad):
    with pytest.raises(ValueError, match="not a staleness bound"):
        ConsensusEngine(_topo(),
                        agents=topo_lib.AgentProcess.always_on(),
                        tau=bad)


def test_tau_inf_is_unbounded():
    eng = ConsensusEngine(_topo(),
                          agents=topo_lib.AgentProcess.always_on(),
                          tau=float("inf"))
    assert eng.tau is None


@pytest.mark.parametrize("bad", [0.0, 1.5, -0.2])
def test_bad_staleness_decay_refused(bad):
    with pytest.raises(ValueError, match=r"must lie in"):
        ConsensusEngine(_topo(),
                        agents=topo_lib.AgentProcess.always_on(),
                        staleness_decay=bad)


def test_agents_wrong_type_names_constructors():
    with pytest.raises(TypeError, match="AgentProcess"):
        ConsensusEngine(_topo(), agents=0.5)


def test_agents_population_mismatch_names_both_sizes():
    proc = topo_lib.AgentProcess.straggler(6, seed=0)
    with pytest.raises(ValueError, match=r"rebuild the process at K=8"):
        ConsensusEngine(_topo(), agents=proc)


def test_agents_on_raw_mix_refused():
    mix = np.asarray(_topo().mixing(), np.float32)
    with pytest.raises(ValueError, match="built from a Topology"):
        ConsensusEngine(mix,
                        agents=topo_lib.AgentProcess.always_on())


def test_async_step_without_survival_points_at_async_round():
    eng = ConsensusEngine(_topo(),
                          agents=topo_lib.AgentProcess.bernoulli(0.5))
    s = _stacked(jax.random.PRNGKey(8))
    with pytest.raises(ValueError, match="async_round"):
        eng.step(s, t=jnp.int32(0))


def test_async_step_needs_state_carry():
    eng = ConsensusEngine(_topo(),
                          agents=topo_lib.AgentProcess.bernoulli(0.5))
    s = _stacked(jax.random.PRNGKey(8))
    with pytest.raises(ValueError, match="init_async_state"):
        eng.async_step(s, t=jnp.int32(0))


def test_async_distributed_over_schedule_bound_names_the_bound():
    from repro.core.engine import DISTRIBUTED_SCHEDULE_BOUND
    with pytest.raises(ValueError) as ei:
        ConsensusEngine(topo_lib.full(DISTRIBUTED_SCHEDULE_BOUND + 6),
                        plan="distributed",
                        agents=topo_lib.AgentProcess.always_on())
    assert str(DISTRIBUTED_SCHEDULE_BOUND) in str(ei.value)
    assert "sparser" in str(ei.value)


def test_agent_process_bad_inputs_named():
    with pytest.raises(ValueError, match="unknown agent process"):
        topo_lib.AgentProcess(kind="bernouli")
    with pytest.raises(ValueError, match=r"p_active must be in \[0, 1\]"):
        topo_lib.AgentProcess.bernoulli(1.5)
    with pytest.raises(ValueError, match=r"lie in \[0, 1\]"):
        topo_lib.AgentProcess(kind="straggler", rates=[0.2, 1.7])
    with pytest.raises(ValueError, match="non-empty"):
        topo_lib.AgentProcess(kind="arrival", t_join=np.zeros((2, 2)))
