"""Per-architecture smoke tests (assignment contract (f)): a REDUCED
variant of each family — ≤2 layers (a few more for hybrids so the pattern
shows), d_model ≤ 512, ≤4 experts — runs one forward and one train step on
CPU, asserting output shapes and no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import INPUT_SHAPES, get_arch, list_archs, reduced
from repro.models import frontend
from repro.models.api import get_model, lm_loss

ARCHS = [a for a in list_archs() if a != "paper-dqn"]


def _toy_inputs(cfg, key, batch=2, seq=16):
    toks = jax.random.randint(key, (batch, seq), 0, cfg.vocab_size)
    labels = jnp.roll(toks, -1, axis=1)
    emb = None
    if cfg.family == "encdec":
        emb = frontend.audio_frame_embeddings(key, cfg, batch)
    return toks, labels, emb


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_no_nan(arch, rng_key):
    cfg = reduced(get_arch(arch))
    model = get_model(cfg)
    params = model.init(rng_key, cfg)
    toks, _, emb = _toy_inputs(cfg, rng_key)
    logits, _, aux = model.forward(params, cfg, toks, embeddings=emb)
    assert logits.shape == (2, 16, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_finite(arch, rng_key):
    cfg = reduced(get_arch(arch))
    model = get_model(cfg)
    params = model.init(rng_key, cfg)
    toks, labels, emb = _toy_inputs(cfg, rng_key)
    loss, grads = jax.value_and_grad(lm_loss)(
        params, cfg, toks, labels, embeddings=emb, model=model)
    assert np.isfinite(float(loss))
    sq = sum(float(jnp.sum(jnp.square(g.astype(jnp.float32))))
             for g in jax.tree.leaves(grads))
    assert np.isfinite(sq) and sq > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_full_forward(arch, rng_key):
    """Prefill+decode through the cache == direct forward at the last
    position (the serve-path correctness contract).

    MoE archs need drop-free capacity here: with the default factor the
    26-token full forward overflows experts (a fresh router routes
    imbalanced) and drops late tokens that the 1-token decode keeps, so
    the two paths legitimately diverge — same idiom as
    test_models.test_moe_dispatch_matches_dense."""
    import dataclasses
    cfg = reduced(get_arch(arch))
    if cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    model = get_model(cfg)
    params = model.init(rng_key, cfg)
    toks, _, emb = _toy_inputs(cfg, rng_key, batch=2, seq=12)
    caches = model.init_cache(cfg, 2, 32)
    lg, caches, _ = model.forward(params, cfg, toks, embeddings=emb,
                                  caches=caches, cache_index=jnp.int32(0))
    nxt = jnp.argmax(lg[:, -1:], axis=-1)
    lg2, _, _ = model.forward(params, cfg, nxt, caches=caches,
                              cache_index=jnp.int32(12))
    full, _, _ = model.forward(params, cfg,
                               jnp.concatenate([toks, nxt], axis=1),
                               embeddings=emb)
    np.testing.assert_allclose(np.asarray(full[:, -1]),
                               np.asarray(lg2[:, 0]), rtol=6e-2, atol=6e-2)


@pytest.mark.parametrize("arch", ["recurrentgemma-9b", "xlstm-125m",
                                  "mixtral-8x7b", "h2o-danube-3-4b"])
def test_subquadratic_flag(arch):
    assert get_arch(arch).subquadratic


@pytest.mark.parametrize("arch", ["granite-8b", "chameleon-34b",
                                  "stablelm-3b", "deepseek-7b",
                                  "whisper-large-v3"])
def test_full_attention_flag(arch):
    assert not get_arch(arch).subquadratic


def test_assigned_configs_exact():
    """The exact assigned hyperparameters (source citations in configs)."""
    expect = {
        "granite-8b": (36, 4096, 32, 8, 14336, 49152),
        "chameleon-34b": (48, 8192, 64, 8, 22016, 65536),
        "stablelm-3b": (32, 2560, 32, 32, 6912, 50304),
        "recurrentgemma-9b": (38, 4096, 16, 1, 12288, 256000),
        "whisper-large-v3": (32, 1280, 20, 20, 5120, 51866),
        "mixtral-8x7b": (32, 4096, 32, 8, 14336, 32000),
        "deepseek-7b": (30, 4096, 32, 32, 11008, 102400),
        "qwen2-moe-a2.7b": (24, 2048, 16, 16, 1408, 151936),
        "h2o-danube-3-4b": (24, 3840, 32, 8, 10240, 32000),
        "xlstm-125m": (12, 768, 4, 4, 0, 50304),
    }
    for name, (L, d, H, K, f, V) in expect.items():
        c = get_arch(name)
        assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads,
                c.d_ff, c.vocab_size) == (L, d, H, K, f, V), name
    assert get_arch("mixtral-8x7b").moe.num_experts == 8
    assert get_arch("mixtral-8x7b").moe.top_k == 2
    assert get_arch("qwen2-moe-a2.7b").moe.num_experts == 60
    assert get_arch("qwen2-moe-a2.7b").moe.top_k == 4


def test_input_shapes_exact():
    s = INPUT_SHAPES
    assert (s["train_4k"].seq_len, s["train_4k"].global_batch) == (4096, 256)
    assert (s["prefill_32k"].seq_len, s["prefill_32k"].global_batch) == (32768, 32)
    assert (s["decode_32k"].seq_len, s["decode_32k"].global_batch) == (32768, 128)
    assert (s["long_500k"].seq_len, s["long_500k"].global_batch) == (524288, 1)
