"""ConsensusEngine contract tests: plan selection, the four-plans-vs-
dense-f32-oracle parity matrix at K = 256 (ring / cluster / small-world,
uncompressed + int8 wires), the permutation-schedule invariants behind
the distributed path, and the codec-aware Eq.-(11) pricing acceptance
(int8 distributed wire >= 3.5x below f32)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import consensus, energy
from repro.core import topology as topo_lib
from repro.core.engine import ConsensusEngine, ExecutionPlan, PLAN_KINDS

K = 256
N = 40


def _topo(fam):
    if fam == "ring":
        return topo_lib.ring(K)
    if fam == "cluster":
        return topo_lib.make("cluster", K)     # 64 clusters x 4
    return topo_lib.small_world(K, k=4, seed=1)


def _stacked(key):
    return {"w": jax.random.normal(key, (K, N)),
            "b": jax.random.normal(jax.random.fold_in(key, 1), (K, 7))}


# ---------------------------------------------------------------------------
# the parity matrix — every plan must agree with the dense f32 oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("codec", [None, "int8"])
@pytest.mark.parametrize("plan", PLAN_KINDS)
@pytest.mark.parametrize("fam", ["ring", "cluster", "small_world"])
def test_all_plans_match_dense_oracle(rng_key, fam, plan, codec):
    """dense-xla / sparse-pallas / sharded / distributed all compute the
    same Eq.-(6) round: exactly (up to fp reassociation) without a codec,
    and within the quantizer's round-trip tolerance with the int8 wire
    (round-to-nearest, EF residual starting at zero — the CHOCO
    recentering keeps the compressed round anchored to the oracle)."""
    topo = _topo(fam)
    s = _stacked(rng_key)
    want = consensus.consensus_step(s, topo.mixing(), impl="xla")
    eng = ConsensusEngine(topo, codec=codec, plan=plan, num_blocks=8)
    out, state = eng.step(s, eng.init_state(s))
    assert (state is None) == (codec is None)
    for leaf in s:
        x = np.asarray(s[leaf], np.float32)
        # int8 tolerance: |x̂ - x| <= step/2 per model; the mixed result
        # touches own + neighbour decoded copies, so a few steps total
        atol = 1e-4 if codec is None else 3.0 * np.abs(x).max() / 127.0
        np.testing.assert_allclose(
            np.asarray(out[leaf], np.float32),
            np.asarray(want[leaf], np.float32), rtol=0, atol=atol,
            err_msg=f"{fam}/{plan}/{codec}/{leaf}")


def test_sharded_and_distributed_keep_population_mean(rng_key):
    """The CHOCO mean-exactness invariant on the new paths: with a
    doubly-stochastic σ the population mean survives int8 compression
    EXACTLY (up to fp summation), not just to quantizer tolerance."""
    topo = topo_lib.ring(16)
    mix = np.asarray(topo.mixing(kind="metropolis"))
    s = {"w": jax.random.normal(rng_key, (16, 33))}
    mean0 = np.asarray(s["w"], np.float32).mean(axis=0)
    for plan, kw in [("sharded", dict(num_blocks=4)), ("distributed", {})]:
        eng = ConsensusEngine(mix, codec="int8", plan=plan, **kw)
        out, _ = eng.step(s, eng.init_state(s))
        np.testing.assert_allclose(
            np.asarray(out["w"], np.float32).mean(axis=0), mean0,
            atol=1e-5, err_msg=plan)


# ---------------------------------------------------------------------------
# plan selection
# ---------------------------------------------------------------------------


def test_auto_plan_without_mesh_follows_density():
    assert ConsensusEngine(topo_lib.ring(256)).plan.kind == "sparse-pallas"
    # star is dense (max degree K-1): auto falls back to the matmul
    assert ConsensusEngine(topo_lib.star(256)).plan.kind == "dense-xla"
    # ...but an int8 wire discounts the gather payload 4x
    assert ConsensusEngine(topo_lib.star(256),
                           codec="int8").plan.kind == "sparse-pallas"


def test_auto_plan_small_k_floor_keeps_dense():
    """Regression for the recorded small-K loss: BENCH_consensus_scale
    rows had auto picking sparse-pallas at K=12 (ring 0.59×, cluster
    0.66× of dense-xla) and across all f32 K=64 graphs — below the
    calibrated K·degree floor the vmapped gather is pure overhead, so
    auto must keep small/dense-ish populations on the (K, K) matmul."""
    assert ConsensusEngine(topo_lib.ring(12)).plan.kind == "dense-xla"
    assert ConsensusEngine(
        topo_lib.make("cluster", 12)).plan.kind == "dense-xla"
    assert ConsensusEngine(topo_lib.ring(64)).plan.kind == "dense-xla"
    # the codec discount shrinks the payload, never re-enables a
    # below-floor gather
    assert ConsensusEngine(topo_lib.ring(12),
                           codec="int8").plan.kind == "dense-xla"
    # ...and never DEMOTES an above-floor one either: the floor is on
    # raw K·H (dispatch overhead, not bytes), so compressing the first
    # winning f32 row keeps it sparse
    assert ConsensusEngine(topo_lib.ring(256),
                           codec="int8").plan.kind == "sparse-pallas"
    # first winning recorded row sits exactly at the floor: K=256 ring
    assert consensus.auto_path(
        np.asarray(topo_lib.ring(256).mixing())) == "sparse"


def test_auto_plan_with_mesh_goes_multi_position():
    mesh = jax.make_mesh((1,), ("agents",))
    eng = ConsensusEngine(topo_lib.ring(8), mesh=mesh)
    assert eng.plan.kind == "sharded"
    assert eng.plan.num_blocks == 1
    # one agent per position => distributed (only reachable here at K=1
    # per the single local device; the selection rule is what's tested)
    eng1 = ConsensusEngine(np.zeros((1, 1), np.float32), mesh=mesh)
    assert eng1.plan.kind == "distributed"


def test_auto_plan_honours_mesh_when_blocks_do_not_divide():
    """A provided mesh must not be silently dropped: when the requested
    block count doesn't divide K, auto falls back to the largest block
    count that does — still the sharded plan, never a single-program
    density fallback."""
    mesh = jax.make_mesh((1,), ("agents",))
    eng = ConsensusEngine(topo_lib.ring(12), mesh=mesh, num_blocks=8)
    assert eng.plan.kind == "sharded"
    assert eng.plan.num_blocks == 6           # largest divisor of 12 <= 8
    s = {"w": jnp.ones((12, 5))}
    out, _ = eng.step(s)                      # and it actually runs
    assert out["w"].shape == (12, 5)


def test_engine_rejects_unknown_plan_and_bad_blocks():
    with pytest.raises(ValueError):
        ConsensusEngine(topo_lib.ring(8), plan="bogus")
    eng = ConsensusEngine(topo_lib.ring(8), plan="sharded", num_blocks=3)
    with pytest.raises(ValueError):           # 3 does not divide K=8
        eng.step({"w": jnp.ones((8, 4))})


def test_engine_wrap():
    topo = topo_lib.ring(6)
    eng = ConsensusEngine(topo)
    assert ConsensusEngine.wrap(eng) is eng
    wrapped = ConsensusEngine.wrap(topo, codec="int8")
    assert wrapped.codec.name == "int8+ef"
    with pytest.raises(ValueError):           # can't re-codec an engine
        ConsensusEngine.wrap(eng, codec="int8")
    with pytest.raises(TypeError):
        ConsensusEngine(eng)


def test_mix_override_dense_only(rng_key):
    """Per-round (traced) mix overrides power time-varying topologies —
    dense-xla honours them; structure-baking plans must refuse."""
    topo = topo_lib.ring(4)
    s = {"w": jax.random.normal(rng_key, (4, 5))}
    eng = ConsensusEngine(topo, plan="dense-xla")
    dead = jnp.zeros((4, 4), jnp.float32)     # every link faded
    out, _ = jax.jit(lambda p, m: eng.step(p, mix=m))(s, dead)
    np.testing.assert_allclose(np.asarray(out["w"]), np.asarray(s["w"]),
                               atol=1e-6)     # no links -> no mixing
    with pytest.raises(ValueError):
        ConsensusEngine(topo, plan="sharded").step(s, mix=dead)


# ---------------------------------------------------------------------------
# the permutation schedule (distributed path backbone)
# ---------------------------------------------------------------------------


def test_permutation_schedule_covers_graph_exactly():
    topo = topo_lib.small_world(32, k=4, seed=3)
    mix = np.asarray(topo.mixing())
    sched = consensus.permutation_schedule(mix)
    K = 32
    covered = np.zeros((K, K), np.float32)
    for pairs, sig in sched:
        assert sorted(s for s, _ in pairs) == list(range(K))   # full perm
        assert sorted(t for _, t in pairs) == list(range(K))
        for src, tgt in pairs:
            covered[tgt, src] += sig[tgt] if sig[tgt] else 0.0
    off = mix.copy()
    np.fill_diagonal(off, 0.0)
    np.testing.assert_allclose(covered, off, atol=1e-6)


def test_permutation_schedule_ring_is_two_rounds():
    sched = consensus.permutation_schedule(
        np.asarray(topo_lib.ring(8).mixing()))
    assert len(sched) == 2                   # one per direction


# ---------------------------------------------------------------------------
# codec-aware Eq.-(11) pricing through the engine
# ---------------------------------------------------------------------------


def test_distributed_int8_wire_prices_at_least_3p5x_below_f32():
    """Acceptance: the distributed plan's int8 wire is >= 3.5x cheaper
    per round than the f32 exchange under Eq. (11) — the wire IS what
    ppermute ships, so round_comm_joules(codec=) is truthful."""
    p = energy.paper_calibrated("fig3")
    topo = topo_lib.ring(64)
    eng = ConsensusEngine(topo, codec="int8", plan="distributed")
    ratio = topo.round_comm_joules(p) / eng.round_comm_joules(p)
    assert ratio >= 3.5
    assert ratio == pytest.approx(4.0)       # 8-bit lanes vs 32-bit

def test_engine_pricing_requires_topology():
    eng = ConsensusEngine(np.asarray(topo_lib.ring(4).mixing()))
    with pytest.raises(ValueError):
        eng.round_comm_joules(energy.paper_calibrated("fig3"))


def test_execution_plan_validates_kind():
    with pytest.raises(ValueError):
        ExecutionPlan("warp-drive", "nope")
