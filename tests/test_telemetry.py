"""Tier-1 contract of ``repro.telemetry``: telemetry off / buffered /
streaming produce BIT-IDENTICAL params, t_i, and metric history across
chunk sizes × engine plans; the streamed per-round Eq.-(11) ledger
reconciles EXACTLY (==, not approx) with the post-hoc dropout replay the
orchestrators bill; plus the program-cache stats counters, sinks, and
the JSONL event schema."""
import collections
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import telemetry as tl
from repro.core import federated, maml, scanloop
from repro.core import topology as topo_lib
from repro.core.engine import ConsensusEngine

K, D = 6, 8
P_DROP, DROP_SEED = 0.3, 7


# ---------------------------------------------------------------------------
# toy FL problem (traced sampler, deterministic, converges fast)
# ---------------------------------------------------------------------------


def _loss(p, batch):
    pred = batch["x"] @ p["w"] + p["b"]
    return jnp.mean((pred - batch["y"]) ** 2)


def _sample(key, _t):
    ks = jax.random.split(key, K)

    def one(k):
        x = jax.random.normal(k, (4, D))
        return {"x": x, "y": jnp.sum(x, -1, keepdims=True)}

    return jax.vmap(one)(ks)


def _never(_p):
    return jnp.asarray(False), jnp.float32(0.0)


def _stacked():
    p = {"w": jnp.zeros((D, 1)), "b": jnp.zeros((1,))}
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (K,) + x.shape), p)


def _engine(plan, dropout=P_DROP, codec="int8"):
    kw = {"num_blocks": 2} if plan == "sharded" else {}
    graph = (topo_lib.GraphProcess.dropout(dropout, seed=DROP_SEED)
             if dropout else None)
    return ConsensusEngine(topo_lib.ring(K), codec=codec, plan=plan,
                           graph=graph, **kw)


def _run(telemetry, chunk, plan, max_rounds=8, target_fn=_never):
    eng = _engine(plan)
    out = federated.run_fl_until_scan(
        _loss, _stacked(), _sample, eng, 0.1, target_fn=target_fn,
        max_rounds=max_rounds, key=jax.random.PRNGKey(0), chunk=chunk,
        telemetry=telemetry)
    return out, eng


def _assert_trees_equal(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# bit-parity matrix: mode × chunk × plan
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("plan", ["dense-xla", "sparse-pallas",
                                  "sharded", "distributed"])
@pytest.mark.parametrize("chunk", [1, 7, 32])
def test_parity_matrix(plan, chunk):
    (p0, r0, h0), _ = _run(None, chunk, plan)
    buf = tl.Telemetry()
    (p1, r1, h1), _ = _run(buf, chunk, plan)
    stream = tl.Telemetry(mode="streaming", sinks=(tl.MemorySink(),))
    (p2, r2, h2), _ = _run(stream, chunk, plan)

    _assert_trees_equal(p0, p1)
    _assert_trees_equal(p0, p2)
    assert r0 == r1 == r2
    assert h0 == h1 == h2

    # both modes buffer the same live rounds, price the same joules
    eb, es = buf.events(driver="fl"), stream.events(driver="fl")
    assert len(eb) == len(es) == r0
    assert [e["round"] for e in eb] == list(range(r0))
    assert buf.joules() == stream.joules()
    # streaming emitted every live round to the sink, in round order
    assert ([e["round"] for e in stream.sinks[0].events]
            == [e["round"] for e in eb])


def test_midchunk_hit_freezes_frozen_rows_out():
    """Target hit mid-chunk: the frozen tail never reaches events(),
    sinks, or the ledger — live rounds == t_i exactly."""
    def target(stacked):
        p0 = jax.tree.map(lambda x: x[0], stacked)
        m = _loss(p0, {"x": jnp.eye(D), "y": jnp.ones((D, 1))})
        return m < 2.0, m

    buf = tl.Telemetry(sinks=(tl.MemorySink(),))
    (_, r, h), _ = _run(buf, 32, "dense-xla", max_rounds=30,
                        target_fn=target)
    assert 0 < r < 30                      # actually hit, mid-chunk
    live = buf.events(driver="fl")
    assert len(live) == r == len(h)
    assert len(buf.sinks[0].events) == r
    assert [e["reached"] for e in live] == [False] * (r - 1) + [True]
    # frozen padding is in the buffer (live=False) but never billed
    frozen = [e for e in buf.events(live_only=False) if not e["live"]]
    assert frozen and all(e["joules"] == 0.0 for e in frozen)


# ---------------------------------------------------------------------------
# exact ledger reconciliation under dropout
# ---------------------------------------------------------------------------


def test_ledger_reconciles_exactly_with_dropout_replay():
    """telemetry.joules() == the post-hoc host replay of
    topology.dropout × round_comm_joules, bitwise (same float64 pricing
    expression, same summation order) — this is the identity that lets
    the stream replace ``fl_comm_joules_measured``."""
    buf = tl.Telemetry()
    (_, rounds, _), eng = _run(buf, 7, "dense-xla")
    want = sum(
        t.round_comm_joules(buf.energy_params, codec=eng.codec)
        for t in topo_lib.dropout(topo_lib.ring(K), P_DROP,
                                  seed=DROP_SEED, rounds=rounds))
    assert buf.joules() == want            # EXACT, not approx
    # per-class splits are consistent with the total, row by row
    for e in buf.events(driver="fl"):
        assert e["edges"] == e["n_sl"] + e["n_ul"] + e["n_dl"]
        assert e["joules"] == pytest.approx(
            e["joules_sl"] + e["joules_ul"] + e["joules_dl"])


def test_distributed_ledger_reconciles_exactly_with_dropout_replay():
    """Acceptance: with dropout active on the DISTRIBUTED plan — the
    masked ppermute schedule superset — the in-scan (M, K) slot counts
    still bill each surviving directed edge exactly once, so the
    streamed Eq.-(11) joules equal the post-hoc host replay bitwise."""
    buf = tl.Telemetry()
    (_, rounds, _), eng = _run(buf, 7, "distributed")
    assert rounds > 0
    want = sum(
        t.round_comm_joules(buf.energy_params, codec=eng.codec)
        for t in topo_lib.dropout(topo_lib.ring(K), P_DROP,
                                  seed=DROP_SEED, rounds=rounds))
    assert buf.joules() == want            # EXACT, not approx
    for e in buf.events(driver="fl"):
        assert e["edges"] == e["n_sl"] + e["n_ul"] + e["n_dl"]


def test_casestudy_stream_reconciles_with_measured_ledger():
    """CaseStudy threading: per-task streamed joules ==
    ``fl_comm_joules_measured`` (the post-hoc dropout replay) EXACTLY,
    and results are bit-identical to a telemetry-off run."""
    from repro.rl.casestudy import CaseStudy
    key = jax.random.PRNGKey(0)

    tel = tl.Telemetry()
    cs = CaseStudy(dropout_p=0.2, codec="int8", chunk=8, telemetry=tel)
    p = cs.init_params(key)
    _, t_i, h = cs.adapt_task(key, 2, p, max_rounds=4)
    assert tel.joules(task_id=2) == cs.last_adapt_comm_joules
    assert len(tel.events(driver="fl")) == t_i

    ref = CaseStudy(dropout_p=0.2, codec="int8", chunk=8)
    pr = ref.init_params(key)
    out_ref = ref.adapt_task(key, 2, pr, max_rounds=4)
    _, t_ref, h_ref = out_ref
    assert t_i == t_ref and h == h_ref
    assert cs.last_adapt_comm_joules == ref.last_adapt_comm_joules


# ---------------------------------------------------------------------------
# MAML + engine.scan_rounds threading
# ---------------------------------------------------------------------------


def _sample_tasks(key, _t):
    ks = jax.random.split(key, 2)

    def one(k):
        x = jax.random.normal(k, (3, 4, D))
        return {"x": x, "y": jnp.sum(x, -1, keepdims=True)}

    sup = jax.vmap(one)(jax.random.split(ks[0], 2))
    qry = jax.vmap(one)(jax.random.split(ks[1], 2))
    return sup, qry


@pytest.mark.parametrize("mode", ["buffered", "streaming"])
def test_maml_parity_and_events(mode):
    p0 = {"w": jnp.zeros((D, 1)), "b": jnp.zeros((1,))}
    kw = dict(rounds=5, inner_lr=0.1, outer_lr=0.1, chunk=3,
              key=jax.random.PRNGKey(1))
    ref, hist_ref = maml.maml_train_scan(_loss, p0, _sample_tasks, **kw)
    tel = tl.Telemetry(mode=mode, sinks=(tl.MemorySink(),))
    out, hist = maml.maml_train_scan(_loss, p0, _sample_tasks,
                                     telemetry=tel, **kw)
    _assert_trees_equal(ref, out)
    assert hist == hist_ref
    ev = tel.events(driver="maml")
    assert [e["round"] for e in ev] == list(range(5))
    assert [e["meta_loss"] for e in ev] == pytest.approx(hist)
    assert len(tel.sinks[0].events) == 5


def test_scan_rounds_consensus_events():
    eng = ConsensusEngine(topo_lib.ring(K))     # static graph
    p = {"w": jnp.arange(K * 16, dtype=jnp.float32).reshape(K, 16)}
    ref, _ = eng.scan_rounds(p, rounds=4)
    tel = tl.Telemetry()
    out, _ = eng.scan_rounds(p, rounds=4, telemetry=tel)
    _assert_trees_equal(ref, out)
    ev = tel.events(driver="consensus")
    assert [e["round"] for e in ev] == list(range(4))
    # gossip on a connected static ring contracts disagreement
    assert ev[-1]["disagreement"] < ev[0]["disagreement"]
    # static graph: every round bills the full ring
    n_edges = sum(eng.topology.links_per_round().values())
    assert all(e["edges"] == n_edges for e in ev)


# ---------------------------------------------------------------------------
# program-cache stats
# ---------------------------------------------------------------------------


@pytest.fixture
def _isolated_cache(monkeypatch):
    """Fresh cache + counters; the session's real cache/counters are
    untouched (TRACE_COUNTS totals feed the CI trace budget)."""
    monkeypatch.setattr(scanloop, "_program_cache",
                        collections.OrderedDict())
    monkeypatch.setattr(scanloop, "PROGRAM_CACHE_SIZE", 2)
    saved_cs = dict(scanloop.CACHE_STATS)
    saved_tc = dict(scanloop.TRACE_COUNTS)
    scanloop.reset_cache_stats()
    yield
    scanloop.CACHE_STATS.clear()
    scanloop.CACHE_STATS.update(saved_cs)
    scanloop.TRACE_COUNTS.clear()
    scanloop.TRACE_COUNTS.update(saved_tc)


def test_cache_stats_counters(_isolated_cache):
    mk = lambda: (lambda x: x)
    assert scanloop.get_cached_program(("a",)) is None        # miss
    f1 = scanloop.cached_program(("a",), mk)                  # insert
    assert scanloop.get_cached_program(("a",)) is f1          # hit
    scanloop.cached_program(("b",), mk)
    scanloop.cached_program(("c",), mk)                       # evicts "a"
    st = scanloop.cache_stats()
    assert st["hits"] == 1 and st["misses"] == 1
    assert st["inserts"] == 3 and st["evictions"] == 1
    assert st["size"] == 2 and st["capacity"] == 2
    assert scanloop.get_cached_program(("a",)) is None        # LRU victim
    assert scanloop.cached_program(("b",), mk) is not None    # re-hit: no
    assert scanloop.cache_stats()["inserts"] == 3             # new insert

    scanloop.reset_cache_stats()
    st2 = scanloop.cache_stats()
    assert st2["hits"] == st2["misses"] == st2["evictions"] == 0
    assert st2["size"] == 2            # reset clears counters, NOT entries
    assert st2["trace_counts"] == {}


def test_report_exposes_harness_counters():
    tel = tl.Telemetry()
    _run(tel, 4, "dense-xla", max_rounds=4)
    rep = tel.report()
    assert rep["mode"] == "buffered"
    assert rep["live_rounds"] == 4
    assert rep["joules"] == tel.joules()
    pc = rep["program_cache"]
    assert {"hits", "misses", "inserts", "evictions", "size",
            "capacity", "registered_programs",
            "trace_counts"} <= set(pc)
    assert rep["programs"] and all(
        {"name", "cached", "donation_honored"} <= set(p)
        for p in rep["programs"])


# ---------------------------------------------------------------------------
# sinks + schema
# ---------------------------------------------------------------------------


def test_jsonl_sink_schema_roundtrip(tmp_path):
    path = tmp_path / "events.jsonl"
    tel = tl.Telemetry(sinks=(tl.JsonlSink(path),))
    (_, rounds, _), _ = _run(tel, 4, "dense-xla", max_rounds=4)
    tel.close()
    count, errors = tl.validate_jsonl(path)
    assert errors == []
    assert count == rounds == 4
    with open(path) as fh:
        ev = [json.loads(line) for line in fh]
    assert all(e["type"] == "round" and e["driver"] == "fl" for e in ev)
    from repro.telemetry import schema
    assert schema.main([str(path)]) == 0
    assert schema.main([]) == 2


def test_validate_event_rejects_bad_events(tmp_path):
    ok = {"type": "round", "driver": "maml", "round": 0, "live": True,
          "meta_loss": 0.5}
    assert tl.validate_event(ok) == []
    assert tl.validate_event({"type": "round"})          # missing fields
    bad = dict(ok, meta_loss="0.5")
    assert any("meta_loss" in e for e in tl.validate_event(bad))
    assert tl.validate_event({"type": "round", "driver": "nope",
                              "round": 0, "live": True})
    # strict JSON: NaN poisons the file, validator reports it
    path = tmp_path / "bad.jsonl"
    path.write_text('{"type": "round", "driver": "maml", "round": 0, '
                    '"live": true, "meta_loss": NaN}\n')
    _, errors = tl.validate_jsonl(path)
    assert errors


def test_buffer_capacity_drops_oldest():
    buf = tl.MetricBuffer(capacity=3)
    buf.extend({"type": "round", "round": i, "live": True}
               for i in range(5))
    assert len(buf) == 3
    assert buf.dropped == 2
    assert [e["round"] for e in buf.rows()] == [2, 3, 4]


def test_telemetry_mode_validated():
    with pytest.raises(ValueError):
        tl.Telemetry(mode="firehose")


# ---------------------------------------------------------------------------
# per-agent energy attribution (PR 10)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("plan,kw", [
    ("dense-xla", {}), ("sparse-pallas", {}),
    ("sharded", {"num_blocks": 2}), ("distributed", {})])
def test_per_agent_attribution_bills_senders_only(plan, kw):
    """The (K,) agent_* rows attribute every surviving wire to its
    SENDER: they sum exactly to the aggregate counts, a sleeping agent
    bills exactly 0.0 J, and the per-plan survival shapes all agree."""
    eng = ConsensusEngine(
        topo_lib.ring(K), codec="int8:b64", plan=plan,
        graph=topo_lib.GraphProcess.dropout(P_DROP, seed=DROP_SEED),
        agents=topo_lib.AgentProcess.bernoulli(0.6, seed=1),
        tau=2, staleness_decay=0.9, **kw)
    rec = tl.RoundRecorder(eng)
    params = {"w": jax.random.normal(jax.random.PRNGKey(0), (K, D))}
    rnd = eng.async_round(jnp.int32(3), eng.init_async_state().age)
    row = rec.row(params, rnd.delivered, metric=0.0, reached=False,
                  live=True, active=rnd.act, age=rnd.age)
    ev = rec.event(3, row)
    assert len(ev["agent_joules"]) == K
    for cls in ("sl", "ul", "dl"):
        assert sum(ev[f"agent_{cls}"]) == ev[f"n_{cls}"], cls
    awake = [bool(a) for a in np.asarray(rnd.act)]
    assert not all(awake), "seed must put at least one agent to sleep"
    for k, up in enumerate(awake):
        if not up:
            assert ev["agent_joules"][k] == 0.0
            assert ev["agent_sl"][k] + ev["agent_ul"][k] \
                + ev["agent_dl"][k] == 0
    # the per-agent ledger decomposes the aggregate (tight, not approx:
    # both sides are sums of the same per-class float64 terms)
    assert sum(ev["agent_joules"]) == pytest.approx(ev["joules"], rel=1e-12)


def test_per_agent_static_rows_match_link_classes():
    """Lockstep static rounds: per-sender counts are the topology's
    outgoing-link table, identical across plan representations."""
    link_class = np.asarray(topo_lib.ring(K).link_class)
    expected = (link_class != topo_lib.NONE).sum(axis=0)
    rows = {}
    for plan, kw in (("dense-xla", {}), ("sparse-pallas", {}),
                     ("sharded", {"num_blocks": 2}), ("distributed", {})):
        eng = ConsensusEngine(topo_lib.ring(K), plan=plan, **kw)
        rec = tl.RoundRecorder(eng)
        params = {"w": jnp.ones((K, D), jnp.float32)}
        row = rec.row(params, None, metric=0.0, reached=False, live=True)
        total = np.asarray(row["agent_sl"]) + np.asarray(row["agent_ul"]) \
            + np.asarray(row["agent_dl"])
        rows[plan] = total
        assert (total == expected).all(), (plan, total, expected)
    assert all((v == rows["dense-xla"]).all() for v in rows.values())
