import os

# Tests see the real (single) CPU device — the 512-device override belongs
# ONLY to repro.launch.dryrun (per the dry-run contract). Guard against a
# leaked env var.
os.environ.pop("XLA_FLAGS", None) if "xla_force_host_platform_device_count" \
    in os.environ.get("XLA_FLAGS", "") else None

import jax
import pytest


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)


@pytest.fixture(scope="session", autouse=True)
def _retrace_budget():
    """CI retrace budget: with ``REPRO_TRACE_BUDGET=<n>`` set, the whole
    tier-1 run may re-trace the chunked drivers at most n times total
    (``scanloop.TRACE_COUNTS``). A driver bypassing
    ``scanloop.cached_program`` re-traces per call and blows the budget
    long before it shows up as wall-clock."""
    yield
    budget = os.environ.get("REPRO_TRACE_BUDGET")
    if not budget:
        return
    from repro.core import scanloop
    total = sum(scanloop.TRACE_COUNTS.values())
    assert total <= int(budget), (
        f"retrace budget exceeded: {dict(scanloop.TRACE_COUNTS)} totals "
        f"{total} > {budget} — a chunked driver is re-tracing instead of "
        "hitting scanloop.cached_program")


def pytest_terminal_summary(terminalreporter):
    # always report the measurement so re-baselining the CI budget never
    # needs an instrumented rerun. This has to be a terminal-summary
    # hook: fd-level capture swallows even sys.__stderr__ writes from
    # session-fixture teardown on green runs.
    budget = os.environ.get("REPRO_TRACE_BUDGET")
    if not budget:
        return
    from repro.core import scanloop
    total = sum(scanloop.TRACE_COUNTS.values())
    terminalreporter.write_line(
        f"[trace-budget] {dict(scanloop.TRACE_COUNTS)} totals {total} "
        f"(budget {budget})")
