import os

# Tests see the real (single) CPU device — the 512-device override belongs
# ONLY to repro.launch.dryrun (per the dry-run contract). Guard against a
# leaked env var.
os.environ.pop("XLA_FLAGS", None) if "xla_force_host_platform_device_count" \
    in os.environ.get("XLA_FLAGS", "") else None

import jax
import pytest


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)
