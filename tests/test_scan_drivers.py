"""Loop-parity contract of the device-resident (chunked lax.scan) round
drivers: scanned vs host-loop produce BIT-IDENTICAL params,
rounds_used/t_i, metric history, and EF codec state — across engine
plans × codecs × chunk sizes, including chunk ∤ max_rounds and a target
hit mid-chunk — plus the engine's ``scan_rounds`` multi-round program
and the traced-sampler / pure_callback fallback machinery."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import federated, maml, scanloop
from repro.core import topology as topo_lib
from repro.core.engine import ConsensusEngine

K = 8


# ---------------------------------------------------------------------------
# toy FL problem: quadratic pull towards sampled targets (deterministic,
# converges fast, and every piece is traceable)
# ---------------------------------------------------------------------------


def _fl_loss(p, b):
    return jnp.mean((p["w"] - b["tgt"]) ** 2)


def _fl_stacked(key):
    return {"w": jax.random.normal(key, (K, 6)),
            "b": jax.random.normal(jax.random.fold_in(key, 1), (K, 3))}


def _fl_sampler(key, t):
    return {"tgt": jax.random.normal(key, (K, 3, 1, 6)) * 0.1}


def _target(thr):
    def target(sp):
        m = jnp.mean(jnp.square(sp["w"]))
        return m < thr, m
    return target


def _tree_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    return len(la) == len(lb) and all(
        np.array_equal(np.asarray(x), np.asarray(y))
        for x, y in zip(la, lb))


def _run(driver, engine, thr, *, max_rounds=21, **kw):
    return driver(
        _fl_loss, _fl_stacked(jax.random.PRNGKey(1)), _fl_sampler, engine,
        0.3, target_fn=_target(thr), max_rounds=max_rounds,
        key=jax.random.PRNGKey(7), return_state=True, **kw)


# ---------------------------------------------------------------------------
# the parity matrix
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("codec", [None, "int8"])
@pytest.mark.parametrize("plan,plan_kw", [
    ("dense-xla", {}),
    ("sparse-pallas", {}),
    ("sharded", {"num_blocks": 4}),            # the shard_map emulation
])
def test_fl_scan_matches_host_loop(plan, plan_kw, codec):
    """run_fl_until_scan == run_fl_until bit for bit: params, t_i,
    history, EF codec state — across chunk sizes including chunk=32 >
    max_rounds, chunk=4 (divides 21's cover of 24 unevenly), and
    chunk=7 (chunk ∤ max_rounds with the hit mid-chunk)."""
    topo = topo_lib.ring(K)
    eng = ConsensusEngine(topo, codec=codec, plan=plan, **plan_kw)
    # pick a threshold that hits strictly mid-run (rounds_used in
    # (1, max_rounds)) from a preliminary no-target trajectory
    _, _, probe_hist, _ = _run(federated.run_fl_until_scan, eng, -1.0,
                               chunk=32)
    thr = probe_hist[2] * 0.999        # first hit at round 3 of 21
    p_h, t_h, h_h, s_h = _run(federated.run_fl_until, eng, thr)
    assert 1 < t_h < 21                # the hit really is mid-run
    for chunk in (4, 7, 32):
        p_s, t_s, h_s, s_s = _run(federated.run_fl_until_scan, eng, thr,
                                  chunk=chunk)
        assert t_s == t_h, f"chunk={chunk}"
        assert h_s == h_h, f"chunk={chunk}"
        assert _tree_equal(p_s, p_h), f"chunk={chunk}"
        if codec is None:
            assert s_s is None and s_h is None
        else:
            assert _tree_equal(s_s, s_h), f"chunk={chunk}"


def test_fl_scan_never_reached_runs_max_rounds():
    """Unreachable target: every chunking runs exactly max_rounds rounds
    (frozen tail rounds past max_rounds are no-ops) with a full
    history, bit-identical to the host loop."""
    eng = ConsensusEngine(topo_lib.ring(K), plan="sparse-pallas")
    p_h, t_h, h_h, _ = _run(federated.run_fl_until, eng, -1.0,
                            max_rounds=10)
    assert t_h == 10 and len(h_h) == 10
    for chunk in (3, 4, 32):           # 3 ∤ 10, 4 ∤ 10, 32 > 10
        p_s, t_s, h_s, _ = _run(federated.run_fl_until_scan, eng, -1.0,
                                max_rounds=10, chunk=chunk)
        assert (t_s, h_s) == (10, h_h)
        assert _tree_equal(p_s, p_h)


def test_fl_scan_eval_every_matches_host():
    """eval_every > 1: evaluation (and the history grid) happens on the
    same rounds in both drivers, and the scanned t_i lands on an eval
    round exactly like the host loop's."""
    eng = ConsensusEngine(topo_lib.ring(K), codec="int8")
    _, _, probe, _ = _run(federated.run_fl_until_scan, eng, -1.0, chunk=32)
    thr = probe[3] * 0.999
    p_h, t_h, h_h, s_h = _run(federated.run_fl_until, eng, thr,
                              eval_every=2)
    assert t_h % 2 == 0                # hits only surface on eval rounds
    p_s, t_s, h_s, s_s = _run(federated.run_fl_until_scan, eng, thr,
                              eval_every=2, chunk=5)
    assert (t_s, h_s) == (t_h, h_h)
    assert _tree_equal(p_s, p_h) and _tree_equal(s_s, s_h)


def test_fl_scan_freeze_pins_params_after_hit():
    """The lax.cond freeze: params/EF-state at the hit round survive the
    rest of the chunk untouched — running with max_rounds == t_i gives
    the same pytrees as a longer run that froze mid-chunk."""
    eng = ConsensusEngine(topo_lib.ring(K), codec="int8")
    _, _, probe, _ = _run(federated.run_fl_until_scan, eng, -1.0, chunk=32)
    thr = probe[2] * 0.999
    p_long, t_long, _, s_long = _run(federated.run_fl_until_scan, eng, thr,
                                     max_rounds=21, chunk=21)
    p_cut, t_cut, _, s_cut = _run(federated.run_fl_until_scan, eng, thr,
                                  max_rounds=t_long, chunk=t_long)
    assert t_cut == t_long
    assert _tree_equal(p_cut, p_long) and _tree_equal(s_cut, s_long)


def test_fl_scan_host_callback_sampler_fallback():
    """A sampler that concretizes the round index (host numpy RNG) fails
    the traced-contract probe and runs through jax.pure_callback — same
    values, same parity."""
    calls = []

    def np_sampler(key, t):
        t = int(t)                     # host concretization: not traceable
        calls.append(t)
        rng = np.random.default_rng(31 + t)
        return {"tgt": jnp.asarray(
            rng.normal(size=(K, 3, 1, 6)).astype(np.float32) * 0.1)}

    eng = ConsensusEngine(topo_lib.ring(K))
    stacked = _fl_stacked(jax.random.PRNGKey(1))
    kw = dict(target_fn=_target(-1.0), max_rounds=6,
              key=jax.random.PRNGKey(7))
    p_h, t_h, h_h = federated.run_fl_until(
        _fl_loss, stacked, np_sampler, eng, 0.3, **kw)
    p_s, t_s, h_s = federated.run_fl_until_scan(
        _fl_loss, stacked, np_sampler, eng, 0.3, chunk=3, **kw)
    assert (t_s, h_s) == (t_h, h_h)
    assert _tree_equal(p_s, p_h)
    assert calls                       # the callback really ran on host


# ---------------------------------------------------------------------------
# MAML: maml_train_scan vs maml_train
# ---------------------------------------------------------------------------


def _net(p, x):
    return jnp.tanh(x @ p["w1"]) @ p["w2"]


def _maml_loss(p, b):
    return jnp.mean((_net(p, b["x"]) - b["y"]) ** 2)


def _maml_init(key):
    k1, k2 = jax.random.split(key)
    return {"w1": jax.random.normal(k1, (2, 8)) * 0.5,
            "w2": jax.random.normal(k2, (8, 1)) * 0.5}


def _maml_sampler(key, t):
    ks = jax.random.split(key, 2)

    def batch(k):
        x = jax.random.normal(k, (4, 16, 2))
        return {"x": x, "y": jnp.sin(x[..., :1]) * 0.3}

    return batch(ks[0]), batch(ks[1])


@pytest.mark.parametrize("first_order", [True, False])
def test_maml_scan_matches_host_loop(first_order):
    """maml_train_scan == maml_train bit for bit (params AND meta-loss
    history) for first- and second-order meta gradients, across chunk
    sizes including chunk ∤ rounds."""
    p0 = _maml_init(jax.random.PRNGKey(0))
    kw = dict(rounds=7, inner_lr=0.05, outer_lr=0.01,
              first_order=first_order, key=jax.random.PRNGKey(3))
    p_h, h_h = maml.maml_train(_maml_loss, p0, _maml_sampler, **kw)
    assert len(h_h) == 7
    for chunk in (1, 3, 8, 32):
        p_s, h_s = maml.maml_train_scan(_maml_loss, p0, _maml_sampler,
                                        chunk=chunk, **kw)
        assert h_s == h_h, f"chunk={chunk}"
        assert _tree_equal(p_s, p_h), f"chunk={chunk}"


def test_maml_scan_host_callback_sampler_fallback():
    """Non-traceable samplers (int(round) + host RNG) take the
    pure_callback fallback and still reproduce the host loop exactly."""

    def np_sampler(key, t):
        t = int(t)
        rng = np.random.default_rng(100 + t)

        def batch():
            x = rng.normal(size=(4, 16, 2)).astype(np.float32)
            return {"x": x, "y": np.sin(x[..., :1]) * 0.3}

        return batch(), batch()

    p0 = _maml_init(jax.random.PRNGKey(0))
    kw = dict(rounds=5, inner_lr=0.05, outer_lr=0.01,
              key=jax.random.PRNGKey(3))
    p_h, h_h = maml.maml_train(_maml_loss, p0, np_sampler, **kw)
    p_s, h_s = maml.maml_train_scan(_maml_loss, p0, np_sampler, chunk=4,
                                    **kw)
    assert h_s == h_h
    assert _tree_equal(p_s, p_h)


def test_maml_train_callback_still_fires_per_round():
    """The host-loop driver remains the per-round-callback path."""
    seen = []
    p0 = _maml_init(jax.random.PRNGKey(0))
    maml.maml_train(_maml_loss, p0, _maml_sampler, rounds=3,
                    inner_lr=0.05, outer_lr=0.01,
                    key=jax.random.PRNGKey(3),
                    callback=lambda t, p, m: seen.append(
                        (t, float(m["meta_loss"]))))
    assert [t for t, _ in seen] == [0, 1, 2]
    assert all(np.isfinite(l) for _, l in seen)


# ---------------------------------------------------------------------------
# engine.scan_rounds
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("plan,plan_kw", [
    ("dense-xla", {}),
    ("sparse-pallas", {}),
    ("sharded", {"num_blocks": 4}),
    ("distributed", {}),
])
def test_engine_scan_rounds_matches_repeated_step(plan, plan_kw):
    """scan_rounds(keys) == R successive engine.step calls for every
    plan, with the EF codec state threaded through the scan carry."""
    topo = topo_lib.ring(K)
    s = _fl_stacked(jax.random.PRNGKey(2))
    eng = ConsensusEngine(topo, codec="int8", plan=plan, **plan_kw)
    keys = jax.random.split(jax.random.PRNGKey(5), 4)
    p_ref, st_ref = s, eng.init_state(s)
    for k in keys:
        p_ref, st_ref = eng.step(p_ref, st_ref, k)
    p_scan, st_scan = jax.jit(
        lambda p, st, ks: eng.scan_rounds(p, st, ks))(
        s, eng.init_state(s), keys)
    for leaf in s:
        np.testing.assert_allclose(
            np.asarray(p_scan[leaf], np.float32),
            np.asarray(p_ref[leaf], np.float32), rtol=0, atol=1e-6,
            err_msg=f"{plan}/{leaf}")
        np.testing.assert_allclose(
            np.asarray(st_scan[leaf], np.float32),
            np.asarray(st_ref[leaf], np.float32), rtol=0, atol=1e-6,
            err_msg=f"{plan}/state/{leaf}")


def test_engine_scan_rounds_keyfree_and_validation():
    eng = ConsensusEngine(topo_lib.ring(K))
    s = _fl_stacked(jax.random.PRNGKey(2))
    p1, st1 = eng.scan_rounds(s, rounds=3)
    p_ref = s
    for _ in range(3):
        p_ref, _ = eng.step(p_ref)
    np.testing.assert_allclose(np.asarray(p1["w"]),
                               np.asarray(p_ref["w"]), rtol=0, atol=1e-6)
    assert st1 is None
    with pytest.raises(ValueError):
        eng.scan_rounds(s)             # neither keys nor rounds


# ---------------------------------------------------------------------------
# scanloop machinery
# ---------------------------------------------------------------------------


def test_traceable_probe_classifies_and_preserves_values():
    traced_fn, traced = scanloop.traceable(
        lambda k, t: jax.random.normal(k, (3,)) + t,
        jax.random.PRNGKey(0), jnp.int32(0))
    assert traced

    def host_fn(k, t):
        return np.float32(int(t)) * np.ones(3, np.float32)

    wrapped, traced = scanloop.traceable(host_fn, jax.random.PRNGKey(0),
                                         jnp.int32(0))
    assert not traced
    out = jax.jit(wrapped)(jax.random.PRNGKey(0), jnp.int32(4))
    np.testing.assert_array_equal(np.asarray(out),
                                  4 * np.ones(3, np.float32))


def test_traceable_routes_constant_output_samplers_to_callback():
    """Impure samplers (stateful iterators, cached host arrays) TRACE
    fine but their outputs are input-independent constants — inside a
    scan the single traced batch would silently replay every round, so
    the probe must route them through pure_callback instead."""
    batches = iter(np.arange(400, dtype=np.float32).reshape(100, 4))

    def it_sampler(key, t):
        return jnp.asarray(next(batches))

    wrapped, traced = scanloop.traceable(it_sampler, jax.random.PRNGKey(0),
                                         jnp.int32(0))
    assert not traced
    # the callback really advances the iterator per call
    a = np.asarray(jax.jit(wrapped)(jax.random.PRNGKey(0), jnp.int32(1)))
    b = np.asarray(jax.jit(wrapped)(jax.random.PRNGKey(0), jnp.int32(2)))
    assert not np.array_equal(a, b)


def test_first_hit():
    assert scanloop.first_hit([False, False, True, True]) == 2
    assert scanloop.first_hit([True]) == 0
    assert scanloop.first_hit([False, False]) is None
