"""MAML (Eqs. 3–5): inner adaptation, first- vs second-order meta
gradients, convergence on the sinusoid-regression testbed."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import maml


def _net(p, x):
    h = jax.nn.relu(x @ p["w1"] + p["b1"])
    return h @ p["w2"] + p["b2"]


def _loss(p, batch):
    return jnp.mean((_net(p, batch["x"]) - batch["y"]) ** 2)


def _init(key, width=32):
    k1, k2 = jax.random.split(key)
    return {"w1": jax.random.normal(k1, (1, width)) * 0.5,
            "b1": jnp.zeros(width),
            "w2": jax.random.normal(k2, (width, 1)) * 0.1,
            "b2": jnp.zeros(1)}


def _task_batch(key, amp, phase, n=32):
    x = jax.random.uniform(key, (n, 1), minval=-5, maxval=5)
    return {"x": x, "y": amp * jnp.sin(x + phase)}


def test_inner_adapt_reduces_loss(rng_key):
    p = _init(rng_key)
    b = _task_batch(rng_key, 1.0, 0.3)
    before = float(_loss(p, b))
    phi = maml.inner_adapt(_loss, p, b, lr=0.05, steps=10)
    assert float(_loss(phi, b)) < before


def test_inner_adapt_scan_vs_loop(rng_key):
    """Leading-steps-axis batches scan; equal to reusing a single batch
    when all steps' batches are identical."""
    p = _init(rng_key)
    b = _task_batch(rng_key, 1.0, 0.3)
    stacked = jax.tree.map(lambda x: jnp.stack([x, x, x]), b)
    a = maml.inner_adapt(_loss, p, stacked, lr=0.01, steps=3)
    c = maml.inner_adapt(_loss, p, b, lr=0.01, steps=3)
    for xa, xc in zip(jax.tree.leaves(a), jax.tree.leaves(c)):
        np.testing.assert_allclose(np.asarray(xa), np.asarray(xc),
                                   rtol=1e-5, atol=1e-6)


def _sample_tasks(key, Q=4):
    ks = jax.random.split(key, 2 + Q)
    amps = jax.random.uniform(ks[0], (Q,), minval=0.5, maxval=2.0)
    phases = jax.random.uniform(ks[1], (Q,), minval=0.0, maxval=np.pi)
    batches = [_task_batch(ks[2 + i], amps[i], phases[i]) for i in range(Q)]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *batches)


def test_meta_step_shapes_and_metrics(rng_key):
    p = _init(rng_key)
    sup = _sample_tasks(rng_key)
    qry = _sample_tasks(jax.random.fold_in(rng_key, 1))
    new_p, m = maml.maml_meta_step(_loss, p, sup, qry, inner_lr=0.01,
                                   outer_lr=0.01)
    assert m["task_losses"].shape == (4,)
    assert np.isfinite(float(m["meta_loss"]))
    # params actually moved
    diff = sum(float(jnp.sum(jnp.abs(a - b)))
               for a, b in zip(jax.tree.leaves(new_p), jax.tree.leaves(p)))
    assert diff > 0


def test_second_order_differs_from_first_order(rng_key):
    """The Jacobian term (Eq. 5) must change the meta gradient."""
    p = _init(rng_key)
    sup = _sample_tasks(rng_key)
    qry = _sample_tasks(jax.random.fold_in(rng_key, 1))
    fo, _ = maml.maml_meta_step(_loss, p, sup, qry, inner_lr=0.1,
                                outer_lr=1.0, first_order=True)
    so, _ = maml.maml_meta_step(_loss, p, sup, qry, inner_lr=0.1,
                                outer_lr=1.0, first_order=False)
    diff = sum(float(jnp.max(jnp.abs(a - b)))
               for a, b in zip(jax.tree.leaves(fo), jax.tree.leaves(so)))
    assert diff > 1e-6


def test_second_order_equals_first_order_at_zero_inner_lr(rng_key):
    """With μ = 0 the inner step is the identity, so J = I exactly and the
    two variants must coincide."""
    p = _init(rng_key)
    sup = _sample_tasks(rng_key)
    qry = _sample_tasks(jax.random.fold_in(rng_key, 1))
    fo, _ = maml.maml_meta_step(_loss, p, sup, qry, inner_lr=0.0,
                                outer_lr=0.5, first_order=True)
    so, _ = maml.maml_meta_step(_loss, p, sup, qry, inner_lr=0.0,
                                outer_lr=0.5, first_order=False)
    for a, b in zip(jax.tree.leaves(fo), jax.tree.leaves(so)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


@pytest.mark.slow
def test_maml_improves_post_adaptation_loss(rng_key):
    p = _init(rng_key, width=64)

    def sample(key, _t):
        return _sample_tasks(key), _sample_tasks(jax.random.fold_in(key, 7))

    def post_adapt(params, n=10):
        tot = 0.0
        for i in range(n):
            k = jax.random.fold_in(jax.random.PRNGKey(99), i)
            amp = 0.5 + 1.5 * (i / n)
            b = _task_batch(k, amp, 0.5)
            q = _task_batch(jax.random.fold_in(k, 1), amp, 0.5)
            phi = maml.inner_adapt(_loss, params, b, 0.02, 5)
            tot += float(_loss(phi, q))
        return tot / n

    base = post_adapt(p)
    trained, _ = maml.maml_train(_loss, p, sample, rounds=150,
                                 inner_lr=0.02, outer_lr=0.002)
    assert post_adapt(trained) < base
