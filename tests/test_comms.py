"""Compressed model-exchange subsystem: codec round-trip error bounds,
exact ``bits()`` accounting, error-feedback residual behaviour
(hypothesis), compressed-consensus convergence (the acceptance tolerance
test: int8 + error feedback reaches the uncompressed consensus mean on
ring/cluster graphs), and Pallas-vs-XLA parity of the fused
dequantize-consensus kernel at K = 256."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro import comms
from repro.core import consensus
from repro.core import topology as topo_lib
from repro.kernels import ops


def _tree(key, scale=1.0):
    return {"w": scale * jax.random.normal(key, (6, 5)),
            "b": scale * jax.random.normal(jax.random.fold_in(key, 1), (9,))}


# ---------------------------------------------------------------------------
# round-trip error bounds per codec
# ---------------------------------------------------------------------------


def test_identity_roundtrip_exact(rng_key):
    c = comms.get_codec("none")
    t = _tree(rng_key)
    out = c.decode(c.encode(t))
    for k in t:
        np.testing.assert_array_equal(np.asarray(out[k]), np.asarray(t[k]))


def test_bf16_roundtrip_bound(rng_key):
    c = comms.get_codec("bf16")
    t = _tree(rng_key)
    out = c.decode(c.encode(t))
    for k in t:
        x = np.asarray(t[k], np.float32)
        # bf16 keeps 8 mantissa bits ⇒ relative error <= 2^-8
        np.testing.assert_allclose(np.asarray(out[k]), x,
                                   atol=2.0 ** -8 * np.abs(x).max())


@pytest.mark.parametrize("bits,qmax", [(8, 127.0), (4, 7.0)])
def test_int_roundtrip_bound(rng_key, bits, qmax):
    c = comms.get_codec(f"int{bits}")
    t = _tree(rng_key)
    out = c.decode(c.encode(t))          # round-to-nearest (no key)
    for k in t:
        x = np.asarray(t[k], np.float32)
        step = np.abs(x).max() / qmax    # per-tensor absmax scale
        assert np.abs(np.asarray(out[k]) - x).max() <= 0.5 * step + 1e-7


def test_int8_stochastic_rounding_unbiased(rng_key):
    """E[decode(encode(x, key))] = x: the quantizer noise is zero-mean."""
    c = comms.get_codec("int8")
    x = {"w": jax.random.uniform(rng_key, (4, 4), minval=-1.0, maxval=1.0)}
    acc = np.zeros((4, 4), np.float32)
    reps = 300
    for i in range(reps):
        wire = c.encode(x, jax.random.fold_in(rng_key, i))
        acc += np.asarray(c.decode(wire)["w"], np.float32)
    step = np.abs(np.asarray(x["w"])).max() / 127.0
    # the empirical mean must be far tighter than one quantization step
    assert np.abs(acc / reps - np.asarray(x["w"])).max() < 0.2 * step


def test_topk_keeps_largest(rng_key):
    c = comms.get_codec("topk:3")
    x = {"w": jnp.asarray([0.1, -5.0, 0.2, 3.0, -0.05, 1.0])}
    out = c.decode(c.encode(x))["w"]
    np.testing.assert_allclose(np.asarray(out),
                               [0.0, -5.0, 0.0, 3.0, 0.0, 1.0], atol=1e-7)


# ---------------------------------------------------------------------------
# block-wise (per-channel) int scales
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("bits,qmax", [(8, 127.0), (4, 7.0)])
def test_blockwise_scales_tighten_roundtrip_bound(rng_key, bits, qmax):
    """Per-channel absmax scales bound the error by the LOCAL absmax:
    on a tensor mixing a tiny and a huge channel, the per-tensor scale
    drowns the tiny half in one global quantization step while block
    scales keep its relative error; the bound is provably tighter."""
    small = 1e-3 * jax.random.normal(rng_key, (64,))
    big = 1e2 * jax.random.normal(jax.random.fold_in(rng_key, 1), (64,))
    x = {"w": jnp.concatenate([small, big])}
    per_tensor = comms.get_codec(f"int{bits}")
    blockwise = comms.get_codec(f"int{bits}:b64")
    err_t = np.abs(np.asarray(per_tensor.decode(per_tensor.encode(x))["w"])
                   - np.asarray(x["w"]))
    err_b = np.abs(np.asarray(blockwise.decode(blockwise.encode(x))["w"])
                   - np.asarray(x["w"]))
    # each 64-block is bounded by ITS OWN absmax step...
    for sl in (slice(0, 64), slice(64, 128)):
        local_step = np.abs(np.asarray(x["w"][sl])).max() / qmax
        assert err_b[sl].max() <= 0.5 * local_step + 1e-7
    # ...which on the small half is orders of magnitude below the
    # per-tensor bound (and below its realized error)
    global_step = np.abs(np.asarray(x["w"])).max() / qmax
    assert err_b[:64].max() < 1e-3 * global_step
    assert err_b[:64].max() < err_t[:64].max()


def test_blockwise_bits_and_pricing_exact():
    c = comms.get_codec("int8:b16")
    x = {"w": jnp.ones((6, 5)), "b": jnp.ones((9,))}
    # 30 params -> 2 blocks of 16 (padded), 9 params -> 1 block
    assert c.leaf_bits((6, 5)) == 30 * 8 + 2 * 32
    assert c.leaf_bits((9,)) == 9 * 8 + 1 * 32
    assert c.model_bits(x) == 30 * 8 + 2 * 32 + 9 * 8 + 32
    assert c.bits(c.encode(x)) == c.model_bits(x)
    # price_bits includes the (non-negligible) block scales
    assert c.price_bits(39 * 32) == 39 * 8 + 32 * int(np.ceil(39 / 16))
    # spec round-trips, EF wraps, unknown block size form rejected
    assert comms.get_codec("int8:b16").name == "int8:b16"
    assert comms.resolve_codec("int4:b8").name == "int4:b8+ef"
    with pytest.raises(ValueError):
        comms.get_codec("int8:b0")


def test_blockwise_consensus_round_runs(rng_key):
    """Block-scaled wires thread the full compressed consensus path
    (dense impl here; the sparse/sharded paths keep the int8 lanes
    through the fused kernel's qblock support)."""
    K = 8
    s = {"w": jax.random.normal(rng_key, (K, 24))}
    mix = topo_lib.ring(K).mixing()
    want = consensus.consensus_step(s, mix)
    out, state = consensus.consensus_step(s, mix, codec="int8:b8")
    assert state is not None
    step = np.abs(np.asarray(s["w"])).max() / 127.0
    assert np.abs(np.asarray(out["w"])
                  - np.asarray(want["w"])).max() <= 3 * step


# ---------------------------------------------------------------------------
# adaptive codec selection from link quality
# ---------------------------------------------------------------------------


def test_select_codec_thresholds():
    """Cheap links afford wide wires; the graph's bottleneck link picks
    the codec. Paper calibration: SL = 4e6 bit/J (ring -> bf16), UL/DL =
    1.6e6 (star -> int8); an order-of-magnitude degraded edge -> int4."""
    assert comms.select_codec(topo_lib.ring(8)).name == "bf16+ef"
    assert comms.select_codec(topo_lib.star(8)).name == "int8+ef"
    degraded = topo_lib.ring(8).with_edge_efficiency(1e5)
    assert comms.select_codec(degraded).name == "int4+ef"
    # explicit link-quality dict + EF opt-out
    c = comms.select_codec(topo_lib.ring(8), {"SL": 1e6},
                           error_feedback=False)
    assert c.name == "int8"
    # hierarchical mixes SL + UL backhaul: the UL bottleneck decides
    assert comms.select_codec(
        topo_lib.hierarchical(3, 2)).name == "int8+ef"


def test_select_codec_edgeless_graph_returns_none():
    lonely = topo_lib.clusters(2, 1)          # 1-device clusters: no links
    assert comms.select_codec(lonely) is None


def test_link_efficiencies_reports_present_classes():
    effs = comms.link_efficiencies(topo_lib.star(6))
    assert set(effs) == {"UL", "DL"}
    # every edge overridden: the class constant prices NOTHING and must
    # not enter the bottleneck (round_comm_joules uses it only for
    # eff==0 edges) — only the per-edge worst case remains
    effs = comms.link_efficiencies(
        topo_lib.ring(6).with_edge_efficiency(2e5))
    assert set(effs) == {"edge"}
    assert effs["edge"] == pytest.approx(2e5)
    # partial override: both the unset edges' class and the edge min
    topo = topo_lib.ring(6)
    eff = np.where(topo.adjacency, 0.0, 0.0)
    first = tuple(np.argwhere(topo.adjacency)[0])
    eff[first] = 3e6
    effs = comms.link_efficiencies(topo.with_edge_efficiency(eff))
    assert set(effs) == {"SL", "edge"}
    # select_codec follows round_comm_joules: all-overridden cheap edges
    # afford bf16 even when the class constant would have said int8
    fast = topo_lib.ring(6).with_edge_efficiency(3e6)
    assert comms.select_codec(fast, {"SL": 1e6}).name == "bf16+ef"


def test_link_quality_dict_must_cover_present_classes():
    """A quality dict missing a class the graph USES is an error, not a
    silent fall-back to the uncompressed wire."""
    with pytest.raises(ValueError):
        comms.select_codec(topo_lib.star(8), {"SL": 1e6})


# ---------------------------------------------------------------------------
# bits() exactness + static Eq.-(11) pricing
# ---------------------------------------------------------------------------


def test_bits_exactness(rng_key):
    t = _tree(rng_key)                      # 30 + 9 = 39 params, 2 tensors
    expect = {
        "none": 39 * 32,
        "bf16": 39 * 16,
        "int8": 39 * 8 + 2 * 32,            # + one f32 scale per tensor
        "int4": 39 * 4 + 2 * 32,
        "topk:0.1": (3 + 1) * 64,           # ceil-ish: round(.1*30)=3, max(1,round(.1*9))=1
        "topk:4": (4 + 4) * 64,
    }
    for spec, want in expect.items():
        c = comms.get_codec(spec)
        wire = c.encode(t)
        assert c.bits(wire) == want, spec
        assert c.model_bits(t) == want, spec
        # error feedback never changes the wire size
        ef = comms.get_codec(spec + "+ef") if spec != "none" else c
        assert ef.leaf_bits((6, 5)) == c.leaf_bits((6, 5))


def test_price_bits_matches_per_param_rate():
    full = 5.6e6 * 8 * 4 / 4                 # arbitrary b(W)
    assert comms.get_codec("int8").price_bits(full) == full / 4
    assert comms.get_codec("int4").price_bits(full) == full / 8
    assert comms.get_codec("bf16").price_bits(full) == full / 2
    assert comms.get_codec("none").price_bits(full) == full
    # fractional top-k: k·(32+32) bits per param
    assert comms.get_codec("topk:0.05").price_bits(full) \
        == pytest.approx(full / 32 * 0.05 * 64, rel=1e-6)


def test_get_codec_specs():
    assert comms.get_codec(None) is None
    assert comms.get_codec("int8+ef").name == "int8+ef"
    assert comms.get_codec("int8+ef").stateful
    assert comms.resolve_codec("int8").name == "int8+ef"      # EF default
    assert comms.resolve_codec("int8", error_feedback=False).name == "int8"
    assert comms.resolve_codec("none").name == "none"         # never wrapped
    c = comms.get_codec("int4")
    assert comms.get_codec(c) is c
    with pytest.raises(ValueError):
        comms.get_codec("int16")
    with pytest.raises(ValueError):
        comms.ErrorFeedback(comms.get_codec("int8+ef"))


# ---------------------------------------------------------------------------
# error feedback: residuals keep the time-average unbiased
# ---------------------------------------------------------------------------


@settings(deadline=None, max_examples=10)
@given(seed=st.integers(0, 2 ** 16), bits=st.sampled_from([8, 4]))
def test_error_feedback_residual_convergence(seed, bits):
    """Encoding a CONSTANT model with EF: the running mean of the decoded
    stream converges to the model (residual telescopes the bias away),
    and the residual stays bounded by one quantization step."""
    rng = np.random.default_rng(seed)
    x = {"w": jnp.asarray(rng.normal(size=(5, 4)), jnp.float32)}
    c = comms.get_codec(f"int{bits}+ef")
    qmax = 127.0 if bits == 8 else 7.0
    step = float(np.abs(np.asarray(x["w"])).max()) / qmax
    state, acc, T = None, np.zeros((5, 4), np.float32), 40
    for t in range(T):
        wire, state = c.encode_stateful(x, state)
        acc += np.asarray(c.decode(wire)["w"], np.float32)
        # residual bounded: |r| <= step/2 + slack for the clip boundary
        assert np.abs(np.asarray(state["w"])).max() <= step * 1.5
    err = np.abs(acc / T - np.asarray(x["w"])).max()
    assert err <= step    # time-average error well below one LSB drift·T


def test_error_feedback_beats_plain_topk(rng_key):
    """With aggressive sparsification, EF consensus converges where the
    plain (stateless) codec stalls — the reason EF is the default."""
    K = 8
    s0 = {"w": jax.random.normal(rng_key, (K, 12))}
    mix = 0.4 * np.asarray(topo_lib.ring(K).mixing(kind="metropolis"))
    mean0 = np.asarray(s0["w"]).mean(axis=0)

    def run(codec, error_feedback):
        s, st_, k = dict(s0), None, jax.random.PRNGKey(7)
        for _ in range(300):
            k, sk = jax.random.split(k)
            s, st_ = consensus.consensus_step(
                s, mix, codec=codec, codec_state=st_, key=sk,
                error_feedback=error_feedback)
        return np.abs(np.asarray(s["w"]).mean(axis=0) - mean0).max(), \
            float(consensus.consensus_error(s))

    dev_ef, err_ef = run("topk:0.25", True)
    dev_plain, err_plain = run("topk:0.25", False)
    # EF contracts the residual quantization floor; plain top-k stalls
    assert err_ef < 0.5 * err_plain
    # the CHOCO recentering keeps the population mean EXACT either way
    # (doubly-stochastic σ) — compression error cancels in the sum
    assert dev_ef < 1e-5 and dev_plain < 1e-5


# ---------------------------------------------------------------------------
# compressed consensus — the acceptance tolerance test
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("topo", [topo_lib.ring(8),
                                  topo_lib.clusters(2, 4)])
def test_int8_consensus_reaches_uncompressed_mean(topo):
    """consensus_step(codec="int8") (error feedback on by default) must
    converge to the same consensus mean as the uncompressed step."""
    key = jax.random.PRNGKey(0)
    K = topo.K
    s0 = {"w": jax.random.normal(key, (K, 5, 3)),
          "b": jax.random.normal(jax.random.fold_in(key, 1), (K, 7))}
    mix = topo.mixing(kind="metropolis")

    ref = dict(s0)
    for _ in range(150):
        ref = consensus.consensus_step(ref, mix)

    s, state, k = dict(s0), None, jax.random.PRNGKey(42)
    for _ in range(150):
        k, sk = jax.random.split(k)
        s, state = consensus.consensus_step(s, mix, codec="int8",
                                            codec_state=state, key=sk)
    for leaf in s0:
        want = np.asarray(ref[leaf], np.float32)
        got = np.asarray(s[leaf], np.float32)
        scale = max(np.abs(want).max(), 1.0)
        assert np.abs(got - want).max() <= 2e-2 * scale, leaf
    if topo.is_connected():     # disjoint clusters keep per-cluster means
        assert float(consensus.consensus_error(s)) < 1e-3


def test_compressed_consensus_returns_state_and_none():
    s = {"w": jnp.ones((4, 8))}
    mix = topo_lib.ring(4).mixing()
    out, state = consensus.consensus_step(s, mix, codec="int8")
    assert state is not None and state["w"].shape == (4, 8)
    out2, state2 = consensus.consensus_step(s, mix, codec="int8",
                                            error_feedback=False)
    assert state2 is None
    # uncompressed API unchanged: bare pytree, no tuple
    assert isinstance(consensus.consensus_step(s, mix), dict)


def test_compressed_consensus_identity_codec_matches_uncompressed(rng_key):
    """codec="none" must be the plain Eq.-(6) step exactly (f32 wire)."""
    K = 6
    s = {"w": jax.random.normal(rng_key, (K, 10))}
    mix = topo_lib.ring(K).mixing()
    want = consensus.consensus_step(s, mix)
    got, state = consensus.consensus_step(s, mix, codec="none")
    assert state is None
    np.testing.assert_allclose(np.asarray(got["w"]),
                               np.asarray(want["w"]), rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# auto-path density heuristic is codec-aware
# ---------------------------------------------------------------------------


def test_auto_path_accounts_for_codec_payload():
    # ring(256, hops=40): H = 80 > 256//4 = 64 ⇒ dense at f32...
    mix = topo_lib.ring(256, hops=40).mixing()
    assert consensus.auto_path(mix) == "dense"
    # ...but int wires move 4×/8× fewer bytes THROUGH THE GATHER (the
    # fused dequant-consensus kernel consumes int8 lanes directly):
    # H_eff = 20 (int8) / 10 (int4) ⇒ sparse
    assert consensus.auto_path(mix, comms.get_codec("int8")) == "sparse"
    assert consensus.auto_path(mix, comms.get_codec("int8+ef")) == "sparse"
    assert consensus.auto_path(mix, comms.get_codec("int4+ef")) == "sparse"
    # block-wise scales ride the fused kernel too, at 8 + 32/64 wire
    # bits per param
    assert consensus.auto_path(mix, comms.get_codec("int8:b64")) == "sparse"
    # f32 wire: unchanged
    assert consensus.auto_path(mix, comms.get_codec("none")) == "dense"
    # bf16/top-k sparse paths gather DECODED f32 neighbours, so their
    # degree counts at full width — no discount, stays dense
    assert consensus.auto_path(mix, comms.get_codec("bf16")) == "dense"
    assert consensus.auto_path(mix, comms.get_codec("topk:0.05")) == "dense"
    star = topo_lib.star(256).mixing()
    # at int8, h_eff = (K−1)/4 ≤ K/4 ALWAYS: even star's gather moves
    # fewer bytes than the f32 matmul — every graph goes sparse
    assert consensus.auto_path(star, comms.get_codec("int8")) == "sparse"
    # ...except below the calibrated K·degree floor, where the vmapped
    # gather can't amortize its overhead (K=12 ring ran at 0.59× dense
    # in BENCH_consensus_scale): small populations stay dense no matter
    # how light the wire
    small = topo_lib.ring(12, hops=2).mixing()
    assert consensus.auto_path(small) == "dense"
    assert consensus.auto_path(small, comms.get_codec("int8")) == "dense"


# ---------------------------------------------------------------------------
# fused quant-consensus kernel: Pallas vs XLA parity
# ---------------------------------------------------------------------------


def test_quant_consensus_kernel_parity():
    """ops.quant_consensus_update interpret (Pallas body) == XLA oracle."""
    rng = np.random.default_rng(0)
    N, H = 1000, 3
    x = jnp.asarray(rng.normal(size=N), jnp.float32)
    qs = jnp.asarray(rng.integers(-127, 128, N), jnp.int8)
    ss = jnp.float32(0.01)
    qn = jnp.asarray(rng.integers(-127, 128, (H, N)), jnp.int8)
    sn = jnp.asarray(rng.uniform(0.005, 0.02, H), jnp.float32)
    sig = jnp.asarray(rng.uniform(0.0, 0.3, H), jnp.float32)
    a = ops.quant_consensus_update(x, qs, ss, qn, sn, sig, impl="xla")
    b = ops.quant_consensus_update(x, qs, ss, qn, sn, sig,
                                   impl="interpret", block_n=256)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-6, atol=1e-6)


def test_quant_consensus_kernel_guards():
    x = jnp.zeros(8, jnp.float32)
    q = jnp.zeros(8, jnp.int8)
    qn = jnp.zeros((2, 8), jnp.int8)
    s = jnp.ones(2, jnp.float32)
    with pytest.raises(TypeError):        # wire must be int8
        ops.quant_consensus_update(x, x, jnp.float32(1), qn, s, s)
    with pytest.raises(ValueError):       # mismatched neighbour count
        ops.quant_consensus_update(x, q, jnp.float32(1), qn, s,
                                   jnp.ones(3))


def test_quant_consensus_parity_at_k256():
    """Full consensus_step parity at K = 256 on a ring: the sparse
    gather + fused Pallas dequant-consensus kernel (interpret mode off
    TPU) must match the dense XLA compressed path."""
    K, N = 256, 96
    key = jax.random.PRNGKey(3)
    s = {"w": jax.random.normal(key, (K, N))}
    mix = topo_lib.ring(K).mixing()
    dense, _ = consensus.consensus_step(s, mix, codec="int8",
                                        impl="xla")
    sparse, _ = consensus.consensus_step(s, mix, codec="int8",
                                         impl="pallas", block_n=N)
    np.testing.assert_allclose(np.asarray(sparse["w"]),
                               np.asarray(dense["w"]),
                               rtol=1e-5, atol=1e-5)


def test_blockwise_quant_consensus_kernel_parity():
    """The fused kernel with per-channel BLOCK-WISE scales (qblock):
    Pallas body (interpret) == XLA oracle == manual decode-then-mix,
    including a tensor length that is not a multiple of the scale block
    or the kernel tile."""
    rng = np.random.default_rng(1)
    N, H, B = 300, 3, 64                  # 300 = 4 full blocks + 44 tail
    nb = -(-N // B)
    x = jnp.asarray(rng.normal(size=N), jnp.float32)
    qs = jnp.asarray(rng.integers(-127, 128, N), jnp.int8)
    ss = jnp.asarray(rng.uniform(0.005, 0.02, nb), jnp.float32)
    qn = jnp.asarray(rng.integers(-127, 128, (H, N)), jnp.int8)
    sn = jnp.asarray(rng.uniform(0.005, 0.02, (H, nb)), jnp.float32)
    sig = jnp.asarray(rng.uniform(0.0, 0.3, H), jnp.float32)
    a = ops.quant_consensus_update(x, qs, ss, qn, sn, sig, impl="xla",
                                   qblock=B)
    b = ops.quant_consensus_update(x, qs, ss, qn, sn, sig,
                                   impl="interpret", qblock=B, block_n=128)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-6, atol=1e-6)
    # manual decode (the codec's own blocking) then the plain Eq.-6 mix
    codec = comms.IntCodec(8, block=B)
    like = jax.ShapeDtypeStruct((N,), jnp.float32)
    xhat = codec.decode_leaf({"q": qs, "scale": ss}, like)
    nbs = jnp.stack([codec.decode_leaf({"q": qn[h], "scale": sn[h]}, like)
                     for h in range(H)])
    want = x + jnp.einsum("h,hn->n", sig, nbs - xhat[None])
    np.testing.assert_allclose(np.asarray(a), np.asarray(want),
                               rtol=1e-6, atol=1e-6)
    # scale-count guard
    with pytest.raises(ValueError):
        ops.quant_consensus_update(x, qs, ss[:-1], qn, sn, sig, qblock=B)


def test_sharded_blockwise_int8_stays_fused_parity_at_k256():
    """int8:b64 wires on the SHARDED plan: block-scaled int wires stay
    int8 lanes through the all_gather and dequantize INSIDE the fused
    combine (no decode-then-combine), matching the per-agent jnp oracle
    at K = 256 and preserving the population mean exactly under
    doubly-stochastic σ."""
    from repro.core.engine import ConsensusEngine
    from repro.kernels import ref

    K, N, B = 256, 96, 64
    s = {"w": jax.random.normal(jax.random.PRNGKey(3), (K, N))}
    topo = topo_lib.ring(K)
    eng = ConsensusEngine(topo, codec="int8:b64", plan="sharded",
                          num_blocks=8)
    out, state = eng.step(s, eng.init_state(s))
    assert state is not None              # EF residual threads through
    # oracle: EF residual starts at 0 ⇒ the wire is the plain blocked
    # encode; mix every row with the blocked reference kernel
    base = eng.codec.inner
    mix = np.asarray(topo.mixing())
    idx, sg = consensus.sparse_structure(mix)
    xf = jnp.asarray(np.asarray(s["w"], np.float32))
    enc = jax.vmap(lambda m: base.encode_leaf(m, None))(xf)
    want = np.stack([np.asarray(ref.quant_consensus_update_reference(
        xf[k], enc["q"][k], enc["scale"][k], enc["q"][idx[k]],
        enc["scale"][idx[k]], jnp.asarray(sg[k]), qblock=B))
        for k in range(K)])
    np.testing.assert_allclose(np.asarray(out["w"], np.float32), want,
                               rtol=0, atol=1e-5)
    # CHOCO mean exactness survives the blocked wire
    mixm = np.asarray(topo.mixing(kind="metropolis"))
    engm = ConsensusEngine(mixm, codec="int8:b64", plan="sharded",
                           num_blocks=8)
    outm, _ = engm.step(s, engm.init_state(s))
    np.testing.assert_allclose(
        np.asarray(outm["w"], np.float32).mean(axis=0),
        np.asarray(s["w"], np.float32).mean(axis=0), atol=1e-5)
