"""Per-kernel correctness: interpret-mode Pallas vs pure-jnp oracle,
swept over shapes and dtypes (assignment contract (c))."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def _rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


TOL = {jnp.float32: dict(rtol=2e-3, atol=2e-3),
       jnp.bfloat16: dict(rtol=6e-2, atol=6e-2)}


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,H,K,hd,causal,window", [
    (2, 128, 4, 2, 64, True, 0),
    (1, 256, 4, 4, 64, True, 0),
    (2, 128, 4, 1, 64, True, 64),     # MQA + sliding window
    (1, 96, 2, 2, 32, True, 0),       # non-multiple-of-block seq
    (1, 128, 4, 2, 128, False, 0),    # bidirectional, hd=128
    (1, 64, 8, 2, 16, True, 32),
])
def test_flash_attention_vs_oracle(B, S, H, K, hd, causal, window, dtype,
                                   rng_key):
    ks = jax.random.split(rng_key, 3)
    q = _rand(ks[0], (B, S, H, hd), dtype)
    k = _rand(ks[1], (B, S, K, hd), dtype)
    v = _rand(ks[2], (B, S, K, hd), dtype)
    out = ops.flash_attention(q, k, v, causal=causal, window=window,
                              impl="interpret", block_q=64, block_k=64)
    want = ref.mha_reference(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **TOL[dtype])


def test_flash_attention_softcap(rng_key):
    ks = jax.random.split(rng_key, 3)
    q = _rand(ks[0], (1, 64, 2, 32), jnp.float32)
    k = _rand(ks[1], (1, 64, 2, 32), jnp.float32)
    v = _rand(ks[2], (1, 64, 2, 32), jnp.float32)
    out = ops.flash_attention(q, k, v, softcap=30.0, impl="interpret",
                              block_q=32, block_k=32)
    want = ref.mha_reference(q, k, v, softcap=30.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,T,W,bt,bw", [
    (2, 64, 32, 16, 16),
    (1, 100, 48, 32, 32),      # ragged T and W
    (3, 256, 128, 128, 128),
])
def test_rglru_scan_vs_oracle(B, T, W, bt, bw, dtype, rng_key):
    ks = jax.random.split(rng_key, 3)
    log_a = (-jax.nn.softplus(_rand(ks[0], (B, T, W), jnp.float32))
             ).astype(dtype)
    b = _rand(ks[1], (B, T, W), dtype)
    h0 = _rand(ks[2], (B, W), jnp.float32)
    h, hl = ops.rglru_scan(log_a, b, h0, impl="interpret",
                           block_t=bt, block_w=bw)
    hr, hlr = ref.rglru_scan_reference(log_a, b, h0)
    np.testing.assert_allclose(np.asarray(h, np.float32),
                               np.asarray(hr, np.float32), **TOL[dtype])
    np.testing.assert_allclose(np.asarray(hl), np.asarray(hlr),
                               **TOL[dtype])


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("H,N,bn", [
    (1, 128, 64), (2, 1000, 256), (4, 70000, 8192),
])
def test_consensus_update_vs_oracle(H, N, bn, dtype, rng_key):
    ks = jax.random.split(rng_key, 3)
    x = _rand(ks[0], (N,), dtype)
    nb = _rand(ks[1], (H, N), dtype)
    sig = jax.nn.softmax(jax.random.normal(ks[2], (H,))) * 0.7
    y = ops.consensus_update(x, nb, sig, impl="interpret", block_n=bn)
    want = ref.consensus_update_reference(x, nb, sig)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(want, np.float32), **TOL[dtype])


def test_ops_shape_guards(rng_key):
    q = jnp.zeros((2, 8, 4, 16))
    k = jnp.zeros((2, 8, 3, 16))    # H % K != 0
    with pytest.raises(ValueError):
        ops.flash_attention(q, k, k)
    with pytest.raises(TypeError):
        ops.consensus_update(jnp.zeros(4, jnp.int32), jnp.zeros((1, 4)),
                             jnp.ones(1))
