"""End-to-end behaviour tests for the paper's system (integration level):
the two-stage protocol on the gridworld case study + the federated LM
trainer + the serve path, all at CPU-tractable sizes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, reduced


def test_case_study_round_and_energy():
    """One jitted MAML round + one FL round of the paper's case study run,
    produce finite numbers, and the energy accounting composes."""
    from repro.rl.casestudy import CaseStudy
    cs = CaseStudy()
    key = jax.random.PRNGKey(0)
    p = cs.init_params(key)
    p2, m = cs._meta_round(p, key)
    assert np.isfinite(float(m["meta_loss"]))
    stacked = jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (2,) + x.shape), p2)
    stacked2, _, R = cs._fl_rounds[0](stacked, None, key, jnp.int32(0))
    assert np.isfinite(float(R))
    res_like = cs.run(jax.random.PRNGKey(1), 0, max_rounds=2)
    s = res_like.summary()
    assert s["E_ML_kJ"] == 0.0            # t0 = 0: no MAML energy
    assert s["E_total_kJ"] > 0


def test_case_study_codec_round_and_energy():
    """The same FL round with an int8 sidelink codec: finite reward,
    error-feedback state threaded, and the Eq.-(11) share of E_FL priced
    4× below the uncompressed exchange."""
    from repro.core import energy
    from repro.rl.casestudy import CaseStudy
    cs = CaseStudy(codec="int8")
    assert cs.codec.name == "int8+ef"
    key = jax.random.PRNGKey(0)
    p = cs.init_params(key)
    stacked = jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (2,) + x.shape), p)
    state = cs.codec.init_state(stacked)
    stacked2, state2, R = cs._fl_rounds[0](stacked, state, key,
                                           jnp.int32(0))
    assert np.isfinite(float(R))
    assert jax.tree.structure(state2) == jax.tree.structure(stacked)
    # codec-priced Eq. (11): comm term drops exactly bits-ratio-fold
    ep = cs.energy_params
    comm = energy.fl_comm_energy(ep, 10, cs.cluster_topology, cs.codec)
    comm_full = energy.fl_comm_energy(ep, 10, cs.cluster_topology)
    assert comm == pytest.approx(comm_full / 4)


def test_case_study_dropout_measures_ti_and_prices_sent_messages():
    """End-to-end RL sweep under p = 0.2 link failures: t_i is measured
    on the time-varying graph (each round mixes only surviving links) and
    the adaptation's Eq.-(11) comm term sums EXACTLY the per-round joules
    of the links actually up — deterministic in the dropout seed."""
    import pytest
    from repro.core import energy, topology as topo_lib
    from repro.rl.casestudy import CaseStudy
    cs = CaseStudy(dropout_p=0.2)
    key = jax.random.PRNGKey(2)
    p = cs.init_params(key)
    _, rounds, hist = cs.adapt_task(key, 0, p, max_rounds=3)
    assert 1 <= rounds <= 3 and len(hist) <= 3
    assert all(np.isfinite(h) for h in hist)
    # measured pricing == replaying the same deterministic fade sequence
    topos = topo_lib.dropout(cs.cluster_topology, 0.2,
                             seed=cs.dropout_seed + 0, rounds=len(hist))
    want = sum(t.round_comm_joules(cs.energy_params) for t in topos)
    assert cs.last_adapt_comm_joules == pytest.approx(want)
    # never above the static graph's bill (faded rounds send less)
    static = len(hist) * cs.cluster_topology.round_comm_joules(
        cs.energy_params)
    assert cs.last_adapt_comm_joules <= static + 1e-9


def test_protocol_result_uses_measured_comm_joules():
    """ProtocolResult prefers per-round MEASURED Eq.-(11) joules (dropout
    runs) over the static-graph model in E_FL."""
    import pytest
    from repro.core import energy, topology as topo_lib
    from repro.core.protocol import ProtocolResult
    ep = energy.paper_calibrated("fig3")
    topo = topo_lib.clusters(1, 2)
    res = ProtocolResult(
        t0=0, rounds_per_task=[4], meta_history=[], fl_histories=[[0.0]],
        energy_params=ep, Q=1, cluster_topology=topo,
        fl_comm_joules_measured=[5.0])
    assert res.E_FL_comm == [5.0]
    assert res.E_FL[0] == pytest.approx(
        energy.fl_learning_energy(ep, 4, topo) + 5.0)
    res_static = ProtocolResult(
        t0=0, rounds_per_task=[4], meta_history=[], fl_histories=[[0.0]],
        energy_params=ep, Q=1, cluster_topology=topo)
    assert res_static.E_FL[0] == pytest.approx(
        energy.fl_energy(ep, 4, topo))


def test_protocol_generic_toy():
    """The generic MTLProtocol runs end-to-end on a toy regression MTL
    network (model-agnostic contract of core/protocol.py)."""
    from repro.core.multitask import ClusterNetwork
    from repro.core.protocol import MTLProtocol

    def net(p, x):
        return jnp.tanh(x @ p["w1"]) @ p["w2"]

    def loss_fn(p, batch):
        return jnp.mean((net(p, batch["x"]) - batch["y"]) ** 2)

    def init_fn(key):
        k1, k2 = jax.random.split(key)
        return {"w1": jax.random.normal(k1, (2, 16)) * 0.5,
                "w2": jax.random.normal(k2, (16, 1)) * 0.5}

    def task_fn(task_id, x):
        return jnp.sin(x[:, :1] + task_id) + 0.5 * task_id * x[:, 1:2]

    def sample_support(key, task_id, steps):
        xs = jax.random.normal(key, (steps, 16, 2))
        return {"x": xs, "y": jax.vmap(lambda x: task_fn(task_id, x))(xs)}

    def sample_query(key, task_id):
        x = jax.random.normal(key, (16, 2))
        return {"x": x, "y": task_fn(task_id, x)}

    def target_fn(p, task_id):
        l = loss_fn(p, sample_query(jax.random.PRNGKey(7), task_id))
        return l < 0.05, -l

    proto = MTLProtocol(
        loss_fn=loss_fn, init_fn=init_fn,
        network=ClusterNetwork(num_tasks=2, devices_per_cluster=2,
                               meta_task_ids=(0,)),
        sample_support=sample_support, sample_query=sample_query,
        target_fn=target_fn, inner_lr=0.05, outer_lr=0.02, fl_lr=0.05,
        inner_steps=3, fl_local_steps=5)
    res = proto.run(jax.random.PRNGKey(0), t0=5, max_rounds=30)
    assert len(res.rounds_per_task) == 2
    assert res.E_total > 0
    assert len(res.meta_history) == 5


def test_federated_lm_trainer_loss_drops():
    # lr 0.1, not 5e-3: the local updates are plain clipped SGD, which at
    # 5e-3 plateaus right after the easy move-mass-to-the-active-vocab win
    # and the 12-round loss never clears the drop threshold
    from repro.launch.train import train_federated
    cfg = reduced(get_arch("stablelm-3b"), num_layers=2, d_model=64)
    _, hist, E = train_federated(cfg, rounds=12, agents=4, tasks=2,
                                 local_steps=8, batch=4, seq=64, lr=1e-1)
    assert E > 0
    assert min(hist[-3:]) < np.mean(hist[:2]) - 0.05


def test_federated_bf16_consensus_close_to_f32():
    from repro.launch.train import train_federated
    cfg = reduced(get_arch("stablelm-3b"), num_layers=2, d_model=64)
    _, h32, _ = train_federated(cfg, rounds=4, agents=2, tasks=1,
                                local_steps=2, batch=2, seq=32, lr=1e-3)
    _, h16, _ = train_federated(cfg, rounds=4, agents=2, tasks=1,
                                local_steps=2, batch=2, seq=32, lr=1e-3,
                                consensus_dtype=jnp.bfloat16)
    assert abs(h16[-1] - h32[-1]) < 0.15


def test_serve_path_runs():
    from repro.launch.serve import serve
    cfg = reduced(get_arch("h2o-danube-3-4b"))
    toks = serve(cfg, batch=2, prompt_len=16, gen=4, verbose=False)
    assert toks.shape == (2, 4)
    assert int(toks.max()) < cfg.vocab_size


def test_train_standard_loss_drops():
    from repro.launch.train import train_standard
    cfg = reduced(get_arch("deepseek-7b"), num_layers=2, d_model=64)
    _, hist = train_standard(cfg, steps=8, batch=4, seq=64, lr=3e-3,
                             log_every=100)
    assert hist[-1] < hist[0]


def test_checkpoint_roundtrip_with_trainer():
    import tempfile
    from repro.checkpoint import CheckpointManager
    from repro.models.api import get_model
    cfg = reduced(get_arch("granite-8b"))
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0), cfg)
    with tempfile.TemporaryDirectory() as d:
        cm = CheckpointManager(d)
        cm.save(10, {"params": params})
        restored, step = cm.restore({"params": params})
        assert step == 10
        for a, b in zip(jax.tree.leaves(params),
                        jax.tree.leaves(restored["params"])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
