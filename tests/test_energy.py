"""Energy model (Eqs. 8–12): closed-form checks, paper-claim validation,
hypothesis property tests on monotonicity/scaling invariants."""
import dataclasses

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import energy

PAPER_T2 = {
    0: [380.1, 129.6, 93.7, 211.5, 24.2, 82.4],
    42: [29.7, 56.4, 70.9, 87.0, 70.4, 57.1],
    66: [178.8, 9.9, 14.3, 104.6, 9.8, 12.4],
    90: [84.9, 8.9, 15.6, 166.2, 11.3, 19.6],
    132: [11.6, 25.5, 25.1, 44.6, 23.1, 23.8],
    210: [6.7, 29.1, 16.5, 27.7, 32.0, 17.2],
    240: [2.7, 10.8, 9.1, 40.0, 21.8, 19.6],
}


def test_eq9_closed_form():
    p = energy.EnergyParams()
    t0, Q = 10, 3
    learn = energy.maml_learning_energy(p, t0, Q)
    want = (p.gamma * t0 * Q * p.meta_devices_per_task
            * (p.B_a + p.beta * p.B_b) * p.P_datacenter
            * p.T_batch_datacenter)
    assert np.isclose(learn, want)
    comm = energy.maml_comm_energy(p, t0, Q)
    want = (t0 * Q * p.meta_devices_per_task * p.data_bits / p.E_UL
            + p.K * p.model_bits / p.E_DL)
    assert np.isclose(comm, want)


def test_eq11_closed_form():
    p = energy.EnergyParams()
    t = 17
    want_l = t * p.devices_per_cluster * p.B_i * p.P_device * p.T_batch_device
    assert np.isclose(energy.fl_learning_energy(p, t), want_l)
    want_c = (p.model_bits * t * p.devices_per_cluster
              * p.neighbors_per_device / p.E_SL)
    assert np.isclose(energy.fl_comm_energy(p, t), want_c)


def test_sidelink_replacement():
    p = dataclasses.replace(energy.EnergyParams(),
                            sidelink_available=False)
    c = energy.sidelink_cost_per_bit(p)
    assert np.isclose(c, 1 / p.E_UL + p.gamma / p.E_DL)
    assert c > energy.sidelink_cost_per_bit(energy.EnergyParams())


def test_beta_jacobian_cost():
    """2nd-order MAML (β = 2) must cost more than first-order (β = 1)."""
    p1 = energy.paper_calibrated("fig3")
    p2 = dataclasses.replace(p1, beta=2.0)
    assert energy.maml_energy(p2, 100, 3) > energy.maml_energy(p1, 100, 3)


# ---------------------------------------------------------------------------
# the paper's claims under the calibrated constants
# ---------------------------------------------------------------------------


def test_paper_fig3_reproduction():
    p = energy.paper_calibrated("fig3")
    E_ml = energy.maml_energy(p, 210, 3)
    assert abs(E_ml / 1e3 - 74) / 74 < 0.15          # paper: 74 kJ
    E_fl = sum(energy.fl_energy(p, t) for t in PAPER_T2[210])
    assert abs(E_fl / 1e3 - 32) / 32 < 0.25          # paper: 32 kJ
    total = energy.total_energy(p, 210, 3, PAPER_T2[210])
    no_maml = energy.total_energy(p, 0, 3, PAPER_T2[0])
    assert abs(no_maml / 1e3 - 227) / 227 < 0.15     # paper: 227 kJ
    assert no_maml / total >= 2.0                    # the >=2x headline


def test_paper_fig4_optimum_shift():
    """Optimal t0 = 42 with cheap sidelink, 132 with cheap uplink."""
    p = energy.paper_calibrated("fig4")
    _, _, eb = energy.optimize_split(p, 3, {k: v for k, v in
                                            PAPER_T2.items() if k > 0})
    assert min(eb, key=eb.get) == 42
    pr = energy.swap_ul_sl(p)
    _, _, er = energy.optimize_split(pr, 3, {k: v for k, v in
                                             PAPER_T2.items() if k > 0})
    assert min(er, key=er.get) == 132


def test_tpu_energy_params_single_chip_mapping():
    """The device role is ONE chip running the whole per-step workload with
    no collectives; the data-center role keeps the full slice. Both must be
    consistent with RooflineTerms.energy_per_step at PUE 1."""
    rt = energy.RooflineTerms(flops=3e14, hbm_bytes=2e12,
                              collective_bytes=5e11, chips=16)
    p = energy.tpu_energy_params(rt, model_bytes=8e9)
    single = energy.single_chip_terms(rt)
    assert single.chips == 1 and single.collective_bytes == 0.0
    assert np.isclose(p.T_batch_device, single.step_time)
    # Ek_C = P_device · T_batch_device == 1-chip J/step (PUE excluded:
    # the paper's device term carries no data-center PUE)
    assert np.isclose(p.Ek_C, single.energy_per_step(pue=1.0))
    # E0_C = P_dc · T_dc == full-slice J/step at PUE 1 (γ carries the PUE)
    assert np.isclose(p.E0_C, rt.energy_per_step(pue=1.0))
    assert np.isclose(p.gamma, energy.TPU_V5E["host_pue"])
    # the lone device is never faster than its share of the full slice
    assert p.T_batch_device >= rt.step_time - 1e-12
    # overrides still apply on top
    p2 = energy.tpu_energy_params(rt, model_bytes=8e9, B_i=7)
    assert p2.B_i == 7 and np.isclose(p2.T_batch_device, p.T_batch_device)


# ---------------------------------------------------------------------------
# property tests
# ---------------------------------------------------------------------------


@settings(deadline=None, max_examples=40)
@given(t0=st.integers(1, 500), Q=st.integers(1, 6),
       scale=st.floats(1.1, 10.0))
def test_maml_energy_monotone_in_rounds_and_comm(t0, Q, scale):
    p = energy.paper_calibrated("fig3")
    assert energy.maml_energy(p, t0 + 1, Q) > energy.maml_energy(p, t0, Q)
    cheaper = dataclasses.replace(p, E_UL=p.E_UL * scale)
    assert energy.maml_energy(cheaper, t0, Q) < energy.maml_energy(p, t0, Q)


@settings(deadline=None, max_examples=40)
@given(t=st.floats(0.0, 500.0), s=st.floats(1.1, 4.0))
def test_fl_energy_linear_in_rounds(t, s):
    p = energy.paper_calibrated("fig3")
    assert np.isclose(energy.fl_energy(p, t * s),
                      s * energy.fl_energy(p, t), rtol=1e-6)


@settings(deadline=None, max_examples=20)
@given(flops=st.floats(1e9, 1e18), bts=st.floats(1e6, 1e15),
       coll=st.floats(0, 1e14), chips=st.integers(1, 512))
def test_roofline_terms_positive_and_bottleneck(flops, bts, coll, chips):
    rt = energy.RooflineTerms(flops=flops, hbm_bytes=bts,
                              collective_bytes=coll, chips=chips)
    assert rt.step_time >= max(rt.t_compute, rt.t_memory, rt.t_collective) \
        - 1e-12
    assert rt.bottleneck in ("compute", "memory", "collective")
    assert rt.energy_per_step() > 0
    # doubling chips cannot increase any term
    rt2 = energy.RooflineTerms(flops=flops, hbm_bytes=bts,
                               collective_bytes=coll, chips=2 * chips)
    assert rt2.step_time <= rt.step_time + 1e-12
