"""Time-varying graphs as first-class engine plans: the GraphProcess
contract (in-scan per-round survival masks on every maskable plan,
bit-identical to the host-prefetched ``topology.dropout`` stream via the
shared fold-in convention), the compiled-chunk-program cache (trace-count
guard), and the CaseStudy regressions (plan knob respected, dropout on
non-dense plans, Eq.-(11) billed over exactly rounds_used)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import federated, maml, scanloop
from repro.core import topology as topo_lib
from repro.core.engine import ConsensusEngine, MASKABLE_PLANS

K = 8
P, SEED, ROUNDS = 0.3, 5, 32

PLANS = [("dense-xla", {}),
         ("sparse-pallas", {}),
         ("sharded", {"num_blocks": 4}),       # the shard_map emulation
         ("distributed", {})]                  # masked ppermute schedule


def _topo():
    return topo_lib.ring(K)


def _gp():
    return topo_lib.GraphProcess.dropout(P, seed=SEED)


def _stacked(key):
    return {"w": jax.random.normal(key, (K, 6)),
            "b": jax.random.normal(jax.random.fold_in(key, 1), (K, 3))}


def _tree_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    return len(la) == len(lb) and all(
        np.array_equal(np.asarray(x), np.asarray(y))
        for x, y in zip(la, lb))


# ---------------------------------------------------------------------------
# the shared fold-in convention
# ---------------------------------------------------------------------------


def test_survival_mask_bit_matches_host_dropout_stream():
    """round_mask(t) — traced, as the scanned drivers call it — equals
    round t of the host topology.dropout stream bit for bit, for every
    round: the one convention in-scan generation and post-hoc Eq.-(11)
    billing share."""
    topo = _topo()
    eng = ConsensusEngine(topo, graph=_gp())
    masked = jax.jit(eng.round_mask)
    for t, rt in enumerate(topo_lib.dropout(topo, P, seed=SEED, rounds=12)):
        np.testing.assert_array_equal(
            np.asarray(masked(jnp.int32(t))), rt.adjacency, err_msg=f"t={t}")


def test_survival_mask_symmetry_and_p0():
    topo = _topo()
    key = topo_lib.survival_key(3)
    m = np.asarray(topo_lib.survival_mask(topo.adjacency, 0.4, key, 2))
    assert np.array_equal(m, m.T)              # pairs fade together
    assert not (m & ~topo.adjacency).any()     # subgraph of the base
    m0 = np.asarray(topo_lib.survival_mask(topo.adjacency, 0.0, key, 2))
    np.testing.assert_array_equal(m0, topo.adjacency)   # p=0: identity


def test_survival_mask_degenerate_p_and_self_loops():
    """p=0 keeps every edge, p=1 keeps ONLY self-loops — agents never
    fade out of their own neighbourhood, no matter how lossy the
    network (the σ renormalization needs a non-empty row)."""
    A = np.asarray(topo_lib.ring(K).adjacency).copy()
    np.fill_diagonal(A, True)                  # base graph w/ self-loops
    key = topo_lib.survival_key(9)
    m1 = np.asarray(topo_lib.survival_mask(A, 1.0, key, 0))
    np.testing.assert_array_equal(m1, np.eye(K, dtype=bool))
    m0 = np.asarray(topo_lib.survival_mask(A, 0.0, key, 0))
    np.testing.assert_array_equal(m0, A)
    # mid-p: the diagonal survives every round
    for t in range(6):
        mt = np.asarray(topo_lib.survival_mask(A, 0.7, key, t))
        assert mt.diagonal().all(), f"t={t}"


def test_survival_mask_asymmetric_adjacency_no_pair_folding():
    """Directed base graphs draw each DIRECTED edge independently —
    edge id ``i*K + j`` with no min/max pair folding — so (i, j) and
    (j, i) fade independently, while the symmetric convention folds
    them onto one id and they fade together."""
    rng = np.random.default_rng(0)
    A = rng.random((K, K)) < 0.8               # dense directed graph
    np.fill_diagonal(A, False)
    assert not (A == A.T).all()
    key = topo_lib.survival_key(4)
    # auto-detection sees the asymmetry and picks per-direction ids
    m = np.asarray(topo_lib.survival_mask(A, 0.5, key, 1))
    both = A & A.T & ~np.eye(K, dtype=bool)    # reciprocated edge pairs
    assert (m[both] != m.T[both]).any()        # directions disagree
    # the same base FORCED symmetric folds the pairs back together
    ms = np.asarray(topo_lib.survival_mask(A, 0.5, key, 1,
                                           symmetric=True))
    np.testing.assert_array_equal(ms[both], ms.T[both])
    # per-edge call form matches the dense grid entry for entry
    ii, jj = np.nonzero(A)
    lanes = np.asarray(topo_lib.survival_mask(
        K, 0.5, key, 1, symmetric=False, receivers=ii, senders=jj))
    np.testing.assert_array_equal(lanes, m[ii, jj])


def test_round_survival_is_the_dense_mask_in_plan_shape():
    """engine.round_survival(t) on the non-dense plans is EXACTLY the
    dense (K, K) mask gathered into the plan's native table — (K, H)
    lanes on sparse-pallas/sharded, (M, K) schedule slots on
    distributed — with padding forced dead. No (K, K) buffer, same
    bits."""
    topo = _topo()
    dense = ConsensusEngine(topo, graph=_gp())
    for plan, kw in [("sparse-pallas", {}), ("sharded", {"num_blocks": 4})]:
        eng = ConsensusEngine(topo, plan=plan, graph=_gp(), **kw)
        idx, valid = eng.lane_structure()
        rows = np.arange(K)[:, None]
        for t in (0, 3):
            grid = np.asarray(dense.round_mask(jnp.int32(t)))
            sv = np.asarray(eng.round_survival(jnp.int32(t)))
            np.testing.assert_array_equal(sv[valid],
                                          grid[rows, idx][valid])
            assert not sv[~valid].any(), f"{plan} t={t}: padding lanes"
    eng = ConsensusEngine(topo, plan="distributed", graph=_gp())
    srcs, real = eng.schedule_structure()
    cols = np.arange(K)[None, :]
    for t in (0, 3):
        grid = np.asarray(dense.round_mask(jnp.int32(t)))
        sv = np.asarray(eng.round_survival(jnp.int32(t)))
        np.testing.assert_array_equal(sv[real], grid[cols, srcs][real])
        assert not sv[~real].any(), f"distributed t={t}: padding slots"


def test_graph_process_validation_and_schedule():
    with pytest.raises(ValueError):
        topo_lib.GraphProcess("weather")
    with pytest.raises(ValueError):
        topo_lib.GraphProcess.dropout(1.0)
    with pytest.raises(ValueError):
        topo_lib.GraphProcess.schedule(np.ones((4, 4), bool))   # not 3-D
    topo = _topo()
    masks = np.stack([np.asarray(rt.adjacency) for rt in
                      topo_lib.dropout(topo, P, seed=1, rounds=3)])
    eng = ConsensusEngine(topo, graph=topo_lib.GraphProcess.schedule(masks))
    for t in (0, 1, 2, 3, 5):                  # wraps modulo R
        np.testing.assert_array_equal(
            np.asarray(eng.round_mask(jnp.int32(t))), masks[t % 3])
    # schedule K must match the engine population
    with pytest.raises(ValueError):
        ConsensusEngine(topo_lib.ring(6),
                        graph=topo_lib.GraphProcess.schedule(masks))
    # raw-mix engines can't renormalize an unknown sigma rule on the
    # surviving graph — refuse instead of silently replacing the weights
    with pytest.raises(ValueError, match="Topology"):
        ConsensusEngine(np.asarray(topo.mixing()), graph=_gp())


# ---------------------------------------------------------------------------
# the bit-parity matrix: in-scan mask generation vs host-prefetched
# topology.dropout, {dense-xla, sparse-pallas, sharded-emulated} x
# {f32, int8:b64} x chunk {1, 7, 32}
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("codec", [None, "int8:b64"])
@pytest.mark.parametrize("plan,plan_kw", PLANS)
def test_in_scan_masks_match_host_prefetch(plan, plan_kw, codec):
    """One engine, two drives: (a) the host-prefetch pattern — every
    round's surviving graph materialized by topology.dropout on the host
    and fed to the scan as a stacked mask operand — and (b) in-scan
    generation from the folded process key (scan_rounds), chunked at
    {1, 7, 32} with per-chunk t0 offsets. Params and EF codec state must
    agree BIT FOR BIT across all of it."""
    topo = _topo()
    eng = ConsensusEngine(topo, codec=codec, plan=plan, graph=_gp(),
                          **plan_kw)
    s = _stacked(jax.random.PRNGKey(2))
    keys = jax.random.split(jax.random.PRNGKey(3), ROUNDS)
    masks = jnp.stack([jnp.asarray(rt.adjacency) for rt in
                       topo_lib.dropout(topo, P, seed=SEED, rounds=ROUNDS)])

    # (a) host-prefetched masks ride the scan as operands
    @jax.jit
    def run_prefetched(p, st, ks, ms):
        def body(c, x):
            return eng.step(c[0], c[1], x[0], mask=x[1]), None
        return jax.lax.scan(body, (p, st), (ks, ms))[0]

    p_ref, st_ref = run_prefetched(s, eng.init_state(s), keys, masks)

    # (b) in-scan generation, chunked with global round offsets
    run = jax.jit(lambda p, st, ks, t0: eng.scan_rounds(p, st, ks, t0=t0))
    for chunk in (1, 7, 32):
        p, st = s, eng.init_state(s)
        for t0 in range(0, ROUNDS, chunk):
            p, st = run(p, st, keys[t0:t0 + chunk], jnp.int32(t0))
        if plan == "distributed":
            # the distributed accumulation chain fuses differently at
            # different scan lengths (1-ULP FMA effects between a
            # length-1 and a length-32 program — even with masks riding
            # as operands in both), so bit-parity is asserted against a
            # prefetched drive chunked EXACTLY the same way
            p_ref, st_ref = s, eng.init_state(s)
            for t0 in range(0, ROUNDS, chunk):
                p_ref, st_ref = run_prefetched(
                    p_ref, st_ref, keys[t0:t0 + chunk],
                    masks[t0:t0 + chunk])
        assert _tree_equal(p, p_ref), f"params chunk={chunk}"
        if codec is None:
            assert st is None and st_ref is None
        else:
            assert _tree_equal(st, st_ref), f"state chunk={chunk}"


def test_masked_mixing_matches_host_survivor_mixing():
    """masked_mixing(mask) == Topology(survivor).mixing() bit for bit —
    dropped links reallocate their sigma mass identically on host and
    device (doubly-stochastic kinds included)."""
    topo = _topo()
    for kind in ("paper", "metropolis"):
        eng = ConsensusEngine(topo, graph=_gp(), mix_kind=kind,
                              plan="dense-xla")
        for t, rt in enumerate(topo_lib.dropout(topo, P, seed=SEED,
                                                rounds=5)):
            got = jax.jit(lambda m: eng.masked_mixing(m))(
                jnp.asarray(rt.adjacency))
            want = rt.mixing(kind=kind)
            np.testing.assert_array_equal(np.asarray(got),
                                          np.asarray(want),
                                          err_msg=f"{kind} t={t}")


def test_distributed_plan_supports_time_varying_graphs():
    """Since the per-edge draw convention, the distributed plan is
    maskable: the ppermute schedule SUPERSET stays host-resolved and
    static while per-round survival zeroes schedule slots through the
    traced ``sig_override`` operand. Jitted ``t=`` and jitted ``mask=``
    drives must agree bit for bit (same compilation level — the
    distributed accumulation chain fuses differently under jit vs
    eager, so parity is asserted jit-vs-jit)."""
    assert set(MASKABLE_PLANS) == {"dense-xla", "sparse-pallas",
                                   "sharded", "distributed"}
    eng = ConsensusEngine(_topo(), plan="distributed", graph=_gp())
    s = _stacked(jax.random.PRNGKey(0))
    step_t = jax.jit(lambda p, t: eng.step(p, t=t)[0])
    step_m = jax.jit(lambda p, m: eng.step(p, mask=m)[0])
    for t, rt in enumerate(topo_lib.dropout(_topo(), P, seed=SEED,
                                            rounds=4)):
        a = step_t(s, jnp.int32(t))
        b = step_m(s, jnp.asarray(rt.adjacency))
        assert _tree_equal(a, b), f"t={t}"
    # explicit masks on a STATIC distributed engine work too (the
    # schedule superset is the full base graph)
    eng_st = ConsensusEngine(_topo(), plan="distributed")
    full_mask = jnp.asarray(_topo().adjacency)
    a, _ = jax.jit(lambda p: eng_st.step(p, mask=full_mask))(s)
    b, _ = jax.jit(lambda p: eng_st.step(p))(s)
    assert _tree_equal(a, b)                   # all-keep mask is a no-op


def test_distributed_plan_bounds_schedule_superset():
    """Satellite: the construction-time error path refuses only graphs
    whose max degree exceeds the fixed schedule-superset bound — and the
    message names the time-varying support, the slot count, and the
    bound, not a blanket 'distributed refuses non-static graphs'."""
    from repro.core.engine import DISTRIBUTED_SCHEDULE_BOUND
    with pytest.raises(ValueError, match="schedule slots") as ei:
        ConsensusEngine(topo_lib.full(DISTRIBUTED_SCHEDULE_BOUND + 6),
                        plan="distributed", graph=_gp())
    assert "time-varying" in str(ei.value)
    assert str(DISTRIBUTED_SCHEDULE_BOUND) in str(ei.value)
    # under the bound: constructs fine at the same K on a sparse graph
    ConsensusEngine(topo_lib.ring(DISTRIBUTED_SCHEDULE_BOUND + 6),
                    plan="distributed", graph=_gp())


def test_time_varying_step_requires_round_index_or_mask():
    """A time-varying engine must not silently mix on the full static
    graph: step() without t=/mask= (or an explicit mix override) fails
    loudly instead of measuring t_i on a never-fading network."""
    eng = ConsensusEngine(_topo(), graph=_gp())
    s = _stacked(jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="time-varying"):
        eng.step(s)
    eng.step(s, t=jnp.int32(0))                # round index: fine
    eng.step(s, mask=jnp.asarray(_topo().adjacency))   # explicit mask


def test_static_engine_ignores_round_index():
    """Passing t to a static engine is a no-op (round_mask is None), so
    shared driver code can always thread the round index through."""
    eng = ConsensusEngine(_topo())
    assert eng.round_mask(jnp.int32(3)) is None
    s = _stacked(jax.random.PRNGKey(1))
    a, _ = eng.step(s)
    b, _ = eng.step(s, t=jnp.int32(3))
    assert _tree_equal(a, b)


# ---------------------------------------------------------------------------
# scanned FL driver under a time-varying engine
# ---------------------------------------------------------------------------


def _fl_loss(p, b):
    return jnp.mean((p["w"] - b["tgt"]) ** 2)


def _fl_sampler(key, t):
    return {"tgt": jax.random.normal(key, (K, 3, 1, 6)) * 0.1}


def _fl_target(sp):
    m = jnp.mean(jnp.square(sp["w"]))
    return m < -1.0, m                         # unreachable


def test_fl_scan_with_dropout_engine_matches_host_loop():
    """run_fl_until_scan == run_fl_until bit for bit when the engine
    carries a GraphProcess (the dropout masks regenerate per round
    inside the scan, keyed on the global round index)."""
    eng = ConsensusEngine(_topo(), plan="sparse-pallas", graph=_gp())
    s = _stacked(jax.random.PRNGKey(1))
    kw = dict(target_fn=_fl_target, max_rounds=9,
              key=jax.random.PRNGKey(7))
    p_h, t_h, h_h = federated.run_fl_until(
        _fl_loss, s, _fl_sampler, eng, 0.3, **kw)
    for chunk in (4, 32):
        p_s, t_s, h_s = federated.run_fl_until_scan(
            _fl_loss, s, _fl_sampler, eng, 0.3, chunk=chunk, **kw)
        assert (t_s, h_s) == (t_h, h_h), f"chunk={chunk}"
        assert _tree_equal(p_s, p_h), f"chunk={chunk}"


# ---------------------------------------------------------------------------
# the compiled-program cache: trace-count guard (CI tier-1)
# ---------------------------------------------------------------------------


def test_fl_chunk_program_compiles_once_across_repetitions():
    """>= 3 Monte-Carlo repetitions of run_fl_until_scan with identical
    (engine, loss, sampler, target, shapes, chunk) must trace the chunk
    program exactly once — the program cache returns the same jit
    object and jax's executable cache does the rest."""
    eng = ConsensusEngine(_topo())
    s = _stacked(jax.random.PRNGKey(1))
    kw = dict(target_fn=_fl_target, max_rounds=6, chunk=3)
    before = scanloop.TRACE_COUNTS["fl_chunk"]
    for rep in range(3):
        federated.run_fl_until_scan(
            _fl_loss, s, _fl_sampler, eng, 0.3,
            key=jax.random.PRNGKey(rep), **kw)
    assert scanloop.TRACE_COUNTS["fl_chunk"] - before == 1
    # a different engine is a different program: exactly one more trace
    eng2 = ConsensusEngine(_topo(), plan="sparse-pallas", graph=_gp())
    for rep in range(3):
        federated.run_fl_until_scan(
            _fl_loss, s, _fl_sampler, eng2, 0.3,
            key=jax.random.PRNGKey(rep), **kw)
    assert scanloop.TRACE_COUNTS["fl_chunk"] - before == 2


def test_maml_chunk_program_compiles_once_across_repetitions():
    def net_loss(p, b):
        return jnp.mean((jnp.tanh(b["x"] @ p["w1"]) @ p["w2"] - b["y"]) ** 2)

    def sampler(key, t):
        x = jax.random.normal(key, (4, 16, 2))
        b = {"x": x, "y": jnp.sin(x[..., :1]) * 0.3}
        return b, b

    p0 = {"w1": jnp.ones((2, 8)) * 0.1, "w2": jnp.ones((8, 1)) * 0.1}
    before = scanloop.TRACE_COUNTS["maml_chunk"]
    for rep in range(3):
        maml.maml_train_scan(net_loss, p0, sampler, rounds=4, chunk=2,
                             inner_lr=0.05, outer_lr=0.01,
                             key=jax.random.PRNGKey(rep))
    assert scanloop.TRACE_COUNTS["maml_chunk"] - before == 1


def test_program_cache_lru_and_signature():
    sig_a = scanloop.tree_signature({"w": jnp.ones((2, 3))})
    sig_b = scanloop.tree_signature({"w": jnp.ones((2, 3))})
    sig_c = scanloop.tree_signature({"w": jnp.ones((4, 3))})
    assert sig_a == sig_b and hash(sig_a) == hash(sig_b)
    assert sig_a != sig_c
    built = []

    def make(i):
        def build():
            built.append(i)
            return ("prog", i)
        return build

    for i in range(3):
        scanloop.cached_program(("t", i, sig_a), make(i))
    assert scanloop.cached_program(("t", 0, sig_a), make(99)) == ("prog", 0)
    assert scanloop.get_cached_program(("t", 1, sig_a)) == ("prog", 1)
    assert scanloop.get_cached_program(("t", "missing")) is None
    assert built == [0, 1, 2]                  # hit: no rebuild


# ---------------------------------------------------------------------------
# CaseStudy regressions (plan knob, dropout on non-dense plans, billing)
# ---------------------------------------------------------------------------


def test_casestudy_respects_plan_knob():
    """Regression: CaseStudy used to hardcode plan="dense-xla" for every
    construction. The static 2-robot case must ride the engine's normal
    auto selection (which lands on dense-xla via the K*degree floor, not
    by fiat), and explicit plans must be honoured — including with
    dropout_p > 0, which previously forced the dense hack."""
    from repro.rl.casestudy import CaseStudy
    cs = CaseStudy()                           # default: plan="auto"
    assert cs.plan == "auto"
    assert cs.engine.plan.kind == "dense-xla"
    assert "heuristic" in cs.engine.plan.reason      # auto picked it
    cs_sp = CaseStudy(plan="sparse-pallas", dropout_p=0.2)
    assert cs_sp.engine.plan.kind == "sparse-pallas"
    assert cs_sp.engine.graph.kind == "dropout"
    # per-task graph seeds follow dropout_seed + task_id
    assert cs_sp._engines[1].graph.seed == cs_sp.dropout_seed + 1
    # the distributed plan takes dropout too (masked schedule superset)
    cs_d = CaseStudy(plan="distributed", dropout_p=0.2)
    assert cs_d.engine.plan.kind == "distributed"
    assert cs_d.engine.graph.kind == "dropout"


@pytest.mark.parametrize("plan,chunk", [("sparse-pallas", 8),
                                        ("sharded", 8)])
def test_casestudy_dropout_cross_plan_matches_dense_host_loop(plan, chunk):
    """Acceptance: CaseStudy(dropout_p=0.2) on the sparse-pallas and
    sharded (emulated) plans reproduces the dense-xla host-loop
    (chunk=1) reference — t_i, measured Eq.-(11) joules, and the reward
    history — with zero host-side per-round graph prefetch."""
    from repro.rl.casestudy import CaseStudy
    key = jax.random.PRNGKey(2)
    ref = CaseStudy(dropout_p=0.2, plan="dense-xla", chunk=1)
    p = ref.init_params(key)
    _, t_ref, h_ref = ref.adapt_task(key, 0, p, max_rounds=4)
    j_ref = ref.last_adapt_comm_joules
    cs = CaseStudy(dropout_p=0.2, plan=plan, chunk=chunk)
    _, t_i, h = cs.adapt_task(key, 0, p, max_rounds=4)
    assert t_i == t_ref
    assert cs.last_adapt_comm_joules == j_ref
    assert h == h_ref


def test_adapt_task_bills_exactly_rounds_used_under_dropout():
    """Satellite audit: with the target hit MID-CHUNK (round 1 of a
    chunk-8 program) the Eq.-(11) bill must cover exactly rounds_used
    surviving-link rounds — the frozen tail bills zero; and a
    never-reached run with chunk > max_rounds bills exactly max_rounds
    rounds."""
    from repro.rl.casestudy import CaseStudy
    key = jax.random.PRNGKey(0)
    cs = CaseStudy(dropout_p=0.3, chunk=8, r_target=-1.0)   # hit round 1
    p = cs.init_params(key)
    _, rounds, _ = cs.adapt_task(key, 0, p, max_rounds=20)
    assert rounds == 1                         # mid-chunk hit
    want = [t.round_comm_joules(cs.energy_params)
            for t in topo_lib.dropout(cs.cluster_topology, 0.3,
                                      seed=cs.dropout_seed + 0, rounds=8)]
    assert cs.last_adapt_comm_joules == pytest.approx(want[0])
    assert cs.last_adapt_comm_joules < sum(want)     # tail billed zero

    cs2 = CaseStudy(dropout_p=0.3, chunk=8, r_target=1e9)   # never hit
    _, rounds2, _ = cs2.adapt_task(key, 0, p, max_rounds=5)
    assert rounds2 == 5
    want2 = sum(t.round_comm_joules(cs2.energy_params)
                for t in topo_lib.dropout(cs2.cluster_topology, 0.3,
                                          seed=cs2.dropout_seed + 0,
                                          rounds=5))
    assert cs2.last_adapt_comm_joules == pytest.approx(want2)
