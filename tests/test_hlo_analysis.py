"""Edge cases of the ``launch/hlo_analysis`` HLO-text parsers that the
``repro.analysis`` H1/H2 audits (and the dry-run roofline) rely on."""
from repro.launch.hlo_analysis import (collective_bytes, square_buffers,
                                       _shape_bytes)


def test_shape_bytes_dtype_table():
    assert _shape_bytes("f32[128]") == 512
    assert _shape_bytes("bf16[128]") == 256
    assert _shape_bytes("s8[128]") == 128
    assert _shape_bytes("pred[128]") == 128
    assert _shape_bytes("u32[2,3]") == 24
    assert _shape_bytes("f32[]") == 4          # scalar: one element


def test_shape_bytes_tuple_result():
    # tuple results sum every component, scalars included
    assert _shape_bytes("(f32[8], u32[])") == 36
    assert _shape_bytes("(bf16[4,4], pred[2], s8[3])") == 37


def test_collective_bytes_basic_and_root():
    txt = """
  %ag = f32[16,8] all-gather(f32[2,8] %x), dimensions={0}
  ROOT %cp = bf16[256] collective-permute(bf16[256] %y)
"""
    out = collective_bytes(txt)
    assert out["all-gather"] == 16 * 8 * 4
    assert out["collective-permute"] == 512
    assert out["all-reduce"] == 0


def test_collective_bytes_start_done_counted_once():
    txt = """
  %ar-start = f32[64] all-reduce-start(f32[64] %p), to_apply=%add
  %ar-done = f32[64] all-reduce-done(f32[64] %ar-start)
"""
    assert collective_bytes(txt)["all-reduce"] == 256


def test_collective_bytes_tuple_result_shapes():
    txt = """
  %cps = (f32[8], u32[]) collective-permute-start(f32[8] %v)
  %cpd = f32[8] collective-permute-done((f32[8], u32[]) %cps)
"""
    # the -start tuple is summed once; -done is skipped entirely
    assert collective_bytes(txt)["collective-permute"] == 36


def test_collective_bytes_sub_byte_and_pred():
    txt = """
  %a = s8[100] all-to-all(s8[100] %q), dimensions={0}
  %b = pred[9] all-gather(pred[3] %m), dimensions={0}
"""
    out = collective_bytes(txt)
    assert out["all-to-all"] == 100
    assert out["all-gather"] == 9


def test_square_buffers_threshold_and_dedup():
    txt = """
  %small = f32[128,128] dot(...)
  %big = f32[4096,4096] dot(...)
  %big2 = f32[4096,4096] add(f32[4096,4096] %big, f32[4096,4096] %big)
  %rect = f32[4096,64] dot(...)
  %bigint = s8[8192,8192] convert(...)
"""
    out = square_buffers(txt, 4096)
    assert out == [("f32", 4096, 4096 * 4096 * 4),
                   ("s8", 8192, 8192 * 8192)]
    assert square_buffers(txt, 100)[0] == ("f32", 128, 128 * 128 * 4)
    assert square_buffers("%x = f32[64] add(...)", 16) == []
