"""Distribution context: a thread-local mesh handle the model layers can
consult (e.g. MoE dispatch must be per-data-shard at production scale —
the launch layer sets the context; single-device tests leave it unset and
get the dense path)."""
from __future__ import annotations

import contextlib
import threading

_STATE = threading.local()


def set_mesh(mesh) -> None:
    _STATE.mesh = mesh


def get_mesh():
    return getattr(_STATE, "mesh", None)


@contextlib.contextmanager
def use_mesh(mesh):
    prev = get_mesh()
    set_mesh(mesh)
    try:
        yield mesh
    finally:
        set_mesh(prev)


def data_axes(mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.shape)
