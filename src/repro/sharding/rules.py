"""Logical-axis sharding rules: param-tree paths -> PartitionSpec.

Strategy (DESIGN.md §4):
* tensor parallelism over the mesh "model" axis: attention heads / kv
  heads / d_ff / lru width / vocab — whichever dim of each leaf carries
  that logical axis, guarded by divisibility (fallback: replicate);
* data parallelism over ("pod", "data"): params replicated, batch sharded;
* stacked per-layer leaves (scan-over-layers) get a leading None.

The rules are name-based on the param tree paths produced by
``repro.models.*`` inits — a deliberate, greppable contract (tested in
tests/test_sharding.py).
"""
from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import NamedSharding, PartitionSpec as P


def _div(n: int, size: int) -> bool:
    return size > 0 and n % size == 0


# leaf-name -> (which dim carries which logical axis)
# dims are AFTER stripping any leading layer-stack dim.
_RULES = {
    # embeddings
    "embed": {0: "vocab"},
    "unembed": {1: "vocab"},
    # attention
    "wq": {1: "heads"},
    "wk": {1: "kv_heads"},
    "wv": {1: "kv_heads"},
    "wo": {0: "heads"},
    # dense mlp
    "w_gate": {1: "mlp"},
    "w_up": {1: "mlp"},
    "w_down": {0: "mlp"},
    # moe (leaves live under "mlp": router (d,E), w_* (E,d,f)/(E,f,d))
    "router": {},
    # rg-lru recurrent block
    "w_branch_x": {1: "lru"},
    "w_branch_gate": {1: "lru"},
    "w_a": {0: "lru_blocks"},       # block-diagonal (H, bw, bw)
    "w_x": {0: "lru_blocks"},
    "b_a": {0: "lru"},
    "b_x": {0: "lru"},
    "lam": {0: "lru"},
    "w_out": {0: "lru"},
    # xlstm
    "w_ff1": {1: "mlp"},
    "w_ff2": {0: "mlp"},
}

_STACK_KEYS = ("blocks", "periods", "enc_blocks", "dec_blocks", "rem")


def _is_stacked(names) -> bool:
    """Scan-over-layers stacks have a stack key in the path and NO integer
    path component (tuple-of-blocks paths contain the layer index)."""
    return (any(n in _STACK_KEYS for n in names)
            and not any(n.isdigit() for n in names))


def _path_names(path):
    out = []
    for p in path:
        out.append(str(getattr(p, "key", getattr(p, "idx", p))))
    return out


def param_spec(path, leaf, cfg, model_axis: str = "model",
               model_size: int = 1) -> P:
    """PartitionSpec for one param leaf."""
    names = _path_names(path)
    name = names[-1]
    stacked = _is_stacked(names[:-1]) and leaf.ndim >= 1
    # MoE expert leaves: (E, d, f) / (E, f, d) — shard the f dim.
    in_moe = cfg.moe is not None and "mlp" in names and name in (
        "w_gate", "w_up", "w_down") and "shared" not in names
    offset = 1 if stacked else 0

    dims: dict = {}
    if in_moe:
        # stripped shape: (E, d, f) or (E, f, d)
        dims = {2: "mlp"} if name in ("w_gate", "w_up") else {1: "mlp"}
    elif name in _RULES:
        dims = _RULES[name]

    spec = [None] * leaf.ndim
    for dim, logical in dims.items():
        d = dim + offset
        if d < leaf.ndim and _div(leaf.shape[d], model_size):
            spec[d] = model_axis
            break
    return P(*spec)


def param_shardings(params, cfg, mesh, model_axis: str = "model"):
    """NamedSharding tree for a param pytree (replicated over data/pod)."""
    size = mesh.shape[model_axis] if model_axis in mesh.shape else 1

    def one(path, leaf):
        return NamedSharding(mesh, param_spec(path, leaf, cfg,
                                              model_axis, size))

    return jax.tree_util.tree_map_with_path(one, params)


def batch_spec(mesh) -> P:
    """Batch-dim sharding over every data-parallel mesh axis present."""
    axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    return P(axes if len(axes) > 1 else axes[0])


def dp_size(mesh) -> int:
    n = 1
    for a in ("pod", "data"):
        if a in mesh.shape:
            n *= mesh.shape[a]
    return n


def data_shardings(batch_like, mesh):
    """Shard dim 0 of every leaf over (pod, data) when divisible."""
    bs = batch_spec(mesh)
    dp = dp_size(mesh)

    def one(leaf):
        spec = [None] * leaf.ndim
        if leaf.ndim and _div(leaf.shape[0], dp):
            spec[0] = bs[0]
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(one, batch_like)


def cache_shardings(caches, cfg, mesh, model_axis: str = "model"):
    """KV caches: batch dim over data axes; kv-head dim over model when
    divisible. Handles stacked (L, B, C, K, hd) kv leaves, recurrent
    {'conv','h'} states and xlstm cell tuples (batch-dim leading after
    optional layer stack)."""
    size = mesh.shape[model_axis] if model_axis in mesh.shape else 1
    baxes = batch_spec(mesh)[0]
    dp = dp_size(mesh)

    def one(path, leaf):
        names = _path_names(path)
        # 'periods' caches are period-stacked tuples: digits index the
        # within-period position, the leading dim is still the stack.
        stacked = (_is_stacked(names) or "self" in names
                   or "periods" in names) and leaf.ndim >= 2
        spec = [None] * leaf.ndim
        b_dim = 1 if (stacked and leaf.ndim >= 2) else 0
        # kv cache leaves are 5D stacked (L,B,C,K,hd) or 4D (B,C,K,hd)
        if names[-1] in ("k", "v") and leaf.ndim >= 4:
            b_dim = leaf.ndim - 4
            if _div(leaf.shape[b_dim], dp):
                spec[b_dim] = baxes
            # tensor-parallel cache: kv-head dim when divisible, else the
            # head_dim — an UNSHARDED cache makes GSPMD all-gather the
            # whole cache every decode step (EXPERIMENTS.md §Perf P0).
            if _div(leaf.shape[leaf.ndim - 2], size):
                spec[leaf.ndim - 2] = model_axis
            elif _div(leaf.shape[leaf.ndim - 1], size):
                spec[leaf.ndim - 1] = model_axis
        elif leaf.ndim > b_dim and _div(leaf.shape[b_dim], dp):
            spec[b_dim] = baxes
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(one, caches)
