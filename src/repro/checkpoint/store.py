"""Pytree checkpointing to .npz (no orbax in this container).

Arrays are gathered to host (fully addressable) before saving; restore
optionally re-places leaves onto a sharding tree. Step-numbered directories
with a retention policy, like a tiny orbax.
"""
from __future__ import annotations

import json
import os
import re
import shutil
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

_SEP = "/"


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        arr = np.asarray(leaf)
        if arr.dtype.kind not in "biufc":   # e.g. bfloat16 -> f32 on disk
            arr = arr.astype(np.float32)
        out[key] = arr
    return out, treedef


def save_pytree(path: str, tree) -> None:
    flat, _ = _flatten(tree)
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    np.savez(path if path.endswith(".npz") else path + ".npz", **flat)


def restore_pytree(path: str, like, shardings=None):
    """Restore into the structure of ``like`` (names must match)."""
    if not path.endswith(".npz"):
        path += ".npz"
    data = np.load(path)
    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for p, leaf in flat:
        key = _SEP.join(str(getattr(q, "key", getattr(q, "idx", q)))
                        for q in p)
        arr = jnp.asarray(data[key], dtype=leaf.dtype)
        leaves.append(arr)
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    if shardings is not None:
        tree = jax.tree.map(jax.device_put, tree, shardings)
    return tree


class CheckpointManager:
    """step-numbered checkpoints with retention."""

    def __init__(self, directory: str, max_to_keep: int = 3):
        self.dir = directory
        self.max_to_keep = max_to_keep
        os.makedirs(directory, exist_ok=True)

    def _step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:08d}")

    def steps(self):
        out = []
        for name in os.listdir(self.dir):
            m = re.fullmatch(r"step_(\d+)", name)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def save(self, step: int, state, metadata: Optional[dict] = None):
        d = self._step_dir(step)
        os.makedirs(d, exist_ok=True)
        save_pytree(os.path.join(d, "state"), state)
        with open(os.path.join(d, "meta.json"), "w") as f:
            json.dump({"step": step, **(metadata or {})}, f)
        for old in self.steps()[:-self.max_to_keep]:
            shutil.rmtree(self._step_dir(old), ignore_errors=True)

    def restore(self, like, step: Optional[int] = None, shardings=None):
        steps = self.steps()
        if not steps:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        step = step if step is not None else steps[-1]
        return restore_pytree(os.path.join(self._step_dir(step), "state"),
                              like, shardings), step
