"""IBM Granite 8B code model [arXiv:2405.04324] — llama-arch dense decoder."""
from repro.configs.base import ArchConfig, register

GRANITE_8B = register(ArchConfig(
    name="granite-8b",
    family="dense",
    num_layers=36,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=49152,
    citation="arXiv:2405.04324",
    rope_theta=10000.0,
    act="silu",
    mlp_kind="gated",
))
