"""H2O-Danube3-4B [arXiv:2401.16818] — llama+mistral mix with SWA."""
from repro.configs.base import ArchConfig, register

H2O_DANUBE_3_4B = register(ArchConfig(
    name="h2o-danube-3-4b",
    family="dense",
    num_layers=24,
    d_model=3840,
    num_heads=32,
    num_kv_heads=8,
    d_ff=10240,
    vocab_size=32000,
    citation="arXiv:2401.16818",
    head_dim=120,
    sliding_window=4096,
    act="silu",
    mlp_kind="gated",
))
