"""Configuration system.

Every assigned architecture is a frozen :class:`ArchConfig`; the four
assignment input shapes are :class:`InputShape` entries in ``INPUT_SHAPES``.
Configs are plain frozen dataclasses — hashable, so they can be closed over
by jitted functions as static data.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Architecture config
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts settings for a block's MLP."""

    num_experts: int = 8
    top_k: int = 2
    num_shared_experts: int = 0      # qwen2-moe style always-on experts
    router_aux_loss_coef: float = 0.01
    capacity_factor: float = 1.25    # used by capacity-based dispatch
    shared_expert_d_ff: int = 0      # d_ff of the shared expert (0 -> same as experts)


@dataclass(frozen=True)
class RGLRUConfig:
    """RecurrentGemma RG-LRU recurrence settings."""

    lru_width: int = 0               # 0 -> d_model
    conv1d_width: int = 4
    block_pattern: Tuple[str, ...] = ("recurrent", "recurrent", "attention")


@dataclass(frozen=True)
class XLSTMConfig:
    """xLSTM block-stack settings (arXiv:2405.04517)."""

    slstm_at: Tuple[int, ...] = ()   # layer indices using sLSTM; rest mLSTM
    mlstm_proj_factor: float = 2.0   # up-projection factor for mLSTM blocks
    slstm_proj_factor: float = 4.0 / 3.0
    conv1d_width: int = 4


@dataclass(frozen=True)
class EncDecConfig:
    """Encoder-decoder (whisper) settings. Frontend is a stub."""

    num_encoder_layers: int = 32
    encoder_seq_len: int = 1500      # 30 s audio -> 1500 frames after conv stub
    max_decoder_ctx: int = 448


@dataclass(frozen=True)
class ArchConfig:
    """A single architecture, exactly as assigned.

    ``family`` selects the model constructor:
      dense | moe | hybrid (rg-lru) | ssm (xlstm) | encdec (whisper) | vlm
    (vlm is a dense decoder over an early-fusion token stream).
    """

    name: str
    family: str
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    citation: str = ""

    head_dim: int = 0                # 0 -> d_model // num_heads
    sliding_window: int = 0          # 0 -> full attention; else SWA window
    attention_types: Tuple[str, ...] = ()  # per-layer override (hybrids)
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    act: str = "silu"                # mlp activation: silu | gelu
    mlp_kind: str = "gated"          # gated (llama) | plain (whisper/gpt)
    use_qk_norm: bool = False
    logit_softcap: float = 0.0

    moe: Optional[MoEConfig] = None
    rglru: Optional[RGLRUConfig] = None
    xlstm: Optional[XLSTMConfig] = None
    encdec: Optional[EncDecConfig] = None

    dtype: str = "bfloat16"          # activation/compute dtype
    param_dtype: str = "float32"
    remat: bool = True               # activation checkpointing per layer/block
    remat_policy: str = "full"       # full | dots (save matmul outputs:
                                     # trades HBM for recompute FLOPs)
    unroll_layers: bool = False      # python-loop layers instead of scan
                                     # (cost-analysis probes; see roofline)

    # -- derived -----------------------------------------------------------
    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def q_per_kv(self) -> int:
        return self.num_heads // max(self.num_kv_heads, 1)

    @property
    def subquadratic(self) -> bool:
        """Can this arch serve ~500k contexts (O(T) or O(w*T) attention)?"""
        return (
            self.family in ("hybrid", "ssm")
            or self.sliding_window > 0
        )

    @property
    def is_decoder(self) -> bool:
        return self.family != "encoder_only"

    def param_count(self) -> int:
        """Analytic parameter count (embeddings + blocks + norms)."""
        d, L, V = self.d_model, self.num_layers, self.vocab_size
        hd = self.head_dim_
        emb = V * d * (1 if self.tie_embeddings else 2)
        att = d * (self.num_heads * hd) + 2 * d * (self.num_kv_heads * hd) \
            + (self.num_heads * hd) * d
        if self.family == "moe":
            assert self.moe is not None
            e = self.moe.num_experts + self.moe.num_shared_experts * (
                (self.moe.shared_expert_d_ff or self.d_ff) // max(self.d_ff, 1))
            n_mlp_mats = 3 if self.mlp_kind == "gated" else 2
            mlp = self.moe.num_experts * n_mlp_mats * d * self.d_ff
            if self.moe.num_shared_experts:
                sdff = self.moe.shared_expert_d_ff or self.d_ff
                mlp += n_mlp_mats * d * sdff
            mlp += d * self.moe.num_experts  # router
            del e
        elif self.family == "ssm":
            # xLSTM: rough (projections + gates); refined by the model itself.
            mlp = 0
            att = 0
            pf = self.xlstm.mlstm_proj_factor if self.xlstm else 2.0
            att = int(4 * d * d * pf)
        else:
            n_mlp_mats = 3 if self.mlp_kind == "gated" else 2
            mlp = n_mlp_mats * d * self.d_ff
        blocks = L * (att + mlp + 2 * d)
        if self.family == "encdec" and self.encdec is not None:
            blocks += self.encdec.num_encoder_layers * (att + mlp + 2 * d)
            # decoder cross-attention
            blocks += L * att
        return emb + blocks + d

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only top-k + shared experts)."""
        if self.family != "moe" or self.moe is None:
            return self.param_count()
        d, L = self.d_model, self.num_layers
        n_mlp_mats = 3 if self.mlp_kind == "gated" else 2
        dense_like = self.param_count() - L * (
            self.moe.num_experts * n_mlp_mats * d * self.d_ff)
        active_mlp = L * self.moe.top_k * n_mlp_mats * d * self.d_ff
        return dense_like + active_mlp


# ---------------------------------------------------------------------------
# Input shapes (the four assigned)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    mode: str        # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: dict = {}


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_arch(name: str) -> ArchConfig:
    if name not in _REGISTRY:
        # import side-effect registration
        from repro import configs as _c  # noqa: F401
        _load_all()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_archs() -> list:
    _load_all()
    return sorted(_REGISTRY)


_LOADED = False


def _load_all() -> None:
    global _LOADED
    if _LOADED:
        return
    import importlib
    for mod in (
        "granite_8b", "chameleon_34b", "stablelm_3b", "recurrentgemma_9b",
        "whisper_large_v3", "mixtral_8x7b", "deepseek_7b", "qwen2_moe_a2_7b",
        "h2o_danube_3_4b", "xlstm_125m", "paper_dqn",
    ):
        importlib.import_module(f"repro.configs.{mod}")
    _LOADED = True


def reduced(cfg: ArchConfig, *, num_layers: int = 2, d_model: int = 256,
            max_experts: int = 4, vocab: int = 512) -> ArchConfig:
    """A smoke-test-sized variant of the same family (CPU-runnable)."""
    heads = max(2, min(cfg.num_heads, 4))
    kv = max(1, min(cfg.num_kv_heads, heads))
    while heads % kv:
        kv -= 1
    changes = dict(
        num_layers=num_layers,
        d_model=d_model,
        num_heads=heads,
        num_kv_heads=kv,
        head_dim=d_model // heads,
        d_ff=max(2 * d_model, 64) if cfg.d_ff else 0,
        vocab_size=vocab,
        sliding_window=min(cfg.sliding_window, 64) if cfg.sliding_window else 0,
        remat=False,
        dtype="float32",
    )
    if cfg.moe is not None:
        changes["moe"] = dataclasses.replace(
            cfg.moe,
            num_experts=min(cfg.moe.num_experts, max_experts),
            top_k=min(cfg.moe.top_k, 2),
            num_shared_experts=min(cfg.moe.num_shared_experts, 1),
            shared_expert_d_ff=0,
        )
    if cfg.rglru is not None:
        changes["rglru"] = dataclasses.replace(cfg.rglru, lru_width=0)
    if cfg.xlstm is not None:
        changes["xlstm"] = dataclasses.replace(
            cfg.xlstm, slstm_at=tuple(i for i in cfg.xlstm.slstm_at
                                      if i < num_layers) or (0,))
    if cfg.encdec is not None:
        changes["encdec"] = dataclasses.replace(
            cfg.encdec, num_encoder_layers=num_layers, encoder_seq_len=32)
    if cfg.attention_types:
        changes["attention_types"] = cfg.attention_types[:num_layers]
    return dataclasses.replace(cfg, **changes)
