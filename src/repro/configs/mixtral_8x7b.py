"""Mixtral 8x7B [arXiv:2401.04088] — 8-expert top-2 MoE with SWA(4096)."""
from repro.configs.base import ArchConfig, MoEConfig, register

MIXTRAL_8X7B = register(ArchConfig(
    name="mixtral-8x7b",
    family="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=32000,
    citation="arXiv:2401.04088",
    sliding_window=4096,
    moe=MoEConfig(num_experts=8, top_k=2),
    act="silu",
    mlp_kind="gated",
    rope_theta=1e6,
))
