"""Whisper large-v3 [arXiv:2212.04356] — encoder-decoder audio transformer.

The mel-spectrogram + conv frontend is STUBBED: input_specs() feeds
precomputed (batch, 1500, d_model) frame embeddings to the encoder
(DESIGN.md §3). Decoder max context 448 — long_500k skipped.
"""
from repro.configs.base import ArchConfig, EncDecConfig, register

WHISPER_LARGE_V3 = register(ArchConfig(
    name="whisper-large-v3",
    family="encdec",
    num_layers=32,                 # decoder layers
    d_model=1280,
    num_heads=20,
    num_kv_heads=20,
    d_ff=5120,
    vocab_size=51866,
    citation="arXiv:2212.04356",
    encdec=EncDecConfig(num_encoder_layers=32, encoder_seq_len=1500,
                        max_decoder_ctx=448),
    act="gelu",
    mlp_kind="plain",
    rope_theta=0.0,                # learned absolute positions
))
