"""The paper's own model: DeepMind DQN Q-network (Mnih et al. 2015), 5
trainable layers / 1.3M params / b(W)=5.6 MB, adapted to the 40-landmark
gridworld state (Sect. IV). Registered so the paper's case study flows
through the same config/launch machinery as the assigned archs.
"""
from repro.configs.base import ArchConfig, register

PAPER_DQN = register(ArchConfig(
    name="paper-dqn",
    family="dqn",
    num_layers=5,
    d_model=512,            # fc width (the 1.3M-param DeepMind shape)
    num_heads=1,
    num_kv_heads=1,
    d_ff=512,
    vocab_size=4,           # |actions| = {F, B, L, R}
    citation="DOI:10.1109/PIMRC54779.2022.9977688 + Mnih et al. 2015",
    dtype="float32",
    remat=False,
))
