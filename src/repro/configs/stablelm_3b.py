"""StableLM-2 family [hf:stabilityai/stablelm-2-1_6b] — dense decoder (MHA)."""
from repro.configs.base import ArchConfig, register

STABLELM_3B = register(ArchConfig(
    name="stablelm-3b",
    family="dense",
    num_layers=32,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    d_ff=6912,
    vocab_size=50304,
    citation="hf:stabilityai/stablelm-2-1_6b",
    rope_theta=10000.0,
    act="silu",
    mlp_kind="gated",
))
