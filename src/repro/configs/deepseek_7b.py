"""DeepSeek LLM 7B [arXiv:2401.02954] — llama-arch dense decoder (MHA)."""
from repro.configs.base import ArchConfig, register

DEEPSEEK_7B = register(ArchConfig(
    name="deepseek-7b",
    family="dense",
    num_layers=30,
    d_model=4096,
    num_heads=32,
    num_kv_heads=32,
    d_ff=11008,
    vocab_size=102400,
    citation="arXiv:2401.02954",
    act="silu",
    mlp_kind="gated",
))
