"""RecurrentGemma-9B / Griffin [arXiv:2402.19427] — RG-LRU + local attention 1:2.

Block pattern (recurrent, recurrent, attention) repeated; local (sliding
window 2048) attention, MQA (1 kv head). Sub-quadratic: runs long_500k.
"""
from repro.configs.base import ArchConfig, RGLRUConfig, register

RECURRENTGEMMA_9B = register(ArchConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    num_layers=38,
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,
    d_ff=12288,
    vocab_size=256000,
    citation="arXiv:2402.19427",
    head_dim=256,
    sliding_window=2048,
    rglru=RGLRUConfig(lru_width=4096, conv1d_width=4,
                      block_pattern=("recurrent", "recurrent", "attention")),
    act="gelu",
    mlp_kind="gated",
    logit_softcap=30.0,
))
