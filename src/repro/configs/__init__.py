from repro.configs.base import (
    ArchConfig, MoEConfig, RGLRUConfig, XLSTMConfig, EncDecConfig,
    InputShape, INPUT_SHAPES, get_arch, list_archs, reduced, register,
)
