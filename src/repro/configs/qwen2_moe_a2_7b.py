"""Qwen1.5-MoE-A2.7B [hf:Qwen/Qwen1.5-MoE-A2.7B] — 60 routed top-4 + 4 shared.

Fine-grained experts (d_ff 1408 each); the 4 shared experts are modeled as
one merged shared expert of d_ff 4*1408=5632 (mathematically identical for
always-on experts).
"""
from repro.configs.base import ArchConfig, MoEConfig, register

QWEN2_MOE_A2_7B = register(ArchConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1408,
    vocab_size=151936,
    citation="hf:Qwen/Qwen1.5-MoE-A2.7B",
    moe=MoEConfig(num_experts=60, top_k=4, num_shared_experts=1,
                  shared_expert_d_ff=5632),
    act="silu",
    mlp_kind="gated",
    rope_theta=1e6,
))
