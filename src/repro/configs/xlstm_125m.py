"""xLSTM-125M [arXiv:2405.04517] — sLSTM + mLSTM block stack (d_ff=0: the
blocks carry their own up/down projections; no separate MLP)."""
from repro.configs.base import ArchConfig, XLSTMConfig, register

XLSTM_125M = register(ArchConfig(
    name="xlstm-125m",
    family="ssm",
    num_layers=12,
    d_model=768,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    citation="arXiv:2405.04517",
    head_dim=192,
    xlstm=XLSTMConfig(slstm_at=(1, 4, 7, 10), mlstm_proj_factor=2.0),
    act="gelu",
    mlp_kind="plain",
))
