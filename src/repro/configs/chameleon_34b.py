"""Chameleon-34B [arXiv:2405.09818] — early-fusion VLM.

Image tokens are VQ codes folded into the 65536 vocabulary; the VQ-VAE
tokenizer is the stubbed modality frontend (DESIGN.md §3). The backbone is
a dense llama-style decoder with qk-norm (per the Chameleon paper).
"""
from repro.configs.base import ArchConfig, register

CHAMELEON_34B = register(ArchConfig(
    name="chameleon-34b",
    family="vlm",
    num_layers=48,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=22016,
    vocab_size=65536,
    citation="arXiv:2405.09818",
    use_qk_norm=True,
    act="silu",
    mlp_kind="gated",
))
