"""Training launcher.

Two modes (the paper's contribution is a first-class feature, not a demo):

* ``standard``  — synchronous data/tensor-parallel training: one jitted
  step, grads averaged over the data axes (GSPMD inserts the all-reduce).
* ``federated`` — the paper's decentralized protocol at LM scale: the
  data axis is a population of AGENTS, each holding its own replica and
  task-conditioned data stream; agents take ``local_steps`` SGD steps per
  round then run one Eq.-(6) consensus mixing step with their cluster
  neighbours (ring over the ICI). No parameter server, no global
  all-reduce — exactly the communication pattern Eqs. (10)–(11) price.

Host execution uses whatever devices exist (tests/examples: 1 CPU);
the production mesh path is exercised by dryrun.py.

Usage:
    PYTHONPATH=src python -m repro.launch.train --arch granite-8b \
        --reduced --steps 20 --mode federated --agents 4 --tasks 2
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, reduced
from repro.core import energy
from repro.core import topology as topo_lib
from repro.core.engine import (PLAN_KINDS, AsyncState, ConsensusEngine,
                               where_active)
from repro.data import TaskTokenDistribution
from repro.launch import steps as steps_lib
from repro.models import frontend
from repro.models.api import get_model, lm_loss
from repro.optim import adam, apply_updates, clip_by_global_norm


def train_standard(cfg, *, steps: int, batch: int, seq: int, lr: float,
                   log_every: int = 5, seed: int = 0):
    model = get_model(cfg)
    key = jax.random.PRNGKey(seed)
    params = model.init(key, cfg)
    opt = adam(lr)
    opt_state = opt.init(params)
    dist = TaskTokenDistribution(vocab_size=cfg.vocab_size, num_tasks=1)

    def loss_fn(p, batch_d):
        return lm_loss(p, cfg, batch_d["tokens"], batch_d["labels"],
                       embeddings=batch_d.get("frames"), model=model)

    @jax.jit
    def step(params, opt_state, batch_d):
        l, g = jax.value_and_grad(loss_fn)(params, batch_d)
        g, gn = clip_by_global_norm(g, 1.0)
        upd, opt_state = opt.update(g, opt_state, params)
        return apply_updates(params, upd), opt_state, l, gn

    hist = []
    for t in range(steps):
        key, sk = jax.random.split(key)
        toks, labels = dist.sample(sk, 0, batch, seq)
        bd = {"tokens": toks, "labels": labels}
        if cfg.family == "encdec":
            bd["frames"] = frontend.audio_frame_embeddings(sk, cfg, batch)
        t0 = time.time()
        params, opt_state, l, gn = step(params, opt_state, bd)
        hist.append(float(l))
        if t % log_every == 0:
            print(f"step {t:4d}  loss {float(l):.4f}  gnorm {float(gn):.3f}"
                  f"  {time.time() - t0:.2f}s")
    return params, hist


def train_federated(cfg, *, rounds: int, agents: int, tasks: int,
                    local_steps: int, batch: int, seq: int, lr: float,
                    consensus_every: int = 1, seed: int = 0,
                    energy_params=None, consensus_dtype=None,
                    consensus_plan: str = "auto", codec=None, mesh=None,
                    chunk: int = 1, dropout_p: float = 0.0,
                    dropout_seed: int = 0, availability=None,
                    tau=None, staleness_decay: float = 1.0,
                    telemetry=None, metrics_path=None):
    """Clustered federated LM training (the paper's stage-2 at LM scale).

    ``agents`` agents form ``tasks`` clusters (agents/tasks per cluster);
    consensus only mixes within a cluster (per-task Topology) through one
    :class:`repro.core.engine.ConsensusEngine` — ``consensus_plan``
    picks the execution plan ("auto", "dense-xla", "sparse-pallas",
    "sharded", "distributed"; a ``mesh`` with an ``agents`` axis enables
    the multi-position plans). Returns (stacked_params, per_round losses,
    energy J). ``consensus_dtype``: cast exchanged models (e.g. bf16) —
    halves the sidelink bytes of Eq. (11); EXPERIMENTS.md §Perf P3.
    ``codec`` (spec string, :mod:`repro.comms`) supersedes it: the
    exchange runs through the codec (error feedback for lossy ones) and
    the Eq.-(11) estimate prices the codec's wire bits instead of the
    storage dtype. ``codec="auto"`` picks the wire format from the
    graph's bottleneck link efficiency (:func:`repro.comms.select_codec`).
    ``chunk`` compiles that many FL rounds into one ``lax.scan`` program
    (loss history synced per chunk, bit-identical to ``chunk=1`` — the
    per-round host loop); the chunk program donates the stacked params +
    EF-residual buffers where the backend supports donation, so the
    agent population updates in place. ``dropout_p > 0`` attaches a
    :class:`repro.core.topology.GraphProcess` to the engine: every FL
    round mixes over that round's SURVIVING sidelinks, with the masks
    generated in-scan from the folded ``dropout_seed`` key (any maskable
    plan; the modeled Eq.-(11) estimate still prices the full graph —
    an upper bound under fading).

    ``telemetry`` (:class:`repro.telemetry.Telemetry`) records one row
    per round — Eq.-(11) joules by link class over the round's ACTUAL
    surviving links, wire bits, disagreement — synced once per chunk
    (buffered; streaming mode also emits live via
    ``jax.debug.callback``). ``metrics_path`` is the shorthand the
    ``--metrics out.jsonl`` CLI flag uses: a buffered Telemetry with a
    JSONL sink is created (and closed) here, giving a round-by-round
    energy ledger that a dropout run's summed stream reconciles with
    exactly. Loss curves and params are bit-identical with telemetry
    off, buffered, or streaming.
    """
    assert agents % tasks == 0
    per = agents // tasks

    # the population graph (per-task SL clusters) drives the Eq.-(6)
    # mixing weights, the engine plan, AND the Eq.-(11) link pricing
    topo = topo_lib.clusters(tasks, per)
    ep = energy_params or energy.paper_calibrated("fig3")
    if codec is not None:
        from repro import comms
        codec = (comms.select_codec(topo, ep) if codec == "auto"
                 else comms.resolve_codec(codec))
        consensus_dtype = None        # the codec defines the wire format
    graph = (topo_lib.GraphProcess.dropout(dropout_p, seed=dropout_seed)
             if dropout_p > 0 else None)
    # ``availability`` (repro.core.topology.AgentProcess) makes the run
    # ASYNCHRONOUS: every round each agent independently wakes or
    # sleeps, sleeping agents skip local SGD and mixing (their params /
    # EF residuals freeze bitwise), awake receivers mix a neighbour's
    # last-published params staleness-weighted (decay^age, dropped past
    # ``tau`` rounds), and the telemetry ledger bills only wires
    # actually DELIVERED. always_on/tau=None reduces to the lockstep
    # run bit-identically.
    engine = ConsensusEngine(topo, codec=codec, mesh=mesh,
                             plan=consensus_plan, graph=graph,
                             agents=availability, tau=tau,
                             staleness_decay=staleness_decay)
    codec = engine.codec
    is_async = engine.agents is not None

    model = get_model(cfg)
    key = jax.random.PRNGKey(seed)
    params = model.init(key, cfg)
    stacked = jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (agents,) + x.shape), params)
    dist = TaskTokenDistribution(vocab_size=cfg.vocab_size, num_tasks=tasks)
    task_of_agent = jnp.arange(agents, dtype=jnp.int32) // per

    def loss_fn(p, b):
        return lm_loss(p, cfg, b["tokens"], b["labels"], model=model)

    def local(p, b):
        def one(p, bb):
            g = jax.grad(loss_fn)(p, bb)
            g, _ = clip_by_global_norm(g, 1.0)
            return jax.tree.map(
                lambda w, gw: (w.astype(jnp.float32) - lr
                               * gw.astype(jnp.float32)).astype(w.dtype),
                p, g), None
        p, _ = jax.lax.scan(one, p, b)
        return p

    def fl_round(stacked, codec_state, key, t, survival=None,
                 active=None):
        # same split as the pre-codec trainer — codec=None runs keep
        # their exact RNG stream (reproducible loss curves); the codec
        # rounding key is folded out of band
        ks = jax.random.split(key, agents)

        def agent_batches(k, task):
            def sample_one(kk):
                toks, labels = dist.sample_traced(kk, task, batch, seq)
                return {"tokens": toks, "labels": labels}
            return jax.vmap(sample_one)(jax.random.split(k, local_steps))

        batches = jax.vmap(agent_batches)(ks, task_of_agent)
        new = jax.vmap(local)(stacked, batches)
        if active is not None:
            # sleeping agents skip local SGD (bitwise hold)
            new = where_active(active, new, stacked)
        pre = new
        # survival= (telemetry shares one plan-shaped draw with its
        # metrics row) takes precedence over t= inside step — identical
        # ops either way
        if codec is not None:
            old_state = (codec_state if codec_state is not None
                         else engine.init_state(pre))
            new, codec_state = engine.step(
                new, codec_state, jax.random.fold_in(key, agents + 1),
                t=t, survival=survival)
            if active is not None and codec_state is not None:
                # sleeping agents' EF residuals hold too
                codec_state = where_active(active, codec_state, old_state)
        elif consensus_dtype is not None:
            cast = jax.tree.map(
                lambda x: x.astype(consensus_dtype), new)
            mixed, _ = engine.step(cast, t=t, survival=survival)
            new = jax.tree.map(lambda m, n: m.astype(n.dtype), mixed, new)
        else:
            new, _ = engine.step(new, t=t, survival=survival)
        if active is not None:
            # sleeping receivers don't mix
            new = where_active(active, new, pre)
        # mean loss of agent 0's task for logging
        l = loss_fn(jax.tree.map(lambda x: x[0], new),
                    jax.tree.map(lambda x: x[0][0], batches))
        return new, codec_state, l

    # the one compiled round-loop program (chunk=1 == the legacy host
    # loop, one dispatch + sync per round; chunk=N syncs once per chunk;
    # stacked params + EF residuals donated where supported)
    from repro.core import scanloop

    def fl_body(carry, t):
        stacked, codec_state, key, astate = carry
        key, sk = jax.random.split(key)
        if is_async:
            # one availability draw per round, shared between the
            # staleness weights, the per-agent freeze, and the
            # telemetry row (billing only DELIVERED wires)
            ar = engine.async_round(t, astate.age)
            sv, act, sv_row = ar.weights, ar.act, ar.delivered
        else:
            ar, act = None, None
            sv = engine.round_survival(t) if tel is not None else None
            sv_row = sv
        stacked, codec_state, l = fl_round(stacked, codec_state, sk, t,
                                           sv, act)
        if is_async:
            astate = AsyncState(
                astate.clock + ar.act.astype(astate.clock.dtype),
                ar.age)
        if tel is None:
            return (stacked, codec_state, key, astate), l
        row = rec.row(stacked, sv_row, metric=l,
                      reached=jnp.asarray(False), live=jnp.asarray(True),
                      active=act, age=(ar.age if is_async else None))
        if stream_cb is not None:
            jax.debug.callback(stream_cb, t, row, ordered=True)
        return (stacked, codec_state, key, astate), (l, row)

    # astate is None on lockstep runs (an empty pytree through the scan
    # carry) and the engine's AsyncState on async runs — clocks/ages
    # persist ACROSS chunks like the params
    fl_chunk = scanloop.donating_jit(
        lambda s, cs, k, ast, ts: jax.lax.scan(
            fl_body, (s, cs, k, ast), ts),
        donate_argnums=(0, 1))

    n_params = sum(x.size for x in jax.tree.leaves(params))
    n_bytes = sum(x.size * (2 if consensus_dtype is not None
                            else x.dtype.itemsize)
                  for x in jax.tree.leaves(params))
    # with a codec, b(W) is the FULL-PRECISION reference size (32-bit per
    # param) that price_bits discounts — deriving it from the storage
    # itemsize would double-discount bf16-stored models; without a codec
    # the wire IS the storage (or consensus_dtype) bytes
    model_bits = (32.0 * n_params if codec is not None
                  else float(n_bytes) * 8)
    import dataclasses as dc
    ep = dc.replace(ep, model_bits=model_bits,
                    devices_per_cluster=per, B_i=local_steps)
    # one cluster's graph: per·(per−1) directed SL messages per round —
    # NOT the legacy devices_per_cluster × neighbors_per_device constant,
    # which under-priced any cluster larger than 2 robots
    cluster_topo = topo_lib.clusters(1, per)

    from repro import telemetry as telemetry_lib
    tel = telemetry
    own_tel = tel is None and metrics_path is not None
    if own_tel:
        tel = telemetry_lib.Telemetry(
            sinks=(telemetry_lib.JsonlSink(metrics_path),))
    # the recorder bills with THIS run's calibrated ep (wire-format
    # model_bits baked above), over the round's actual surviving links
    rec = tel.recorder_for(engine, ep) if tel is not None else None
    stream_cb = (tel.stream_cb(rec, "fl")
                 if tel is not None and tel.streaming else None)

    codec_state = (codec.init_state(stacked)
                   if codec is not None and codec.stateful else None)
    # own(): fl_chunk donates the stacked/EF carries on donating backends
    stacked = scanloop.own(stacked)
    codec_state = scanloop.own(codec_state)
    astate = engine.init_async_state() if is_async else None
    hist = []
    chunk = max(int(chunk), 1)
    for start in range(0, rounds, chunk):
        n = min(chunk, rounds - start)
        ts = jnp.arange(start, start + n, dtype=jnp.int32)
        (stacked, codec_state, key, astate), ls = fl_chunk(
            stacked, codec_state, key, astate, ts)
        if tel is not None:
            ls, rows = ls
            tel.record_rounds(rec, rows, start, driver="fl")
        for r, l in enumerate(np.asarray(ls), start):   # one sync/chunk
            hist.append(float(l))
            print(f"round {r:3d}  loss {float(l):.4f}")
    # Eq.-(11) priced at the codec's wire size (b(W) · bits ratio)
    E = tasks * energy.fl_energy(ep, rounds, topology=cluster_topo,
                                 codec=codec)
    wire_mb = (codec.price_bits(model_bits) / 8e6 if codec is not None
               else n_bytes / 1e6)
    print(f"estimated FL energy for {rounds} rounds x {tasks} clusters: "
          f"{E / 1e3:.2f} kJ ({wire_mb:.2f} MB per exchange"
          f"{', codec ' + codec.name if codec is not None else ''})")
    if tel is not None:
        n_ev = len(tel.events(driver="fl"))
        print(f"telemetry: {n_ev} round events, measured comm energy "
              f"{tel.joules() / 1e3:.2f} kJ (per-round Eq.-11 ledger)")
        if own_tel:
            tel.close()
    return stacked, hist, E


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--mode", choices=["standard", "federated"],
                    default="standard")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--rounds", type=int, default=10)
    ap.add_argument("--agents", type=int, default=4)
    ap.add_argument("--tasks", type=int, default=2)
    ap.add_argument("--local-steps", type=int, default=4)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--bf16-consensus", action="store_true")
    ap.add_argument("--consensus-plan",
                    choices=["auto"] + list(PLAN_KINDS), default="auto",
                    help="consensus execution plan (repro.core.engine)")
    ap.add_argument("--codec", default=None,
                    help="model-exchange codec spec (bf16, int8, int4, "
                         "int8:b64 block scales, topk:0.05, +ef suffix; "
                         "'auto' picks from link quality; see repro.comms)")
    ap.add_argument("--chunk", type=int, default=1,
                    help="FL rounds per compiled scan program (1 = "
                         "per-round host loop; larger chunks sync once "
                         "per chunk, bit-identical results)")
    ap.add_argument("--dropout-p", type=float, default=0.0,
                    help="per-round sidelink failure probability: each "
                         "FL round mixes over that round's surviving "
                         "links, masks generated in-scan "
                         "(repro.core.topology.GraphProcess)")
    ap.add_argument("--dropout-seed", type=int, default=0)
    ap.add_argument("--availability-p", type=float, default=None,
                    help="per-round agent wake probability: attaches a "
                         "Bernoulli AgentProcess — sleeping agents skip "
                         "local SGD and mixing, receivers mix stale "
                         "neighbour params (repro.core.topology)")
    ap.add_argument("--availability-seed", type=int, default=0)
    ap.add_argument("--tau", type=float, default=None,
                    help="hard staleness bound: wires older than tau "
                         "rounds stop mixing (sigma renormalizes); "
                         "default None = unbounded")
    ap.add_argument("--staleness-decay", type=float, default=1.0,
                    help="per-round age decay of stale-wire mixing "
                         "weight (lambda**age; 1.0 keeps full weight)")
    ap.add_argument("--metrics", default=None, metavar="OUT.JSONL",
                    help="write a per-round telemetry event log (JSONL; "
                         "Eq.-11 joules by link class, wire bits, "
                         "disagreement — see repro.telemetry.schema)")
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    if args.mode == "standard":
        train_standard(cfg, steps=args.steps, batch=args.batch,
                       seq=args.seq, lr=args.lr)
    else:
        train_federated(
            cfg, rounds=args.rounds, agents=args.agents, tasks=args.tasks,
            local_steps=args.local_steps, batch=args.batch, seq=args.seq,
            lr=args.lr,
            consensus_dtype=jnp.bfloat16 if args.bf16_consensus else None,
            consensus_plan=args.consensus_plan, codec=args.codec,
            chunk=args.chunk, dropout_p=args.dropout_p,
            dropout_seed=args.dropout_seed,
            availability=(topo_lib.AgentProcess.bernoulli(
                args.availability_p, seed=args.availability_seed)
                if args.availability_p is not None else None),
            tau=args.tau, staleness_decay=args.staleness_decay,
            metrics_path=args.metrics)


if __name__ == "__main__":
    main()
