import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 "
    + os.environ.get("XLA_FLAGS", "").replace(
        "--xla_force_host_platform_device_count=8", ""))

"""Multi-chip dry-run harness for the consensus engine.

The FIRST import above pins 8 placeholder host devices BEFORE jax
initializes (the ``launch/dryrun`` pattern), so this module — and only
this module — sees an emulated multi-device mesh; tests and benchmarks
importing jax normally see 1 device. Everything here is compile-and-
inspect plus small numeric parity runs: no accelerator is required, and
the artifacts audited are the SAME compiled modules a real 8-chip mesh
would execute per device (SPMD partitioning happens at compile time).

Three checks per run, all with dropout ACTIVE (the masked round is the
one the per-edge survival convention compiles; auditing the static fast
path would miss every regression this harness exists to catch):

* **H1, no (K, K) buffer** — the masked sharded step at ``--k`` (default
  4096) must compile with no square buffer of dim >= K anywhere in the
  optimized module: per-lane survival draws + lane-σ renormalization
  replace the dense rebuild, so dropout no longer reintroduces the
  O(K²) wall the plan removes.
* **collective layout** — the plan's wire collective (``all-gather`` on
  sharded, ``collective-permute`` on distributed, from
  ``engine.audit_meta()``) must ship nonzero bytes, and an int8 codec
  must keep ``s8`` lanes IN the collective's result layout (decode
  fusing after the gather, not before — the JX2 invariant, asserted on
  the partitioned artifact).
* **JX3 donation honored** — the step jitted with donated params/state
  must alias every donated leaf in ``input_output_alias``; XLA drops
  donation silently when layouts fail to pair up, doubling peak memory
  exactly where a real mesh can least afford it.
* **C3, no collective outside the ledger** — every collective in the
  partitioned module must be the plan's priced wire or control plane
  (``repro.analysis.costmodel.collective_ledger``); the report carries
  the resulting priced/control/unpriced byte ledger per plan.

Plus mesh-vs-emulation parity: the sharded and distributed plans driven
on the 8-device mesh must agree with their single-device emulations
(``mesh=None`` vmap fallback) to allclose on a masked round — same
survival bits by construction (the per-edge convention is a pure
function of (key, t, edge id)), different collectives.

Usage::

    PYTHONPATH=src python -m repro.launch.multichip [--k 4096]
        [--parity-k 32] [--out report.json]

Exit status 1 on any violation (CI runs this as the multi-chip smoke).
"""
import argparse
import json
import re
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.analysis.costmodel import collective_ledger
from repro.analysis.jaxpr_audit import alias_param_indices
from repro.core import topology as topo_lib
from repro.core.engine import ConsensusEngine
from repro.launch.hlo_analysis import collective_bytes, square_buffers

DROPOUT_P, DROPOUT_SEED = 0.3, 0


def agent_mesh(n: int = 8) -> Mesh:
    """1-D mesh over the first ``n`` host devices (axis ``"agents"``)."""
    devs = jax.devices()
    if len(devs) < n:
        raise RuntimeError(
            f"{len(devs)} device(s) visible — the multichip module must "
            "be the first jax import (XLA_FLAGS pins 8 host devices)")
    return Mesh(np.array(devs[:n]), ("agents",))


def _wire_dtypes(hlo_text: str, kind: str):
    """Element dtypes in the result layouts of every ``kind`` collective
    in the module (``-done`` halves skipped, like collective_bytes)."""
    dts = set()
    for m in re.finditer(
            r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*((?:\([^)]*\))|(?:\S+))\s+"
            + re.escape(kind) + r"(-start|-done)?\(", hlo_text, re.M):
        shape_str, phase = m.groups()
        if phase == "-done":
            continue
        dts.update(mm.group(1) for mm in
                   re.finditer(r"(pred|[suc]\d+|bf16|f16|f32|f64)\[",
                               shape_str))
    return dts


def _masked_step_fn(eng):
    def step(p, st, kk, tt):
        return eng.step(p, st, kk, t=tt)
    return step


def _compile_masked_step(eng, params, *, donate=True):
    """Compile one masked round (traced ``t``), donated params/state."""
    state = eng.init_state(params)
    key = jax.random.PRNGKey(0)
    donate_argnums = (0, 1) if donate else ()
    jitted = jax.jit(_masked_step_fn(eng), donate_argnums=donate_argnums)
    t0 = time.time()
    compiled = jitted.lower(params, state, key, jnp.int32(0)).compile()
    secs = time.time() - t0
    return compiled, (params, state, key), secs


def _donation_gaps(hlo_text, abstract_args, donate_argnums):
    """Flat parameter indices of donated leaves NOT covered by the
    module's input_output_alias directive (check_donation's arithmetic,
    applied to an already-compiled module)."""
    aliased = alias_param_indices(hlo_text)
    starts, n = [], 0
    for a in abstract_args:
        starts.append(n)
        n += len(jax.tree.leaves(a))
    missing = []
    for argnum in donate_argnums:
        leaves = len(jax.tree.leaves(abstract_args[argnum]))
        missing += [i for i in range(starts[argnum],
                                     starts[argnum] + leaves)
                    if i not in aliased]
    return missing


def dry_run_sharded(k: int = 4096, *, num_blocks: int = 8,
                    codec: str = "int8", n: int = 64, verbose=True):
    """Masked sharded round at scale on the 8-device mesh: H1 +
    collective layout + donation, one compile."""
    mesh = agent_mesh(num_blocks)
    eng = ConsensusEngine(
        topo_lib.ring(k), codec=codec, plan="sharded",
        num_blocks=num_blocks, mesh=mesh,
        graph=topo_lib.GraphProcess.dropout(DROPOUT_P, seed=DROPOUT_SEED))
    params = {"w": jnp.zeros((k, n), jnp.float32)}
    compiled, args, secs = _compile_masked_step(eng, params)
    txt = compiled.as_text()
    wire_op = eng.audit_meta()["wire_collective"]
    colls = collective_bytes(txt)
    report = {
        "plan": "sharded", "k": k, "num_blocks": num_blocks,
        "codec": codec, "dropout_p": DROPOUT_P,
        "compile_seconds": round(secs, 2),
        "collectives": {kk: v for kk, v in colls.items() if v},
        "wire_dtypes": sorted(_wire_dtypes(txt, wire_op)),
    }
    violations = []
    squares = square_buffers(txt, k)
    for dt, dim, nbytes in squares:
        violations.append(
            f"H1: ({dim}, {dim}) {dt} buffer ({nbytes / 1e6:.0f} MB) in "
            f"the compiled MASKED sharded module at K={k}")
    if colls.get(wire_op, 0) == 0:
        violations.append(
            f"layout: no {wire_op} bytes in the sharded module — the "
            "wire collective vanished from the partitioned program")
    if codec and codec.startswith("int8") and "s8" not in report["wire_dtypes"]:
        violations.append(
            f"layout: {wire_op} result carries {report['wire_dtypes']} "
            "but no s8 — the int8 wire was decoded before the collective")
    gaps = _donation_gaps(txt, args, (0, 1))
    if gaps:
        violations.append(
            f"JX3: donation dropped for {len(gaps)} params/state leaves "
            f"(flat indices {gaps}) in the masked sharded step")
    ledger, c3 = collective_ledger(eng.audit_meta(), txt,
                                   f"multichip:sharded/{codec}")
    report["ledger"] = {"priced_bytes": ledger.priced_bytes,
                        "control_bytes": ledger.control_bytes,
                        "unpriced_bytes": ledger.unpriced_bytes}
    violations += [f"C3: {f.message}" for f in c3]
    report["violations"] = violations
    if verbose:
        print(f"== sharded K={k} blocks={num_blocks} codec={codec} "
              f"p={DROPOUT_P} (compile {secs:.1f}s)")
        print(f"   collectives: {report['collectives']}  "
              f"wire={wire_op}:{report['wire_dtypes']}")
        print(f"   square buffers >= {k}: {squares or 'none'}")
    return report


def dry_run_distributed(k: int = 8, *, codec: str = "int8", n: int = 64,
                        verbose=True):
    """Masked distributed round, one agent per mesh position: the
    ppermute schedule superset must survive partitioning with survival
    riding the traced sig_override only."""
    mesh = agent_mesh(k)
    eng = ConsensusEngine(
        topo_lib.ring(k), codec=codec, plan="distributed", mesh=mesh,
        graph=topo_lib.GraphProcess.dropout(DROPOUT_P, seed=DROPOUT_SEED))
    params = {"w": jnp.zeros((k, n), jnp.float32)}
    compiled, args, secs = _compile_masked_step(eng, params)
    txt = compiled.as_text()
    wire_op = eng.audit_meta()["wire_collective"]
    colls = collective_bytes(txt)
    report = {
        "plan": "distributed", "k": k, "codec": codec,
        "dropout_p": DROPOUT_P, "compile_seconds": round(secs, 2),
        "schedule_slots": len(eng._schedule),
        "collectives": {kk: v for kk, v in colls.items() if v},
        "wire_dtypes": sorted(_wire_dtypes(txt, wire_op)),
    }
    violations = []
    if colls.get(wire_op, 0) == 0:
        violations.append(
            f"layout: no {wire_op} bytes in the distributed module — "
            "the masked schedule superset lost its permutes")
    gaps = _donation_gaps(txt, args, (0, 1))
    if gaps:
        violations.append(
            f"JX3: donation dropped for {len(gaps)} params/state leaves "
            f"(flat indices {gaps}) in the masked distributed step")
    ledger, c3 = collective_ledger(eng.audit_meta(), txt,
                                   f"multichip:distributed/{codec}")
    report["ledger"] = {"priced_bytes": ledger.priced_bytes,
                        "control_bytes": ledger.control_bytes,
                        "unpriced_bytes": ledger.unpriced_bytes}
    violations += [f"C3: {f.message}" for f in c3]
    report["violations"] = violations
    if verbose:
        print(f"== distributed K={k} codec={codec} p={DROPOUT_P} "
              f"({report['schedule_slots']} schedule slots, "
              f"compile {secs:.1f}s)")
        print(f"   collectives: {report['collectives']}  "
              f"wire={wire_op}:{report['wire_dtypes']}")
    return report


def parity_mesh_vs_emulation(k: int = 32, *, num_blocks: int = 8,
                             rounds: int = 4, verbose=True):
    """Both multi-device plans vs their single-device emulations on
    ``rounds`` masked rounds: same survival bits by construction, so the
    trajectories must agree to allclose (different collectives — bitwise
    is not on the table across compilation strategies)."""
    gp = topo_lib.GraphProcess.dropout(DROPOUT_P, seed=DROPOUT_SEED)
    key = jax.random.PRNGKey(1)
    keys = jax.random.split(jax.random.PRNGKey(2), rounds)
    violations = []
    cases = [("sharded", topo_lib.ring(k),
              {"num_blocks": num_blocks}),
             ("distributed", topo_lib.ring(8), {})]
    for plan, topo, kw in cases:
        kk = topo.K
        mesh = agent_mesh(kk if plan == "distributed" else num_blocks)
        params = {"w": jax.random.normal(key, (kk, 16)),
                  "b": jax.random.normal(jax.random.fold_in(key, 1),
                                         (kk, 4))}
        outs = []
        for m in (mesh, None):
            eng = ConsensusEngine(topo, codec="int8", plan=plan,
                                  mesh=m, graph=gp, **kw)
            run = jax.jit(lambda p, st, ks, t0:
                          eng.scan_rounds(p, st, ks, t0=t0))
            p, st = run(params, eng.init_state(params), keys,
                        jnp.int32(0))
            outs.append(jax.tree.map(np.asarray, p))
        err = max(float(np.abs(a - b).max()) for a, b in
                  zip(jax.tree.leaves(outs[0]), jax.tree.leaves(outs[1])))
        if verbose:
            print(f"== parity {plan} K={kk}: mesh vs emulation "
                  f"max|Δ|={err:.2e} over {rounds} masked rounds")
        if err > 1e-5:
            violations.append(
                f"parity: {plan} mesh vs emulation diverge by {err:.2e} "
                f"(> 1e-5) over {rounds} masked rounds at K={kk}")
    return {"rounds": rounds, "violations": violations}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--k", type=int, default=4096,
                    help="sharded H1 population (acceptance: 4096)")
    ap.add_argument("--parity-k", type=int, default=32,
                    help="population for the mesh-vs-emulation parity runs")
    ap.add_argument("--out", default=None, help="JSON report path")
    args = ap.parse_args(argv)

    reports = {
        "devices": len(jax.devices()),
        "sharded": dry_run_sharded(args.k),
        "distributed": dry_run_distributed(),
        "parity": parity_mesh_vs_emulation(args.parity_k),
    }
    violations = (reports["sharded"]["violations"]
                  + reports["distributed"]["violations"]
                  + reports["parity"]["violations"])
    if args.out:
        with open(args.out, "w") as f:
            json.dump(reports, f, indent=1)
    for v in violations:
        print(f"VIOLATION  {v}")
    print(f"\nmultichip dry-run: {len(violations)} violation(s) on "
          f"{reports['devices']} emulated devices")
    return 1 if violations else 0


if __name__ == "__main__":
    sys.exit(main())
