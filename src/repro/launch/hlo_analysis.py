"""Extract roofline inputs from a lowered/compiled XLA program.

``cost_analysis()`` provides HLO FLOPs and bytes accessed; collective
bytes are NOT in cost_analysis, so we parse the (optimized, if available)
HLO text and sum the result-shape bytes of every collective op
(all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute), per §Roofline of the assignment.
"""
from __future__ import annotations

import re
from dataclasses import dataclass
from typing import Dict, Optional

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(pred|[suc]\d+|bf16|f16|f32|f64)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """Total bytes of all tensors in an HLO result type string."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.groups()
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|\S+)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", re.M)


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """{collective kind: result bytes} summed over the module.

    ``-start``/``-done`` pairs are counted once (we skip ``-done``:
    its operand is the started op)."""
    out: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for m in re.finditer(
            r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*((?:\([^)]*\))|(?:\S+))\s+"
            r"(all-gather|all-reduce|reduce-scatter|all-to-all|"
            r"collective-permute)(-start|-done)?\(",
            hlo_text, re.M):
        shape_str, kind, phase = m.groups()
        if phase == "-done":
            continue
        out[kind] += _shape_bytes(shape_str)
    return out


def square_buffers(hlo_text: str, min_dim: int):
    """Every DISTINCT square tensor shape ``dt[D,D]`` with D >= min_dim
    appearing anywhere in the module, as ``(dtype, D, bytes)`` tuples.

    The sharded/distributed plans exist so no single program ever
    materializes the (K, K) mixing stack; ``repro.analysis`` rule H1
    asserts this on the compiled artifact at K >= its threshold."""
    seen = set()
    for m in _SHAPE_RE.finditer(hlo_text):
        dt, dims = m.groups()
        parts = [int(d) for d in dims.split(",") if d]
        if len(parts) == 2 and parts[0] == parts[1] and parts[0] >= min_dim:
            seen.add((dt, parts[0],
                      parts[0] * parts[1] * _DTYPE_BYTES.get(dt, 4)))
    return sorted(seen)


@dataclass
class DryRunReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops: float
    hbm_bytes: float
    collectives: Dict[str, int]
    bytes_per_device: Optional[float] = None
    compile_seconds: float = 0.0

    @property
    def collective_total(self) -> int:
        return sum(self.collectives.values())

    def roofline(self, **kw):
        from repro.core.energy import RooflineTerms
        return RooflineTerms(
            flops=self.flops, hbm_bytes=self.hbm_bytes,
            collective_bytes=float(self.collective_total),
            chips=self.chips, **kw)


def analyze_compiled(compiled, *, arch: str, shape: str, mesh_name: str,
                     chips: int, compile_seconds: float = 0.0,
                     hlo_text: Optional[str] = None) -> DryRunReport:
    """NOTE: XLA's cost_analysis (and the SPMD HLO module) are PER-DEVICE
    (verified empirically; EXPERIMENTS.md §Roofline/Methodology) — we
    multiply by ``chips`` so the report carries GLOBAL totals and the
    §Roofline formulas (which divide by chips) apply as written. Scan
    bodies are counted once; see launch/probes.py for the correction."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    flops = float(ca.get("flops", 0.0)) * chips
    hbm = float(ca.get("bytes accessed", 0.0)) * chips
    text = hlo_text if hlo_text is not None else compiled.as_text()
    colls = {k: v * chips for k, v in collective_bytes(text).items()}
    bpd = None
    try:
        ma = compiled.memory_analysis()
        bpd = float(getattr(ma, "temp_size_in_bytes", 0)
                    + getattr(ma, "argument_size_in_bytes", 0)
                    + getattr(ma, "output_size_in_bytes", 0)
                    + getattr(ma, "generated_code_size_in_bytes", 0))
    except Exception:
        pass
    return DryRunReport(arch=arch, shape=shape, mesh=mesh_name, chips=chips,
                        flops=flops, hbm_bytes=hbm, collectives=colls,
                        bytes_per_device=bpd,
                        compile_seconds=compile_seconds)
