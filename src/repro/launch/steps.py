"""Step builders: train_step / prefill_step / decode_step per architecture,
plus ``input_specs`` ShapeDtypeStruct stand-ins for the dry-run (no device
allocation — weak-type-correct, shardable).

Decode shapes lower ``serve_step`` — ONE new token against a seq_len KV
cache (SWA archs physically cache only their window; SSM/hybrid archs
carry recurrent state) — per the assignment contract.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ArchConfig, InputShape
from repro.models.api import get_model, lm_loss
from repro.optim import adam, apply_updates, clip_by_global_norm
from repro.sharding import rules
from repro.sharding.context import use_mesh


# ---------------------------------------------------------------------------
# abstract inputs
# ---------------------------------------------------------------------------


def input_specs(cfg: ArchConfig, shape: InputShape) -> dict:
    """ShapeDtypeStructs for every model input of this (arch, shape)."""
    B, S = shape.global_batch, shape.seq_len
    tok = lambda b, s: jax.ShapeDtypeStruct((b, s), jnp.int32)
    out = {}
    if shape.mode == "train":
        out["tokens"] = tok(B, S)
        out["labels"] = tok(B, S)
    elif shape.mode == "prefill":
        out["tokens"] = tok(B, S)
    else:  # decode
        out["tokens"] = tok(B, 1)
        out["cache_index"] = jax.ShapeDtypeStruct((), jnp.int32)
    if cfg.family == "encdec":
        T = cfg.encdec.encoder_seq_len
        out["frames"] = jax.ShapeDtypeStruct((B, T, cfg.d_model),
                                             jnp.dtype(cfg.dtype))
        if shape.mode == "decode":
            # cross-KV is computed at prefill; decode consumes the cache
            del out["frames"]
    return out


def abstract_params(cfg: ArchConfig):
    """Params as ShapeDtypeStructs via eval_shape (no allocation)."""
    model = get_model(cfg)
    return jax.eval_shape(
        lambda k: model.init(k, cfg), jax.random.PRNGKey(0))


def abstract_caches(cfg: ArchConfig, shape: InputShape):
    model = get_model(cfg)
    return jax.eval_shape(
        functools.partial(model.init_cache, cfg, shape.global_batch,
                          shape.seq_len))


# ---------------------------------------------------------------------------
# steps
# ---------------------------------------------------------------------------


def make_train_step(cfg: ArchConfig, *, lr: float = 3e-4,
                    clip_norm: float = 1.0):
    """(params, opt_state, batch) -> (params, opt_state, metrics)."""
    model = get_model(cfg)
    opt = adam(lr)

    def loss(params, batch):
        return lm_loss(params, cfg, batch["tokens"], batch["labels"],
                       embeddings=batch.get("frames"), model=model)

    def train_step(params, opt_state, batch):
        l, g = jax.value_and_grad(loss)(params, batch)
        g, gnorm = clip_by_global_norm(g, clip_norm)
        updates, opt_state = opt.update(g, opt_state, params)
        params = apply_updates(params, updates)
        return params, opt_state, {"loss": l, "grad_norm": gnorm}

    return train_step, opt


def make_prefill_step(cfg: ArchConfig):
    """(params, caches, batch) -> (last_logits, caches)."""
    model = get_model(cfg)

    def prefill_step(params, caches, batch):
        logits, caches, _ = model.forward(
            params, cfg, batch["tokens"],
            embeddings=batch.get("frames"),
            caches=caches, cache_index=jnp.int32(0))
        return logits[:, -1:], caches

    return prefill_step


def make_decode_step(cfg: ArchConfig):
    """(params, caches, batch{tokens,cache_index}) -> (next_token, caches)."""
    model = get_model(cfg)

    def decode_step(params, caches, batch):
        logits, caches, _ = model.forward(
            params, cfg, batch["tokens"],
            caches=caches, cache_index=batch["cache_index"])
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return nxt[:, None], caches

    return decode_step


# ---------------------------------------------------------------------------
# sharded lowering (shared by dryrun.py / train.py / serve.py)
# ---------------------------------------------------------------------------


def shardings_for(cfg, mesh, shape: InputShape, *, with_opt: bool):
    """(param_sh, opt_sh, cache_sh, batch_sh) NamedSharding trees."""
    p_abs = abstract_params(cfg)
    p_sh = rules.param_shardings(p_abs, cfg, mesh)
    o_sh = None
    if with_opt:
        opt = adam(1e-4)
        o_abs = jax.eval_shape(opt.init, p_abs)
        # optimizer state inherits its param's sharding; scalars replicate
        flat_p = {id(l): s for (l, s) in zip(
            jax.tree.leaves(p_abs), jax.tree.leaves(p_sh))}

        def opt_leaf_sharding(leaf):
            return NamedSharding(mesh, P())

        # mu/nu mirror params exactly -> reuse param sharding by structure
        o_sh = {
            "step": NamedSharding(mesh, P()),
            "mu": jax.tree.map(lambda s: s, p_sh),
            "nu": jax.tree.map(lambda s: s, p_sh),
        }
    c_sh = None
    if shape.mode != "train":
        c_abs = abstract_caches(cfg, shape)
        c_sh = rules.cache_shardings(c_abs, cfg, mesh)
    b_abs = input_specs(cfg, shape)
    b_sh = {}
    dp = rules.dp_size(mesh)
    for name, spec in b_abs.items():
        sdims = [None] * len(spec.shape)
        if (name != "cache_index" and len(spec.shape)
                and spec.shape[0] % dp == 0):
            sdims[0] = rules.batch_spec(mesh)[0]
        b_sh[name] = NamedSharding(mesh, P(*sdims))
    return p_sh, o_sh, c_sh, b_sh


def lower_step(cfg, mesh, shape: InputShape, *, donate: bool = True):
    """Build + lower the right step for (cfg, shape) on ``mesh``.

    Returns (lowered, specs_dict) — ``lowered.compile()`` is the dry-run.
    """
    with use_mesh(mesh):
        p_abs = abstract_params(cfg)
        b_abs = input_specs(cfg, shape)
        p_sh, o_sh, c_sh, b_sh = shardings_for(
            cfg, mesh, shape, with_opt=shape.mode == "train")

        if shape.mode == "train":
            step, opt = make_train_step(cfg)
            o_abs = jax.eval_shape(opt.init, p_abs)
            jitted = jax.jit(
                step,
                in_shardings=(p_sh, o_sh, b_sh),
                out_shardings=(p_sh, o_sh, None),
                donate_argnums=(0, 1) if donate else (),
            )
            lowered = jitted.lower(p_abs, o_abs, b_abs)
        elif shape.mode == "prefill":
            step = make_prefill_step(cfg)
            c_abs = abstract_caches(cfg, shape)
            jitted = jax.jit(
                step,
                in_shardings=(p_sh, c_sh, b_sh),
                out_shardings=(None, c_sh),
                donate_argnums=(1,) if donate else (),
            )
            lowered = jitted.lower(p_abs, c_abs, b_abs)
        else:
            step = make_decode_step(cfg)
            c_abs = abstract_caches(cfg, shape)
            jitted = jax.jit(
                step,
                in_shardings=(p_sh, c_sh, b_sh),
                out_shardings=(None, c_sh),
                donate_argnums=(1,) if donate else (),
            )
            lowered = jitted.lower(p_abs, c_abs, b_abs)
    return lowered
