"""Serving launcher: batched prefill + greedy decode with KV caches.

Usage:
    PYTHONPATH=src python -m repro.launch.serve --arch h2o-danube-3-4b \
        --reduced --batch 4 --prompt-len 64 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_arch, reduced
from repro.launch.steps import make_decode_step, make_prefill_step
from repro.models import frontend
from repro.models.api import get_model


def serve(cfg, *, batch: int, prompt_len: int, gen: int, seed: int = 0,
          verbose: bool = True):
    model = get_model(cfg)
    key = jax.random.PRNGKey(seed)
    params = model.init(key, cfg)
    max_len = prompt_len + gen
    caches = model.init_cache(cfg, batch, max_len)
    prefill = jax.jit(make_prefill_step(cfg))
    decode = jax.jit(make_decode_step(cfg))

    prompts = jax.random.randint(key, (batch, prompt_len), 0,
                                 cfg.vocab_size)
    bd = {"tokens": prompts}
    if cfg.family == "encdec":
        bd["frames"] = frontend.audio_frame_embeddings(key, cfg, batch)

    t0 = time.time()
    last_logits, caches = prefill(params, caches, bd)
    nxt = jnp.argmax(last_logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
    t_prefill = time.time() - t0

    out = [nxt]
    t0 = time.time()
    for i in range(gen - 1):
        nxt, caches = decode(params, caches,
                             {"tokens": nxt,
                              "cache_index": jnp.int32(prompt_len + i)})
        out.append(nxt)
    t_decode = time.time() - t0
    tokens = jnp.concatenate(out, axis=1)
    if verbose:
        print(f"prefill {batch}x{prompt_len}: {t_prefill*1e3:.1f} ms")
        print(f"decode {gen-1} steps: {t_decode*1e3:.1f} ms "
              f"({t_decode/(max(gen-1,1))*1e3:.2f} ms/tok/batch)")
        print(f"generated shape: {tokens.shape}")
    return tokens


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="h2o-danube-3-4b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args()
    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    serve(cfg, batch=args.batch, prompt_len=args.prompt_len, gen=args.gen)


if __name__ == "__main__":
    main()
