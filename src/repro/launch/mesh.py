"""Production mesh construction (functions only — importing this module
never touches jax device state; see the dry-run's XLA_FLAGS contract)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever local devices exist (tests/examples)."""
    n = len(jax.devices())
    data = min(data, n)
    model = min(model, max(n // data, 1))
    return jax.make_mesh((data, model), ("data", "model"))


def make_agent_mesh(positions: int = 0, axis_name: str = "agents"):
    """1-D mesh whose axis carries consensus AGENTS (one agent per
    position for the engine's ``distributed`` plan; a block of agents
    per position for ``sharded``). ``positions`` 0 ⇒ all local devices;
    values above the device count are clamped."""
    n = len(jax.devices())
    positions = n if positions <= 0 else min(positions, n)
    return jax.make_mesh((positions,), (axis_name,))
