import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "").replace(
        "--xla_force_host_platform_device_count=512", ""))

"""Multi-pod dry-run: prove every (architecture × input shape × mesh)
combination lowers and compiles on the production mesh (DESIGN.md §4).

The FIRST import above pins 512 placeholder host devices BEFORE jax
initializes — this module (and ONLY this module) sees the full production
topology; tests and benchmarks see 1 device.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch granite-8b \
        --shape train_4k [--multi-pod]
    PYTHONPATH=src python -m repro.launch.dryrun --all [--out report.json]

Per combination it records compiled.memory_analysis() (fits?),
cost_analysis() FLOPs/bytes, and the collective-bytes breakdown parsed
from the optimized HLO — the inputs of EXPERIMENTS.md §Roofline.
"""
import argparse
import dataclasses
import json
import sys
import time

import jax

from repro.configs import INPUT_SHAPES, get_arch, list_archs
from repro.launch import steps
from repro.launch.hlo_analysis import analyze_compiled
from repro.launch.mesh import make_production_mesh

# (arch, shape) pairs excluded from long_500k with the reason recorded —
# full-attention archs cannot serve 512k contexts (DESIGN.md §3).
LONG_CONTEXT_SKIPS = {
    "granite-8b": "full attention (llama arch); no SWA variant claimed",
    "chameleon-34b": "full attention early-fusion VLM",
    "stablelm-3b": "full attention (MHA)",
    "deepseek-7b": "full attention (MHA)",
    "whisper-large-v3": "decoder ctx 448; full attention enc-dec",
    "paper-dqn": "not a sequence model",
}


def runnable(arch: str, shape_name: str) -> bool:
    if arch == "paper-dqn":
        return False
    if shape_name == "long_500k" and arch in LONG_CONTEXT_SKIPS:
        return False
    return True


def dry_run_one(arch: str, shape_name: str, *, multi_pod: bool = False,
                verbose: bool = True, probe: bool = False):
    cfg = get_arch(arch)
    shape = INPUT_SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    chips = mesh.size

    t0 = time.time()
    lowered = steps.lower_step(cfg, mesh, shape)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    report = analyze_compiled(
        compiled, arch=arch, shape=shape_name, mesh_name=mesh_name,
        chips=chips, compile_seconds=t_lower + t_compile)
    if verbose:
        ma = compiled.memory_analysis()
        print(f"== {arch} × {shape_name} × {mesh_name} "
              f"(lower {t_lower:.1f}s compile {t_compile:.1f}s)")
        print(f"   memory_analysis: {ma}")
        print(f"   flops={report.flops:.3e} bytes={report.hbm_bytes:.3e}")
        print(f"   collectives: { {k: v for k, v in report.collectives.items() if v} }")

    extra = {}
    if probe:
        from repro.launch.probes import (corrected, probe_configs,
                                         ssm_analytic_correction)
        pc_out = probe_configs(cfg)
        full = {"flops": report.flops, "hbm_bytes": report.hbm_bytes,
                "collective_total": float(report.collective_total)}
        if pc_out is None:
            # ssm: layers already unrolled; add the analytic inner-scan term
            extra = dict(full)
            extra["flops"] += ssm_analytic_correction(cfg, shape)
            extra["probe_units"] = 0.0
        else:
            c1cfg, u1, c2cfg, u2, units = pc_out
            probe_reports = []
            for pcfg in (c1cfg, c2cfg):
                pc = steps.lower_step(pcfg, mesh, shape).compile()
                pr = analyze_compiled(pc, arch=arch, shape=shape_name,
                                      mesh_name=mesh_name, chips=chips)
                probe_reports.append({
                    "flops": pr.flops, "hbm_bytes": pr.hbm_bytes,
                    "collective_total": float(pr.collective_total)})
            extra = corrected(full, probe_reports[0], probe_reports[1],
                              u1, u2, units)
            extra["probe_units"] = units
        if verbose:
            print(f"   corrected (probe): flops={extra['flops']:.3e} "
                  f"bytes={extra['hbm_bytes']:.3e} "
                  f"coll={extra['collective_total']:.3e}")
            from repro.core.energy import RooflineTerms
            rt = RooflineTerms(flops=extra["flops"],
                               hbm_bytes=extra["hbm_bytes"],
                               collective_bytes=extra["collective_total"],
                               chips=chips)
            print(f"   roofline: compute {rt.t_compute*1e3:.2f} ms | memory "
                  f"{rt.t_memory*1e3:.2f} ms | collective "
                  f"{rt.t_collective*1e3:.2f} ms -> {rt.bottleneck}-bound")
    return report, extra


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None,
                    choices=list(INPUT_SHAPES) + [None])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="every runnable (arch × shape) on this mesh")
    ap.add_argument("--probe", action="store_true",
                    help="also lower 1/2-layer unrolled probes and emit "
                         "scan-corrected cost totals (launch/probes.py)")
    ap.add_argument("--out", default=None, help="JSON report path")
    args = ap.parse_args(argv)

    pairs = []
    archs = [args.arch] if args.arch else [a for a in list_archs()
                                           if a != "paper-dqn"]
    shapes = [args.shape] if args.shape else list(INPUT_SHAPES)
    for a in archs:
        for s in shapes:
            if runnable(a, s):
                pairs.append((a, s))
            elif args.arch or args.shape:
                print(f"SKIP {a} × {s}: "
                      f"{LONG_CONTEXT_SKIPS.get(a, 'excluded')}")

    reports, failures = [], []
    # resume support: skip pairs already in --out
    done = set()
    if args.out:
        try:
            with open(args.out) as f:
                prev = json.load(f)
            reports = prev.get("reports", [])
            done = {(r["arch"], r["shape"]) for r in reports}
        except (OSError, json.JSONDecodeError):
            pass

    def save():
        if args.out:
            with open(args.out, "w") as f:
                json.dump({"reports": reports, "failures": failures}, f,
                          indent=1)

    for a, s in pairs:
        if (a, s) in done:
            print(f"skip {a} × {s}: already in {args.out}")
            continue
        try:
            r, extra = dry_run_one(a, s, multi_pod=args.multi_pod,
                                   probe=args.probe)
            d = dataclasses.asdict(r)
            d["corrected"] = extra
            reports.append(d)
        except Exception as e:  # a failure here is a bug in the system
            failures.append((a, s, repr(e)))
            print(f"FAIL {a} × {s}: {e}")
        save()
    save()
    print(f"\n{len(reports)} ok, {len(failures)} failed "
          f"({'multi-pod 2x16x16' if args.multi_pod else 'single-pod 16x16'})")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
