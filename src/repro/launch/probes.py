"""Scan-corrected cost analysis ("probe" methodology).

XLA's HLOCostAnalysis counts a ``while`` body ONCE, ignoring trip count
(verified empirically — see EXPERIMENTS.md §Roofline/Methodology), and our
models scan over layers, so the raw ``cost_analysis()`` of the production
program undercounts FLOPs/bytes/collective-bytes by ~the layer count.

Fix: lower the SAME (arch × shape × mesh) with 1 and 2 UNROLLED layers
(``cfg.unroll_layers``), take the per-layer body cost as the difference,
and extrapolate:   total ≈ c(n1) + (units − n1) · (c(n2) − c(n1)).

Family notes:
* hybrid (rg-lru): unit = one (r, r, a) period; 38 layers = 12.67 units.
* encdec: encoder and decoder have equal depth (32/32) so one probe pair
  varies both together; unit = one enc+dec layer pair.
* ssm (xlstm): unit = one (m, s, m) period (12 layers = 4 units); the
  sLSTM hidden-to-hidden recurrence is a time scan whose per-step body is
  also counted once — its recurrent-matmul FLOPs are added analytically
  (``slstm_recurrent_flops``); probes use a single mLSTM chunk so the
  chunk scan has trip count 1.
* probes reuse the production mesh, so tensor-parallel collectives inside
  the body are captured and extrapolated identically.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

from repro.configs import ArchConfig, InputShape


def probe_configs(cfg: ArchConfig) -> Tuple[ArchConfig, float,
                                            ArchConfig, float, float]:
    """(cfg_n1, units1, cfg_n2, units2, total_units)."""
    if cfg.family == "hybrid":
        period = len(cfg.rglru.block_pattern)
        c1 = dataclasses.replace(cfg, num_layers=period, unroll_layers=True,
                                 remat=False)
        c2 = dataclasses.replace(cfg, num_layers=2 * period,
                                 unroll_layers=True, remat=False)
        return c1, 1.0, c2, 2.0, cfg.num_layers / period
    if cfg.family == "encdec":
        e1 = dataclasses.replace(cfg.encdec, num_encoder_layers=1)
        e2 = dataclasses.replace(cfg.encdec, num_encoder_layers=2)
        c1 = dataclasses.replace(cfg, num_layers=1, encdec=e1,
                                 unroll_layers=True, remat=False)
        c2 = dataclasses.replace(cfg, num_layers=2, encdec=e2,
                                 unroll_layers=True, remat=False)
        return c1, 1.0, c2, 2.0, float(cfg.num_layers)
    if cfg.family == "ssm":
        # xlstm already python-loops over its 12 layers (no layer scan);
        # only the INNER time scans are undercounted — corrected
        # analytically (``ssm_analytic_correction``), no probe compiles
        # (the unrolled-chunk probes blow up CPU LLVM compile times).
        return None
    c1 = dataclasses.replace(cfg, num_layers=1, unroll_layers=True,
                             remat=False)
    c2 = dataclasses.replace(cfg, num_layers=2, unroll_layers=True,
                             remat=False)
    return c1, 1.0, c2, 2.0, float(cfg.num_layers)


def slstm_recurrent_flops(cfg: ArchConfig, shape: InputShape,
                          chips: int) -> float:
    """Per-device analytic FLOPs of the sLSTM time-scan recurrent matmuls
    (4 gates × blockdiag (H, hd, hd) per step), fwd (+2x for train bwd)."""
    if cfg.family != "ssm":
        return 0.0
    n_slstm = len(cfg.xlstm.slstm_at)
    H = cfg.num_heads
    hd = cfg.d_model // H
    tokens = shape.global_batch * (shape.seq_len
                                   if shape.mode == "train" else
                                   (shape.seq_len if shape.mode == "prefill"
                                    else 1))
    per_tok = 4 * H * hd * hd * 2            # 4 gate matmuls, 2 flops/MAC
    mult = 3.0 if shape.mode == "train" else 1.0
    return n_slstm * tokens * per_tok * mult / chips


def corrected(c_full: dict, c1: dict, c2: dict, u1: float, u2: float,
              total_units: float) -> dict:
    """Extrapolate each cost field; keep full-run fields where bigger
    (head terms like the unembed/loss are inside all three, and the
    full run is a lower bound)."""
    out = {}
    for k in ("flops", "hbm_bytes", "collective_total"):
        body = max(c2[k] - c1[k], 0.0) / (u2 - u1)
        est = c1[k] + (total_units - u1) * body
        out[k] = max(est, c_full[k])
    return out


def mlstm_intra_flops(cfg: ArchConfig, shape: InputShape,
                      chunk: int = 256) -> float:
    """Analytic FLOPs of the mLSTM chunkwise cell (intra-chunk quadratic +
    carry updates), GLOBAL, fwd (+2x bwd for train). The chunk lax.scan
    body is counted once by XLA, so (nc-1)/nc of this is missing from the
    raw numbers; we return the missing share."""
    if cfg.family != "ssm":
        return 0.0
    T = shape.seq_len if shape.mode in ("train", "prefill") else 1
    if T <= chunk:
        return 0.0
    B = shape.global_batch
    H = cfg.num_heads
    pdim = int(cfg.xlstm.mlstm_proj_factor * cfg.d_model)
    phd = pdim // H
    n_mlstm = cfg.num_layers - len(cfg.xlstm.slstm_at)
    nc = -(-T // chunk)
    per_chunk = (2 * chunk * chunk * phd * 2     # S = qk^T, num = S@v
                 + 2 * chunk * phd * phd * 2)    # carry C/n updates
    total = B * H * n_mlstm * nc * per_chunk
    mult = 3.0 if shape.mode == "train" else 1.0
    return total * mult * (nc - 1) / nc


def ssm_analytic_correction(cfg: ArchConfig, shape: InputShape) -> float:
    """Global FLOPs missing from raw cost_analysis for the ssm family."""
    return (slstm_recurrent_flops(cfg, shape, 1)
            + mlstm_intra_flops(cfg, shape))
