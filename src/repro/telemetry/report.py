"""Harness counters: the compiled-program side of ``telemetry.report()``.

Everything here reads state :mod:`repro.core.scanloop` already tracks —
:data:`~repro.core.scanloop.TRACE_COUNTS` (retraces per driver family),
the program-cache hit/miss/eviction counters behind
:func:`~repro.core.scanloop.cache_stats`, and the donation flags on
every live :class:`~repro.core.scanloop.ProgramRecord` — so the answer
to "did my sweep recompile / recopy anything?" is one call away instead
of buried in CI assertions.
"""
from __future__ import annotations

from repro.core import scanloop


def harness_report() -> dict:
    """Snapshot of the scan-driver harness counters.

    ``program_cache``: :func:`scanloop.cache_stats` (hits, misses,
    inserts, evictions, size/capacity, trace counts).
    ``programs``: one entry per live :class:`ProgramRecord` —
    ``donation_honored`` is True when the driver requested donation AND
    the backend gate kept it (False on CPU, where XLA would copy
    anyway), ``cached`` marks program-cache admission (the JX1/JX4
    purity domain).
    """
    programs = []
    for rec in scanloop.registered_programs():
        programs.append({
            "name": rec.name,
            "donate_argnums": list(rec.donate_argnums),
            "donation_gated": rec.donation_gated,
            "donation_honored": bool(rec.donate_argnums)
            and not rec.donation_gated,
            "cached": rec.cache_key is not None,
        })
    return {
        "program_cache": scanloop.cache_stats(),
        "programs": programs,
    }
