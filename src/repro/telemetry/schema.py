"""Event schema + JSONL validation for the telemetry stream.

CI runs a ``train_federated --metrics out.jsonl`` smoke and then
``python -m repro.telemetry.schema out.jsonl`` — exit 0 iff every line
parses as strict JSON and every event carries the fields its driver
promises, correctly typed. No external schema library: the checks are a
plain field table, which is also the authoritative documentation of the
event format.
"""
from __future__ import annotations

import json
import sys

#: fields every event carries.
COMMON_FIELDS = {
    "type": str,           # "round"
    "driver": str,         # "fl" | "maml" | "consensus"
    "round": int,
    "live": bool,
}

#: link-billed drivers (fl / consensus) add the Eq.-(11) ledger fields.
LEDGER_FIELDS = {
    "reached": bool,
    "metric": float,
    "disagreement": float,
    "n_sl": int, "n_ul": int, "n_dl": int, "edges": int,
    "wire_bits": float,
    "joules_sl": float, "joules_ul": float, "joules_dl": float,
    "joules": float,
    "plan": str, "topology": str, "K": int,
    # async availability observables (K and 0 on lockstep rounds)
    "n_active": int, "max_age": int,
    # per-SENDER attribution: length-K lists summing to n_sl/n_ul/n_dl
    # and the per-agent Eq.-(11) joules (0.0 for a sleeping agent)
    "agent_sl": list, "agent_ul": list, "agent_dl": list,
    "agent_joules": list,
}

#: meta-training events carry losses instead of a link ledger.
MAML_FIELDS = {
    "meta_loss": float,
}


def _check(event: dict, fields: dict, errors: list, where: str):
    for name, typ in fields.items():
        if name not in event:
            errors.append(f"{where}: missing field {name!r}")
        elif typ is float:
            if not isinstance(event[name], (int, float)) \
                    or isinstance(event[name], bool):
                errors.append(f"{where}: field {name!r} is "
                              f"{type(event[name]).__name__}, not number")
        elif not isinstance(event[name], typ):
            errors.append(f"{where}: field {name!r} is "
                          f"{type(event[name]).__name__}, "
                          f"not {typ.__name__}")


def validate_event(event: dict, where: str = "event") -> list:
    """List of problems with one event dict (empty = valid)."""
    errors: list = []
    if not isinstance(event, dict):
        return [f"{where}: not a JSON object"]
    _check(event, COMMON_FIELDS, errors, where)
    driver = event.get("driver")
    if driver in ("fl", "consensus"):
        _check(event, LEDGER_FIELDS, errors, where)
    elif driver == "maml":
        _check(event, MAML_FIELDS, errors, where)
    elif isinstance(driver, str):
        errors.append(f"{where}: unknown driver {driver!r}")
    return errors


def validate_jsonl(path) -> tuple:
    """(#valid events, list of problems) for a JSONL file."""
    errors: list = []
    count = 0
    with open(path) as fh:
        for lineno, line in enumerate(fh, 1):
            line = line.strip()
            if not line:
                continue
            where = f"{path}:{lineno}"
            try:
                # parse_constant: reject NaN/Infinity — strict JSON only
                event = json.loads(line, parse_constant=lambda s: (
                    (_ for _ in ()).throw(ValueError(s))))
            except ValueError as exc:
                errors.append(f"{where}: invalid JSON ({exc})")
                continue
            errs = validate_event(event, where)
            errors.extend(errs)
            if not errs:
                count += 1
    return count, errors


def main(argv=None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    if len(argv) != 1:
        print("usage: python -m repro.telemetry.schema <events.jsonl>",
              file=sys.stderr)
        return 2
    count, errors = validate_jsonl(argv[0])
    for e in errors:
        print(e, file=sys.stderr)
    if errors:
        print(f"{argv[0]}: {len(errors)} schema problem(s)",
              file=sys.stderr)
        return 1
    if count == 0:
        print(f"{argv[0]}: no events", file=sys.stderr)
        return 1
    print(f"{argv[0]}: {count} events OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
