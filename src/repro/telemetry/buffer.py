"""Device-side metric rows and the host-side ring buffer.

The scanned drivers cannot emit anything mid-chunk on the default path —
a chunk is ONE compiled XLA program (see :mod:`repro.core.scanloop`) —
so per-round observability has to ride the scan outputs: each round
appends one fixed-shape ROW (a small dict of scalars) to the chunk's
stacked ys, and the whole per-round buffer reaches the host in the same
single device→host sync the driver already pays at the chunk boundary.
That keeps the buffered path pure (no callbacks → JX1/JX4-clean and
program-cache-admissible) and bit-parity trivial: the row computation
reads the round's state, it never feeds back into it.

Two halves live here:

* :class:`RoundRecorder` — built per engine; its :meth:`RoundRecorder.row`
  runs INSIDE the trace and records only what must be measured on
  device: exact int32 surviving-link counts per class (from the same
  plan-shaped ``engine.round_survival(t)`` the mixing consumed — never
  a re-draw, never a dense (K, K) rebuild),
  consensus disagreement ‖x_i − x̄‖, the round's eval metric, and
  reached/live flags. Everything derivable on the host — Eq.-(11)
  joules, wire bits — is priced in :meth:`RoundRecorder.finalize` in
  float64 with the LITERAL :meth:`Topology.round_comm_joules
  <repro.core.topology.Topology.round_comm_joules>` expression, so the
  summed stream reconciles EXACTLY (``==``, not ``pytest.approx``) with
  the post-hoc billing replay in :mod:`repro.rl.casestudy`.
* :class:`MetricBuffer` — the host ring buffer the finalized events land
  in; fixed capacity (oldest rounds dropped) or unbounded.
"""
from __future__ import annotations

import collections
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import energy, topology as topo_lib

#: traced per-round row fields, in emission order. ``live`` marks real
#: rounds (False = the frozen lax.cond branch after the target was hit
#: or past max_rounds — zero links, excluded from ledgers and sinks).
#: ``n_active``/``max_age`` are the async (agent-availability) health
#: observables: how many agents participated, and the oldest wire any
#: receiver is still mixing — K and 0 on lockstep rounds.
#: ``agent_sl``/``agent_ul``/``agent_dl`` are the only non-scalar rows:
#: (K,) int32 per-SENDER surviving-wire counts (``link_class[k, h]``
#: classes the h → k message, so the transmitting agent h pays) — the
#: per-agent attribution of the same aggregate ``n_*`` counts, summing
#: exactly to them, and exactly zero for an agent that slept or whose
#: every link died.
ROW_FIELDS = ("live", "reached", "metric", "disagreement",
              "n_sl", "n_ul", "n_dl", "n_active", "max_age",
              "agent_sl", "agent_ul", "agent_dl")


def consensus_disagreement(stacked):
    """Mean over agents of ‖x_i − x̄‖ (f32, full flattened tree) — the
    convergence observable of the consensus plans. Traced; runs on the
    POST-mix params so round r reports the disagreement its own mixing
    left behind."""
    leaves = jax.tree.leaves(stacked)
    K = leaves[0].shape[0]
    sq = jnp.zeros((K,), jnp.float32)
    for x in leaves:
        xf = x.astype(jnp.float32).reshape(K, -1)
        d = xf - jnp.mean(xf, axis=0, keepdims=True)
        sq = sq + jnp.sum(d * d, axis=1)
    return jnp.mean(jnp.sqrt(sq))


class RoundRecorder:
    """Per-engine row maker (traced) + event pricer (host, float64).

    Construction bakes the engine's static billing constants the same
    way the post-hoc replay computes them: ``bits`` =
    ``codec.price_bits(p.model_bits)`` (or raw ``model_bits`` uncoded)
    and the class link masks from ``topology.link_class``. Per-edge
    heterogeneous pricing (``edge_efficiency``) is refused — in-scan
    rows carry per-CLASS counts only.
    """

    def __init__(self, engine, energy_params=None):
        topo = getattr(engine, "topology", None)
        if topo is None:
            raise ValueError(
                "telemetry needs an engine built from a Topology (raw "
                "mixing matrices carry no link classes to bill)")
        if topo.edge_efficiency is not None:
            raise NotImplementedError(
                "per-edge efficiencies are priced post-hoc only; in-scan "
                "telemetry rows carry per-class counts")
        self.engine = engine
        self.topology = topo
        self.codec = engine.codec
        self.energy_params = (energy_params
                              or energy.paper_calibrated("fig3"))
        link_class = np.asarray(topo.link_class)
        # the per-class link table in the ENGINE PLAN's native survival
        # shape, so masked-round counts never touch a (K, K) buffer on
        # the plans that avoid one (rule H1 holds with dropout active):
        # (K, K) classes on dense-xla, (K, H) lane classes on
        # sparse-pallas/sharded (padding lanes -> NONE), (M, K)
        # schedule-slot classes on distributed (completion padding ->
        # NONE). Every real directed edge appears exactly once in each
        # representation, so the per-class counts are identical ints.
        if engine.plan.kind == "distributed":
            srcs, real = engine.schedule_structure()
            rows = np.arange(srcs.shape[1])[None, :]
            table = np.where(real, link_class[rows, srcs], topo_lib.NONE)
        elif engine.plan.kind in ("sparse-pallas", "sharded"):
            idx, valid = engine.lane_structure()
            rows = np.arange(idx.shape[0])[:, None]
            table = np.where(valid, link_class[rows, idx], topo_lib.NONE)
        else:
            table = link_class
        self._class_masks = {
            "SL": table == topo_lib.SL,
            "UL": table == topo_lib.UL,
            "DL": table == topo_lib.DL,
        }
        # real lanes in the plan shape — max_age reads only these (the
        # sparse plans' padding lanes and the distributed completion
        # slots never deliver, so their ages grow without meaning)
        self._real_mask = table != topo_lib.NONE
        self._static_counts = {
            "SL": int((link_class == topo_lib.SL).sum()),
            "UL": int((link_class == topo_lib.UL).sum()),
            "DL": int((link_class == topo_lib.DL).sum()),
        }
        # per-SENDER attribution: which agent each table position bills.
        # link_class[k, h] classes the h → k message, so the sender is
        # the second index — column h on dense (K, K), the neighbour
        # table idx[i, h] on the lane plans, the schedule sources
        # srcs[m, k] on distributed. None = dense (axis sum, no scatter).
        if engine.plan.kind == "distributed":
            self._sender_index = np.asarray(srcs)
        elif engine.plan.kind in ("sparse-pallas", "sharded"):
            self._sender_index = np.asarray(idx)
        else:
            self._sender_index = None
        K = topo.K
        self._static_agent_counts = {}
        for name, cls in (("SL", topo_lib.SL), ("UL", topo_lib.UL),
                          ("DL", topo_lib.DL)):
            hit = (table == cls)
            if self._sender_index is None:
                per = hit.sum(axis=0)
            else:
                per = np.zeros((K,), np.int64)
                np.add.at(per, self._sender_index, hit)
            self._static_agent_counts[name] = per.astype(np.int32)
        p = self.energy_params
        bits = p.model_bits
        if self.codec is not None:
            bits = self.codec.price_bits(bits)
        self._priced_bits = float(bits)

    # -- traced (inside the scan body) ----------------------------------

    def _per_agent(self, hit):
        """(K,) int32 per-SENDER count of the True positions of ``hit``
        (plan-shaped bool). Dense sums the receiver axis; the lane/slot
        plans scatter-add over their baked sender index."""
        if self._sender_index is None:
            return jnp.sum(hit, axis=0, dtype=jnp.int32)
        return jnp.zeros((self.topology.K,), jnp.int32).at[
            jnp.asarray(self._sender_index)].add(
            jnp.asarray(hit, jnp.int32))

    def row(self, stacked, survival, *, metric, reached, live,
            active=None, age=None):
        """One live round's row. ``survival`` is the PLAN-SHAPED
        surviving-edge operand the round's mixing ACTUALLY used — from
        ``engine.round_survival(t)``: (K, K) on dense-xla, (K, H) lanes
        on sparse-pallas/sharded, (M, K) slots on distributed (``None``
        on static graphs, where the counts are numpy constants folded
        into the program). Counts stay exact int32 in every shape, so
        the priced stream reconciles with the post-hoc replay.

        Async rounds pass ``survival=round.delivered`` (wires ACTUALLY
        shipped — Eq.-(11) bills nothing a sleeping agent didn't send),
        plus ``active=`` (K,) activity bools and ``age=`` the
        plan-shaped wire ages; lockstep rounds leave both None and the
        row reports full participation (``n_active = K, max_age = 0``).
        """
        if survival is None:
            counts = {k: jnp.int32(self._static_counts[k])
                      for k in ("SL", "UL", "DL")}
            agents = {k: jnp.asarray(self._static_agent_counts[k])
                      for k in ("SL", "UL", "DL")}
        else:
            counts, agents = {}, {}
            for k in ("SL", "UL", "DL"):
                hit = survival & jnp.asarray(self._class_masks[k])
                counts[k] = jnp.sum(hit, dtype=jnp.int32)
                agents[k] = self._per_agent(hit)
        n_active = (jnp.int32(self.topology.K) if active is None
                    else jnp.sum(jnp.asarray(active), dtype=jnp.int32))
        max_age = (jnp.int32(0) if age is None
                   else jnp.max(jnp.where(jnp.asarray(self._real_mask),
                                          jnp.asarray(age, jnp.int32),
                                          jnp.int32(0))))
        return {
            "live": jnp.asarray(live, bool),
            "reached": jnp.asarray(reached, bool),
            "metric": jnp.asarray(metric, jnp.float32),
            "disagreement": consensus_disagreement(stacked),
            "n_sl": counts["SL"], "n_ul": counts["UL"],
            "n_dl": counts["DL"],
            "n_active": n_active, "max_age": max_age,
            "agent_sl": agents["SL"], "agent_ul": agents["UL"],
            "agent_dl": agents["DL"],
        }

    def frozen_row(self):
        """The frozen ``lax.cond`` branch's row: all-zero, ``live`` off —
        pricing and ledgers skip it, so post-hit padding rounds never
        bill."""
        z32 = jnp.int32(0)
        zk = jnp.zeros((self.topology.K,), jnp.int32)
        return {"live": jnp.asarray(False), "reached": jnp.asarray(False),
                "metric": jnp.float32(0.0),
                "disagreement": jnp.float32(0.0),
                "n_sl": z32, "n_ul": z32, "n_dl": z32,
                "n_active": z32, "max_age": z32,
                "agent_sl": zk, "agent_ul": zk, "agent_dl": zk}

    # -- host (once per chunk, after the sync) --------------------------

    def price(self, n_sl: int, n_ul: int, n_dl: int) -> dict:
        """Eq.-(11) joules of one round from its surviving per-class
        counts — float64, written as the SAME Python expression
        ``Topology.round_comm_joules`` evaluates (float addition is not
        associative; matching the expression keeps the stream's sum
        bitwise equal to the post-hoc replay)."""
        p = self.energy_params
        bits = self._priced_bits
        sl_cost = energy.sidelink_cost_per_bit(p)
        return {
            "wire_bits": bits * (n_sl + n_ul + n_dl),
            "joules_sl": bits * (n_sl * sl_cost),
            "joules_ul": bits * (n_ul / p.E_UL),
            "joules_dl": bits * (n_dl / p.E_DL),
            "joules": bits * (n_sl * sl_cost
                              + n_ul / p.E_UL + n_dl / p.E_DL),
        }

    def price_agents(self, agent_sl, agent_ul, agent_dl) -> list:
        """Per-agent Eq.-(11) joules from the per-SENDER counts — the
        same literal expression as :meth:`price` per agent, so an agent
        with zero surviving sends bills exactly ``0.0`` (a sleeping
        agent transmits nothing and pays nothing)."""
        p = self.energy_params
        bits = self._priced_bits
        sl_cost = energy.sidelink_cost_per_bit(p)
        return [bits * (int(a_sl) * sl_cost
                        + int(a_ul) / p.E_UL + int(a_dl) / p.E_DL)
                for a_sl, a_ul, a_dl in zip(agent_sl, agent_ul, agent_dl)]

    def finalize(self, rows, start: int, driver: str = "fl",
                 extra: Optional[dict] = None):
        """Stacked chunk rows (device or numpy, leading axis = rounds)
        → list of host event dicts, one per round, priced in float64."""
        host = {k: np.asarray(v) for k, v in rows.items()}
        n = host["live"].shape[0]
        base = {"type": "round", "driver": driver,
                "plan": self.engine.plan.kind,
                "topology": self.topology.name, "K": int(self.topology.K)}
        if extra:
            base.update(extra)
        events = []
        for i in range(n):
            e = dict(base)
            e["round"] = int(start) + i
            e["live"] = bool(host["live"][i])
            e["reached"] = bool(host["reached"][i])
            e["metric"] = float(host["metric"][i])
            e["disagreement"] = float(host["disagreement"][i])
            n_sl = int(host["n_sl"][i])
            n_ul = int(host["n_ul"][i])
            n_dl = int(host["n_dl"][i])
            e.update(n_sl=n_sl, n_ul=n_ul, n_dl=n_dl,
                     edges=n_sl + n_ul + n_dl,
                     n_active=int(host["n_active"][i]),
                     max_age=int(host["max_age"][i]))
            e.update(self.price(n_sl, n_ul, n_dl))
            a_sl = [int(v) for v in host["agent_sl"][i]]
            a_ul = [int(v) for v in host["agent_ul"][i]]
            a_dl = [int(v) for v in host["agent_dl"][i]]
            e.update(agent_sl=a_sl, agent_ul=a_ul, agent_dl=a_dl,
                     agent_joules=self.price_agents(a_sl, a_ul, a_dl))
            events.append(e)
        return events

    def event(self, t: int, row, driver: str = "fl",
              extra: Optional[dict] = None) -> dict:
        """One round's event (the streaming callback path)."""
        single = {k: np.asarray(v)[None] for k, v in row.items()}
        return self.finalize(single, start=int(t), driver=driver,
                             extra=extra)[0]


class MetricBuffer:
    """Host-side ring buffer of finalized round events. ``capacity``
    bounds retention (oldest rounds dropped first); ``None`` keeps
    everything — the default, since one event is a few hundred bytes."""

    def __init__(self, capacity: Optional[int] = None):
        self.capacity = capacity
        self._events = collections.deque(maxlen=capacity)
        self.dropped = 0            # rounds evicted by the ring

    def append(self, event: dict):
        if (self.capacity is not None
                and len(self._events) == self.capacity):
            self.dropped += 1
        self._events.append(event)

    def extend(self, events):
        for e in events:
            self.append(e)

    def rows(self, live_only: bool = True):
        """Events in round order; ``live_only`` drops the frozen
        padding rounds (the default — they carry no information)."""
        if live_only:
            return [e for e in self._events if e.get("live", True)]
        return list(self._events)

    def __len__(self):
        return len(self._events)

    def clear(self):
        self._events.clear()
        self.dropped = 0
