"""Event sinks for :class:`repro.telemetry.Telemetry`.

A sink is anything with ``emit(event: dict)`` and (optionally)
``close()``. Sinks receive finalized HOST events only — plain dicts of
Python scalars (plus the length-K per-agent attribution lists), never
tracers — at chunk boundaries in buffered mode or per round (from the
``jax.debug.callback``) in streaming mode. Frozen padding rounds are
filtered before sinks see anything.
"""
from __future__ import annotations

import json
import sys
from typing import Optional


class MemorySink:
    """Collect events in a list (tests)."""

    def __init__(self):
        self.events = []

    def emit(self, event: dict):
        self.events.append(event)

    def close(self):
        pass


class JsonlSink:
    """One JSON object per line. The file opens lazily on the first
    event and flushes per emit, so a live ``tail -f`` of a streaming run
    sees rounds as they happen."""

    def __init__(self, path):
        self.path = path
        self._fh = None
        self.count = 0

    def emit(self, event: dict):
        if self._fh is None:
            self._fh = open(self.path, "w")
        # allow_nan=False: the emitted log must be strict JSON — a NaN
        # metric would poison downstream schema validation
        self._fh.write(json.dumps(event, allow_nan=False) + "\n")
        self._fh.flush()
        self.count += 1

    def close(self):
        if self._fh is not None:
            self._fh.close()
            self._fh = None


class ConsoleSink:
    """Compact per-round lines on a stream (default stderr, keeping
    stdout clean for driver output)."""

    def __init__(self, stream=None, every: int = 1):
        self.stream = stream if stream is not None else sys.stderr
        self.every = max(1, int(every))
        self._n = 0

    def emit(self, event: dict):
        self._n += 1
        if (self._n - 1) % self.every:
            return
        d = event.get("driver", "?")
        t = event.get("round", "?")
        if d == "maml":
            body = f"meta_loss={event.get('meta_loss', float('nan')):.6g}"
        else:
            body = (f"J={event.get('joules', 0.0):.4g}"
                    f" edges={event.get('edges', 0)}"
                    f" disagreement={event.get('disagreement', 0.0):.4g}")
        print(f"[telemetry] {d} round={t} {body}", file=self.stream)

    def close(self):
        pass
