"""repro.telemetry — per-round energy/comms/convergence metrics out of
compiled scan chunks.

The scanned drivers (``federated.run_fl_until_scan``,
``maml.maml_train_scan``, ``engine.scan_rounds``) compile ``chunk``
rounds into one XLA program and sync once per chunk — which is exactly
why nothing used to escape a chunk at round granularity. This package
restores observability without giving that up, in two modes with a
sharp contract:

**buffered** (default) — stays PURE. Each round's metrics ride the scan
outputs as one fixed-shape row (:class:`~repro.telemetry.buffer
.RoundRecorder`); the whole per-round buffer reaches the host in the
single sync the driver already pays at the chunk boundary, where it is
priced (Eq.-11 joules by UL/DL/SL class, wire bits) in float64 and
appended to the :class:`~repro.telemetry.buffer.MetricBuffer` and sinks.
No callbacks enter the trace, so buffered programs remain
program-cache-admissible — they cache under a key extended with
:meth:`Telemetry.trace_signature` — and the JX1/JX4 purity audits hold.
Round results are bit-identical to telemetry-off: rows READ the round
state, they never feed back into it.

**streaming** — opt-in liveness. The same rows are additionally emitted
round-by-round from INSIDE the chunk via ``jax.debug.callback``
(ordered), so sinks see round ``t`` while round ``t+1`` is still on
device. The callback closes over host state, so streaming programs are
impure by construction: the drivers key them OUT of
``scanloop.cached_program`` entirely (built per call, never admitted),
and the JX4 analysis rule proves no cached program ever contains a
``debug_callback``. Params/t_i/history remain bit-identical — the
callback only observes.

Sinks (:mod:`~repro.telemetry.sinks`) are pluggable: in-memory for
tests, JSONL event log (schema-checked by
``python -m repro.telemetry.schema``), console. ``report()`` adds the
harness counters — ``scanloop.TRACE_COUNTS``, program-cache
hits/misses/evictions, per-``ProgramRecord`` donation flags — so one
call answers both "what did each round cost?" and "did the sweep
recompile or recopy anything?".
"""
from __future__ import annotations

from typing import Optional

import jax
import numpy as np

from repro.core import energy
from repro.telemetry.buffer import (MetricBuffer, RoundRecorder,
                                    consensus_disagreement, ROW_FIELDS)
from repro.telemetry.report import harness_report
from repro.telemetry.schema import validate_event, validate_jsonl
from repro.telemetry.sinks import ConsoleSink, JsonlSink, MemorySink

__all__ = [
    "Telemetry", "MetricBuffer", "RoundRecorder", "ROW_FIELDS",
    "consensus_disagreement", "harness_report",
    "validate_event", "validate_jsonl",
    "MemorySink", "JsonlSink", "ConsoleSink",
]

MODES = ("buffered", "streaming")


class Telemetry:
    """Run-scoped telemetry configuration + collected events.

    One instance is threaded through a driver (or ``MTLProtocol`` /
    ``CaseStudy`` / ``train_federated``); every chunk lands its rounds
    here. ``mode`` picks the contract described in the module docstring;
    ``energy_params`` prices the ledger (defaults to the paper's Fig.-3
    calibration); ``capacity`` bounds the in-memory ring buffer.
    """

    def __init__(self, mode: str = "buffered", sinks=(),
                 energy_params=None, capacity: Optional[int] = None):
        if mode not in MODES:
            raise ValueError(f"mode must be one of {MODES}, got {mode!r}")
        self.mode = mode
        self.sinks = tuple(sinks)
        self.energy_params = (energy_params
                              or energy.paper_calibrated("fig3"))
        self.buffer = MetricBuffer(capacity)
        self._recorders: dict = {}      # id(engine) -> (engine, recorder)

    # -- identity of the traced program ---------------------------------

    @property
    def streaming(self) -> bool:
        return self.mode == "streaming"

    def trace_signature(self) -> tuple:
        """What this instance bakes into a driver's TRACED program —
        part of the ``cached_program`` key for buffered programs (their
        extra row outputs change the jaxpr, so they must not collide
        with telemetry-off entries). Streaming programs never reach a
        cache key at all: their callback closes over this instance, so
        the drivers build them per call, uncached."""
        return ("telemetry", self.mode)

    # -- recorders ------------------------------------------------------

    def recorder_for(self, engine, energy_params=None) -> RoundRecorder:
        """The per-engine :class:`RoundRecorder` (memoized by engine
        identity, so the traced row fn and the host pricer agree).
        ``energy_params`` overrides this instance's pricing for the
        recorder CREATED here (first creation wins) — orchestrators like
        ``CaseStudy`` pre-register their engines with their own billing
        constants so the stream reconciles with their post-hoc ledger."""
        hit = self._recorders.get(id(engine))
        if hit is not None and hit[0] is engine:
            return hit[1]
        rec = RoundRecorder(engine, energy_params or self.energy_params)
        self._recorders[id(engine)] = (engine, rec)
        return rec

    # -- host ingestion (once per chunk) --------------------------------

    def record_rounds(self, recorder: RoundRecorder, rows, start,
                      driver: str = "fl", extra: Optional[dict] = None):
        """Finalize one chunk's stacked rows into events: price, append
        to the buffer, and (buffered mode) emit live rounds to sinks —
        streaming mode already emitted them from inside the chunk, so
        here it only fills the buffer."""
        if any(isinstance(x, jax.core.Tracer) for x in jax.tree.leaves(rows)):
            if self.streaming:
                return []       # sinks got the rounds via the callback
            raise ValueError(
                "buffered telemetry cannot ingest rows under an outer "
                "jit (they are tracers, not values) — run the driver "
                "outside jit, or use streaming mode, whose "
                "jax.debug.callback emits from inside the trace")
        events = recorder.finalize(rows, int(start), driver=driver,
                                   extra=extra)
        self.buffer.extend(events)
        if not self.streaming:
            for e in events:
                if e["live"]:
                    self._emit(e)
        return events

    def record_maml_rounds(self, metrics, start,
                           extra: Optional[dict] = None):
        """Meta-training rounds from a chunk's stacked metrics dict
        (``meta_loss`` required; ``meta_grad_norm`` optional)."""
        if any(isinstance(x, jax.core.Tracer)
               for x in jax.tree.leaves(metrics)):
            if self.streaming:
                return []
            raise ValueError(
                "buffered telemetry cannot ingest meta metrics under an "
                "outer jit — use streaming mode")
        loss = np.asarray(metrics["meta_loss"])
        gn = metrics.get("meta_grad_norm")
        gn = None if gn is None else np.asarray(gn)
        events = []
        for i in range(loss.shape[0]):
            e = {"type": "round", "driver": "maml",
                 "round": int(start) + i, "live": True,
                 "meta_loss": float(loss[i])}
            if gn is not None:
                e["meta_grad_norm"] = float(gn[i])
            if extra:
                e.update(extra)
            events.append(e)
        self.buffer.extend(events)
        if not self.streaming:
            for e in events:
                self._emit(e)
        return events

    # -- streaming callbacks (called from INSIDE the chunk) -------------

    def stream_cb(self, recorder: RoundRecorder, driver: str = "fl",
                  extra: Optional[dict] = None):
        """Host function for ``jax.debug.callback(cb, t, row)`` — prices
        one round and emits it to the sinks as it happens. Frozen rounds
        are dropped. The buffer is NOT filled here (the chunk-boundary
        :meth:`record_rounds` does that in both modes, keeping buffer
        contents identical across modes)."""
        def cb(t, row):
            if not bool(np.asarray(row["live"])):
                return
            self._emit(recorder.event(int(np.asarray(t)), row,
                                      driver=driver, extra=extra))
        return cb

    def maml_stream_cb(self, extra: Optional[dict] = None):
        """Host function for the meta-training streaming callback:
        ``jax.debug.callback(cb, t, meta_loss, meta_grad_norm)``."""
        def cb(t, meta_loss, meta_grad_norm):
            e = {"type": "round", "driver": "maml",
                 "round": int(np.asarray(t)), "live": True,
                 "meta_loss": float(np.asarray(meta_loss)),
                 "meta_grad_norm": float(np.asarray(meta_grad_norm))}
            if extra:
                e.update(extra)
            self._emit(e)
        return cb

    def _emit(self, event: dict):
        for sink in self.sinks:
            sink.emit(event)

    # -- reading back ---------------------------------------------------

    def events(self, live_only: bool = True, driver: Optional[str] = None):
        out = self.buffer.rows(live_only=live_only)
        if driver is not None:
            out = [e for e in out if e.get("driver") == driver]
        return out

    def joules(self, driver: str = "fl",
               task_id: Optional[int] = None) -> float:
        """Summed per-round Eq.-(11) ledger over live rounds — plain
        left-to-right ``sum`` of the float64 stream, so under identical
        masks it equals the post-hoc replay
        (``ProtocolResult.fl_comm_joules_measured``) EXACTLY."""
        return sum(e["joules"] for e in self.events(driver=driver)
                   if task_id is None or e.get("task_id") == task_id)

    def report(self) -> dict:
        """Run summary + harness counters (see
        :func:`repro.telemetry.report.harness_report`)."""
        live = self.buffer.rows(live_only=True)
        out = {
            "mode": self.mode,
            "events": len(self.buffer),
            "live_rounds": len(live),
            "dropped": self.buffer.dropped,
            "joules": sum(e.get("joules", 0.0) for e in live),
            "wire_bits": sum(e.get("wire_bits", 0.0) for e in live),
        }
        out.update(harness_report())
        return out

    # -- lifecycle ------------------------------------------------------

    def reset(self):
        """Drop collected events (recorders and sinks stay)."""
        self.buffer.clear()

    def close(self):
        for sink in self.sinks:
            close = getattr(sink, "close", None)
            if close is not None:
                close()
