"""Synthetic task-conditioned token pipeline.

Offline container ⇒ no real corpora; the pipeline generates deterministic,
task-dependent token streams with genuinely learnable structure (per-task
Markov chains over the vocabulary), so FL/MAML on LM architectures has
real task commonalities to exploit — tasks share a backbone transition
matrix and differ by a per-task perturbation, mirroring the paper's
"different but related tasks" premise.

The pipeline is sharding-aware: ``sharded_batches`` places the global
batch along the mesh data axis via ``jax.device_put`` with a
NamedSharding.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class TaskTokenDistribution:
    """Per-task Markov chain: P_task = normalize(P_base + strength * D_task)."""

    vocab_size: int
    num_tasks: int
    order_strength: float = 4.0
    task_strength: float = 2.0
    seed: int = 0

    def transition(self, task_id: int) -> np.ndarray:
        rng = np.random.default_rng(self.seed)
        V = min(self.vocab_size, 256)   # active vocabulary (rest unused)
        base = rng.exponential(1.0, (V, V)) \
            + self.order_strength * np.eye(V)[:, ::-1]
        trng = np.random.default_rng(self.seed + 1000 + task_id)
        pert = trng.exponential(self.task_strength, (V, V)) \
            * (trng.random((V, V)) < 0.05)
        P = base + pert
        return P / P.sum(axis=1, keepdims=True)

    def transitions(self) -> np.ndarray:
        """(num_tasks, V, V) stacked transition tables (host-computed)."""
        return np.stack([self.transition(t) for t in range(self.num_tasks)])

    def _rollout(self, key, logP, batch: int, seq_len: int):
        V = logP.shape[-1]
        k0, k1 = jax.random.split(key)
        x0 = jax.random.randint(k0, (batch,), 0, V)

        def step(x, k):
            nxt = jax.random.categorical(k, logP[x])
            return nxt, nxt

        keys = jax.random.split(k1, seq_len)
        _, toks = jax.lax.scan(step, x0, keys)
        toks = jnp.concatenate([x0[None], toks], axis=0).T  # (B, S+1)
        return toks[:, :-1].astype(jnp.int32), toks[:, 1:].astype(jnp.int32)

    def sample(self, key, task_id: int, batch: int, seq_len: int):
        """JAX-random Markov rollout -> (tokens, labels) int32 (B, S)."""
        P = jnp.asarray(self.transition(task_id), jnp.float32)
        return self._rollout(key, jnp.log(P + 1e-9), batch, seq_len)

    def sample_traced(self, key, task_id, batch: int, seq_len: int):
        """Like :meth:`sample` but ``task_id`` may be a TRACED int (vmap /
        jit over agents): indexes a precomputed (num_tasks, V, V) stack
        instead of selecting the table host-side."""
        P_all = jnp.asarray(self.transitions(), jnp.float32)
        logP = jnp.log(P_all + 1e-9)[task_id]
        return self._rollout(key, logP, batch, seq_len)


def batches(dist: TaskTokenDistribution, task_id: int, batch: int,
            seq_len: int, *, key=None) -> Iterator:
    key = key if key is not None else jax.random.PRNGKey(0)
    while True:
        key, sk = jax.random.split(key)
        yield dist.sample(sk, task_id, batch, seq_len)


def sharded_batch(tokens, labels, mesh, data_axes=("data",)):
    """Place (B, S) arrays with batch sharded over the mesh data axes."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    spec = P(data_axes, None)
    sh = NamedSharding(mesh, spec)
    return jax.device_put(tokens, sh), jax.device_put(labels, sh)
