from repro.data.pipeline import TaskTokenDistribution, batches, sharded_batch
