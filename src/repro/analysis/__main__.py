"""CLI: ``python -m repro.analysis [--strict] [--layer ...]``."""
from __future__ import annotations

import argparse
import os
import sys


def _force_multi_device():
    """The H2 sweep needs >= 2 devices; must run BEFORE jax imports."""
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            flags + " --xla_force_host_platform_device_count=8").strip()


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="audit the engine's compiled-program invariants "
                    "(see repro.analysis module docs for the rules)")
    ap.add_argument("--strict", action="store_true",
                    help="exit 1 on any non-allowlisted finding (CI)")
    ap.add_argument("--layer", choices=("all", "lint", "jaxpr", "hlo"),
                    default="all")
    ap.add_argument("--root", default=None,
                    help="repo root for the lint layer (default: "
                         "two levels above the src/ package)")
    ap.add_argument("--allowlist", default=None,
                    help="allowlist TOML (default: the package's "
                         "allowlist.toml)")
    ap.add_argument("--h1-k", type=int, default=4096,
                    help="population size for the H1 square-buffer "
                         "audit (compile cost grows with it)")
    args = ap.parse_args(argv)

    if args.layer in ("all", "jaxpr", "hlo"):
        _force_multi_device()

    pkg_dir = os.path.dirname(os.path.abspath(__file__))
    root = args.root or os.path.dirname(os.path.dirname(
        os.path.dirname(pkg_dir)))
    allow_path = args.allowlist or os.path.join(pkg_dir, "allowlist.toml")

    from repro.analysis import (apply_allowlist, load_allowlist,
                                render_report)

    findings = []
    if args.layer in ("all", "lint"):
        from repro.analysis.lint import run_lint
        findings += run_lint(root)
    if args.layer in ("all", "jaxpr"):
        from repro.analysis.jaxpr_audit import run_jaxpr_audit
        findings += run_jaxpr_audit()
    if args.layer in ("all", "hlo"):
        from repro.analysis.hlo_audit import run_hlo_audit
        findings += run_hlo_audit(h1_k=args.h1_k)

    findings = apply_allowlist(findings, load_allowlist(allow_path))
    print(render_report(findings))
    n_open = sum(1 for f in findings if not f.allowlisted)
    n_known = len(findings) - n_open
    print(f"\n{n_open} open finding(s), {n_known} allowlisted")
    if args.strict and n_open:
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
