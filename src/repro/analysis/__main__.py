"""CLI: ``python -m repro.analysis [--strict] [--layer ...]``.

Output formats and the CI baseline-diff workflow
------------------------------------------------

``--format text`` (default) prints the human report.  ``--format json``
prints the findings as a stable JSON array — the ARTIFACT format — and
``--format sarif`` prints a SARIF 2.1.0 log for code-scanning UIs.
``--json-out PATH`` additionally writes the JSON artifact to ``PATH``
regardless of the stdout format, so CI can upload it while humans read
the text report.

The committed JSON artifact doubles as a BASELINE.  CI runs::

    python -m repro.analysis --strict \\
        --baseline src/repro/analysis/baseline.json \\
        --json-out analysis_findings.json

With ``--baseline``, strict mode fails only on findings whose
``(rule, file, message)`` key is NOT in the baseline — a PR is gated on
the findings it INTRODUCES, not on pre-existing tracked debt.  The
produced ``analysis_findings.json`` is uploaded as a CI artifact;
refreshing the committed baseline is a deliberate act: download the
artifact (or run ``--format json`` locally) and commit it as
``baseline.json`` together with the justification for any newly
baselined finding.  An unreadable or malformed baseline is a hard
error, never an empty set — see :mod:`repro.analysis.baseline`.

Under ``--strict`` the CLI also prints per-layer wall-clock timings
(the audit budget is part of CI latency) and warns on stale allowlist
entries (``added_in`` older than
:data:`repro.analysis.findings.STALE_AFTER_PRS` PRs, or missing).
"""
from __future__ import annotations

import argparse
import os
import sys
import time


def _force_multi_device():
    """The H2/C1 sweeps need >= 2 devices; must run BEFORE jax imports."""
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            flags + " --xla_force_host_platform_device_count=8").strip()


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="audit the engine's compiled-program invariants "
                    "(see repro.analysis module docs for the rules)")
    ap.add_argument("--strict", action="store_true",
                    help="exit 1 on any non-allowlisted finding (CI); "
                         "with --baseline, only on NEW ones")
    ap.add_argument("--layer",
                    choices=("all", "lint", "jaxpr", "hlo", "cost"),
                    default="all")
    ap.add_argument("--root", default=None,
                    help="repo root for the lint layer (default: "
                         "two levels above the src/ package)")
    ap.add_argument("--allowlist", default=None,
                    help="allowlist TOML (default: the package's "
                         "allowlist.toml)")
    ap.add_argument("--h1-k", type=int, default=4096,
                    help="population size for the H1 square-buffer "
                         "audit (compile cost grows with it)")
    ap.add_argument("--format", choices=("text", "json", "sarif"),
                    default="text", dest="fmt",
                    help="stdout format: human report, the JSON "
                         "artifact, or SARIF 2.1.0")
    ap.add_argument("--baseline", default=None, metavar="PATH",
                    help="committed --format json artifact; --strict "
                         "then fails only on findings NOT in it "
                         "(keyed on rule/file/message)")
    ap.add_argument("--json-out", default=None, metavar="PATH",
                    help="also write the JSON artifact here, whatever "
                         "--format prints to stdout")
    args = ap.parse_args(argv)

    if args.layer in ("all", "jaxpr", "hlo", "cost"):
        _force_multi_device()

    pkg_dir = os.path.dirname(os.path.abspath(__file__))
    root = args.root or os.path.dirname(os.path.dirname(
        os.path.dirname(pkg_dir)))
    allow_path = args.allowlist or os.path.join(pkg_dir, "allowlist.toml")

    from repro.analysis import (apply_allowlist, load_allowlist,
                                render_report)
    from repro.analysis.baseline import (findings_to_json,
                                         findings_to_sarif,
                                         load_baseline, new_findings)
    from repro.analysis.findings import dedup_findings, stale_entries

    # fail fast on a malformed baseline BEFORE paying for the audits
    baseline = (load_baseline(args.baseline)
                if args.baseline is not None else None)

    findings = []
    timings = []
    if args.layer in ("all", "lint"):
        from repro.analysis.lint import run_lint
        t0 = time.monotonic()
        findings += run_lint(root)
        timings.append(("lint", time.monotonic() - t0))
    if args.layer in ("all", "jaxpr"):
        from repro.analysis.jaxpr_audit import run_jaxpr_audit
        t0 = time.monotonic()
        findings += run_jaxpr_audit()
        timings.append(("jaxpr", time.monotonic() - t0))
    if args.layer in ("all", "hlo"):
        from repro.analysis.hlo_audit import run_hlo_audit
        t0 = time.monotonic()
        findings += run_hlo_audit(h1_k=args.h1_k)
        timings.append(("hlo", time.monotonic() - t0))
    if args.layer in ("all", "cost"):
        from repro.analysis.costmodel import run_cost_audit
        t0 = time.monotonic()
        findings += run_cost_audit()
        timings.append(("cost", time.monotonic() - t0))

    entries = load_allowlist(allow_path)
    findings = apply_allowlist(dedup_findings(findings), entries)

    if args.json_out:
        with open(args.json_out, "w", encoding="utf-8") as fh:
            fh.write(findings_to_json(findings))

    if args.fmt == "json":
        sys.stdout.write(findings_to_json(findings))
    elif args.fmt == "sarif":
        sys.stdout.write(findings_to_sarif(findings))
    else:
        print(render_report(findings))
        n_open = sum(1 for f in findings if not f.allowlisted)
        n_known = len(findings) - n_open
        print(f"\n{n_open} open finding(s), {n_known} allowlisted")

    if args.strict:
        for name, dt in timings:
            print(f"[timing] {name:5s} {dt:7.2f}s", file=sys.stderr)
        for _e, warning in stale_entries(entries):
            print(f"[stale] {warning}", file=sys.stderr)

    open_f = [f for f in findings if not f.allowlisted]
    if baseline is not None:
        fresh = new_findings(findings, baseline)
        if fresh and args.strict:
            print(f"[baseline] {len(fresh)} NEW finding(s) not in "
                  f"{args.baseline}:", file=sys.stderr)
            for f in fresh:
                print("  " + f.format(), file=sys.stderr)
            return 1
        return 0
    if args.strict and open_f:
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
