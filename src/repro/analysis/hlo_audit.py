"""Layer 2 — compiled-HLO audits (grown out of ``launch/hlo_analysis``).

H1  the sharded plan's compiled module must contain NO (K, K) buffer at
    K >= the audit threshold (default 4096): the plan exists precisely
    so no single program materializes the dense σ stack, and a square
    buffer reappearing in the ARTIFACT — whatever the Python code says —
    re-introduces the O(K²) wall the plan removes.
H2  Eq.-(11) truthfulness of the compiled artifact: on a real mesh, the
    bytes the wire collective ships (``collective_bytes`` over the SPMD
    module) must match the codec's ``model_bits`` pricing within
    scale-overhead tolerance. Pricing code that disagrees with the
    executable is exactly the "optimistic estimate" failure mode the
    reproduction's energy claims rule out.

H3  int wires stay int through the ASYNC combine: the staleness-σ path
    (availability masking + λ^age weights + the τ drop) rebuilds the
    mixing weights per round, and the tempting implementation decodes
    the int8 lanes to float FIRST so one dense f32 gather serves both
    halves — which ships/spills 4x the wire. The OPTIMIZED module of an
    async masked ``async_step`` must still gather s8 lanes (JX2 proves
    this at jaxpr level for the lockstep path; H3 proves the async
    artifact, after XLA's fusion passes, kept it).

The audits reuse the ``launch/hlo_analysis`` parser
(:func:`collective_bytes`, :func:`square_buffers`). The H2 sweep needs
a multi-device mesh — the CLI forces
``--xla_force_host_platform_device_count=8`` before jax initializes;
with fewer than 2 devices the sweep is skipped (reported as a note,
never silently).
"""
from __future__ import annotations

import re
from typing import List, Optional

from repro.analysis.findings import Finding

#: measured wire bytes may exceed the priced bytes by this ratio plus a
#: small absolute slack before H2 fires — covers per-message scale
#: vectors, layout padding, and sub-byte lane packing, not a dtype-wide
#: (2x/4x) regression.
H2_RATIO = 1.35
H2_SLACK_BYTES = 128


def audit_square_buffers(k: int = 4096, *, plan: str = "sharded",
                         num_blocks: int = 8,
                         codec: Optional[str] = "int8",
                         dropout: float = 0.3) -> List[Finding]:
    """H1: compile one ``engine.step`` round at population ``k`` and scan
    the optimized module for square buffers of dim >= ``k``.

    The audited round is the MASKED one (``dropout`` > 0 bakes a
    ``GraphProcess.dropout`` into the engine and steps with a traced
    ``t=``): since the per-edge survival convention, time-varying rounds
    draw per-LANE keeps over the (K, H) neighbour table and renormalize
    σ directly on the lanes — no dense rebuild — so the no-(K, K) claim
    must hold with dropout ACTIVE, not just on the static fast path.
    ``dropout=0.0`` audits the static program instead.
    """
    import jax
    import jax.numpy as jnp
    from repro.core import topology as topo_lib
    from repro.core.engine import ConsensusEngine
    from repro.launch.hlo_analysis import square_buffers

    findings: List[Finding] = []
    graph = (topo_lib.GraphProcess.dropout(dropout, seed=0)
             if dropout else None)
    eng = ConsensusEngine(topo_lib.ring(k), codec=codec, plan=plan,
                          num_blocks=num_blocks, graph=graph)
    meta = eng.audit_meta()
    params = {"w": jnp.zeros((k, 64), jnp.float32)}
    state = eng.init_state(params)
    key = jax.random.PRNGKey(0)
    if graph is not None:
        lowered = jax.jit(
            lambda p, st, kk, tt: eng.step(p, st, kk, t=tt)).lower(
            params, state, key, jnp.int32(0))
    else:
        lowered = jax.jit(lambda p, st, kk: eng.step(p, st, kk)).lower(
            params, state, key)
    txt = lowered.compile().as_text()
    squares = square_buffers(txt, k)
    if squares and not meta["kk_buffer"]:
        masked = "masked " if graph is not None else ""
        for dt, dim, nbytes in squares:
            findings.append(Finding(
                "H1", f"engine:{plan}", 0,
                f"({dim}, {dim}) {dt} buffer ({nbytes / 1e6:.0f} MB) in "
                f"the compiled {masked}{plan} module at K={k} — the plan "
                "must never materialize the dense sigma stack"))
    return findings


def _expected_wire_bytes(eng, params) -> Optional[float]:
    """Priced bytes ONE device ships through the wire collective for one
    ``engine.step``: per-agent wire bytes x the number of messages the
    plan's collective carries per device per round."""
    import jax
    from repro.core import consensus

    codec = eng.codec
    per_agent = jax.tree.map(lambda x: x[0], params)
    agent_bits = (codec.model_bits(per_agent) if codec is not None
                  else 32.0 * sum(x.size for x in
                                  jax.tree.leaves(per_agent)))
    if eng.plan.kind == "distributed":
        n_msgs = len(consensus.permutation_schedule(eng.mix, eng.gamma))
    elif eng.plan.kind == "sharded":
        # the all-gather result holds every agent's wire once per device
        n_msgs = eng.K
    else:
        return None
    return n_msgs * agent_bits / 8.0


def audit_collective_pricing(k: int = 8, n: int = 256) -> List[Finding]:
    """H2: compile one round per (plan x codec) on a real device mesh and
    reconcile the wire collective's bytes against the codec pricing."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import Mesh
    from repro.core import topology as topo_lib
    from repro.core.engine import ConsensusEngine
    from repro.launch.hlo_analysis import collective_bytes

    findings: List[Finding] = []
    devs = jax.devices()
    if len(devs) < 2:
        return [Finding(
            "H2", "environment", 0,
            f"skipped: {len(devs)} device(s) — the collective-pricing "
            "sweep needs a multi-device mesh (set "
            "XLA_FLAGS=--xla_force_host_platform_device_count=8, as "
            "`python -m repro.analysis` does)", allowlisted=True,
            note="environment, not code")]
    k = min(k, len(devs))
    mesh = Mesh(np.array(devs[:k]), ("agents",))
    topo = topo_lib.ring(k)
    params = {"w": jnp.zeros((k, n), jnp.float32)}
    key = jax.random.PRNGKey(0)

    for plan in ("distributed", "sharded"):
        for codec, dropout in ((None, 0.0), ("bf16", 0.0), ("int8", 0.0),
                               ("int8", 0.3)):
            kw = {"num_blocks": k} if plan == "sharded" else {}
            graph = (topo_lib.GraphProcess.dropout(dropout, seed=0)
                     if dropout else None)
            eng = ConsensusEngine(topo, codec=codec, plan=plan,
                                  mesh=mesh, graph=graph, **kw)
            meta = eng.audit_meta()
            wire_op = meta["wire_collective"]
            state = eng.init_state(params)
            if graph is not None:
                # masked rounds still ship the full static collective —
                # the distributed schedule superset permutes every slot
                # and the sharded all-gather carries every agent's wire;
                # survival only zeroes σ. Pricing stays the static
                # _expected_wire_bytes, so the H2 bound is unchanged.
                txt = jax.jit(
                    lambda p, st, kk, tt: eng.step(p, st, kk, t=tt)).lower(
                    params, state, key, jnp.int32(0)).compile().as_text()
            else:
                txt = jax.jit(lambda p, st, kk: eng.step(p, st, kk)).lower(
                    params, state, key).compile().as_text()
            measured = collective_bytes(txt).get(wire_op, 0)
            expected = _expected_wire_bytes(eng, params)
            label = (f"engine:{plan}/{codec}"
                     + (f"/p={dropout}" if dropout else ""))
            if expected is None:
                continue
            if measured == 0:
                findings.append(Finding(
                    "H2", label, 0,
                    f"no {wire_op} bytes in the compiled {plan} module — "
                    "the wire collective vanished (wrong mesh wiring?)"))
                continue
            limit = expected * H2_RATIO + H2_SLACK_BYTES
            if measured > limit:
                findings.append(Finding(
                    "H2", label, 0,
                    f"wire ships {measured} B/device/round over {wire_op} "
                    f"but Eq.-(11) prices {expected:.0f} B "
                    f"({measured / expected:.2f}x, tolerance "
                    f"{H2_RATIO}x + {H2_SLACK_BYTES} B) — the compiled "
                    "artifact sends more than the codec bills"))
    return findings


_GATHER_RE = re.compile(r"=\s*(pred|[suc]\d+|bf16|f16|f32|f64)"
                        r"\[[\d,]*\]\S*\s+gather\(")


def check_wire_lane_dtype(hlo_text: str, label: str,
                          qbits: int = 8) -> List[Finding]:
    """H3 core (pure text, so tests can seed an upcast module): the
    optimized module must contain at least one gather whose RESULT is
    the s{qbits} wire dtype — the lane gather consuming the int wire
    directly. All-float gathers mean the decode ran first and the
    combine consumed a densified f32 tensor the wire never shipped."""
    wire_dt = f"s{qbits}"
    dtypes = _GATHER_RE.findall(hlo_text)
    if not dtypes:
        return [Finding(
            "H3", label, 0,
            f"no gather in the optimized module at all — the async "
            f"combine should gather {wire_dt} wire lanes; the lane "
            "path vanished (wrong plan wiring?)")]
    if wire_dt not in dtypes:
        return [Finding(
            "H3", label, 0,
            f"every gather in the optimized module is "
            f"{sorted(set(dtypes))} — none consumes the {wire_dt} wire "
            "directly, so the staleness-σ path upcast the int lanes to "
            "float BEFORE the combine (4x the shipped/spilled bytes)")]
    return []


def audit_async_wire_lanes(k: int = 8) -> List[Finding]:
    """H3: compile one ASYNC masked ``async_step`` per int-lane plan
    (churn + dropout + τ — the maximal staleness-σ branch) and prove
    the optimized artifact still gathers s8 lanes."""
    import jax
    import jax.numpy as jnp
    from repro.core import topology as topo_lib
    from repro.core.engine import ConsensusEngine

    findings: List[Finding] = []
    topo = topo_lib.ring(k)
    params = {"w": jnp.zeros((k, 16), jnp.float32)}
    for plan in ("sparse-pallas", "sharded"):
        kw = {"num_blocks": 2} if plan == "sharded" else {}
        eng = ConsensusEngine(
            topo, codec="int8", plan=plan,
            graph=topo_lib.GraphProcess.dropout(0.3, seed=0),
            agents=topo_lib.AgentProcess.bernoulli(0.6, seed=0),
            tau=2, **kw)
        meta = eng.audit_meta()
        if not meta["int_lane_gather"]:
            continue
        state = eng.init_state(params)
        txt = jax.jit(
            lambda p, st, kk, tt, ast: eng.async_step(
                p, st, kk, t=tt, state=ast)).lower(
            params, state, jax.random.PRNGKey(0), jnp.int32(0),
            eng.init_async_state()).compile().as_text()
        findings += check_wire_lane_dtype(
            txt, f"engine:{plan}/int8/p=0.3/async",
            qbits=meta["qbits"])
    return findings


def run_hlo_audit(*, h1_k: int = 4096) -> List[Finding]:
    """The full Layer-2 pass."""
    return (audit_square_buffers(h1_k) + audit_collective_pricing()
            + audit_async_wire_lanes())
