"""Layer 2 — compiled-HLO audits (grown out of ``launch/hlo_analysis``).

H1  the sharded plan's compiled module must contain NO (K, K) buffer at
    K >= the audit threshold (default 4096): the plan exists precisely
    so no single program materializes the dense σ stack, and a square
    buffer reappearing in the ARTIFACT — whatever the Python code says —
    re-introduces the O(K²) wall the plan removes.
H2  Eq.-(11) truthfulness of the compiled artifact: on a real mesh, the
    bytes the wire collective ships (``collective_bytes`` over the SPMD
    module) must match the codec's ``model_bits`` pricing within
    scale-overhead tolerance. Pricing code that disagrees with the
    executable is exactly the "optimistic estimate" failure mode the
    reproduction's energy claims rule out.

Both audits reuse the ``launch/hlo_analysis`` parser
(:func:`collective_bytes`, :func:`square_buffers`). The H2 sweep needs
a multi-device mesh — the CLI forces
``--xla_force_host_platform_device_count=8`` before jax initializes;
with fewer than 2 devices the sweep is skipped (reported as a note,
never silently).
"""
from __future__ import annotations

from typing import List, Optional

from repro.analysis.findings import Finding

#: measured wire bytes may exceed the priced bytes by this ratio plus a
#: small absolute slack before H2 fires — covers per-message scale
#: vectors, layout padding, and sub-byte lane packing, not a dtype-wide
#: (2x/4x) regression.
H2_RATIO = 1.35
H2_SLACK_BYTES = 128


def audit_square_buffers(k: int = 4096, *, plan: str = "sharded",
                         num_blocks: int = 8,
                         codec: Optional[str] = "int8") -> List[Finding]:
    """H1: compile one ``engine.step`` round at population ``k`` and scan
    the optimized module for square buffers of dim >= ``k``."""
    import jax
    import jax.numpy as jnp
    from repro.core import topology as topo_lib
    from repro.core.engine import ConsensusEngine
    from repro.launch.hlo_analysis import square_buffers

    findings: List[Finding] = []
    eng = ConsensusEngine(topo_lib.ring(k), codec=codec, plan=plan,
                          num_blocks=num_blocks)
    meta = eng.audit_meta()
    params = {"w": jnp.zeros((k, 64), jnp.float32)}
    state = eng.init_state(params)
    key = jax.random.PRNGKey(0)
    txt = jax.jit(lambda p, st, kk: eng.step(p, st, kk)).lower(
        params, state, key).compile().as_text()
    squares = square_buffers(txt, k)
    if squares and not meta["kk_buffer"]:
        for dt, dim, nbytes in squares:
            findings.append(Finding(
                "H1", f"engine:{plan}", 0,
                f"({dim}, {dim}) {dt} buffer ({nbytes / 1e6:.0f} MB) in "
                f"the compiled {plan} module at K={k} — the plan must "
                "never materialize the dense sigma stack"))
    return findings


def _expected_wire_bytes(eng, params) -> Optional[float]:
    """Priced bytes ONE device ships through the wire collective for one
    ``engine.step``: per-agent wire bytes x the number of messages the
    plan's collective carries per device per round."""
    import jax
    from repro.core import consensus

    codec = eng.codec
    per_agent = jax.tree.map(lambda x: x[0], params)
    agent_bits = (codec.model_bits(per_agent) if codec is not None
                  else 32.0 * sum(x.size for x in
                                  jax.tree.leaves(per_agent)))
    if eng.plan.kind == "distributed":
        n_msgs = len(consensus.permutation_schedule(eng.mix, eng.gamma))
    elif eng.plan.kind == "sharded":
        # the all-gather result holds every agent's wire once per device
        n_msgs = eng.K
    else:
        return None
    return n_msgs * agent_bits / 8.0


def audit_collective_pricing(k: int = 8, n: int = 256) -> List[Finding]:
    """H2: compile one round per (plan x codec) on a real device mesh and
    reconcile the wire collective's bytes against the codec pricing."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import Mesh
    from repro.core import topology as topo_lib
    from repro.core.engine import ConsensusEngine
    from repro.launch.hlo_analysis import collective_bytes

    findings: List[Finding] = []
    devs = jax.devices()
    if len(devs) < 2:
        return [Finding(
            "H2", "environment", 0,
            f"skipped: {len(devs)} device(s) — the collective-pricing "
            "sweep needs a multi-device mesh (set "
            "XLA_FLAGS=--xla_force_host_platform_device_count=8, as "
            "`python -m repro.analysis` does)", allowlisted=True,
            note="environment, not code")]
    k = min(k, len(devs))
    mesh = Mesh(np.array(devs[:k]), ("agents",))
    topo = topo_lib.ring(k)
    params = {"w": jnp.zeros((k, n), jnp.float32)}
    key = jax.random.PRNGKey(0)

    for plan in ("distributed", "sharded"):
        for codec in (None, "bf16", "int8"):
            kw = {"num_blocks": k} if plan == "sharded" else {}
            eng = ConsensusEngine(topo, codec=codec, plan=plan,
                                  mesh=mesh, **kw)
            meta = eng.audit_meta()
            wire_op = meta["wire_collective"]
            state = eng.init_state(params)
            txt = jax.jit(lambda p, st, kk: eng.step(p, st, kk)).lower(
                params, state, key).compile().as_text()
            measured = collective_bytes(txt).get(wire_op, 0)
            expected = _expected_wire_bytes(eng, params)
            label = f"engine:{plan}/{codec}"
            if expected is None:
                continue
            if measured == 0:
                findings.append(Finding(
                    "H2", label, 0,
                    f"no {wire_op} bytes in the compiled {plan} module — "
                    "the wire collective vanished (wrong mesh wiring?)"))
                continue
            limit = expected * H2_RATIO + H2_SLACK_BYTES
            if measured > limit:
                findings.append(Finding(
                    "H2", label, 0,
                    f"wire ships {measured} B/device/round over {wire_op} "
                    f"but Eq.-(11) prices {expected:.0f} B "
                    f"({measured / expected:.2f}x, tolerance "
                    f"{H2_RATIO}x + {H2_SLACK_BYTES} B) — the compiled "
                    "artifact sends more than the codec bills"))
    return findings


def run_hlo_audit(*, h1_k: int = 4096) -> List[Finding]:
    """The full Layer-2 pass."""
    return audit_square_buffers(h1_k) + audit_collective_pricing()
