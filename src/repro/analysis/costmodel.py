"""Layer 4 — the C-layer: a static Eq.-(11)/compute cost-model prover.

The paper's claims are a ledger: joules per round = bits-on-the-wire x
per-class link efficiencies (Eq. 11) plus compute cycles. PRs 7-9 made
the MEASURED ledger exact (telemetry rows reconcile ``==`` with the
host billing replay); this layer proves, before a single round runs,
that the COMPILED artifact and the static prediction agree with both —
a :class:`StaticLedger` per audited program, checked three ways:

C1  static bytes vs codec bits vs measured rows. Two halves:
    (a) the wire collective's bytes in the optimized SPMD module must
        bracket ``codec.model_bits`` pricing (lower bound: nothing the
        ledger bills is missing from the wire; upper bound: H2's
        scale-overhead tolerance), and
    (b) a host replay of the engine's blessed survival/availability
        streams (the SAME draws the in-scan rounds consume, bit for
        bit) must reconcile EXACTLY (``==``) with a short
        telemetry-buffered ``scan_rounds`` run — per-round per-class
        counts, ``wire_bits``, and float64 Eq.-(11) joules — for every
        plan x codec, async configs included.
C2  static round FLOPs: ``compiled.cost_analysis()`` of one round body
    at the case-study shape must stay within a coarse tolerance of the
    counted reference (the dense mixing's 2·K²·N per leaf) — a 4x drift
    means the compute half of the energy model no longer describes the
    executable.
C3  no collective outside the ledger: every collective op in an audited
    module either carries the plan's priced wire payload
    (``audit_meta()['priced_collectives']``), is recognizable control
    plane (integer PRNG/mask/schedule traffic, or per-agent scalars),
    or is allowlisted. Unaccounted payload movement is exactly the
    "free" communication Eq. (11) would silently not bill.

Pure-text helpers (:func:`collective_instances`,
:func:`collective_ledger`, :func:`check_round_flops`) take HLO text /
numbers so tests can seed violations without a mesh; the ``audit_*``
entry points compile live engines the same way ``hlo_audit`` does and
need the CLI's forced 8-device host platform for the mesh sweeps.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

from repro.analysis.findings import Finding

#: HLO dtypes that never carry wire payload: PRNG keys, schedule
#: indices, masks, loop counters. An int-codec's lanes are s8/u8 (or
#: s4/u4 packed) and floats are payload — neither appears here.
CONTROL_DTYPES = frozenset(
    {"pred", "u16", "u32", "u64", "s16", "s32", "s64"})

#: a non-priced collective whose total payload is at most this many
#: bytes PER AGENT is control plane (per-agent availability bits, lane
#: weights, scale scalars), not an unbilled model wire.
CONTROL_BYTES_PER_AGENT = 8

#: C1's HLO-side tolerance mirrors H2: the priced collective may carry
#: scale vectors / layout padding over the codec's bits, never a
#: dtype-wide regression — and never LESS than the bits the ledger
#: bills.
C1_RATIO = 1.35
C1_SLACK_BYTES = 128

#: C2's tolerance is deliberately coarse: XLA's flop counter and the
#: hand count disagree on fusion bookkeeping by a few percent; a real
#: model drift (wrong mixing order, a dense rebuild) lands at >= K/2 x.
C2_RATIO = 4.0
C2_SLACK_FLOPS = 1024.0

_COLLECTIVE_RE = re.compile(
    r"=\s*(\S+)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(-start|-done)?\(")


@dataclasses.dataclass
class StaticLedger:
    """What a program moves and computes per round, statically.

    The HLO half (``priced_bytes``/``control_bytes``/``unpriced_bytes``
    and ``flops``) comes from the optimized module; the replay half
    (``rounds``) from the host survival/availability streams — each
    entry one round's exact per-class counts, ``wire_bits``, and
    float64 Eq.-(11) ``joules``.
    """

    label: str
    plan: Optional[str] = None
    codec: Optional[str] = None
    priced_bytes: Dict[str, int] = dataclasses.field(default_factory=dict)
    control_bytes: int = 0
    unpriced_bytes: int = 0
    flops: Optional[float] = None
    rounds: List[dict] = dataclasses.field(default_factory=list)

    @property
    def wire_bytes(self) -> int:
        return sum(self.priced_bytes.values())

    @property
    def total_joules(self) -> float:
        total = 0.0
        for r in self.rounds:
            total += r["joules"]
        return total


# -- pure-text HLO side (no jax) ------------------------------------------


def collective_instances(hlo_text: str):
    """Every collective op in an (optimized) HLO module as
    ``(kind, result_shape, payload_bytes, dtypes)`` — ``-done`` halves
    of async pairs are skipped so each transfer counts once."""
    from repro.launch.hlo_analysis import _DTYPE_BYTES, _SHAPE_RE

    out = []
    for m in _COLLECTIVE_RE.finditer(hlo_text):
        shape, kind, suffix = m.group(1), m.group(2), m.group(3)
        if suffix == "-done":
            continue
        nbytes, dtypes = 0, set()
        for sm in _SHAPE_RE.finditer(shape):
            dt, dims = sm.group(1), sm.group(2)
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * _DTYPE_BYTES.get(dt, 4)
            dtypes.add(dt)
        out.append((kind, shape.split("{")[0], nbytes, frozenset(dtypes)))
    return out


def collective_ledger(meta: dict, hlo_text: str,
                      label: str) -> Tuple[StaticLedger, List[Finding]]:
    """C3 over one module: classify every collective as priced (the
    plan's wire), control plane, or a finding. ``meta`` is
    ``engine.audit_meta()`` (or ``{}`` for plain registered programs,
    where NO payload collective is priced)."""
    priced = meta.get("priced_collectives") or {}
    k = meta.get("K") or 0
    ledger = StaticLedger(label=label, plan=meta.get("plan"),
                          codec=meta.get("codec"))
    findings: List[Finding] = []
    for kind, shape, nbytes, dtypes in collective_instances(hlo_text):
        if kind in priced:
            ledger.priced_bytes[kind] = (
                ledger.priced_bytes.get(kind, 0) + nbytes)
        elif dtypes <= CONTROL_DTYPES or nbytes <= CONTROL_BYTES_PER_AGENT * k:
            ledger.control_bytes += nbytes
        else:
            ledger.unpriced_bytes += nbytes
            findings.append(Finding(
                "C3", label, 0,
                f"{kind} ships {nbytes} B of {shape} outside the "
                f"Eq.-(11) ledger — the plan prices "
                f"{sorted(priced) or 'no collectives'}; map this "
                "transfer to a link class in audit_meta() or allowlist "
                "it with a note"))
    return ledger, findings


def check_round_flops(measured: Optional[float], expected: float,
                      label: str) -> List[Finding]:
    """C2 core: the compiled round's flop count must bracket the
    counted reference within :data:`C2_RATIO`."""
    if measured is None:
        return [Finding(
            "C2", label, 0,
            "skipped: compiled.cost_analysis() reported no flops on "
            "this backend — the compute half of the ledger cannot be "
            "proven here", allowlisted=True,
            note="environment, not code")]
    if (measured > expected * C2_RATIO + C2_SLACK_FLOPS
            or measured < expected / C2_RATIO):
        return [Finding(
            "C2", label, 0,
            f"compiled round body costs {measured:.0f} flops but the "
            f"counted reference (2·K²·N per leaf) expects "
            f"{expected:.0f} ({measured / max(expected, 1.0):.2f}x, "
            f"tolerance {C2_RATIO}x) — the compute model no longer "
            "describes the executable")]
    return []


# -- host replay side (C1b) -----------------------------------------------


def static_round_counts(engine, rounds: int, *, t0: int = 0,
                        energy_params=None,
                        expected_bits: Optional[float] = None) -> List[dict]:
    """The static per-round ledger rows: replay the engine's blessed
    host streams (``topology.dropout`` for link fades,
    ``availability_stream`` for agent churn — bit-identical with the
    in-scan draws) and bill each round with the LITERAL
    ``Topology.round_comm_joules`` expression. A wire bills iff its
    link survived AND both endpoints were awake — exactly what the
    recorder's ``survival=delivered`` rows count.

    ``expected_bits`` overrides the codec-priced per-message bits in
    ``wire_bits`` (the seeded-mispricing hook for C1 tests); joules
    always come from the topology's own codec-aware pricing.
    """
    import numpy as np
    from repro.core import energy, topology as topo_lib

    topo = getattr(engine, "topology", None)
    if topo is None:
        raise ValueError(
            f"static_round_counts needs an engine built from a "
            f"Topology, but this {engine.plan.kind!r} engine came from "
            "a raw mix matrix (no link classes to bill) — construct it "
            "from e.g. topology.ring(K)")
    ep = energy_params or energy.paper_calibrated("fig3")
    total = t0 + rounds
    graph = engine.graph
    if graph.kind == "dropout":
        adjs = [np.asarray(t_r.adjacency, bool) for t_r in
                topo_lib.dropout(topo, graph.p, seed=graph.seed,
                                 rounds=total)]
    elif graph.kind == "schedule":
        masks = np.asarray(graph.masks, bool)
        adjs = [np.asarray(topo.adjacency, bool) & masks[t % len(masks)]
                for t in range(total)]
    else:
        adjs = [np.asarray(topo.adjacency, bool)] * total
    if engine.agents is not None:
        acts = np.asarray(topo_lib.availability_stream(
            engine.agents, topo.K, total), bool)
    else:
        acts = np.ones((total, topo.K), bool)
    bits = float(ep.model_bits)
    if engine.codec is not None:
        bits = float(engine.codec.price_bits(bits))
    if expected_bits is not None:
        bits = float(expected_bits)
    link_class = np.asarray(topo.link_class)
    rows = []
    for t in range(t0, total):
        m = adjs[t] & acts[t][:, None] & acts[t][None, :]
        billed = topo_lib.Topology(
            f"{topo.name}~billed", m,
            np.where(m, link_class, topo_lib.NONE))
        counts = billed.links_per_round()
        n_sl, n_ul, n_dl = counts["SL"], counts["UL"], counts["DL"]
        rows.append({
            "round": t, "n_sl": n_sl, "n_ul": n_ul, "n_dl": n_dl,
            "n_active": int(acts[t].sum()),
            "wire_bits": bits * (n_sl + n_ul + n_dl),
            "joules": billed.round_comm_joules(ep, codec=engine.codec),
        })
    return rows


def reconcile_engine_run(engine, *, rounds: int, label: str,
                         energy_params=None,
                         expected_bits: Optional[float] = None,
                         n: int = 16) -> List[Finding]:
    """C1b: drive ``rounds`` buffered-telemetry rounds and reconcile
    every measured row against :func:`static_round_counts` — counts
    and ``n_active`` as exact ints, ``wire_bits`` and joules as exact
    float64 (``==``, never approx: both sides evaluate the same
    literal expression on the same replayed draws)."""
    import jax
    import jax.numpy as jnp
    from repro import telemetry as telemetry_lib
    from repro.core import energy

    ep = energy_params or energy.paper_calibrated("fig3")
    static_rows = static_round_counts(engine, rounds, energy_params=ep,
                                      expected_bits=expected_bits)
    k = engine.K
    key = jax.random.PRNGKey(7)
    params = {"w": jax.random.normal(key, (k, n))}
    tel = telemetry_lib.Telemetry(energy_params=ep)
    engine.scan_rounds(params, rounds=rounds, telemetry=tel,
                       keys=jax.random.split(jax.random.PRNGKey(11),
                                             rounds))
    events = tel.events(driver="consensus")
    findings: List[Finding] = []
    if len(events) != rounds:
        return [Finding(
            "C1", label, 0,
            f"telemetry produced {len(events)} round events for a "
            f"{rounds}-round run — the measured ledger is incomplete, "
            "nothing to reconcile")]
    for s, e in zip(static_rows, events):
        t = s["round"]
        for f in ("n_sl", "n_ul", "n_dl", "n_active"):
            if e[f] != s[f]:
                findings.append(Finding(
                    "C1", label, t,
                    f"round {t}: static replay predicts {f}={s[f]} but "
                    f"the measured row says {e[f]} — the compiled "
                    "round moved wires the host streams did not "
                    "predict (or vice versa)"))
        if e["wire_bits"] != s["wire_bits"]:
            findings.append(Finding(
                "C1", label, t,
                f"round {t}: static ledger prices "
                f"{s['wire_bits']:.0f} wire bits but the measured row "
                f"bills {e['wire_bits']:.0f} — the per-message bits "
                "disagree with codec.price_bits(model_bits)"))
        if e["joules"] != s["joules"]:
            findings.append(Finding(
                "C1", label, t,
                f"round {t}: static Eq.-(11) replay bills "
                f"{s['joules']!r} J but the stream recorded "
                f"{e['joules']!r} J — the float64 pricing expressions "
                "diverged"))
    return findings


# -- live audits (the CLI's cost layer) -----------------------------------


def audit_round_flops(k: int = 12, widths=(64, 8)) -> List[Finding]:
    """C2 on the case-study shape (the 12-robot fleet of
    ``repro.rl.casestudy``): one uncompressed dense-xla round, XLA's
    own flop count vs the counted 2·K²·N-per-leaf reference."""
    import jax
    import jax.numpy as jnp
    from repro.core import topology as topo_lib
    from repro.core.engine import ConsensusEngine

    eng = ConsensusEngine(topo_lib.ring(k), plan="dense-xla")
    params = {f"w{i}": jnp.zeros((k, n), jnp.float32)
              for i, n in enumerate(widths)}
    compiled = jax.jit(lambda p: eng.step(p)[0]).lower(params).compile()
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    measured = None if ca is None else ca.get("flops")
    expected = float(sum(2 * k * k * n for n in widths))
    return check_round_flops(measured, expected,
                             f"engine:dense-xla/K={k} (case study)")


def audit_mesh_ledgers(k: int = 8, n: int = 64) -> List[Finding]:
    """C1a + C3 on real-mesh modules: for each SPMD plan x codec,
    compile one masked round on the forced 8-device host mesh, build
    its :func:`collective_ledger`, and bracket the priced bytes
    against ``codec.model_bits``."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import Mesh
    from repro.analysis.hlo_audit import _expected_wire_bytes
    from repro.core import topology as topo_lib
    from repro.core.engine import ConsensusEngine

    devs = jax.devices()
    if len(devs) < 2:
        return [Finding(
            "C1", "environment", 0,
            f"skipped: {len(devs)} device(s) — the mesh ledger sweep "
            "needs a multi-device mesh (set "
            "XLA_FLAGS=--xla_force_host_platform_device_count=8, as "
            "`python -m repro.analysis` does)", allowlisted=True,
            note="environment, not code")]
    k = min(k, len(devs))
    mesh = Mesh(np.array(devs[:k]), ("agents",))
    topo = topo_lib.ring(k)
    params = {"w": jnp.zeros((k, n), jnp.float32)}
    key = jax.random.PRNGKey(0)
    findings: List[Finding] = []
    for plan in ("sharded", "distributed"):
        for codec in (None, "int8"):
            kw = {"num_blocks": k} if plan == "sharded" else {}
            eng = ConsensusEngine(
                topo, codec=codec, plan=plan, mesh=mesh,
                graph=topo_lib.GraphProcess.dropout(0.3, seed=0), **kw)
            meta = eng.audit_meta()
            state = eng.init_state(params)
            txt = jax.jit(
                lambda p, st, kk, tt: eng.step(p, st, kk, t=tt)).lower(
                params, state, key, jnp.int32(0)).compile().as_text()
            label = f"engine:{plan}/{codec}/p=0.3"
            ledger, c3 = collective_ledger(meta, txt, label)
            findings += c3
            expected = _expected_wire_bytes(eng, params)
            measured = ledger.wire_bytes
            if expected is None:
                continue
            if measured < expected:
                findings.append(Finding(
                    "C1", label, 0,
                    f"the priced {sorted(meta['priced_collectives'])} "
                    f"collective ships only {measured} B/device/round "
                    f"but Eq.-(11) bills {expected:.0f} B — the ledger "
                    "charges for bytes the artifact never moves"))
            elif measured > expected * C1_RATIO + C1_SLACK_BYTES:
                findings.append(Finding(
                    "C1", label, 0,
                    f"the priced collective ships {measured} "
                    f"B/device/round but Eq.-(11) bills only "
                    f"{expected:.0f} B ({measured / expected:.2f}x, "
                    f"tolerance {C1_RATIO}x + {C1_SLACK_BYTES} B) — "
                    "the artifact moves more than the codec prices"))
    return findings


def audit_registered_collectives(records=None) -> List[Finding]:
    """C3 over every registered program: compile each cached chunk
    program from its recorded abstract args and demand a
    collective-free (or fully control-plane) module — the chunked
    drivers run per-device; any payload collective here is data
    movement no ledger bills."""
    import jax
    from repro.core import scanloop

    if records is None:
        records = scanloop.registered_programs()
    findings: List[Finding] = []
    for rec in records:
        if rec.abstract_args is None:
            continue
        try:
            txt = jax.jit(
                rec.fn, donate_argnums=rec.donate_argnums,
                **rec.jit_kwargs).lower(
                *rec.abstract_args).compile().as_text()
        except Exception as exc:   # pragma: no cover - lowering quirks
            findings.append(Finding(
                "C3", rec.name, 0,
                f"skipped: could not recompile from recorded abstract "
                f"args ({type(exc).__name__}: {exc}) — the module's "
                "collectives were not audited", allowlisted=True,
                note="recompile failure, not a ledger violation"))
            continue
        _, c3 = collective_ledger({}, txt, rec.name)
        findings += c3
    return findings


def audit_ledger_reconciliation(rounds: int = 3,
                                k: int = 8) -> List[Finding]:
    """C1b matrix: every plan x {uncoded, int8:b64}, dropout active,
    plus one async config (bernoulli churn + staleness bound) per
    plan."""
    from repro.core import topology as topo_lib
    from repro.core.engine import ConsensusEngine

    topo = topo_lib.ring(k)
    findings: List[Finding] = []
    for plan, kw in (("dense-xla", {}), ("sparse-pallas", {}),
                     ("sharded", {"num_blocks": 4}), ("distributed", {})):
        for codec in (None, "int8:b64"):
            eng = ConsensusEngine(
                topo, codec=codec, plan=plan,
                graph=topo_lib.GraphProcess.dropout(0.3, seed=0), **kw)
            findings += reconcile_engine_run(
                eng, rounds=rounds,
                label=f"engine:{plan}/{codec or 'f32'}/p=0.3")
        eng = ConsensusEngine(
            topo, codec="int8:b64", plan=plan,
            graph=topo_lib.GraphProcess.dropout(0.3, seed=0),
            agents=topo_lib.AgentProcess.bernoulli(0.6, seed=1),
            tau=2, staleness_decay=0.9, **kw)
        findings += reconcile_engine_run(
            eng, rounds=rounds,
            label=f"engine:{plan}/int8:b64/p=0.3/async")
    return findings


def run_cost_audit(*, reconcile: bool = True,
                   records=None) -> List[Finding]:
    """The full C-layer pass. ``reconcile=False`` skips the (slow)
    C1b scan_rounds matrix — the HLO-side checks still run."""
    from repro.core import scanloop

    if records is None and not scanloop.registered_programs():
        # standalone `--layer cost` runs: populate the registry the
        # same way the jaxpr layer does
        from repro.analysis.jaxpr_audit import _tiny_drivers
        _tiny_drivers()
    findings = audit_round_flops()
    findings += audit_mesh_ledgers()
    findings += audit_registered_collectives(records)
    if reconcile:
        findings += audit_ledger_reconciliation()
    return findings
