"""``repro.analysis`` — static/compiled-artifact audits of the engine's
program invariants.

The reproduction's correctness story rests on invariants no test
exercises directly: scanned and host drivers dispatch the same cached
program (so that program must be PURE), int wires stay integer lanes
through the Eq.-(6) combine, donated buffers are actually donated, and
Eq.-(11) joules bill exactly the bytes the compiled module ships. This
package turns those from ROADMAP prose into checked properties, in
four layers:

* **Layer 1 — jaxpr** (:mod:`.jaxpr_audit`): walks the jaxprs/compiled
  executables of the programs in ``scanloop.registered_programs()`` and
  of ``engine.scan_rounds`` for all four plans.
  Rules: JX1 (no data callbacks in cached programs), JX2 (no
  decode-then-combine on sparse/sharded wires), JX3 (donation honored
  in the executable's ``input_output_alias``), JX4 (no streaming
  telemetry ``debug_callback`` in cached programs — streaming
  drivers build per call, uncached), JX5 (the ``AsyncState`` carry is
  donated through chunk programs — an undonated staleness carry doubles
  the resident model memory every chunk).
* **Layer 2 — HLO** (:mod:`.hlo_audit`): parses optimized modules with
  the ``launch/hlo_analysis`` collective/shape parser.
  Rules: H1 (no (K, K) buffer at K >= 4096 on the sharded plan), H2
  (collective bytes match ``codec.model_bits`` pricing within
  tolerance), H3 (the async staleness-σ path still gathers the int8
  wire lanes in the OPTIMIZED module — no decode-before-combine upcast
  sneaks in after XLA's fusion passes).
* **Layer 3 — AST lint** (:mod:`.lint`): repo-specific rules over
  ``src/`` and ``benchmarks/``.
  Rules: R1 (survival draws via ``topology.survival_mask`` only), R2
  (no naked ``jax.jit`` in ``core/``/``rl/``), R3 (median-of-N timing
  asserts), R4 (no unpriced transmissions), R5 (``own()`` donated
  carries), R6 (every ``raise`` in ``core/``/``rl/``/``launch/`` names
  the offending input and a nearest alternative).
* **Layer 4 — cost model** (:mod:`.costmodel`): the STATIC ENERGY
  LEDGER — prices every collective in the compiled modules and
  reconciles Eq.-(11) predictions against a telemetry-buffered run.
  Rules: C1 (static wire bytes/joules reconcile with the codec pricing
  AND with measured telemetry rows, exactly, per plan x codec, async
  included), C2 (static round FLOPs match a counted reference on the
  case-study shape), C3 (no collective outside the ledger: every
  collective in a compiled module is either the priced wire, control
  plane, or a finding).

Usage::

    PYTHONPATH=src python -m repro.analysis            # report
    PYTHONPATH=src python -m repro.analysis --strict   # CI: exit 1 on
                                                       # any finding not
                                                       # in the allowlist
    PYTHONPATH=src python -m repro.analysis --layer lint   # fast subset
    PYTHONPATH=src python -m repro.analysis --h1-k 512     # cheap H1
    PYTHONPATH=src python -m repro.analysis --format json  # artifact
    PYTHONPATH=src python -m repro.analysis --strict \\
        --baseline src/repro/analysis/baseline.json    # fail on NEW
                                                       # findings only

Findings carry a rule ID and ``file:line``; intentional exceptions live
in ``src/repro/analysis/allowlist.toml`` with a justification and an
``added_in`` PR each — tracked debt, not silence, and ``--strict``
warns once an entry is 4+ PRs old. The baseline-diff CI workflow
(``--format json`` artifacts, ``--baseline``) is documented in
:mod:`repro.analysis.__main__`. The CLI forces
``--xla_force_host_platform_device_count=8`` into ``XLA_FLAGS`` before
jax initializes so the H2/C1 mesh sweeps run on CPU CI. See ROADMAP.md
"Invariants & how they're enforced" for the invariant -> rule map.

Importing this package (and running the lint layer) does NOT import
jax; the jaxpr/HLO/cost layers import it lazily.
"""
from repro.analysis.findings import (Finding, apply_allowlist,
                                     dedup_findings, load_allowlist,
                                     render_report, stale_entries)

__all__ = ["Finding", "apply_allowlist", "dedup_findings",
           "load_allowlist", "render_report", "stale_entries"]
