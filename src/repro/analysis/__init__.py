"""``repro.analysis`` — static/compiled-artifact audits of the engine's
program invariants.

The reproduction's correctness story rests on invariants no test
exercises directly: scanned and host drivers dispatch the same cached
program (so that program must be PURE), int wires stay integer lanes
through the Eq.-(6) combine, donated buffers are actually donated, and
Eq.-(11) joules bill exactly the bytes the compiled module ships. This
package turns those from ROADMAP prose into checked properties, in
three layers:

* **Layer 1 — jaxpr** (:mod:`.jaxpr_audit`): walks the jaxprs/compiled
  executables of the programs in ``scanloop.registered_programs()`` and
  of ``engine.scan_rounds`` for all four plans.
  Rules: JX1 (no data callbacks in cached programs), JX2 (no
  decode-then-combine on sparse/sharded wires), JX3 (donation honored
  in the executable's ``input_output_alias``), JX4 (no streaming
  telemetry ``debug_callback`` in cached programs — streaming
  drivers build per call, uncached).
* **Layer 2 — HLO** (:mod:`.hlo_audit`): parses optimized modules with
  the ``launch/hlo_analysis`` collective/shape parser.
  Rules: H1 (no (K, K) buffer at K >= 4096 on the sharded plan), H2
  (collective bytes match ``codec.model_bits`` pricing within
  tolerance).
* **Layer 3 — AST lint** (:mod:`.lint`): repo-specific rules over
  ``src/`` and ``benchmarks/``.
  Rules: R1 (survival draws via ``topology.survival_mask`` only), R2
  (no naked ``jax.jit`` in ``core/``/``rl/``), R3 (median-of-N timing
  asserts), R4 (no unpriced transmissions), R5 (``own()`` donated
  carries).

Usage::

    PYTHONPATH=src python -m repro.analysis            # report
    PYTHONPATH=src python -m repro.analysis --strict   # CI: exit 1 on
                                                       # any finding not
                                                       # in the allowlist
    PYTHONPATH=src python -m repro.analysis --layer lint   # fast subset
    PYTHONPATH=src python -m repro.analysis --h1-k 512     # cheap H1

Findings carry a rule ID and ``file:line``; intentional exceptions live
in ``src/repro/analysis/allowlist.toml`` with a justification each —
tracked debt, not silence. The CLI forces
``--xla_force_host_platform_device_count=8`` into ``XLA_FLAGS`` before
jax initializes so the H2 mesh sweep runs on CPU CI. See ROADMAP.md
"Invariants & how they're enforced" for the invariant -> rule map.

Importing this package (and running the lint layer) does NOT import
jax; the jaxpr/HLO layers import it lazily.
"""
from repro.analysis.findings import (Finding, apply_allowlist,
                                     load_allowlist, render_report)

__all__ = ["Finding", "apply_allowlist", "load_allowlist",
           "render_report"]
