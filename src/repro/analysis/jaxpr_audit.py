"""Layer 1 — jaxpr audits of the engine's compiled round programs.

Four rules, each checked against the ARTIFACT the drivers dispatch
(the registered program's own jaxpr / compiled executable, re-derived
from :func:`repro.core.scanloop.registered_programs`), never against a
reimplementation:

JX1  no data callbacks inside a CACHED program: ``pure_callback`` /
     ``io_callback`` primitives in a program admitted to
     ``scanloop.cached_program`` replay one host state against many
     cache hits (the impure-sampler fallback is exactly the case the
     drivers must never cache — see ``_scan_round_program``).
JX2  no decode-then-combine on the sparse/sharded paths: the Eq.-(6)
     combine must gather WIRE lanes (int8/int4 stay integer through the
     gather; dequant fuses inside the combine). A ``gather`` whose
     operand derives from an int-wire→float ``convert_element_type`` —
     or from a ``scatter`` densification of the wire (the top-k
     reconstruction) — mixes a dense f32 tensor the wire never shipped.
JX3  donation honored: for every program built with ``donate_argnums``,
     the compiled executable's ``input_output_alias`` directive must
     cover every donated leaf — XLA drops donation SILENTLY (no Python
     warning) when shapes fail to pair up, doubling peak memory.
JX4  no streaming telemetry inside a CACHED program: a
     ``debug_callback`` (the ``repro.telemetry`` streaming emitter)
     closes over host sink state, so the drivers must build streaming
     programs per call and never admit them to the cache — a cached
     one would replay a dead run's sinks against every later hit.
     (Buffered telemetry rows are pure scan outputs and cache fine.)
JX5  async carry donated: any registered program whose recorded
     abstract args hold an ``AsyncState`` (the per-agent clocks and
     per-lane wire ages the async protocol threads chunk to chunk)
     must list that argument in ``donate_argnums`` — it is a carry
     exactly like the params, and a dropped alias keeps two
     generations of the availability bookkeeping alive through every
     dispatch.

``run_jaxpr_audit()`` drives tiny FL/MAML configurations through the
real chunked drivers — telemetry off, buffered, and streaming — to
populate the program registry, audits every registered record, then
traces ``engine.scan_rounds`` for all four plans (× int8 / top-k wires
on the sparse/sharded paths) for JX1/JX2.
"""
from __future__ import annotations

from typing import List, Optional

from repro.analysis.findings import Finding

#: JX4 domain: the streaming-telemetry emitter primitive.
_STREAMING_PRIMS = {"debug_callback"}
_CALLBACK_PRIMS = {"pure_callback", "debug_callback", "io_callback"}
_INT_WIRE_DTYPES = {"int4", "uint4", "int8", "uint8"}
_PASSTHROUGH = {"reshape", "transpose", "broadcast_in_dim", "squeeze",
                "slice", "rev", "copy", "expand_dims",
                # elementwise: a decoded wire scaled/shifted is STILL the
                # decoded wire — density and derivation are preserved
                "mul", "add", "sub", "div", "neg", "max", "min",
                "select_n"}


# ---------------------------------------------------------------------------
# jaxpr plumbing
# ---------------------------------------------------------------------------

def _source_of(eqn):
    """(file, line) of the user frame that emitted ``eqn`` (best effort).
    Paths are cut down to repo-relative (``src/...``) so findings — and
    the committed baseline keyed on them — match across checkouts."""
    try:
        from jax._src import source_info_util
        fr = source_info_util.user_frame(eqn.source_info)
        if fr is not None:
            f = fr.file_name.replace("\\", "/")
            if "/src/repro/" in f:
                f = "src/repro/" + f.rsplit("/src/repro/", 1)[1]
            return f, int(fr.start_line)
    except Exception:
        pass
    return "<jaxpr>", 0


def _sub_closed_jaxprs(eqn):
    """Every (Closed)Jaxpr in ``eqn.params`` (incl. inside lists/tuples),
    duck-typed: a raw Jaxpr has ``eqns``, a ClosedJaxpr wraps one."""
    subs = []
    for v in eqn.params.values():
        vals = v if isinstance(v, (list, tuple)) else (v,)
        for x in vals:
            if hasattr(x, "eqns") or (hasattr(x, "jaxpr")
                                      and hasattr(x.jaxpr, "eqns")):
                subs.append(x)
    return subs


def _closed(x):
    return x.jaxpr if hasattr(x, "jaxpr") else x


def iter_eqns(closed_jaxpr):
    """Depth-first over every equation, through all nested jaxprs."""
    stack = [_closed(closed_jaxpr)]
    while stack:
        j = stack.pop()
        for eqn in j.eqns:
            yield eqn
            for sub in _sub_closed_jaxprs(eqn):
                stack.append(_closed(sub))


def find_callbacks(closed_jaxpr):
    """[(primitive name, file, line)] of every host-callback equation."""
    out = []
    for eqn in iter_eqns(closed_jaxpr):
        name = eqn.primitive.name
        if name in _CALLBACK_PRIMS or any(p in name
                                          for p in _CALLBACK_PRIMS):
            f, ln = _source_of(eqn)
            out.append((name, f, ln))
    return out


def _dtype_name(v) -> Optional[str]:
    aval = getattr(v, "aval", None)
    dt = getattr(aval, "dtype", None)
    return None if dt is None else getattr(dt, "name", str(dt))


def _is_float(v) -> bool:
    name = _dtype_name(v) or ""
    return name.startswith(("float", "bfloat"))


def find_decode_then_combine(closed_jaxpr):
    """[(kind, file, line)] where a ``gather`` consumes a tensor derived
    from an int-wire upcast or a scatter densification — the
    decode-then-combine regression class (rule JX2).

    Taint sources: ``convert_element_type`` int8/int4 → float, and
    ``scatter*``. Taint propagates through shape/layout ops and into
    sub-jaxprs whose invars map positionally onto the call's operands
    (pjit / scan / cond branches / custom-derivative calls).
    """
    found = []

    def hit(v, tainted):
        # Literals (inline constants) are unhashable and never tainted
        return not hasattr(v, "val") and v in tainted

    def walk(jaxpr, tainted):
        j = _closed(jaxpr)
        tainted = set(tainted)
        for eqn in j.eqns:
            name = eqn.primitive.name
            ins = eqn.invars
            if name == "convert_element_type":
                src = _dtype_name(ins[0]) or ""
                if src in _INT_WIRE_DTYPES and _is_float(eqn.outvars[0]):
                    tainted.update(eqn.outvars)
                    continue
                if hit(ins[0], tainted) and _is_float(eqn.outvars[0]):
                    tainted.update(eqn.outvars)
                continue
            if name.startswith("scatter"):
                tainted.update(eqn.outvars)
                continue
            if name == "gather" and ins and hit(ins[0], tainted):
                f, ln = _source_of(eqn)
                found.append(("gather-of-decoded-wire", f, ln))
                continue
            if name in _PASSTHROUGH:
                if any(hit(v, tainted) for v in ins):
                    tainted.update(eqn.outvars)
                continue
            subs = _sub_closed_jaxprs(eqn)
            if subs:
                operands = ins[1:] if name == "cond" else ins
                for sub in subs:
                    inner = _closed(sub)
                    seed = set()
                    if len(inner.invars) == len(operands):
                        seed = {iv for iv, ov in zip(inner.invars,
                                                     operands)
                                if hit(ov, tainted)}
                    out_taint = walk(sub, seed)
                    if len(inner.outvars) == len(eqn.outvars):
                        tainted.update(
                            ov for iv, ov in zip(inner.outvars,
                                                 eqn.outvars)
                            if iv in out_taint)
        return tainted

    walk(closed_jaxpr, set())
    return found


def has_int_lane_gather(closed_jaxpr) -> bool:
    """True when some combine gathers integer WIRE lanes directly."""
    for eqn in iter_eqns(closed_jaxpr):
        if eqn.primitive.name == "gather" and eqn.invars:
            if (_dtype_name(eqn.invars[0]) or "") in _INT_WIRE_DTYPES:
                return True
    return False


# ---------------------------------------------------------------------------
# compiled-executable donation check
# ---------------------------------------------------------------------------

def alias_param_indices(hlo_text: str):
    """Parameter indices covered by the module's ``input_output_alias``
    directive (balanced-brace segment; a non-greedy regex truncates at
    the first inner ``}``). Empty set when the directive is absent —
    which is exactly how XLA reports a silently dropped donation."""
    import re
    i = hlo_text.find("input_output_alias=")
    if i < 0:
        return set()
    j = hlo_text.index("{", i)
    depth, k = 0, j
    for k in range(j, len(hlo_text)):
        if hlo_text[k] == "{":
            depth += 1
        elif hlo_text[k] == "}":
            depth -= 1
            if depth == 0:
                break
    seg = hlo_text[j:k + 1]
    return {int(m.group(1)) for m in re.finditer(r"\((\d+)\s*,", seg)}


def check_donation(fn, donate_argnums, abstract_args, *,
                   jit_kwargs=None, label="program") -> List[Finding]:
    """JX3 for one program: compile ``fn`` WITH donation requested and
    verify the executable aliases every donated leaf."""
    import jax
    findings: List[Finding] = []
    jitted = jax.jit(fn, donate_argnums=tuple(donate_argnums),
                     **(jit_kwargs or {}))
    try:
        txt = jitted.lower(*abstract_args).compile().as_text()
    except Exception as e:                  # pragma: no cover - diagnostics
        return [Finding("JX3", label, 0,
                        f"could not compile for donation check: {e}")]
    aliased = alias_param_indices(txt)
    starts, n = [], 0
    for a in abstract_args:
        starts.append(n)
        n += len(jax.tree.leaves(a))
    for argnum in donate_argnums:
        leaves = len(jax.tree.leaves(abstract_args[argnum]))
        missing = [i for i in range(starts[argnum], starts[argnum] + leaves)
                   if i not in aliased]
        if missing:
            findings.append(Finding(
                "JX3", label, 0,
                f"donation dropped: arg {argnum} of {label} donates "
                f"{leaves} leaves but {len(missing)} have no "
                "input_output_alias in the compiled executable"))
    return findings


def _holds_async_state(tree) -> bool:
    """True iff ``tree`` contains an :class:`AsyncState` anywhere —
    ``scanloop._abstractify`` maps leaves but PRESERVES container
    structure (NamedTuples included), so the recorded abstract args
    still carry the carry's type."""
    from repro.core.engine import AsyncState
    if isinstance(tree, AsyncState):
        return True
    if isinstance(tree, dict):
        return any(_holds_async_state(v) for v in tree.values())
    if isinstance(tree, (list, tuple)):
        return any(_holds_async_state(v) for v in tree)
    return False


def check_async_state_donated(rec) -> List[Finding]:
    """JX5 for one program record: every argument that carries an
    ``AsyncState`` (the async protocol's per-agent clocks + per-lane
    wire ages) must be in ``donate_argnums``. The state is a carry
    exactly like the params — threaded chunk to chunk — so a dropped
    donation keeps BOTH generations of (clock, age) buffers alive
    through every dispatch, silently doubling the async bookkeeping's
    footprint at fleet scale."""
    if rec.abstract_args is None:
        return []
    donated = set(rec.donate_argnums or ())
    findings: List[Finding] = []
    for i, arg in enumerate(rec.abstract_args):
        if _holds_async_state(arg) and i not in donated:
            findings.append(Finding(
                "JX5", rec.name, 0,
                f"arg {i} of {rec.name!r} carries the AsyncState "
                f"(clock, age) but donate_argnums={tuple(sorted(donated))} "
                "leaves it undonated — the async carry must alias "
                "through the chunk like the params (add the arg to "
                "donate_argnums in the driver's donating_jit)"))
    return findings


# ---------------------------------------------------------------------------
# registry + engine sweeps
# ---------------------------------------------------------------------------

def audit_registered_programs(records=None) -> List[Finding]:
    """JX1 + JX3 + JX4 + JX5 over the scanloop program registry."""
    import jax
    from repro.core import scanloop
    findings: List[Finding] = []
    records = (scanloop.registered_programs()
               if records is None else list(records))
    for rec in records:
        if rec.abstract_args is None:
            continue                       # never dispatched: nothing baked
        try:
            closed = jax.make_jaxpr(rec.fn)(*rec.abstract_args)
        except Exception as e:             # pragma: no cover - diagnostics
            findings.append(Finding(
                "JX1", rec.name, 0, f"could not re-trace for audit: {e}"))
            continue
        if rec.cache_key is not None:
            for prim, f, ln in find_callbacks(closed):
                if any(s in prim for s in _STREAMING_PRIMS):
                    findings.append(Finding(
                        "JX4", f, ln,
                        f"{prim} inside CACHED program {rec.name!r} "
                        f"(cache key {rec.cache_key[0]!r}) — streaming "
                        "telemetry callbacks close over host sinks, so "
                        "the drivers must build streaming programs per "
                        "call and never admit them to "
                        "scanloop.cached_program"))
                else:
                    findings.append(Finding(
                        "JX1", f, ln,
                        f"{prim} inside CACHED program {rec.name!r} "
                        f"(cache key {rec.cache_key[0]!r}) — impure "
                        "programs must never be admitted to "
                        "scanloop.cached_program"))
        if rec.donate_argnums:
            findings.extend(check_donation(
                rec.fn, rec.donate_argnums, rec.abstract_args,
                jit_kwargs=rec.jit_kwargs, label=rec.name))
        findings.extend(check_async_state_donated(rec))
    return findings


def _tiny_drivers():
    """Drive minimal FL + MAML configurations through the REAL chunked
    drivers so the registry holds the programs tier-1 actually runs —
    telemetry off, buffered (cached, must audit clean), and streaming
    (never cached, so JX4 stays silent on the live tree)."""
    import jax
    import jax.numpy as jnp
    from repro import telemetry as telemetry_lib
    from repro.core import federated, maml, topology as topo_lib
    from repro.core.engine import ConsensusEngine

    K, D = 4, 8

    def loss_fn(p, batch):
        pred = batch["x"] @ p["w"] + p["b"]
        return jnp.mean((pred - batch["y"]) ** 2)

    def sample_batches(key, t):
        ks = jax.random.split(key, K)

        def one(k):
            x = jax.random.normal(k, (4, D))
            return {"x": x, "y": jnp.sum(x, -1, keepdims=True)}

        return jax.vmap(one)(ks)

    def target_fn(stacked):
        # input-DEPENDENT on purpose: a constant target would trip the
        # traceable() impurity fallback, the driver would build the
        # program per call instead of admitting it to the cache, and
        # the registry would hold NOTHING for this audit to check —
        # the async chunk (JX5's whole surface) included
        d = jnp.mean(jnp.asarray(jax.tree.leaves(stacked)[0],
                                 jnp.float32))
        return d < jnp.float32(-1e9), d

    params = {"w": jnp.zeros((D, 1)), "b": jnp.zeros((1,))}
    stacked = jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (K,) + x.shape), params)
    engine = ConsensusEngine(topo_lib.ring(K), codec="int8")
    federated.run_fl_until_scan(
        loss_fn, stacked, sample_batches, engine, 0.1,
        target_fn=target_fn, max_rounds=2, key=jax.random.PRNGKey(0),
        chunk=2)
    # async FL: churn + dropout + staleness bound through the REAL
    # chunked driver — the availability draws, staleness weights, and
    # per-agent freezes run in-scan and must audit callback-free like
    # every other cached program
    async_engine = ConsensusEngine(
        topo_lib.ring(K), codec="int8",
        graph=topo_lib.GraphProcess.dropout(0.3, seed=0),
        agents=topo_lib.AgentProcess.bernoulli(0.6, seed=0), tau=2)
    federated.run_fl_until_scan(
        loss_fn, stacked, sample_batches, async_engine, 0.1,
        target_fn=target_fn, max_rounds=2, key=jax.random.PRNGKey(0),
        chunk=2, telemetry=telemetry_lib.Telemetry())
    # buffered telemetry: rows ride the ys, program is cached under the
    # telemetry-extended key and must re-audit callback-free (JX1/JX4)
    federated.run_fl_until_scan(
        loss_fn, stacked, sample_batches, engine, 0.1,
        target_fn=target_fn, max_rounds=2, key=jax.random.PRNGKey(0),
        chunk=2, telemetry=telemetry_lib.Telemetry())
    # streaming telemetry: the debug_callback program is built per call
    # and never admitted to the cache — nothing for JX4 to flag
    federated.run_fl_until_scan(
        loss_fn, stacked, sample_batches, engine, 0.1,
        target_fn=target_fn, max_rounds=2, key=jax.random.PRNGKey(0),
        chunk=2, telemetry=telemetry_lib.Telemetry(mode="streaming"))

    def sample_tasks(key, t):
        ks = jax.random.split(key, 2)

        def one(k):
            x = jax.random.normal(k, (3, 4, D))
            return {"x": x, "y": jnp.sum(x, -1, keepdims=True)}

        sup = jax.vmap(one)(jax.random.split(ks[0], 2))
        qry = jax.vmap(one)(jax.random.split(ks[1], 2))
        return sup, qry

    maml.maml_train_scan(loss_fn, params, sample_tasks, rounds=2,
                         inner_lr=0.1, outer_lr=0.1, chunk=2,
                         key=jax.random.PRNGKey(1))


def audit_engine_plans(k: int = 8) -> List[Finding]:
    """JX1 + JX2 over ``engine.scan_rounds`` jaxprs for all four plans
    (int8 and top-k wires on the sparse/sharded paths), each audited
    both static and MASKED (a ``GraphProcess.dropout`` engine — the
    in-scan per-lane survival draws and σ renormalization must stay
    callback-free and keep the integer wire integer through the
    combine), plus one ASYNC configuration per plan (``AgentProcess``
    churn + staleness bound τ — availability draws, staleness weights,
    and the per-agent freeze are in-scan too)."""
    import jax
    import jax.numpy as jnp
    from repro.core import topology as topo_lib
    from repro.core.engine import ConsensusEngine, PLAN_KINDS

    findings: List[Finding] = []
    topo = topo_lib.ring(k)
    params = {"w": jnp.zeros((k, 16), jnp.float32)}

    for plan in PLAN_KINDS:
        codecs = ("int8", "topk:0.25") if plan in ("sparse-pallas",
                                                   "sharded") else (None,)
        configs = [(c, p, False) for c in codecs for p in (0.0, 0.3)]
        # one async config per plan: churn + dropout + τ, the maximal
        # in-scan branch (staleness weights, renormalized float σ, age
        # clocks, per-agent freeze)
        configs.append((codecs[0], 0.3, True))
        for codec, dropout, asynchronous in configs:
            kw = {"num_blocks": 2} if plan == "sharded" else {}
            graph = (topo_lib.GraphProcess.dropout(dropout, seed=0)
                     if dropout else None)
            agents = (topo_lib.AgentProcess.bernoulli(0.6, seed=0)
                      if asynchronous else None)
            eng = ConsensusEngine(topo, codec=codec, plan=plan,
                                  graph=graph, agents=agents,
                                  tau=2 if asynchronous else None, **kw)
            meta = eng.audit_meta()
            label = (f"scan_rounds[{plan}/{codec}"
                     + (f"/p={dropout}" if dropout else "")
                     + ("/async]" if asynchronous else "]"))
            closed = jax.make_jaxpr(
                lambda p: eng.scan_rounds(p, rounds=2))(params)
            for prim, f, ln in find_callbacks(closed):
                rule = ("JX4" if any(s in prim for s in _STREAMING_PRIMS)
                        else "JX1")
                findings.append(Finding(
                    rule, f, ln,
                    f"{prim} inside {label} — scan_rounds programs are "
                    "cached by the chunked drivers and must stay pure"))
            if not meta["int_lane_gather"]:
                continue
            for kind, f, ln in find_decode_then_combine(closed):
                findings.append(Finding(
                    "JX2", f, ln,
                    f"decode-then-combine ({kind}) in {label}: the "
                    "Eq.-(6) combine consumes a densified f32 tensor "
                    "the wire never shipped"))
            if meta["qbits"] is not None and not has_int_lane_gather(closed):
                findings.append(Finding(
                    "JX2", f"engine:{plan}", 0,
                    f"no integer-lane gather in {label}: the int wire "
                    "was decoded before the combine"))
    return findings


def run_jaxpr_audit() -> List[Finding]:
    """The full Layer-1 pass (drives tiny drivers first)."""
    _tiny_drivers()
    return audit_registered_programs() + audit_engine_plans()
