"""Finding serialization + the CI baseline diff.

``python -m repro.analysis --format json`` emits findings as a stable
JSON array; ``--format sarif`` emits a minimal SARIF 2.1.0 log (one
run, one rule per distinct rule ID) for code-scanning UIs. A committed
``--format json`` artifact doubles as the BASELINE: with
``--baseline findings.json``, strict mode fails only on findings whose
``(rule, file, message)`` key is NOT in the baseline — line numbers
drift with unrelated edits, so they are deliberately not part of the
identity.

No jax imports here: the baseline diff must run (and fail fast on a
malformed baseline file) before any backend initialization.
"""
from __future__ import annotations

import json
from typing import Iterable, List, Set, Tuple

from repro.analysis.findings import Finding

#: what identifies a finding across runs — everything except the line
#: number (drifts) and the allowlist marking (derived, not observed).
Key = Tuple[str, str, str]


def finding_key(f: Finding) -> Key:
    return (f.rule, f.file, f.message)


def findings_to_json(findings: Iterable[Finding]) -> str:
    """Stable JSON array of finding dicts (the artifact format)."""
    return json.dumps(
        [{"rule": f.rule, "file": f.file, "line": f.line,
          "message": f.message, "allowlisted": f.allowlisted,
          "note": f.note} for f in findings],
        indent=2, sort_keys=True) + "\n"


def findings_to_sarif(findings: Iterable[Finding]) -> str:
    """Minimal SARIF 2.1.0: one run, one driver, allowlisted findings
    carry level "note", open ones "error"."""
    findings = list(findings)
    rules = sorted({f.rule for f in findings})
    results = []
    for f in findings:
        results.append({
            "ruleId": f.rule,
            "level": "note" if f.allowlisted else "error",
            "message": {"text": f.message
                        + (f" [allowlisted: {f.note}]" if f.allowlisted
                           else "")},
            "locations": [{"physicalLocation": {
                "artifactLocation": {"uri": f.file},
                "region": {"startLine": max(f.line, 1)},
            }}],
        })
    log = {
        "$schema": ("https://raw.githubusercontent.com/oasis-tcs/"
                    "sarif-spec/master/Schemata/sarif-schema-2.1.0.json"),
        "version": "2.1.0",
        "runs": [{
            "tool": {"driver": {
                "name": "repro.analysis",
                "rules": [{"id": r} for r in rules],
            }},
            "results": results,
        }],
    }
    return json.dumps(log, indent=2, sort_keys=True) + "\n"


def load_baseline(path: str) -> Set[Key]:
    """The ``(rule, file, message)`` key set of a committed
    ``--format json`` artifact. Raises on unreadable/malformed input —
    a silently-empty baseline would re-fail CI on every known
    finding."""
    with open(path, "r", encoding="utf-8") as fh:
        data = json.load(fh)
    if not isinstance(data, list):
        raise ValueError(
            f"baseline {path!r} holds a {type(data).__name__}, not the "
            "JSON array `--format json` writes — regenerate it with "
            "`python -m repro.analysis --format json`")
    keys: Set[Key] = set()
    for i, d in enumerate(data):
        try:
            keys.add((str(d["rule"]), str(d["file"]), str(d["message"])))
        except (TypeError, KeyError) as exc:
            raise ValueError(
                f"baseline {path!r} entry {i} is missing {exc} — every "
                "entry needs rule/file/message; regenerate the file "
                "with `python -m repro.analysis --format json`")
    return keys


def new_findings(findings: Iterable[Finding],
                 baseline: Set[Key]) -> List[Finding]:
    """Open findings NOT present in the baseline — what a baselined
    strict run fails on."""
    return [f for f in findings
            if not f.allowlisted and finding_key(f) not in baseline]
