"""Finding records, the allowlist, and report formatting.

Every audit layer (:mod:`.lint`, :mod:`.jaxpr_audit`, :mod:`.hlo_audit`)
returns a list of :class:`Finding`; the CLI merges them, marks the ones
covered by ``analysis/allowlist.toml`` (known debt is TRACKED with a
justification, never silenced), prints the report, and — under
``--strict`` — fails on any finding left unallowlisted.

The allowlist is an array of ``[[allow]]`` tables::

    [[allow]]
    rule     = "R4"                          # required: the rule ID
    file     = "src/repro/core/consensus.py" # required: path suffix/glob
    match    = "ppermute"                    # optional: message substring
    note     = "why this is intentional"     # required by convention
    added_in = 6                             # required: the PR that
                                             # admitted this debt

Allowlist entries EXPIRE: debt older than
:data:`STALE_AFTER_PRS` PRs (relative to :data:`CURRENT_PR`) is
reported as a warning by ``--strict`` — tracked debt that nobody
revisits is just silence with paperwork. :func:`stale_entries` computes
the list; the CLI prints it.

This module intentionally imports no jax — the lint layer (and the CLI
argument parsing) must run before any backend initialization.
"""
from __future__ import annotations

import dataclasses
import fnmatch
import re
from typing import Iterable, List, Optional, Tuple

#: the PR this tree is at — bump when a PR lands new allowlist entries.
CURRENT_PR = 10

#: an allowlist entry older than this many PRs is stale: ``--strict``
#: warns (the debt stays allowlisted — expiry nags, it does not break).
STALE_AFTER_PRS = 4


@dataclasses.dataclass
class Finding:
    """One audit finding: rule ID + file:line + human message."""

    rule: str
    file: str
    line: int
    message: str
    allowlisted: bool = False
    note: str = ""

    def format(self) -> str:
        tail = f"  [allowlisted: {self.note}]" if self.allowlisted else ""
        return f"{self.rule:4s} {self.file}:{self.line}  {self.message}{tail}"


# ---------------------------------------------------------------------------
# allowlist: TOML loading (stdlib tomllib when present, else a minimal
# subset parser — the container pins Python 3.10 and new deps are off
# the table, and the allowlist grammar above is tiny)
# ---------------------------------------------------------------------------

_STRING_RE = re.compile(r'"((?:[^"\\]|\\.)*)"')


_ESCAPES = {"\\\\": "\\", '\\"': '"', "\\n": "\n", "\\t": "\t"}


def _parse_scalar(v: str, lineno: int):
    m = _STRING_RE.match(v)
    if m:
        trailing = v[m.end():].split("#", 1)[0].strip()
        if trailing:
            raise ValueError(
                f"allowlist line {lineno}: trailing garbage {trailing!r} "
                f"after the string value — one scalar per key")
        s = m.group(1)
        # hand-rolled escapes: unicode_escape would mangle non-ASCII text
        for esc, ch in _ESCAPES.items():
            s = s.replace(esc, ch)
        return s
    if v.startswith('"'):
        raise ValueError(
            f"allowlist line {lineno}: unterminated string {v!r} — "
            f"close the quote")
    v = v.split("#", 1)[0].strip()
    if v in ("true", "false"):
        return v == "true"
    try:
        return int(v)
    except ValueError:
        try:
            return float(v)
        except ValueError:
            raise ValueError(
                f"allowlist line {lineno}: {v!r} is not a supported "
                f"scalar — quote strings, or use an int/float/bool")


#: sentinel: inside a table that is not ours — keys skipped, not errors
_OTHER_TABLE = object()

_HEADER_RE = re.compile(r"^\[\[?[A-Za-z0-9_.\-]+\]\]?$")


def parse_toml_min(text: str) -> dict:
    """Parse the ``[[allow]]``-tables-of-scalars TOML subset.

    Malformed input RAISES ``ValueError`` with the line number — a
    typo'd allowlist entry that silently parsed to nothing would
    un-track debt without anyone noticing (the failure mode this
    replaced). Tables other than ``[[allow]]`` are still skipped
    whole: the file may carry unrelated sections."""
    entries: List[dict] = []
    cur = None
    for lineno, raw in enumerate(text.splitlines(), 1):
        line = raw.strip()
        if not line or line.startswith("#"):
            continue
        if line == "[[allow]]":
            cur = {}
            entries.append(cur)
            continue
        if line.startswith("["):
            if not _HEADER_RE.match(line):
                raise ValueError(
                    f"allowlist line {lineno}: malformed table header "
                    f"{line!r} — expected [[allow]] or a [name] table")
            cur = _OTHER_TABLE   # some other table: not ours, skip
            continue
        if cur is _OTHER_TABLE:
            continue
        if cur is None:
            raise ValueError(
                f"allowlist line {lineno}: {line!r} outside any table — "
                f"every key belongs under an [[allow]] header")
        k, eq, v = line.partition("=")
        k = k.strip()
        if not eq or not k:
            raise ValueError(
                f"allowlist line {lineno}: {line!r} is not a `key = "
                f"value` pair inside [[allow]]")
        cur[k] = _parse_scalar(v.strip(), lineno)
    return {"allow": entries}


def load_allowlist(path: str) -> List[dict]:
    """The ``allow`` entries of ``path`` ([] when the file is absent)."""
    try:
        with open(path, "rb") as f:
            raw = f.read()
    except OSError:
        return []
    try:
        import tomllib
        return list(tomllib.loads(raw.decode("utf-8")).get("allow", []))
    except ImportError:
        return list(parse_toml_min(raw.decode("utf-8"))["allow"])


def stale_entries(entries: Iterable[dict],
                  current_pr: int = CURRENT_PR,
                  stale_after: int = STALE_AFTER_PRS
                  ) -> List[Tuple[dict, str]]:
    """(entry, warning) pairs for allowlist debt due a revisit: entries
    whose ``added_in`` is ``stale_after``+ PRs old, or missing (undated
    debt can never expire, which defeats the point)."""
    out: List[Tuple[dict, str]] = []
    for e in entries:
        added = e.get("added_in")
        label = f"{e.get('rule', '?')} @ {e.get('file', '*')}"
        if added is None:
            out.append((e, f"allowlist entry {label} has no added_in= "
                           "PR — undated debt never expires; date it"))
        elif current_pr - int(added) >= stale_after:
            out.append((e, f"allowlist entry {label} is "
                           f"{current_pr - int(added)} PRs old "
                           f"(added_in={added}, now PR {current_pr}) — "
                           "revisit: fix the finding or re-justify the "
                           "debt"))
    return out


def dedup_findings(findings: Iterable[Finding]) -> List[Finding]:
    """Drop exact duplicates (same rule/file/line/message), keeping
    first occurrence order — layers legitimately overlap (e.g. a
    registry program audited under two cache keys) and a doubled
    finding reads as two bugs."""
    seen = set()
    out: List[Finding] = []
    for f in findings:
        key = (f.rule, f.file, f.line, f.message)
        if key not in seen:
            seen.add(key)
            out.append(f)
    return out


def _file_matches(finding_file: str, pattern: str) -> bool:
    f = finding_file.replace("\\", "/")
    return (f == pattern or f.endswith("/" + pattern) or f.endswith(pattern)
            or fnmatch.fnmatch(f, pattern))


def apply_allowlist(findings: Iterable[Finding],
                    entries: Iterable[dict]) -> List[Finding]:
    """Mark findings covered by an allowlist entry (first match wins)."""
    findings = list(findings)
    for f in findings:
        for e in entries:
            if e.get("rule") != f.rule:
                continue
            if not _file_matches(f.file, str(e.get("file", "*"))):
                continue
            needle = e.get("match")
            if needle and str(needle) not in f.message:
                continue
            f.allowlisted = True
            f.note = str(e.get("note", ""))
            break
    return findings


def render_report(findings: List[Finding]) -> str:
    """Human report: open findings first, allowlisted debt after."""
    open_f = [f for f in findings if not f.allowlisted]
    known = [f for f in findings if f.allowlisted]
    lines = []
    if open_f:
        lines.append(f"== {len(open_f)} finding(s) ==")
        lines += [f.format() for f in open_f]
    if known:
        lines.append(f"== {len(known)} allowlisted (tracked debt) ==")
        lines += [f.format() for f in known]
    if not findings:
        lines.append("no findings")
    return "\n".join(lines)
