"""Finding records, the allowlist, and report formatting.

Every audit layer (:mod:`.lint`, :mod:`.jaxpr_audit`, :mod:`.hlo_audit`)
returns a list of :class:`Finding`; the CLI merges them, marks the ones
covered by ``analysis/allowlist.toml`` (known debt is TRACKED with a
justification, never silenced), prints the report, and — under
``--strict`` — fails on any finding left unallowlisted.

The allowlist is an array of ``[[allow]]`` tables::

    [[allow]]
    rule  = "R4"                          # required: the rule ID
    file  = "src/repro/core/consensus.py" # required: path suffix/glob
    match = "ppermute"                    # optional: message substring
    note  = "why this is intentional"     # required by convention

This module intentionally imports no jax — the lint layer (and the CLI
argument parsing) must run before any backend initialization.
"""
from __future__ import annotations

import dataclasses
import fnmatch
import re
from typing import Iterable, List, Optional


@dataclasses.dataclass
class Finding:
    """One audit finding: rule ID + file:line + human message."""

    rule: str
    file: str
    line: int
    message: str
    allowlisted: bool = False
    note: str = ""

    def format(self) -> str:
        tail = f"  [allowlisted: {self.note}]" if self.allowlisted else ""
        return f"{self.rule:4s} {self.file}:{self.line}  {self.message}{tail}"


# ---------------------------------------------------------------------------
# allowlist: TOML loading (stdlib tomllib when present, else a minimal
# subset parser — the container pins Python 3.10 and new deps are off
# the table, and the allowlist grammar above is tiny)
# ---------------------------------------------------------------------------

_STRING_RE = re.compile(r'"((?:[^"\\]|\\.)*)"')


_ESCAPES = {"\\\\": "\\", '\\"': '"', "\\n": "\n", "\\t": "\t"}


def _parse_scalar(v: str):
    m = _STRING_RE.match(v)
    if m:
        s = m.group(1)
        # hand-rolled escapes: unicode_escape would mangle non-ASCII text
        for esc, ch in _ESCAPES.items():
            s = s.replace(esc, ch)
        return s
    v = v.split("#", 1)[0].strip()
    if v in ("true", "false"):
        return v == "true"
    try:
        return int(v)
    except ValueError:
        try:
            return float(v)
        except ValueError:
            return v


def parse_toml_min(text: str) -> dict:
    """Parse the ``[[allow]]``-tables-of-scalars TOML subset."""
    entries: List[dict] = []
    cur: Optional[dict] = None
    for raw in text.splitlines():
        line = raw.strip()
        if not line or line.startswith("#"):
            continue
        if line == "[[allow]]":
            cur = {}
            entries.append(cur)
            continue
        if line.startswith("["):
            cur = None           # some other table: not ours, skip
            continue
        if "=" in line and cur is not None:
            k, _, v = line.partition("=")
            cur[k.strip()] = _parse_scalar(v.strip())
    return {"allow": entries}


def load_allowlist(path: str) -> List[dict]:
    """The ``allow`` entries of ``path`` ([] when the file is absent)."""
    try:
        with open(path, "rb") as f:
            raw = f.read()
    except OSError:
        return []
    try:
        import tomllib
        return list(tomllib.loads(raw.decode("utf-8")).get("allow", []))
    except ImportError:
        return list(parse_toml_min(raw.decode("utf-8"))["allow"])


def _file_matches(finding_file: str, pattern: str) -> bool:
    f = finding_file.replace("\\", "/")
    return (f == pattern or f.endswith("/" + pattern) or f.endswith(pattern)
            or fnmatch.fnmatch(f, pattern))


def apply_allowlist(findings: Iterable[Finding],
                    entries: Iterable[dict]) -> List[Finding]:
    """Mark findings covered by an allowlist entry (first match wins)."""
    findings = list(findings)
    for f in findings:
        for e in entries:
            if e.get("rule") != f.rule:
                continue
            if not _file_matches(f.file, str(e.get("file", "*"))):
                continue
            needle = e.get("match")
            if needle and str(needle) not in f.message:
                continue
            f.allowlisted = True
            f.note = str(e.get("note", ""))
            break
    return findings


def render_report(findings: List[Finding]) -> str:
    """Human report: open findings first, allowlisted debt after."""
    open_f = [f for f in findings if not f.allowlisted]
    known = [f for f in findings if f.allowlisted]
    lines = []
    if open_f:
        lines.append(f"== {len(open_f)} finding(s) ==")
        lines += [f.format() for f in open_f]
    if known:
        lines.append(f"== {len(known)} allowlisted (tracked debt) ==")
        lines += [f.format() for f in known]
    if not findings:
        lines.append("no findings")
    return "\n".join(lines)
