"""Layer 3 — repo-specific AST lint over ``src/`` and ``benchmarks/``.

Rules (IDs referenced from ROADMAP.md §Invariants and allowlist.toml):

R1  edge-survival / agent-availability fold-in draws must go through
    ``topology.survival_mask`` or ``topology.availability_mask``: a
    ``jax.random.uniform``/``bernoulli`` call consuming a
    ``fold_in(...)`` key anywhere else forks the host/in-scan
    bit-parity convention the Eq.-(11) post-hoc billing replays. (The
    two definition sites, ``core/topology.py::survival_mask`` (edge
    half) and ``core/topology.py::availability_mask`` (agent half),
    are structurally exempt.)
R2  no naked ``jax.jit`` in ``core/`` or ``rl/`` — round programs must
    go through ``scanloop.donating_jit`` so donation policy and the
    ``repro.analysis`` program registry see them (``core/scanloop.py``,
    the gate itself, is exempt).
R3  timing assertions in ``benchmarks/`` must be median-of-N with
    tolerance: a timing-named value asserted in a module that never
    computes a ``median`` is a single-shot flake.
R4  no unpriced transmissions: a module with wire-send calls (codec
    ``encode_leaf``/``encode_leaf_stateful``/``encode_stateful``,
    ``ring_consensus_step``, ``ppermute``) must reach an Eq.-(11)
    billing call (``round_comm_joules``/``price_bits``/``model_bits``)
    in the same module. (``src/repro/comms/`` — the wire-format layer
    that DEFINES encode — is structurally exempt.)
R5  a module creating donating programs (``donating_jit`` with
    ``donate_argnums``) must ``scanloop.own()`` the carries it feeds
    them — donation consumes buffers, and only driver-owned copies may
    be consumed (``core/scanloop.py`` is exempt).
R6  error paths name the offending input: every ``raise`` in
    ``core/``, ``rl/``, and ``launch/`` must interpolate the bad value
    (an f-string, formatted name, or attribute in the message) and
    point at a nearest alternative — a constant-string raise tells the
    caller WHAT rule broke but not WHICH of their inputs broke it, the
    convention the PR-9 async error paths established by hand. Bare
    re-raises and ``raise err`` of a caught variable are exempt.

Pure ``ast`` — no jax import, so the lint layer runs in any process.
"""
from __future__ import annotations

import ast
import os
import re
from typing import List, Optional

from repro.analysis.findings import Finding

#: identifiers / subscript-string keys that mark a value as a timing
_TIMING_RE = re.compile(
    r"(^|_)(us|ms|usec|msec|sec|secs|seconds|elapsed|wall|time|times|"
    r"dt|latency|duration)(_|$|s$)")

_SEND_NAMES = {"encode_leaf", "encode_leaf_stateful", "encode_stateful",
               "ring_consensus_step", "ppermute"}
_BILLING_NAMES = {"round_comm_joules", "price_bits", "model_bits"}

_R2_SCOPES = ("src/repro/core/", "src/repro/rl/")
_R2_EXEMPT = ("src/repro/core/scanloop.py",)
_R4_EXEMPT_DIRS = ("src/repro/comms/",)
_R5_EXEMPT = ("src/repro/core/scanloop.py",)
_R6_SCOPES = ("src/repro/core/", "src/repro/rl/", "src/repro/launch/")


def _names_offending_input(raise_node: ast.Raise) -> bool:
    """R6 heuristic: does the raise's message interpolate ANY dynamic
    value (f-string piece, name, attribute, or call)? A message built
    purely from constants cannot name the caller's bad input."""
    exc = raise_node.exc
    if exc is None or isinstance(exc, ast.Name):
        return True                   # bare re-raise / `raise err`
    if not isinstance(exc, ast.Call) or not exc.args:
        return False                  # `raise TypeError` / no message
    for arg in exc.args:
        for sub in ast.walk(arg):
            if isinstance(sub, (ast.JoinedStr, ast.FormattedValue,
                                ast.Name, ast.Attribute, ast.Call)):
                return True
    return False


def _dotted(node) -> str:
    """Best-effort dotted name of an expression ("jax.random.uniform")."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
    return ".".join(reversed(parts))


def _contains_call(node, leaf_name: str) -> bool:
    for sub in ast.walk(node):
        if isinstance(sub, ast.Call):
            d = _dotted(sub.func)
            if d == leaf_name or d.endswith("." + leaf_name):
                return True
    return False


def _timingish(node) -> bool:
    for sub in ast.walk(node):
        ident = None
        if isinstance(sub, ast.Name):
            ident = sub.id
        elif isinstance(sub, ast.Attribute):
            ident = sub.attr
        elif isinstance(sub, ast.Constant) and isinstance(sub.value, str):
            ident = sub.value
        if ident and _TIMING_RE.search(ident):
            return True
    return False


class _ModuleFacts(ast.NodeVisitor):
    """One pass collecting every rule's raw facts for a module."""

    def __init__(self):
        self.jax_jit_sites: List[int] = []          # R2
        self.fold_draws: List[tuple] = []           # R1: (line, func name)
        self.timing_asserts: List[int] = []         # R3
        self.has_median = False                     # R3
        self.send_sites: List[tuple] = []           # R4: (line, name)
        self.has_billing = False                    # R4
        self.donating_sites: List[int] = []         # R5
        self.has_own = False                        # R5
        self.nameless_raises: List[int] = []        # R6
        self._func_stack: List[str] = []

    # -- scope tracking ---------------------------------------------------
    def visit_FunctionDef(self, node):
        self._func_stack.append(node.name)
        self.generic_visit(node)
        self._func_stack.pop()

    visit_AsyncFunctionDef = visit_FunctionDef

    # -- facts --------------------------------------------------------------
    def visit_Attribute(self, node):
        if node.attr == "jit" and isinstance(node.value, ast.Name) \
                and node.value.id == "jax":
            self.jax_jit_sites.append(node.lineno)   # call, decorator,
        self.generic_visit(node)                     # or partial() arg

    def visit_Assert(self, node):
        if _timingish(node.test):
            self.timing_asserts.append(node.lineno)
        self.generic_visit(node)

    def visit_Raise(self, node):
        if not _names_offending_input(node):
            self.nameless_raises.append(node.lineno)
        self.generic_visit(node)

    def visit_Call(self, node):
        d = _dotted(node.func)
        leaf = d.rsplit(".", 1)[-1]
        if leaf == "median":
            self.has_median = True
        if leaf == "own":
            self.has_own = True
        if leaf in _BILLING_NAMES:
            self.has_billing = True
        if leaf in _SEND_NAMES:
            self.send_sites.append((node.lineno, leaf))
        if leaf in ("uniform", "bernoulli") and node.args \
                and _contains_call(node.args[0], "fold_in"):
            self.fold_draws.append(
                (node.lineno, self._func_stack[-1]
                 if self._func_stack else "<module>"))
        if leaf == "donating_jit":
            donate = None
            if len(node.args) >= 2:
                donate = node.args[1]
            for kw in node.keywords:
                if kw.arg == "donate_argnums":
                    donate = kw.value
            empty = (isinstance(donate, (ast.Tuple, ast.List))
                     and not donate.elts)
            if donate is not None and not empty:
                self.donating_sites.append(node.lineno)
        self.generic_visit(node)


def lint_file(path: str, rel: str) -> List[Finding]:
    """All rule findings for one file (``rel``: repo-relative path)."""
    with open(path, "r", encoding="utf-8") as f:
        src = f.read()
    try:
        tree = ast.parse(src, filename=path)
    except SyntaxError as e:
        return [Finding("R0", rel, e.lineno or 0,
                        f"file does not parse: {e.msg}")]
    facts = _ModuleFacts()
    facts.visit(tree)
    rel = rel.replace("\\", "/")
    out: List[Finding] = []

    for line, func in facts.fold_draws:                               # R1
        if rel.endswith("core/topology.py") and func in (
                "survival_mask", "availability_mask"):
            continue          # the one blessed definition site
        out.append(Finding(
            "R1", rel, line,
            f"raw uniform(fold_in(...)) fold-in draw in {func}() — go "
            "through topology.survival_mask (edges) or "
            "topology.availability_mask (agents) for host/in-scan bit "
            "parity"))

    if any(rel.startswith(s) for s in _R2_SCOPES) \
            and rel not in _R2_EXEMPT:                                # R2
        for line in facts.jax_jit_sites:
            out.append(Finding(
                "R2", rel, line,
                "naked jax.jit — use scanloop.donating_jit (donation "
                "policy + program registry) or allowlist"))

    if rel.startswith("benchmarks/") and not facts.has_median:        # R3
        for line in facts.timing_asserts:
            out.append(Finding(
                "R3", rel, line,
                "single-shot timing assertion — time median-of-N with a "
                "tolerance (the module never computes a median)"))

    if not any(rel.startswith(d) for d in _R4_EXEMPT_DIRS) \
            and facts.send_sites and not facts.has_billing:           # R4
        for line, name in facts.send_sites:
            out.append(Finding(
                "R4", rel, line,
                f"wire send ({name}) with no Eq.-(11) billing call "
                "(round_comm_joules/price_bits/model_bits) in this "
                "module — unpriced transmission"))

    if rel not in _R5_EXEMPT and facts.donating_sites \
            and not facts.has_own:                                    # R5
        for line in facts.donating_sites:
            out.append(Finding(
                "R5", rel, line,
                "donating_jit(donate_argnums=...) in a module that never "
                "scanloop.own()s a carry — donated inputs must be "
                "driver-owned copies"))

    if any(rel.startswith(s) for s in _R6_SCOPES):                    # R6
        for line in facts.nameless_raises:
            out.append(Finding(
                "R6", rel, line,
                "raise with a constant-only message — interpolate the "
                "offending input (an f-string with the bad value) and "
                "name a nearest alternative, so the caller learns "
                "WHICH input broke the rule, not just which rule "
                "broke"))
    return out


def run_lint(root: str, subdirs=("src", "benchmarks")) -> List[Finding]:
    """Lint every ``*.py`` under ``root``'s ``subdirs``."""
    findings: List[Finding] = []
    for sub in subdirs:
        base = os.path.join(root, sub)
        for dirpath, _dirnames, filenames in os.walk(base):
            for fn in sorted(filenames):
                if not fn.endswith(".py"):
                    continue
                path = os.path.join(dirpath, fn)
                rel = os.path.relpath(path, root)
                findings.extend(lint_file(path, rel))
    return findings
