"""Learning-rate schedules (step -> lr)."""
from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: jnp.float32(lr)


def cosine_decay(lr: float, steps: int, final_frac: float = 0.1):
    def f(step):
        t = jnp.clip(step.astype(jnp.float32) / steps, 0.0, 1.0)
        c = 0.5 * (1 + jnp.cos(jnp.pi * t))
        return jnp.float32(lr) * (final_frac + (1 - final_frac) * c)
    return f


def warmup_cosine(lr: float, warmup: int, steps: int,
                  final_frac: float = 0.1):
    cos = cosine_decay(lr, max(steps - warmup, 1), final_frac)

    def f(step):
        s = step.astype(jnp.float32)
        w = jnp.minimum(s / max(warmup, 1), 1.0)
        return jnp.where(step <= warmup, jnp.float32(lr) * w,
                         cos(step - warmup))
    return f
