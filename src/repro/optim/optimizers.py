"""Pure-JAX optimizers (optax-style (init, update) pairs, no dependency).

An optimizer is a SimpleNamespace(init, update):
    state = opt.init(params)
    updates, state = opt.update(grads, state, params)
    params = apply_updates(params, updates)
Updates are NEGATIVE deltas already scaled by the learning rate.
"""
from __future__ import annotations

from types import SimpleNamespace
from typing import Callable, Union

import jax
import jax.numpy as jnp

Schedule = Union[float, Callable[[jnp.ndarray], jnp.ndarray]]


def _lr_at(lr: Schedule, step):
    return lr(step) if callable(lr) else lr


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(tree, max_norm: float):
    n = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(n, 1e-9))
    return jax.tree.map(lambda x: x * scale.astype(x.dtype), tree), n


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p.astype(jnp.float32)
                                      + u.astype(jnp.float32)).astype(p.dtype),
                        params, updates)


def sgd(lr: Schedule, momentum: float = 0.0):
    """Plain SGD — the paper's device-side optimizer (Eq. 3 inner steps)."""

    def init(params):
        st = {"step": jnp.zeros((), jnp.int32)}
        if momentum:
            st["mom"] = jax.tree.map(
                lambda p: jnp.zeros_like(p, jnp.float32), params)
        return st

    def update(grads, state, params=None):
        step = state["step"] + 1
        lr_t = _lr_at(lr, step)
        if momentum:
            mom = jax.tree.map(
                lambda m, g: momentum * m + g.astype(jnp.float32),
                state["mom"], grads)
            upd = jax.tree.map(lambda m: -lr_t * m, mom)
            return upd, {"step": step, "mom": mom}
        upd = jax.tree.map(lambda g: -lr_t * g.astype(jnp.float32), grads)
        return upd, {"step": step}

    return SimpleNamespace(init=init, update=update)


def adam(lr: Schedule, b1: float = 0.9, b2: float = 0.999,
         eps: float = 1e-8, weight_decay: float = 0.0):
    def init(params):
        z = lambda p: jnp.zeros_like(p, jnp.float32)
        return {"step": jnp.zeros((), jnp.int32),
                "mu": jax.tree.map(z, params),
                "nu": jax.tree.map(z, params)}

    def update(grads, state, params=None):
        step = state["step"] + 1
        lr_t = _lr_at(lr, step)
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1)
                          * g.astype(jnp.float32), state["mu"], grads)
        nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2)
                          * jnp.square(g.astype(jnp.float32)),
                          state["nu"], grads)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(m, v, p):
            u = -lr_t * (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            if weight_decay:
                u = u - lr_t * weight_decay * p.astype(jnp.float32)
            return u

        updates = jax.tree.map(upd, mu, nu,
                               params if params is not None else mu)
        return updates, {"step": step, "mu": mu, "nu": nu}

    return SimpleNamespace(init=init, update=update)


def adamw(lr: Schedule, weight_decay: float = 0.01, **kw):
    return adam(lr, weight_decay=weight_decay, **kw)
