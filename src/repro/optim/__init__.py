from repro.optim.optimizers import (
    sgd, adam, adamw, clip_by_global_norm, apply_updates, global_norm,
)
from repro.optim.schedules import constant, cosine_decay, warmup_cosine
