"""The paper's contribution: MAML meta-learning (Eqs. 2-5), decentralized
consensus FL (Eq. 6), the energy/communication footprint model (Eqs. 8-12),
and the two-stage MTL protocol tying them together."""
from repro.core import (consensus, energy, engine, federated, maml,
                        multitask, protocol, topology)
from repro.core.engine import ConsensusEngine  # noqa: F401
