"""Communication-graph topology engine — the single source of truth for
WHO talks to WHOM in one Eq.-(6) consensus round and WHAT each message
costs under the paper's Eq. (11) link pricing.

One :class:`Topology` object produces, for a population of K agents:

* ``adjacency``        — (K, K) bool; ``A[k, h]`` ⇒ h ∈ N_k, i.e. agent k
                         consumes agent h's model (one directed message
                         h → k per round);
* ``mixing(...)``      — the (K, K) σ matrix of Eq. (6) (delegates to
                         :mod:`repro.core.consensus`);
* ``links_per_round`` — per-round directed message counts split by link
                         efficiency class;
* ``round_comm_joules``— the Eq.-(11) communication term for ONE round,
                         priced per link class (SL honours the paper's
                         UL + γ·DL replacement when sidelink is off),
                         optionally per EDGE (``edge_efficiency`` /
                         ``with_edge_efficiency`` — heterogeneous
                         bandwidth) and per CODEC (``codec=`` prices each
                         message at its :mod:`repro.comms` wire size
                         instead of the full-precision b(W)).

Link classes follow Sect. III-B: ``SL`` (device↔device sidelink), ``UL``
(device→infrastructure uplink), ``DL`` (infrastructure→device downlink).
Peer exchanges are SL; star (FedAvg) leaves upload to the hub over UL and
receive the aggregate over DL; hierarchical gateways backhaul over UL.

Graph families: ring, full, torus, small-world (Watts–Strogatz), star
(FedAvg), per-task clusters (the paper's C_i), and hierarchical
cluster-of-clusters. ``make(name, K)`` is the uniform constructor used by
the scale benchmark. :func:`dropout` derives time-varying per-round
link-failure sequences from any of them (fading / mobility), priced only
on the messages actually sent; :class:`GraphProcess` is the first-class
description of such a process (static | dropout(p, seed) | schedule)
that :class:`repro.core.engine.ConsensusEngine` resolves at construction
so the scanned drivers can regenerate each round's surviving graph
IN-SCAN from a folded key (:func:`survival_mask` — bit-identical to the
host :func:`dropout` stream by the shared fold-in convention).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import consensus, energy

# link efficiency classes (Sect. III-B)
NONE, SL, UL, DL = 0, 1, 2, 3
LINK_CLASS_NAMES = {SL: "SL", UL: "UL", DL: "DL"}


@dataclass(frozen=True, eq=False)   # eq=False: dataclass __eq__/__hash__
class Topology:                     # would crash on the ndarray fields
    """An immutable communication graph with per-link efficiency classes.

    ``adjacency[k, h]`` — agent k receives agent h's model each round.
    ``link_class[k, h]`` — class of that h → k message (SL/UL/DL); must be
    NONE exactly where ``adjacency`` is False.
    """

    name: str
    adjacency: np.ndarray
    link_class: np.ndarray
    meta: dict = field(default_factory=dict)
    #: optional (K, K) per-edge efficiency in bit/J (heterogeneous
    #: bandwidth): entries > 0 override that directed edge's class-wide
    #: constant in Eq.-(11) pricing; 0 elsewhere. None ⇒ class constants.
    edge_efficiency: Optional[np.ndarray] = None

    def __post_init__(self):
        A = np.asarray(self.adjacency, bool)
        L = np.asarray(self.link_class, np.int8)
        if A.ndim != 2 or A.shape[0] != A.shape[1]:
            raise ValueError(f"adjacency must be square, got {A.shape}")
        if L.shape != A.shape:
            raise ValueError(f"link_class shape {L.shape} != {A.shape}")
        if A.diagonal().any():
            raise ValueError(
                f"adjacency has self loops at agents "
                f"{np.flatnonzero(A.diagonal()).tolist()} — zero the "
                "diagonal (an agent never wires to itself; self-mixing "
                "is the σ diagonal's job)")
        if ((L != NONE) != A).any():
            raise ValueError(
                f"link_class disagrees with adjacency on "
                f"{int(((L != NONE) != A).sum())} entries — set a "
                "class (SL/UL/DL) exactly on edges and NONE exactly "
                "off them")
        object.__setattr__(self, "adjacency", A)
        object.__setattr__(self, "link_class", L)
        if self.edge_efficiency is not None:
            E = np.asarray(self.edge_efficiency, np.float64)
            if E.shape != A.shape:
                raise ValueError(
                    f"edge_efficiency shape {E.shape} != {A.shape}")
            if (E < 0).any():
                raise ValueError(
                    f"edge efficiencies must be >= 0 bit/J, got min "
                    f"{E.min()} — fix the negative entries or drop "
                    "edge_efficiency= for class-constant pricing")
            if (E[~A] != 0).any():
                raise ValueError(
                    f"edge_efficiency has {int((E[~A] != 0).sum())} "
                    "nonzero entries off the edge set — mask it with "
                    "the adjacency (efficiencies only price wires that "
                    "exist)")
            object.__setattr__(self, "edge_efficiency", E)

    # -- structure ----------------------------------------------------------
    @property
    def K(self) -> int:
        return self.adjacency.shape[0]

    @property
    def degrees(self) -> np.ndarray:
        """In-degree |N_k| per agent."""
        return self.adjacency.sum(axis=1)

    @property
    def max_degree(self) -> int:
        return int(self.degrees.max()) if self.K else 0

    @property
    def directed_links(self) -> int:
        """Total directed messages per consensus round (Σ_k |N_k|)."""
        return int(self.adjacency.sum())

    @property
    def is_symmetric(self) -> bool:
        return bool((self.adjacency == self.adjacency.T).all())

    def neighbors_of(self, k: int) -> List[int]:
        return list(np.flatnonzero(self.adjacency[k]))

    def is_connected(self) -> bool:
        """Weak connectivity (BFS over the undirected support)."""
        if self.K == 0:
            return True
        und = self.adjacency | self.adjacency.T
        seen = np.zeros(self.K, bool)
        frontier = [0]
        seen[0] = True
        while frontier:
            nxt = np.flatnonzero(und[frontier].any(axis=0) & ~seen)
            seen[nxt] = True
            frontier = list(nxt)
        return bool(seen.all())

    # -- mixing (Eq. 6) ------------------------------------------------------
    def mixing(self, data_sizes: Optional[Sequence[float]] = None,
               kind: str = "paper", include_self: bool = True):
        """σ matrix of Eq. (6) on this graph (uniform |E_k| by default)."""
        sizes = np.ones(self.K) if data_sizes is None else data_sizes
        return consensus.mixing_weights(sizes, self.adjacency, kind,
                                        include_self=include_self)

    # -- Eq. (11) link pricing ----------------------------------------------
    def links_per_round(self) -> Dict[str, int]:
        """Directed message counts per round, keyed by link class."""
        return {name: int((self.link_class == cls).sum())
                for cls, name in LINK_CLASS_NAMES.items()}

    def with_edge_efficiency(self, eff) -> "Topology":
        """Copy of this graph with per-edge efficiencies (bit/J): ``eff``
        is (K, K) — entries on edges override the class constants in
        Eq.-(11) pricing — or a scalar applied to every edge."""
        eff = np.asarray(eff, np.float64)
        if eff.ndim == 0:
            eff = np.where(self.adjacency, float(eff), 0.0)
        return dataclasses.replace(self, edge_efficiency=eff)

    def round_comm_joules(self, p: energy.EnergyParams,
                          model_bits: Optional[float] = None,
                          codec=None) -> float:
        """Eq.-(11) communication energy of ONE consensus round: every
        directed message carries b(W) bits at its class's efficiency.

        ``codec`` (spec string or :class:`repro.comms.codecs.Codec`)
        prices each message at the codec's WIRE size instead of the
        full-precision b(W) — ``codec.price_bits(b(W))`` — which is the
        whole bits-vs-rounds-vs-joules tradeoff axis. With
        ``edge_efficiency`` set, the SL/UL/DL sums run per-edge
        (heterogeneous bandwidth) rather than per class-wide constant;
        edges left at 0 fall back to their class constant.
        """
        bits = p.model_bits if model_bits is None else model_bits
        if codec is not None:
            from repro import comms   # deferred: avoid import cycles
            bits = comms.get_codec(codec).price_bits(bits)
        if self.edge_efficiency is None:
            n = self.links_per_round()
            return bits * (n["SL"] * energy.sidelink_cost_per_bit(p)
                           + n["UL"] / p.E_UL + n["DL"] / p.E_DL)
        # per-edge: J/bit of each directed edge, class default where the
        # per-edge efficiency is unset (0)
        class_cost = np.zeros(self.adjacency.shape)
        class_cost[self.link_class == SL] = energy.sidelink_cost_per_bit(p)
        class_cost[self.link_class == UL] = 1.0 / p.E_UL
        class_cost[self.link_class == DL] = 1.0 / p.E_DL
        eff = self.edge_efficiency
        cost = np.where(eff > 0, 1.0 / np.maximum(eff, 1e-300), class_cost)
        return float(bits * cost[self.adjacency].sum())

    def __repr__(self):  # compact — adjacency can be 1024^2
        lk = {k: v for k, v in self.links_per_round().items() if v}
        return (f"Topology({self.name!r}, K={self.K}, "
                f"max_degree={self.max_degree}, links={lk})")


# ---------------------------------------------------------------------------
# construction helpers
# ---------------------------------------------------------------------------


def _from_edges(name: str, K: int, edges, cls_of=None, meta=None) -> Topology:
    """Build from directed (receiver, sender) pairs; ``cls_of(k, h)`` gives
    the link class (default SL)."""
    A = np.zeros((K, K), bool)
    L = np.zeros((K, K), np.int8)
    for k, h in edges:
        if k == h:
            continue
        A[k, h] = True
        L[k, h] = SL if cls_of is None else cls_of(k, h)
    return Topology(name, A, L, meta or {})


def _symmetric(name: str, K: int, pairs, cls: int = SL, meta=None) -> Topology:
    edges = [(k, h) for k, h in pairs] + [(h, k) for k, h in pairs]
    return _from_edges(name, K, edges, lambda *_: cls, meta)


# -- graph families ---------------------------------------------------------


def ring(K: int, hops: int = 1) -> Topology:
    """Symmetric ring; each agent sees ``hops`` neighbours each side (SL)."""
    A = consensus.ring_adjacency(K, hops)
    return Topology("ring", A, np.where(A, SL, NONE).astype(np.int8),
                    {"hops": hops})


def full(K: int) -> Topology:
    """All-to-all sidelink mesh."""
    A = consensus.full_adjacency(K)
    return Topology("full", A, np.where(A, SL, NONE).astype(np.int8))


def torus(rows: int, cols: int) -> Topology:
    """2-D 4-neighbour torus (rows × cols agents, SL links)."""
    K = rows * cols
    pairs = set()
    for r in range(rows):
        for c in range(cols):
            k = r * cols + c
            for rr, cc in ((r, (c + 1) % cols), ((r + 1) % rows, c)):
                h = rr * cols + cc
                if h != k:
                    pairs.add((min(k, h), max(k, h)))
    return _symmetric("torus", K, pairs, meta={"rows": rows, "cols": cols})


def small_world(K: int, k: int = 4, rewire_p: float = 0.1,
                seed: int = 0) -> Topology:
    """Watts–Strogatz: ring(K, k/2) with each edge rewired with prob. p
    (symmetric, self/duplicate edges skipped — stays connected w.h.p.)."""
    if k % 2 or not 0 < k < K:
        raise ValueError(f"need even 0 < k < K, got k={k} K={K}")
    rng = np.random.default_rng(seed)
    pairs = {(kk, (kk + d) % K) for kk in range(K) for d in range(1, k // 2 + 1)}
    pairs = {(min(a, b), max(a, b)) for a, b in pairs}
    out = set(pairs)
    for a, b in sorted(pairs):
        if rng.random() < rewire_p:
            c = int(rng.integers(K))
            new = (min(a, c), max(a, c))
            if c != a and new not in out:
                out.discard((a, b))
                out.add(new)
    return _symmetric("small_world", K, out,
                      meta={"k": k, "rewire_p": rewire_p, "seed": seed})


def star(K: int) -> Topology:
    """FedAvg star: agent 0 is the hub/server. Leaf models reach the hub
    over UL; the hub's (aggregated) model reaches leaves over DL."""
    edges, cls = [], {}
    for leaf in range(1, K):
        edges.append((0, leaf))      # hub consumes leaf  → leaf uploads: UL
        edges.append((leaf, 0))      # leaf consumes hub  → hub pushes:  DL
        cls[(0, leaf)] = UL
        cls[(leaf, 0)] = DL
    return _from_edges("star", K, edges, lambda kk, h: cls[(kk, h)])


def clusters(num_clusters: int, devices_per_cluster: int) -> Topology:
    """The paper's per-task clusters C_i: all-to-all SL within a cluster,
    no inter-cluster links (Sect. II-B)."""
    per = devices_per_cluster
    K = num_clusters * per
    pairs = {(c * per + i, c * per + j)
             for c in range(num_clusters)
             for i in range(per) for j in range(i + 1, per)}
    return _symmetric("cluster", K, pairs,
                      meta={"num_clusters": num_clusters,
                            "devices_per_cluster": per})


def hierarchical(num_clusters: int, devices_per_cluster: int) -> Topology:
    """Cluster-of-clusters: all-to-all SL within each cluster, plus each
    cluster's first device acting as gateway on an inter-cluster ring
    (backhaul links priced as UL)."""
    per = devices_per_cluster
    K = num_clusters * per
    base = clusters(num_clusters, per)
    A = base.adjacency.copy()
    L = base.link_class.copy()
    if num_clusters > 1:
        gws = [c * per for c in range(num_clusters)]
        for i, g in enumerate(gws):
            for d in (1, -1):
                h = gws[(i + d) % num_clusters]
                if h != g:
                    A[g, h] = True
                    L[g, h] = UL
    return Topology("hierarchical", A, L,
                    {"num_clusters": num_clusters,
                     "devices_per_cluster": per})


def from_cluster_network(net) -> Topology:
    """Adapter for :class:`repro.core.multitask.ClusterNetwork`."""
    return clusters(net.num_tasks, net.devices_per_cluster)


# -- time-varying topologies -------------------------------------------------


def survival_key(seed: int):
    """The PRNG key a dropout :class:`GraphProcess` with this seed folds
    its per-round indices into (the shared fold-in convention)."""
    return jax.random.PRNGKey(seed)


def survival_mask(adjacency, p: float, key, t, symmetric: Optional[bool]
                  = None, *, receivers=None, senders=None):
    """Edge-survival bools of round ``t`` — THE shared fold-in
    convention, defined PER EDGE. Each directed edge (receiver ``i``,
    sender ``j``) owns one canonical id — ``min(i,j)·K + max(i,j)`` on
    symmetric graphs (one draw per undirected PAIR: a faded channel
    kills both directions together) or ``i·K + j`` on asymmetric ones
    (star's UL/DL, hierarchical backhaul fade per directed edge) — and
    survives round ``t`` iff

        ``uniform(fold_in(fold_in(key, t), edge_id)) >= p`` .

    Self loops never fade (``i == j`` keeps unconditionally): an agent
    always reaches its own model, whatever the radio does. ``p = 0``
    keeps every edge, ``p = 1`` drops every non-self edge — both exact
    (``uniform`` draws in [0, 1)).

    Two call forms share this one draw site (analysis rule R1):

    * dense — ``survival_mask(adjacency, p, key, t)`` evaluates the
      convention over the full (K, K) index grid and returns
      ``adjacency & keep`` (the host :func:`dropout` stream and the
      dense-xla plan);
    * per-edge — ``survival_mask(K, p, key, t, symmetric=...,
      receivers=i, senders=j)`` evaluates it ONLY at the given
      (receiver, sender) index arrays (broadcast together) and returns
      the raw keep bools of that shape: O(#edges) work and memory with
      no (K, K) anywhere, which is how the engine's sparse/sharded
      plans draw their (K, H) lane survival and the distributed plan
      its (M, K) ppermute-schedule survival from the same stream —
      bit-identical to the dense grid at those entries, because every
      edge's draw is a pure function of ``(key, t, edge_id)``. Callers
      AND with lane validity / adjacency themselves; ``symmetric=`` is
      required (there is no adjacency to infer pair-folding from).

    ``t`` may be a TRACED int32 (``jax.random.fold_in`` accepts traced
    data), which is what lets the scanned drivers generate each round's
    surviving edges INSIDE a ``lax.scan`` body; jax's counter-based
    PRNG is bit-deterministic across eager, jitted and vmapped
    execution, so the host-side :func:`dropout` stream (which calls
    this same function concretely) and the in-scan draws of
    :meth:`repro.core.engine.ConsensusEngine.round_survival` agree bit
    for bit — the bit-parity invariant the engine's time-varying plans
    and the post-hoc Eq.-(11) billing both rely on.
    """
    A = None
    if receivers is not None or senders is not None:
        if receivers is None or senders is None:
            missing = "senders=" if senders is None else "receivers="
            raise ValueError(
                f"per-edge survival draws need BOTH receivers= and "
                f"senders=, but {missing} is None — pass both endpoint "
                "index arrays, or a full adjacency for the dense form")
        if symmetric is None:
            raise ValueError(
                f"per-edge survival draws over {np.shape(receivers)} "
                "endpoint arrays need an explicit symmetric= (there is "
                "no adjacency to infer pair-folding from) — pass "
                "symmetric=True for undirected links, False for "
                "directed")
        K = int(adjacency)
        sym = bool(symmetric)
        i = jnp.asarray(receivers, jnp.uint32)
        j = jnp.asarray(senders, jnp.uint32)
        i, j = jnp.broadcast_arrays(i, j)
    else:
        A = np.asarray(adjacency, bool)
        K = A.shape[0]
        sym = bool((A == A.T).all()) if symmetric is None else bool(symmetric)
        i = jnp.broadcast_to(
            jnp.arange(K, dtype=jnp.uint32)[:, None], (K, K))
        j = jnp.broadcast_to(
            jnp.arange(K, dtype=jnp.uint32)[None, :], (K, K))
    rk = jax.random.fold_in(key, t)
    lo = jnp.minimum(i, j) if sym else i
    hi = jnp.maximum(i, j) if sym else j
    eid = lo * jnp.uint32(K) + hi
    u = jax.vmap(
        lambda e: jax.random.uniform(jax.random.fold_in(rk, e)))(
        eid.ravel()).reshape(eid.shape)
    keep = (u >= p) | (i == j)
    if A is None:
        return keep
    return jnp.asarray(A) & keep


@dataclass(frozen=True)
class GraphProcess:
    """A time-varying communication-graph process — how the engine's σ
    evolves round over round. Resolved ONCE at
    :class:`repro.core.engine.ConsensusEngine` construction:

    * ``static()``            — the graph never changes (the default);
    * ``dropout(p, seed)``    — every round, each link of the engine's
      base graph is independently DOWN with probability ``p`` (fading /
      contention / mobility), masks drawn by :func:`survival_mask` from
      ``fold_in(PRNGKey(seed), round)`` — cheap seeded samples the
      scanned drivers generate in-scan, bit-identical to the host
      :func:`dropout` stream;
    * ``schedule(masks)``     — an explicit (R, K, K) bool stack of keep
      masks; round ``t`` applies ``masks[t % R]`` (MATCHA-style
      randomized link schedules, TDMA frames).

    The per-round σ is RENORMALIZED on the surviving graph (self loops
    kept, σ mass of dropped links reallocated by the engine's mixing
    kind — doubly-stochastic kinds stay doubly stochastic on every
    surviving subgraph), never silently zeroed — and in each plan's
    NATIVE shape: the dense-xla plan rebuilds the (K, K) mix, the
    sparse-pallas/sharded plans renormalize directly on their (K, H)
    lanes, and the distributed plan scales its (K, M) schedule slots
    (bitwise the same weights on every surviving edge).
    """

    kind: str = "static"                  # static | dropout | schedule
    p: float = 0.0
    seed: int = 0
    masks: Optional[np.ndarray] = None    # (R, K, K) for "schedule"

    def __post_init__(self):
        if self.kind not in ("static", "dropout", "schedule"):
            raise ValueError(f"unknown graph process {self.kind!r}")
        if self.kind == "dropout" and not 0 <= self.p < 1:
            raise ValueError(
                f"dropout probability must be in [0, 1), got {self.p}")
        if self.kind == "schedule":
            m = np.asarray(self.masks, bool)
            if m.ndim != 3 or m.shape[1] != m.shape[2] or not m.shape[0]:
                raise ValueError(
                    f"schedule masks must be (R, K, K), got {m.shape}")
            object.__setattr__(self, "masks", m)

    @staticmethod
    def static() -> "GraphProcess":
        return GraphProcess("static")

    @staticmethod
    def dropout(p: float, seed: int = 0) -> "GraphProcess":
        return GraphProcess("dropout", p=float(p), seed=int(seed))

    @staticmethod
    def schedule(masks) -> "GraphProcess":
        return GraphProcess("schedule", masks=masks)

    def __repr__(self):
        if self.kind == "dropout":
            return f"GraphProcess.dropout(p={self.p}, seed={self.seed})"
        if self.kind == "schedule":
            return f"GraphProcess.schedule(R={self.masks.shape[0]})"
        return "GraphProcess.static()"


def dropout(topo: Topology, p: float, seed: int = 0,
            rounds: Optional[int] = None):
    """Per-round link-dropout sequence: each round, every link of ``topo``
    is independently DOWN with probability ``p`` (fading / contention /
    mobility — the paper's t_i is measured on exactly these rounds).

    Round ``r``'s keep mask is :func:`survival_mask` at
    ``fold_in(PRNGKey(seed), r)`` — the SAME fold-in convention a
    ``GraphProcess.dropout(p, seed)`` engine uses to generate masks
    in-scan, so this host-materialized stream and the device-resident
    one are bit-identical (which is how post-hoc Eq.-(11) billing prices
    exactly the links the scanned rounds actually used, with zero
    per-round host prefetch during the loop). Symmetric graphs drop
    whole undirected PAIRS; asymmetric edges drop per directed edge.
    Surviving links keep their class and any per-edge efficiency, and
    mixing weights must be rebuilt from each round's surviving graph
    (``t.mixing(...)``) — dropping a link reallocates its σ mass, it
    does not silently zero it.

    With ``rounds`` returns a list of ``rounds`` Topologies; without, an
    infinite generator. Deterministic in ``seed``.
    """
    if not 0 <= p < 1:
        raise ValueError(f"dropout probability must be in [0, 1), got {p}")

    def _rounds():
        key = survival_key(seed)
        sym = topo.is_symmetric
        r = 0
        while True:
            mask = np.asarray(survival_mask(topo.adjacency, p, key, r,
                                            symmetric=sym))
            eff = (None if topo.edge_efficiency is None
                   else np.where(mask, topo.edge_efficiency, 0.0))
            yield Topology(
                f"{topo.name}~drop", mask,
                np.where(mask, topo.link_class, NONE).astype(np.int8),
                {**topo.meta, "dropout_p": p, "dropout_seed": seed,
                 "round": r},
                edge_efficiency=eff)
            r += 1

    gen = _rounds()
    if rounds is None:
        return gen
    return [next(gen) for _ in range(rounds)]


# -- per-agent availability (the async protocol's churn source) -------------


def availability_key(seed: int):
    """Root PRNG key of a per-agent availability stream (seeded churn)."""
    return jax.random.PRNGKey(seed)


def availability_mask(K, p_inactive, key, t, *, agents=None):
    """Per-agent activity bools for round ``t`` — the AGENT half of the
    repo's fold-in bit-parity convention (the link half is
    :func:`survival_mask`; analysis rule R1 blesses exactly these two
    draw sites).

    Agent ``k`` is ACTIVE in round ``t`` iff

        ``uniform(fold_in(fold_in(key, t), k)) >= p_inactive_k`` ,

    one independent draw per (round, agent), a pure function of
    ``(key, t, k)``. ``p_inactive`` is a scalar (i.i.d. duty cycle) or a
    (K,) array of per-agent sleep probabilities (heterogeneous straggler
    populations); ``p = 0`` keeps every agent awake and ``p = 1`` sleeps
    it every round — both exact (``uniform`` draws in [0, 1)).

    ``agents=`` restricts the draw to the given agent-id array (any
    shape) and returns bools of that shape, evaluated at those ids only
    — bit-identical to the corresponding entries of the full (K,) draw,
    which is what lets plan-native kernels sample availability at their
    own lane/slot indices. ``t`` may be traced (the scanned drivers draw
    availability INSIDE ``lax.scan`` bodies); jax's counter-based PRNG
    makes the host replay :func:`availability_stream` and the in-scan
    draws agree bit for bit.
    """
    ids = (jnp.arange(int(K), dtype=jnp.uint32) if agents is None
           else jnp.asarray(agents, jnp.uint32))
    p = jnp.asarray(p_inactive, jnp.float32)
    thresh = p if p.ndim == 0 else p[ids.astype(jnp.int32)]
    rk = jax.random.fold_in(key, t)
    u = jax.vmap(
        lambda a: jax.random.uniform(jax.random.fold_in(rk, a)))(
        ids.ravel()).reshape(ids.shape)
    return u >= thresh


@dataclass(frozen=True)
class AgentProcess:
    """A per-agent availability process — WHO participates each round,
    the companion of :class:`GraphProcess` (which says which LINKS are
    up). Resolved once at ConsensusEngine construction; per-round
    activity is then drawn in-scan by :func:`agent_availability`:

    * ``always_on()``          — every agent, every round (the lockstep
      protocol; with τ=∞ the async engine reduces to today's engine bit
      for bit);
    * ``bernoulli(p_active)``  — i.i.d. duty cycle: each agent is awake
      each round with probability ``p_active`` (duty-cycled radios);
    * ``straggler(K, ...)``    — heterogeneous heavy-tail population:
      per-agent sleep probabilities drawn host-side at CONSTRUCTION from
      a Pareto(``tail``) tail (most agents almost never sleep, a few
      sleep most rounds — the classic straggler fleet), then applied
      per round through the same in-scan draw;
    * ``arrival(t_join)``      — agent ``k`` joins at round
      ``t_join[k]`` (active iff ``t >= t_join[k]``), deterministic;
    * ``departure(t_leave)``   — agent ``k`` leaves at round
      ``t_leave[k]`` (active iff ``t < t_leave[k]``), deterministic.

    An INACTIVE agent neither runs local SGD nor sends or receives
    wires that round: its params and codec residuals freeze, its round
    clock stops, and (under the async engine's staleness rule) its
    neighbours keep mixing its frozen last-published state at decayed
    weight until the wire age passes the engine's hard bound τ.
    """

    kind: str = "always_on"   # always_on | bernoulli | straggler
                              # | arrival | departure
    p_active: float = 1.0
    seed: int = 0
    rates: Optional[np.ndarray] = None     # (K,) sleep probs, straggler
    t_join: Optional[np.ndarray] = None    # (K,) int rounds, arrival
    t_leave: Optional[np.ndarray] = None   # (K,) int rounds, departure

    def __post_init__(self):
        kinds = ("always_on", "bernoulli", "straggler", "arrival",
                 "departure")
        if self.kind not in kinds:
            raise ValueError(
                f"unknown agent process {self.kind!r}; choose from "
                f"{kinds} (see AgentProcess's constructors)")
        if self.kind == "bernoulli" and not 0 <= self.p_active <= 1:
            raise ValueError(
                f"bernoulli duty cycle p_active must be in [0, 1], got "
                f"{self.p_active}")
        if self.kind == "straggler":
            r = np.asarray(self.rates, np.float64)
            if r.ndim != 1 or not r.size:
                raise ValueError(
                    f"straggler rates must be a non-empty (K,) vector "
                    f"of per-agent sleep probabilities, got shape "
                    f"{r.shape}")
            if not ((r >= 0) & (r <= 1)).all():
                raise ValueError(
                    "straggler rates must all lie in [0, 1], got "
                    f"min={r.min()} max={r.max()}")
            object.__setattr__(self, "rates", r)
        for name in ("t_join", "t_leave"):
            v = getattr(self, name)
            if v is None:
                continue
            v = np.asarray(v, np.int64)
            if v.ndim != 1 or not v.size:
                raise ValueError(
                    f"{name} must be a non-empty (K,) vector of round "
                    f"indices, got shape {v.shape}")
            object.__setattr__(self, name, v)

    @property
    def K(self) -> Optional[int]:
        """Population size the process pins, or None if size-free."""
        for v in (self.rates, self.t_join, self.t_leave):
            if v is not None:
                return int(v.shape[0])
        return None

    @staticmethod
    def always_on() -> "AgentProcess":
        return AgentProcess("always_on")

    @staticmethod
    def bernoulli(p_active: float, seed: int = 0) -> "AgentProcess":
        return AgentProcess("bernoulli", p_active=float(p_active),
                            seed=int(seed))

    @staticmethod
    def straggler(K: int, *, tail: float = 1.1, scale: float = 0.05,
                  cap: float = 0.9, seed: int = 0,
                  rates=None) -> "AgentProcess":
        """Heavy-tail straggler fleet: per-agent sleep probability
        ``min(cap, scale · Pareto(tail))`` drawn host-side from
        ``seed`` (pass explicit ``rates=`` to pin them instead)."""
        if rates is None:
            rng = np.random.default_rng(seed)
            rates = np.minimum(float(cap),
                               float(scale) * rng.pareto(float(tail),
                                                         size=int(K)))
        return AgentProcess("straggler", seed=int(seed), rates=rates)

    @staticmethod
    def arrival(t_join) -> "AgentProcess":
        return AgentProcess("arrival", t_join=t_join)

    @staticmethod
    def departure(t_leave) -> "AgentProcess":
        return AgentProcess("departure", t_leave=t_leave)

    def __repr__(self):
        if self.kind == "bernoulli":
            return (f"AgentProcess.bernoulli(p_active={self.p_active}, "
                    f"seed={self.seed})")
        if self.kind == "straggler":
            return (f"AgentProcess.straggler(K={self.K}, "
                    f"seed={self.seed})")
        if self.kind == "arrival":
            return f"AgentProcess.arrival(K={self.K})"
        if self.kind == "departure":
            return f"AgentProcess.departure(K={self.K})"
        return "AgentProcess.always_on()"


def agent_availability(process: Optional[AgentProcess], K: int, t):
    """(K,) activity bools of round ``t`` under ``process`` (None means
    always on). ``t`` may be traced OR concrete — the single dispatch
    the in-scan drivers and the host replay
    (:func:`availability_stream`) both go through, which is what makes
    the two streams bit-identical."""
    if process is None or process.kind == "always_on":
        return jnp.ones(int(K), bool)
    if process.kind == "bernoulli":
        return availability_mask(K, 1.0 - process.p_active,
                                 availability_key(process.seed), t)
    if process.kind == "straggler":
        return availability_mask(K, process.rates.astype(np.float32),
                                 availability_key(process.seed), t)
    t = jnp.asarray(t, jnp.int32)
    if process.kind == "arrival":
        return t >= jnp.asarray(process.t_join, jnp.int32)
    return t < jnp.asarray(process.t_leave, jnp.int32)


def availability_stream(process: Optional[AgentProcess], K: int,
                        rounds: int) -> np.ndarray:
    """(rounds, K) bool host replay of ``process`` — concretely
    evaluates the SAME draws the scanned drivers generate in-scan
    (bit-parity, like :func:`dropout` for links), which is how post-hoc
    Eq.-(11) billing prices exactly the wires active agents sent."""
    return np.stack([np.asarray(agent_availability(process, K, t))
                     for t in range(int(rounds))])


# -- uniform constructor for sweeps -----------------------------------------


def _near_square(K: int):
    r = int(np.sqrt(K))
    while K % r:
        r -= 1
    return r, K // r


FAMILIES = ("ring", "full", "torus", "small_world", "star", "cluster",
            "hierarchical")


def make(name: str, K: int, **kw) -> Topology:
    """Build any family at population size K with sensible defaults."""
    if name == "ring":
        return ring(K, **kw)
    if name == "full":
        return full(K)
    if name == "torus":
        return torus(*_near_square(K))
    if name == "small_world":
        kw.setdefault("k", min(4, 2 * ((K - 1) // 2)))
        return small_world(K, **kw)
    if name == "star":
        return star(K)
    if name == "cluster":
        per = kw.pop("devices_per_cluster", 4 if K % 4 == 0 else 2)
        if K % per:
            raise ValueError(f"K={K} not divisible by cluster size {per}")
        return clusters(K // per, per)
    if name == "hierarchical":
        per = kw.pop("devices_per_cluster", 4 if K % 4 == 0 else 2)
        if K % per:
            raise ValueError(f"K={K} not divisible by cluster size {per}")
        return hierarchical(K // per, per)
    raise ValueError(f"unknown topology family {name!r}; "
                     f"choose from {FAMILIES}")
