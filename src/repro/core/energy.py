"""End-to-end energy & communication footprint model — paper Eqs. (8)–(12).

Stage 1 (MAML at the data center), Eq. (8)–(9):
    E_ML(t0, Q) = E_ML^L(t0, Q) + E_ML^C(Q)
    E_ML^L = γ · t0 · Σ_{i≤Q} Σ_{k∈C_i} [B_a + β·B_b] · E0^C
    E_ML^C = t0 · Σ_{i≤Q} Σ_{k∈C_i} b(E_ik)/E_UL  +  Σ_{k≤K} b(W)/E_DL

Stage 2 (per-task FL adaptation), Eq. (10)–(11):
    E_FL(t_i) = t_i · Σ_{k∈C_i} B_i · E_k^C
              + b(W) · t_i · Σ_{k∈C_i} Σ_{h∈N_ki} 1/E_SL

Total (Eq. 12):  E = E_ML(t0, Q) + Σ_{i≤M} E_FL(t_i)

Efficiencies are expressed as in Sect. III-B: E_UL/E_DL/E_SL in bit/J,
computing in grad/J. When sidelink is unavailable, each SL message is
replaced by UL + γ·DL (Sect. III-A last paragraph).

The module also prices the SAME protocol on TPU v5e hardware (beyond-paper,
DESIGN.md §2): per-round FLOPs/bytes come from the compiled dry-run
(`launch/dryrun.py` / `benchmarks/roofline.py`) instead of Table I's
measured constants.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field, replace
from typing import Dict, Optional, Sequence

import numpy as np

MB = 1e6          # paper sizes are decimal MB
BYTE = 8.0        # bits per byte


# ---------------------------------------------------------------------------
# parameters (Table I defaults)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class EnergyParams:
    """All constants of Sect. III / Table I (SI units: J, s, bit)."""

    # computing
    P_datacenter: float = 590.0          # W (350 W GPU included)
    T_batch_datacenter: float = 0.020    # s per batch (GPU)
    P_device: float = 5.1                # W (Cortex-A72)
    T_batch_device: float = 0.400        # s per batch
    gamma: float = 1.67                  # PUE of the data center
    beta: float = 1.0                    # Jacobian factor (1 = first-order)

    # batches per round
    B_a: int = 10                        # task-adaptation batches (Eq. 3)
    B_b: int = 10                        # meta-update batches (Eq. 4)
    B_i: int = 20                        # device batches per FL round

    # data / model sizes (bits)
    data_bits: float = 24.6 * MB * BYTE  # b(E_ik), 24.6 MB
    model_bits: float = 5.6 * MB * BYTE  # b(W), 5.6 MB

    # communication efficiencies (bit/J)
    E_UL: float = 200e3
    E_DL: float = 200e3
    E_SL: float = 500e3
    sidelink_available: bool = True

    # topology
    devices_per_cluster: int = 2         # |C_i| (2 robots per cluster)
    meta_devices_per_task: int = 1       # robots streaming data per training
                                         # task during MAML (Sect. IV-A: the
                                         # Q=3 tasks' data comes from 3 robots)
    neighbors_per_device: int = 1        # |N_{k,i}| within the cluster
    K: int = 12                          # total devices (M=6 clusters × 2)

    # -- derived ------------------------------------------------------------
    @property
    def E0_C(self) -> float:
        """J per gradient at the data center, E0^C = P0 · T0 (Sect. III-A).

        Note Table I's measured "0.03 grad/J" is NOT equal to 1/(P0·T0)
        = 1/11.8 J; the measured figure folds in duty factors. Use
        ``from_grad_per_joule`` / ``paper_calibrated`` for the measured
        variants — see EXPERIMENTS.md §Paper-validation for the arithmetic."""
        return self.P_datacenter * self.T_batch_datacenter

    @property
    def Ek_C(self) -> float:
        """J per gradient on a device (P_k · T_k)."""
        return self.P_device * self.T_batch_device


PAPER_TABLE_I = EnergyParams()


def from_grad_per_joule(dc_grad_per_J: float = 0.03,
                        dev_grad_per_J: float = 0.16,
                        **kw) -> EnergyParams:
    """Table I's measured efficiencies: E_C = 0.03 grad/J (data center),
    0.16 grad/J (device) ⇒ E^C = 1/efficiency J per gradient."""
    p = EnergyParams(**kw)
    # back out P·T to match the requested J/grad with T fixed
    return replace(
        p,
        P_datacenter=(1.0 / dc_grad_per_J) / p.T_batch_datacenter,
        P_device=(1.0 / dev_grad_per_J) / p.T_batch_device,
    )


# ---------------------------------------------------------------------------
# Eq. (8)–(9): MAML stage
# ---------------------------------------------------------------------------


def maml_learning_energy(p: EnergyParams, t0: int, Q: int) -> float:
    """E_ML^(L)(t0, Q) — γ · t0 · Σ_i Σ_k [B_a + β B_b] E0^C."""
    per_round = (Q * p.meta_devices_per_task
                 * (p.B_a + p.beta * p.B_b) * p.E0_C)
    return p.gamma * t0 * per_round


def maml_comm_energy(p: EnergyParams, t0: int, Q: int) -> float:
    """E_ML^(C)(Q) — UL data collection each round + one DL model push."""
    ul = t0 * Q * p.meta_devices_per_task * p.data_bits / p.E_UL
    dl = p.K * p.model_bits / p.E_DL
    return ul + dl


def maml_energy(p: EnergyParams, t0: int, Q: int) -> float:
    """Eq. (8)."""
    if t0 <= 0:
        return 0.0
    return maml_learning_energy(p, t0, Q) + maml_comm_energy(p, t0, Q)


# ---------------------------------------------------------------------------
# Eq. (10)–(11): FL adaptation stage
# ---------------------------------------------------------------------------


def sidelink_cost_per_bit(p: EnergyParams) -> float:
    """1/E_SL, or the UL+γ·DL replacement when SL is unavailable."""
    if p.sidelink_available:
        return 1.0 / p.E_SL
    return 1.0 / p.E_UL + p.gamma / p.E_DL


def fl_learning_energy(p: EnergyParams, t_i: float, topology=None) -> float:
    """``topology`` must be ONE cluster C_i's graph (its K is the cluster's
    device count) — see :func:`fl_comm_energy`."""
    devices = p.devices_per_cluster if topology is None else topology.K
    return t_i * devices * p.B_i * p.Ek_C


def fl_comm_energy(p: EnergyParams, t_i: float, topology=None,
                   codec=None) -> float:
    """Eq.-(11) communication term. With a ``topology``
    (:class:`repro.core.topology.Topology`) the link count and per-link
    classes come from the graph's actual directed edges; without one, the
    legacy 2-robot constants ``devices_per_cluster × neighbors_per_device``
    are used (all-SL).

    ``codec`` (spec string or :class:`repro.comms.codecs.Codec`) prices
    each exchanged model at its WIRE size — ``codec.price_bits(b(W))``
    instead of the full-precision b(W) — making Eq. (11) codec-aware.

    ``topology`` is a SINGLE cluster C_i's graph — pass
    ``ClusterNetwork.cluster_topology()`` / ``topology.clusters(1, per)``.
    Eqs. (10)–(12) sum per task, so passing the whole population graph
    here would price every cluster's links into each task."""
    if topology is not None:
        return t_i * topology.round_comm_joules(p, codec=codec)
    bits = p.model_bits
    if codec is not None:
        from repro import comms     # deferred: avoid import cycles
        bits = comms.get_codec(codec).price_bits(bits)
    links = p.devices_per_cluster * p.neighbors_per_device
    return bits * t_i * links * sidelink_cost_per_bit(p)


def fl_energy(p: EnergyParams, t_i: float, topology=None,
              codec=None) -> float:
    """Eq. (10) for one task (cluster graph supplied via ``topology``)."""
    return (fl_learning_energy(p, t_i, topology)
            + fl_comm_energy(p, t_i, topology, codec))


# ---------------------------------------------------------------------------
# Eq. (12): total + split-point optimization (Fig. 4)
# ---------------------------------------------------------------------------


def total_energy(p: EnergyParams, t0: int, Q: int,
                 t_is: Sequence[float], topology=None,
                 codec=None) -> float:
    return maml_energy(p, t0, Q) + sum(fl_energy(p, t, topology, codec)
                                       for t in t_is)


def optimize_split(p: EnergyParams, Q: int,
                   rounds_by_t0: Dict[int, Sequence[float]]):
    """Given measured {t0: [t_1..t_M]} adaptation rounds (Table II), return
    (best_t0, best_E, {t0: E}) — the Fig. 4(a) analysis."""
    energies = {t0: total_energy(p, t0, Q, tis)
                for t0, tis in rounds_by_t0.items()}
    best_t0 = min(energies, key=energies.get)
    return best_t0, energies[best_t0], energies


# ---------------------------------------------------------------------------
# TPU v5e pricing of the same protocol (beyond-paper)
# ---------------------------------------------------------------------------

TPU_V5E = {
    "peak_flops_bf16": 197e12,     # FLOP/s per chip
    "hbm_bw": 819e9,               # B/s per chip
    "ici_bw": 50e9,                # B/s per link
    "chip_power": 200.0,           # W per chip (assumed board TDP)
    "host_pue": 1.1,               # modern DC PUE
}


@dataclass(frozen=True)
class RooflineTerms:
    """Per-step roofline terms (seconds) + inputs, from a compiled dry-run."""
    flops: float
    hbm_bytes: float
    collective_bytes: float
    chips: int
    peak_flops: float = TPU_V5E["peak_flops_bf16"]
    hbm_bw: float = TPU_V5E["hbm_bw"]
    link_bw: float = TPU_V5E["ici_bw"]

    @property
    def t_compute(self) -> float:
        return self.flops / (self.chips * self.peak_flops)

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / (self.chips * self.hbm_bw)

    @property
    def t_collective(self) -> float:
        return self.collective_bytes / (self.chips * self.link_bw)

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def step_time(self) -> float:
        """Roofline (no-overlap upper bound uses sum; we report max —
        perfectly-overlapped bound)."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    def energy_per_step(self, power: float = TPU_V5E["chip_power"],
                        pue: float = TPU_V5E["host_pue"]) -> float:
        """J per step: chips × W × roofline step time × PUE."""
        return pue * self.chips * power * self.step_time


def single_chip_terms(step_terms: RooflineTerms) -> RooflineTerms:
    """The same per-step workload on ONE chip: the whole FLOP/byte budget
    lands on a single device and there are no cross-chip collectives."""
    return replace(step_terms, chips=1, collective_bytes=0.0)


def tpu_energy_params(step_terms: RooflineTerms, model_bytes: float,
                      *, dcn_bit_per_joule: float = 5e9,
                      ici_bit_per_joule: float = 50e9,
                      **overrides) -> EnergyParams:
    """Map the paper's Table-I shape onto TPU constants: a 'gradient' is one
    compiled train step; UL/DL become DCN transfers; SL becomes ICI.

    The data-center role keeps the full ``step_terms.chips`` slice (so
    E0^C = chips · W · step_time = per-step energy at PUE 1); the device
    role is ONE chip running the same workload alone
    (:func:`single_chip_terms`), so Ek_C = W · single-chip step time.
    """
    single = single_chip_terms(step_terms)
    base = EnergyParams(
        P_datacenter=TPU_V5E["chip_power"] * step_terms.chips,
        T_batch_datacenter=step_terms.step_time,
        P_device=TPU_V5E["chip_power"],
        T_batch_device=single.step_time,
        gamma=TPU_V5E["host_pue"],
        model_bits=model_bytes * BYTE,
        E_UL=dcn_bit_per_joule, E_DL=dcn_bit_per_joule,
        E_SL=ici_bit_per_joule,
    )
    return replace(base, **overrides) if overrides else base


# ---------------------------------------------------------------------------
# calibrations reproducing the paper's reported numbers
# ---------------------------------------------------------------------------


def paper_calibrated(regime: str = "fig3") -> EnergyParams:
    """Constants that reproduce the paper's reported energies.

    Table I's units are ambiguous (its "200 kb/J" and "0.16 grad/J" cannot
    jointly reproduce Figs. 3–4 under any single reading; see
    EXPERIMENTS.md §Paper-validation for the arithmetic). Two calibrations:

    * ``fig3``: kB/J communication efficiencies + the measured grad/J device
      cost (1/0.16 = 6.25 J/grad) + near-zero data-center compute. Lands
      within ~10% of E_ML = 74 kJ, ΣE_FL = 32 kJ, no-MAML = 227 kJ, and the
      ≥2× headline.
    * ``fig4``: same comm constants with the lighter per-round device cost
      implied by Fig. 4's dashed curves (the paper's Fig. 4 and Fig. 3 are
      mutually inconsistent by ~2.3×) — reproduces the OPTIMUM-SHIFT claim:
      t0* = 42 when sidelink is cheap vs t0* = 132 when uplink is cheap.
    """
    base = replace(
        PAPER_TABLE_I,
        E_UL=200e3 * 8, E_DL=200e3 * 8, E_SL=500e3 * 8,   # 200/500 kB/J
        P_device=(1 / 0.16) / PAPER_TABLE_I.T_batch_device,
        P_datacenter=0.05 / PAPER_TABLE_I.T_batch_datacenter,
    )
    if regime == "fig3":
        return base
    if regime == "fig4":
        return replace(base, P_device=1.25 / PAPER_TABLE_I.T_batch_device)
    raise ValueError(regime)


def swap_ul_sl(p: EnergyParams) -> EnergyParams:
    """The paper's red-line regime: efficient UL, inefficient SL."""
    return replace(p, E_UL=p.E_SL, E_DL=p.E_SL, E_SL=p.E_UL)
