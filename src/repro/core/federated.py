"""Federated-learning runtimes: the decentralized per-cluster FL of the
paper (Sect. II-B) plus a FedAvg star-topology baseline, and the
"no inductive transfer" baseline (t0 = 0, random init) the paper compares
against in Fig. 3 (blue bars).
"""
from __future__ import annotations

from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import scanloop
from repro.core.engine import AsyncState, ConsensusEngine, where_active
from repro.optim import sgd, apply_updates


def local_steps(loss_fn, params, batches, lr: float):
    """B_i local SGD steps on one device (batches has leading step axis)."""

    def one(p, b):
        g = jax.grad(loss_fn)(p, b)
        p = jax.tree.map(lambda w, gw: (w.astype(jnp.float32)
                                        - lr * gw.astype(jnp.float32)
                                        ).astype(w.dtype), p, g)
        return p, None

    p, _ = jax.lax.scan(one, params, batches)
    return p


def decentralized_fl_round(loss_fn, stacked_params, stacked_batches,
                           engine, lr: float,
                           codec=None, codec_state=None, key=None,
                           t=None, mask=None, survival=None,
                           active=None):
    """One FL round, Eq. (6) semantics: every agent takes its local SGD
    steps, then one consensus mixing step through the engine.

    stacked_params / stacked_batches: leading agent axis K (vmapped).
    ``engine``: a :class:`repro.core.engine.ConsensusEngine` (the single
    consensus entry point), or a (K, K) σ matrix / Topology that is
    wrapped into one (``codec`` then applies to the wrapped engine;
    passing ``codec`` alongside a ready engine is an error).

    With a codec the return value is ``(params, new_codec_state)`` and
    the round's sidelink bytes are the codec's wire size (Eq. 11);
    without one it returns just the params as before. ``key`` enables
    stochastic rounding. ``t`` (round index, may be traced) drives
    engines with a time-varying
    :class:`~repro.core.topology.GraphProcess`: the round mixes over
    round ``t``'s surviving links (ignored by static engines). ``mask``
    passes that round's (K, K) survival mask explicitly;  ``survival``
    passes the round's PLAN-SHAPED survival a caller already drew via
    ``engine.round_survival(t)`` (the telemetry path draws it once and
    shares it between the mixing and the metrics row); ``engine.step``
    gives them precedence over ``t``, and the survival-bearing ops are
    the same either way, so results are bit-identical.

    ``active`` (async engines): the round's (K,) activity bools from
    ``engine.async_round(t, age)`` — inactive agents keep their
    pre-round params (their local SGD is discarded bit-exactly) and
    their post-mix params and codec residuals freeze, so a sleeping
    agent neither moves nor accumulates error-feedback state; pass the
    matching ``survival=round.weights`` alongside it.
    """
    engine = ConsensusEngine.wrap(engine, codec=codec)
    new_params = jax.vmap(
        lambda p, b: local_steps(loss_fn, p, b, lr))(stacked_params,
                                                     stacked_batches)
    if active is not None:
        # inactive agents skip local compute: hold the round's input
        new_params = where_active(active, new_params, stacked_params)
    # static engines ignore t (round_survival is None), so the traced
    # program is unchanged for them
    params, state = engine.step(new_params, codec_state, key, t=t,
                                mask=mask, survival=survival)
    if active is not None:
        # inactive receivers don't mix; their codec residuals hold too
        params = where_active(active, params, new_params)
        if state is not None:
            old_state = (codec_state if codec_state is not None
                         else engine.init_state(new_params))
            state = where_active(active, state, old_state)
    if engine.codec is None:
        return params
    return params, state


def fedavg_round(loss_fn, global_params, stacked_batches, weights,
                 lr: float):
    """Star-topology FedAvg baseline: server broadcasts, devices run local
    steps, server takes the data-size-weighted average."""
    K = weights.shape[0]
    stacked = jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (K,) + x.shape), global_params)
    locals_ = jax.vmap(
        lambda p, b: local_steps(loss_fn, p, b, lr))(stacked,
                                                     stacked_batches)
    w = (weights / weights.sum()).astype(jnp.float32)

    def avg(x):
        return jnp.einsum("k,k...->...", w, x.astype(jnp.float32)
                          ).astype(x.dtype)

    return jax.tree.map(avg, locals_)


def _fl_scan_program(loss_fn, engine, lr: float, *, sample_batches,
                     target_fn, stacked_params, key, max_rounds: int,
                     eval_every: int, telemetry=None,
                     telemetry_extra=None):
    """The ONE compiled FL round-loop program both drivers share: local
    SGD + ``engine.step`` + in-scan ``target_fn`` evaluation per round,
    with a ``lax.cond`` that FREEZES the carry (params, EF codec state,
    key) once an evaluated round reaches the target — every later round
    of the chunk is a no-op, so the params/state that come back are
    exactly the host loop's early-``break`` values, and the first-hit
    round (the paper's t_i) is recovered bit-exactly from the per-round
    reached mask. Rounds past ``max_rounds`` freeze the same way, which
    lets every chunk reuse one program when ``chunk ∤ max_rounds``.

    Batches are sampled INSIDE the scan from per-round split keys (same
    split order as the legacy host loop — identical PRNG stream);
    ``sample_batches``/``target_fn`` go through
    :func:`repro.core.scanloop.traceable`, so non-traceable host
    functions still work via ``jax.pure_callback``. ``lax.scan``
    compiles the same loop-body HLO for every chunk length, so a
    length-1 ``ts`` (host loop) and a length-``chunk`` ``ts`` produce
    bit-identical params, t_i, history, and codec state. The stacked
    params + EF-residual buffers are donated where the backend supports
    it (scanloop's donation invariant: never reuse a pytree after
    passing it in).

    Returns ``run_chunk(params, codec_state, key, reached, ts) ->
    ((params, codec_state, key, reached), (hit, evaled, metric))`` with
    one per-round row per ``ts`` entry.

    Programs are MEMOIZED through :func:`repro.core.scanloop.cached_program`
    on (loss_fn, sampler, target_fn — by identity; the engine — whose
    identity covers plan kind, codec, graph process, and concrete mix;
    the baked lr/max_rounds/eval_every scalars; and the carry's leaf
    shapes/dtypes), so Monte-Carlo sweeps that re-enter the drivers with
    an identical configuration reuse ONE jit object instead of
    re-tracing per call — the retrace counter
    ``scanloop.TRACE_COUNTS["fl_chunk"]`` only moves on genuine cache
    misses. Time-varying engines generate round ``t``'s survival mask
    in-scan (``decentralized_fl_round(t=...)``), so dropout sweeps stay
    device-resident too. Programs whose sampler/target FAILED the traced
    contract (the ``jax.pure_callback`` fallback) are NEVER cached: the
    probe consumes elements from stateful host samplers, so a cache hit
    that skipped it would shift the stream between the first and repeat
    invocations — impure round functions keep the per-call probe (and
    re-trace) the legacy drivers always had.

    Telemetry (:class:`repro.telemetry.Telemetry`): BUFFERED mode adds
    one pure per-round metrics row to the scan outputs (exact surviving
    per-class link counts, disagreement, metric, reached/live flags) —
    the program stays cache-admissible under a key extended with
    ``telemetry.trace_signature()`` so it never collides with the
    telemetry-off entry. STREAMING mode additionally plants a
    ``jax.debug.callback`` in the body; that callback closes over host
    state, so streaming programs are built per call and NEVER cached
    (rule JX4 audits that no cached program contains one).
    """
    streaming = telemetry is not None and telemetry.streaming
    cache_key = ("fl_chunk", loss_fn, sample_batches, target_fn, engine,
                 float(lr), int(max_rounds), int(eval_every),
                 scanloop.tree_signature(stacked_params))
    if telemetry is not None:
        cache_key = cache_key + (telemetry.trace_signature(),)
    if not streaming:
        cached = scanloop.get_cached_program(cache_key)
        if cached is not None:
            return cached              # hit: skip the probes entirely
    has_codec = engine.codec is not None
    recorder = (telemetry.recorder_for(engine)
                if telemetry is not None else None)
    stream_cb = (telemetry.stream_cb(recorder, "fl", telemetry_extra)
                 if streaming else None)
    sampler, sampler_traced = scanloop.traceable(
        sample_batches, key, jnp.int32(0), name="sample_batches")
    tfn, target_traced = scanloop.traceable(target_fn, stacked_params,
                                            name="target_fn")
    _, metric_sds = jax.eval_shape(tfn, stacked_params)

    is_async = engine.agents is not None

    def build():

        def body(carry, t):
            def live(c):
                if is_async:
                    p, st, k, _, ast = c
                else:
                    p, st, k, _ = c
                k, sk = jax.random.split(k)
                batches = sampler(sk, t)
                if is_async:
                    # one availability draw per round, shared between
                    # the staleness mixing weights, the per-agent
                    # freeze, and the telemetry row (billing only
                    # DELIVERED wires)
                    ar = engine.async_round(t, ast.age)
                    sv, act, sv_row = ar.weights, ar.act, ar.delivered
                else:
                    # telemetry shares ONE plan-shaped survival draw
                    # between the round's mixing and its row;
                    # engine.step gives survival= precedence over t=,
                    # so the survival-bearing ops are identical to the
                    # telemetry-off t= path (bit-parity)
                    sv = (engine.round_survival(t)
                          if telemetry is not None else None)
                    act, sv_row = None, sv
                if has_codec:
                    k, ck = jax.random.split(k)
                    p, st = decentralized_fl_round(
                        loss_fn, p, batches, engine, lr, codec_state=st,
                        key=ck, t=t, survival=sv, active=act)
                else:
                    p = decentralized_fl_round(loss_fn, p, batches, engine,
                                               lr, t=t, survival=sv,
                                               active=act)
                if is_async:
                    ast = AsyncState(
                        ast.clock + ar.act.astype(ast.clock.dtype),
                        ar.age)
                if eval_every == 1:
                    r, metric = tfn(p)
                    hit = jnp.asarray(r, bool)
                    do_eval = jnp.asarray(True)
                else:
                    # off-grid rounds skip the evaluation entirely (it may
                    # be an expensive rollout or a pure_callback host trip)
                    do_eval = (t + 1) % eval_every == 0

                    def evaluate(p_):
                        r_, m_ = tfn(p_)
                        return (jnp.asarray(r_, bool),
                                jnp.asarray(m_, metric_sds.dtype))

                    def skip(p_):
                        return (jnp.asarray(False),
                                jnp.zeros(metric_sds.shape,
                                          metric_sds.dtype))

                    hit, metric = jax.lax.cond(do_eval, evaluate, skip, p)
                ys = (hit, do_eval, jnp.asarray(metric, metric_sds.dtype))
                if telemetry is not None:
                    row = recorder.row(
                        p, sv_row,
                        metric=jnp.mean(jnp.asarray(metric, jnp.float32)),
                        reached=hit, live=jnp.asarray(True),
                        active=act,
                        age=(ar.age if is_async else None))
                    if stream_cb is not None:
                        jax.debug.callback(stream_cb, t, row, ordered=True)
                    ys = ys + (row,)
                if is_async:
                    return (p, st, k, hit, ast), ys
                return (p, st, k, hit), ys

            def frozen(c):
                ys = (c[3], jnp.asarray(False),
                      jnp.zeros(metric_sds.shape, metric_sds.dtype))
                if telemetry is not None:
                    row = recorder.frozen_row()
                    if stream_cb is not None:
                        jax.debug.callback(stream_cb, t, row, ordered=True)
                    ys = ys + (row,)
                return c, ys

            pred = jnp.logical_and(jnp.logical_not(carry[3]),
                                   t < max_rounds)
            return jax.lax.cond(pred, live, frozen, carry)

        if is_async:
            # the async carry additionally threads the AsyncState —
            # per-agent clocks and per-lane wire ages persist ACROSS
            # chunks (handed back to the host at each boundary like the
            # params), so chunked and per-round drivers see one
            # continuous availability history
            def run_chunk(p, st, k, r, ts, ast):
                scanloop.TRACE_COUNTS["fl_chunk"] += 1
                return jax.lax.scan(body, (p, st, k, r, ast), ts)
        else:
            def run_chunk(p, st, k, r, ts):
                # executes at TRACE time only: the counter moves exactly
                # when jax re-traces this chunk program (the tier-1
                # guard's signal)
                scanloop.TRACE_COUNTS["fl_chunk"] += 1
                return jax.lax.scan(body, (p, st, k, r), ts)

        # the async chunk's AsyncState (arg 5) is a carry like the
        # params/codec state: donate it too, or every chunk holds the
        # previous (clock, age) buffers alive alongside the new ones
        # (rule JX5 — a dropped alias doubles fleet-scale async memory)
        donate = (0, 1, 5) if is_async else (0, 1)
        return scanloop.donating_jit(run_chunk, donate_argnums=donate)

    if streaming or not (sampler_traced and target_traced):
        # streaming telemetry (host-closing debug_callback) and impure
        # round fns: built per call, never cached (JX1/JX4 domain)
        return build()
    return scanloop.cached_program(cache_key, build)


def _run_fl_chunked(loss_fn, stacked_params, sample_batches, engine, lr, *,
                    target_fn, max_rounds, key, eval_every, codec, chunk,
                    return_state, telemetry=None, telemetry_extra=None):
    """Shared chunked loop behind :func:`run_fl_until` (chunk=1) and
    :func:`run_fl_until_scan`: one program dispatch and ONE host sync
    (the chunk's reached mask + metric row, plus the telemetry rows
    when enabled) per chunk, early exit between chunks when any round
    hit."""
    engine = ConsensusEngine.wrap(engine, codec=codec)
    # copy-on-entry (donating backends only): donation then consumes
    # driver-owned buffers, never the caller's pytree
    stacked_params = scanloop.own(stacked_params)
    codec_state = (engine.init_state(stacked_params)
                   if engine.codec is not None else None)
    chunk = max(1, min(int(chunk), max_rounds))
    run_chunk = _fl_scan_program(
        loss_fn, engine, lr, sample_batches=sample_batches,
        target_fn=target_fn, stacked_params=stacked_params, key=key,
        max_rounds=max_rounds, eval_every=eval_every,
        telemetry=telemetry, telemetry_extra=telemetry_extra)
    recorder = (telemetry.recorder_for(engine)
                if telemetry is not None else None)

    history = []
    rounds_used = max_rounds
    reached = jnp.asarray(False)
    astate = (engine.init_async_state() if engine.agents is not None
              else None)
    for start in range(0, max_rounds, chunk):
        ts = jnp.arange(start, start + chunk, dtype=jnp.int32)
        if astate is not None:
            (stacked_params, codec_state, key, reached, astate), ys = \
                run_chunk(stacked_params, codec_state, key, reached, ts,
                          astate)
        else:
            (stacked_params, codec_state, key, reached), ys = run_chunk(
                stacked_params, codec_state, key, reached, ts)
        hits, evaled, metrics = (np.asarray(y) for y in ys[:3])  # ONE sync
        if telemetry is not None:
            telemetry.record_rounds(recorder, ys[3], start, driver="fl",
                                    extra=telemetry_extra)
        history.extend(float(m) for m, v in zip(metrics, evaled) if v)
        h = scanloop.first_hit(hits)
        if h is not None:
            rounds_used = start + h + 1
            break
    if return_state:
        return stacked_params, rounds_used, history, codec_state
    return stacked_params, rounds_used, history


def run_fl_until(loss_fn, stacked_params, sample_batches, engine,
                 lr: float, *, target_fn: Callable, max_rounds: int, key,
                 eval_every: int = 1, codec=None,
                 return_state: bool = False, telemetry=None,
                 telemetry_extra=None):
    """Drive decentralized FL rounds until ``target_fn(stacked_params) >=
    target`` (it returns (reached: bool, metric)) or ``max_rounds``.

    Returns (params, rounds_used, metric_history) — plus the final codec
    state with ``return_state=True``. This is how the paper's t_i (rounds
    to reach running reward R) is measured. ``engine`` may be a
    :class:`repro.core.engine.ConsensusEngine`, a σ matrix, or a
    Topology (the latter two are wrapped, with ``codec`` applied — the
    engine's plan bakes the concrete neighbour structure in at trace
    time).

    The engine codec's error-feedback residual state is threaded across
    rounds here (one residual pytree per agent, carried like the params).

    Host-loop driver: one program dispatch and one blocking
    device→host sync per ROUND. It drives the same compiled round
    program as :func:`run_fl_until_scan` (which syncs once per CHUNK
    and reproduces this loop's params, t_i, history, and codec state
    bit for bit) — use the scanned driver for sweeps, this one when a
    host decision is genuinely needed every round.
    """
    return _run_fl_chunked(
        loss_fn, stacked_params, sample_batches, engine, lr,
        target_fn=target_fn, max_rounds=max_rounds, key=key,
        eval_every=eval_every, codec=codec, chunk=1,
        return_state=return_state, telemetry=telemetry,
        telemetry_extra=telemetry_extra)


def run_fl_until_scan(loss_fn, stacked_params, sample_batches, engine,
                      lr: float, *, target_fn: Callable, max_rounds: int,
                      key, eval_every: int = 1, codec=None,
                      chunk: int = 32, return_state: bool = False,
                      telemetry=None, telemetry_extra=None):
    """Device-resident :func:`run_fl_until`: ``chunk`` FL rounds (local
    SGD + ``engine.step`` + in-scan ``target_fn`` evaluation) per
    compiled ``lax.scan`` program, ONE host sync per chunk instead of
    one per round.

    Exactness contract — this is NOT an approximation of the host loop
    (see :func:`_fl_scan_program` for how each property is enforced):

    * same PRNG stream: the key is carried through the scan and split
      per round in the host loop's order, with batches sampled in-scan
      (``sample_batches(key, round)`` should satisfy the traced-sampler
      contract of :mod:`repro.core.scanloop`; non-traceable samplers
      fall back to a ``jax.pure_callback`` round-trip with identical
      values);
    * ``lax.cond`` freeze on target hit: params, EF codec state, and
      key stop updating mid-chunk, and the exact first-hit round — the
      paper's t_i — is recovered from the per-round reached mask, so
      ``rounds_used``, params, history, and codec state are
      bit-identical to the host loop's early ``break``;
    * ``max_rounds`` need not be a multiple of ``chunk`` — tail rounds
      past it freeze the same way, keeping one compiled program.

    The chunk program donates the stacked params and EF-residual
    buffers on backends with donation support, so K-stacked populations
    update in place instead of doubling peak memory (never reuse the
    pytrees passed in — scanloop's donation invariant).

    ``telemetry`` (a :class:`repro.telemetry.Telemetry`) records one
    row per round — Eq.-(11) joules by link class, wire bits,
    surviving-edge counts, disagreement, reached flags — synced once
    per chunk (buffered mode, pure, cache-admissible) or additionally
    emitted live per round via ``jax.debug.callback`` (streaming mode,
    program built per call and never cached). Round results are
    bit-identical with telemetry off, buffered, or streaming.
    ``telemetry_extra``: optional dict merged into every emitted event
    (e.g. ``{"task_id": i}``).
    """
    return _run_fl_chunked(
        loss_fn, stacked_params, sample_batches, engine, lr,
        target_fn=target_fn, max_rounds=max_rounds, key=key,
        eval_every=eval_every, codec=codec, chunk=chunk,
        return_state=return_state, telemetry=telemetry,
        telemetry_extra=telemetry_extra)
