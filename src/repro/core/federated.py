"""Federated-learning runtimes: the decentralized per-cluster FL of the
paper (Sect. II-B) plus a FedAvg star-topology baseline, and the
"no inductive transfer" baseline (t0 = 0, random init) the paper compares
against in Fig. 3 (blue bars).
"""
from __future__ import annotations

from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core.engine import ConsensusEngine
from repro.optim import sgd, apply_updates


def local_steps(loss_fn, params, batches, lr: float):
    """B_i local SGD steps on one device (batches has leading step axis)."""

    def one(p, b):
        g = jax.grad(loss_fn)(p, b)
        p = jax.tree.map(lambda w, gw: (w.astype(jnp.float32)
                                        - lr * gw.astype(jnp.float32)
                                        ).astype(w.dtype), p, g)
        return p, None

    p, _ = jax.lax.scan(one, params, batches)
    return p


def decentralized_fl_round(loss_fn, stacked_params, stacked_batches,
                           engine, lr: float,
                           codec=None, codec_state=None, key=None):
    """One FL round, Eq. (6) semantics: every agent takes its local SGD
    steps, then one consensus mixing step through the engine.

    stacked_params / stacked_batches: leading agent axis K (vmapped).
    ``engine``: a :class:`repro.core.engine.ConsensusEngine` (the single
    consensus entry point), or a (K, K) σ matrix / Topology that is
    wrapped into one (``codec`` then applies to the wrapped engine;
    passing ``codec`` alongside a ready engine is an error).

    With a codec the return value is ``(params, new_codec_state)`` and
    the round's sidelink bytes are the codec's wire size (Eq. 11);
    without one it returns just the params as before. ``key`` enables
    stochastic rounding.
    """
    engine = ConsensusEngine.wrap(engine, codec=codec)
    new_params = jax.vmap(
        lambda p, b: local_steps(loss_fn, p, b, lr))(stacked_params,
                                                     stacked_batches)
    params, state = engine.step(new_params, codec_state, key)
    if engine.codec is None:
        return params
    return params, state


def fedavg_round(loss_fn, global_params, stacked_batches, weights,
                 lr: float):
    """Star-topology FedAvg baseline: server broadcasts, devices run local
    steps, server takes the data-size-weighted average."""
    K = weights.shape[0]
    stacked = jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (K,) + x.shape), global_params)
    locals_ = jax.vmap(
        lambda p, b: local_steps(loss_fn, p, b, lr))(stacked,
                                                     stacked_batches)
    w = (weights / weights.sum()).astype(jnp.float32)

    def avg(x):
        return jnp.einsum("k,k...->...", w, x.astype(jnp.float32)
                          ).astype(x.dtype)

    return jax.tree.map(avg, locals_)


def run_fl_until(loss_fn, stacked_params, sample_batches, engine,
                 lr: float, *, target_fn: Callable, max_rounds: int, key,
                 eval_every: int = 1, codec=None):
    """Drive decentralized FL rounds until ``target_fn(stacked_params) >=
    target`` (it returns (reached: bool, metric)) or ``max_rounds``.

    Returns (params, rounds_used, metric_history). This is how the paper's
    t_i (rounds to reach running reward R) is measured. ``engine`` may be
    a :class:`repro.core.engine.ConsensusEngine`, a σ matrix, or a
    Topology (the latter two are wrapped, with ``codec`` applied — the
    engine's plan bakes the concrete neighbour structure in at trace
    time).

    The engine codec's error-feedback residual state is threaded across
    rounds here (one residual pytree per agent, carried like the params).
    """
    engine = ConsensusEngine.wrap(engine, codec=codec)
    if engine.codec is not None:
        step = jax.jit(lambda sp, st, b, k: decentralized_fl_round(
            loss_fn, sp, b, engine, lr, codec_state=st, key=k))
        codec_state = engine.init_state(stacked_params)
    else:
        step = jax.jit(lambda sp, b: decentralized_fl_round(
            loss_fn, sp, b, engine, lr))
    history = []
    rounds_used = max_rounds
    for t in range(max_rounds):
        key, sk = jax.random.split(key)
        batches = sample_batches(sk, t)
        if engine.codec is not None:
            key, ck = jax.random.split(key)
            stacked_params, codec_state = step(stacked_params, codec_state,
                                               batches, ck)
        else:
            stacked_params = step(stacked_params, batches)
        if (t + 1) % eval_every == 0:
            reached, metric = target_fn(stacked_params)
            history.append(float(metric))
            if bool(reached):
                rounds_used = t + 1
                break
    return stacked_params, rounds_used, history
