"""Federated-learning runtimes: the decentralized per-cluster FL of the
paper (Sect. II-B) plus a FedAvg star-topology baseline, and the
"no inductive transfer" baseline (t0 = 0, random init) the paper compares
against in Fig. 3 (blue bars).
"""
from __future__ import annotations

from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core import consensus
from repro.optim import sgd, apply_updates


def local_steps(loss_fn, params, batches, lr: float):
    """B_i local SGD steps on one device (batches has leading step axis)."""

    def one(p, b):
        g = jax.grad(loss_fn)(p, b)
        p = jax.tree.map(lambda w, gw: (w.astype(jnp.float32)
                                        - lr * gw.astype(jnp.float32)
                                        ).astype(w.dtype), p, g)
        return p, None

    p, _ = jax.lax.scan(one, params, batches)
    return p


def decentralized_fl_round(loss_fn, stacked_params, stacked_batches,
                           mix, lr: float, impl: str = "xla",
                           codec=None, codec_state=None, key=None):
    """One FL round, Eq. (6) semantics: every agent takes its local SGD
    steps, then one consensus mixing step with the σ weights.

    stacked_params / stacked_batches: leading agent axis K (vmapped).
    ``mix`` may be a (K, K) σ matrix or a Topology; ``impl`` selects the
    consensus execution path (see :func:`consensus.consensus_step`).

    ``codec``: compress the exchanged models (:mod:`repro.comms`) —
    returns ``(params, new_codec_state)`` and the round's sidelink bytes
    become the codec's wire size (Eq. 11); without a codec, returns just
    the params as before. ``key`` enables stochastic rounding.
    """
    new_params = jax.vmap(
        lambda p, b: local_steps(loss_fn, p, b, lr))(stacked_params,
                                                     stacked_batches)
    if codec is None:
        return consensus.consensus_step(new_params, mix, impl=impl)
    return consensus.consensus_step(new_params, mix, impl=impl,
                                    codec=codec, codec_state=codec_state,
                                    key=key)


def fedavg_round(loss_fn, global_params, stacked_batches, weights,
                 lr: float):
    """Star-topology FedAvg baseline: server broadcasts, devices run local
    steps, server takes the data-size-weighted average."""
    K = weights.shape[0]
    stacked = jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (K,) + x.shape), global_params)
    locals_ = jax.vmap(
        lambda p, b: local_steps(loss_fn, p, b, lr))(stacked,
                                                     stacked_batches)
    w = (weights / weights.sum()).astype(jnp.float32)

    def avg(x):
        return jnp.einsum("k,k...->...", w, x.astype(jnp.float32)
                          ).astype(x.dtype)

    return jax.tree.map(avg, locals_)


def run_fl_until(loss_fn, stacked_params, sample_batches, mix, lr: float,
                 *, target_fn: Callable, max_rounds: int, key,
                 eval_every: int = 1, impl: str = "xla", codec=None):
    """Drive decentralized FL rounds until ``target_fn(stacked_params) >=
    target`` (it returns (reached: bool, metric)) or ``max_rounds``.

    Returns (params, rounds_used, metric_history). This is how the paper's
    t_i (rounds to reach running reward R) is measured. ``mix`` may be a
    σ matrix or a Topology (closed over so the sparse consensus paths see
    the concrete neighbour structure at trace time).

    ``codec``: spec string / Codec — compress every model exchange. The
    codec's error-feedback residual state is threaded across rounds here
    (one residual pytree per agent, carried like the params).
    """
    if codec is not None:
        from repro import comms
        codec = comms.resolve_codec(codec)
        step = jax.jit(lambda sp, st, b, k: decentralized_fl_round(
            loss_fn, sp, b, mix, lr, impl=impl, codec=codec,
            codec_state=st, key=k))
        codec_state = (codec.init_state(stacked_params)
                       if codec.stateful else None)
    else:
        step = jax.jit(lambda sp, b: decentralized_fl_round(
            loss_fn, sp, b, mix, lr, impl=impl))
    history = []
    rounds_used = max_rounds
    for t in range(max_rounds):
        key, sk = jax.random.split(key)
        batches = sample_batches(sk, t)
        if codec is not None:
            key, ck = jax.random.split(key)
            stacked_params, codec_state = step(stacked_params, codec_state,
                                               batches, ck)
        else:
            stacked_params = step(stacked_params, batches)
        if (t + 1) % eval_every == 0:
            reached, metric = target_fn(stacked_params)
            history.append(float(metric))
            if bool(reached):
                rounds_used = t + 1
                break
    return stacked_params, rounds_used, history
