"""ConsensusEngine — the single entry point for one Eq.-(6) mixing round.

The paper's energy balance (Eqs. 6/11) is evaluated per consensus round,
so the round executor is the hot path of every scaling experiment. This
module turns a ``(Topology, K, codec, mesh)`` description into an
execution **plan** once, at construction, and every caller
(:mod:`repro.core.protocol`, :mod:`repro.core.federated`,
:mod:`repro.rl.casestudy`, :mod:`repro.launch.train`, the scale
benchmark) drives the same ``engine.step(stacked_params, codec_state,
key) -> (params, codec_state)`` — no ``impl=`` strings or per-caller
path wiring.

Plans
-----
* ``dense-xla``     — the reference (K, K) matmul per leaf; the only plan
  that accepts a TRACED per-round mix override (time-varying topologies,
  :func:`repro.core.topology.dropout`).
* ``sparse-pallas`` — batched-over-agents sparse gather through the fused
  Pallas consensus kernels (the bit-identical jnp oracle off TPU);
  O(K·H·N) instead of O(K²·N).
* ``sharded``       — the sparse gather under shard_map over an agent
  axis: each mesh position owns a block of K/num_blocks agents, encodes
  its own block's wires, ``all_gather``s the (K, ·) WIRE (codec bytes,
  not f32), and mixes only its rows. No single program materializes the
  (K, K) stack, which is what lets K = 16384 populations mix on meshes
  of any size (and on one CPU via the vmap-with-axis_name emulation).
* ``distributed``   — one agent per mesh position; neighbour exchange is
  ``jax.lax.ppermute`` rounds from a host-computed permutation schedule,
  and the permuted payload is the CODEC wire: int8/int4 lanes + scales,
  bf16 casts. This makes ``Topology.round_comm_joules(codec=)`` pricing
  truthful on the one path that actually distributes across a mesh —
  an int8 wire ships (and prices) 4× below f32.

Wire formats per path: ``dense-xla`` mixes DECODED f32 models (the wire
is an accounting construct priced by Eq. 11); ``sparse-pallas`` and
``sharded`` gather the int-quantized wire itself through the fused
dequant-consensus kernel — int8/int4 lanes with per-tensor OR
block-wise ``int8:b64`` scales (other codecs decode before the
gather); ``distributed`` permutes the wire payload for every codec.

Multi-round programs: :meth:`ConsensusEngine.scan_rounds` runs R rounds
inside one ``lax.scan`` with the codec/EF state in the carry — the
building block of the chunked protocol drivers
(:func:`repro.core.federated.run_fl_until_scan`,
:func:`repro.core.maml.maml_train_scan`), which compile whole stretches
of the round loop into single programs and sync the host once per
chunk.

CHOCO mean-exactness invariant: every compressed plan recenters each
agent's update on its OWN decoded copy — W_k + Σ_h σ_{k,h}(x̂_h − x̂_k) —
so under doubly-stochastic σ the population mean is exactly preserved no
matter how lossy the codec; the error-feedback wrapper (on by default
for lossy codecs) telescopes the per-round quantization error. All four
plans therefore agree with the dense-f32 oracle to within the codec's
round-trip tolerance (tested at K = 256 in ``tests/test_engine.py``).

``plan="auto"`` selection: with no mesh, the payload-aware density
heuristic (:func:`repro.core.consensus.auto_path`) picks dense-xla vs
sparse-pallas; with a mesh carrying the agent axis, one-agent-per-
position meshes take ``distributed`` and everything else ``sharded``
(blocks = mesh axis size).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import numpy as np

from repro.core import consensus

PLAN_KINDS = ("dense-xla", "sparse-pallas", "sharded", "distributed")


@dataclass(frozen=True)
class ExecutionPlan:
    """A resolved consensus execution strategy (see module docstring)."""

    kind: str
    reason: str
    num_blocks: int = 1
    axis_name: str = "agents"

    def __post_init__(self):
        if self.kind not in PLAN_KINDS:
            raise ValueError(f"unknown plan {self.kind!r}; "
                             f"choose from {PLAN_KINDS} or 'auto'")


class ConsensusEngine:
    """One Eq.-(6) round behind one entry point (see module docstring).

    Arguments
    ---------
    topology:   a :class:`repro.core.topology.Topology` (preferred — also
                enables :meth:`round_comm_joules`) or a concrete (K, K)
                σ matrix.
    codec:      model-exchange codec spec/Codec (:mod:`repro.comms`);
                lossy codecs get the error-feedback wrapper unless
                ``error_feedback=False``.
    mesh:       optional ``jax.sharding.Mesh`` whose ``axis_name`` axis
                carries agents (one per position ⇒ distributed; blocks
                ⇒ sharded). ``None`` runs every plan in one program
                (sharded/distributed fall back to the vmap-with-
                axis_name emulation, which shares collective semantics).
    plan:       "auto" (default) or one of :data:`PLAN_KINDS`.
    num_blocks: block count for the sharded plan (default: mesh axis
                size, else 1).
    data_sizes / mix_kind / include_self: forwarded to the topology's
                ``mixing`` (uniform paper weights by default).
    gamma:      CHOCO consensus step size (damps off-diagonal σ).
    """

    def __init__(self, topology, *, codec=None, mesh=None,
                 plan: str = "auto", axis_name: str = "agents",
                 num_blocks: Optional[int] = None, data_sizes=None,
                 mix_kind: str = "paper", include_self: bool = True,
                 gamma: float = 1.0, error_feedback: bool = True,
                 block_n: Optional[int] = None):
        from repro import comms   # deferred: core stays import-light
        if isinstance(topology, ConsensusEngine):
            raise TypeError("pass a Topology or mix, not an engine "
                            "(use ConsensusEngine.wrap)")
        self.topology = topology if hasattr(topology, "mixing") else None
        self.mix = np.asarray(
            topology.mixing(data_sizes, kind=mix_kind,
                            include_self=include_self)
            if self.topology is not None else topology, np.float32)
        self.K = self.mix.shape[0]
        self.codec = comms.resolve_codec(codec, error_feedback)
        self.mesh = mesh
        self.gamma = float(gamma)
        self.block_n = block_n
        self.plan = self._resolve_plan(plan, axis_name, num_blocks)
        self._schedule = None          # distributed ppermute rounds, lazy

    # -- plan selection -----------------------------------------------------
    def _resolve_plan(self, plan: str, axis_name: str,
                      num_blocks: Optional[int]) -> ExecutionPlan:
        mesh_axis = consensus._mesh_axis(self.mesh, axis_name)
        if plan == "auto":
            if mesh_axis is not None:
                if mesh_axis == self.K:
                    return ExecutionPlan(
                        "distributed", "mesh holds one agent per "
                        f"'{axis_name}' position", 1, axis_name)
                nb = num_blocks or mesh_axis
                if self.K % nb:
                    # a mesh was given: honour it — fall back to the
                    # largest block count that divides K rather than
                    # silently reverting to a single-program plan
                    nb = next(d for d in range(min(nb, self.K), 0, -1)
                              if self.K % d == 0)
                return ExecutionPlan(
                    "sharded", f"K={self.K} agents in {nb} blocks over "
                    f"the {mesh_axis}-wide '{axis_name}' mesh axis",
                    nb, axis_name)
            base = getattr(self.codec, "inner", self.codec)
            dense = consensus.auto_path(self.mix, codec=base) == "dense"
            return ExecutionPlan(
                "dense-xla" if dense else "sparse-pallas",
                "payload-aware density heuristic "
                f"(max degree vs K={self.K})", 1, axis_name)
        if plan == "sharded":
            nb = num_blocks or mesh_axis or 1
            return ExecutionPlan("sharded", "explicit", nb, axis_name)
        return ExecutionPlan(plan, "explicit", num_blocks or 1, axis_name)

    # -- state --------------------------------------------------------------
    def init_state(self, stacked_params):
        """Initial codec state (stacked EF residuals; None if stateless)."""
        if self.codec is None or not self.codec.stateful:
            return None
        return self.codec.init_state(stacked_params)

    # -- the round ----------------------------------------------------------
    def step(self, stacked_params, codec_state=None, key=None, *, mix=None):
        """One Eq.-(6) consensus round on agent-stacked params (leading
        axis K). Returns ``(new_stacked_params, new_codec_state)`` for
        EVERY plan and codec (state is None for codec-free rounds).

        ``key`` enables stochastic rounding for quantizing codecs.
        ``mix`` overrides the engine's σ matrix for THIS round (may be
        traced — time-varying topologies under jit); only the dense-xla
        plan supports it, every other plan bakes the neighbour structure
        in at trace time.
        """
        kind = self.plan.kind
        if mix is not None and kind != "dense-xla":
            raise ValueError(
                f"per-round mix overrides need the dense-xla plan, not "
                f"{kind!r} (sparse structure is fixed at trace time)")
        mix_ = self.mix if mix is None else mix
        if kind == "dense-xla" or kind == "sparse-pallas":
            impl = "xla" if kind == "dense-xla" else "sparse"
            if self.codec is None:
                return consensus.consensus_step(
                    stacked_params, mix_, impl=impl,
                    block_n=self.block_n), None
            # error_feedback=False: self.codec is ALREADY resolved (the
            # EF default was applied at engine construction) — the step
            # functions must not re-wrap it
            return consensus.consensus_step(
                stacked_params, mix_, impl=impl, block_n=self.block_n,
                codec=self.codec, codec_state=codec_state, key=key,
                gamma=self.gamma, error_feedback=False)
        if kind == "sharded":
            return consensus.sharded_consensus_step(
                stacked_params, mix_, num_blocks=self.plan.num_blocks,
                axis_name=self.plan.axis_name, mesh=self.mesh,
                codec=self.codec, codec_state=codec_state, key=key,
                gamma=self.gamma, block_n=self.block_n,
                error_feedback=False)
        if self._schedule is None:
            self._schedule = consensus.permutation_schedule(
                self.mix, self.gamma)
        return consensus.distributed_consensus_step(
            stacked_params, mix_, axis_name=self.plan.axis_name,
            mesh=self.mesh, codec=self.codec, codec_state=codec_state,
            key=key, gamma=self.gamma, schedule=self._schedule,
            error_feedback=False)

    def scan_rounds(self, stacked_params, codec_state=None, keys=None, *,
                    rounds: Optional[int] = None):
        """Run many Eq.-(6) rounds inside ONE ``jax.lax.scan`` program.

        ``keys``: optional (R, …) stacked PRNG keys, one per round
        (stochastic rounding); without them pass ``rounds=R`` and every
        round runs key-free. The codec / error-feedback state threads
        through the scan carry for all four plans (``codec_state=None``
        initializes stacked zero residuals for stateful codecs), and the
        distributed plan's host-side ppermute permutation schedule is
        resolved HERE, before the scan body is traced, so the loop body
        contains only the collectives. Returns ``(params, codec_state)``
        after R rounds — bit-identical to R successive :meth:`step`
        calls. Trace-time structure (sparse gathers, schedules) is baked
        once per program instead of once per round, which is what the
        chunked drivers (:func:`repro.core.federated.run_fl_until_scan`,
        :func:`repro.core.maml.maml_train_scan`) and the ``rounds_loop``
        benchmark build on.
        """
        if keys is None and rounds is None:
            raise ValueError("pass per-round keys or rounds=")
        if codec_state is None:
            codec_state = self.init_state(stacked_params)
        if self.plan.kind == "distributed" and self._schedule is None:
            # hoist the host-computed schedule out of the scan body
            self._schedule = consensus.permutation_schedule(
                self.mix, self.gamma)

        def body(carry, k):
            p, st = self.step(carry[0], carry[1], k)
            return (p, st), None

        if keys is None:
            (p, st), _ = jax.lax.scan(
                lambda c, _x: body(c, None), (stacked_params, codec_state),
                None, length=int(rounds))
        else:
            (p, st), _ = jax.lax.scan(
                body, (stacked_params, codec_state), keys)
        return p, st

    # -- Eq.-(11) pricing ---------------------------------------------------
    def round_comm_joules(self, energy_params,
                          model_bits: Optional[float] = None) -> float:
        """Eq.-(11) communication energy of ONE round at THIS engine's
        wire format (delegates to the topology's codec-aware pricing)."""
        if self.topology is None:
            raise ValueError("engine was built from a raw mix matrix; "
                             "construct it from a Topology to price rounds")
        return self.topology.round_comm_joules(
            energy_params, model_bits=model_bits, codec=self.codec)

    # -- conveniences -------------------------------------------------------
    @classmethod
    def wrap(cls, obj, **kw) -> "ConsensusEngine":
        """Coerce ``obj`` (engine, Topology, or concrete mix) to an
        engine; extra kwargs only apply when constructing a new one."""
        if isinstance(obj, cls):
            if any(v is not None for v in kw.values()):
                raise ValueError(
                    f"{sorted(k for k, v in kw.items() if v is not None)} "
                    "cannot be re-specified for an existing engine")
            return obj
        return cls(obj, **kw)

    def __repr__(self):
        codec = self.codec.name if self.codec is not None else None
        return (f"ConsensusEngine(K={self.K}, plan={self.plan.kind!r}, "
                f"codec={codec!r}, blocks={self.plan.num_blocks})")
