"""ConsensusEngine — the single entry point for one Eq.-(6) mixing round.

The paper's energy balance (Eqs. 6/11) is evaluated per consensus round,
so the round executor is the hot path of every scaling experiment. This
module turns a ``(Topology, K, codec, mesh)`` description into an
execution **plan** once, at construction, and every caller
(:mod:`repro.core.protocol`, :mod:`repro.core.federated`,
:mod:`repro.rl.casestudy`, :mod:`repro.launch.train`, the scale
benchmark) drives the same ``engine.step(stacked_params, codec_state,
key) -> (params, codec_state)`` — no ``impl=`` strings or per-caller
path wiring.

Plans
-----
* ``dense-xla``     — the reference (K, K) matmul per leaf; also accepts
  a TRACED per-round full mix override via ``step(mix=...)`` (the legacy
  time-varying hook, kept for raw-σ callers).
* ``sparse-pallas`` — batched-over-agents sparse gather through the fused
  Pallas consensus kernels (the bit-identical jnp oracle off TPU);
  O(K·H·N) instead of O(K²·N).
* ``sharded``       — the sparse gather under shard_map over an agent
  axis: each mesh position owns a block of K/num_blocks agents, encodes
  its own block's wires, ``all_gather``s the (K, ·) WIRE (codec bytes,
  not f32), and mixes only its rows. No single program materializes the
  (K, K) stack, which is what lets K = 16384 populations mix on meshes
  of any size (and on one CPU via the vmap-with-axis_name emulation).
* ``distributed``   — one agent per mesh position; neighbour exchange is
  ``jax.lax.ppermute`` rounds from a host-computed permutation schedule,
  and the permuted payload is the CODEC wire: int8/int4 lanes + scales,
  bf16 casts. This makes ``Topology.round_comm_joules(codec=)`` pricing
  truthful on the one path that actually distributes across a mesh —
  an int8 wire ships (and prices) 4× below f32.

Wire formats per path: ``dense-xla`` mixes DECODED f32 models (the wire
is an accounting construct priced by Eq. 11); ``sparse-pallas`` and
``sharded`` gather the int-quantized wire itself through the fused
dequant-consensus kernel — int8/int4 lanes with per-tensor OR
block-wise ``int8:b64`` scales (other codecs decode before the
gather); ``distributed`` permutes the wire payload for every codec.

Time-varying graphs (:class:`repro.core.topology.GraphProcess`)
---------------------------------------------------------------
``ConsensusEngine(topo, graph=GraphProcess.dropout(p, seed))`` resolves
a time-varying graph process ONCE at construction, making per-round
link failures a capability of every maskable plan instead of a
dense-only traced-mix hack. Each round ``t``, :meth:`round_mask` draws
the (K, K) edge-survival mask in-scan from ``fold_in(PRNGKey(seed), t)``
(:func:`repro.core.topology.survival_mask` — symmetric graphs fade
whole undirected pairs, self loops are kept) and :meth:`masked_mixing`
REBUILDS the σ matrix on the surviving graph with the engine's mixing
kind, so dropped links reallocate their σ mass (doubly-stochastic kinds
stay doubly stochastic on every surviving subgraph). Per plan:

* ``dense-xla``     — the masked mix rides the matmul as a traced
  operand;
* ``sparse-pallas`` / ``sharded`` — the gather INDICES stay baked from
  the full base graph; the per-round renormalized σ is gathered into
  the (K, H) lane table and rides the fused (dequant-)consensus kernels
  as a traced operand, so faded neighbour lanes simply carry σ = 0
  (exact no-ops) — one compiled program for every round;
* ``distributed``   — unsupported (its ppermute schedule is a
  host-resolved trace-time structure); construction raises.

Masks are bit-identical to the host :func:`repro.core.topology.dropout`
stream via the shared fold-in convention, which is what lets callers
bill Eq.-(11) joules post hoc over exactly the rounds used with ZERO
host-side per-round graph prefetch.

COST NOTE: each masked round draws a (K, K) uniform and rebuilds the
(K, K) σ in-scan before gathering the (K, H) lane weights — O(K²) work
and memory per round even on the sparse/sharded plans. That is free at
the populations the time-varying paths target (the 12-robot case study,
K ≤ O(10³) sweeps) but re-introduces a quadratic term the sharded plan
otherwise avoids at K ≫ 10⁴; huge populations should keep static
graphs, use precomputed ``GraphProcess.schedule`` masks, or wait for
the per-lane draw convention (ROADMAP).

Multi-round programs: :meth:`ConsensusEngine.scan_rounds` runs R rounds
inside one ``lax.scan`` with the codec/EF state in the carry — the
building block of the chunked protocol drivers
(:func:`repro.core.federated.run_fl_until_scan`,
:func:`repro.core.maml.maml_train_scan`), which compile whole stretches
of the round loop into single programs and sync the host once per
chunk.

CHOCO mean-exactness invariant: every compressed plan recenters each
agent's update on its OWN decoded copy — W_k + Σ_h σ_{k,h}(x̂_h − x̂_k) —
so under doubly-stochastic σ the population mean is exactly preserved no
matter how lossy the codec; the error-feedback wrapper (on by default
for lossy codecs) telescopes the per-round quantization error. All four
plans therefore agree with the dense-f32 oracle to within the codec's
round-trip tolerance (tested at K = 256 in ``tests/test_engine.py``).

``plan="auto"`` selection: with no mesh, the payload-aware density
heuristic (:func:`repro.core.consensus.auto_path`) picks dense-xla vs
sparse-pallas; with a mesh carrying the agent axis, one-agent-per-
position meshes take ``distributed`` and everything else ``sharded``
(blocks = mesh axis size).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import consensus

PLAN_KINDS = ("dense-xla", "sparse-pallas", "sharded", "distributed")
#: plans that accept a per-round survival mask (traced σ operands); the
#: distributed plan's ppermute schedule is host-resolved at trace time
#: and cannot re-route around faded links without a retrace.
MASKABLE_PLANS = ("dense-xla", "sparse-pallas", "sharded")

#: per-plan compiled-artifact expectations ``repro.analysis`` keys on.
#: ``kk_buffer``: whether the plan's program may legitimately
#: materialize a (K, K) tensor (the dense σ stack); the sharded and
#: distributed plans exist precisely so it never does, and the HLO
#: auditor (rule H1) fails them if one appears at K ≥ its threshold.
#: ``wire_collective``: which collective carries the codec WIRE on a
#: real mesh — the op whose result bytes rule H2 reconciles against
#: ``codec.bits()`` pricing. ``int_lane_gather``: the plan mixes
#: int-codec wires through a fused gather that must keep int8/int4
#: lanes (the decode-then-combine regression class, rule JX2).
PLAN_AUDIT_EXPECTATIONS = {
    "dense-xla":     {"kk_buffer": True, "wire_collective": None,
                      "int_lane_gather": False},
    "sparse-pallas": {"kk_buffer": False, "wire_collective": None,
                      "int_lane_gather": True},
    "sharded":       {"kk_buffer": False, "wire_collective": "all-gather",
                      "int_lane_gather": True},
    "distributed":   {"kk_buffer": False,
                      "wire_collective": "collective-permute",
                      "int_lane_gather": False},
}


@dataclass(frozen=True)
class ExecutionPlan:
    """A resolved consensus execution strategy (see module docstring)."""

    kind: str
    reason: str
    num_blocks: int = 1
    axis_name: str = "agents"

    def __post_init__(self):
        if self.kind not in PLAN_KINDS:
            raise ValueError(f"unknown plan {self.kind!r}; "
                             f"choose from {PLAN_KINDS} or 'auto'")


class ConsensusEngine:
    """One Eq.-(6) round behind one entry point (see module docstring).

    Arguments
    ---------
    topology:   a :class:`repro.core.topology.Topology` (preferred — also
                enables :meth:`round_comm_joules`) or a concrete (K, K)
                σ matrix.
    codec:      model-exchange codec spec/Codec (:mod:`repro.comms`);
                lossy codecs get the error-feedback wrapper unless
                ``error_feedback=False``.
    mesh:       optional ``jax.sharding.Mesh`` whose ``axis_name`` axis
                carries agents (one per position ⇒ distributed; blocks
                ⇒ sharded). ``None`` runs every plan in one program
                (sharded/distributed fall back to the vmap-with-
                axis_name emulation, which shares collective semantics).
    plan:       "auto" (default) or one of :data:`PLAN_KINDS`.
    num_blocks: block count for the sharded plan (default: mesh axis
                size, else 1).
    data_sizes / mix_kind / include_self: forwarded to the topology's
                ``mixing`` (uniform paper weights by default) and reused
                to REBUILD the per-round mix on surviving subgraphs when
                a time-varying ``graph`` is attached.
    gamma:      CHOCO consensus step size (damps off-diagonal σ).
    graph:      a :class:`repro.core.topology.GraphProcess` (or None ⇒
                static). Non-static processes turn every maskable plan
                time-varying: each round's edge-survival mask is drawn
                in-scan from the folded process key and the σ is rebuilt
                on the surviving graph (see the module docstring). The
                ``distributed`` plan refuses non-static processes here,
                at construction.
    """

    def __init__(self, topology, *, codec=None, mesh=None,
                 plan: str = "auto", axis_name: str = "agents",
                 num_blocks: Optional[int] = None, data_sizes=None,
                 mix_kind: str = "paper", include_self: bool = True,
                 gamma: float = 1.0, error_feedback: bool = True,
                 block_n: Optional[int] = None, graph=None):
        from repro import comms   # deferred: core stays import-light
        from repro.core import topology as topo_lib
        if isinstance(topology, ConsensusEngine):
            raise TypeError("pass a Topology or mix, not an engine "
                            "(use ConsensusEngine.wrap)")
        self.topology = topology if hasattr(topology, "mixing") else None
        self.mix = np.asarray(
            topology.mixing(data_sizes, kind=mix_kind,
                            include_self=include_self)
            if self.topology is not None else topology, np.float32)
        self.K = self.mix.shape[0]
        self.codec = comms.resolve_codec(codec, error_feedback)
        self.mesh = mesh
        self.gamma = float(gamma)
        self.block_n = block_n
        self.mix_kind = mix_kind
        self.include_self = include_self
        self.data_sizes = (None if data_sizes is None
                           else np.asarray(data_sizes, np.float32))
        self.graph = graph if graph is not None else topo_lib.GraphProcess.static()
        self.plan = self._resolve_plan(plan, axis_name, num_blocks)
        self._schedule = None          # distributed ppermute rounds, lazy
        self._masked_struct = None     # (idx, lane-valid) for masked sig
        if self.graph.kind != "static":
            if self.plan.kind not in MASKABLE_PLANS:
                raise ValueError(
                    f"time-varying graphs ({self.graph!r}) are not "
                    f"supported on the {self.plan.kind!r} plan — its "
                    "ppermute schedule is resolved on the host at trace "
                    "time; use one of the maskable plans "
                    f"{MASKABLE_PLANS} (or prefetch concrete Topology "
                    "objects via repro.core.topology.dropout)")
            if self.topology is None:
                # a raw σ matrix's generating rule is unknown, so the
                # per-round rebuild would silently REPLACE the caller's
                # weights with mixing_weights(kind) on the survivor —
                # refuse rather than diverge
                raise ValueError(
                    "time-varying graphs need an engine built from a "
                    "Topology: each round's σ is REBUILT from the "
                    "surviving graph with the engine's mixing "
                    "kind/data_sizes, which cannot faithfully "
                    "renormalize an arbitrary raw mix matrix")
            # the base adjacency the survival masks apply to
            self._adjacency = np.asarray(self.topology.adjacency, bool)
            self._symmetric = bool(
                (self._adjacency == self._adjacency.T).all())
            if self.graph.kind == "dropout":
                self._graph_key = topo_lib.survival_key(self.graph.seed)
            elif self.graph.masks.shape[1:] != (self.K, self.K):
                raise ValueError(
                    f"schedule masks are {self.graph.masks.shape[1:]}, "
                    f"population is K={self.K}")

    # -- plan selection -----------------------------------------------------
    def _resolve_plan(self, plan: str, axis_name: str,
                      num_blocks: Optional[int]) -> ExecutionPlan:
        mesh_axis = consensus._mesh_axis(self.mesh, axis_name)
        if plan == "auto":
            if mesh_axis is not None:
                if mesh_axis == self.K:
                    return ExecutionPlan(
                        "distributed", "mesh holds one agent per "
                        f"'{axis_name}' position", 1, axis_name)
                nb = num_blocks or mesh_axis
                if self.K % nb:
                    # a mesh was given: honour it — fall back to the
                    # largest block count that divides K rather than
                    # silently reverting to a single-program plan
                    nb = next(d for d in range(min(nb, self.K), 0, -1)
                              if self.K % d == 0)
                return ExecutionPlan(
                    "sharded", f"K={self.K} agents in {nb} blocks over "
                    f"the {mesh_axis}-wide '{axis_name}' mesh axis",
                    nb, axis_name)
            base = getattr(self.codec, "inner", self.codec)
            dense = consensus.auto_path(self.mix, codec=base) == "dense"
            return ExecutionPlan(
                "dense-xla" if dense else "sparse-pallas",
                "payload-aware density heuristic "
                f"(max degree vs K={self.K})", 1, axis_name)
        if plan == "sharded":
            nb = num_blocks or mesh_axis or 1
            return ExecutionPlan("sharded", "explicit", nb, axis_name)
        return ExecutionPlan(plan, "explicit", num_blocks or 1, axis_name)

    # -- state --------------------------------------------------------------
    def init_state(self, stacked_params):
        """Initial codec state (stacked EF residuals; None if stateless)."""
        if self.codec is None or not self.codec.stateful:
            return None
        return self.codec.init_state(stacked_params)

    # -- time-varying graphs ------------------------------------------------
    def round_mask(self, t):
        """(K, K) bool edge-survival mask of round ``t`` under this
        engine's :class:`~repro.core.topology.GraphProcess` (None for a
        static graph). ``t`` may be TRACED — this is what the scanned
        drivers call per round INSIDE ``lax.scan``, and by the shared
        fold-in convention the result is bit-identical to round ``t`` of
        the host :func:`repro.core.topology.dropout` stream."""
        from repro.core import topology as topo_lib
        if self.graph.kind == "static":
            return None
        if self.graph.kind == "dropout":
            return topo_lib.survival_mask(
                self._adjacency, self.graph.p, self._graph_key, t,
                symmetric=self._symmetric)
        masks = jnp.asarray(self.graph.masks)          # schedule
        return jnp.asarray(self._adjacency) & masks[
            jnp.asarray(t) % masks.shape[0]]

    def masked_mixing(self, mask):
        """Rebuild the σ matrix on the SURVIVING graph (possibly traced
        mask): the engine's mixing kind / data sizes / include_self are
        re-applied to ``adjacency & mask``, so dropped links reallocate
        their σ mass exactly as ``Topology.mixing`` would on the
        host-materialized survivor (bit-identical — same jnp ops)."""
        sizes = (np.ones(self.K, np.float32) if self.data_sizes is None
                 else self.data_sizes)
        return consensus.mixing_weights(
            sizes, mask, self.mix_kind, include_self=self.include_self)

    def _masked_structure(self, mix_t):
        """(idx, sig_t) for the sparse/sharded plans: the CONCRETE
        full-graph lane indices (baked once, lazily) and the per-round σ
        gathered from the masked mix — faded lanes land at σ = 0, so the
        fused kernels skip them exactly without rebuilding the gather."""
        if self._masked_struct is None:
            # numpy constants: this cache outlives any one trace, so it
            # must never hold tracer-backed arrays
            idx_np, _ = consensus.sparse_structure(self.mix)
            self._masked_struct = (idx_np, np.arange(self.K)[:, None])
        idx, rows = self._masked_struct
        # padding lanes index the agent itself; mix_t's diagonal is 0
        # (self weight is implicit), so they stay exact no-ops
        return jnp.asarray(idx), jnp.asarray(mix_t, jnp.float32)[rows, idx]

    # -- the round ----------------------------------------------------------
    def step(self, stacked_params, codec_state=None, key=None, *, mix=None,
             t=None, mask=None):
        """One Eq.-(6) consensus round on agent-stacked params (leading
        axis K). Returns ``(new_stacked_params, new_codec_state)`` for
        EVERY plan and codec (state is None for codec-free rounds).

        ``key`` enables stochastic rounding for quantizing codecs.

        Time-varying graphs: ``t`` (round index, may be traced) draws
        the round's survival mask from the engine's graph process —
        the preferred entry point for the scanned drivers; ``mask``
        passes an explicit (K, K) bool survival mask instead (e.g. a
        host-prefetched :func:`topology.dropout` round). Both rebuild σ
        on the surviving graph via :meth:`masked_mixing` and run it as
        a traced operand — dense-xla takes the full masked mix, the
        sparse-pallas/sharded gathers take the per-lane σ with faded
        lanes zeroed (indices stay baked). The distributed plan raises.

        ``mix`` overrides the engine's σ matrix wholesale for THIS round
        (may be traced); only the dense-xla plan supports it, every
        other plan bakes the neighbour structure in at trace time.
        """
        kind = self.plan.kind
        if mix is not None and kind != "dense-xla":
            raise ValueError(
                f"per-round mix overrides need the dense-xla plan, not "
                f"{kind!r} (sparse structure is fixed at trace time; "
                "time-varying graphs go through mask=/t= instead)")
        if mask is None and t is not None:
            mask = self.round_mask(t)
        if mask is None and mix is None and self.graph.kind != "static":
            # silently mixing on the full static graph would measure t_i
            # (and bill Eq.-11) on a never-fading network — fail loudly
            raise ValueError(
                f"this engine carries a time-varying {self.graph!r}: "
                "step() needs the round index (t=) or an explicit "
                "survival mask (mask=); use scan_rounds for whole "
                "round loops")
        structure = None
        if mask is not None:
            if mix is not None:
                raise ValueError("pass mix= or mask=/t=, not both")
            if kind not in MASKABLE_PLANS:
                raise ValueError(
                    f"per-round survival masks are not supported on the "
                    f"{kind!r} plan (host-resolved ppermute schedule); "
                    f"use one of {MASKABLE_PLANS}")
            mix_t = self.masked_mixing(mask)
            if kind == "dense-xla":
                mix = mix_t
            else:
                structure = self._masked_structure(mix_t)
        mix_ = self.mix if mix is None else mix
        if kind == "dense-xla" or kind == "sparse-pallas":
            impl = "xla" if kind == "dense-xla" else "sparse"
            if self.codec is None:
                return consensus.consensus_step(
                    stacked_params, mix_, impl=impl,
                    block_n=self.block_n, structure=structure), None
            # error_feedback=False: self.codec is ALREADY resolved (the
            # EF default was applied at engine construction) — the step
            # functions must not re-wrap it
            return consensus.consensus_step(
                stacked_params, mix_, impl=impl, block_n=self.block_n,
                codec=self.codec, codec_state=codec_state, key=key,
                gamma=self.gamma, error_feedback=False,
                structure=structure)
        if kind == "sharded":
            return consensus.sharded_consensus_step(
                stacked_params, mix_, num_blocks=self.plan.num_blocks,
                axis_name=self.plan.axis_name, mesh=self.mesh,
                codec=self.codec, codec_state=codec_state, key=key,
                gamma=self.gamma, block_n=self.block_n,
                error_feedback=False, structure=structure)
        if self._schedule is None:
            self._schedule = consensus.permutation_schedule(
                self.mix, self.gamma)
        return consensus.distributed_consensus_step(
            stacked_params, mix_, axis_name=self.plan.axis_name,
            mesh=self.mesh, codec=self.codec, codec_state=codec_state,
            key=key, gamma=self.gamma, schedule=self._schedule,
            error_feedback=False)

    def scan_rounds(self, stacked_params, codec_state=None, keys=None, *,
                    rounds: Optional[int] = None, t0=0, telemetry=None):
        """Run many Eq.-(6) rounds inside ONE ``jax.lax.scan`` program.

        ``keys``: optional (R, …) stacked PRNG keys, one per round
        (stochastic rounding); without them pass ``rounds=R`` and every
        round runs key-free. The codec / error-feedback state threads
        through the scan carry for all four plans (``codec_state=None``
        initializes stacked zero residuals for stateful codecs), and the
        distributed plan's host-side ppermute permutation schedule is
        resolved HERE, before the scan body is traced, so the loop body
        contains only the collectives. Returns ``(params, codec_state)``
        after R rounds — bit-identical to R successive :meth:`step`
        calls. Trace-time structure (sparse gathers, schedules) is baked
        once per program instead of once per round, which is what the
        chunked drivers (:func:`repro.core.federated.run_fl_until_scan`,
        :func:`repro.core.maml.maml_train_scan`) and the ``rounds_loop``
        benchmark build on.

        Time-varying graphs run device-resident: with a non-static
        :class:`~repro.core.topology.GraphProcess` the rounds are
        numbered ``t0, t0+1, …`` (``t0`` may be traced — chunked callers
        pass each chunk's global offset) and every round's survival mask
        is generated IN-SCAN from the folded process key; no host-side
        per-round graph prefetch, and the masks are bit-identical to the
        host ``topology.dropout`` stream.

        ``telemetry`` (:class:`repro.telemetry.Telemetry`) records one
        row per round (Eq.-(11) joules by link class from the round's
        ACTUAL surviving links, disagreement, wire bits): buffered mode
        stays pure (rows ride the scan outputs, ingested host-side
        right here — so the call must run OUTSIDE any caller jit);
        streaming mode additionally emits each round live via
        ``jax.debug.callback``. Params/state are bit-identical with
        telemetry off, buffered, or streaming: the rows read the round
        state, the mixing consumes the same mask either way.
        """
        if keys is None and rounds is None:
            raise ValueError("pass per-round keys or rounds=")
        if codec_state is None:
            codec_state = self.init_state(stacked_params)
        if self.plan.kind == "distributed" and self._schedule is None:
            # hoist the host-computed schedule out of the scan body
            self._schedule = consensus.permutation_schedule(
                self.mix, self.gamma)
        R = (int(rounds) if keys is None
             else jax.tree.leaves(keys)[0].shape[0])
        ts = (t0 + jnp.arange(R, dtype=jnp.int32)
              if self.graph.kind != "static" or telemetry is not None
              else None)
        recorder = (telemetry.recorder_for(self)
                    if telemetry is not None else None)
        stream_cb = (telemetry.stream_cb(recorder, "consensus")
                     if telemetry is not None and telemetry.streaming
                     else None)

        def body(carry, xs):
            t, k = xs
            # telemetry draws the round's mask ONCE and shares it with
            # step() (mask= takes precedence over t=; identical ops, so
            # results match the telemetry-off t= path bit for bit)
            mask = (self.round_mask(t)
                    if telemetry is not None and t is not None else None)
            p, st = self.step(carry[0], carry[1], k, t=t, mask=mask)
            row = None
            if telemetry is not None:
                row = recorder.row(p, mask, metric=jnp.float32(0.0),
                                   reached=jnp.asarray(False),
                                   live=jnp.asarray(True))
                if stream_cb is not None:
                    jax.debug.callback(stream_cb, t, row, ordered=True)
            return (p, st), row

        if ts is None and keys is None:
            (p, st), rows = jax.lax.scan(
                lambda c, _x: body(c, (None, None)),
                (stacked_params, codec_state), None, length=R)
        else:
            (p, st), rows = jax.lax.scan(
                body, (stacked_params, codec_state), (ts, keys))
        if telemetry is not None:
            telemetry.record_rounds(recorder, rows, t0, driver="consensus")
        return p, st

    # -- Eq.-(11) pricing ---------------------------------------------------
    def round_comm_joules(self, energy_params,
                          model_bits: Optional[float] = None) -> float:
        """Eq.-(11) communication energy of ONE round at THIS engine's
        wire format (delegates to the topology's codec-aware pricing)."""
        if self.topology is None:
            raise ValueError("engine was built from a raw mix matrix; "
                             "construct it from a Topology to price rounds")
        return self.topology.round_comm_joules(
            energy_params, model_bits=model_bits, codec=self.codec)

    # -- audit metadata -----------------------------------------------------
    def audit_meta(self) -> dict:
        """Resolved facts ``repro.analysis`` keys its checks on: the
        plan kind, its :data:`PLAN_AUDIT_EXPECTATIONS` entry, and the
        wire codec (base codec under the error-feedback wrapper, with
        its int-lane bit width if any). Rule H2 reconciles the compiled
        module's collective bytes against ``codec.model_bits(tree)``."""
        base = (getattr(self.codec, "inner", self.codec)
                if self.codec is not None else None)
        meta = dict(PLAN_AUDIT_EXPECTATIONS[self.plan.kind])
        meta.update(
            plan=self.plan.kind, K=self.K,
            num_blocks=self.plan.num_blocks,
            axis_name=self.plan.axis_name,
            mesh_axis=(None if self.mesh is None else
                       dict(self.mesh.shape).get(self.plan.axis_name)),
            codec=None if self.codec is None else self.codec.name,
            qbits=getattr(base, "qbits", None),
        )
        return meta

    # -- conveniences -------------------------------------------------------
    @classmethod
    def wrap(cls, obj, **kw) -> "ConsensusEngine":
        """Coerce ``obj`` (engine, Topology, or concrete mix) to an
        engine; extra kwargs only apply when constructing a new one."""
        if isinstance(obj, cls):
            if any(v is not None for v in kw.values()):
                raise ValueError(
                    f"{sorted(k for k, v in kw.items() if v is not None)} "
                    "cannot be re-specified for an existing engine")
            return obj
        return cls(obj, **kw)

    def __repr__(self):
        codec = self.codec.name if self.codec is not None else None
        graph = "" if self.graph.kind == "static" else f", graph={self.graph!r}"
        return (f"ConsensusEngine(K={self.K}, plan={self.plan.kind!r}, "
                f"codec={codec!r}, blocks={self.plan.num_blocks}{graph})")
