"""ConsensusEngine — the single entry point for one Eq.-(6) mixing round.

The paper's energy balance (Eqs. 6/11) is evaluated per consensus round,
so the round executor is the hot path of every scaling experiment. This
module turns a ``(Topology, K, codec, mesh)`` description into an
execution **plan** once, at construction, and every caller
(:mod:`repro.core.protocol`, :mod:`repro.core.federated`,
:mod:`repro.rl.casestudy`, :mod:`repro.launch.train`, the scale
benchmark) drives the same ``engine.step(stacked_params, codec_state,
key) -> (params, codec_state)`` — no ``impl=`` strings or per-caller
path wiring.

Plans
-----
* ``dense-xla``     — the reference (K, K) matmul per leaf; also accepts
  a TRACED per-round full mix override via ``step(mix=...)`` (the legacy
  time-varying hook, kept for raw-σ callers).
* ``sparse-pallas`` — batched-over-agents sparse gather through the fused
  Pallas consensus kernels (the bit-identical jnp oracle off TPU);
  O(K·H·N) instead of O(K²·N).
* ``sharded``       — the sparse gather under shard_map over an agent
  axis: each mesh position owns a block of K/num_blocks agents, encodes
  its own block's wires, ``all_gather``s the (K, ·) WIRE (codec bytes,
  not f32), and mixes only its rows. No single program materializes the
  (K, K) stack, which is what lets K = 16384 populations mix on meshes
  of any size (and on one CPU via the vmap-with-axis_name emulation).
* ``distributed``   — one agent per mesh position; neighbour exchange is
  ``jax.lax.ppermute`` rounds from a host-computed permutation schedule,
  and the permuted payload is the CODEC wire: int8/int4 lanes + scales,
  bf16 casts. This makes ``Topology.round_comm_joules(codec=)`` pricing
  truthful on the one path that actually distributes across a mesh —
  an int8 wire ships (and prices) 4× below f32.

Wire formats per path: ``dense-xla`` mixes DECODED f32 models (the wire
is an accounting construct priced by Eq. 11); ``sparse-pallas`` and
``sharded`` gather the int-quantized wire itself through the fused
dequant-consensus kernel — int8/int4 lanes with per-tensor OR
block-wise ``int8:b64`` scales (other codecs decode before the
gather); ``distributed`` permutes the wire payload for every codec.

Time-varying graphs (:class:`repro.core.topology.GraphProcess`)
---------------------------------------------------------------
``ConsensusEngine(topo, graph=GraphProcess.dropout(p, seed))`` resolves
a time-varying graph process ONCE at construction, making per-round
link failures a capability of EVERY plan. Survival is drawn per EDGE:
each directed edge owns a canonical id (symmetric pairs share one, so
a faded channel kills both directions) and round ``t``'s draw is the
pure function ``uniform(fold_in(fold_in(key, t), edge_id)) >= p``
(:func:`repro.core.topology.survival_mask`, the single blessed draw
site — rule R1). Because every edge's fate is independent of HOW the
edges are enumerated, each plan draws survival in its own native
shape — O(#edges) work, never a dense rebuild — via
:meth:`round_survival`:

* ``dense-xla``     — the (K, K) mask; :meth:`masked_mixing` REBUILDS
  the σ matrix on the surviving graph with the engine's mixing kind,
  riding the matmul as a traced operand (dropped links reallocate
  their σ mass; doubly-stochastic kinds stay doubly stochastic on
  every surviving subgraph);
* ``sparse-pallas`` / ``sharded`` — the gather INDICES stay baked from
  the full base graph; survival is drawn straight into the (K, H)
  neighbour-lane table and the per-lane σ is renormalized DIRECTLY on
  the lanes (same values bit for bit as the dense rebuild under the
  default uniform data sizes) and rides the fused (dequant-)consensus
  kernels as a traced operand, so faded lanes carry σ = 0 (exact
  no-ops) — one compiled program for every round and O(K·H) per-round
  work, no (K, K) buffer anywhere (rule H1 holds at K = 4096 WITH
  dropout active);
* ``distributed``   — the ppermute schedule SUPERSET of the base graph
  is resolved once at construction (every surviving graph is a
  subgraph, and each directed edge is carried by exactly one schedule
  slot); survival is drawn straight into the (M, K) schedule table,
  the per-slot σ is renormalized on the survivors and rides the
  permutes as a traced (K, M) operand — faded slots apply σ = 0 while
  the wire still ships the full M permutations (a fixed TDMA-frame-
  like schedule; Eq.-(11) billing counts only the surviving real
  edges). Graphs whose schedule superset exceeds
  :data:`DISTRIBUTED_SCHEDULE_BOUND` slots are refused at
  construction.

Draws are bit-identical to the host
:func:`repro.core.topology.dropout` stream via the shared per-edge
fold-in convention, which is what lets callers bill Eq.-(11) joules
post hoc over exactly the rounds used with ZERO host-side per-round
graph prefetch.

Asynchronous consensus (:class:`repro.core.topology.AgentProcess`)
------------------------------------------------------------------
``ConsensusEngine(topo, agents=AgentProcess.…, tau=τ)`` layers per-AGENT
availability on top of per-LINK survival: each round the engine draws
WHO is awake (:func:`repro.core.topology.availability_mask`, the agent
half of the fold-in convention — duty cycles, heavy-tail stragglers,
arrivals, departures), and the protocol degrades instead of wedging.
Inactive agents freeze — no local compute, no wires, params/codec
residuals/round clocks hold bit-for-bit — while their neighbours keep
mixing the frozen last-published state at staleness-decayed weight
λ^age through the SAME per-plan σ machinery (``masked_mixing`` /
``_lane_sigma`` / ``_schedule_sigma``, which accept float weights),
until the wire age passes the hard bound τ and the lane drops with σ
renormalizing over the survivors. The ``(clock, age)``
:class:`AsyncState` threads through the scan carry
(:meth:`async_step` / :meth:`scan_rounds` / the FL drivers), and
telemetry bills Eq.-(11) only on DELIVERED wires — what active agents
actually sent. Two invariants pin the construction:
``AgentProcess.always_on()`` with τ=∞ reduces to the lockstep engine
bit for bit (stale weights are exactly {0, 1} floats, and IEEE
``1.0·x == x`` / ``0.0·x == +0.0`` make the weighted σ identical to
the bool rebuild), and the in-scan availability draws are bit-parity
with the host :func:`repro.core.topology.availability_stream` replay.

Multi-round programs: :meth:`ConsensusEngine.scan_rounds` runs R rounds
inside one ``lax.scan`` with the codec/EF state in the carry — the
building block of the chunked protocol drivers
(:func:`repro.core.federated.run_fl_until_scan`,
:func:`repro.core.maml.maml_train_scan`), which compile whole stretches
of the round loop into single programs and sync the host once per
chunk.

CHOCO mean-exactness invariant: every compressed plan recenters each
agent's update on its OWN decoded copy — W_k + Σ_h σ_{k,h}(x̂_h − x̂_k) —
so under doubly-stochastic σ the population mean is exactly preserved no
matter how lossy the codec; the error-feedback wrapper (on by default
for lossy codecs) telescopes the per-round quantization error. All four
plans therefore agree with the dense-f32 oracle to within the codec's
round-trip tolerance (tested at K = 256 in ``tests/test_engine.py``).

``plan="auto"`` selection: with no mesh, the payload-aware density
heuristic (:func:`repro.core.consensus.auto_path`) picks dense-xla vs
sparse-pallas; with a mesh carrying the agent axis, one-agent-per-
position meshes take ``distributed`` and everything else ``sharded``
(blocks = mesh axis size).
"""
from __future__ import annotations

import difflib
from dataclasses import dataclass
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import consensus

PLAN_KINDS = ("dense-xla", "sparse-pallas", "sharded", "distributed")
#: plans that accept a per-round survival mask (traced σ operands).
#: Since the per-edge draw convention, ALL of them: the distributed
#: plan keeps its ppermute schedule superset fixed at trace time and
#: masks individual schedule slots via a traced (K, M) σ operand.
MASKABLE_PLANS = ("dense-xla", "sparse-pallas", "sharded", "distributed")

#: largest ppermute-schedule superset a time-varying ``distributed``
#: engine accepts (schedule length ≈ the base graph's max degree — one
#: slot per matching). Every masked round ships all M slots whether or
#: not their edges survived (the superset is the fixed TDMA frame), so
#: a graph needing more slots than this would spend more air time on
#: faded slots than a prefetched-schedule rebuild costs; such graphs
#: are refused at construction.
DISTRIBUTED_SCHEDULE_BOUND = 64

#: per-plan compiled-artifact expectations ``repro.analysis`` keys on.
#: ``kk_buffer``: whether the plan's program may legitimately
#: materialize a (K, K) tensor (the dense σ stack); the sharded and
#: distributed plans exist precisely so it never does, and the HLO
#: auditor (rule H1) fails them if one appears at K ≥ its threshold.
#: ``wire_collective``: which collective carries the codec WIRE on a
#: real mesh — the op whose result bytes rule H2 reconciles against
#: ``codec.bits()`` pricing. ``int_lane_gather``: the plan mixes
#: int-codec wires through a fused gather that must keep int8/int4
#: lanes (the decode-then-combine regression class, rule JX2).
PLAN_AUDIT_EXPECTATIONS = {
    "dense-xla":     {"kk_buffer": True, "wire_collective": None,
                      "int_lane_gather": False},
    "sparse-pallas": {"kk_buffer": False, "wire_collective": None,
                      "int_lane_gather": True},
    "sharded":       {"kk_buffer": False, "wire_collective": "all-gather",
                      "int_lane_gather": True},
    "distributed":   {"kk_buffer": False,
                      "wire_collective": "collective-permute",
                      "int_lane_gather": False},
}


@dataclass(frozen=True)
class ExecutionPlan:
    """A resolved consensus execution strategy (see module docstring)."""

    kind: str
    reason: str
    num_blocks: int = 1
    axis_name: str = "agents"

    def __post_init__(self):
        if self.kind not in PLAN_KINDS:
            close = difflib.get_close_matches(
                str(self.kind), PLAN_KINDS + ("auto",), n=1)
            hint = f"; did you mean {close[0]!r}?" if close else ""
            raise ValueError(f"unknown plan {self.kind!r}; "
                             f"choose from {PLAN_KINDS} or 'auto'{hint}")


class AsyncState(NamedTuple):
    """Per-caller carry of an async (agent-availability) engine:

    * ``clock`` — (K,) int32 per-agent round clocks: how many rounds
      each agent has actually PARTICIPATED in (ticks only while
      active; a straggler's clock lags the global round index);
    * ``age``   — plan-shaped int32 last-received-wire age per lane —
      (K, K) on dense-xla, (K, H) lanes on sparse-pallas/sharded,
      (M, K) schedule slots on distributed — rounds since receiver k
      last got a FRESH wire from that lane's sender (0 after a
      delivery, +1 per round otherwise).

    ``init_async_state`` starts both at zero — the protocol's "all
    agents exchanged initial models at t=0" convention.
    """

    clock: jnp.ndarray
    age: jnp.ndarray


class AsyncRound(NamedTuple):
    """One round's resolved availability facts (``async_round``):
    ``act`` (K,) activity bools; ``weights`` plan-shaped float32
    staleness-scaled σ input (1 fresh, λ^age stale, 0 dropped);
    ``delivered`` plan-shaped bools marking wires ACTUALLY shipped
    this round (what Eq.-(11) bills); ``age`` the post-round wire
    ages (the next carry's ``AsyncState.age``)."""

    act: jnp.ndarray
    weights: jnp.ndarray
    delivered: jnp.ndarray
    age: jnp.ndarray


def where_active(active, new, old):
    """Per-agent freeze/select over K-stacked pytrees: leaf ``[k]``
    takes ``new[k]`` where ``active[k]`` else ``old[k]`` (broadcast over
    trailing axes). An inactive agent's params / codec residuals /
    clocks hold bit-for-bit; an all-True (all-False) mask returns the
    first (second) operand's values exactly, which is what keeps the
    always-on lockstep reduction and the fully-dead-round no-op
    bitwise."""
    act = jnp.asarray(active, bool)

    def sel(n, o):
        a = act.reshape(act.shape + (1,) * (jnp.ndim(n) - 1))
        return jnp.where(a, n, o)

    return jax.tree.map(sel, new, old)


class ConsensusEngine:
    """One Eq.-(6) round behind one entry point (see module docstring).

    Arguments
    ---------
    topology:   a :class:`repro.core.topology.Topology` (preferred — also
                enables :meth:`round_comm_joules`) or a concrete (K, K)
                σ matrix.
    codec:      model-exchange codec spec/Codec (:mod:`repro.comms`);
                lossy codecs get the error-feedback wrapper unless
                ``error_feedback=False``.
    mesh:       optional ``jax.sharding.Mesh`` whose ``axis_name`` axis
                carries agents (one per position ⇒ distributed; blocks
                ⇒ sharded). ``None`` runs every plan in one program
                (sharded/distributed fall back to the vmap-with-
                axis_name emulation, which shares collective semantics).
    plan:       "auto" (default) or one of :data:`PLAN_KINDS`.
    num_blocks: block count for the sharded plan (default: mesh axis
                size, else 1).
    data_sizes / mix_kind / include_self: forwarded to the topology's
                ``mixing`` (uniform paper weights by default) and reused
                to REBUILD the per-round mix on surviving subgraphs when
                a time-varying ``graph`` is attached.
    gamma:      CHOCO consensus step size (damps off-diagonal σ).
    graph:      a :class:`repro.core.topology.GraphProcess` (or None ⇒
                static). Non-static processes turn EVERY plan
                time-varying: each round's edge survival is drawn
                in-scan from the folded process key in the plan's
                native shape — (K, K) mask, (K, H) lanes, or (M, K)
                schedule slots — and the σ is renormalized on the
                survivors (see the module docstring). The
                ``distributed`` plan resolves its ppermute schedule
                superset here, at construction, and refuses graphs
                needing more than :data:`DISTRIBUTED_SCHEDULE_BOUND`
                slots.
    agents:     a :class:`repro.core.topology.AgentProcess` (or None ⇒
                lockstep). Attaching one turns the engine ASYNC: each
                round's per-agent availability is drawn in-scan from
                the same fold-in convention, inactive agents freeze
                (params, codec residuals, round clocks), and mixing
                becomes staleness-weighted — a sleeping neighbour's
                frozen last-published state mixes at weight
                ``staleness_decay ** age`` until ``age > tau``, where
                its lane drops and σ renormalizes (see
                :meth:`async_round`). ``AgentProcess.always_on()``
                with ``tau=None`` reduces to the lockstep engine bit
                for bit.
    tau:        hard staleness bound in rounds (async only): a lane
                whose wire age exceeds τ drops from the mix entirely.
                None ⇒ ∞ (stale lanes never drop); 0 ⇒ only fresh
                wires mix.
    staleness_decay: λ ∈ (0, 1] — stale lanes mix at λ^age. The
                default 1.0 keeps stale weights at exactly 1 (the
                lockstep-exact choice); smaller values fade old wires
                smoothly before the hard τ cut.
    """

    def __init__(self, topology, *, codec=None, mesh=None,
                 plan: str = "auto", axis_name: str = "agents",
                 num_blocks: Optional[int] = None, data_sizes=None,
                 mix_kind: str = "paper", include_self: bool = True,
                 gamma: float = 1.0, error_feedback: bool = True,
                 block_n: Optional[int] = None, graph=None,
                 agents=None, tau=None, staleness_decay: float = 1.0):
        from repro import comms   # deferred: core stays import-light
        from repro.core import topology as topo_lib
        if isinstance(topology, ConsensusEngine):
            raise TypeError(
                f"topology= got an already-built {type(topology).__name__} "
                f"(plan={topology.plan.kind!r}); pass a Topology or mix "
                "matrix, or coerce with ConsensusEngine.wrap(engine)")
        if mix_kind not in consensus.MIX_KINDS:
            # validated here, at construction, so a typo'd kind is
            # refused before any (possibly jitted) round traces it
            raise ValueError(consensus._unknown_kind_msg(mix_kind))
        self.topology = topology if hasattr(topology, "mixing") else None
        self.mix = np.asarray(
            topology.mixing(data_sizes, kind=mix_kind,
                            include_self=include_self)
            if self.topology is not None else topology, np.float32)
        self.K = self.mix.shape[0]
        self.codec = comms.resolve_codec(codec, error_feedback)
        self.mesh = mesh
        self.gamma = float(gamma)
        self.block_n = block_n
        self.mix_kind = mix_kind
        self.include_self = include_self
        self.data_sizes = (None if data_sizes is None
                           else np.asarray(data_sizes, np.float32))
        self.graph = graph if graph is not None else topo_lib.GraphProcess.static()
        if agents is not None and not isinstance(agents,
                                                 topo_lib.AgentProcess):
            raise TypeError(
                f"agents= takes a repro.core.topology.AgentProcess (or "
                f"None), got {agents!r}; build one with "
                "AgentProcess.always_on() / .bernoulli(p_active) / "
                ".straggler(K) / .arrival(t_join) / .departure(t_leave)")
        self.agents = agents
        if agents is not None:
            if self.topology is None:
                raise ValueError(
                    f"agents={agents!r} needs an engine built from a "
                    "Topology, but this one came from a raw mix matrix: "
                    "staleness σ is REBUILT per round from the "
                    "delivered/stale lanes with the engine's mixing "
                    "kind, which cannot faithfully renormalize an "
                    "arbitrary raw mix — construct from a Topology "
                    "(e.g. topology.ring(K)) or drop agents=")
            pk = agents.K
            if pk is not None and pk != self.K:
                raise ValueError(
                    f"agents={agents!r} pins a population of {pk} "
                    f"agents but this engine's topology has K="
                    f"{self.K}; rebuild the process at K={self.K}")
        if tau is not None and agents is None:
            raise ValueError(
                f"tau={tau!r} (the hard staleness bound) only applies "
                "to async engines: pass agents=AgentProcess.… alongside "
                "it, or drop tau= for the lockstep protocol")
        if tau is not None:
            tf = float(tau)
            if np.isnan(tf) or tf < 0:
                raise ValueError(
                    f"tau={tau!r} is not a staleness bound: τ counts "
                    "rounds since the last delivered wire — use "
                    "tau=None (∞: stale lanes never drop), tau=0 "
                    "(only fresh wires mix), or a positive round count")
            tau = None if np.isinf(tf) else tf
        self.tau = tau
        self.staleness_decay = float(staleness_decay)
        if not 0.0 < self.staleness_decay <= 1.0:
            raise ValueError(
                f"staleness_decay={staleness_decay!r} must lie in "
                "(0, 1]: a stale lane mixes at weight λ^age — use "
                "λ=1.0 (no decay, the lockstep-exact default) or a "
                "positive fraction like 0.9")
        self.plan = self._resolve_plan(plan, axis_name, num_blocks)
        self._schedule = None          # distributed ppermute rounds, lazy
        self._masked_struct = None     # (idx, lane-valid) for masked sig
        self._sched_struct = None      # (srcs, real) of the schedule
        self._sched_keep = None        # schedule-kind masks, plan-shaped
        if self.graph.kind != "static":
            if self.topology is None:
                # a raw σ matrix's generating rule is unknown, so the
                # per-round rebuild would silently REPLACE the caller's
                # weights with mixing_weights(kind) on the survivor —
                # refuse rather than diverge
                raise ValueError(
                    f"graph={self.graph!r} (time-varying) needs an "
                    "engine built from a Topology, but this one came "
                    "from a raw mix matrix: each round's σ is REBUILT "
                    "from the surviving graph with the engine's mixing "
                    "kind/data_sizes, which cannot faithfully "
                    "renormalize an arbitrary raw mix — construct from "
                    "a Topology or use GraphProcess.static()")
            # the base adjacency the survival masks apply to
            self._adjacency = np.asarray(self.topology.adjacency, bool)
            self._symmetric = bool(
                (self._adjacency == self._adjacency.T).all())
            if self.graph.kind == "dropout":
                self._graph_key = topo_lib.survival_key(self.graph.seed)
            elif self.graph.masks.shape[1:] != (self.K, self.K):
                raise ValueError(
                    f"schedule masks are {self.graph.masks.shape[1:]}, "
                    f"population is K={self.K}")
        if (self.plan.kind == "distributed"
                and (self.graph.kind != "static"
                     or self.agents is not None)):
            # resolve the ppermute schedule SUPERSET now: every
            # surviving (or delivered) graph is a subgraph of the base
            # graph, so a schedule covering the base graph covers every
            # round — masked slots ride as σ = 0 on a traced operand,
            # no retrace. One slot per matching ⇒ length ≈ max degree.
            self._schedule = consensus.permutation_schedule(
                self.mix, self.gamma)
            if len(self._schedule) > DISTRIBUTED_SCHEDULE_BOUND:
                raise ValueError(
                    f"time-varying/async engines on the distributed "
                    f"plan mask a fixed ppermute schedule superset, "
                    f"and this graph needs {len(self._schedule)} "
                    f"schedule slots (≈ max degree "
                    f"{self.topology.max_degree}) — over the "
                    f"{DISTRIBUTED_SCHEDULE_BOUND}-slot bound "
                    "(DISTRIBUTED_SCHEDULE_BOUND). Use a sparser "
                    "base graph, or the sharded plan (per-lane "
                    "masks, no schedule)")

    # -- plan selection -----------------------------------------------------
    def _resolve_plan(self, plan: str, axis_name: str,
                      num_blocks: Optional[int]) -> ExecutionPlan:
        mesh_axis = consensus._mesh_axis(self.mesh, axis_name)
        if plan == "auto":
            if mesh_axis is not None:
                if mesh_axis == self.K:
                    return ExecutionPlan(
                        "distributed", "mesh holds one agent per "
                        f"'{axis_name}' position", 1, axis_name)
                nb = num_blocks or mesh_axis
                if self.K % nb:
                    # a mesh was given: honour it — fall back to the
                    # largest block count that divides K rather than
                    # silently reverting to a single-program plan
                    nb = next(d for d in range(min(nb, self.K), 0, -1)
                              if self.K % d == 0)
                return ExecutionPlan(
                    "sharded", f"K={self.K} agents in {nb} blocks over "
                    f"the {mesh_axis}-wide '{axis_name}' mesh axis",
                    nb, axis_name)
            base = getattr(self.codec, "inner", self.codec)
            dense = consensus.auto_path(self.mix, codec=base) == "dense"
            return ExecutionPlan(
                "dense-xla" if dense else "sparse-pallas",
                "payload-aware density heuristic "
                f"(max degree vs K={self.K})", 1, axis_name)
        if plan == "sharded":
            nb = num_blocks or mesh_axis or 1
            return ExecutionPlan("sharded", "explicit", nb, axis_name)
        return ExecutionPlan(plan, "explicit", num_blocks or 1, axis_name)

    # -- state --------------------------------------------------------------
    def init_state(self, stacked_params):
        """Initial codec state (stacked EF residuals; None if stateless)."""
        if self.codec is None or not self.codec.stateful:
            return None
        return self.codec.init_state(stacked_params)

    # -- time-varying graphs ------------------------------------------------
    def round_mask(self, t):
        """(K, K) bool edge-survival mask of round ``t`` under this
        engine's :class:`~repro.core.topology.GraphProcess` (None for a
        static graph). ``t`` may be TRACED — this is what the scanned
        drivers call per round INSIDE ``lax.scan``, and by the shared
        fold-in convention the result is bit-identical to round ``t`` of
        the host :func:`repro.core.topology.dropout` stream."""
        from repro.core import topology as topo_lib
        if self.graph.kind == "static":
            return None
        if self.graph.kind == "dropout":
            return topo_lib.survival_mask(
                self._adjacency, self.graph.p, self._graph_key, t,
                symmetric=self._symmetric)
        masks = jnp.asarray(self.graph.masks)          # schedule
        return jnp.asarray(self._adjacency) & masks[
            jnp.asarray(t) % masks.shape[0]]

    def masked_mixing(self, mask):
        """Rebuild the σ matrix on the SURVIVING graph (possibly traced
        mask): the engine's mixing kind / data sizes / include_self are
        re-applied to ``adjacency & mask``, so dropped links reallocate
        their σ mass exactly as ``Topology.mixing`` would on the
        host-materialized survivor (bit-identical — same jnp ops)."""
        sizes = (np.ones(self.K, np.float32) if self.data_sizes is None
                 else self.data_sizes)
        return consensus.mixing_weights(
            sizes, mask, self.mix_kind, include_self=self.include_self)

    def lane_structure(self):
        """(idx, valid) neighbour-lane table of the BASE graph for the
        sparse/sharded plans: idx (K, H) int32 ascending neighbour
        indices (padding lanes index the agent itself), valid (K, H)
        bool marking real lanes. Baked once, lazily, as numpy — the
        cache outlives any one trace, so it must never hold
        tracer-backed arrays."""
        if self._masked_struct is None:
            A = (np.asarray(self.topology.adjacency, bool).copy()
                 if self.topology is not None else self.mix != 0)
            np.fill_diagonal(A, False)
            deg = A.sum(axis=1)
            H = max(int(deg.max()), 1) if self.K else 1
            idx = np.tile(np.arange(self.K, dtype=np.int32)[:, None],
                          (1, H))
            for k in range(self.K):
                nbr = np.flatnonzero(A[k])
                idx[k, :len(nbr)] = nbr
            valid = np.arange(H)[None, :] < deg[:, None]
            self._masked_struct = (idx, valid)
        return self._masked_struct

    def schedule_structure(self):
        """(srcs, real) of the distributed plan's ppermute schedule
        superset: srcs (M, K) int32 — the mesh position each target
        receives from in slot m — and real (M, K) bool marking slots
        that carry an actual base-graph edge (the rest are permutation-
        completion padding, σ = 0 forever). Baked once, lazily, numpy."""
        if self._sched_struct is None:
            if self._schedule is None:
                self._schedule = consensus.permutation_schedule(
                    self.mix, self.gamma)
            M = len(self._schedule)
            srcs = np.zeros((M, self.K), np.int32)
            real = np.zeros((M, self.K), bool)
            for m, (pairs, sig) in enumerate(self._schedule):
                for s, tgt in pairs:
                    srcs[m, tgt] = s
                real[m] = np.asarray(sig) != 0.0
            self._sched_struct = (srcs, real)
        return self._sched_struct

    def round_survival(self, t=None, mask=None):
        """Round ``t``'s edge survival in THIS plan's native shape —
        the in-scan fast path that never materializes (K, K) on the
        non-dense plans: a (K, K) bool mask on dense-xla, surviving-
        lane (K, H) bools on sparse-pallas/sharded, surviving-slot
        (M, K) bools on distributed. ``t`` may be traced; ``mask``
        instead converts an explicit (K, K) survival mask (e.g. a
        host-prefetched :func:`repro.core.topology.dropout` round) to
        the plan shape — bit-identical to the in-scan draw of the same
        round by the shared per-edge fold-in convention. Returns None
        for a static graph with no explicit mask."""
        from repro.core import topology as topo_lib
        kind = self.plan.kind
        if kind == "dense-xla":
            return (jnp.asarray(mask) if mask is not None
                    else self.round_mask(t))
        if mask is None and self.graph.kind == "static":
            return None
        if kind == "distributed":
            srcs, real = self.schedule_structure()
            rows = np.arange(self.K, dtype=np.int32)[None, :]
        else:
            srcs, real = self.lane_structure()      # (idx, valid)
            rows = np.arange(self.K, dtype=np.int32)[:, None]
        if mask is not None:
            keep = jnp.asarray(mask)[rows, srcs]
        elif self.graph.kind == "dropout":
            keep = topo_lib.survival_mask(
                self.K, self.graph.p, self._graph_key, t,
                symmetric=self._symmetric, receivers=rows, senders=srcs)
        else:                                        # schedule masks
            if self._sched_keep is None:
                # pre-gather the (R, K, K) mask stack into the plan
                # shape ONCE (numpy), so the in-scan lookup is a
                # dynamic slice of lanes/slots, never a (K, K) constant
                self._sched_keep = np.asarray(
                    self.graph.masks[:, rows, srcs])
            stack = jnp.asarray(self._sched_keep)
            keep = stack[jnp.asarray(t) % stack.shape[0]]
        return keep & jnp.asarray(real)

    # -- per-agent availability (the async protocol) ------------------------
    def availability(self, t):
        """(K,) activity bools of round ``t`` under this engine's
        :class:`~repro.core.topology.AgentProcess` (all-True when no
        agents= is attached). ``t`` may be traced — drawn in-scan,
        bit-identical to the host
        :func:`repro.core.topology.availability_stream` replay."""
        from repro.core import topology as topo_lib
        return topo_lib.agent_availability(self.agents, self.K, t)

    def _real_edges(self):
        """Plan-shaped bool mask of the REAL base-graph lanes (numpy
        constants baked at trace time): the adjacency on dense-xla,
        lane validity on sparse-pallas/sharded, real schedule slots on
        distributed."""
        kind = self.plan.kind
        if kind == "dense-xla":
            return np.asarray(self.topology.adjacency, bool)
        if kind == "distributed":
            return self.schedule_structure()[1]
        return self.lane_structure()[1]

    def _act_shapes(self, act):
        """Broadcast the (K,) activity vector into this plan's native
        survival shape: ``(act_recv, act_sender)`` per lane — receiver
        rows/sender columns on the (K, K) grid, receiver rows/sender
        lane indices on (K, H), receiver columns/sender schedule
        sources on (M, K)."""
        kind = self.plan.kind
        if kind == "dense-xla":
            return act[:, None], act[None, :]
        if kind == "distributed":
            srcs, _real = self.schedule_structure()
            return act[None, :], act[jnp.asarray(srcs)]
        idx, _valid = self.lane_structure()
        return act[:, None], act[jnp.asarray(idx)]

    def init_async_state(self) -> AsyncState:
        """Zeroed :class:`AsyncState` carry — clocks at 0, every wire
        age 0 ("all agents exchanged initial models at t=0")."""
        if self.agents is None:
            raise ValueError(
                "init_async_state() is the async protocol's carry, but "
                f"this {self.plan.kind!r} engine has agents=None — pass "
                "agents=AgentProcess.bernoulli(p_active) (or another "
                "availability process) at construction")
        shape = np.asarray(self._real_edges()).shape
        return AsyncState(jnp.zeros(self.K, jnp.int32),
                          jnp.zeros(shape, jnp.int32))

    def async_round(self, t, age) -> AsyncRound:
        """Resolve round ``t``'s availability facts against the wire
        ages ``age`` (the :class:`AsyncState` carry): who is awake,
        which wires actually ship, and the staleness-scaled σ input.

        Per lane (receiver k ← sender h), with ``up`` the link survival
        of the engine's graph process (all real lanes, for a static
        graph):

        * DELIVERED (``act[h] & act[k] & up``): a fresh wire ships;
          weight 1, age resets to 0. A lane whose SENDER is awake but
          whose LINK faded drops outright (weight 0) — exactly today's
          lockstep fade semantics, which is what keeps the always-on
          reduction bitwise.
        * STALE (``act[k] & ~act[h]``, real lane): the sender sleeps,
          so the receiver keeps mixing the sender's FROZEN last-
          published params at weight ``staleness_decay ** age`` — a
          stale neighbour is a faded lane with memory — until
          ``age > τ``, where the lane drops and σ renormalizes over
          the survivors. (Optimistic-cache caveat: if the sender's
          last pre-sleep wire itself faded, the cache is the frozen
          params, not the older wire actually received — the engine
          models the cache, not a (K, H, N) wire buffer.)
        * otherwise weight 0 (receiver asleep, or padding lane).

        ``age`` counts rounds since the last delivery and increments
        on every non-delivered lane. With ``AgentProcess.always_on``
        and τ=∞ every real surviving lane is DELIVERED, the weights
        are exactly {0.0, 1.0}, and the staleness σ reproduces the
        lockstep σ bit for bit.
        """
        if self.agents is None:
            raise ValueError(
                "async_round() needs an agents= AgentProcess attached "
                f"at construction, but this {self.plan.kind!r} engine "
                "has agents=None (it runs the lockstep protocol; use "
                "step(t=...) instead)")
        act = self.availability(t)
        act_recv, act_send = self._act_shapes(act)
        real = jnp.asarray(self._real_edges())
        link = self.round_survival(t)   # already ANDed with real lanes
        up = real if link is None else jnp.asarray(link)
        age = jnp.asarray(age, jnp.int32)
        delivered = act_send & act_recv & up
        new_age = jnp.where(delivered, 0, age + 1)
        stale = act_recv & ~act_send & real
        if self.tau is not None:
            stale = stale & (new_age <= self.tau)
        if self.staleness_decay == 1.0:
            stale_w = jnp.float32(1.0)
        else:
            stale_w = (jnp.float32(self.staleness_decay)
                       ** new_age.astype(jnp.float32))
        weights = jnp.where(delivered, jnp.float32(1.0),
                            jnp.where(stale, stale_w, jnp.float32(0.0)))
        return AsyncRound(act, weights, delivered, new_age)

    def async_step(self, stacked_params, codec_state=None, key=None, *,
                   t=None, state: Optional[AsyncState] = None,
                   round_info: Optional[AsyncRound] = None):
        """One async Eq.-(6) round: resolve availability, staleness-mix
        through :meth:`step`, freeze inactive agents' params and codec
        residuals, and advance clocks/ages. Returns ``(params,
        codec_state, AsyncState, AsyncRound)`` — thread the state into
        the next call (start from :meth:`init_async_state`); pass
        ``round_info=`` to reuse facts already drawn (e.g. shared with
        telemetry), else they are drawn from ``t``."""
        if state is None:
            raise ValueError(
                f"async_step at t={t!r} needs state= (the AsyncState "
                "carry, got state=None) — start from "
                "init_async_state() and thread each call's returned "
                "state into the next")
        ar = (round_info if round_info is not None
              else self.async_round(t, state.age))
        p, st = self.step(stacked_params, codec_state, key,
                          survival=ar.weights)
        p = where_active(ar.act, p, stacked_params)
        if st is not None:
            old = (codec_state if codec_state is not None
                   else self.init_state(stacked_params))
            st = where_active(ar.act, st, old)
        new_state = AsyncState(
            state.clock + ar.act.astype(state.clock.dtype), ar.age)
        return p, st, new_state, ar

    def _sizes(self):
        return (np.ones(self.K, np.float32) if self.data_sizes is None
                else self.data_sizes)

    def _lane_sigma(self, survival):
        """(idx, sig_t) structure for the sparse/sharded plans: σ
        renormalized DIRECTLY on the surviving (K, H) lanes — same
        formulas as :func:`repro.core.consensus.mixing_weights` per
        entry, O(K·H) with no dense rebuild. Faded/padding lanes land
        at σ = 0, exact no-ops in the fused kernels. Bit-identical to
        gathering the dense rebuild under uniform data sizes (sums of
        equal addends are association-free).

        ``survival`` may be bool lane keeps (the lockstep protocol) or
        FLOAT per-lane weights in [0, 1] (the async staleness path:
        λ^age on stale lanes, 1 fresh, 0 dropped) — each lane's σ mass
        scales by its weight before renormalizing; {0, 1} floats
        reproduce the bool path bit for bit, and metropolis degrees
        generalize to weighted degrees."""
        idx, _valid = self.lane_structure()
        keep = jnp.asarray(survival)
        sizes = jnp.asarray(self._sizes())
        weighted = jnp.issubdtype(keep.dtype, jnp.floating)
        if weighted:
            keep = keep.astype(jnp.float32)
        if self.mix_kind == "paper":
            w = (keep * sizes[jnp.asarray(idx)] if weighted
                 else jnp.where(keep, sizes[jnp.asarray(idx)], 0.0))
            denom = w.sum(axis=1)
            if self.include_self:
                denom = denom + sizes
            sig = w / jnp.maximum(denom, 1e-12)[:, None]
        elif self.mix_kind == "metropolis":
            deg = (keep.sum(axis=1) if weighted
                   else keep.sum(axis=1).astype(jnp.float32))
            inv = 1.0 / (1.0 + jnp.maximum(deg[:, None],
                                           deg[jnp.asarray(idx)]))
            sig = keep * inv if weighted else jnp.where(keep, inv, 0.0)
        else:
            raise ValueError(consensus._unknown_kind_msg(self.mix_kind))
        return jnp.asarray(idx), sig

    def _schedule_sigma(self, survival):
        """γ-scaled (K, M) schedule σ for the distributed plan,
        renormalized on the surviving (M, K) slots — the traced
        ``sig_override`` operand that replaces the baked full-graph
        ``sig_stack`` without retracing (the ppermute pairs stay
        trace-time structure). Every real directed edge occupies
        exactly one slot, so the per-target sum over slots equals the
        dense rebuild's per-row sum over neighbours. Like
        :meth:`_lane_sigma`, ``survival`` may be bool slot keeps or
        float staleness weights — {0, 1} floats reproduce the bool
        path bit for bit."""
        srcs, _real = self.schedule_structure()
        keep = jnp.asarray(survival)                 # (M, K)
        sizes = jnp.asarray(self._sizes())
        weighted = jnp.issubdtype(keep.dtype, jnp.floating)
        if weighted:
            keep = keep.astype(jnp.float32)
        if self.mix_kind == "paper":
            w = (keep * sizes[jnp.asarray(srcs)] if weighted
                 else jnp.where(keep, sizes[jnp.asarray(srcs)], 0.0))
            denom = w.sum(axis=0)
            if self.include_self:
                denom = denom + sizes
            sig = w / jnp.maximum(denom, 1e-12)[None, :]
        elif self.mix_kind == "metropolis":
            deg = (keep.sum(axis=0) if weighted
                   else keep.sum(axis=0).astype(jnp.float32))
            inv = 1.0 / (1.0 + jnp.maximum(deg[None, :],
                                           deg[jnp.asarray(srcs)]))
            sig = keep * inv if weighted else jnp.where(keep, inv, 0.0)
        else:
            raise ValueError(consensus._unknown_kind_msg(self.mix_kind))
        return (self.gamma * sig).T

    # -- the round ----------------------------------------------------------
    def step(self, stacked_params, codec_state=None, key=None, *, mix=None,
             t=None, mask=None, survival=None):
        """One Eq.-(6) consensus round on agent-stacked params (leading
        axis K). Returns ``(new_stacked_params, new_codec_state)`` for
        EVERY plan and codec (state is None for codec-free rounds).

        ``key`` enables stochastic rounding for quantizing codecs.

        Time-varying graphs: ``t`` (round index, may be traced) draws
        the round's edge survival from the engine's graph process in
        the plan's native shape — the preferred entry point for the
        scanned drivers; ``mask`` passes an explicit (K, K) bool
        survival mask instead (e.g. a host-prefetched
        :func:`topology.dropout` round), converted to the plan shape
        bit-identically; ``survival`` passes a plan-shaped operand a
        caller already drew via :meth:`round_survival` (so one draw can
        be shared with telemetry). All three renormalize σ on the
        surviving edges and run it as a traced operand — dense-xla
        takes the full masked mix, the sparse-pallas/sharded gathers
        take the per-lane σ with faded lanes zeroed (indices stay
        baked), and the distributed plan applies per-slot σ over its
        fixed ppermute schedule superset (faded slots σ = 0).

        ``mix`` overrides the engine's σ matrix wholesale for THIS round
        (may be traced); only the dense-xla plan supports it, every
        other plan bakes the neighbour structure in at trace time.
        """
        kind = self.plan.kind
        if mix is not None and kind != "dense-xla":
            raise ValueError(
                f"per-round mix overrides need the dense-xla plan, not "
                f"{kind!r} (sparse structure is fixed at trace time; "
                "time-varying graphs go through mask=/t= instead)")
        if self.agents is not None and survival is None:
            # deriving survival from t=/mask= here would silently
            # ignore WHO is awake — mixing sleeping agents at full
            # weight and billing wires nobody sent
            raise ValueError(
                f"this engine carries an availability process "
                f"{self.agents!r}: step() needs the staleness-weighted "
                "survival from async_round(t, age).weights passed via "
                "survival= — or drive whole rounds through async_step()"
                " / scan_rounds(), which thread the (clock, age) "
                "AsyncState carry for you")
        if survival is None and (mask is not None or t is not None):
            if mix is not None and mask is not None:
                raise ValueError(
                    f"step() got BOTH mix (shape {jnp.shape(mix)}) and "
                    f"mask (shape {jnp.shape(mask)}) — pass the explicit "
                    "mix= alone, or let mask=/t= rebuild σ from the "
                    "surviving graph")
            survival = self.round_survival(t, mask=mask)
        if survival is None and mix is None and self.graph.kind != "static":
            # silently mixing on the full static graph would measure t_i
            # (and bill Eq.-11) on a never-fading network — fail loudly
            raise ValueError(
                f"this engine carries a time-varying {self.graph!r}: "
                "step() needs the round index (t=) or an explicit "
                "survival mask (mask=); use scan_rounds for whole "
                "round loops")
        structure = None
        sig_override = None
        if survival is not None:
            if mix is not None:
                raise ValueError(
                    f"step() got BOTH mix (shape {jnp.shape(mix)}) and "
                    f"survival (shape {jnp.shape(survival)}) — pass the "
                    "explicit mix= alone, or let survival=/t= rebuild σ "
                    "from the surviving lanes")
            if kind == "dense-xla":
                mix = self.masked_mixing(survival)
            elif kind == "distributed":
                sig_override = self._schedule_sigma(survival)
            else:
                structure = self._lane_sigma(survival)
        mix_ = self.mix if mix is None else mix
        if kind == "dense-xla" or kind == "sparse-pallas":
            impl = "xla" if kind == "dense-xla" else "sparse"
            if self.codec is None:
                return consensus.consensus_step(
                    stacked_params, mix_, impl=impl,
                    block_n=self.block_n, structure=structure), None
            # error_feedback=False: self.codec is ALREADY resolved (the
            # EF default was applied at engine construction) — the step
            # functions must not re-wrap it
            return consensus.consensus_step(
                stacked_params, mix_, impl=impl, block_n=self.block_n,
                codec=self.codec, codec_state=codec_state, key=key,
                gamma=self.gamma, error_feedback=False,
                structure=structure)
        if kind == "sharded":
            return consensus.sharded_consensus_step(
                stacked_params, mix_, num_blocks=self.plan.num_blocks,
                axis_name=self.plan.axis_name, mesh=self.mesh,
                codec=self.codec, codec_state=codec_state, key=key,
                gamma=self.gamma, block_n=self.block_n,
                error_feedback=False, structure=structure)
        if self._schedule is None:
            self._schedule = consensus.permutation_schedule(
                self.mix, self.gamma)
        return consensus.distributed_consensus_step(
            stacked_params, mix_, axis_name=self.plan.axis_name,
            mesh=self.mesh, codec=self.codec, codec_state=codec_state,
            key=key, gamma=self.gamma, schedule=self._schedule,
            error_feedback=False, sig_override=sig_override)

    def scan_rounds(self, stacked_params, codec_state=None, keys=None, *,
                    rounds: Optional[int] = None, t0=0, telemetry=None):
        """Run many Eq.-(6) rounds inside ONE ``jax.lax.scan`` program.

        ``keys``: optional (R, …) stacked PRNG keys, one per round
        (stochastic rounding); without them pass ``rounds=R`` and every
        round runs key-free. The codec / error-feedback state threads
        through the scan carry for all four plans (``codec_state=None``
        initializes stacked zero residuals for stateful codecs), and the
        distributed plan's host-side ppermute permutation schedule is
        resolved HERE, before the scan body is traced, so the loop body
        contains only the collectives. Returns ``(params, codec_state)``
        after R rounds — bit-identical to R successive :meth:`step`
        calls. Trace-time structure (sparse gathers, schedules) is baked
        once per program instead of once per round, which is what the
        chunked drivers (:func:`repro.core.federated.run_fl_until_scan`,
        :func:`repro.core.maml.maml_train_scan`) and the ``rounds_loop``
        benchmark build on.

        Time-varying graphs run device-resident: with a non-static
        :class:`~repro.core.topology.GraphProcess` the rounds are
        numbered ``t0, t0+1, …`` (``t0`` may be traced — chunked callers
        pass each chunk's global offset) and every round's survival mask
        is generated IN-SCAN from the folded process key; no host-side
        per-round graph prefetch, and the masks are bit-identical to the
        host ``topology.dropout`` stream.

        ``telemetry`` (:class:`repro.telemetry.Telemetry`) records one
        row per round (Eq.-(11) joules by link class from the round's
        ACTUAL surviving links, disagreement, wire bits): buffered mode
        stays pure (rows ride the scan outputs, ingested host-side
        right here — so the call must run OUTSIDE any caller jit);
        streaming mode additionally emits each round live via
        ``jax.debug.callback``. Params/state are bit-identical with
        telemetry off, buffered, or streaming: the rows read the round
        state, the mixing consumes the same mask either way.
        """
        if keys is None and rounds is None:
            raise ValueError(
                f"scan_rounds got keys={keys!r} and rounds={rounds!r} — "
                "pass rounds= (a round count) or keys= (one PRNG key "
                "per round, e.g. jax.random.split(key, R))")
        if codec_state is None:
            codec_state = self.init_state(stacked_params)
        if self.plan.kind == "distributed" and self._schedule is None:
            # hoist the host-computed schedule out of the scan body
            self._schedule = consensus.permutation_schedule(
                self.mix, self.gamma)
        is_async = self.agents is not None
        R = (int(rounds) if keys is None
             else jax.tree.leaves(keys)[0].shape[0])
        ts = (t0 + jnp.arange(R, dtype=jnp.int32)
              if (self.graph.kind != "static" or is_async
                  or telemetry is not None)
              else None)
        recorder = (telemetry.recorder_for(self)
                    if telemetry is not None else None)
        stream_cb = (telemetry.stream_cb(recorder, "consensus")
                     if telemetry is not None and telemetry.streaming
                     else None)

        def body(carry, xs):
            t, k = xs
            if is_async:
                p0, st0, ast = carry
                # the round's availability facts are drawn ONCE and
                # shared between the mixing weights, the per-agent
                # freeze, and the telemetry row (which bills only
                # DELIVERED wires)
                ar = self.async_round(t, ast.age)
                p, st = self.step(p0, st0, k, survival=ar.weights)
                p = where_active(ar.act, p, p0)
                if st is not None:
                    st = where_active(ar.act, st, st0)
                ast = AsyncState(
                    ast.clock + ar.act.astype(ast.clock.dtype), ar.age)
                out = (p, st, ast)
                sv_row, act, age = ar.delivered, ar.act, ar.age
            else:
                # telemetry draws the round's survival ONCE — in the
                # plan's native shape, never a dense (K, K) rebuild —
                # and shares it with step() (survival= takes precedence
                # over t=; identical ops, so results match the
                # telemetry-off t= path bit for bit)
                sv = (self.round_survival(t)
                      if telemetry is not None and t is not None
                      else None)
                p, st = self.step(carry[0], carry[1], k, t=t, survival=sv)
                out = (p, st)
                sv_row, act, age = sv, None, None
            row = None
            if telemetry is not None:
                row = recorder.row(p, sv_row, metric=jnp.float32(0.0),
                                   reached=jnp.asarray(False),
                                   live=jnp.asarray(True),
                                   active=act, age=age)
                if stream_cb is not None:
                    jax.debug.callback(stream_cb, t, row, ordered=True)
            return out, row

        carry0 = (stacked_params, codec_state)
        if is_async:
            # NOTE: each scan_rounds call starts a FRESH AsyncState
            # (clocks and ages at zero); callers that chunk a longer
            # round loop thread the state themselves via async_step or
            # the FL drivers, which carry it across chunks
            carry0 = carry0 + (self.init_async_state(),)
        if ts is None and keys is None:
            final, rows = jax.lax.scan(
                lambda c, _x: body(c, (None, None)),
                carry0, None, length=R)
        else:
            final, rows = jax.lax.scan(body, carry0, (ts, keys))
        p, st = final[0], final[1]
        if telemetry is not None:
            telemetry.record_rounds(recorder, rows, t0, driver="consensus")
        return p, st

    # -- Eq.-(11) pricing ---------------------------------------------------
    def round_comm_joules(self, energy_params,
                          model_bits: Optional[float] = None) -> float:
        """Eq.-(11) communication energy of ONE round at THIS engine's
        wire format (delegates to the topology's codec-aware pricing)."""
        if self.topology is None:
            raise ValueError(
                f"this {self.plan.kind!r} engine was built from a raw "
                f"{self.mix.shape} mix matrix, which carries no link "
                "classes to bill; construct it from a Topology (e.g. "
                "topology.ring(K)) to price rounds")
        return self.topology.round_comm_joules(
            energy_params, model_bits=model_bits, codec=self.codec)

    # -- audit metadata -----------------------------------------------------
    def audit_meta(self) -> dict:
        """Resolved facts ``repro.analysis`` keys its checks on: the
        plan kind, its :data:`PLAN_AUDIT_EXPECTATIONS` entry, and the
        wire codec (base codec under the error-feedback wrapper, with
        its int-lane bit width if any). Rule H2 reconciles the compiled
        module's collective bytes against ``codec.model_bits(tree)``;
        the C-layer (``repro.analysis.costmodel``) additionally reads
        ``link_classes`` (the topology's per-class directed message
        counts, ``None`` on raw-mix engines) and ``priced_collectives``
        (which HLO collective kind carries the Eq.-(11)-billed wire
        payload for this plan — every other collective in the compiled
        module must be control plane or allowlisted, rule C3)."""
        base = (getattr(self.codec, "inner", self.codec)
                if self.codec is not None else None)
        meta = dict(PLAN_AUDIT_EXPECTATIONS[self.plan.kind])
        link_classes = (None if self.topology is None else {
            k: v for k, v in self.topology.links_per_round().items()
            if k != "NONE"})
        wire = meta.get("wire_collective")
        meta.update(
            plan=self.plan.kind, K=self.K,
            num_blocks=self.plan.num_blocks,
            axis_name=self.plan.axis_name,
            mesh_axis=(None if self.mesh is None else
                       dict(self.mesh.shape).get(self.plan.axis_name)),
            codec=None if self.codec is None else self.codec.name,
            qbits=getattr(base, "qbits", None),
            link_classes=link_classes,
            priced_collectives=({} if wire is None
                                else {wire: link_classes}),
        )
        return meta

    # -- conveniences -------------------------------------------------------
    @classmethod
    def wrap(cls, obj, **kw) -> "ConsensusEngine":
        """Coerce ``obj`` (engine, Topology, or concrete mix) to an
        engine; extra kwargs only apply when constructing a new one."""
        if isinstance(obj, cls):
            if any(v is not None for v in kw.values()):
                raise ValueError(
                    f"{sorted(k for k, v in kw.items() if v is not None)} "
                    "cannot be re-specified for an existing engine")
            return obj
        return cls(obj, **kw)

    def __repr__(self):
        codec = self.codec.name if self.codec is not None else None
        graph = "" if self.graph.kind == "static" else f", graph={self.graph!r}"
        agents = "" if self.agents is None else (
            f", agents={self.agents!r}, tau="
            f"{'inf' if self.tau is None else self.tau}")
        return (f"ConsensusEngine(K={self.K}, plan={self.plan.kind!r}, "
                f"codec={codec!r}, blocks={self.plan.num_blocks}"
                f"{graph}{agents})")
