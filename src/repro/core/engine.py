"""ConsensusEngine — the single entry point for one Eq.-(6) mixing round.

The paper's energy balance (Eqs. 6/11) is evaluated per consensus round,
so the round executor is the hot path of every scaling experiment. This
module turns a ``(Topology, K, codec, mesh)`` description into an
execution **plan** once, at construction, and every caller
(:mod:`repro.core.protocol`, :mod:`repro.core.federated`,
:mod:`repro.rl.casestudy`, :mod:`repro.launch.train`, the scale
benchmark) drives the same ``engine.step(stacked_params, codec_state,
key) -> (params, codec_state)`` — no ``impl=`` strings or per-caller
path wiring.

Plans
-----
* ``dense-xla``     — the reference (K, K) matmul per leaf; also accepts
  a TRACED per-round full mix override via ``step(mix=...)`` (the legacy
  time-varying hook, kept for raw-σ callers).
* ``sparse-pallas`` — batched-over-agents sparse gather through the fused
  Pallas consensus kernels (the bit-identical jnp oracle off TPU);
  O(K·H·N) instead of O(K²·N).
* ``sharded``       — the sparse gather under shard_map over an agent
  axis: each mesh position owns a block of K/num_blocks agents, encodes
  its own block's wires, ``all_gather``s the (K, ·) WIRE (codec bytes,
  not f32), and mixes only its rows. No single program materializes the
  (K, K) stack, which is what lets K = 16384 populations mix on meshes
  of any size (and on one CPU via the vmap-with-axis_name emulation).
* ``distributed``   — one agent per mesh position; neighbour exchange is
  ``jax.lax.ppermute`` rounds from a host-computed permutation schedule,
  and the permuted payload is the CODEC wire: int8/int4 lanes + scales,
  bf16 casts. This makes ``Topology.round_comm_joules(codec=)`` pricing
  truthful on the one path that actually distributes across a mesh —
  an int8 wire ships (and prices) 4× below f32.

Wire formats per path: ``dense-xla`` mixes DECODED f32 models (the wire
is an accounting construct priced by Eq. 11); ``sparse-pallas`` and
``sharded`` gather the int-quantized wire itself through the fused
dequant-consensus kernel — int8/int4 lanes with per-tensor OR
block-wise ``int8:b64`` scales (other codecs decode before the
gather); ``distributed`` permutes the wire payload for every codec.

Time-varying graphs (:class:`repro.core.topology.GraphProcess`)
---------------------------------------------------------------
``ConsensusEngine(topo, graph=GraphProcess.dropout(p, seed))`` resolves
a time-varying graph process ONCE at construction, making per-round
link failures a capability of EVERY plan. Survival is drawn per EDGE:
each directed edge owns a canonical id (symmetric pairs share one, so
a faded channel kills both directions) and round ``t``'s draw is the
pure function ``uniform(fold_in(fold_in(key, t), edge_id)) >= p``
(:func:`repro.core.topology.survival_mask`, the single blessed draw
site — rule R1). Because every edge's fate is independent of HOW the
edges are enumerated, each plan draws survival in its own native
shape — O(#edges) work, never a dense rebuild — via
:meth:`round_survival`:

* ``dense-xla``     — the (K, K) mask; :meth:`masked_mixing` REBUILDS
  the σ matrix on the surviving graph with the engine's mixing kind,
  riding the matmul as a traced operand (dropped links reallocate
  their σ mass; doubly-stochastic kinds stay doubly stochastic on
  every surviving subgraph);
* ``sparse-pallas`` / ``sharded`` — the gather INDICES stay baked from
  the full base graph; survival is drawn straight into the (K, H)
  neighbour-lane table and the per-lane σ is renormalized DIRECTLY on
  the lanes (same values bit for bit as the dense rebuild under the
  default uniform data sizes) and rides the fused (dequant-)consensus
  kernels as a traced operand, so faded lanes carry σ = 0 (exact
  no-ops) — one compiled program for every round and O(K·H) per-round
  work, no (K, K) buffer anywhere (rule H1 holds at K = 4096 WITH
  dropout active);
* ``distributed``   — the ppermute schedule SUPERSET of the base graph
  is resolved once at construction (every surviving graph is a
  subgraph, and each directed edge is carried by exactly one schedule
  slot); survival is drawn straight into the (M, K) schedule table,
  the per-slot σ is renormalized on the survivors and rides the
  permutes as a traced (K, M) operand — faded slots apply σ = 0 while
  the wire still ships the full M permutations (a fixed TDMA-frame-
  like schedule; Eq.-(11) billing counts only the surviving real
  edges). Graphs whose schedule superset exceeds
  :data:`DISTRIBUTED_SCHEDULE_BOUND` slots are refused at
  construction.

Draws are bit-identical to the host
:func:`repro.core.topology.dropout` stream via the shared per-edge
fold-in convention, which is what lets callers bill Eq.-(11) joules
post hoc over exactly the rounds used with ZERO host-side per-round
graph prefetch.

Multi-round programs: :meth:`ConsensusEngine.scan_rounds` runs R rounds
inside one ``lax.scan`` with the codec/EF state in the carry — the
building block of the chunked protocol drivers
(:func:`repro.core.federated.run_fl_until_scan`,
:func:`repro.core.maml.maml_train_scan`), which compile whole stretches
of the round loop into single programs and sync the host once per
chunk.

CHOCO mean-exactness invariant: every compressed plan recenters each
agent's update on its OWN decoded copy — W_k + Σ_h σ_{k,h}(x̂_h − x̂_k) —
so under doubly-stochastic σ the population mean is exactly preserved no
matter how lossy the codec; the error-feedback wrapper (on by default
for lossy codecs) telescopes the per-round quantization error. All four
plans therefore agree with the dense-f32 oracle to within the codec's
round-trip tolerance (tested at K = 256 in ``tests/test_engine.py``).

``plan="auto"`` selection: with no mesh, the payload-aware density
heuristic (:func:`repro.core.consensus.auto_path`) picks dense-xla vs
sparse-pallas; with a mesh carrying the agent axis, one-agent-per-
position meshes take ``distributed`` and everything else ``sharded``
(blocks = mesh axis size).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import consensus

PLAN_KINDS = ("dense-xla", "sparse-pallas", "sharded", "distributed")
#: plans that accept a per-round survival mask (traced σ operands).
#: Since the per-edge draw convention, ALL of them: the distributed
#: plan keeps its ppermute schedule superset fixed at trace time and
#: masks individual schedule slots via a traced (K, M) σ operand.
MASKABLE_PLANS = ("dense-xla", "sparse-pallas", "sharded", "distributed")

#: largest ppermute-schedule superset a time-varying ``distributed``
#: engine accepts (schedule length ≈ the base graph's max degree — one
#: slot per matching). Every masked round ships all M slots whether or
#: not their edges survived (the superset is the fixed TDMA frame), so
#: a graph needing more slots than this would spend more air time on
#: faded slots than a prefetched-schedule rebuild costs; such graphs
#: are refused at construction.
DISTRIBUTED_SCHEDULE_BOUND = 64

#: per-plan compiled-artifact expectations ``repro.analysis`` keys on.
#: ``kk_buffer``: whether the plan's program may legitimately
#: materialize a (K, K) tensor (the dense σ stack); the sharded and
#: distributed plans exist precisely so it never does, and the HLO
#: auditor (rule H1) fails them if one appears at K ≥ its threshold.
#: ``wire_collective``: which collective carries the codec WIRE on a
#: real mesh — the op whose result bytes rule H2 reconciles against
#: ``codec.bits()`` pricing. ``int_lane_gather``: the plan mixes
#: int-codec wires through a fused gather that must keep int8/int4
#: lanes (the decode-then-combine regression class, rule JX2).
PLAN_AUDIT_EXPECTATIONS = {
    "dense-xla":     {"kk_buffer": True, "wire_collective": None,
                      "int_lane_gather": False},
    "sparse-pallas": {"kk_buffer": False, "wire_collective": None,
                      "int_lane_gather": True},
    "sharded":       {"kk_buffer": False, "wire_collective": "all-gather",
                      "int_lane_gather": True},
    "distributed":   {"kk_buffer": False,
                      "wire_collective": "collective-permute",
                      "int_lane_gather": False},
}


@dataclass(frozen=True)
class ExecutionPlan:
    """A resolved consensus execution strategy (see module docstring)."""

    kind: str
    reason: str
    num_blocks: int = 1
    axis_name: str = "agents"

    def __post_init__(self):
        if self.kind not in PLAN_KINDS:
            raise ValueError(f"unknown plan {self.kind!r}; "
                             f"choose from {PLAN_KINDS} or 'auto'")


class ConsensusEngine:
    """One Eq.-(6) round behind one entry point (see module docstring).

    Arguments
    ---------
    topology:   a :class:`repro.core.topology.Topology` (preferred — also
                enables :meth:`round_comm_joules`) or a concrete (K, K)
                σ matrix.
    codec:      model-exchange codec spec/Codec (:mod:`repro.comms`);
                lossy codecs get the error-feedback wrapper unless
                ``error_feedback=False``.
    mesh:       optional ``jax.sharding.Mesh`` whose ``axis_name`` axis
                carries agents (one per position ⇒ distributed; blocks
                ⇒ sharded). ``None`` runs every plan in one program
                (sharded/distributed fall back to the vmap-with-
                axis_name emulation, which shares collective semantics).
    plan:       "auto" (default) or one of :data:`PLAN_KINDS`.
    num_blocks: block count for the sharded plan (default: mesh axis
                size, else 1).
    data_sizes / mix_kind / include_self: forwarded to the topology's
                ``mixing`` (uniform paper weights by default) and reused
                to REBUILD the per-round mix on surviving subgraphs when
                a time-varying ``graph`` is attached.
    gamma:      CHOCO consensus step size (damps off-diagonal σ).
    graph:      a :class:`repro.core.topology.GraphProcess` (or None ⇒
                static). Non-static processes turn EVERY plan
                time-varying: each round's edge survival is drawn
                in-scan from the folded process key in the plan's
                native shape — (K, K) mask, (K, H) lanes, or (M, K)
                schedule slots — and the σ is renormalized on the
                survivors (see the module docstring). The
                ``distributed`` plan resolves its ppermute schedule
                superset here, at construction, and refuses graphs
                needing more than :data:`DISTRIBUTED_SCHEDULE_BOUND`
                slots.
    """

    def __init__(self, topology, *, codec=None, mesh=None,
                 plan: str = "auto", axis_name: str = "agents",
                 num_blocks: Optional[int] = None, data_sizes=None,
                 mix_kind: str = "paper", include_self: bool = True,
                 gamma: float = 1.0, error_feedback: bool = True,
                 block_n: Optional[int] = None, graph=None):
        from repro import comms   # deferred: core stays import-light
        from repro.core import topology as topo_lib
        if isinstance(topology, ConsensusEngine):
            raise TypeError("pass a Topology or mix, not an engine "
                            "(use ConsensusEngine.wrap)")
        self.topology = topology if hasattr(topology, "mixing") else None
        self.mix = np.asarray(
            topology.mixing(data_sizes, kind=mix_kind,
                            include_self=include_self)
            if self.topology is not None else topology, np.float32)
        self.K = self.mix.shape[0]
        self.codec = comms.resolve_codec(codec, error_feedback)
        self.mesh = mesh
        self.gamma = float(gamma)
        self.block_n = block_n
        self.mix_kind = mix_kind
        self.include_self = include_self
        self.data_sizes = (None if data_sizes is None
                           else np.asarray(data_sizes, np.float32))
        self.graph = graph if graph is not None else topo_lib.GraphProcess.static()
        self.plan = self._resolve_plan(plan, axis_name, num_blocks)
        self._schedule = None          # distributed ppermute rounds, lazy
        self._masked_struct = None     # (idx, lane-valid) for masked sig
        self._sched_struct = None      # (srcs, real) of the schedule
        self._sched_keep = None        # schedule-kind masks, plan-shaped
        if self.graph.kind != "static":
            if self.topology is None:
                # a raw σ matrix's generating rule is unknown, so the
                # per-round rebuild would silently REPLACE the caller's
                # weights with mixing_weights(kind) on the survivor —
                # refuse rather than diverge
                raise ValueError(
                    "time-varying graphs need an engine built from a "
                    "Topology: each round's σ is REBUILT from the "
                    "surviving graph with the engine's mixing "
                    "kind/data_sizes, which cannot faithfully "
                    "renormalize an arbitrary raw mix matrix")
            # the base adjacency the survival masks apply to
            self._adjacency = np.asarray(self.topology.adjacency, bool)
            self._symmetric = bool(
                (self._adjacency == self._adjacency.T).all())
            if self.graph.kind == "dropout":
                self._graph_key = topo_lib.survival_key(self.graph.seed)
            elif self.graph.masks.shape[1:] != (self.K, self.K):
                raise ValueError(
                    f"schedule masks are {self.graph.masks.shape[1:]}, "
                    f"population is K={self.K}")
            if self.plan.kind == "distributed":
                # resolve the ppermute schedule SUPERSET now: every
                # surviving graph is a subgraph of the base graph, so a
                # schedule covering the base graph covers every round —
                # masked slots ride as σ = 0 on a traced operand, no
                # retrace. One slot per matching ⇒ length ≈ max degree.
                self._schedule = consensus.permutation_schedule(
                    self.mix, self.gamma)
                if len(self._schedule) > DISTRIBUTED_SCHEDULE_BOUND:
                    raise ValueError(
                        f"time-varying graphs on the distributed plan "
                        f"mask a fixed ppermute schedule superset, and "
                        f"this graph needs {len(self._schedule)} "
                        f"schedule slots (≈ max degree "
                        f"{self.topology.max_degree}) — over the "
                        f"{DISTRIBUTED_SCHEDULE_BOUND}-slot bound "
                        "(DISTRIBUTED_SCHEDULE_BOUND). Use a sparser "
                        "base graph, or the sharded plan (per-lane "
                        "masks, no schedule)")

    # -- plan selection -----------------------------------------------------
    def _resolve_plan(self, plan: str, axis_name: str,
                      num_blocks: Optional[int]) -> ExecutionPlan:
        mesh_axis = consensus._mesh_axis(self.mesh, axis_name)
        if plan == "auto":
            if mesh_axis is not None:
                if mesh_axis == self.K:
                    return ExecutionPlan(
                        "distributed", "mesh holds one agent per "
                        f"'{axis_name}' position", 1, axis_name)
                nb = num_blocks or mesh_axis
                if self.K % nb:
                    # a mesh was given: honour it — fall back to the
                    # largest block count that divides K rather than
                    # silently reverting to a single-program plan
                    nb = next(d for d in range(min(nb, self.K), 0, -1)
                              if self.K % d == 0)
                return ExecutionPlan(
                    "sharded", f"K={self.K} agents in {nb} blocks over "
                    f"the {mesh_axis}-wide '{axis_name}' mesh axis",
                    nb, axis_name)
            base = getattr(self.codec, "inner", self.codec)
            dense = consensus.auto_path(self.mix, codec=base) == "dense"
            return ExecutionPlan(
                "dense-xla" if dense else "sparse-pallas",
                "payload-aware density heuristic "
                f"(max degree vs K={self.K})", 1, axis_name)
        if plan == "sharded":
            nb = num_blocks or mesh_axis or 1
            return ExecutionPlan("sharded", "explicit", nb, axis_name)
        return ExecutionPlan(plan, "explicit", num_blocks or 1, axis_name)

    # -- state --------------------------------------------------------------
    def init_state(self, stacked_params):
        """Initial codec state (stacked EF residuals; None if stateless)."""
        if self.codec is None or not self.codec.stateful:
            return None
        return self.codec.init_state(stacked_params)

    # -- time-varying graphs ------------------------------------------------
    def round_mask(self, t):
        """(K, K) bool edge-survival mask of round ``t`` under this
        engine's :class:`~repro.core.topology.GraphProcess` (None for a
        static graph). ``t`` may be TRACED — this is what the scanned
        drivers call per round INSIDE ``lax.scan``, and by the shared
        fold-in convention the result is bit-identical to round ``t`` of
        the host :func:`repro.core.topology.dropout` stream."""
        from repro.core import topology as topo_lib
        if self.graph.kind == "static":
            return None
        if self.graph.kind == "dropout":
            return topo_lib.survival_mask(
                self._adjacency, self.graph.p, self._graph_key, t,
                symmetric=self._symmetric)
        masks = jnp.asarray(self.graph.masks)          # schedule
        return jnp.asarray(self._adjacency) & masks[
            jnp.asarray(t) % masks.shape[0]]

    def masked_mixing(self, mask):
        """Rebuild the σ matrix on the SURVIVING graph (possibly traced
        mask): the engine's mixing kind / data sizes / include_self are
        re-applied to ``adjacency & mask``, so dropped links reallocate
        their σ mass exactly as ``Topology.mixing`` would on the
        host-materialized survivor (bit-identical — same jnp ops)."""
        sizes = (np.ones(self.K, np.float32) if self.data_sizes is None
                 else self.data_sizes)
        return consensus.mixing_weights(
            sizes, mask, self.mix_kind, include_self=self.include_self)

    def lane_structure(self):
        """(idx, valid) neighbour-lane table of the BASE graph for the
        sparse/sharded plans: idx (K, H) int32 ascending neighbour
        indices (padding lanes index the agent itself), valid (K, H)
        bool marking real lanes. Baked once, lazily, as numpy — the
        cache outlives any one trace, so it must never hold
        tracer-backed arrays."""
        if self._masked_struct is None:
            A = (np.asarray(self.topology.adjacency, bool).copy()
                 if self.topology is not None else self.mix != 0)
            np.fill_diagonal(A, False)
            deg = A.sum(axis=1)
            H = max(int(deg.max()), 1) if self.K else 1
            idx = np.tile(np.arange(self.K, dtype=np.int32)[:, None],
                          (1, H))
            for k in range(self.K):
                nbr = np.flatnonzero(A[k])
                idx[k, :len(nbr)] = nbr
            valid = np.arange(H)[None, :] < deg[:, None]
            self._masked_struct = (idx, valid)
        return self._masked_struct

    def schedule_structure(self):
        """(srcs, real) of the distributed plan's ppermute schedule
        superset: srcs (M, K) int32 — the mesh position each target
        receives from in slot m — and real (M, K) bool marking slots
        that carry an actual base-graph edge (the rest are permutation-
        completion padding, σ = 0 forever). Baked once, lazily, numpy."""
        if self._sched_struct is None:
            if self._schedule is None:
                self._schedule = consensus.permutation_schedule(
                    self.mix, self.gamma)
            M = len(self._schedule)
            srcs = np.zeros((M, self.K), np.int32)
            real = np.zeros((M, self.K), bool)
            for m, (pairs, sig) in enumerate(self._schedule):
                for s, tgt in pairs:
                    srcs[m, tgt] = s
                real[m] = np.asarray(sig) != 0.0
            self._sched_struct = (srcs, real)
        return self._sched_struct

    def round_survival(self, t=None, mask=None):
        """Round ``t``'s edge survival in THIS plan's native shape —
        the in-scan fast path that never materializes (K, K) on the
        non-dense plans: a (K, K) bool mask on dense-xla, surviving-
        lane (K, H) bools on sparse-pallas/sharded, surviving-slot
        (M, K) bools on distributed. ``t`` may be traced; ``mask``
        instead converts an explicit (K, K) survival mask (e.g. a
        host-prefetched :func:`repro.core.topology.dropout` round) to
        the plan shape — bit-identical to the in-scan draw of the same
        round by the shared per-edge fold-in convention. Returns None
        for a static graph with no explicit mask."""
        from repro.core import topology as topo_lib
        kind = self.plan.kind
        if kind == "dense-xla":
            return (jnp.asarray(mask) if mask is not None
                    else self.round_mask(t))
        if mask is None and self.graph.kind == "static":
            return None
        if kind == "distributed":
            srcs, real = self.schedule_structure()
            rows = np.arange(self.K, dtype=np.int32)[None, :]
        else:
            srcs, real = self.lane_structure()      # (idx, valid)
            rows = np.arange(self.K, dtype=np.int32)[:, None]
        if mask is not None:
            keep = jnp.asarray(mask)[rows, srcs]
        elif self.graph.kind == "dropout":
            keep = topo_lib.survival_mask(
                self.K, self.graph.p, self._graph_key, t,
                symmetric=self._symmetric, receivers=rows, senders=srcs)
        else:                                        # schedule masks
            if self._sched_keep is None:
                # pre-gather the (R, K, K) mask stack into the plan
                # shape ONCE (numpy), so the in-scan lookup is a
                # dynamic slice of lanes/slots, never a (K, K) constant
                self._sched_keep = np.asarray(
                    self.graph.masks[:, rows, srcs])
            stack = jnp.asarray(self._sched_keep)
            keep = stack[jnp.asarray(t) % stack.shape[0]]
        return keep & jnp.asarray(real)

    def _sizes(self):
        return (np.ones(self.K, np.float32) if self.data_sizes is None
                else self.data_sizes)

    def _lane_sigma(self, survival):
        """(idx, sig_t) structure for the sparse/sharded plans: σ
        renormalized DIRECTLY on the surviving (K, H) lanes — same
        formulas as :func:`repro.core.consensus.mixing_weights` per
        entry, O(K·H) with no dense rebuild. Faded/padding lanes land
        at σ = 0, exact no-ops in the fused kernels. Bit-identical to
        gathering the dense rebuild under uniform data sizes (sums of
        equal addends are association-free)."""
        idx, _valid = self.lane_structure()
        keep = jnp.asarray(survival)
        sizes = jnp.asarray(self._sizes())
        if self.mix_kind == "paper":
            w = jnp.where(keep, sizes[jnp.asarray(idx)], 0.0)
            denom = w.sum(axis=1)
            if self.include_self:
                denom = denom + sizes
            sig = w / jnp.maximum(denom, 1e-12)[:, None]
        elif self.mix_kind == "metropolis":
            deg = keep.sum(axis=1).astype(jnp.float32)
            sig = jnp.where(
                keep,
                1.0 / (1.0 + jnp.maximum(deg[:, None],
                                         deg[jnp.asarray(idx)])),
                0.0)
        else:
            raise ValueError(f"unknown kind {self.mix_kind!r}")
        return jnp.asarray(idx), sig

    def _schedule_sigma(self, survival):
        """γ-scaled (K, M) schedule σ for the distributed plan,
        renormalized on the surviving (M, K) slots — the traced
        ``sig_override`` operand that replaces the baked full-graph
        ``sig_stack`` without retracing (the ppermute pairs stay
        trace-time structure). Every real directed edge occupies
        exactly one slot, so the per-target sum over slots equals the
        dense rebuild's per-row sum over neighbours."""
        srcs, _real = self.schedule_structure()
        keep = jnp.asarray(survival)                 # (M, K)
        sizes = jnp.asarray(self._sizes())
        if self.mix_kind == "paper":
            w = jnp.where(keep, sizes[jnp.asarray(srcs)], 0.0)
            denom = w.sum(axis=0)
            if self.include_self:
                denom = denom + sizes
            sig = w / jnp.maximum(denom, 1e-12)[None, :]
        elif self.mix_kind == "metropolis":
            deg = keep.sum(axis=0).astype(jnp.float32)
            sig = jnp.where(
                keep,
                1.0 / (1.0 + jnp.maximum(deg[None, :],
                                         deg[jnp.asarray(srcs)])),
                0.0)
        else:
            raise ValueError(f"unknown kind {self.mix_kind!r}")
        return (self.gamma * sig).T

    # -- the round ----------------------------------------------------------
    def step(self, stacked_params, codec_state=None, key=None, *, mix=None,
             t=None, mask=None, survival=None):
        """One Eq.-(6) consensus round on agent-stacked params (leading
        axis K). Returns ``(new_stacked_params, new_codec_state)`` for
        EVERY plan and codec (state is None for codec-free rounds).

        ``key`` enables stochastic rounding for quantizing codecs.

        Time-varying graphs: ``t`` (round index, may be traced) draws
        the round's edge survival from the engine's graph process in
        the plan's native shape — the preferred entry point for the
        scanned drivers; ``mask`` passes an explicit (K, K) bool
        survival mask instead (e.g. a host-prefetched
        :func:`topology.dropout` round), converted to the plan shape
        bit-identically; ``survival`` passes a plan-shaped operand a
        caller already drew via :meth:`round_survival` (so one draw can
        be shared with telemetry). All three renormalize σ on the
        surviving edges and run it as a traced operand — dense-xla
        takes the full masked mix, the sparse-pallas/sharded gathers
        take the per-lane σ with faded lanes zeroed (indices stay
        baked), and the distributed plan applies per-slot σ over its
        fixed ppermute schedule superset (faded slots σ = 0).

        ``mix`` overrides the engine's σ matrix wholesale for THIS round
        (may be traced); only the dense-xla plan supports it, every
        other plan bakes the neighbour structure in at trace time.
        """
        kind = self.plan.kind
        if mix is not None and kind != "dense-xla":
            raise ValueError(
                f"per-round mix overrides need the dense-xla plan, not "
                f"{kind!r} (sparse structure is fixed at trace time; "
                "time-varying graphs go through mask=/t= instead)")
        if survival is None and (mask is not None or t is not None):
            if mix is not None and mask is not None:
                raise ValueError("pass mix= or mask=/t=, not both")
            survival = self.round_survival(t, mask=mask)
        if survival is None and mix is None and self.graph.kind != "static":
            # silently mixing on the full static graph would measure t_i
            # (and bill Eq.-11) on a never-fading network — fail loudly
            raise ValueError(
                f"this engine carries a time-varying {self.graph!r}: "
                "step() needs the round index (t=) or an explicit "
                "survival mask (mask=); use scan_rounds for whole "
                "round loops")
        structure = None
        sig_override = None
        if survival is not None:
            if mix is not None:
                raise ValueError("pass mix= or mask=/t=, not both")
            if kind == "dense-xla":
                mix = self.masked_mixing(survival)
            elif kind == "distributed":
                sig_override = self._schedule_sigma(survival)
            else:
                structure = self._lane_sigma(survival)
        mix_ = self.mix if mix is None else mix
        if kind == "dense-xla" or kind == "sparse-pallas":
            impl = "xla" if kind == "dense-xla" else "sparse"
            if self.codec is None:
                return consensus.consensus_step(
                    stacked_params, mix_, impl=impl,
                    block_n=self.block_n, structure=structure), None
            # error_feedback=False: self.codec is ALREADY resolved (the
            # EF default was applied at engine construction) — the step
            # functions must not re-wrap it
            return consensus.consensus_step(
                stacked_params, mix_, impl=impl, block_n=self.block_n,
                codec=self.codec, codec_state=codec_state, key=key,
                gamma=self.gamma, error_feedback=False,
                structure=structure)
        if kind == "sharded":
            return consensus.sharded_consensus_step(
                stacked_params, mix_, num_blocks=self.plan.num_blocks,
                axis_name=self.plan.axis_name, mesh=self.mesh,
                codec=self.codec, codec_state=codec_state, key=key,
                gamma=self.gamma, block_n=self.block_n,
                error_feedback=False, structure=structure)
        if self._schedule is None:
            self._schedule = consensus.permutation_schedule(
                self.mix, self.gamma)
        return consensus.distributed_consensus_step(
            stacked_params, mix_, axis_name=self.plan.axis_name,
            mesh=self.mesh, codec=self.codec, codec_state=codec_state,
            key=key, gamma=self.gamma, schedule=self._schedule,
            error_feedback=False, sig_override=sig_override)

    def scan_rounds(self, stacked_params, codec_state=None, keys=None, *,
                    rounds: Optional[int] = None, t0=0, telemetry=None):
        """Run many Eq.-(6) rounds inside ONE ``jax.lax.scan`` program.

        ``keys``: optional (R, …) stacked PRNG keys, one per round
        (stochastic rounding); without them pass ``rounds=R`` and every
        round runs key-free. The codec / error-feedback state threads
        through the scan carry for all four plans (``codec_state=None``
        initializes stacked zero residuals for stateful codecs), and the
        distributed plan's host-side ppermute permutation schedule is
        resolved HERE, before the scan body is traced, so the loop body
        contains only the collectives. Returns ``(params, codec_state)``
        after R rounds — bit-identical to R successive :meth:`step`
        calls. Trace-time structure (sparse gathers, schedules) is baked
        once per program instead of once per round, which is what the
        chunked drivers (:func:`repro.core.federated.run_fl_until_scan`,
        :func:`repro.core.maml.maml_train_scan`) and the ``rounds_loop``
        benchmark build on.

        Time-varying graphs run device-resident: with a non-static
        :class:`~repro.core.topology.GraphProcess` the rounds are
        numbered ``t0, t0+1, …`` (``t0`` may be traced — chunked callers
        pass each chunk's global offset) and every round's survival mask
        is generated IN-SCAN from the folded process key; no host-side
        per-round graph prefetch, and the masks are bit-identical to the
        host ``topology.dropout`` stream.

        ``telemetry`` (:class:`repro.telemetry.Telemetry`) records one
        row per round (Eq.-(11) joules by link class from the round's
        ACTUAL surviving links, disagreement, wire bits): buffered mode
        stays pure (rows ride the scan outputs, ingested host-side
        right here — so the call must run OUTSIDE any caller jit);
        streaming mode additionally emits each round live via
        ``jax.debug.callback``. Params/state are bit-identical with
        telemetry off, buffered, or streaming: the rows read the round
        state, the mixing consumes the same mask either way.
        """
        if keys is None and rounds is None:
            raise ValueError("pass per-round keys or rounds=")
        if codec_state is None:
            codec_state = self.init_state(stacked_params)
        if self.plan.kind == "distributed" and self._schedule is None:
            # hoist the host-computed schedule out of the scan body
            self._schedule = consensus.permutation_schedule(
                self.mix, self.gamma)
        R = (int(rounds) if keys is None
             else jax.tree.leaves(keys)[0].shape[0])
        ts = (t0 + jnp.arange(R, dtype=jnp.int32)
              if self.graph.kind != "static" or telemetry is not None
              else None)
        recorder = (telemetry.recorder_for(self)
                    if telemetry is not None else None)
        stream_cb = (telemetry.stream_cb(recorder, "consensus")
                     if telemetry is not None and telemetry.streaming
                     else None)

        def body(carry, xs):
            t, k = xs
            # telemetry draws the round's survival ONCE — in the plan's
            # native shape, never a dense (K, K) rebuild — and shares
            # it with step() (survival= takes precedence over t=;
            # identical ops, so results match the telemetry-off t=
            # path bit for bit)
            sv = (self.round_survival(t)
                  if telemetry is not None and t is not None else None)
            p, st = self.step(carry[0], carry[1], k, t=t, survival=sv)
            row = None
            if telemetry is not None:
                row = recorder.row(p, sv, metric=jnp.float32(0.0),
                                   reached=jnp.asarray(False),
                                   live=jnp.asarray(True))
                if stream_cb is not None:
                    jax.debug.callback(stream_cb, t, row, ordered=True)
            return (p, st), row

        if ts is None and keys is None:
            (p, st), rows = jax.lax.scan(
                lambda c, _x: body(c, (None, None)),
                (stacked_params, codec_state), None, length=R)
        else:
            (p, st), rows = jax.lax.scan(
                body, (stacked_params, codec_state), (ts, keys))
        if telemetry is not None:
            telemetry.record_rounds(recorder, rows, t0, driver="consensus")
        return p, st

    # -- Eq.-(11) pricing ---------------------------------------------------
    def round_comm_joules(self, energy_params,
                          model_bits: Optional[float] = None) -> float:
        """Eq.-(11) communication energy of ONE round at THIS engine's
        wire format (delegates to the topology's codec-aware pricing)."""
        if self.topology is None:
            raise ValueError("engine was built from a raw mix matrix; "
                             "construct it from a Topology to price rounds")
        return self.topology.round_comm_joules(
            energy_params, model_bits=model_bits, codec=self.codec)

    # -- audit metadata -----------------------------------------------------
    def audit_meta(self) -> dict:
        """Resolved facts ``repro.analysis`` keys its checks on: the
        plan kind, its :data:`PLAN_AUDIT_EXPECTATIONS` entry, and the
        wire codec (base codec under the error-feedback wrapper, with
        its int-lane bit width if any). Rule H2 reconciles the compiled
        module's collective bytes against ``codec.model_bits(tree)``."""
        base = (getattr(self.codec, "inner", self.codec)
                if self.codec is not None else None)
        meta = dict(PLAN_AUDIT_EXPECTATIONS[self.plan.kind])
        meta.update(
            plan=self.plan.kind, K=self.K,
            num_blocks=self.plan.num_blocks,
            axis_name=self.plan.axis_name,
            mesh_axis=(None if self.mesh is None else
                       dict(self.mesh.shape).get(self.plan.axis_name)),
            codec=None if self.codec is None else self.codec.name,
            qbits=getattr(base, "qbits", None),
        )
        return meta

    # -- conveniences -------------------------------------------------------
    @classmethod
    def wrap(cls, obj, **kw) -> "ConsensusEngine":
        """Coerce ``obj`` (engine, Topology, or concrete mix) to an
        engine; extra kwargs only apply when constructing a new one."""
        if isinstance(obj, cls):
            if any(v is not None for v in kw.values()):
                raise ValueError(
                    f"{sorted(k for k, v in kw.items() if v is not None)} "
                    "cannot be re-specified for an existing engine")
            return obj
        return cls(obj, **kw)

    def __repr__(self):
        codec = self.codec.name if self.codec is not None else None
        graph = "" if self.graph.kind == "static" else f", graph={self.graph!r}"
        return (f"ConsensusEngine(K={self.K}, plan={self.plan.kind!r}, "
                f"codec={codec!r}, blocks={self.plan.num_blocks}{graph})")
