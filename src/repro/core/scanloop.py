"""Shared machinery for the device-resident (chunked ``lax.scan``) round
loops of :mod:`repro.core.maml` and :mod:`repro.core.federated`.

The paper's energy balance is measured in ROUNDS (t0 meta rounds, t_i
adaptation rounds per task), so Monte-Carlo sweeps execute tens of
thousands of them — and a host loop pays a Python-level jit dispatch
plus a blocking device→host sync per round. The scanned drivers compile
``chunk`` rounds into ONE XLA program and sync once per chunk, which
drops the host overhead from O(rounds) to O(rounds/chunk). Three pieces
are shared:

* :func:`donating_jit` — ``jax.jit`` with ``donate_argnums`` on backends
  that implement buffer donation, so the K-stacked population params and
  error-feedback residuals are updated IN PLACE chunk over chunk instead
  of doubling peak memory. CPU does not support donation (XLA would warn
  and copy anyway), so the gate keeps the test path quiet. The DONATION
  INVARIANT: arrays passed as donated arguments are dead after the
  call. The public drivers keep this INTERNAL — they :func:`own` (copy
  once, on donating backends only) any caller-provided pytree before
  the first chunk, so callers may freely reuse their own params across
  driver calls; only the driver-owned carries are donated.
* :func:`traceable` — the ``sample_tasks_traced`` contract probe: a
  sampler that traces under abstract (key, round) arguments — AND whose
  output actually depends on them — runs INSIDE the scan; anything
  else (host RNG, ``int(t)`` round logic, file I/O, stateful iterators
  whose trace would bake one batch in as a constant) is transparently
  wrapped in ``jax.pure_callback`` so the scanned drivers accept every
  sampler the host-loop drivers did, at the cost of one host round-trip
  per round for that sampler only.
* :func:`first_hit` — recover the EXACT first round that hit the target
  from a per-round reached mask (the scanned FL driver freezes state
  with ``lax.cond`` once the target is reached, so t_i is bit-identical
  to the host loop's early ``break``, not approximated by the chunk
  grid).
* :func:`cached_program` — the compiled-chunk-program cache: the scanned
  drivers used to REBUILD their ``donating_jit`` wrapper per call, so
  every Monte-Carlo repetition re-traced (and re-compiled) the whole
  chunk program. Drivers now memoize the wrapper on a key of everything
  baked into the trace — the round functions (loss / sampler / target,
  by identity), the engine (whose identity covers plan kind, codec,
  graph process, and the concrete mix), the baked scalars (lr,
  max_rounds, eval_every), and the carry's :func:`tree_signature` (leaf
  shapes/dtypes + treedef) — so repeated invocations with identical
  configuration dispatch the SAME jit object and XLA's executable cache
  does the rest (one compile per distinct ``ts`` length).
  :data:`TRACE_COUNTS` counts actual retraces per driver (a counter
  bumped inside the traced Python body, i.e. only on jit cache misses)
  — the tier-1 trace-count guard asserts it stays flat across
  repetitions.
"""
from __future__ import annotations

import collections
import dataclasses
import functools
import weakref
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class ProgramRecord:
    """Audit-facing record of one :func:`donating_jit` program.

    ``repro.analysis`` walks :func:`registered_programs` to re-derive
    each program's jaxpr (``jax.make_jaxpr(fn)(*abstract_args)``) and
    compiled HLO, so the invariants — no callbacks inside cached
    programs, donation actually honored — are checked against the
    artifacts the drivers dispatch, not against reimplementations.
    """
    name: str
    fn: Callable                      # the raw traced round body
    jitted: Callable                  # the jit handle dispatch calls
    donate_argnums: tuple             # as REQUESTED by the driver
    donation_gated: bool              # True: the CPU gate dropped them
    jit_kwargs: dict
    abstract_args: Optional[tuple] = None   # SDS tree of the first call
    cache_key: Optional[tuple] = None       # set on cached_program admit


#: weakrefs to live dispatch wrappers — entries vanish with their
#: program (LRU eviction + driver GC), so the registry never extends a
#: compiled executable's lifetime.
_PROGRAM_REFS: list = []


def registered_programs():
    """Live :class:`ProgramRecord`\\ s of every :func:`donating_jit`
    program still referenced somewhere (program cache, driver closures).
    Dead weakrefs are pruned in passing."""
    out, alive = [], []
    for ref in _PROGRAM_REFS:
        w = ref()
        if w is not None:
            alive.append(ref)
            out.append(w._program_record)
    _PROGRAM_REFS[:] = alive
    return out


def clear_program_registry():
    """Forget every registered program (tests)."""
    _PROGRAM_REFS.clear()


def _abstractify(tree):
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(jnp.shape(x), jnp.result_type(x)),
        tree)


def donating_jit(fn: Callable, donate_argnums=(), **jit_kwargs):
    """``jax.jit`` that donates ``donate_argnums`` where the backend
    supports it (TPU/GPU). On CPU donation is unimplemented — XLA logs a
    "donated buffers were not usable" warning and copies — so the gate
    compiles without donation there. See the module docstring for the
    donation invariant callers must respect.

    Every program is registered for ``repro.analysis`` (see
    :class:`ProgramRecord`); the returned callable dispatches straight
    to the jit handle after recording the first call's abstract args.
    """
    gated = jax.default_backend() == "cpu"
    if gated:
        jitted = jax.jit(fn, **jit_kwargs)
    else:
        jitted = jax.jit(fn, donate_argnums=donate_argnums, **jit_kwargs)
    rec = ProgramRecord(
        name=getattr(fn, "__name__", repr(fn)), fn=fn, jitted=jitted,
        donate_argnums=tuple(donate_argnums), donation_gated=gated,
        jit_kwargs=dict(jit_kwargs))

    @functools.wraps(fn)
    def dispatch(*args, **kwargs):
        if rec.abstract_args is None:
            rec.abstract_args = _abstractify(args)
        return jitted(*args, **kwargs)

    dispatch._program_record = rec
    _PROGRAM_REFS.append(weakref.ref(dispatch))
    return dispatch


def own(tree):
    """Driver-owned copy of a CALLER-provided pytree on donating
    backends (no-op on CPU, where :func:`donating_jit` never donates).
    The chunked drivers copy incoming params/state once before the
    first chunk so donation consumes only driver-owned buffers — the
    caller's pytree stays valid across repeated driver calls (e.g.
    Monte-Carlo sweeps from one meta-init)."""
    if jax.default_backend() == "cpu":
        return tree
    return jax.tree.map(jnp.copy, tree)


def _outputs_all_constant(closed_jaxpr) -> bool:
    """True when no output of a traced function (transitively) depends
    on any input — i.e. everything it returns is a baked-in constant.
    That is the signature of an IMPURE sampler (``next(iterator)``,
    cached host arrays): it traces fine, but inside a scan its single
    traced value would replay every round. Dependence is propagated
    conservatively through equations, so mixed const/input ops count as
    input-dependent (classified traced, never falsely demoted)."""
    j = closed_jaxpr.jaxpr
    dependent = set(j.invars)
    for eqn in j.eqns:
        if any(not hasattr(v, "val") and v in dependent
               for v in eqn.invars):
            dependent.update(eqn.outvars)
    return all(hasattr(v, "val") or v not in dependent
               for v in j.outvars)


def traceable(fn: Callable, *probe_args, name: str = "sampler"):
    """Return a scan-safe version of ``fn`` plus whether it traced.

    ``fn(*probe_args)`` is probed with ``jax.make_jaxpr`` (abstract
    values, nothing executes): success — with outputs that actually
    DEPEND on the inputs — means ``fn`` satisfies the traced contract
    (pure jax ops, no host concretization of the round index or key)
    and it is returned as-is to run on-device inside the scan.

    Everything else falls back: functions that fail to trace, and
    traceable-but-impure ones whose outputs are input-independent
    constants (a stateful ``next(batch_iter)`` sampler would otherwise
    silently bake ONE batch into the compiled loop). The fallback calls
    ``fn`` once CONCRETELY to learn the output structure, then wraps it
    in ``jax.pure_callback``: the scanned loop stays one compiled
    program, and this one function round-trips to the host each round
    with concrete (numpy) arguments — exactly the values the host-loop
    driver would have passed, so results are unchanged, only slower.
    Samplers should migrate to the traced contract to drop the round
    trip.
    """
    try:
        if not _outputs_all_constant(jax.make_jaxpr(fn)(*probe_args)):
            return fn, True
    except Exception:
        pass
    out = fn(*probe_args)
    sds = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(jnp.shape(x), jnp.result_type(x)),
        out)

    def host_fn(*args):
        np_args = jax.tree.map(np.asarray, args)
        return jax.tree.map(np.asarray, fn(*np_args))

    def wrapped(*args):
        return jax.pure_callback(host_fn, sds, *args)

    wrapped.__name__ = f"host_callback_{name}"
    return wrapped, False


#: retrace counters per driver family ("fl_chunk", "maml_chunk"):
#: incremented inside the traced Python chunk body, so they only move
#: when jax actually re-traces — the observable the trace-count guard
#: in tier-1 asserts on (compile once across >= 3 repetitions).
TRACE_COUNTS: collections.Counter = collections.Counter()

#: compiled-program LRU capacity. Keys hold strong references to the
#: functions/engines they were built from, which both bounds memory and
#: prevents id()-reuse collisions while an entry is alive.
PROGRAM_CACHE_SIZE = 32
_program_cache: "collections.OrderedDict" = collections.OrderedDict()

#: program-cache counters ("hits", "misses", "inserts", "evictions") —
#: the runtime-inspectable complement to :data:`TRACE_COUNTS`. Bumped by
#: :func:`get_cached_program` / :func:`cached_program`; read them
#: through :func:`cache_stats`, not directly.
CACHE_STATS: collections.Counter = collections.Counter()


def cache_stats() -> dict:
    """Snapshot of the compiled-program cache counters plus registry
    size — the harness half of ``telemetry.report()``.

    Returns a plain dict: ``hits`` / ``misses`` (from the drivers'
    :func:`get_cached_program` probes), ``inserts`` / ``evictions``
    (from :func:`cached_program`), ``size`` / ``capacity`` (current LRU
    occupancy), ``registered_programs`` (live :class:`ProgramRecord`
    count), and ``trace_counts`` (a dict copy of
    :data:`TRACE_COUNTS`)."""
    return {
        "hits": CACHE_STATS["hits"],
        "misses": CACHE_STATS["misses"],
        "inserts": CACHE_STATS["inserts"],
        "evictions": CACHE_STATS["evictions"],
        "size": len(_program_cache),
        "capacity": PROGRAM_CACHE_SIZE,
        "registered_programs": len(registered_programs()),
        "trace_counts": dict(TRACE_COUNTS),
    }


def reset_cache_stats():
    """Zero the hit/miss/eviction counters AND :data:`TRACE_COUNTS`
    (tests, benchmark sections). Does NOT drop cached programs — use
    :func:`clear_program_cache` for that."""
    CACHE_STATS.clear()
    TRACE_COUNTS.clear()


def tree_signature(tree):
    """Hashable (treedef, ((shape, dtype), …)) signature of a pytree —
    the shapes/dtypes part of a program-cache key."""
    leaves, treedef = jax.tree.flatten(tree)
    return (treedef, tuple((tuple(jnp.shape(x)), str(jnp.result_type(x)))
                           for x in leaves))


def _cache_lookup(key):
    """LRU-bumping lookup WITHOUT touching :data:`CACHE_STATS` — the
    shared primitive under :func:`get_cached_program` (which counts) and
    :func:`cached_program` (whose driver already counted its probe, so
    re-counting here would double every miss)."""
    try:
        fn = _program_cache.pop(key)       # move-to-end on hit
    except KeyError:
        return None
    _program_cache[key] = fn
    return fn


def get_cached_program(key):
    """Cached program for ``key`` (LRU-bumped), or None. Drivers check
    this BEFORE probing their round functions, so cache hits skip the
    per-call ``traceable``/``eval_shape`` probes too — an entry only
    exists if the probe verdict was 'traced' when it was built. Each
    probe bumps ``hits`` or ``misses`` in :data:`CACHE_STATS`."""
    fn = _cache_lookup(key)
    CACHE_STATS["hits" if fn is not None else "misses"] += 1
    return fn


def cached_program(key, build: Callable):
    """Memoize a compiled chunk program (LRU, size
    :data:`PROGRAM_CACHE_SIZE`). ``key`` must be a hashable tuple
    covering EVERYTHING the trace bakes in (see the module docstring for
    the convention the drivers use); ``build()`` constructs the jitted
    program on a miss. Returns the cached callable.

    Admissions bump ``inserts`` and LRU drops bump ``evictions`` in
    :data:`CACHE_STATS` (the lookup itself is stats-silent — drivers
    count their entry probe via :func:`get_cached_program`)."""
    fn = _cache_lookup(key)
    if fn is None:
        fn = build()
        rec = getattr(fn, "_program_record", None)
        if rec is not None:
            rec.cache_key = key        # audit: this program was admitted
        CACHE_STATS["inserts"] += 1
    _program_cache[key] = fn
    while len(_program_cache) > PROGRAM_CACHE_SIZE:
        _program_cache.popitem(last=False)
        CACHE_STATS["evictions"] += 1
    return fn


def clear_program_cache():
    """Drop every cached chunk program (tests; frees engine refs)."""
    _program_cache.clear()


def first_hit(reached_mask) -> Optional[int]:
    """Index of the first True in a per-round reached mask (host-side,
    one chunk), or None if the chunk never hit the target."""
    mask = np.asarray(reached_mask)
    idx = np.flatnonzero(mask)
    return int(idx[0]) if idx.size else None
