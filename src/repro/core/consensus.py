"""Decentralized federated learning by average consensus — paper Eq. (6).

    W^{(k)}_{t+1} = W^{(k)}_t + Σ_{h∈N_k} σ_{k,h} (W^{(h)}_t − W^{(k)}_t),
    σ_{k,h} = |E_h| / Σ_{j∈N_k} |E_j|                       (paper / ref [5])

Two execution modes:

* ``consensus_step``           — dense: agent-stacked params (K on the
  leading axis) mixed by a (K, K) matrix. This is the reference semantics
  and the CPU path for the paper's 12-robot case study.
* ``ring_consensus_step``      — distributed: each mesh position along
  ``axis_name`` holds ONE agent's replica; neighbour exchange is
  ``jax.lax.ppermute`` on the ICI ring (sidelink SL in the paper's terms).
  Run under ``shard_map``. Communication per round per agent =
  2 · b(W) — exactly the quantity the paper's Eq. (11) prices.

Also provides Metropolis–Hastings weights (symmetric, doubly-stochastic —
the consensus-theory default) behind ``kind="metropolis"``.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# mixing matrices
# ---------------------------------------------------------------------------


def ring_adjacency(K: int, hops: int = 1) -> np.ndarray:
    """Symmetric ring: each agent sees ``hops`` neighbours each side."""
    A = np.zeros((K, K), bool)
    for k in range(K):
        for d in range(1, hops + 1):
            A[k, (k + d) % K] = True
            A[k, (k - d) % K] = True
    if K > 1:
        np.fill_diagonal(A, False)
    return A


def full_adjacency(K: int) -> np.ndarray:
    A = np.ones((K, K), bool)
    np.fill_diagonal(A, False)
    return A


def mixing_weights(data_sizes, adjacency, kind: str = "paper",
                   include_self: bool = True):
    """(K, K) row-stochastic mixing matrix Σ with Σ[k, h] = σ_{k,h}.

    kind="paper":  σ_{k,h} = |E_h| / Σ_j |E_j| with the sum over N_k
                   (``include_self=False``, the literal Eq. 6 reading) or
                   N_k ∪ {k} (``include_self=True``, default). Eq. (6)'s
                   text is ambiguous ("computed using |E_{i,h}| and
                   |{E_{i,j}}_{j∈N_{k,i}}|"); the literal reading has ZERO
                   self-weight, which is non-convergent under pure mixing
                   on even rings and a pure swap for the paper's own
                   2-robot clusters — so the implementation they ran must
                   keep the local share. Both are exposed; tests cover the
                   convergence difference.
    kind="metropolis": σ_{k,h} = 1 / (1 + max(deg_k, deg_h)), self weight
                   1 − Σ — symmetric, doubly stochastic.
    """
    sizes = jnp.asarray(data_sizes, jnp.float32)
    A = jnp.asarray(adjacency, bool)
    K = A.shape[0]
    if kind == "paper":
        w = jnp.where(A, sizes[None, :], 0.0)
        denom = w.sum(axis=1, keepdims=True)
        if include_self:
            denom = denom + sizes[:, None]
        denom = jnp.maximum(denom, 1e-12)
        return w / denom
    if kind == "metropolis":
        deg = A.sum(axis=1).astype(jnp.float32)
        w = jnp.where(A, 1.0 / (1.0 + jnp.maximum(deg[:, None], deg[None, :])),
                      0.0)
        self_w = 1.0 - w.sum(axis=1)
        return w + jnp.diag(self_w)
    raise ValueError(f"unknown kind {kind!r}")


def _effective_mix(mix):
    """Add the implicit self weight so rows sum to 1 exactly."""
    self_w = 1.0 - mix.sum(axis=1)
    return mix + jnp.diag(self_w)


# ---------------------------------------------------------------------------
# dense (reference) consensus
# ---------------------------------------------------------------------------


def consensus_step(stacked_params, mix):
    """Eq. (6) on agent-stacked params (leading axis K). mix: (K, K) σ."""
    M = _effective_mix(jnp.asarray(mix, jnp.float32))

    def mix_leaf(x):
        xf = x.astype(jnp.float32).reshape(x.shape[0], -1)
        y = M @ xf
        return y.reshape(x.shape).astype(x.dtype)

    return jax.tree.map(mix_leaf, stacked_params)


def consensus_error(stacked_params) -> jnp.ndarray:
    """Mean squared deviation from the agent average (0 ⇒ consensus)."""
    tot, n = 0.0, 0
    for x in jax.tree.leaves(stacked_params):
        xf = x.astype(jnp.float32)
        dev = xf - xf.mean(axis=0, keepdims=True)
        tot = tot + jnp.sum(jnp.square(dev))
        n += dev.size
    return tot / n


# ---------------------------------------------------------------------------
# distributed (sharded) consensus — sidelink == ICI ring
# ---------------------------------------------------------------------------


def ring_consensus_step(params, data_size, axis_name: str, hops: int = 1,
                        include_self: bool = True, message_dtype=None):
    """One Eq.-(6) round where each ``axis_name`` position is an agent.

    Must run inside shard_map. ``data_size``: scalar |E_k| per agent.
    Exchanges params + sizes with ±1..hops ring neighbours via ppermute
    (2·hops messages of b(W) per agent per round — the paper's SL traffic).
    ``include_self`` as in :func:`mixing_weights`.

    ``message_dtype``: cast the EXCHANGED copy (e.g. bf16) — halves the
    Eq.-(11) sidelink bytes. An optimization_barrier pins the cast before
    the ppermute (XLA otherwise commutes converts past permutes and keeps
    the wire at the storage dtype — EXPERIMENTS.md §Perf P3).
    """
    K = jax.lax.axis_size(axis_name)
    perms = []
    for d in range(1, hops + 1):
        perms.append([(i, (i + d) % K) for i in range(K)])   # from left
        perms.append([(i, (i - d) % K) for i in range(K)])   # from right

    sizes = [jax.lax.ppermute(data_size, axis_name, p) for p in perms]
    denom = sum(sizes) + (data_size if include_self else 0.0)
    sigmas = [s / jnp.maximum(denom, 1e-12) for s in sizes]

    def combine(x):
        if message_dtype is not None and x.dtype != jnp.dtype(message_dtype):
            # the whole neighbour pathway stays in message_dtype: if the
            # received value were upcast, XLA CSEs the convert with the
            # local f32 accumulator and moves the WIRE back to f32 —
            # consuming neighbours only in bf16 pins a bf16 exchange.
            md = jnp.dtype(message_dtype)
            msg = x.astype(md)
            neigh = [jax.lax.ppermute(msg, axis_name, p) for p in perms]
            upd = sum((sig.astype(md) * (nb - msg)).astype(jnp.float32)
                      for sig, nb in zip(sigmas, neigh))
        else:
            neigh = [jax.lax.ppermute(x, axis_name, p) for p in perms]
            xf32 = x.astype(jnp.float32)
            upd = sum(sig * (nb.astype(jnp.float32) - xf32)
                      for sig, nb in zip(sigmas, neigh))
        return (x.astype(jnp.float32) + upd).astype(x.dtype)

    return jax.tree.map(combine, params)


def cluster_ring_consensus_step(params, data_size, axis_name: str,
                                cluster_size: int,
                                include_self: bool = True):
    """Ring consensus restricted to contiguous clusters of ``cluster_size``
    agents along ``axis_name`` (the paper's per-task clusters C_i: only
    same-cluster agents exchange models)."""
    K = jax.lax.axis_size(axis_name)
    assert K % cluster_size == 0
    if cluster_size == 1:
        return params
    perm_fwd, perm_bwd = [], []
    for i in range(K):
        c = i // cluster_size
        perm_fwd.append((i, c * cluster_size + (i + 1 - c * cluster_size)
                         % cluster_size))
        perm_bwd.append((i, c * cluster_size + (i - 1 - c * cluster_size)
                         % cluster_size))
    perms = [perm_fwd, perm_bwd] if cluster_size > 2 else [perm_fwd]

    sizes = [jax.lax.ppermute(data_size, axis_name, p) for p in perms]
    denom = sum(sizes) + (data_size if include_self else 0.0)
    sigmas = [s / jnp.maximum(denom, 1e-12) for s in sizes]

    def combine(x):
        neigh = [jax.lax.ppermute(x, axis_name, p) for p in perms]
        xf = x.astype(jnp.float32)
        upd = sum(sig * (nb.astype(jnp.float32) - xf)
                  for sig, nb in zip(sigmas, neigh))
        return (xf + upd).astype(x.dtype)

    return jax.tree.map(combine, params)
