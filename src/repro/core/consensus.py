"""Decentralized federated learning by average consensus — paper Eq. (6).

    W^{(k)}_{t+1} = W^{(k)}_t + Σ_{h∈N_k} σ_{k,h} (W^{(h)}_t − W^{(k)}_t),
    σ_{k,h} = |E_h| / Σ_{j∈N_k} |E_j|                       (paper / ref [5])

Execution primitives (pick via :class:`repro.core.engine.ConsensusEngine`
rather than calling these directly):

* ``consensus_step``           — dense: agent-stacked params (K on the
  leading axis) mixed by a (K, K) matrix. This is the reference semantics
  and the CPU path for the paper's 12-robot case study; ``impl`` selects
  the dense matmul or the batched sparse gather / fused Pallas kernel.
* ``sharded_consensus_step``   — the population split into per-mesh-
  position BLOCKS of agents under shard_map; each block all_gathers the
  codec WIRE along the agent axis and mixes its own rows (K ≫ cores).
* ``distributed_consensus_step`` — each mesh position holds ONE agent;
  neighbour exchange is ``jax.lax.ppermute`` rounds from
  :func:`permutation_schedule`, shipping the codec wire format (int8
  lanes + scales, bf16, …) — the paper's sidelink SL traffic, priced by
  Eq. (11) at exactly the permuted bytes.
* ``ring_consensus_step``      — the legacy ring-only ppermute path
  (``message_dtype`` casts the wire); kept for the volume benchmark.

Also provides Metropolis–Hastings weights (symmetric, doubly-stochastic —
the consensus-theory default) behind ``kind="metropolis"``.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# mixing matrices
# ---------------------------------------------------------------------------


def ring_adjacency(K: int, hops: int = 1) -> np.ndarray:
    """Symmetric ring: each agent sees ``hops`` neighbours each side."""
    A = np.zeros((K, K), bool)
    for k in range(K):
        for d in range(1, hops + 1):
            A[k, (k + d) % K] = True
            A[k, (k - d) % K] = True
    if K > 1:
        np.fill_diagonal(A, False)
    return A


def full_adjacency(K: int) -> np.ndarray:
    A = np.ones((K, K), bool)
    np.fill_diagonal(A, False)
    return A


def mixing_weights(data_sizes, adjacency, kind: str = "paper",
                   include_self: bool = True):
    """(K, K) row-stochastic mixing matrix Σ with Σ[k, h] = σ_{k,h}.

    kind="paper":  σ_{k,h} = |E_h| / Σ_j |E_j| with the sum over N_k
                   (``include_self=False``, the literal Eq. 6 reading) or
                   N_k ∪ {k} (``include_self=True``, default). Eq. (6)'s
                   text is ambiguous ("computed using |E_{i,h}| and
                   |{E_{i,j}}_{j∈N_{k,i}}|"); the literal reading has ZERO
                   self-weight, which is non-convergent under pure mixing
                   on even rings and a pure swap for the paper's own
                   2-robot clusters — so the implementation they ran must
                   keep the local share. Both are exposed; tests cover the
                   convergence difference.
    kind="metropolis": σ_{k,h} = 1 / (1 + max(deg_k, deg_h)), self weight
                   1 − Σ — symmetric, doubly stochastic.

    ``adjacency`` may be bool (the lockstep protocol: an edge is up or
    down) or FLOAT per-edge weights in [0, 1] (the async engine's
    staleness-decayed lanes: λ^age on stale wires, 1 on fresh, 0 on
    dropped). The float path scales each edge's mass by its weight
    before normalizing — a stale neighbour is a faded lane with memory
    — and a {0, 1}-valued float input reproduces the bool path bit for
    bit (IEEE: ``1.0·x == x`` and ``0.0·x == +0.0`` for the finite
    positive sizes here), which is what keeps the always-on/τ=∞
    reduction exact. Metropolis degrees generalize to weighted degrees
    ``Σ_h w_{k,h}`` on the float path.
    """
    sizes = jnp.asarray(data_sizes, jnp.float32)
    A = jnp.asarray(adjacency)
    if jnp.issubdtype(A.dtype, jnp.floating):
        A = A.astype(jnp.float32)
        if kind == "paper":
            w = A * sizes[None, :]
            denom = w.sum(axis=1, keepdims=True)
            if include_self:
                denom = denom + sizes[:, None]
            denom = jnp.maximum(denom, 1e-12)
            return w / denom
        if kind == "metropolis":
            deg = A.sum(axis=1)
            w = A * (1.0 / (1.0 + jnp.maximum(deg[:, None], deg[None, :])))
            self_w = 1.0 - w.sum(axis=1)
            return w + jnp.diag(self_w)
        raise ValueError(_unknown_kind_msg(kind))
    A = A.astype(bool)
    if kind == "paper":
        w = jnp.where(A, sizes[None, :], 0.0)
        denom = w.sum(axis=1, keepdims=True)
        if include_self:
            denom = denom + sizes[:, None]
        denom = jnp.maximum(denom, 1e-12)
        return w / denom
    if kind == "metropolis":
        deg = A.sum(axis=1).astype(jnp.float32)
        w = jnp.where(A, 1.0 / (1.0 + jnp.maximum(deg[:, None], deg[None, :])),
                      0.0)
        self_w = 1.0 - w.sum(axis=1)
        return w + jnp.diag(self_w)
    raise ValueError(_unknown_kind_msg(kind))


MIX_KINDS = ("paper", "metropolis")


def _unknown_kind_msg(kind) -> str:
    """Refusal text for a bad mixing kind, naming the nearest match."""
    import difflib
    close = difflib.get_close_matches(str(kind), MIX_KINDS, n=1)
    hint = f"; did you mean {close[0]!r}?" if close else ""
    return (f"unknown mixing kind {kind!r}: supported kinds are "
            f"'paper' (Eq.-(6) data-size weights) and 'metropolis' "
            f"(doubly stochastic){hint}")


def _effective_mix(mix):
    """Add the implicit self weight so rows sum to 1 exactly."""
    self_w = 1.0 - mix.sum(axis=1)
    return mix + jnp.diag(self_w)


def resolve_mix(mix, data_sizes=None, kind: str = "paper",
                include_self: bool = True):
    """Accept either a ready (K, K) σ matrix or a Topology object."""
    if hasattr(mix, "mixing"):
        return mix.mixing(data_sizes, kind=kind, include_self=include_self)
    return mix


#: K · max-degree floor below which the batched sparse gather cannot
#: amortize its per-agent dispatch overhead and ``auto`` keeps the
#: dense (K, K) matmul (the overhead scales with agents × neighbours,
#: not payload bytes, so the floor is codec-independent). Calibrated
#: against the recorded ``BENCH_consensus_scale.json`` rows: every f32
#: sparse-pallas pick at K·H < 512 LOST to dense-xla (K=12 ring 0.59×,
#: K=12 cluster 0.66×, K=64 ring 0.80× … small_world 0.30×), while the
#: first winning row is exactly at the floor (K=256 ring, K·H = 512,
#: 1.46×).
SPARSE_GATHER_FLOOR = 512


def auto_path(mix, codec=None) -> str:
    """What ``impl="auto"`` resolves to for this (concrete) mix: the sparse
    gather only wins while the graph is actually sparse — on dense graphs
    (max degree > K/4, e.g. star or full) the gathered (K, H, N) neighbour
    tensor exceeds the (K, K) matmul's traffic and ``auto`` falls back to
    the dense path.

    Small/dense-ish populations also stay dense: below
    :data:`SPARSE_GATHER_FLOOR` total gather work (K · max degree) the
    vmapped per-agent gather is pure overhead against one small matmul
    — the benchmark recorded the K=12 ring sparse pick running at
    0.59× dense — so ``auto`` keeps them on the (K, K) path regardless
    of sparsity. The floor uses the RAW K·H (per-agent gather dispatch
    overhead scales with agents × neighbours, not with payload bytes),
    so a codec never demotes a population the f32 rows showed winning.

    With an int ``codec`` the gathered payload is the WIRE format, not
    f32 — the fused dequant-consensus kernel consumes int8-lane
    neighbour blocks directly (plus per-block scales when the codec
    quantizes block-wise), a quarter of the bytes — so the degree is
    discounted by the wire's DEVICE bytes per parameter (int8 lanes for
    both int8 and int4: what the gather actually moves) before
    comparing against the dense threshold (the dense matmul always runs
    on decoded f32). The discount applies ONLY to codecs whose sparse
    path gathers the wire itself (IntCodec through the fused
    dequant-consensus kernel, per-tensor or block-wise scales); every
    other codec decodes to f32 BEFORE the gather, so its degree counts
    at full width. The old heuristic ignored payload bytes entirely and
    kicked graphs to the dense path that a compressed gather serves
    cheaper.
    """
    M = np.asarray(mix)
    K = M.shape[0]
    off = M.copy()
    np.fill_diagonal(off, 0.0)
    H = int((off != 0).sum(axis=1).max()) if K else 0
    if K * max(float(H), 1.0) < SPARSE_GATHER_FLOOR:
        return "dense"
    codec = getattr(codec, "inner", codec)       # unwrap ErrorFeedback
    qblock = getattr(codec, "block", None)
    gathers_wire = getattr(codec, "qbits", None) is not None
    # the gather moves int8 LANES for every IntCodec (int4 values ride
    # int8 storage on-device) plus one f32 scale per qblock params
    wire_bits = (8.0 + (32.0 / qblock if qblock else 0.0)
                 if gathers_wire else None)
    h_eff = H * (wire_bits / 32.0) if wire_bits else float(H)
    return "sparse" if h_eff <= max(K // 4, 1) else "dense"


def sparse_structure(mix):
    """(idx, sig): per-agent neighbour indices and σ's from a CONCRETE mix.

    idx: (K, H) int32, sig: (K, H) float32 with H = max degree; rows with
    fewer neighbours are padded with the agent's own index and σ = 0 (a
    zero-weight self message, exact no-op in Eq. 6). Diagonal self weights
    are dropped — the update form x + Σ σ(nb − x) carries them implicitly.
    """
    M = np.asarray(mix, np.float32)
    K = M.shape[0]
    off = M.copy()
    np.fill_diagonal(off, 0.0)
    H = max(int((off != 0).sum(axis=1).max()), 1)
    idx = np.tile(np.arange(K, dtype=np.int32)[:, None], (1, H))
    sig = np.zeros((K, H), np.float32)
    for k in range(K):
        nbr = np.flatnonzero(off[k])
        idx[k, :len(nbr)] = nbr
        sig[k, :len(nbr)] = off[k, nbr]
    return idx, sig


# ---------------------------------------------------------------------------
# dense consensus — reference (K, K) matmul and the batched sparse paths
# ---------------------------------------------------------------------------


def consensus_step(stacked_params, mix, *, impl: str = "xla",
                   block_n: Optional[int] = None,
                   codec=None, codec_state=None, key=None,
                   error_feedback: bool = True, gamma: float = 1.0,
                   structure=None):
    """Eq. (6) on agent-stacked params (leading axis K). mix: (K, K) σ or a
    :class:`repro.core.topology.Topology` (uniform paper weights).

    impl:
      * ``"xla"``    — dense matmul ``M @ xf`` per leaf (reference; fine for
        the 12-robot case study, O(K²·N) and H extra parameter-sized
        temporaries at large K);
      * ``"pallas"`` — batched-over-agents sparse gather feeding the fused
        :mod:`repro.kernels.consensus_update` kernel (interpret mode off
        TPU), O(K·H·N);
      * ``"sparse"`` — the same sparse gather, but off TPU it runs the
        pure-jnp kernel oracle instead of interpret mode (what the
        engine's ``sparse-pallas`` plan uses: Pallas where it compiles,
        the bit-identical oracle elsewhere);
      * ``"auto"``   — for sparse graphs (see :func:`auto_path`), pallas on
        TPU and otherwise the same sparse gather applied through the
        pure-jnp kernel oracle (bit-identical to
        ``ref.consensus_update_reference`` per agent); for dense graphs
        (star, full — max degree > K/4) it falls back to the dense matmul,
        which moves strictly fewer bytes there. With a codec the
        threshold is payload-aware (:func:`auto_path`).

    codec — compress the EXCHANGED models (:mod:`repro.comms`): a spec
    string (``"int8"``, ``"bf16"``, ``"topk:0.05"``, …) or Codec. Every
    agent consumes its neighbours' DECODED models x̂_h and recenters on
    its own decoded copy: W_k + Σ_h σ_{k,h} (x̂_h − x̂_k), which keeps the
    population mean exact under doubly-stochastic σ regardless of the
    compression (the CHOCO-gossip identity). Lossy codecs are wrapped in
    :class:`~repro.comms.codecs.ErrorFeedback` by default
    (``error_feedback=False`` opts out) so the per-round quantization
    error telescopes instead of accumulating; ``codec_state`` is the
    stacked residual pytree (None ⇒ zeros) and ``key`` enables
    stochastic rounding; ``gamma`` damps the off-diagonal σ (CHOCO-style
    consensus step size — aggressive sparsifiers like top-k need γ < 1
    to contract). With a codec the return value is
    ``(new_stacked_params, new_codec_state)``; without, just the params
    (unchanged API). Int wires (int8/int4 lanes, per-tensor or
    block-wise ``int8:b64`` scales) route through the fused
    dequantize-consensus kernel on the sparse path
    (:mod:`repro.kernels.quant_consensus`).

    The sparse paths need a CONCRETE mix (numpy / non-traced) — the
    neighbour structure is extracted at trace time — UNLESS ``structure``
    is given: a ``(idx, sig)`` pair in :func:`sparse_structure` layout
    where ``idx`` (K, H) int32 is the CONCRETE full-graph neighbour
    index table and ``sig`` (K, H) float32 may be TRACED. This is the
    time-varying-graph hook: per-round survival masks zero (and
    renormalize) the σ of faded neighbour lanes without rebuilding the
    gather indices, so sparse plans stay one compiled program across
    rounds (σ is already a runtime operand of the fused kernels).
    ``gamma`` is applied to the provided ``sig`` exactly as it would be
    to the extracted one.
    """
    mix = resolve_mix(mix)
    if impl not in ("xla", "pallas", "auto", "sparse"):
        raise ValueError(f"unknown impl {impl!r}; use xla/pallas/sparse/auto")
    if codec is None and (codec_state is not None or gamma != 1.0):
        raise ValueError(
            f"codec_state={'set' if codec_state is not None else None} "
            f"/ gamma={gamma} only apply to compressed consensus but "
            "codec=None — pass codec= (e.g. 'int8'), or drop them "
            "(they would be silently ignored otherwise)")
    if codec is not None:
        from repro import comms   # deferred: core stays import-light
        codec = comms.resolve_codec(codec, error_feedback)
        return _compressed_consensus_step(
            stacked_params, mix, codec, codec_state, key,
            impl=impl, block_n=block_n, gamma=gamma, structure=structure)
    if impl == "auto" and auto_path(mix) == "dense":
        impl = "xla"
    if impl == "xla":
        M = _effective_mix(jnp.asarray(mix, jnp.float32))

        def mix_leaf(x):
            xf = x.astype(jnp.float32).reshape(x.shape[0], -1)
            y = M @ xf
            return y.reshape(x.shape).astype(x.dtype)

        return jax.tree.map(mix_leaf, stacked_params)

    use_pallas = impl == "pallas" or (impl in ("auto", "sparse")
                                      and jax.default_backend() == "tpu")
    if structure is None:
        idx_np, sig_np = sparse_structure(mix)
        idx, sig = jnp.asarray(idx_np), jnp.asarray(sig_np)
    else:                  # per-round (possibly traced) σ on baked indices
        idx, sig = (jnp.asarray(structure[0]),
                    jnp.asarray(structure[1], jnp.float32))

    from repro.kernels import ops  # deferred: keeps consensus importable
                                   # without the Pallas toolchain

    kernel_impl = ("pallas" if jax.default_backend() == "tpu"
                   else "interpret") if use_pallas else "xla"

    def mix_leaf(x):
        K = x.shape[0]
        xf = x.astype(jnp.float32).reshape(K, -1)
        kw = {} if block_n is None else {"block_n": block_n}

        def one(xk, ik, sk):
            return ops.consensus_update(xk, xf[ik], sk, impl=kernel_impl,
                                        **kw)

        y = jax.vmap(one)(xf, idx, sig)
        return y.reshape(x.shape).astype(x.dtype)

    return jax.tree.map(mix_leaf, stacked_params)


def _compressed_consensus_step(stacked_params, mix, codec, codec_state,
                               key, *, impl: str, block_n: Optional[int],
                               gamma: float = 1.0, structure=None):
    """Eq. (6) over codec'd exchanges (see :func:`consensus_step`).

    Per leaf: (1) each agent encodes its message m_k = W_k + r_k (r = 0
    without error feedback) to the wire format and decodes x̂_k back,
    (2) the mixing update runs on the decoded models around the agent's
    own decoded copy, (3) residuals carry the compression error to the
    next round. Int wires (per-tensor or block-wise scales) take the
    fused Pallas dequant-consensus kernel on the sparse path; other
    codecs decode first and reuse the plain consensus kernel.
    ``structure``: per-round (idx, possibly-traced sig) override of the
    sparse neighbour structure (see :func:`consensus_step`).
    """
    from repro import comms
    from repro.kernels import ops

    base = codec.inner if isinstance(codec, comms.ErrorFeedback) else codec
    stateful = isinstance(codec, comms.ErrorFeedback)

    if impl == "auto":
        impl = "xla" if auto_path(mix, codec=base) == "dense" else "sparse"
    use_pallas = impl == "pallas" or (impl == "sparse"
                                      and jax.default_backend() == "tpu")
    sparse = impl in ("pallas", "sparse")
    kernel_impl = ("pallas" if jax.default_backend() == "tpu"
                   else "interpret") if use_pallas else "xla"
    kw = {} if block_n is None else {"block_n": block_n}

    if sparse:
        if structure is None:
            idx_np, sig_np = sparse_structure(mix)
            idx, sig = jnp.asarray(idx_np), gamma * jnp.asarray(sig_np)
        else:
            idx = jnp.asarray(structure[0])
            sig = gamma * jnp.asarray(structure[1], jnp.float32)
    else:
        M = jnp.asarray(mix, jnp.float32)
        off = gamma * (M - jnp.diag(jnp.diag(M)))
        rowsum = off.sum(axis=1)

    leaves, treedef = jax.tree.flatten(stacked_params)
    if stateful:
        state_leaves = (jax.tree.leaves(codec_state)
                        if codec_state is not None
                        else [jnp.zeros(jnp.shape(x), jnp.float32)
                              for x in leaves])
        if len(state_leaves) != len(leaves):
            raise ValueError(
                f"codec_state has {len(state_leaves)} leaves but "
                f"stacked_params has {len(leaves)} — thread the "
                "codec_state returned by the previous step (or pass "
                "None to start from zero error-feedback residuals)")
    else:
        state_leaves = [None] * len(leaves)

    new_leaves, new_state = [], []
    for li, (x, r) in enumerate(zip(leaves, state_leaves)):
        K = x.shape[0]
        xf = x.astype(jnp.float32).reshape(K, -1)
        agent_keys = (None if key is None else
                      jax.random.split(jax.random.fold_in(key, li), K))

        if stateful:     # the EF identity lives in ONE place: the codec
            step_fn = (lambda mm, rr, kk=None:
                       codec.encode_leaf_stateful(mm, rr, kk))
            if agent_keys is None:
                enc, xhat, r_new = jax.vmap(step_fn)(xf, r.reshape(K, -1))
            else:
                enc, xhat, r_new = jax.vmap(step_fn)(xf, r.reshape(K, -1),
                                                     agent_keys)
        else:
            if agent_keys is None:
                enc = jax.vmap(lambda mm: base.encode_leaf(mm, None))(xf)
            else:
                enc = jax.vmap(base.encode_leaf)(xf, agent_keys)
            like = jax.ShapeDtypeStruct(xf.shape[1:], jnp.float32)
            xhat = jax.vmap(lambda p: base.decode_leaf(p, like))(enc)

        if sparse and isinstance(base, comms.IntCodec):
            # int wire (per-tensor OR block-wise scales): neighbour
            # tiles stay int8 lanes through the gather; dequant happens
            # INSIDE the fused combine
            q, s = enc["q"], enc["scale"]
            qkw = dict(kw) if base.block is None \
                else dict(kw, qblock=base.block)

            def one(xk, qk, sk, ik, sgk):
                return ops.quant_consensus_update(
                    xk, qk, sk, q[ik], s[ik], sgk,
                    impl=kernel_impl, **qkw)

            y = jax.vmap(one)(xf, q, s, idx, sig)
        elif sparse:
            def one(xk, xhk, ik, sgk):
                mixed_hat = ops.consensus_update(
                    xhk, xhat[ik], sgk, impl=kernel_impl, **kw)
                return xk + (mixed_hat - xhk)

            y = jax.vmap(one)(xf, xhat, idx, sig)
        else:
            y = xf + off @ xhat - rowsum[:, None] * xhat

        new_leaves.append(y.reshape(x.shape).astype(x.dtype))
        if stateful:
            new_state.append(r_new.reshape(x.shape))

    new_params = jax.tree.unflatten(treedef, new_leaves)
    state_out = (jax.tree.unflatten(treedef, new_state)
                 if stateful else None)
    return new_params, state_out


def consensus_error(stacked_params) -> jnp.ndarray:
    """Mean squared deviation from the agent average (0 ⇒ consensus)."""
    tot, n = 0.0, 0
    for x in jax.tree.leaves(stacked_params):
        xf = x.astype(jnp.float32)
        dev = xf - xf.mean(axis=0, keepdims=True)
        tot = tot + jnp.sum(jnp.square(dev))
        n += dev.size
    return tot / n


# ---------------------------------------------------------------------------
# distributed (sharded) consensus — sidelink == ICI ring
# ---------------------------------------------------------------------------


def _axis_size(axis_name: str) -> int:
    """Static size of a mapped axis. ``jax.lax.axis_size`` only exists on
    newer jax; ``psum(1, name)`` constant-folds to a Python int under both
    vmap and shard_map on every version we support."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)


def ring_consensus_step(params, data_size, axis_name: str, hops: int = 1,
                        include_self: bool = True, message_dtype=None):
    """One Eq.-(6) round where each ``axis_name`` position is an agent.

    Must run inside shard_map. ``data_size``: scalar |E_k| per agent.
    Exchanges params + sizes with ±1..hops ring neighbours via ppermute
    (2·hops messages of b(W) per agent per round — the paper's SL traffic).
    ``include_self`` as in :func:`mixing_weights`.

    ``message_dtype``: cast the EXCHANGED copy (e.g. bf16) — halves the
    Eq.-(11) sidelink bytes. An optimization_barrier pins the cast before
    the ppermute (XLA otherwise commutes converts past permutes and keeps
    the wire at the storage dtype — EXPERIMENTS.md §Perf P3).
    """
    K = _axis_size(axis_name)
    perms = []
    for d in range(1, hops + 1):
        perms.append([(i, (i + d) % K) for i in range(K)])   # from left
        perms.append([(i, (i - d) % K) for i in range(K)])   # from right

    sizes = [jax.lax.ppermute(data_size, axis_name, p) for p in perms]
    denom = sum(sizes) + (data_size if include_self else 0.0)
    sigmas = [s / jnp.maximum(denom, 1e-12) for s in sizes]

    def combine(x):
        if message_dtype is not None and x.dtype != jnp.dtype(message_dtype):
            # the whole neighbour pathway stays in message_dtype: if the
            # received value were upcast, XLA CSEs the convert with the
            # local f32 accumulator and moves the WIRE back to f32 —
            # consuming neighbours only in bf16 pins a bf16 exchange.
            md = jnp.dtype(message_dtype)
            msg = x.astype(md)
            neigh = [jax.lax.ppermute(msg, axis_name, p) for p in perms]
            upd = sum((sig.astype(md) * (nb - msg)).astype(jnp.float32)
                      for sig, nb in zip(sigmas, neigh))
        else:
            neigh = [jax.lax.ppermute(x, axis_name, p) for p in perms]
            xf32 = x.astype(jnp.float32)
            upd = sum(sig * (nb.astype(jnp.float32) - xf32)
                      for sig, nb in zip(sigmas, neigh))
        return (x.astype(jnp.float32) + upd).astype(x.dtype)

    return jax.tree.map(combine, params)


def permutation_schedule(mix, gamma: float = 1.0):
    """Decompose a CONCRETE σ matrix into ppermute rounds for the
    distributed path: a list of ``(pairs, sig)`` where ``pairs`` is a full
    source→target permutation of the K mesh positions and ``sig`` is the
    (K,) vector of Eq.-(6) weights each target applies to the message it
    receives that round (γ·σ_{tgt,src}; 0 where the round carries no real
    edge for that target).

    Greedy maximal-matching cover: every directed edge of the graph is
    carried by exactly one round, so the number of ppermutes is ≥ the max
    degree and usually equal to it (ring hops=1 ⇒ 2 rounds). Each matching
    is completed to a FULL permutation — vmap's ppermute batching rule
    (and a clean SPMD lowering) wants every position as source and target
    exactly once — and the padding lanes land with σ = 0, an exact no-op
    in Eq. (6). Eq.-(11) pricing is untouched: it counts the graph's
    directed edges, not the permutation padding.
    """
    M = np.asarray(mix, np.float32)
    K = M.shape[0]
    off = M.copy()
    np.fill_diagonal(off, 0.0)
    edges = {(k, h) for k in range(K)
             for h in np.flatnonzero(off[k] != 0.0)}
    schedule = []
    while edges:
        used_src, used_tgt = set(), set()
        pairs, sig = [], np.zeros(K, np.float32)
        for k, h in sorted(edges):
            if h in used_src or k in used_tgt:
                continue
            pairs.append((h, k))
            sig[k] = gamma * off[k, h]
            used_src.add(h)
            used_tgt.add(k)
        edges -= {(tgt, src) for src, tgt in pairs}
        free_src = [s for s in range(K) if s not in used_src]
        free_tgt = [t for t in range(K) if t not in used_tgt]
        pairs.extend(zip(free_src, free_tgt))
        schedule.append((tuple(pairs), sig))
    return schedule


def _permute_agent_step(params, residual, sigs, akey, *, pairs_list,
                        axis_name: str, codec, stateful: bool,
                        pin_wire: bool = False):
    """One agent's Eq.-(6) round on the ppermute path (runs per mesh
    position under shard_map, or per vmapped lane in the emulation).

    The agent encodes its message once (m = W + r with error feedback),
    the WIRE payload (int8 q + scales, bf16, top-k pairs, …) rides every
    scheduled ppermute, and each received payload is decoded INSIDE the
    combine around the agent's own decoded copy x̂_k — the same CHOCO
    recentering as the dense path, so the population mean stays exact
    under doubly-stochastic σ regardless of the wire format.
    """
    leaves, treedef = jax.tree.flatten(params)
    res_leaves = (jax.tree.leaves(residual) if residual is not None
                  else [None] * len(leaves))
    new_leaves, new_res = [], []
    for li, (x, r) in enumerate(zip(leaves, res_leaves)):
        xf = jnp.asarray(x, jnp.float32).ravel()
        kk = None if akey is None else jax.random.fold_in(akey, li)
        like = jax.ShapeDtypeStruct(xf.shape, jnp.float32)
        if codec is None:
            payload, xhat = {"v": xf}, xf
        elif stateful:
            payload, xhat, r_new = codec.encode_leaf_stateful(
                xf, r.ravel(), kk)
            new_res.append(r_new.reshape(jnp.shape(x)))
        else:
            payload = codec.encode_leaf(xf, kk)
            xhat = codec.decode_leaf(payload, like)
        if pin_wire:
            # pin the wire format: XLA commutes pure-convert encodes
            # (bf16) past collective-permutes and would ship f32
            # otherwise (the barrier has no vmap batching rule, so the
            # emulation path — where no bytes cross a real link — skips it)
            payload = jax.lax.optimization_barrier(payload)
        acc = jnp.zeros_like(xf)
        for m, pairs in enumerate(pairs_list):
            nb = jax.tree.map(
                lambda a: jax.lax.ppermute(a, axis_name, pairs), payload)
            if pin_wire:
                # pin the RECEIVE side too: the decode convert otherwise
                # commutes back through the ppermute (convert(permute(x))
                # == permute(convert(x))) and the wire ships f32 even
                # though the send side was pinned — repro.analysis H2
                # caught exactly this on the bf16 distributed wire
                nb = jax.lax.optimization_barrier(nb)
            nb_hat = nb["v"] if codec is None else codec.decode_leaf(nb, like)
            acc = acc + sigs[m] * (nb_hat - xhat)
        new_leaves.append((xf + acc).reshape(jnp.shape(x)).astype(x.dtype))
    new_params = jax.tree.unflatten(treedef, new_leaves)
    res_out = jax.tree.unflatten(treedef, new_res) if stateful else None
    return new_params, res_out


def _mesh_axis(mesh, axis_name: str):
    if mesh is None:
        return None
    return dict(mesh.shape).get(axis_name)


def distributed_consensus_step(stacked_params, mix, *,
                               axis_name: str = "agents", mesh=None,
                               codec=None, codec_state=None, key=None,
                               gamma: float = 1.0,
                               error_feedback: bool = True,
                               schedule=None, sig_override=None):
    """Eq. (6) on the DISTRIBUTED path with codec-aware wires: one agent
    per mesh position, neighbour exchange via ``jax.lax.ppermute`` rounds
    from :func:`permutation_schedule` (works for ANY concrete graph, not
    just rings), and the permuted payload is the CODEC wire — int8/int4
    lanes plus their scales for :class:`~repro.comms.codecs.IntCodec`,
    bf16 for the cast codec — so ``Topology.round_comm_joules(codec=)``
    prices exactly what this path ships.

    With ``mesh`` holding an ``axis_name`` axis of size K, runs under
    shard_map (one agent per device; the ppermutes are ICI sidelink
    traffic). Otherwise runs the vmap-with-axis_name emulation, which
    shares the collective semantics — the CPU test path.

    ``sig_override``: traced (K, M) per-slot weights replacing the
    schedule's baked γ·σ stack for THIS round — the σ is a runtime
    operand of the compiled program (the ppermute pairs stay trace-time
    structure), which is how the time-varying engine masks individual
    schedule slots in-scan without a retrace: faded slots ride with
    σ = 0, exact no-ops in Eq. (6), while the wire still ships all M
    permutations of the fixed schedule superset.

    Returns ``(new_stacked_params, new_codec_state)``; the state is the
    stacked error-feedback residual (None for stateless codecs).
    """
    mix = resolve_mix(mix)
    if codec is not None:
        from repro import comms   # deferred: core stays import-light
        codec = comms.resolve_codec(codec, error_feedback)
    stateful = codec is not None and codec.stateful
    if schedule is None:
        schedule = permutation_schedule(mix, gamma)
    K = jax.tree.leaves(stacked_params)[0].shape[0]
    pairs_list = [p for p, _ in schedule]
    if sig_override is not None:
        sig_stack = jnp.asarray(sig_override, jnp.float32)
        if sig_stack.shape != (K, len(schedule)):
            raise ValueError(
                f"sig_override is {sig_stack.shape}, schedule wants "
                f"(K={K}, M={len(schedule)})")
    else:
        sig_stack = (jnp.stack([jnp.asarray(s) for _, s in schedule],
                               axis=1)
                     if schedule else jnp.zeros((K, 0), jnp.float32))
    keys = None if key is None else jax.random.split(key, K)
    if stateful and codec_state is None:
        codec_state = jax.tree.map(
            lambda x: jnp.zeros(jnp.shape(x), jnp.float32), stacked_params)
    if not stateful:
        codec_state = None

    use_mesh = _mesh_axis(mesh, axis_name) == K

    def agent_fn(p, r, sg, kk):
        return _permute_agent_step(p, r, sg, kk, pairs_list=pairs_list,
                                   axis_name=axis_name, codec=codec,
                                   stateful=stateful, pin_wire=use_mesh)

    if use_mesh:
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec

        spec = PartitionSpec(axis_name)

        def block_fn(p, r, sg, kk):     # each position holds ONE agent
            sq = lambda t: jax.tree.map(lambda a: a[0], t)
            out, res = agent_fn(sq(p), sq(r), sq(sg), sq(kk))
            un = lambda t: jax.tree.map(lambda a: a[None], t)
            return un(out), un(res)

        new, res = shard_map(
            block_fn, mesh=mesh, in_specs=(spec,) * 4,
            out_specs=(spec, spec), check_rep=False)(
            stacked_params, codec_state, sig_stack, keys)
    else:
        new, res = jax.vmap(agent_fn, axis_name=axis_name)(
            stacked_params, codec_state, sig_stack, keys)
    return new, (res if stateful else None)


def _sharded_block_leaf(x_blk, r_blk, idx_blk, sig_blk, keys_blk, *, K: int,
                        codec, stateful: bool, axis_name: str,
                        kernel_impl: str, kw: dict,
                        pin_wire: bool = False):
    """One mesh position's block of agents, one leaf: encode the owned
    rows, all_gather the WIRE along the agent axis, then mix every owned
    row from the gathered wire (fused dequant-consensus kernel for every
    IntCodec wire — per-tensor AND block-wise scales stay int8 lanes
    through the gather; generic decode-then-combine otherwise)."""
    like = jax.ShapeDtypeStruct(x_blk.shape[1:], jnp.float32)
    r_new = None
    if codec is None:
        payload, xhat_blk = {"v": x_blk}, x_blk
    elif stateful:
        if keys_blk is None:
            payload, xhat_blk, r_new = jax.vmap(
                lambda m, rr: codec.encode_leaf_stateful(m, rr, None))(
                x_blk, r_blk)
        else:
            payload, xhat_blk, r_new = jax.vmap(
                codec.encode_leaf_stateful)(x_blk, r_blk, keys_blk)
    else:
        if keys_blk is None:
            payload = jax.vmap(lambda m: codec.encode_leaf(m, None))(x_blk)
        else:
            payload = jax.vmap(codec.encode_leaf)(x_blk, keys_blk)
        xhat_blk = jax.vmap(lambda p: codec.decode_leaf(p, like))(payload)
    if pin_wire:    # pin the wire dtype (no batching rule: mesh path only)
        payload = jax.lax.optimization_barrier(payload)
    gathered = jax.tree.map(
        lambda a: jax.lax.all_gather(a, axis_name
                                     ).reshape((K,) + a.shape[1:]),
        payload)
    if pin_wire:
        # receive-side pin: without it the generic decode below commutes
        # back through the all_gather and the wire reverts to f32 (the
        # int-wire fused path is immune — its gather operands are int8)
        gathered = jax.lax.optimization_barrier(gathered)

    from repro.kernels import ops   # deferred: keeps consensus importable

    base = getattr(codec, "inner", codec)
    if codec is not None and getattr(base, "qbits", None) is not None:
        # int wire (per-tensor OR block-wise scales): neighbour tiles
        # stay int8 lanes through the gather; dequant happens INSIDE the
        # fused combine — block-scaled wires no longer decode-then-
        # combine on the sharded plan
        qblock = getattr(base, "block", None)
        qkw = dict(kw) if qblock is None else dict(kw, qblock=qblock)

        def one(xk, qk, sk, ik, sgk):
            return ops.quant_consensus_update(
                xk, qk, sk, gathered["q"][ik], gathered["scale"][ik], sgk,
                impl=kernel_impl, **qkw)

        y = jax.vmap(one)(x_blk, payload["q"], payload["scale"],
                          idx_blk, sig_blk)
    else:
        xhat_all = (gathered["v"] if codec is None else
                    jax.vmap(lambda p: codec.decode_leaf(p, like))(gathered))

        def one(xk, xhk, ik, sgk):
            mixed_hat = ops.consensus_update(xhk, xhat_all[ik], sgk,
                                             impl=kernel_impl, **kw)
            return xk + (mixed_hat - xhk)

        y = jax.vmap(one)(x_blk, xhat_blk, idx_blk, sig_blk)
    return y, r_new


def sharded_consensus_step(stacked_params, mix, *, num_blocks: int,
                           axis_name: str = "agents", mesh=None,
                           codec=None, codec_state=None, key=None,
                           gamma: float = 1.0,
                           error_feedback: bool = True,
                           block_n: Optional[int] = None,
                           structure=None):
    """Eq. (6) on the SHARDED path: the K-agent population is split into
    ``num_blocks`` contiguous blocks of B = K/num_blocks agents, each
    owned by one mesh position. Per round, every position encodes its own
    block's wires, ``all_gather``s the (K, ·) WIRE along the agent axis
    (codec-compressed bytes, not f32), and mixes its owned rows through
    the sparse gather — so no single program ever materializes the
    (K, K) mixing stack or the K×H f32 neighbour tensor, which is what
    lifts the single-program vmap limit for K ≫ core count.

    With ``mesh`` holding an ``axis_name`` axis of size ``num_blocks``,
    runs under shard_map; otherwise the vmap-with-axis_name emulation
    (identical collective semantics — the CPU test path).

    Returns ``(new_stacked_params, new_codec_state)`` like the other
    compressed paths; the sparse structure needs a CONCRETE mix unless
    ``structure`` supplies a per-round ``(idx, sig)`` override — ``idx``
    concrete, ``sig`` possibly traced — in which case faded-neighbour
    lanes carry σ = 0 and the all_gather/gather indices stay baked (the
    time-varying-graph contract of :func:`consensus_step`).
    """
    mix = resolve_mix(mix)
    if codec is not None:
        from repro import comms
        codec = comms.resolve_codec(codec, error_feedback)
    stateful = codec is not None and codec.stateful
    leaves, treedef = jax.tree.flatten(stacked_params)
    K = leaves[0].shape[0]
    if num_blocks < 1 or K % num_blocks:
        raise ValueError(
            f"num_blocks={num_blocks} must divide the population K={K}")
    B = K // num_blocks
    if structure is None:
        idx_np, sig_np = sparse_structure(mix)
        idx = jnp.asarray(idx_np)
        sig = gamma * jnp.asarray(sig_np)
    else:
        idx = jnp.asarray(structure[0])
        sig = gamma * jnp.asarray(structure[1], jnp.float32)
    kernel_impl = "pallas" if jax.default_backend() == "tpu" else "xla"
    kw = {} if block_n is None else {"block_n": block_n}

    if stateful:
        state_leaves = (jax.tree.leaves(codec_state)
                        if codec_state is not None
                        else [jnp.zeros(jnp.shape(x), jnp.float32)
                              for x in leaves])
        if len(state_leaves) != len(leaves):
            raise ValueError(
                f"codec_state has {len(state_leaves)} leaves but "
                f"stacked_params has {len(leaves)} — thread the "
                "codec_state returned by the previous step (or pass "
                "None to start from zero error-feedback residuals)")
    else:
        state_leaves = [None] * len(leaves)

    use_mesh = _mesh_axis(mesh, axis_name) == num_blocks

    def _run(fn, *args):
        """Map ``fn`` over the block axis: shard_map on a real mesh,
        vmap(axis_name) emulation otherwise. args are (K, ...) or None."""
        if use_mesh:
            from jax.experimental.shard_map import shard_map
            from jax.sharding import PartitionSpec

            spec = PartitionSpec(axis_name)
            return shard_map(fn, mesh=mesh, in_specs=(spec,) * len(args),
                             out_specs=(spec, spec),
                             check_rep=False)(*args)
        blk = jax.tree.map(
            lambda a: a.reshape((num_blocks, B) + a.shape[1:]), args)
        out, res = jax.vmap(fn, axis_name=axis_name)(*blk)
        return jax.tree.map(
            lambda a: a.reshape((K,) + a.shape[2:]), (out, res))

    new_leaves, new_state = [], []
    for li, (x, r) in enumerate(zip(leaves, state_leaves)):
        xf = x.astype(jnp.float32).reshape(K, -1)
        rf = None if r is None else r.reshape(K, -1)
        keys_leaf = (None if key is None else
                     jax.random.split(jax.random.fold_in(key, li), K))
        block_fn = functools.partial(
            _sharded_block_leaf, K=K, codec=codec, stateful=stateful,
            axis_name=axis_name, kernel_impl=kernel_impl, kw=kw,
            pin_wire=use_mesh)
        y, r_new = _run(block_fn, xf, rf, idx, sig, keys_leaf)
        new_leaves.append(y.reshape(x.shape).astype(x.dtype))
        if stateful:
            new_state.append(r_new.reshape(x.shape))

    new_params = jax.tree.unflatten(treedef, new_leaves)
    state_out = (jax.tree.unflatten(treedef, new_state)
                 if stateful else None)
    return new_params, state_out


def cluster_ring_consensus_step(params, data_size, axis_name: str,
                                cluster_size: int,
                                include_self: bool = True):
    """Ring consensus restricted to contiguous clusters of ``cluster_size``
    agents along ``axis_name`` (the paper's per-task clusters C_i: only
    same-cluster agents exchange models)."""
    K = _axis_size(axis_name)
    assert K % cluster_size == 0
    if cluster_size == 1:
        return params
    perm_fwd, perm_bwd = [], []
    for i in range(K):
        c = i // cluster_size
        perm_fwd.append((i, c * cluster_size + (i + 1 - c * cluster_size)
                         % cluster_size))
        perm_bwd.append((i, c * cluster_size + (i - 1 - c * cluster_size)
                         % cluster_size))
    perms = [perm_fwd, perm_bwd] if cluster_size > 2 else [perm_fwd]

    sizes = [jax.lax.ppermute(data_size, axis_name, p) for p in perms]
    denom = sum(sizes) + (data_size if include_self else 0.0)
    sigmas = [s / jnp.maximum(denom, 1e-12) for s in sizes]

    def combine(x):
        neigh = [jax.lax.ppermute(x, axis_name, p) for p in perms]
        xf = x.astype(jnp.float32)
        upd = sum(sig * (nb.astype(jnp.float32) - xf)
                  for sig, nb in zip(sigmas, neigh))
        return (xf + upd).astype(x.dtype)

    return jax.tree.map(combine, params)
