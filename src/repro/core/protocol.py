"""The paper's two-stage MTL protocol, end to end (Fig. 1):

  stage 1 — MAML meta-optimization at the data center for t0 rounds over
            Q training tasks (Sect. II-A);
  stage 2 — per-cluster decentralized FL adaptation from the broadcast
            meta-model until each task hits its accuracy target
            (Sect. II-B), measuring t_i;

plus the energy accounting of both stages (Sect. III). This is the
composable core feature: it is model-agnostic (DQN robots, LM tasks, any
pytree + loss) and is what `examples/meta_rl_robots.py` and the
benchmarks drive.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core import energy, federated, maml
from repro.core import topology as topo_lib
from repro.core.multitask import ClusterNetwork


@dataclass
class ProtocolResult:
    t0: int
    rounds_per_task: List[int]              # t_i, i = 1..M
    meta_history: List[float]
    fl_histories: List[List[float]]
    energy_params: energy.EnergyParams
    Q: int
    cluster_topology: Optional[topo_lib.Topology] = None
    #: model-exchange codec (spec string or Codec) — prices each stage-2
    #: sidelink message at its wire size in Eq. (11)
    codec: object = None
    #: per-task Eq.-(11) comm joules MEASURED on the links actually up
    #: each round (time-varying graphs, :func:`topology.dropout`); None
    #: for static topologies, where the modeled E_FL term is exact
    fl_comm_joules_measured: Optional[List[float]] = None

    @property
    def E_FL_comm(self) -> List[float]:
        """Per-task Eq.-(11) comm term: measured per-round joules when a
        time-varying topology recorded them, else modeled from the
        static graph."""
        if self.fl_comm_joules_measured is not None:
            return list(self.fl_comm_joules_measured)
        return [energy.fl_comm_energy(self.energy_params, t,
                                      self.cluster_topology, self.codec)
                for t in self.rounds_per_task]

    @property
    def E_ML(self) -> float:
        return energy.maml_energy(self.energy_params, self.t0, self.Q)

    @property
    def E_FL(self) -> List[float]:
        return [energy.fl_learning_energy(self.energy_params, t,
                                          self.cluster_topology) + c
                for t, c in zip(self.rounds_per_task, self.E_FL_comm)]

    @property
    def E_total(self) -> float:
        return self.E_ML + sum(self.E_FL)

    def summary(self) -> Dict:
        from repro import comms
        codec = comms.get_codec(self.codec)   # spec strings resolve too
        return {
            "t0": self.t0,
            "t_i": self.rounds_per_task,
            "codec": codec.name if codec is not None else None,
            "E_ML_kJ": self.E_ML / 1e3,
            "E_FL_kJ": [e / 1e3 for e in self.E_FL],
            "E_total_kJ": self.E_total / 1e3,
        }


class MTLProtocol:
    """Orchestrates meta-training + task adaptation for a clustered MTL net.

    Arguments
    ---------
    loss_fn:        loss_fn(params, batch) -> scalar, model-agnostic.
    init_fn:        init_fn(key) -> params (random init).
    network:        ClusterNetwork topology (M clusters, Q meta tasks).
    sample_support: (key, task_id, steps) -> batch pytree with leading
                    steps axis (inner-adaptation / local-SGD data).
    sample_query:   (key, task_id) -> batch (meta-update data).
    target_fn:      (params, task_id) -> (reached, metric) — the paper's
                    per-task accuracy target (running reward R).
    chunk:          rounds per compiled program for BOTH stages (the
                    scanned drivers :func:`repro.core.maml.
                    maml_train_scan` / :func:`repro.core.federated.
                    run_fl_until_scan`): the host syncs once per chunk
                    instead of once per round, with t0 / t_i trajectories
                    bit-identical to ``chunk=1`` (the host-loop
                    fallback). Samplers/target_fn that don't trace fall
                    back to ``jax.pure_callback`` transparently.
    telemetry:      optional :class:`repro.telemetry.Telemetry` threaded
                    through BOTH stages — meta rounds land as ``maml``
                    events, every task's FL rounds as ``fl`` events
                    tagged ``task_id`` (so the per-task Eq.-(11) ledger
                    ``telemetry.joules(task_id=i)`` reconciles with the
                    post-hoc billing). Results are bit-identical with
                    telemetry off, buffered, or streaming.
    """

    def __init__(self, *, loss_fn, init_fn, network: ClusterNetwork,
                 sample_support, sample_query, target_fn,
                 inner_lr=0.01, outer_lr=0.001, fl_lr=0.01,
                 inner_steps=1, fl_local_steps=20,
                 first_order=True,
                 energy_params: Optional[energy.EnergyParams] = None,
                 codec=None, chunk: int = 16, telemetry=None):
        self.loss_fn = loss_fn
        self.init_fn = init_fn
        self.net = network
        self.sample_support = sample_support
        self.sample_query = sample_query
        self.target_fn = target_fn
        self.inner_lr = inner_lr
        self.outer_lr = outer_lr
        self.fl_lr = fl_lr
        self.inner_steps = inner_steps
        self.fl_local_steps = fl_local_steps
        self.first_order = first_order
        self.chunk = max(int(chunk), 1)
        self.telemetry = telemetry
        self.energy_params = energy_params or energy.paper_calibrated()
        if not first_order:
            self.energy_params = dataclasses.replace(
                self.energy_params, beta=2.0)
        # one cluster C_i's communication graph — drives BOTH the Eq.-(6)
        # mixing weights and the Eq.-(11) link pricing. The engine is the
        # single consensus entry point: it resolves the codec (lossy ones
        # get the error-feedback wrapper so adaptation still converges)
        # and picks the execution plan for the cluster graph.
        from repro.core.engine import ConsensusEngine
        self.cluster_topology = network.cluster_topology()
        self.engine = ConsensusEngine(self.cluster_topology, codec=codec)
        self.codec = self.engine.codec
        if self.telemetry is not None:
            # pre-register with THIS protocol's billing constants so the
            # streamed ledger prices like ProtocolResult does
            self.telemetry.recorder_for(self.engine, self.energy_params)

    # -- stage 1 ------------------------------------------------------------
    def meta_train(self, key, t0: int):
        """t0 MAML rounds over the Q meta tasks, driven by the chunked
        scan driver (``self.chunk`` rounds per compiled program; the
        meta-loss history syncs once per chunk). Returns (meta_params,
        history)."""
        kinit, kdata = jax.random.split(key)
        meta_params = self.init_fn(kinit)
        if t0 <= 0:
            return meta_params, []
        task_ids = list(self.net.meta_task_ids)

        def sample_tasks(k, _round):
            ks = jax.random.split(k, 2 * len(task_ids))
            sup = [self.sample_support(ks[2 * j], tid, self.inner_steps)
                   for j, tid in enumerate(task_ids)]
            qry = [self.sample_query(ks[2 * j + 1], tid)
                   for j, tid in enumerate(task_ids)]
            stack = lambda bs: jax.tree.map(
                lambda *xs: jnp.stack(xs), *bs)
            return stack(sup), stack(qry)

        return maml.maml_train_scan(
            self.loss_fn, meta_params, sample_tasks, rounds=t0,
            inner_lr=self.inner_lr, outer_lr=self.outer_lr,
            inner_steps=self.inner_steps, first_order=self.first_order,
            key=kdata, chunk=self.chunk, telemetry=self.telemetry)

    # -- stage 2 ------------------------------------------------------------
    def adapt_task(self, key, task_id: int, init_params, *,
                   max_rounds: int = 500):
        """Decentralized FL (Eq. 6) within cluster C_i from ``init_params``,
        driven by the chunked scan driver (``self.chunk`` rounds per
        program; t_i recovered bit-exactly from the in-scan reached
        mask). Returns (params, rounds_used t_i, history)."""
        C = self.net.devices_per_cluster
        stacked = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (C,) + x.shape)
            if hasattr(x, "shape") else x, init_params)

        def sample_batches(k, _t):
            ks = jax.random.split(k, C)
            bs = [self.sample_support(ks[j], task_id, self.fl_local_steps)
                  for j in range(C)]
            return jax.tree.map(lambda *xs: jnp.stack(xs), *bs)

        def target(stacked_params):
            p0 = jax.tree.map(lambda x: x[0], stacked_params)
            return self.target_fn(p0, task_id)

        return federated.run_fl_until_scan(
            self.loss_fn, stacked, sample_batches, self.engine,
            self.fl_lr, target_fn=target, max_rounds=max_rounds, key=key,
            chunk=self.chunk, telemetry=self.telemetry,
            telemetry_extra=({"task_id": int(task_id)}
                             if self.telemetry is not None else None))

    # -- full protocol --------------------------------------------------------
    def run(self, key, t0: int, *, max_rounds: int = 500) -> ProtocolResult:
        kmeta, kfl = jax.random.split(key)
        meta_params, meta_hist = self.meta_train(kmeta, t0)
        rounds, hists = [], []
        for task_id in range(self.net.num_tasks):
            kfl, kt = jax.random.split(kfl)
            _, t_i, hist = self.adapt_task(kt, task_id, meta_params,
                                           max_rounds=max_rounds)
            rounds.append(t_i)
            hists.append(hist)
        return ProtocolResult(
            t0=t0, rounds_per_task=rounds, meta_history=meta_hist,
            fl_histories=hists, energy_params=self.energy_params,
            Q=self.net.Q, cluster_topology=self.cluster_topology,
            codec=self.codec)
