"""Clustered multi-task network model — paper Sect. II.

K devices form M clusters C_i; cluster i learns task τ_i (Eq. 1). A subset
Q_τ of Q ≤ M tasks is used for MAML meta-training (Eq. 2). This module is
the bookkeeping layer shared by the RL case study and the LM examples.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, Sequence, Tuple

import numpy as np


@dataclass(frozen=True)
class TaskSpec:
    """One task τ_i: a name and a sampler of (support, query) batches.

    ``sample(key, batch_size) -> batch`` — model-agnostic pytree batches.
    """
    name: str
    sample: Callable = None
    meta: dict = field(default_factory=dict)

    def __hash__(self):
        return hash(self.name)


@dataclass(frozen=True)
class ClusterNetwork:
    """The clustered multi-task topology: device k ∈ C_i learns τ_i."""

    num_tasks: int                        # M
    devices_per_cluster: int = 2          # |C_i|
    meta_task_ids: Tuple[int, ...] = ()   # Q_τ ⊆ {0..M-1}

    @property
    def K(self) -> int:
        return self.num_tasks * self.devices_per_cluster

    @property
    def Q(self) -> int:
        return len(self.meta_task_ids)

    def cluster_of(self, device: int) -> int:
        return device // self.devices_per_cluster

    def devices_of(self, task: int) -> Sequence[int]:
        c = self.devices_per_cluster
        return list(range(task * c, (task + 1) * c))

    def neighbors_of(self, device: int) -> Sequence[int]:
        """In-cluster neighbourhood N_{k,i} (all-to-all within the cluster,
        which for |C_i| = 2 is the paper's single-neighbour sidelink)."""
        return [d for d in self.devices_of(self.cluster_of(device))
                if d != device]

    def adjacency(self) -> np.ndarray:
        A = np.zeros((self.K, self.K), bool)
        for k in range(self.K):
            for h in self.neighbors_of(k):
                A[k, h] = True
        return A

    def topology(self):
        """The population's communication graph as a first-class
        :class:`repro.core.topology.Topology` (per-task SL clusters)."""
        from repro.core import topology as topo_lib
        return topo_lib.from_cluster_network(self)

    def cluster_topology(self):
        """One cluster C_i's graph (drives per-task Eq.-(11) pricing)."""
        from repro.core import topology as topo_lib
        return topo_lib.clusters(1, self.devices_per_cluster)


class TaskRegistry:
    """Name -> TaskSpec registry with deterministic ordering."""

    def __init__(self):
        self._tasks: Dict[str, TaskSpec] = {}

    def add(self, task: TaskSpec) -> TaskSpec:
        self._tasks[task.name] = task
        return task

    def __getitem__(self, name: str) -> TaskSpec:
        return self._tasks[name]

    def __len__(self):
        return len(self._tasks)

    def names(self):
        return sorted(self._tasks)

    def ordered(self):
        return [self._tasks[n] for n in self.names()]
