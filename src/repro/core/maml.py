"""Model-Agnostic Meta-Learning — paper Eqs. (2)–(5).

One MAML round (Sect. II-A):

  task-specific training (Eq. 3):
      φ_{t,τ_i} = W_t − μ Σ_k ∇_W L_k(W_t | E^(a)_{i,k})
  meta-model update (Eq. 4):
      W_{t+1} = W_t − η Σ_i Σ_k ∇_W L_k[φ_{t,τ_i} | E^(b)_{i,k}]
  where (Eq. 5) ∇_W L = J_W[φ] · ∇_φ L — the gradient-through-gradient.

``first_order=True`` applies the paper's J ≈ I approximation (β = 1 in the
energy model); ``False`` differentiates through the inner SGD exactly
(β > 1 — the Jacobian-vector products cost extra backward passes).

Everything is model-agnostic: ``loss_fn(params, batch) -> scalar`` and
params is any pytree. Tasks are vmapped, so the Q tasks of a MAML round
lower to one batched XLA program (shardable over the mesh's data axis).
"""
from __future__ import annotations

import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import scanloop


def inner_adapt(loss_fn: Callable, params, batch, lr: float,
                steps: int = 1):
    """Eq. (3): ``steps`` SGD steps on one task's support data.

    ``batch`` may have a leading steps axis (one mini-batch per step) or be
    a single batch reused every step. Differentiable (used by 2nd-order).
    """

    def one_step(p, b):
        g = jax.grad(loss_fn)(p, b)
        return jax.tree.map(
            lambda w, gw: w - lr * gw.astype(w.dtype), p, g), None

    if steps == 1:
        p, _ = one_step(params, batch)
        return p

    leaves = jax.tree.leaves(batch)
    has_step_axis = leaves and all(
        hasattr(x, "shape") and x.shape[:1] == (steps,) for x in leaves)
    if has_step_axis:
        p, _ = jax.lax.scan(one_step, params, batch)
        return p
    for _ in range(steps):
        p, _ = one_step(params, batch)
        params = p
    return params


def maml_meta_step(loss_fn: Callable, meta_params, support, query, *,
                   inner_lr: float, outer_lr: float,
                   inner_steps: int = 1, first_order: bool = True,
                   grad_reduce: Optional[Callable] = None):
    """One MAML round over Q tasks (support/query have leading task axis Q).

    Returns (new_meta_params, metrics dict).
    ``grad_reduce``: optional tree-map'd reduction applied to the meta
    gradient before the update (e.g. a psum for multi-host sharding).
    """

    def task_meta_loss(p, sup, qry):
        phi = inner_adapt(loss_fn, p, sup, inner_lr, inner_steps)
        if first_order:
            # J ≈ I: grads flow to φ only, not through the inner gradient
            phi = jax.tree.map(
                lambda w, pw: jax.lax.stop_gradient(w - pw) + pw, phi, p)
        return loss_fn(phi, qry)

    def mean_meta_loss(p):
        losses = jax.vmap(lambda s, q: task_meta_loss(p, s, q))(
            support, query)
        return jnp.mean(losses), losses

    (mloss, task_losses), g = jax.value_and_grad(
        mean_meta_loss, has_aux=True)(meta_params)
    if grad_reduce is not None:
        g = grad_reduce(g)
    new_params = jax.tree.map(
        lambda w, gw: (w.astype(jnp.float32)
                       - outer_lr * gw.astype(jnp.float32)).astype(w.dtype),
        meta_params, g)
    metrics = {"meta_loss": mloss, "task_losses": task_losses,
               "meta_grad_norm": jnp.sqrt(sum(
                   jnp.sum(jnp.square(x.astype(jnp.float32)))
                   for x in jax.tree.leaves(g)))}
    return new_params, metrics


def _scan_round_program(loss_fn: Callable, sample_tasks: Callable, key, *,
                        inner_lr: float, outer_lr: float, inner_steps: int,
                        first_order: bool, telemetry=None):
    """The ONE compiled MAML round-loop program both drivers share.

    Data is sampled INSIDE the scan from per-round derived keys (the
    carried key is split per round exactly like the legacy host loop,
    so the PRNG stream — and therefore every batch — is unchanged), and
    the per-round metrics accumulate as stacked device arrays, synced
    only when the caller pulls them. Samplers that satisfy the
    ``sample_tasks_traced`` contract (pure traced jax function of
    ``(key, int32 round)``; vmapped task samplers qualify) run
    on-device; anything else is transparently routed through
    ``jax.pure_callback`` by :func:`repro.core.scanloop.traceable`.

    ``jax.lax.scan`` compiles the SAME loop-body HLO for every chunk
    length, so driving this program with length-1 ``ts`` (the host
    loop) or length-``chunk`` ``ts`` produces bit-identical params and
    losses — which is the whole parity contract between
    :func:`maml_train` and :func:`maml_train_scan`. The params buffer
    is donated on backends with donation support (scanloop's donation
    invariant: don't reuse a pytree after passing it in).

    Programs are memoized through
    :func:`repro.core.scanloop.cached_program` on (loss_fn,
    sample_tasks — by identity — and the baked hyper-parameters), so
    Monte-Carlo sweeps re-entering the drivers with one configuration
    re-trace only when the meta-params' shapes change (jit's own
    per-shape cache); ``scanloop.TRACE_COUNTS["maml_chunk"]`` observes
    the retraces. Samplers that failed the traced contract (the
    ``pure_callback`` fallback) are never cached — the probe consumes
    elements from stateful host samplers, and skipping it on a cache
    hit would shift their stream between invocations.

    Telemetry: the per-round metrics (``meta_loss`` etc.) ALREADY ride
    the scan outputs, so BUFFERED telemetry needs no program change at
    all — the drivers ingest the same stacked metrics host-side and the
    cache key is untouched (buffered runs share the telemetry-off
    program). STREAMING telemetry plants a ``jax.debug.callback`` in
    the body that emits each round's meta-loss live; that callback
    closes over host state, so streaming programs are built per call
    and never cached (rule JX4).
    """
    streaming = telemetry is not None and telemetry.streaming
    cache_key = ("maml_chunk", loss_fn, sample_tasks, float(inner_lr),
                 float(outer_lr), int(inner_steps), bool(first_order))
    if not streaming:
        cached = scanloop.get_cached_program(cache_key)
        if cached is not None:
            return cached              # hit: skip the probe entirely
    sampler, sampler_traced = scanloop.traceable(
        sample_tasks, key, jnp.int32(0), name="sample_tasks")
    stream_cb = telemetry.maml_stream_cb() if streaming else None

    def build():
        step = functools.partial(
            maml_meta_step, loss_fn, inner_lr=inner_lr, outer_lr=outer_lr,
            inner_steps=inner_steps, first_order=first_order)

        def body(carry, t):
            p, k = carry
            k, sk = jax.random.split(k)
            support, query = sampler(sk, t)
            p, m = step(p, support, query)
            if stream_cb is not None:
                jax.debug.callback(stream_cb, t, m["meta_loss"],
                                   m["meta_grad_norm"], ordered=True)
            return (p, k), m

        def run_chunk(p, k, ts):
            scanloop.TRACE_COUNTS["maml_chunk"] += 1   # trace-time only
            return jax.lax.scan(body, (p, k), ts)

        return scanloop.donating_jit(run_chunk, donate_argnums=(0,))

    if streaming or not sampler_traced:
        # streaming telemetry / impure sampler: never cached
        return build()
    return scanloop.cached_program(cache_key, build)


def maml_train(loss_fn: Callable, meta_params, sample_tasks: Callable,
               *, rounds: int, inner_lr: float, outer_lr: float,
               inner_steps: int = 1, first_order: bool = True,
               key=None, callback: Optional[Callable] = None):
    """Run ``rounds`` MAML rounds. ``sample_tasks(key, round) -> (support,
    query)`` with leading task axis. Host-loop driver: one dispatch and
    one blocking ``float(meta_loss)`` sync per round — the
    ``chunk=1``-equivalent fallback of :func:`maml_train_scan` (both
    drive the same compiled round program, so their params and history
    agree bit for bit), and the only driver with a per-round host
    ``callback(t, params, metrics)``."""
    key = key if key is not None else jax.random.PRNGKey(0)
    meta_params = scanloop.own(meta_params)    # donation never touches
    run_round = _scan_round_program(           # the caller's pytree
        loss_fn, sample_tasks, key, inner_lr=inner_lr, outer_lr=outer_lr,
        inner_steps=inner_steps, first_order=first_order)
    history = []
    for t in range(rounds):
        (meta_params, key), ms = run_round(
            meta_params, key, jnp.arange(t, t + 1, dtype=jnp.int32))
        history.append(float(ms["meta_loss"][0]))
        if callback is not None:
            # own(): the carry is donated to the NEXT round's dispatch on
            # donating backends — a callback that retains the params
            # (snapshots, checkpoints) must not see buffers that round
            # t+1 will invalidate
            callback(t, scanloop.own(meta_params),
                     jax.tree.map(lambda x: x[0], ms))
    return meta_params, history


def maml_train_scan(loss_fn: Callable, meta_params, sample_tasks: Callable,
                    *, rounds: int, inner_lr: float, outer_lr: float,
                    inner_steps: int = 1, first_order: bool = True,
                    key=None, chunk: int = 32, telemetry=None):
    """Device-resident MAML driver: ``chunk`` rounds per compiled program.

    Bit-identical to :func:`maml_train` — same PRNG stream (the key is
    carried through the scan and split per round in the same order),
    same round body, same compiled scan program — but the host loop
    drops from O(rounds) jit dispatches + blocking ``float(meta_loss)``
    syncs to O(rounds/chunk): the meta-loss history accumulates as a
    device array and is synced once per chunk. See
    :func:`_scan_round_program` for the traced-sampler contract and the
    buffer-donation invariant. ``rounds`` need not be a multiple of
    ``chunk`` (the remainder runs as one shorter scan — at most two
    compiled programs in total).

    ``telemetry`` records one meta-round event per round from the
    chunk's stacked metrics (buffered mode reuses the telemetry-off
    program — metrics already ride the scan outputs; streaming mode
    emits each round live via ``jax.debug.callback`` from an uncached
    program). Params and history stay bit-identical in every mode."""
    key = key if key is not None else jax.random.PRNGKey(0)
    if rounds <= 0:
        return meta_params, []
    chunk = max(1, min(int(chunk), rounds))
    meta_params = scanloop.own(meta_params)    # donation never touches
    run_chunk = _scan_round_program(           # the caller's pytree
        loss_fn, sample_tasks, key, inner_lr=inner_lr, outer_lr=outer_lr,
        inner_steps=inner_steps, first_order=first_order,
        telemetry=telemetry)
    history = []
    for start in range(0, rounds, chunk):
        ts = jnp.arange(start, min(start + chunk, rounds), dtype=jnp.int32)
        (meta_params, key), ms = run_chunk(meta_params, key, ts)
        if telemetry is not None:
            telemetry.record_maml_rounds(
                {"meta_loss": ms["meta_loss"],
                 "meta_grad_norm": ms["meta_grad_norm"]}, start)
        history.extend(float(x) for x in np.asarray(ms["meta_loss"]))
    return meta_params, history
