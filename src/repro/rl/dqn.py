"""Deep Q-Learning with double learning (paper Sect. II-C, Eq. 7):

    ℓ(x | W) = [ r + ν max_y q̃(x', y) − q(x, y | W) ]²

with ν = 0.99 and q̃ a target network (van Hasselt double-DQN: online net
picks the argmax action, target net evaluates it). The Q-network is the
DeepMind model shape (repro.models.dqn) on the gridworld one-hot state.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models import dqn as qmodel
from repro.rl import gridworld as gw

NU = 0.99
R_SCALE = 0.1     # TD-target reward scaling (argmax-invariant; keeps Q and
                  # the squared TD loss numerically tame under γ=0.99
                  # bootstrap — evaluation uses raw rewards)


class DQNState(NamedTuple):
    params: dict
    target_params: dict


def init(key, cfg) -> DQNState:
    p = qmodel.init(key, cfg)
    return DQNState(params=p, target_params=p)


def td_loss(params, cfg, batch, target_params=None):
    """Double-DQN TD loss on a batch of transitions.

    batch: {"state": (B, 40), "action": (B,), "reward": (B,),
            "next_state": (B, 40)}. If target_params is None it is taken
    from the batch dict (keyed 'target' as a pytree closed over by the
    caller) or falls back to params (plain DQN).
    """
    tp = target_params if target_params is not None else \
        batch.get("target_params", params)
    q, _, _ = qmodel.forward(params, cfg, batch["state"])
    q_sa = jnp.take_along_axis(q, batch["action"][:, None].astype(jnp.int32),
                               axis=1)[:, 0]
    q_next_online, _, _ = qmodel.forward(params, cfg, batch["next_state"])
    a_star = jnp.argmax(q_next_online, axis=-1)
    q_next_t, _, _ = qmodel.forward(tp, cfg, batch["next_state"])
    q_next = jnp.take_along_axis(q_next_t, a_star[:, None], axis=1)[:, 0]
    target = batch["reward"] * R_SCALE + NU * jax.lax.stop_gradient(q_next)
    return jnp.mean(jnp.square(target - q_sa))


def make_loss_fn(cfg):
    """loss_fn(params, batch) for the protocol/MAML machinery: the target
    network is frozen inside the batch (standard replay-style training)."""

    def loss_fn(params, batch):
        return td_loss(params, cfg, batch,
                       target_params=batch.get("target_params"))

    return loss_fn


def collect_experience(key, params, cfg, task_id: int, *, steps: int = 20,
                       epsilon: float = 0.1, batch: int = 2):
    """ε-greedy experience: the paper's E_ik (20 consecutive motions)."""
    qfn = lambda s: qmodel.forward(params, cfg, s)[0]
    data = gw.rollout(key, qfn, task_id, steps=steps, epsilon=epsilon,
                      batch=batch)
    flat = jax.tree.map(
        lambda x: x.reshape((-1,) + x.shape[2:]), data)
    return flat


def experience_batches(key, params, cfg, task_id: int, n_batches: int,
                       *, batch_size: int = 32, epsilon: float = 0.1,
                       target_params=None):
    """Sample ``n_batches`` TD mini-batches (leading batch axis stacked) —
    feeds inner_adapt / local_steps which scan over the leading axis."""
    k1, k2 = jax.random.split(key)
    episodes = max(batch_size * n_batches // 20, 2)
    data = collect_experience(k1, params, cfg, task_id, batch=episodes,
                              epsilon=epsilon)
    N = data["state"].shape[0]
    idx = jax.random.randint(k2, (n_batches, batch_size), 0, N)
    out = jax.tree.map(lambda x: x[idx], data)
    if target_params is not None:
        out["target_params"] = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (n_batches,) + x.shape),
            target_params)
    return out


def evaluate(key, params, cfg, task_id: int, *, episodes: int = 4,
             steps: int = 20):
    """Mean greedy running reward R (paper's accuracy target R = 50)."""
    qfn = lambda s: qmodel.forward(params, cfg, s)[0]
    return gw.greedy_running_reward(key, qfn, task_id, steps=steps,
                                    episodes=episodes)
