"""The paper's robotized environment (Sect. IV): crawling robots on a 2D
regular grid of 40 landmark points, 4 actions (F, B, L, R), and M = 6
trajectory tasks described by position-reward lookup tables.

The paper's dataset repo is offline-unavailable; the environment is
re-implemented from its spec (DESIGN.md §7): a 8×5 grid (40 landmarks),
a common entry point, six maximum-reward trajectories with shared prefix
and diverging exits (Fig. 2(b)), and rewards growing as the robot
approaches the assigned trajectory.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

GRID_W, GRID_H = 8, 5           # 40 landmark points
NUM_CELLS = GRID_W * GRID_H
NUM_ACTIONS = 4                 # F(+x), B(-x), L(+y), R(-y)
ENTRY = (0, 2)                  # common entry point (left edge, mid row)
NUM_TASKS = 6

# action -> (dx, dy)
MOVES = np.array([[1, 0], [-1, 0], [0, 1], [0, -1]], np.int32)


def _trajectories():
    """Six max-reward trajectories: common entry + prefix, diverging paths
    (Fig. 2(b) has a common entry point, different exits)."""
    trajs = []
    # shared prefix along the mid row
    prefix = [(x, 2) for x in range(0, 3)]
    exits = [
        [(3, 2), (4, 2), (5, 2), (6, 2), (7, 2)],                  # straight
        [(3, 3), (4, 3), (5, 4), (6, 4), (7, 4)],                  # up-right
        [(3, 1), (4, 1), (5, 0), (6, 0), (7, 0)],                  # down-right
        [(3, 3), (3, 4), (4, 4), (5, 4), (5, 3)],                  # up hook
        [(3, 1), (3, 0), (4, 0), (5, 0), (5, 1)],                  # down hook
        [(3, 2), (4, 2), (4, 3), (5, 3), (6, 3), (7, 3)],          # late up
    ]
    for e in exits:
        trajs.append(prefix + e)
    return trajs


TRAJECTORIES = _trajectories()


def reward_table(task_id: int) -> np.ndarray:
    """Position-reward lookup (Sect. IV-A): larger reward approaching the
    task's trajectory, graded by grid distance, progress-weighted along the
    path (so trajectory FOLLOWING, not reward camping near the shared
    prefix, maximizes the running reward); off-trajectory cells penalize."""
    tr = TRAJECTORIES[task_id]
    R = np.full((GRID_W, GRID_H), -0.5, np.float32)
    for x in range(GRID_W):
        for y in range(GRID_H):
            d, i_near = min(
                (abs(x - tx) + abs(y - ty), i)
                for i, (tx, ty) in enumerate(tr))
            prog = i_near / max(len(tr) - 1, 1)
            if d == 0:
                R[x, y] = 5.0 + 5.0 * prog
            elif d == 1:
                R[x, y] = 1.0
            elif d == 2:
                R[x, y] = 0.0
    return R


REWARD_TABLES = jnp.asarray(
    np.stack([reward_table(i) for i in range(NUM_TASKS)]))   # (M, W, H)


def cell_index(pos):
    return pos[..., 0] * GRID_H + pos[..., 1]


def one_hot_state(pos):
    """(..., 2) int -> (..., 40) one-hot — the DQN observation."""
    return jax.nn.one_hot(cell_index(pos), NUM_CELLS, dtype=jnp.float32)


def step(pos, action, task_id):
    """pos (..., 2) int32, action (...,) int32 -> (new_pos, reward)."""
    delta = jnp.asarray(MOVES)[action]
    new = jnp.clip(pos + delta,
                   jnp.array([0, 0]), jnp.array([GRID_W - 1, GRID_H - 1]))
    r = REWARD_TABLES[task_id, new[..., 0], new[..., 1]]
    return new, r


def rollout(key, qnet_fn, task_id: int, *, steps: int = 20,
            epsilon: float = 0.1, batch: int = 1):
    """ε-greedy episode(s) from the common entry point.

    qnet_fn: (state (B, 40)) -> q-values (B, 4). Returns dict of
    (B, steps) arrays: states (B, steps, 40), actions, rewards, next_states.
    The paper's E_ik is exactly this: 20 consecutive motions.
    """
    pos0 = jnp.broadcast_to(jnp.asarray(ENTRY, jnp.int32), (batch, 2))

    def body(carry, k):
        pos = carry
        s = one_hot_state(pos)
        q = qnet_fn(s)
        ka, ke = jax.random.split(k)
        greedy = jnp.argmax(q, axis=-1)
        rand = jax.random.randint(ka, (batch,), 0, NUM_ACTIONS)
        explore = jax.random.uniform(ke, (batch,)) < epsilon
        a = jnp.where(explore, rand, greedy).astype(jnp.int32)
        new, r = jax.vmap(lambda p, aa: step(p, aa, task_id))(pos, a)
        return new, (s, a, r, one_hot_state(new))

    keys = jax.random.split(key, steps)
    _, (s, a, r, s2) = jax.lax.scan(body, pos0, keys)
    return {
        "state": s.swapaxes(0, 1),        # (B, steps, 40)
        "action": a.swapaxes(0, 1),
        "reward": r.swapaxes(0, 1),
        "next_state": s2.swapaxes(0, 1),
    }


def running_reward(rewards, nu: float = 0.99):
    """The paper's accuracy indicator R = Σ_h ν^h r_h (per episode)."""
    H = rewards.shape[-1]
    disc = nu ** jnp.arange(H)
    return jnp.sum(rewards * disc, axis=-1)


def greedy_running_reward(key, qnet_fn, task_id: int, *, steps: int = 20,
                          episodes: int = 4, nu: float = 0.99):
    """Evaluate a policy: mean running reward of greedy (ε=0) episodes."""
    data = rollout(key, qnet_fn, task_id, steps=steps, epsilon=0.0,
                   batch=episodes)
    return jnp.mean(running_reward(data["reward"], nu))
