from repro.rl import gridworld, dqn
