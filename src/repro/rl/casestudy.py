"""The paper's Sect. IV case study, wired end-to-end:

* M = 6 trajectory tasks, 2-robot clusters (ClusterNetwork);
* MAML meta-training on Q = 3 tasks {τ1, τ2, τ6} (Fig. 2(c)) at the
  "data center";
* per-cluster decentralized FL (Eq. 6) adaptation measuring t_i = rounds
  to reach the running-reward target;
* energy accounting with the paper-calibrated constants.

Experience follows the paper's Sect. IV-A budget: each robot gathers ONE
20-motion ε-greedy episode per round (ε = 0.1, b(E_ik) = 20 consecutive
motions) and takes B_i = 20 local SGD minibatch steps on it. The ε-greedy
behaviour is wrapped around the agent's own current Q — this is exactly
why a good meta-initialization cuts t_i: it walks on-trajectory from
round one, while a random init explores blindly. CHUNKS of ``chunk``
protocol rounds (sampling + local SGD + consensus + greedy evaluation,
each) compile into ONE ``lax.scan`` XLA program; the host checks the
per-round reached-target flags once per chunk and recovers the exact t_i
from the in-scan reached mask (a ``lax.cond`` freezes the population
after the hit), which is what makes Monte-Carlo sweeps over t0 tractable
on CPU — O(rounds/chunk) dispatches and syncs instead of O(rounds).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field, replace
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro import comms
from repro.core import energy, maml, scanloop
from repro.core import topology as topo_lib
from repro.core.engine import AsyncState, ConsensusEngine, where_active
from repro.core.multitask import ClusterNetwork
from repro.core.protocol import ProtocolResult
from repro.models import dqn as qmodel
from repro.rl import dqn as dqnrl
from repro.rl import gridworld as gw

META_TASKS = (0, 1, 5)        # {τ1, τ2, τ6} of Fig. 2(c)
R_TARGET = 100.0              # running-reward target (paper: R = 50 in its
                              # own reward units; ours rescale — DESIGN.md §7)


def behaviour_rollout(key, task_id: int, *, steps: int = 20,
                      batch: int = 8):
    """Random-walk behaviour policy (ε = 1), task-dependent rewards only."""
    pos0 = jnp.broadcast_to(jnp.asarray(gw.ENTRY, jnp.int32), (batch, 2))

    def body(pos, k):
        a = jax.random.randint(k, (batch,), 0, gw.NUM_ACTIONS)
        s = gw.one_hot_state(pos)
        new, r = jax.vmap(lambda p, aa: gw.step(p, aa, task_id))(pos, a)
        return new, (s, a, r, gw.one_hot_state(new))

    keys = jax.random.split(key, steps)
    _, (s, a, r, s2) = jax.lax.scan(body, pos0, keys)
    return {"state": s.reshape(-1, gw.NUM_CELLS),
            "action": a.reshape(-1),
            "reward": r.reshape(-1),
            "next_state": s2.reshape(-1, gw.NUM_CELLS)}


def sample_td_batches(key, task_id: int, n_batches: int, *,
                      batch_size: int = 64, episodes: int = 16):
    """(n_batches, batch_size, ...) TD transitions, random behaviour."""
    k1, k2 = jax.random.split(key)
    data = behaviour_rollout(k1, task_id, batch=episodes)
    N = data["state"].shape[0]
    idx = jax.random.randint(k2, (n_batches, batch_size), 0, N)
    return jax.tree.map(lambda x: x[idx], data)


def sample_episode_batches(key, params, cfg, task_id: int, n_batches: int,
                           *, batch_size: int = 16, epsilon: float = 0.1,
                           episodes: int = 1):
    """The paper's per-round data: ``episodes`` ε-greedy 20-motion episodes
    collected with the CURRENT Q-network, resampled into B_i minibatches."""
    k1, k2 = jax.random.split(key)
    qfn = lambda s: qmodel.forward(params, cfg, s)[0]
    data = gw.rollout(k1, qfn, task_id, steps=20, epsilon=epsilon,
                      batch=episodes)
    flat = jax.tree.map(lambda x: x.reshape((-1,) + x.shape[2:]), data)
    N = flat["state"].shape[0]
    idx = jax.random.randint(k2, (n_batches, batch_size), 0, N)
    return jax.tree.map(lambda x: x[idx], flat)


def _clipped_sgd_steps(loss_fn, params, batches, lr: float,
                       clip: float = 5.0):
    def one(p, b):
        g = jax.grad(loss_fn)(p, b)
        gn = jnp.sqrt(sum(jnp.sum(jnp.square(x)) for x in jax.tree.leaves(g)))
        scale = jnp.minimum(1.0, clip / jnp.maximum(gn, 1e-9))
        p = jax.tree.map(lambda w, gg: w - lr * scale * gg, p, g)
        return p, None

    p, _ = jax.lax.scan(one, params, batches)
    return p


@dataclass
class CaseStudy:
    """Fast, fully-jitted driver for the Fig. 3 / Fig. 4 experiments."""

    cfg: object = None
    inner_lr: float = 0.01
    outer_lr: float = 0.005
    fl_lr: float = 0.01
    inner_steps: int = 5
    fl_local_steps: int = 20       # B_i of Table I
    epsilon: float = 0.1           # Sect. IV-A exploration
    first_order: bool = True
    r_target: float = R_TARGET
    energy_params: object = None
    #: model-exchange codec spec (e.g. "int8", "int4", "topk:0.05") — the
    #: cluster's sidelink messages are sent AND Eq.-(11)-priced in this
    #: wire format (error feedback applied to lossy codecs), so the
    #: Fig.-3 energy comparison reruns at any compression level
    codec: object = None
    #: per-round link-failure probability (fading / contention — the
    #: paper's t_i is then MEASURED under a time-varying graph: each
    #: cluster engine carries a ``GraphProcess.dropout`` whose per-round
    #: survival masks are generated IN-SCAN, and the Eq.-(11) comm term
    #: is billed post hoc — over exactly the rounds used — by replaying
    #: the bit-identical host :func:`repro.core.topology.dropout` stream)
    dropout_p: float = 0.0
    dropout_seed: int = 0
    #: optional :class:`repro.core.topology.AgentProcess` — per-round
    #: per-AGENT availability (duty cycles, heavy-tail stragglers,
    #: arrivals/departures). Each task's cluster engine runs ASYNC with
    #: the process reseeded at ``seed + task_id`` (same fleet
    #: heterogeneity, independent sleep realizations per task):
    #: sleeping robots freeze (no local steps, no wires, codec
    #: residuals hold), neighbours mix their frozen last-published
    #: params at ``staleness_decay ** age`` until ``age > tau``, and
    #: ``last_adapt_comm_joules`` bills only DELIVERED wires by
    #: replaying the bit-identical host availability stream.
    availability: object = None
    #: hard staleness bound τ in rounds (async only; None = ∞)
    tau: object = None
    #: λ ∈ (0, 1]: stale lanes mix at λ^age (1.0 = lockstep-exact)
    staleness_decay: float = 1.0
    #: consensus execution plan for the per-cluster Eq.-(6) engine:
    #: "auto" rides the engine's normal selection (the 2-robot clusters
    #: sit far below the sparse-gather floor, so auto keeps them on
    #: dense-xla), or force any plan — ALL of them, "distributed"
    #: included, support dropout_p > 0 via in-scan per-edge survival
    #: draws (the distributed plan masks slots of its fixed ppermute
    #: schedule superset with a traced σ operand).
    plan: str = "auto"
    #: protocol rounds per compiled program: both stages run inside
    #: chunked ``lax.scan`` programs, so the host syncs (the per-round
    #: reached flags / meta losses) once per CHUNK instead of once per
    #: round — t0 and t_i trajectories are bit-identical to ``chunk=1``
    #: (the per-round host loop), the Monte-Carlo sweeps just stop
    #: paying O(rounds) dispatches. Dropout rounds generate each
    #: round's surviving graph inside the scan from the folded
    #: process key (zero host-side per-round graph prefetch).
    chunk: int = 8
    #: optional :class:`repro.telemetry.Telemetry`: meta rounds land as
    #: ``maml`` events, every task's FL rounds as ``fl`` events tagged
    #: ``task_id`` — one pure metrics row per round rides the scan
    #: outputs (buffered mode; streaming mode also emits each round
    #: live via ``jax.debug.callback`` from programs that are never
    #: cache-admitted). The per-round Eq.-(11) stream prices each
    #: round's ACTUAL surviving links with this case study's
    #: ``energy_params``, so ``telemetry.joules(task_id=i)`` equals the
    #: post-hoc ``last_adapt_comm_joules`` replay EXACTLY under
    #: dropout. t0/t_i/params are bit-identical with telemetry off,
    #: buffered, or streaming.
    telemetry: object = None

    def __post_init__(self):
        self.cfg = self.cfg or get_arch("paper-dqn")
        self.chunk = max(int(self.chunk), 1)
        self.energy_params = (self.energy_params
                              or energy.paper_calibrated("fig3"))
        self.codec = comms.resolve_codec(self.codec)
        cfg = self.cfg
        base_loss = dqnrl.make_loss_fn(cfg)

        def loss_fn(p, batch):
            return dqnrl.td_loss(p, cfg, batch,
                                 target_params=batch["target_params"])

        del base_loss
        self._loss_fn = loss_fn
        self.network = ClusterNetwork(num_tasks=gw.NUM_TASKS,
                                      devices_per_cluster=2,
                                      meta_task_ids=META_TASKS)
        # per-cluster communication graph: single source of truth for the
        # Eq.-(6) mixing below AND the Eq.-(11) pricing in ProtocolResult
        self.cluster_topology = self.network.cluster_topology()

        # ---- jitted meta round (Eqs. 3–5 over the Q tasks) ----------------
        def meta_round(params, key):
            ks = jax.random.split(key, 2 * len(META_TASKS))
            sup, qry = [], []
            for j, tid in enumerate(META_TASKS):
                s = sample_episode_batches(
                    ks[2 * j], params, self.cfg, tid, self.inner_steps,
                    epsilon=self.epsilon)
                q = jax.tree.map(lambda x: x[0], sample_episode_batches(
                    ks[2 * j + 1], params, self.cfg, tid, 1,
                    epsilon=self.epsilon))
                s["target_params"] = jax.tree.map(
                    lambda x: jnp.broadcast_to(
                        x[None], (self.inner_steps,) + x.shape), params)
                q["target_params"] = params
                sup.append(s)
                qry.append(q)
            stack = lambda bs: jax.tree.map(lambda *xs: jnp.stack(xs), *bs)
            return maml.maml_meta_step(
                loss_fn, params, stack(sup), stack(qry),
                inner_lr=self.inner_lr, outer_lr=self.outer_lr,
                inner_steps=self.inner_steps,
                first_order=self.first_order)

        # no donate_argnums: host drivers (benchmarks, tests) replay the
        # SAME params pytree across calls — donation would invalidate it
        meta_round = scanloop.donating_jit(meta_round)
        self._meta_round = meta_round

        # chunked stage-1 driver: `chunk` meta rounds per compiled scan
        # program, key split per round exactly like the host loop (same
        # PRNG stream, bit-identical history), losses synced per chunk
        def meta_body(carry, t):
            p, k = carry
            k, sk = jax.random.split(k)
            p, m = meta_round(p, sk)     # jit-of-jit inlines when traced
            if self.telemetry is not None and self.telemetry.streaming:
                jax.debug.callback(self._meta_stream_cb, t,
                                   m["meta_loss"], m["meta_grad_norm"],
                                   ordered=True)
            return (p, k), m["meta_loss"]

        self._meta_chunk = scanloop.donating_jit(
            lambda p, k, ts: jax.lax.scan(meta_body, (p, k), ts),
            donate_argnums=(0,))

        # ---- jitted FL round per task (Eq. 6 cluster) ---------------------
        # the engine plan is a knob ("auto" rides the normal selection —
        # the 2-robot cluster sits below the sparse-gather floor, so auto
        # resolves to dense-xla); with dropout_p > 0 each task gets its
        # own engine carrying a GraphProcess.dropout seeded at
        # dropout_seed + task_id, so every maskable plan generates that
        # round's surviving graph IN-SCAN (bit-identical to the host
        # topology.dropout stream by the shared fold-in convention)
        C = self.network.devices_per_cluster
        self._engines = {
            tid: ConsensusEngine(
                self.cluster_topology, codec=self.codec, plan=self.plan,
                graph=(topo_lib.GraphProcess.dropout(
                    self.dropout_p, seed=self.dropout_seed + tid)
                    if self.dropout_p > 0 else None),
                agents=self._agent_process(tid), tau=self.tau,
                staleness_decay=self.staleness_decay)
            for tid in range(gw.NUM_TASKS)}
        self.engine = self._engines[0]

        tel = self.telemetry
        if tel is not None:
            # recorders carry THIS case study's billing constants (not
            # the Telemetry default) so the stream reconciles exactly
            # with the post-hoc last_adapt_comm_joules replay
            self._recorders = {
                tid: tel.recorder_for(eng, self.energy_params)
                for tid, eng in self._engines.items()}
            if tel.streaming:
                self._stream_cbs = {
                    tid: tel.stream_cb(self._recorders[tid], "fl",
                                       {"task_id": tid})
                    for tid in self._engines}
                self._meta_stream_cb = tel.maml_stream_cb()

        def fl_round(task_id, stacked_params, codec_state, key, t,
                     survival=None, active=None):
            # split C+1 exactly as pre-codec (codec=None rounds keep
            # their RNG stream); the rounding key is folded out of band
            ks = jax.random.split(key, C + 1)
            target = jax.tree.map(lambda x: x[0], stacked_params)

            def local(p, k):
                b = sample_episode_batches(
                    k, p, self.cfg, task_id, self.fl_local_steps,
                    epsilon=self.epsilon)
                b["target_params"] = jax.tree.map(
                    lambda x: jnp.broadcast_to(
                        x[None], (self.fl_local_steps,) + x.shape), target)
                return _clipped_sgd_steps(loss_fn, p, b, self.fl_lr)

            new = jax.vmap(local)(stacked_params, jnp.stack(ks[:C]))
            if active is not None:
                # sleeping robots skip local SGD (bitwise hold)
                new = where_active(active, new, stacked_params)
            # survival= (telemetry shares one plan-shaped draw with the
            # metrics row) takes precedence over t= inside step;
            # identical ops either way
            mixed, new_state = self._engines[task_id].step(
                new, codec_state,
                None if self.codec is None
                else jax.random.fold_in(key, C + 1),
                t=t, survival=survival)
            if active is not None:
                # sleeping receivers don't mix; residuals hold too
                mixed = where_active(active, mixed, new)
                if new_state is not None:
                    old = (codec_state if codec_state is not None
                           else self._engines[task_id].init_state(new))
                    new_state = where_active(active, new_state, old)
            new, codec_state = mixed, new_state
            p0 = jax.tree.map(lambda x: x[0], new)
            R = dqnrl.evaluate(ks[C], p0, self.cfg, task_id, episodes=4)
            return new, codec_state, R

        self._fl_rounds = {
            tid: scanloop.donating_jit(functools.partial(fl_round, tid))
            for tid in range(gw.NUM_TASKS)}

        # chunked stage-2 driver: `chunk` FL rounds per compiled scan
        # program. Time-varying rounds derive their survival mask from
        # the scanned round index t IN-SCAN (no prefetched mix input),
        # a lax.cond freezes params/EF-state/key once the running
        # reward hits the target, and the per-round reached flags sync
        # to the host once per CHUNK — the exact t_i comes back out of
        # the reached mask, bit-identical to the per-round host loop.
        is_async = self.availability is not None

        def fl_body(task_id, limit, carry, t):
            def live(c):
                st, cs, k, _, ast = c
                k, sk = jax.random.split(k)
                if is_async:
                    # one availability draw per round, shared between
                    # the staleness weights, the per-robot freeze, and
                    # the telemetry row (billing only DELIVERED wires)
                    ar = self._engines[task_id].async_round(t, ast.age)
                    sv, act, sv_row = ar.weights, ar.act, ar.delivered
                else:
                    ar, act = None, None
                    sv = (self._engines[task_id].round_survival(t)
                          if tel is not None else None)
                    sv_row = sv
                st, cs, R = fl_round(task_id, st, cs, sk, t, sv, act)
                if is_async:
                    ast = AsyncState(
                        ast.clock + ar.act.astype(ast.clock.dtype),
                        ar.age)
                hit = R >= self.r_target
                ys = (hit, jnp.asarray(True), R)
                if tel is not None:
                    row = self._recorders[task_id].row(
                        st, sv_row, metric=R, reached=hit,
                        live=jnp.asarray(True), active=act,
                        age=(ar.age if is_async else None))
                    if tel.streaming:
                        jax.debug.callback(self._stream_cbs[task_id], t,
                                           row, ordered=True)
                    ys = ys + (row,)
                return (st, cs, k, hit, ast), ys

            def frozen(c):
                ys = (c[3], jnp.asarray(False), jnp.float32(0))
                if tel is not None:
                    row = self._recorders[task_id].frozen_row()
                    if tel.streaming:
                        jax.debug.callback(self._stream_cbs[task_id], t,
                                           row, ordered=True)
                    ys = ys + (row,)
                return c, ys

            pred = jnp.logical_and(jnp.logical_not(carry[3]), t < limit)
            return jax.lax.cond(pred, live, frozen, carry)

        def fl_chunk(task_id, stacked, codec_state, k, reached, ts,
                     limit, ast):
            # ast is None on lockstep runs (an empty pytree through the
            # scan carry) and the task's AsyncState on async runs —
            # clocks/ages persist ACROSS chunks like the params
            return jax.lax.scan(functools.partial(fl_body, task_id, limit),
                                (stacked, codec_state, k, reached, ast),
                                ts)

        self._fl_chunks = {
            tid: scanloop.donating_jit(functools.partial(fl_chunk, tid),
                                       donate_argnums=(0, 1))
            for tid in range(gw.NUM_TASKS)}

    # -- API ------------------------------------------------------------
    def _agent_process(self, task_id):
        """Per-task availability process: same kind/knobs as
        ``self.availability`` but reseeded at ``seed + task_id``, so each
        task cluster draws an independent (and host-replayable) churn
        stream — mirroring how dropout_seed shifts per task."""
        if self.availability is None:
            return None
        return replace(self.availability,
                       seed=self.availability.seed + task_id)

    def init_params(self, key):
        return qmodel.init(key, self.cfg)

    def meta_train(self, key, t0: int):
        """Stage 1: t0 meta rounds, ``self.chunk`` rounds per compiled
        program, meta-loss history synced once per chunk."""
        kinit, kdata = jax.random.split(key)
        # own(): _meta_chunk donates its params carry on donating backends
        params = scanloop.own(self.init_params(kinit))
        hist = []
        for start in range(0, t0, self.chunk):
            n = min(self.chunk, t0 - start)
            ts = jnp.arange(start, start + n, dtype=jnp.int32)
            (params, kdata), losses = self._meta_chunk(params, kdata, ts)
            if self.telemetry is not None:
                self.telemetry.record_maml_rounds(
                    {"meta_loss": losses}, start)
            hist.extend(float(x) for x in np.asarray(losses))
        return params, hist

    def adapt_task(self, key, task_id: int, init_params, *,
                   max_rounds: int = 400):
        """Decentralized FL adaptation of one task; measures t_i. With
        ``dropout_p > 0`` every round mixes over that round's SURVIVING
        links (deterministic in ``dropout_seed`` + task — the masks are
        generated INSIDE the compiled scan from the engine's folded
        graph key, zero host-side per-round prefetch) and the Eq.-(11)
        comm joules of the adaptation are accumulated per sent message
        in ``self.last_adapt_comm_joules``.

        Runs ``self.chunk`` rounds per compiled program: the per-round
        reached flags sync once per chunk and the in-scan freeze keeps
        params/EF-state pinned after the hit. The comm-joules bill is
        computed AFTER t_i is known, by replaying the bit-identical
        host :func:`repro.core.topology.dropout` stream over exactly
        the ``rounds_used`` rounds actually executed — frozen tail
        rounds (target hit mid-chunk, or chunk ∤ max_rounds) bill
        zero."""
        C = self.network.devices_per_cluster
        # own(): _fl_chunks donate the stacked/EF carries; the broadcast
        # must not alias the caller's init_params on donating backends
        stacked = scanloop.own(jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (C,) + x.shape),
            init_params))
        codec_state = (self.codec.init_state(stacked)
                       if self.codec is not None and self.codec.stateful
                       else None)
        hist = []
        rounds = max_rounds
        reached = jnp.asarray(False)
        step = self._fl_chunks[task_id]
        limit = jnp.int32(max_rounds)
        eng = self._engines[task_id]
        astate = (eng.init_async_state() if eng.agents is not None
                  else None)
        for start in range(0, max_rounds, self.chunk):
            ts = jnp.arange(start, start + self.chunk, dtype=jnp.int32)
            (stacked, codec_state, key, reached, astate), ys = step(
                stacked, codec_state, key, reached, ts, limit, astate)
            hits, live_mask, Rs = (np.asarray(y) for y in ys[:3])  # ONE sync
            if self.telemetry is not None:
                self.telemetry.record_rounds(
                    self._recorders[task_id], ys[3], start, driver="fl",
                    extra={"task_id": task_id})
            hist.extend(float(r) for r, v in zip(Rs, live_mask) if v)
            h = scanloop.first_hit(hits)
            if h is not None:
                rounds = start + h + 1
                break
        # Eq.-(11) bill over EXACTLY the rounds_used executed rounds:
        # static lockstep runs price rounds × the full graph; dropout
        # and/or availability runs replay the host streams
        # (bit-identical to the in-scan masks by the shared fold-in
        # convention) and price each round's DELIVERED wires only — a
        # wire bills iff its link survived AND both endpoints were
        # awake, matching ``AsyncRound.delivered`` and the telemetry
        # stream exactly (left-to-right float64 sum, same expression)
        proc = self._agent_process(task_id)
        if self.dropout_p > 0 or proc is not None:
            base = self.cluster_topology
            drops = (topo_lib.dropout(
                base, self.dropout_p,
                seed=self.dropout_seed + task_id, rounds=rounds)
                if self.dropout_p > 0 else [base] * rounds)
            acts = topo_lib.availability_stream(proc, base.K, rounds)
            total = 0.0
            for t_r, a in zip(drops, acts):
                m = (np.asarray(t_r.adjacency)
                     & np.asarray(a)[:, None] & np.asarray(a)[None, :])
                billed = topo_lib.Topology(
                    f"{base.name}~billed", m,
                    np.where(m, np.asarray(base.link_class),
                             topo_lib.NONE))
                total += billed.round_comm_joules(
                    self.energy_params, codec=self.codec)
            self.last_adapt_comm_joules = float(total)
        else:
            self.last_adapt_comm_joules = rounds * float(
                self.cluster_topology.round_comm_joules(
                    self.energy_params, codec=self.codec))
        return stacked, rounds, hist

    def run(self, key, t0: int, *, max_rounds: int = 400) -> ProtocolResult:
        kmeta, kfl = jax.random.split(key)
        meta_params, meta_hist = self.meta_train(kmeta, t0)
        rounds, hists, comm = [], [], []
        for tid in range(self.network.num_tasks):
            kfl, kt = jax.random.split(kfl)
            _, t_i, h = self.adapt_task(kt, tid, meta_params,
                                        max_rounds=max_rounds)
            rounds.append(t_i)
            hists.append(h)
            comm.append(self.last_adapt_comm_joules)
        return ProtocolResult(
            t0=t0, rounds_per_task=rounds, meta_history=meta_hist,
            fl_histories=hists, energy_params=self.energy_params,
            Q=self.network.Q, cluster_topology=self.cluster_topology,
            codec=self.codec,
            fl_comm_joules_measured=(comm if self.dropout_p > 0 else None))


def run_case_study(key=None, *, t0: int = 210, max_rounds: int = 400,
                   codec=None, dropout_p: float = 0.0,
                   plan: str = "auto"):
    """One Monte-Carlo run of the full Fig. 3 experiment (optionally with
    compressed sidelink exchange + codec-priced Eq.-(11) energy, and/or
    p-probability per-round link failures — on any maskable engine
    ``plan``, not just dense-xla)."""
    key = key if key is not None else jax.random.PRNGKey(0)
    return CaseStudy(codec=codec, dropout_p=dropout_p, plan=plan).run(
        key, t0, max_rounds=max_rounds)
