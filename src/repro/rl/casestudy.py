"""The paper's Sect. IV case study, wired end-to-end:

* M = 6 trajectory tasks, 2-robot clusters (ClusterNetwork);
* MAML meta-training on Q = 3 tasks {τ1, τ2, τ6} (Fig. 2(c)) at the
  "data center";
* per-cluster decentralized FL (Eq. 6) adaptation measuring t_i = rounds
  to reach the running-reward target;
* energy accounting with the paper-calibrated constants.

Experience follows the paper's Sect. IV-A budget: each robot gathers ONE
20-motion ε-greedy episode per round (ε = 0.1, b(E_ik) = 20 consecutive
motions) and takes B_i = 20 local SGD minibatch steps on it. The ε-greedy
behaviour is wrapped around the agent's own current Q — this is exactly
why a good meta-initialization cuts t_i: it walks on-trajectory from
round one, while a random init explores blindly. Every protocol round
(sampling + local SGD + consensus + greedy evaluation) is ONE jitted XLA
program; the host loop only checks the reached-target flag, which is what
makes Monte-Carlo sweeps over t0 tractable on CPU.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import List, Optional

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro import comms
from repro.core import energy, maml
from repro.core import topology as topo_lib
from repro.core.engine import ConsensusEngine
from repro.core.multitask import ClusterNetwork
from repro.core.protocol import ProtocolResult
from repro.models import dqn as qmodel
from repro.rl import dqn as dqnrl
from repro.rl import gridworld as gw

META_TASKS = (0, 1, 5)        # {τ1, τ2, τ6} of Fig. 2(c)
R_TARGET = 100.0              # running-reward target (paper: R = 50 in its
                              # own reward units; ours rescale — DESIGN.md §7)


def behaviour_rollout(key, task_id: int, *, steps: int = 20,
                      batch: int = 8):
    """Random-walk behaviour policy (ε = 1), task-dependent rewards only."""
    pos0 = jnp.broadcast_to(jnp.asarray(gw.ENTRY, jnp.int32), (batch, 2))

    def body(pos, k):
        a = jax.random.randint(k, (batch,), 0, gw.NUM_ACTIONS)
        s = gw.one_hot_state(pos)
        new, r = jax.vmap(lambda p, aa: gw.step(p, aa, task_id))(pos, a)
        return new, (s, a, r, gw.one_hot_state(new))

    keys = jax.random.split(key, steps)
    _, (s, a, r, s2) = jax.lax.scan(body, pos0, keys)
    return {"state": s.reshape(-1, gw.NUM_CELLS),
            "action": a.reshape(-1),
            "reward": r.reshape(-1),
            "next_state": s2.reshape(-1, gw.NUM_CELLS)}


def sample_td_batches(key, task_id: int, n_batches: int, *,
                      batch_size: int = 64, episodes: int = 16):
    """(n_batches, batch_size, ...) TD transitions, random behaviour."""
    k1, k2 = jax.random.split(key)
    data = behaviour_rollout(k1, task_id, batch=episodes)
    N = data["state"].shape[0]
    idx = jax.random.randint(k2, (n_batches, batch_size), 0, N)
    return jax.tree.map(lambda x: x[idx], data)


def sample_episode_batches(key, params, cfg, task_id: int, n_batches: int,
                           *, batch_size: int = 16, epsilon: float = 0.1,
                           episodes: int = 1):
    """The paper's per-round data: ``episodes`` ε-greedy 20-motion episodes
    collected with the CURRENT Q-network, resampled into B_i minibatches."""
    k1, k2 = jax.random.split(key)
    qfn = lambda s: qmodel.forward(params, cfg, s)[0]
    data = gw.rollout(k1, qfn, task_id, steps=20, epsilon=epsilon,
                      batch=episodes)
    flat = jax.tree.map(lambda x: x.reshape((-1,) + x.shape[2:]), data)
    N = flat["state"].shape[0]
    idx = jax.random.randint(k2, (n_batches, batch_size), 0, N)
    return jax.tree.map(lambda x: x[idx], flat)


def _clipped_sgd_steps(loss_fn, params, batches, lr: float,
                       clip: float = 5.0):
    def one(p, b):
        g = jax.grad(loss_fn)(p, b)
        gn = jnp.sqrt(sum(jnp.sum(jnp.square(x)) for x in jax.tree.leaves(g)))
        scale = jnp.minimum(1.0, clip / jnp.maximum(gn, 1e-9))
        p = jax.tree.map(lambda w, gg: w - lr * scale * gg, p, g)
        return p, None

    p, _ = jax.lax.scan(one, params, batches)
    return p


@dataclass
class CaseStudy:
    """Fast, fully-jitted driver for the Fig. 3 / Fig. 4 experiments."""

    cfg: object = None
    inner_lr: float = 0.01
    outer_lr: float = 0.005
    fl_lr: float = 0.01
    inner_steps: int = 5
    fl_local_steps: int = 20       # B_i of Table I
    epsilon: float = 0.1           # Sect. IV-A exploration
    first_order: bool = True
    r_target: float = R_TARGET
    energy_params: object = None
    #: model-exchange codec spec (e.g. "int8", "int4", "topk:0.05") — the
    #: cluster's sidelink messages are sent AND Eq.-(11)-priced in this
    #: wire format (error feedback applied to lossy codecs), so the
    #: Fig.-3 energy comparison reruns at any compression level
    codec: object = None
    #: per-round link-failure probability (fading / contention — the
    #: paper's t_i is then MEASURED under a time-varying graph from
    #: :func:`repro.core.topology.dropout`, and the Eq.-(11) comm term
    #: is accumulated only over messages actually sent)
    dropout_p: float = 0.0
    dropout_seed: int = 0

    def __post_init__(self):
        self.cfg = self.cfg or get_arch("paper-dqn")
        self.energy_params = (self.energy_params
                              or energy.paper_calibrated("fig3"))
        self.codec = comms.resolve_codec(self.codec)
        cfg = self.cfg
        base_loss = dqnrl.make_loss_fn(cfg)

        def loss_fn(p, batch):
            return dqnrl.td_loss(p, cfg, batch,
                                 target_params=batch["target_params"])

        del base_loss
        self._loss_fn = loss_fn
        self.network = ClusterNetwork(num_tasks=gw.NUM_TASKS,
                                      devices_per_cluster=2,
                                      meta_task_ids=META_TASKS)
        # per-cluster communication graph: single source of truth for the
        # Eq.-(6) mixing below AND the Eq.-(11) pricing in ProtocolResult
        self.cluster_topology = self.network.cluster_topology()

        # ---- jitted meta round (Eqs. 3–5 over the Q tasks) ----------------
        @jax.jit
        def meta_round(params, key):
            ks = jax.random.split(key, 2 * len(META_TASKS))
            sup, qry = [], []
            for j, tid in enumerate(META_TASKS):
                s = sample_episode_batches(
                    ks[2 * j], params, self.cfg, tid, self.inner_steps,
                    epsilon=self.epsilon)
                q = jax.tree.map(lambda x: x[0], sample_episode_batches(
                    ks[2 * j + 1], params, self.cfg, tid, 1,
                    epsilon=self.epsilon))
                s["target_params"] = jax.tree.map(
                    lambda x: jnp.broadcast_to(
                        x[None], (self.inner_steps,) + x.shape), params)
                q["target_params"] = params
                sup.append(s)
                qry.append(q)
            stack = lambda bs: jax.tree.map(lambda *xs: jnp.stack(xs), *bs)
            return maml.maml_meta_step(
                loss_fn, params, stack(sup), stack(qry),
                inner_lr=self.inner_lr, outer_lr=self.outer_lr,
                inner_steps=self.inner_steps,
                first_order=self.first_order)

        self._meta_round = meta_round

        # ---- jitted FL round per task (Eq. 6 cluster) ---------------------
        # dense-xla is the one engine plan that accepts a TRACED per-round
        # mix — which is how the dropout_p > 0 path swaps each round's
        # surviving graph in without recompiling (2-robot clusters have
        # only two distinct mixes, but the mix rides as a traced array)
        C = self.network.devices_per_cluster
        self.engine = ConsensusEngine(self.cluster_topology,
                                      codec=self.codec, plan="dense-xla")
        self._static_mix = jnp.asarray(
            self.cluster_topology.mixing(kind="paper"))

        def fl_round(task_id, stacked_params, codec_state, key, mix):
            # split C+1 exactly as pre-codec (codec=None rounds keep
            # their RNG stream); the rounding key is folded out of band
            ks = jax.random.split(key, C + 1)
            target = jax.tree.map(lambda x: x[0], stacked_params)

            def local(p, k):
                b = sample_episode_batches(
                    k, p, self.cfg, task_id, self.fl_local_steps,
                    epsilon=self.epsilon)
                b["target_params"] = jax.tree.map(
                    lambda x: jnp.broadcast_to(
                        x[None], (self.fl_local_steps,) + x.shape), target)
                return _clipped_sgd_steps(loss_fn, p, b, self.fl_lr)

            new = jax.vmap(local)(stacked_params, jnp.stack(ks[:C]))
            new, codec_state = self.engine.step(
                new, codec_state,
                None if self.codec is None
                else jax.random.fold_in(key, C + 1),
                mix=mix)
            p0 = jax.tree.map(lambda x: x[0], new)
            R = dqnrl.evaluate(ks[C], p0, self.cfg, task_id, episodes=4)
            return new, codec_state, R

        self._fl_rounds = {
            tid: jax.jit(functools.partial(fl_round, tid))
            for tid in range(gw.NUM_TASKS)}

    # -- API ------------------------------------------------------------
    def init_params(self, key):
        return qmodel.init(key, self.cfg)

    def meta_train(self, key, t0: int):
        kinit, kdata = jax.random.split(key)
        params = self.init_params(kinit)
        hist = []
        for t in range(t0):
            kdata, sk = jax.random.split(kdata)
            params, m = self._meta_round(params, sk)
            hist.append(float(m["meta_loss"]))
        return params, hist

    def adapt_task(self, key, task_id: int, init_params, *,
                   max_rounds: int = 400):
        """Decentralized FL adaptation of one task; measures t_i. With
        ``dropout_p > 0`` every round mixes over that round's SURVIVING
        links (deterministic in ``dropout_seed`` + task) and the Eq.-(11)
        comm joules of the adaptation are accumulated per sent message in
        ``self.last_adapt_comm_joules``."""
        C = self.network.devices_per_cluster
        stacked = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (C,) + x.shape), init_params)
        codec_state = (self.codec.init_state(stacked)
                       if self.codec is not None and self.codec.stateful
                       else None)
        topo_seq = (topo_lib.dropout(self.cluster_topology, self.dropout_p,
                                     seed=self.dropout_seed + task_id)
                    if self.dropout_p > 0 else None)
        hist = []
        rounds = max_rounds
        comm_joules = 0.0
        step = self._fl_rounds[task_id]
        for t in range(max_rounds):
            key, sk = jax.random.split(key)
            if topo_seq is None:
                mix_t = self._static_mix
                comm_joules += self.cluster_topology.round_comm_joules(
                    self.energy_params, codec=self.codec)
            else:
                topo_t = next(topo_seq)
                mix_t = jnp.asarray(topo_t.mixing(kind="paper"))
                comm_joules += topo_t.round_comm_joules(
                    self.energy_params, codec=self.codec)
            stacked, codec_state, R = step(stacked, codec_state, sk, mix_t)
            hist.append(float(R))
            if float(R) >= self.r_target:
                rounds = t + 1
                break
        self.last_adapt_comm_joules = comm_joules
        return stacked, rounds, hist

    def run(self, key, t0: int, *, max_rounds: int = 400) -> ProtocolResult:
        kmeta, kfl = jax.random.split(key)
        meta_params, meta_hist = self.meta_train(kmeta, t0)
        rounds, hists, comm = [], [], []
        for tid in range(self.network.num_tasks):
            kfl, kt = jax.random.split(kfl)
            _, t_i, h = self.adapt_task(kt, tid, meta_params,
                                        max_rounds=max_rounds)
            rounds.append(t_i)
            hists.append(h)
            comm.append(self.last_adapt_comm_joules)
        return ProtocolResult(
            t0=t0, rounds_per_task=rounds, meta_history=meta_hist,
            fl_histories=hists, energy_params=self.energy_params,
            Q=self.network.Q, cluster_topology=self.cluster_topology,
            codec=self.codec,
            fl_comm_joules_measured=(comm if self.dropout_p > 0 else None))


def run_case_study(key=None, *, t0: int = 210, max_rounds: int = 400,
                   codec=None, dropout_p: float = 0.0):
    """One Monte-Carlo run of the full Fig. 3 experiment (optionally with
    compressed sidelink exchange + codec-priced Eq.-(11) energy, and/or
    p-probability per-round link failures)."""
    key = key if key is not None else jax.random.PRNGKey(0)
    return CaseStudy(codec=codec, dropout_p=dropout_p).run(
        key, t0, max_rounds=max_rounds)
