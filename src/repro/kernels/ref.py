"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

These are also the XLA production paths used by the dry-run lowering
(interpret-mode Pallas unrolls its grid at trace time on CPU — DESIGN.md §5).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import attention_reference


def mha_reference(q, k, v, *, causal: bool = True, window: int = 0,
                  softcap: float = 0.0):
    """Oracle for kernels.flash_attention (O(S·T) einsum attention)."""
    return attention_reference(q, k, v, causal=causal, window=window,
                               softcap=softcap)


def rglru_scan_reference(log_a, b, h0=None):
    """Oracle for kernels.rglru_scan: sequential-in-time recurrence."""
    B, T, W = log_a.shape
    h = jnp.zeros((B, W), jnp.float32) if h0 is None else h0.astype(jnp.float32)

    def step(h, inp):
        la, bb = inp
        h = jnp.exp(la.astype(jnp.float32)) * h + bb.astype(jnp.float32)
        return h, h

    h_last, hs = jax.lax.scan(step, h, (log_a.swapaxes(0, 1),
                                        b.swapaxes(0, 1)))
    return hs.swapaxes(0, 1).astype(log_a.dtype), h_last


def consensus_update_reference(x, neighbors, sigmas):
    """Oracle for kernels.consensus_update (Eq. 6, one agent)."""
    xf = x.astype(jnp.float32)
    delta = (neighbors.astype(jnp.float32) - xf[None, :])
    upd = jnp.einsum("h,hn->n", sigmas.astype(jnp.float32), delta)
    return (xf + upd).astype(x.dtype)


def quant_consensus_update_reference(x, q_self, s_self, q_neighbors,
                                     s_neighbors, sigmas, qblock=None):
    """Oracle for kernels.quant_consensus_update: dequantize the int8
    wire models and mix (Eq. 6) around the agent's own DECODED model.

    ``qblock=None``: one scale per model (s_self scalar, s_neighbors
    (H,)). ``qblock=B``: per-channel block-wise scales — s_self
    (⌈N/B⌉,), s_neighbors (H, ⌈N/B⌉), scale j covering the flat run
    [j·B, (j+1)·B) exactly like ``IntCodec(bits, block=B)``."""
    xf = x.astype(jnp.float32)
    if qblock is None:
        xhat = q_self.astype(jnp.float32) * jnp.asarray(s_self, jnp.float32)
        nb = (q_neighbors.astype(jnp.float32)
              * s_neighbors.astype(jnp.float32)[:, None])
    else:
        N = x.shape[0]
        n_scales = -(-N // qblock)
        pad = n_scales * qblock - N

        def dequant(q, s):                       # q (..., N), s (..., nb)
            qp = jnp.pad(q.astype(jnp.float32),
                         [(0, 0)] * (q.ndim - 1) + [(0, pad)])
            rows = qp.reshape(q.shape[:-1] + (n_scales, qblock))
            y = (rows * s.astype(jnp.float32)[..., None]).reshape(
                q.shape[:-1] + (n_scales * qblock,))
            return y[..., :N]

        xhat = dequant(q_self, s_self)
        nb = dequant(q_neighbors, s_neighbors)
    upd = jnp.einsum("h,hn->n", sigmas.astype(jnp.float32),
                     nb - xhat[None, :])
    return (xf + upd).astype(x.dtype)
