"""Pallas TPU kernel for the RG-LRU linear recurrence (Griffin/
RecurrentGemma):   h_t = exp(log_a_t) · h_{t-1} + b_t.

TPU adaptation: the recurrence is serial in time but fully parallel over
(batch, channel). The grid is (batch, width_blocks, time_chunks) with the
time dimension innermost; the carry state lives in a VMEM scratch row that
persists across time-chunk grid steps (no HBM round-trip between chunks).
Inside a chunk the loop is a ``fori_loop`` over rows: each step is one
(1 × block_w) VPU fma — lanes carry the channels. Channel blocks of 512
lanes keep the VPU saturated; time chunks of 256 amortize grid overhead.

The pure-jnp oracle is ``repro.models.rglru.rglru_scan`` (associative
scan), which is also the XLA production path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_W = 512
DEFAULT_BLOCK_T = 256


def _rglru_kernel(log_a_ref, b_ref, h0_ref, o_ref, carry_ref, *,
                  block_t: int):
    it = pl.program_id(2)

    @pl.when(it == 0)
    def _init():
        carry_ref[...] = h0_ref[0].astype(jnp.float32)     # (1, bw) -> (bw,)

    def body(t, h):
        a = jnp.exp(log_a_ref[0, t, :].astype(jnp.float32))
        h = a * h + b_ref[0, t, :].astype(jnp.float32)
        o_ref[0, t, :] = h.astype(o_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, block_t, body, carry_ref[...])
    carry_ref[...] = h


def rglru_scan(log_a, b, h0=None, *, block_w: int = DEFAULT_BLOCK_W,
               block_t: int = DEFAULT_BLOCK_T, interpret: bool = False):
    """log_a, b: (B, T, W); h0: (B, W) or None. Returns (h, h_last)."""
    B, T, W = log_a.shape
    if h0 is None:
        h0 = jnp.zeros((B, W), jnp.float32)
    block_w = min(block_w, W)
    block_t = min(block_t, T)
    Wp = -(-W // block_w) * block_w
    Tp = -(-T // block_t) * block_t
    if Wp != W or Tp != T:
        log_a = jnp.pad(log_a, ((0, 0), (0, Tp - T), (0, Wp - W)))
        b = jnp.pad(b, ((0, 0), (0, Tp - T), (0, Wp - W)))
        h0 = jnp.pad(h0, ((0, 0), (0, Wp - W)))

    grid = (B, Wp // block_w, Tp // block_t)

    out = pl.pallas_call(
        functools.partial(_rglru_kernel, block_t=block_t),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_t, block_w), lambda bb, wv, tt: (bb, tt, wv)),
            pl.BlockSpec((1, block_t, block_w), lambda bb, wv, tt: (bb, tt, wv)),
            pl.BlockSpec((1, block_w), lambda bb, wv, tt: (bb, wv)),
        ],
        out_specs=pl.BlockSpec((1, block_t, block_w),
                               lambda bb, wv, tt: (bb, tt, wv)),
        out_shape=jax.ShapeDtypeStruct((B, Tp, Wp), log_a.dtype),
        scratch_shapes=[pltpu.VMEM((block_w,), jnp.float32)],
        interpret=interpret,
    )(log_a, b, h0)
    h = out[:, :T, :W]
    return h, h[:, -1].astype(jnp.float32)
