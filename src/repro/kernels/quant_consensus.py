"""Fused Pallas TPU kernel: int8 dequantize + Eq.-(6) consensus update.

    W_k  ←  W_k + Σ_h σ_{k,h} (s_h·q_h − s_k·q_k)

where q are per-tensor absmax-quantized int8 models (the sidelink wire
format of :mod:`repro.comms.codecs`) and s their f32 scales. The unfused
path materializes H dequantized parameter-sized f32 temporaries before
mixing; this kernel streams (H, block_n) int8 tiles through VMEM and
dequantizes INSIDE the combine, so HBM traffic for the neighbour models
is H·N bytes (int8) instead of 4·H·N (f32) plus the extra round trip —
the consensus round is purely memory-bound, so wire-dtype traffic is the
whole game.

Note the mixing recenters on the agent's OWN decoded model s_k·q_k (not
W_k): with a doubly-stochastic σ this keeps the population mean exact
under compression (the CHOCO-gossip trick), and it is what the
error-feedback wrapper assumes.

Grid: (N // block_n,). Oracle: ``ref.quant_consensus_update_reference``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_N = 64 * 1024


def _quant_consensus_kernel(x_ref, qs_ref, ss_ref, qn_ref, sn_ref, sig_ref,
                            o_ref, *, num_neighbors: int):
    x = x_ref[...].astype(jnp.float32)                     # (bn,)
    xhat = qs_ref[...].astype(jnp.float32) * ss_ref[0]     # own decoded model
    acc = jnp.zeros_like(x)
    for h in range(num_neighbors):
        nb = qn_ref[h].astype(jnp.float32) * sn_ref[h]     # fused dequant
        acc = acc + sig_ref[h] * (nb - xhat)
    o_ref[...] = (x + acc).astype(o_ref.dtype)


def quant_consensus_update(x, q_self, s_self, q_neighbors, s_neighbors,
                           sigmas, *, block_n: int = DEFAULT_BLOCK_N,
                           interpret: bool = False):
    """x: (N,) own full-precision params; q_self: (N,) int8 own quantized
    model with scalar scale s_self; q_neighbors: (H, N) int8 neighbour
    models with scales s_neighbors: (H,); sigmas: (H,) Eq.-(6) weights.

    Returns the updated (N,) params for one agent, one round.
    """
    N = x.shape[0]
    H = q_neighbors.shape[0]
    block_n = min(block_n, N)
    Np = -(-N // block_n) * block_n
    if Np != N:
        x = jnp.pad(x, (0, Np - N))
        q_self = jnp.pad(q_self, (0, Np - N))
        q_neighbors = jnp.pad(q_neighbors, ((0, 0), (0, Np - N)))

    out = pl.pallas_call(
        functools.partial(_quant_consensus_kernel, num_neighbors=H),
        grid=(Np // block_n,),
        in_specs=[
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((H, block_n), lambda i: (0, i)),
            pl.BlockSpec((H,), lambda i: (0,)),
            pl.BlockSpec((H,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_n,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((Np,), x.dtype),
        interpret=interpret,
    )(x, q_self, jnp.reshape(s_self, (1,)).astype(jnp.float32),
      q_neighbors, s_neighbors.astype(jnp.float32),
      sigmas.astype(jnp.float32))
    return out[:N]
