"""Fused Pallas TPU kernel: int8 dequantize + Eq.-(6) consensus update.

    W_k  ←  W_k + Σ_h σ_{k,h} (s_h·q_h − s_k·q_k)

where q are absmax-quantized int models in int8 lanes (the sidelink wire
format of :mod:`repro.comms.codecs`) and s their f32 scales — ONE scale
per tensor by default, or per-channel BLOCK-WISE scales (``qblock``:
each consecutive ``qblock``-long run of the flattened tensor carries its
own scale, the ``"int8:b64"`` wire). The unfused path materializes H
dequantized parameter-sized f32 temporaries before mixing; this kernel
streams (H, block_n) int8 tiles through VMEM and dequantizes INSIDE the
combine, so HBM traffic for the neighbour models is H·N bytes (int8)
instead of 4·H·N (f32) plus the extra round trip — the consensus round
is purely memory-bound, so wire-dtype traffic is the whole game. Block
scales ride along as one (H, block_n/qblock) f32 tile per grid step
(the kernel tile is snapped to a multiple of ``qblock`` so every scale
block lives wholly inside one tile).

Note the mixing recenters on the agent's OWN decoded model s_k·q_k (not
W_k): with a doubly-stochastic σ this keeps the population mean exact
under compression (the CHOCO-gossip trick), and it is what the
error-feedback wrapper assumes.

The σ weights are a RUNTIME operand (an (H,) f32 tile streamed per grid
step), not trace-time structure — which is what makes the fused gather
time-varying-graph capable: the engine's per-round survival masks
(:class:`repro.core.topology.GraphProcess`) feed a freshly renormalized
σ each round with faded-neighbour lanes at exactly 0.0, and a zero-σ
lane contributes ``0 · (nb − xhat) = 0`` to the combine — an exact
no-op, same as the padding lanes — so one compiled kernel serves every
surviving subgraph without rebuilding the neighbour indices.

Grid: (N // block_n,). Oracle: ``ref.quant_consensus_update_reference``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_N = 64 * 1024


def _quant_consensus_kernel(x_ref, qs_ref, ss_ref, qn_ref, sn_ref, sig_ref,
                            o_ref, *, num_neighbors: int):
    x = x_ref[...].astype(jnp.float32)                     # (bn,)
    xhat = qs_ref[...].astype(jnp.float32) * ss_ref[0]     # own decoded model
    acc = jnp.zeros_like(x)
    for h in range(num_neighbors):
        nb = qn_ref[h].astype(jnp.float32) * sn_ref[h]     # fused dequant
        acc = acc + sig_ref[h] * (nb - xhat)
    o_ref[...] = (x + acc).astype(o_ref.dtype)


def _quant_consensus_kernel_blocked(x_ref, qs_ref, ss_ref, qn_ref, sn_ref,
                                    sig_ref, o_ref, *, num_neighbors: int,
                                    qblock: int):
    x = x_ref[...].astype(jnp.float32)                     # (bn,)
    bn = x.shape[0]
    sb = bn // qblock

    def dequant(q, s):                 # q: (bn,) int8 lanes, s: (sb,) f32
        rows = q.astype(jnp.float32).reshape(sb, qblock)
        return (rows * s[:, None]).reshape(bn)

    xhat = dequant(qs_ref[...], ss_ref[...])
    acc = jnp.zeros_like(x)
    for h in range(num_neighbors):
        nb = dequant(qn_ref[h], sn_ref[h])                 # fused dequant
        acc = acc + sig_ref[h] * (nb - xhat)
    o_ref[...] = (x + acc).astype(o_ref.dtype)


def quant_consensus_update(x, q_self, s_self, q_neighbors, s_neighbors,
                           sigmas, *, block_n: int = DEFAULT_BLOCK_N,
                           interpret: bool = False, qblock=None):
    """x: (N,) own full-precision params; q_self: (N,) own quantized model
    (int8 lanes); q_neighbors: (H, N) neighbour models; sigmas: (H,)
    Eq.-(6) weights.

    Scale layout — ``qblock=None`` (per-tensor): s_self scalar,
    s_neighbors (H,). ``qblock=B`` (block-wise, the ``"int8:b64"``
    wire): s_self (⌈N/B⌉,), s_neighbors (H, ⌈N/B⌉) — scale j dequantizes
    the flat run [j·B, (j+1)·B), exactly the codec's blocking, and the
    dequant stays fused inside the combine. Returns the updated (N,)
    params for one agent, one round.
    """
    N = x.shape[0]
    H = q_neighbors.shape[0]
    if qblock is None:
        block_n = min(block_n, N)
        Np = -(-N // block_n) * block_n
        if Np != N:
            x = jnp.pad(x, (0, Np - N))
            q_self = jnp.pad(q_self, (0, Np - N))
            q_neighbors = jnp.pad(q_neighbors, ((0, 0), (0, Np - N)))
        kernel = functools.partial(_quant_consensus_kernel,
                                   num_neighbors=H)
        in_specs = [
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((H, block_n), lambda i: (0, i)),
            pl.BlockSpec((H,), lambda i: (0,)),
            pl.BlockSpec((H,), lambda i: (0,)),
        ]
        args = (x, q_self, jnp.reshape(s_self, (1,)).astype(jnp.float32),
                q_neighbors, s_neighbors.astype(jnp.float32),
                sigmas.astype(jnp.float32))
    else:
        qblock = int(qblock)
        # snap the tile to a whole number of scale blocks so each grid
        # step sees its scales in one contiguous (sb,) slice
        block_n = max(qblock, (min(block_n, -(-N // qblock) * qblock)
                               // qblock) * qblock)
        sb = block_n // qblock
        Np = -(-N // block_n) * block_n
        nb = Np // qblock                      # padded scale count
        n_scales = -(-N // qblock)             # the codec's scale count
        if Np != N:
            x = jnp.pad(x, (0, Np - N))
            q_self = jnp.pad(q_self, (0, Np - N))
            q_neighbors = jnp.pad(q_neighbors, ((0, 0), (0, Np - N)))
        if nb != n_scales:                     # padded q is 0: scale moot
            s_self = jnp.pad(s_self, (0, nb - n_scales))
            s_neighbors = jnp.pad(s_neighbors, ((0, 0), (0, nb - n_scales)))
        kernel = functools.partial(_quant_consensus_kernel_blocked,
                                   num_neighbors=H, qblock=qblock)
        in_specs = [
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((sb,), lambda i: (i,)),
            pl.BlockSpec((H, block_n), lambda i: (0, i)),
            pl.BlockSpec((H, sb), lambda i: (0, i)),
            pl.BlockSpec((H,), lambda i: (0,)),
        ]
        args = (x, q_self, s_self.astype(jnp.float32),
                q_neighbors, s_neighbors.astype(jnp.float32),
                sigmas.astype(jnp.float32))

    out = pl.pallas_call(
        kernel,
        grid=(Np // block_n,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block_n,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((Np,), x.dtype),
        interpret=interpret,
    )(*args)
    return out[:N]
