"""Pallas TPU kernel for the fused consensus update — paper Eq. (6):

    W_k  ←  W_k + Σ_h σ_{k,h} (W_h − W_k)

over flat parameter tiles. The XLA path materializes H neighbour deltas
(H extra parameter-sized temporaries); this kernel streams (H, block_n)
neighbour tiles through VMEM and applies the weighted combine in one pass
— HBM traffic is (H+2)·N instead of (3H+2)·N, which matters because the
consensus round is purely memory-bound (zero-FLOP roofline corner).

Grid: (N // block_n,). Tiles are (8, 128)-aligned via the caller.
Oracle: ``ref.consensus_update_reference``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_N = 64 * 1024


def _consensus_kernel(x_ref, nb_ref, sig_ref, o_ref, *, num_neighbors: int):
    x = x_ref[...].astype(jnp.float32)                     # (bn,)
    acc = jnp.zeros_like(x)
    for h in range(num_neighbors):
        sig = sig_ref[h]
        acc = acc + sig * (nb_ref[h].astype(jnp.float32) - x)
    o_ref[...] = (x + acc).astype(o_ref.dtype)


def consensus_update(x, neighbors, sigmas, *,
                     block_n: int = DEFAULT_BLOCK_N,
                     interpret: bool = False):
    """x: (N,) own flat params; neighbors: (H, N); sigmas: (H,) weights.

    Returns the updated (N,) params (Eq. 6, one round, one agent).
    """
    N = x.shape[0]
    H = neighbors.shape[0]
    block_n = min(block_n, N)
    Np = -(-N // block_n) * block_n
    if Np != N:
        x = jnp.pad(x, (0, Np - N))
        neighbors = jnp.pad(neighbors, ((0, 0), (0, Np - N)))

    out = pl.pallas_call(
        functools.partial(_consensus_kernel, num_neighbors=H),
        grid=(Np // block_n,),
        in_specs=[
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((H, block_n), lambda i: (0, i)),
            pl.BlockSpec((H,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_n,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((Np,), x.dtype),
        interpret=interpret,
    )(x, neighbors, sigmas.astype(jnp.float32))
    return out[:N]
