"""Jit'd public wrappers around the Pallas kernels with shape/dtype guards
and an ``impl`` switch:

    impl="pallas"     — TPU kernel (compile target)
    impl="interpret"  — kernel body executed in Python on CPU (validation)
    impl="xla"        — the pure-jnp oracle (CPU/dry-run production path)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import rglru_scan as _rg
from repro.kernels import consensus_update as _cu
from repro.kernels import quant_consensus as _qc
from repro.kernels import ref as _ref

_ALLOWED_DTYPES = (jnp.float32, jnp.bfloat16)


def _check_dtype(*arrays):
    for a in arrays:
        if a.dtype not in [jnp.dtype(d) for d in _ALLOWED_DTYPES]:
            raise TypeError(f"unsupported dtype {a.dtype}; use f32/bf16")


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "softcap", "block_q", "block_k", "impl"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    softcap: float = 0.0, block_q: int = 128,
                    block_k: int = 128, impl: str = "xla"):
    """Batched GQA attention. q (B,S,H,hd); k,v (B,T,K,hd); H % K == 0."""
    _check_dtype(q, k, v)
    if q.ndim != 4 or k.shape != v.shape or q.shape[3] != k.shape[3]:
        raise ValueError(f"bad shapes {q.shape} {k.shape} {v.shape}")
    if q.shape[2] % k.shape[2]:
        raise ValueError(f"H={q.shape[2]} not a multiple of K={k.shape[2]}")
    if impl == "xla":
        return _ref.mha_reference(q, k, v, causal=causal, window=window,
                                  softcap=softcap)
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               softcap=softcap, block_q=block_q,
                               block_k=block_k,
                               interpret=(impl == "interpret"))


@functools.partial(jax.jit, static_argnames=("block_w", "block_t", "impl"))
def rglru_scan(log_a, b, h0=None, *, block_w: int = 512, block_t: int = 256,
               impl: str = "xla"):
    """Linear recurrence h_t = exp(log_a_t)·h_{t-1} + b_t over (B, T, W)."""
    _check_dtype(log_a, b)
    if log_a.shape != b.shape or log_a.ndim != 3:
        raise ValueError(f"bad shapes {log_a.shape} {b.shape}")
    if impl == "xla":
        return _ref.rglru_scan_reference(log_a, b, h0)
    return _rg.rglru_scan(log_a, b, h0, block_w=block_w, block_t=block_t,
                          interpret=(impl == "interpret"))


@functools.partial(jax.jit, static_argnames=("block_n", "impl"))
def consensus_update(x, neighbors, sigmas, *, block_n: int = 64 * 1024,
                     impl: str = "xla"):
    """Fused Eq.-(6) update: x + Σ_h σ_h (neighbors_h − x), flat params."""
    _check_dtype(x, neighbors)
    if neighbors.ndim != 2 or neighbors.shape[1] != x.shape[0] \
            or sigmas.shape[0] != neighbors.shape[0]:
        raise ValueError(
            f"bad shapes {x.shape} {neighbors.shape} {sigmas.shape}")
    if impl == "xla":
        return _ref.consensus_update_reference(x, neighbors, sigmas)
    return _cu.consensus_update(x, neighbors, sigmas, block_n=block_n,
                                interpret=(impl == "interpret"))


@functools.partial(jax.jit, static_argnames=("block_n", "impl", "qblock"))
def quant_consensus_update(x, q_self, s_self, q_neighbors, s_neighbors,
                           sigmas, *, block_n: int = 64 * 1024,
                           impl: str = "xla", qblock=None):
    """Fused int-dequant + Eq.-(6) update around the agent's own decoded
    model: x + Σ_h σ_h (s_h·q_h − s_self·q_self). Wire models ride int8
    lanes. ``qblock=None``: one scale per model (s_self scalar,
    s_neighbors (H,)); ``qblock=B``: per-channel block-wise scales
    (``"int8:b64"`` wires) — s_self (⌈N/B⌉,), s_neighbors (H, ⌈N/B⌉)."""
    _check_dtype(x)
    if q_self.dtype != jnp.int8 or q_neighbors.dtype != jnp.int8:
        raise TypeError(
            f"wire models must be int8, got {q_self.dtype} "
            f"{q_neighbors.dtype}")
    if (q_neighbors.ndim != 2 or q_neighbors.shape[1] != x.shape[0]
            or q_self.shape != x.shape
            or s_neighbors.shape[0] != q_neighbors.shape[0]
            or sigmas.shape[0] != q_neighbors.shape[0]):
        raise ValueError(
            f"bad shapes {x.shape} {q_self.shape} {q_neighbors.shape} "
            f"{s_neighbors.shape} {sigmas.shape}")
    if qblock is not None:
        nb = -(-x.shape[0] // int(qblock))
        if s_self.shape != (nb,) or s_neighbors.shape[1:] != (nb,):
            raise ValueError(
                f"qblock={qblock} wants {nb} scales per model, got "
                f"{s_self.shape} {s_neighbors.shape}")
    if impl == "xla":
        return _ref.quant_consensus_update_reference(
            x, q_self, s_self, q_neighbors, s_neighbors, sigmas,
            qblock=qblock)
    return _qc.quant_consensus_update(
        x, q_self, s_self, q_neighbors, s_neighbors, sigmas,
        block_n=block_n, interpret=(impl == "interpret"), qblock=qblock)
