"""Pallas TPU flash attention: causal / sliding-window, GQA, online softmax.

TPU adaptation (DESIGN.md §2): q/k/v tiles live in VMEM via BlockSpec;
the MXU sees (block_q × head_dim) @ (head_dim × block_k) matmuls with
128-aligned dims; the softmax running max/sum and the f32 accumulator are
VMEM scratch persisting across the kv grid dimension (innermost, so each
(batch, head, q-block) revisits its accumulator across kv blocks —
the standard TPU flash schedule, no HBM round-trips for the accumulator).

Fully-masked kv blocks (beyond the causal frontier or behind the sliding
window) are skipped with ``pl.when`` — for SWA the skipped fraction makes
long-context cost O(window·T) rather than O(T²).

Validated in interpret mode against ``ref.mha_reference`` (this container
is CPU-only; TPU is the compile target).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG_INF = -2.0 ** 30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                 causal: bool, window: int, softcap: float, scale: float,
                 block_q: int, block_k: int, seq_len: int):
    """Grid: (batch, q_heads, num_q_blocks, num_kv_blocks); kv innermost."""
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = iq * block_q
    k_start = ik * block_k

    # block-level visibility:
    #   causal: need k_start <= q_end
    #   window: need k_end > q_start - window + 1
    visible = True
    if causal:
        visible = k_start <= q_start + block_q - 1
    if window > 0:
        visible = jnp.logical_and(
            visible, k_start + block_k - 1 > q_start - window)

    @pl.when(visible)
    def _compute():
        q = q_ref[0, :, 0, :].astype(jnp.float32) * scale   # (bq, hd)
        k = k_ref[0, :, 0, :].astype(jnp.float32)           # (bk, hd)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)             # (bq, bk)
        if softcap > 0:
            s = softcap * jnp.tanh(s / softcap)

        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 1)
        ok = k_pos < seq_len
        if causal:
            ok = jnp.logical_and(ok, k_pos <= q_pos)
        if window > 0:
            ok = jnp.logical_and(ok, k_pos > q_pos - window)
        s = jnp.where(ok, s, NEG_INF)

        m_prev = m_ref[...]                                  # (bq,)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(ok, p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, :, 0, :] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    softcap: float = 0.0,
                    block_q: int = DEFAULT_BLOCK_Q,
                    block_k: int = DEFAULT_BLOCK_K,
                    interpret: bool = False):
    """q: (B, S, H, hd); k, v: (B, T, K, hd) with H % K == 0.

    Returns (B, S, H, hd) in q.dtype. Exact (non-approximate) attention.
    """
    B, S, H, hd = q.shape
    T, K = k.shape[1], k.shape[2]
    assert H % K == 0, (H, K)
    scale = 1.0 / math.sqrt(hd)

    block_q = min(block_q, S)
    block_k = min(block_k, T)
    Sp = -(-S // block_q) * block_q
    Tp = -(-T // block_k) * block_k
    if Sp != S:
        q = jnp.pad(q, ((0, 0), (0, Sp - S), (0, 0), (0, 0)))
    if Tp != T:
        k = jnp.pad(k, ((0, 0), (0, Tp - T), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, Tp - T), (0, 0), (0, 0)))

    grid = (B, H, Sp // block_q, Tp // block_k)
    g = H // K

    kernel = functools.partial(
        _attn_kernel, causal=causal, window=window, softcap=softcap,
        scale=scale, block_q=block_q, block_k=block_k, seq_len=T)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, 1, hd),
                         lambda b, h, i, j: (b, i, h, 0)),
            pl.BlockSpec((1, block_k, 1, hd),
                         lambda b, h, i, j, g=g: (b, j, h // g, 0)),
            pl.BlockSpec((1, block_k, 1, hd),
                         lambda b, h, i, j, g=g: (b, j, h // g, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, 1, hd),
                               lambda b, h, i, j: (b, i, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Sp, H, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, hd), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :S]
