from repro.kernels.ops import (flash_attention, rglru_scan,
                               consensus_update, quant_consensus_update)
