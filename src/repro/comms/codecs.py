"""Model-exchange codecs — the "bytes knob" of the paper's Eq. (11).

A :class:`Codec` maps a parameter pytree to a wire representation and
back, and prices the wire exactly in bits:

    wire  = codec.encode(tree, key)      # key: stochastic rounding
    tree' = codec.decode(wire)
    codec.bits(wire)                     # EXACT wire size in bits
    codec.price_bits(full_bits)          # static Eq.-(11) pricing: the
                                         # wire bits of a model whose
                                         # full-precision size is b(W)

Implementations
---------------
* ``IdentityCodec``  — f32 passthrough (32 bit/param), the uncompressed
  baseline every sweep is measured against.
* ``Bf16Codec``      — bf16 cast (16 bit/param), the paper-era default.
* ``IntCodec(8|4)``  — absmax-scaled integer quantization (8 or 4
  bit/param + f32 scales: one per tensor by default, or per-channel
  block-wise scales via ``block=``/``"int8:b64"``) with optional
  stochastic rounding (pass a PRNG key to ``encode``) so the quantizer
  is unbiased.
* ``TopKCodec``      — magnitude top-k sparsification; the wire is
  (int32 index, f32 value) pairs, 64 bit per kept entry.
* ``ErrorFeedback``  — wrapper holding a per-round residual r: each round
  encodes ``x + r`` and accumulates the compression error back into r,
  so the time-average of the decoded stream is unbiased and compressed
  consensus (Eq. 6) still contracts to the uncompressed fixed point.

All leaf-level methods (``encode_leaf`` / ``decode_leaf``) are pure
traced jax functions — ``jax.vmap`` over a leading agent axis gives the
per-agent wires of one consensus round (per-(agent, tensor) scales).
Pytree-level ``encode``/``decode`` carry the treedef and leaf metadata
statically and are host-side conveniences.

``get_codec`` parses string specs (``"int8"``, ``"int4"``, ``"bf16"``,
``"topk:0.05"``, ``"topk:64"``, optional ``"+ef"`` suffix);
``resolve_codec`` additionally applies the error-feedback default used
by the consensus path.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, List, Optional, Sequence

import jax
import jax.numpy as jnp

F32_BITS = 32.0
SCALE_BITS = 32.0        # one f32 scale per quantized tensor
IDX_BITS = 32.0          # int32 index per kept top-k entry


@dataclass
class Wire:
    """A codec'd pytree: per-leaf payloads + static structure metadata."""

    codec: str
    payloads: List[Any]                    # per-leaf dicts of arrays
    treedef: Any
    leaves_meta: List[jax.ShapeDtypeStruct]

    def __iter__(self):                    # allow tuple-unpacking styles
        return iter((self.codec, self.payloads))


def _sds(x) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(jnp.shape(x), jnp.result_type(x))


def _stochastic_round(y, key):
    """floor(y + u), u ~ U[0, 1): unbiased rounding, E[round] = y."""
    if key is None:
        return jnp.round(y)
    u = jax.random.uniform(key, jnp.shape(y), jnp.float32)
    return jnp.floor(y + u)


class Codec:
    """Uniform model-exchange compression API (see module docstring)."""

    name: str = "codec"
    stateful: bool = False
    #: wire bits per parameter (None when size-dependent, e.g. absolute
    #: top-k) — drives the consensus auto dense-vs-sparse heuristic.
    bits_per_param: Optional[float] = None

    # -- leaf level (pure jax, vmappable) -----------------------------------
    def encode_leaf(self, x, key=None):
        raise NotImplementedError

    def decode_leaf(self, payload, like):
        """Reconstruct a tensor of ``like``'s shape/dtype from a payload."""
        raise NotImplementedError

    def leaf_bits(self, shape) -> float:
        """EXACT wire bits for one tensor of ``shape``."""
        raise NotImplementedError

    # -- pytree level -------------------------------------------------------
    def encode(self, tree, key=None) -> Wire:
        leaves, treedef = jax.tree.flatten(tree)
        keys = ([None] * len(leaves) if key is None
                else list(jax.random.split(key, max(len(leaves), 1))))
        payloads = [self.encode_leaf(x, k) for x, k in zip(leaves, keys)]
        return Wire(self.name, payloads, treedef,
                    [_sds(x) for x in leaves])

    def decode(self, wire: Wire):
        leaves = [self.decode_leaf(p, m)
                  for p, m in zip(wire.payloads, wire.leaves_meta)]
        return jax.tree.unflatten(wire.treedef, leaves)

    def bits(self, wire: Wire) -> float:
        """Exact wire size of one encoded model, in bits."""
        return float(sum(self.leaf_bits(m.shape)
                         for m in wire.leaves_meta))

    def model_bits(self, tree) -> float:
        """Exact wire bits this codec would use for ``tree`` (no encode)."""
        return float(sum(self.leaf_bits(jnp.shape(x))
                         for x in jax.tree.leaves(tree)))

    # -- static Eq.-(11) pricing -------------------------------------------
    def price_bits(self, full_bits: float,
                   ref_bits: float = F32_BITS) -> float:
        """Wire bits of a model whose FULL-precision size is ``full_bits``
        (b(W) of the paper, ``ref_bits`` per parameter). Per-tensor scale
        overhead is excluded — it is unknowable from a byte count alone
        and negligible for any real model; ``bits()`` is the exact form.
        """
        raise NotImplementedError

    def __repr__(self):
        return f"{type(self).__name__}({self.name!r})"


class IdentityCodec(Codec):
    """f32 passthrough — the uncompressed baseline."""

    name = "none"
    bits_per_param = F32_BITS

    def encode_leaf(self, x, key=None):
        return {"v": jnp.asarray(x, jnp.float32)}

    def decode_leaf(self, payload, like):
        return payload["v"].reshape(like.shape).astype(like.dtype)

    def leaf_bits(self, shape) -> float:
        return F32_BITS * math.prod(shape)

    def price_bits(self, full_bits, ref_bits=F32_BITS):
        return full_bits * F32_BITS / ref_bits


class Bf16Codec(Codec):
    """bf16 cast: 16 bit/param, ~3 decimal digits of mantissa."""

    name = "bf16"
    bits_per_param = 16.0

    def encode_leaf(self, x, key=None):
        return {"v": jnp.asarray(x).astype(jnp.bfloat16)}

    def decode_leaf(self, payload, like):
        return payload["v"].reshape(like.shape).astype(like.dtype)

    def leaf_bits(self, shape) -> float:
        return 16.0 * math.prod(shape)

    def price_bits(self, full_bits, ref_bits=F32_BITS):
        return full_bits * 16.0 / ref_bits


class IntCodec(Codec):
    """Absmax-scaled ``bits``-bit integer quantization.

    q = clip(round(x / s), ±qmax), s = absmax / qmax; the wire carries q
    (``bits`` bits each — int4 values are stored in int8 lanes on-device
    but PRICED at 4 bits, i.e. two values per wire byte) plus the f32
    scales. With a PRNG key the rounding is stochastic (unbiased);
    without, round-to-nearest.

    ``block`` selects the scale granularity: ``None`` (default) keeps ONE
    scale per tensor; an integer quantizes each consecutive ``block``-long
    run of the flattened tensor with its own absmax scale (per-channel /
    block-wise quantization). Block scales bound the round-trip error by
    the LOCAL absmax — a tensor mixing large and small channels loses
    ~absmax(tensor)/qmax/2 per entry under one global scale but only
    ~absmax(block)/qmax/2 with block scales — at SCALE_BITS·⌈n/block⌉
    extra wire bits, which ``leaf_bits``/``price_bits`` account exactly.
    """

    def __init__(self, bits: int, block: Optional[int] = None):
        if bits not in (4, 8):
            raise ValueError(f"IntCodec supports 4/8 bits, got {bits}")
        if block is not None and block < 1:
            raise ValueError(f"block size must be >= 1, got {block}")
        self.qbits = bits
        self.qmax = float(2 ** (bits - 1) - 1)
        self.block = block
        self.name = f"int{bits}" + ("" if block is None else f":b{block}")
        self.bits_per_param = float(bits)

    def _blocked(self, flat):
        """(nb, block) view of a flat tensor, zero-padded on the right."""
        n = flat.shape[0]
        nb = -(-n // self.block)
        pad = nb * self.block - n
        if pad:
            flat = jnp.pad(flat, (0, pad))
        return flat.reshape(nb, self.block)

    def encode_leaf(self, x, key=None):
        xf = jnp.asarray(x, jnp.float32)
        if self.block is None:
            absmax = jnp.max(jnp.abs(xf))
            scale = jnp.maximum(absmax, 1e-12) / self.qmax
            q = _stochastic_round(xf / scale, key)
            q = jnp.clip(q, -self.qmax, self.qmax).astype(jnp.int8)
            return {"q": q, "scale": scale.astype(jnp.float32)}
        n = xf.size
        rows = self._blocked(xf.ravel())
        absmax = jnp.max(jnp.abs(rows), axis=1)
        scale = jnp.maximum(absmax, 1e-12) / self.qmax
        q = _stochastic_round(rows / scale[:, None], key)
        q = jnp.clip(q, -self.qmax, self.qmax).astype(jnp.int8)
        return {"q": q.ravel()[:n].reshape(xf.shape),
                "scale": scale.astype(jnp.float32)}

    def decode_leaf(self, payload, like):
        if self.block is None:
            y = payload["q"].astype(jnp.float32) * payload["scale"]
            return y.reshape(like.shape).astype(like.dtype)
        n = math.prod(like.shape)
        rows = self._blocked(payload["q"].ravel().astype(jnp.float32))
        y = (rows * payload["scale"][:, None]).ravel()[:n]
        return y.reshape(like.shape).astype(like.dtype)

    def _num_scales(self, n: int) -> int:
        return 1 if self.block is None else -(-n // self.block)

    def leaf_bits(self, shape) -> float:
        n = math.prod(shape)
        return float(self.qbits) * n + SCALE_BITS * self._num_scales(n)

    def price_bits(self, full_bits, ref_bits=F32_BITS):
        wire = full_bits * self.qbits / ref_bits
        if self.block is not None:
            # block scales are NOT negligible at small blocks: price them
            # (treating the model as one flat tensor, like TopKCodec)
            wire += SCALE_BITS * math.ceil(full_bits / ref_bits / self.block)
        return wire


class TopKCodec(Codec):
    """Magnitude top-k sparsification over each flattened tensor.

    ``k``: fraction of entries kept when < 1, absolute count otherwise.
    Wire per tensor: k' (int32 idx, f32 value) pairs, 64 bits each, where
    k' = max(1, round(k·n)) (fraction) or min(k, n) (absolute).
    """

    def __init__(self, k: float = 0.05):
        if k <= 0:
            raise ValueError(f"top-k needs k > 0, got {k}")
        self.k = k
        kname = f"{k:g}"
        self.name = f"topk:{kname}"
        # fractional k has a well-defined per-param wire cost; absolute k
        # depends on the tensor size, so leave it None (assume dense).
        self.bits_per_param = k * (IDX_BITS + F32_BITS) if k < 1 else None

    def _k_of(self, n: int) -> int:
        if self.k < 1:
            return max(1, int(round(self.k * n)))
        return min(int(self.k), n)

    def encode_leaf(self, x, key=None):
        flat = jnp.asarray(x, jnp.float32).ravel()
        k = self._k_of(flat.shape[0])
        _, idx = jax.lax.top_k(jnp.abs(flat), k)
        return {"idx": idx.astype(jnp.int32), "val": flat[idx]}

    def decode_leaf(self, payload, like):
        n = math.prod(like.shape)
        y = jnp.zeros((n,), jnp.float32
                      ).at[payload["idx"]].set(payload["val"])
        return y.reshape(like.shape).astype(like.dtype)

    def leaf_bits(self, shape) -> float:
        return self._k_of(math.prod(shape)) * (IDX_BITS + F32_BITS)

    def price_bits(self, full_bits, ref_bits=F32_BITS):
        """Static pricing treats the model as ONE flat tensor: fractional
        k is exact up to the per-leaf max(1, round(...)) granularity, but
        ABSOLUTE k under-counts a multi-tensor model (the real wire keeps
        k entries PER TENSOR — use ``model_bits(tree)`` / ``bits(wire)``
        for the exact figure, or fractional k for pricing sweeps)."""
        n = full_bits / ref_bits
        if self.k < 1:
            kept = max(1.0, round(self.k * n))
        else:
            kept = min(float(self.k), n)
        return kept * (IDX_BITS + F32_BITS)


class ErrorFeedback(Codec):
    """Residual-accumulating wrapper: encode(x + r), r ← (x + r) − x̂.

    The compression error of every round is fed back into the next
    round's message, so the decoded stream is unbiased over time and
    compressed consensus keeps the uncompressed fixed point (the
    standard EF-SGD / CHOCO argument). State is a pytree of f32
    residuals shaped like the model; thread it through
    ``encode_stateful``.
    """

    stateful = True

    def __init__(self, inner: Codec):
        if isinstance(inner, ErrorFeedback):
            raise ValueError("cannot nest ErrorFeedback")
        self.inner = inner
        self.name = inner.name + "+ef"
        self.bits_per_param = inner.bits_per_param

    # -- state --------------------------------------------------------------
    def init_state(self, tree):
        return jax.tree.map(
            lambda x: jnp.zeros(jnp.shape(x), jnp.float32), tree)

    def init_leaf_state(self, x):
        return jnp.zeros(jnp.shape(x), jnp.float32)

    # -- leaf level ---------------------------------------------------------
    def encode_leaf_stateful(self, x, residual, key=None):
        """Returns (payload, decoded x̂ as f32, new residual)."""
        m = jnp.asarray(x, jnp.float32) + residual
        payload = self.inner.encode_leaf(m, key)
        xhat = self.inner.decode_leaf(
            payload, jax.ShapeDtypeStruct(jnp.shape(x), jnp.float32))
        return payload, xhat, m - xhat

    def encode_leaf(self, x, key=None):       # stateless fallback (r = 0)
        return self.inner.encode_leaf(x, key)

    def decode_leaf(self, payload, like):
        return self.inner.decode_leaf(payload, like)

    def leaf_bits(self, shape) -> float:
        return self.inner.leaf_bits(shape)

    # -- pytree level -------------------------------------------------------
    def encode_stateful(self, tree, state, key=None):
        """(wire, new_state) — the round's message and carried residual."""
        leaves, treedef = jax.tree.flatten(tree)
        res = jax.tree.unflatten(treedef, jax.tree.leaves(state)) \
            if state is not None else self.init_state(tree)
        res_leaves = jax.tree.leaves(res)
        keys = ([None] * len(leaves) if key is None
                else list(jax.random.split(key, max(len(leaves), 1))))
        payloads, new_res = [], []
        for x, r, k in zip(leaves, res_leaves, keys):
            p, _, nr = self.encode_leaf_stateful(x, r, k)
            payloads.append(p)
            new_res.append(nr)
        wire = Wire(self.name, payloads, treedef,
                    [_sds(x) for x in leaves])
        return wire, jax.tree.unflatten(treedef, new_res)

    def price_bits(self, full_bits, ref_bits=F32_BITS):
        return self.inner.price_bits(full_bits, ref_bits)


# ---------------------------------------------------------------------------
# registry / spec parsing
# ---------------------------------------------------------------------------

#: canonical sweep order for benchmarks: uncompressed baseline first.
CODECS = ("none", "bf16", "int8", "int4", "topk:0.05")


def get_codec(spec) -> Optional[Codec]:
    """Parse a codec spec: a Codec (returned as-is), None, or a string —
    ``none|f32|identity``, ``bf16``, ``int8``, ``int4`` (optionally with
    block-wise scales: ``int8:b64``), ``topk[:k]``, each with an optional
    ``+ef`` error-feedback suffix."""
    if spec is None or isinstance(spec, Codec):
        return spec
    if not isinstance(spec, str):
        raise TypeError(f"codec spec must be str/Codec/None, got {spec!r}")
    name = spec.strip().lower()
    ef = name.endswith("+ef")
    if ef:
        name = name[:-3]
    if name in ("none", "f32", "identity"):
        codec = IdentityCodec()
    elif name == "bf16":
        codec = Bf16Codec()
    elif name in ("int8", "int4") or name.startswith(("int8:", "int4:")):
        bits = int(name[3])
        _, _, arg = name.partition(":")
        block = int(arg.lstrip("b")) if arg else None
        codec = IntCodec(bits, block=block)
    elif name.startswith("topk"):
        _, _, arg = name.partition(":")
        codec = TopKCodec(float(arg)) if arg else TopKCodec()
    else:
        raise ValueError(f"unknown codec {spec!r}; "
                         f"choose from {CODECS} (+ optional '+ef')")
    return ErrorFeedback(codec) if ef else codec


def resolve_codec(spec, error_feedback: bool = True) -> Optional[Codec]:
    """``get_codec`` plus the consensus-path default: wrap lossy codecs in
    :class:`ErrorFeedback` unless already wrapped or disabled. The
    identity codec is never wrapped (its residual is identically 0)."""
    codec = get_codec(spec)
    if codec is None or isinstance(codec, (ErrorFeedback, IdentityCodec)):
        return codec
    return ErrorFeedback(codec) if error_feedback else codec
