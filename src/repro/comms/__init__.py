"""Compressed model-exchange subsystem.

Everything a consensus round (Eq. 6) or a federated exchange sends over
the air goes through a :class:`~repro.comms.codecs.Codec`: ``encode``
turns a parameter pytree into a wire representation, ``decode`` turns it
back, and ``bits`` prices the wire EXACTLY — which is what makes the
paper's Eq.-(11) communication energy a function of the codec instead of
a constant b(W). See :mod:`repro.comms.codecs` for the codec zoo
(bf16 cast, stochastic-rounding int8/int4, top-k sparsification) and the
error-feedback wrapper that keeps compressed consensus convergent.
"""
from repro.comms.codecs import (           # noqa: F401
    CODECS,
    Codec,
    Bf16Codec,
    ErrorFeedback,
    IdentityCodec,
    IntCodec,
    TopKCodec,
    get_codec,
    resolve_codec,
)
from repro.comms.select import (       # noqa: F401
    link_efficiencies,
    select_codec,
)
