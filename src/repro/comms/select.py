"""Adaptive codec selection from link quality — the "auto" wire format.

The Eq.-(11) cost of a consensus round is (wire bits) × (J/bit of the
links that carry them), so the right compression level is a function of
link EFFICIENCY: on cheap links (high bit/J) a wide wire costs little and
keeps the quantization error floor low; on expensive links the bits
dominate the energy balance and a narrow wire wins even after paying the
extra rounds the compression error induces (Elgabli et al.,
arXiv:2105.14772 make the same tradeoff the optimization variable).

``select_codec`` inspects the topology's link classes (and any per-edge
``edge_efficiency`` overrides) against two thresholds and picks the wire
for the WORST link the round has to cross — the graph's bottleneck link
sets the energy bill, so it sets the codec:

    eff >= bf16_min_bit_per_joule   ->  bf16   (cheap links, wide wire)
    eff >= int8_min_bit_per_joule   ->  int8
    otherwise                       ->  int4   (expensive links)

``train_federated --codec auto`` routes through this helper.
"""
from __future__ import annotations

from typing import Optional

from repro.comms.codecs import Codec, resolve_codec

#: bit/J thresholds: the paper-calibrated sidelink (4e6 bit/J) affords
#: bf16; its uplink/downlink (1.6e6) and Table I's raw 500 kbit/J land on
#: int8; an order-of-magnitude degraded link (< 0.5e6) drops to int4.
BF16_MIN_BIT_PER_JOULE = 2e6
INT8_MIN_BIT_PER_JOULE = 0.5e6


def link_efficiencies(topology, link_quality=None) -> dict:
    """bit/J of every link class PRESENT in ``topology`` (keyed SL/UL/DL),
    plus per-edge overrides' worst case under ``"edge"`` when set.

    ``link_quality``: an :class:`repro.core.energy.EnergyParams` (its
    E_SL/E_UL/E_DL, honouring the UL+γ·DL sidelink replacement), a dict
    ``{"SL": bit_per_joule, ...}``, or None for the paper calibration.
    """
    from repro.core import energy  # deferred: keep comms import-light
    from repro.core.topology import LINK_CLASS_NAMES

    if link_quality is None:
        link_quality = energy.paper_calibrated("fig3")
    if isinstance(link_quality, dict):
        effs = dict(link_quality)
    else:
        p = link_quality
        effs = {"SL": 1.0 / energy.sidelink_cost_per_bit(p),
                "UL": p.E_UL, "DL": p.E_DL}
    # class constants only price edges WITHOUT a per-edge override (that
    # is exactly round_comm_joules's fallback rule) — a class whose every
    # edge is overridden must not enter the bottleneck computation
    eff_mat = getattr(topology, "edge_efficiency", None)
    unset = (topology.adjacency if eff_mat is None
             else topology.adjacency & ~(eff_mat > 0))
    out = {}
    for cls_id, name in LINK_CLASS_NAMES.items():
        if not ((topology.link_class == cls_id) & unset).any():
            continue
        if name not in effs:
            raise ValueError(
                f"link_quality is missing an efficiency for class "
                f"{name!r}, which {topology.name!r} has links in")
        out[name] = effs[name]
    if eff_mat is not None:
        per_edge = eff_mat[topology.adjacency]
        per_edge = per_edge[per_edge > 0]
        if per_edge.size:
            out["edge"] = float(per_edge.min())
    return out


def select_codec(topology, link_quality=None, *,
                 error_feedback: bool = True,
                 bf16_min_bit_per_joule: float = BF16_MIN_BIT_PER_JOULE,
                 int8_min_bit_per_joule: float = INT8_MIN_BIT_PER_JOULE,
                 ) -> Optional[Codec]:
    """Pick the wire format for ``topology`` from its bottleneck link
    efficiency (see module docstring). Returns a resolved Codec (lossy
    picks carry the error-feedback wrapper unless disabled)."""
    effs = link_efficiencies(topology, link_quality)
    if not effs:                      # edgeless graph: nothing on the wire
        return None
    worst = min(effs.values())
    if worst >= bf16_min_bit_per_joule:
        spec = "bf16"
    elif worst >= int8_min_bit_per_joule:
        spec = "int8"
    else:
        spec = "int4"
    return resolve_codec(spec, error_feedback)
