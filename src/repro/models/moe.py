"""Mixture-of-Experts MLP (mixtral / qwen2-moe style).

Dispatch is capacity-based with *scatter/gather* routing (not the GShard
(N, E, Cap) one-hot einsum, whose dispatch tensor is O(N^2) at our token
counts): tokens are scatter-added into an (E, Cap, d) buffer at
(expert, position-in-expert) coordinates, expert MLPs run as one batched
einsum over the stacked expert weights, and results are gathered back and
combined with the router gates. Compiled FLOPs ≈ active-expert FLOPs ×
capacity_factor — the roofline sees what a production MoE would do.

Dropped tokens (overflow past capacity) contribute zero — the residual
stream carries them unchanged, the standard Switch/GShard behaviour.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import layers as L


def init_moe_mlp(key, cfg):
    assert cfg.moe is not None
    m = cfg.moe
    d, f = cfg.d_model, cfg.d_ff
    pd = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 6)
    E = m.num_experts
    p = {
        "router": L.dense_init(ks[0], (d, E), pd),
        "w_gate": L.dense_init(ks[1], (E, d, f), pd),
        "w_up": L.dense_init(ks[2], (E, d, f), pd),
        "w_down": L.dense_init(ks[3], (E, f, d), pd),
    }
    if m.num_shared_experts:
        sdff = m.shared_expert_d_ff or f
        p["shared"] = L.init_mlp(ks[4], cfg, d_ff=sdff)
        p["shared_gate"] = L.dense_init(ks[5], (d, 1), pd)
    return p


def moe_block(p, cfg, x, *, capacity: Optional[int] = None):
    """x: (B, S, d) -> (out (B, S, d), aux_loss scalar)."""
    m = cfg.moe
    dt = jnp.dtype(cfg.dtype)
    x = x.astype(dt)
    B, S, d = x.shape
    N = B * S
    E, k = m.num_experts, m.top_k
    xf = x.reshape(N, d)

    # ---- router ----------------------------------------------------------
    logits = (xf.astype(jnp.float32) @ p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                   # (N, E)
    gate_vals, topk_idx = jax.lax.top_k(logits, k)            # (N, k)
    gates = jax.nn.softmax(gate_vals, axis=-1)                # renorm over top-k

    # load-balancing aux loss (Switch): E * sum_e f_e * P_e
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(jax.nn.one_hot(topk_idx, E, dtype=jnp.float32).sum(1), axis=0) / k
    aux = m.router_aux_loss_coef * E * jnp.sum(me * ce)

    # ---- capacity + position-in-expert -----------------------------------
    cap = capacity or max(int(math.ceil(k * N / E * m.capacity_factor)), 1)
    flat_e = topk_idx.reshape(-1)                              # (N*k,) int32
    oh = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)            # (N*k, E)
    pos = (jnp.cumsum(oh, axis=0) - oh)                        # prior count
    pos = jnp.take_along_axis(pos, flat_e[:, None], axis=1)[:, 0]
    keep = pos < cap
    pos = jnp.where(keep, pos, 0)

    # ---- dispatch: scatter tokens into (E, cap, d) ------------------------
    xk = jnp.repeat(xf[:, None, :], k, axis=1).reshape(N * k, d)
    xk = xk * keep[:, None].astype(dt)
    buf = jnp.zeros((E, cap, d), dt).at[flat_e, pos].add(xk)

    # ---- expert MLPs (batched over E) -------------------------------------
    act = {"silu": jax.nn.silu, "gelu": jax.nn.gelu}[cfg.act]
    hg = jnp.einsum("ecd,edf->ecf", buf, p["w_gate"].astype(dt))
    hu = jnp.einsum("ecd,edf->ecf", buf, p["w_up"].astype(dt))
    ho = jnp.einsum("ecf,efd->ecd", act(hg) * hu, p["w_down"].astype(dt))

    # ---- combine: gather back and gate-weight -----------------------------
    yk = ho[flat_e, pos]                                       # (N*k, d)
    w = (gates.reshape(N * k) * keep.astype(jnp.float32)).astype(dt)
    y = (yk * w[:, None]).reshape(N, k, d).sum(axis=1)

    if "shared" in p:
        sg = jax.nn.sigmoid(
            (xf.astype(jnp.float32) @ p["shared_gate"].astype(jnp.float32)))
        y = y + L.mlp_block(p["shared"], cfg, xf) * sg.astype(dt)
    return y.reshape(B, S, d), aux


def moe_block_distributed(p, cfg, x, mesh):
    """Per-data-shard MoE dispatch (production path).

    The scatter/gather routing must not cross data shards: a global-token
    dispatch buffer is O(global_tokens · d) and GSPMD cannot shard a
    scatter's written dim. So we go manual over the data axes with
    ``shard_map(axis_names=data_axes)`` — each shard routes its LOCAL
    tokens into a local (E, cap_local, d) buffer — while the expert
    weights' d_ff dim stays under GSPMD auto sharding over "model"
    (tensor parallel inside every data shard). The router aux loss is
    pmean'd over the data axes so every shard returns the same scalar.
    """
    from jax.sharding import PartitionSpec as P
    from repro.sharding.context import data_axes

    daxes = data_axes(mesh)
    dp = 1
    for a in daxes:
        dp *= mesh.shape[a]
    if not daxes or x.shape[0] % dp:
        # batch not divisible over the data axes (e.g. long_500k B=1):
        # token count is tiny there, the plain GSPMD path is fine.
        return moe_block(p, cfg, x)
    batch_spec = P(daxes if len(daxes) > 1 else daxes[0])

    def local(pp, xx):
        y, aux = moe_block(pp, cfg, xx)
        for a in daxes:
            aux = jax.lax.pmean(aux, a)
        return y, aux

    fn = jax.shard_map(
        local, mesh=mesh,
        in_specs=(P(), batch_spec),
        out_specs=(batch_spec, P()),
        axis_names=frozenset(daxes),
        check_vma=False,
    )
    return fn(p, x)
