"""Unified model API: family dispatch + loss/step helpers.

Every family exposes:
    init(key, cfg) -> params
    forward(params, cfg, tokens, *, positions=None, caches=None,
            cache_index=None, embeddings=None) -> (logits, new_caches, aux)
    init_cache(cfg, batch, seq_len) -> caches   (decoder families)
"""
from __future__ import annotations

from types import SimpleNamespace

import jax
import jax.numpy as jnp


def get_model(cfg) -> SimpleNamespace:
    fam = cfg.family
    if fam in ("dense", "vlm"):
        from repro.models import transformer as m
    elif fam == "moe":
        from repro.models import transformer as m
    elif fam == "hybrid":
        from repro.models import rglru as m
    elif fam == "ssm":
        from repro.models import xlstm as m
    elif fam == "encdec":
        from repro.models import encdec as m
    elif fam == "dqn":
        from repro.models import dqn as m
        return SimpleNamespace(init=m.init, forward=m.forward,
                               init_cache=None)
    else:
        raise ValueError(f"unknown family {fam!r}")
    return SimpleNamespace(init=m.init, forward=m.forward,
                           init_cache=m.init_cache)


def lm_loss(params, cfg, tokens, labels, *, embeddings=None,
            model=None):
    """Next-token cross-entropy (mean over valid labels) + MoE aux loss."""
    model = model or get_model(cfg)
    logits, _, aux = model.forward(params, cfg, tokens,
                                   embeddings=embeddings)
    logits = logits.astype(jnp.float32)
    logp = jax.nn.log_softmax(logits, axis=-1)
    valid = (labels >= 0)
    labels_safe = jnp.maximum(labels, 0)
    nll = -jnp.take_along_axis(logp, labels_safe[..., None], axis=-1)[..., 0]
    loss = jnp.sum(nll * valid) / jnp.maximum(jnp.sum(valid), 1)
    return loss + aux


def count_params(params) -> int:
    return sum(x.size for x in jax.tree.leaves(params))
