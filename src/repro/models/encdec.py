"""Whisper-style encoder-decoder transformer backbone [arXiv:2212.04356].

The mel-spectrogram + conv feature extractor is the STUBBED modality
frontend (DESIGN.md §3): the encoder consumes precomputed frame embeddings
(B, encoder_seq_len, d_model) supplied via ``embeddings``. Positions are
sinusoidal (whisper's encoder convention; we use sinusoids on the decoder
too instead of a learned 448-entry table — noted in DESIGN.md §7).

Layers use LayerNorm + plain (biased) MLP per whisper; attention
projections reuse the shared GQA module (num_kv_heads == num_heads here).
Both stacks scan over layers.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.models import layers as L


def sinusoids(length: int, channels: int):
    log_timescale = np.log(10000.0) / (channels // 2 - 1)
    inv = np.exp(-log_timescale * np.arange(channels // 2))
    t = np.arange(length)[:, None] * inv[None, :]
    return jnp.asarray(np.concatenate([np.sin(t), np.cos(t)], axis=1),
                       jnp.float32)


def _init_ln(cfg, pd):
    return {"w": jnp.ones((cfg.d_model,), pd),
            "b": jnp.zeros((cfg.d_model,), pd)}


def _ln(p, cfg, x):
    return L.layer_norm(x, p["w"], p["b"], cfg.norm_eps)


def init_enc_block(key, cfg):
    k1, k2 = jax.random.split(key)
    pd = jnp.dtype(cfg.param_dtype)
    return {
        "ln1": _init_ln(cfg, pd),
        "attn": L.init_attention(k1, cfg),
        "ln2": _init_ln(cfg, pd),
        "mlp": L.init_mlp(k2, cfg),
    }


def init_dec_block(key, cfg):
    k1, k2, k3 = jax.random.split(key, 3)
    pd = jnp.dtype(cfg.param_dtype)
    return {
        "ln1": _init_ln(cfg, pd),
        "self_attn": L.init_attention(k1, cfg),
        "ln2": _init_ln(cfg, pd),
        "cross_attn": L.init_attention(k2, cfg),
        "ln3": _init_ln(cfg, pd),
        "mlp": L.init_mlp(k3, cfg),
    }


def init(key, cfg):
    assert cfg.encdec is not None
    ks = jax.random.split(key, 3)
    pd = jnp.dtype(cfg.param_dtype)
    enc_keys = jax.random.split(ks[0], cfg.encdec.num_encoder_layers)
    dec_keys = jax.random.split(ks[1], cfg.num_layers)
    return {
        "enc_blocks": jax.vmap(lambda k: init_enc_block(k, cfg))(enc_keys),
        "enc_norm": _init_ln(cfg, pd),
        "embed": L.dense_init(ks[2], (cfg.vocab_size, cfg.d_model), pd,
                              scale=1.0),
        "dec_blocks": jax.vmap(lambda k: init_dec_block(k, cfg))(dec_keys),
        "dec_norm": _init_ln(cfg, pd),
    }


def encode(params, cfg, frames):
    """frames: (B, T_enc, d) stub embeddings -> encoder states (B, T_enc, d)."""
    dt = jnp.dtype(cfg.dtype)
    B, T, _ = frames.shape
    x = frames.astype(dt) + sinusoids(T, cfg.d_model).astype(dt)[None]
    positions = jnp.broadcast_to(jnp.arange(T)[None], (B, T))

    def body(x, bp):
        h = _ln(bp["ln1"], cfg, x)
        dtl = jnp.dtype(cfg.dtype)
        q = jnp.einsum("bsd,dhk->bshk", h, bp["attn"]["wq"].astype(dtl))
        k = jnp.einsum("bsd,dhk->bshk", h, bp["attn"]["wk"].astype(dtl))
        v = jnp.einsum("bsd,dhk->bshk", h, bp["attn"]["wv"].astype(dtl))
        out = L.attention_reference(q, k, v, causal=False)
        a = jnp.einsum("bshk,hkd->bsd", out, bp["attn"]["wo"].astype(dtl))
        x = x + a
        h = _ln(bp["ln2"], cfg, x)
        x = x + L.mlp_block(bp["mlp"], cfg, h)
        return x, None

    if cfg.remat:
        body = L.checkpoint_fn(cfg)(body)
    if cfg.unroll_layers:
        for i in range(cfg.encdec.num_encoder_layers):
            bp = jax.tree.map(lambda a: a[i], params["enc_blocks"])
            x, _ = body(x, bp)
    else:
        x, _ = jax.lax.scan(lambda c, bp: body(c, bp), x,
                            params["enc_blocks"])
    return _ln(params["enc_norm"], cfg, x)


def compute_cross_kv(params, cfg, enc_out):
    """Per-decoder-layer cross K/V from encoder states: (L, B, T_enc, H, hd)."""
    dt = jnp.dtype(cfg.dtype)

    def per_layer(bp):
        k = jnp.einsum("bsd,dhk->bshk", enc_out,
                       bp["cross_attn"]["wk"].astype(dt))
        v = jnp.einsum("bsd,dhk->bshk", enc_out,
                       bp["cross_attn"]["wv"].astype(dt))
        return {"k": k, "v": v}

    return jax.vmap(per_layer)(params["dec_blocks"])


def _dec_block(bp, cfg, x, positions, cross, cache, cache_index):
    h = _ln(bp["ln1"], cfg, x)
    a, new_cache = L.attention_block(bp["self_attn"], cfg, h, positions,
                                     cache=cache, cache_index=cache_index)
    x = x + a
    h = _ln(bp["ln2"], cfg, x)
    a, _ = L.attention_block(bp["cross_attn"], cfg, h, positions,
                             cross_kv=(cross["k"], cross["v"]))
    x = x + a
    h = _ln(bp["ln3"], cfg, x)
    x = x + L.mlp_block(bp["mlp"], cfg, h)
    return x, new_cache


def forward(params, cfg, tokens, *, positions=None, caches=None,
            cache_index=None, embeddings=None):
    """Unified entry.

    embeddings: encoder frame embeddings (run the encoder; train/prefill), or
    None (decode continuation — cross KV must already be in ``caches``).
    caches: {"self": stacked kv, "cross": stacked cross kv} or None (train:
    teacher forcing, encoder runs, no self cache).
    """
    dt = jnp.dtype(cfg.dtype)
    B, S = tokens.shape
    if embeddings is not None:
        enc_out = encode(params, cfg, embeddings)
        cross = compute_cross_kv(params, cfg, enc_out)
    else:
        assert caches is not None and caches.get("cross") is not None
        cross = caches["cross"]

    if positions is None:
        positions = jnp.arange(S)[None, :] + (
            0 if cache_index is None else cache_index)
        positions = jnp.broadcast_to(positions, (B, S))

    x = params["embed"][tokens].astype(dt)
    pos_table = sinusoids(max(cfg.encdec.max_decoder_ctx, 1), cfg.d_model)
    # gather per-token sinusoid (mod table length for out-of-range dry runs)
    idx = jnp.mod(positions, pos_table.shape[0])
    x = x + pos_table[idx].astype(dt)

    def block_fn(bp, x, cross_l, cache):
        return _dec_block(bp, cfg, x, positions, cross_l, cache, cache_index)

    if cfg.remat:
        block_fn = L.checkpoint_fn(cfg)(block_fn)

    self_caches = None if caches is None else caches["self"]
    if cfg.unroll_layers:
        new_list = []
        for i in range(cfg.num_layers):
            bp = jax.tree.map(lambda a: a[i], params["dec_blocks"])
            cr = jax.tree.map(lambda a: a[i], cross)
            cache = None if self_caches is None else jax.tree.map(
                lambda a: a[i], self_caches)
            x, nc = block_fn(bp, x, cr, cache)
            new_list.append(nc)
        new_self = None if self_caches is None else jax.tree.map(
            lambda *xs: jnp.stack(xs), *new_list)
    elif self_caches is None:
        def body(x, inp):
            bp, cross_l = inp
            y, _ = block_fn(bp, x, cross_l, None)
            return y, None
        x, _ = jax.lax.scan(body, x, (params["dec_blocks"], cross))
        new_self = None
    else:
        def body(x, inp):
            bp, cross_l, cache = inp
            return block_fn(bp, x, cross_l, cache)
        x, new_self = jax.lax.scan(
            body, x, (params["dec_blocks"], cross, self_caches))

    x = _ln(params["dec_norm"], cfg, x)
    logits = x @ params["embed"].T.astype(dt)      # tied
    new_caches = None if caches is None else {"self": new_self,
                                              "cross": cross}
    return logits, new_caches, jnp.float32(0.0)


def init_cache(cfg, batch: int, seq_len: int):
    one = L.init_kv_cache(cfg, batch, seq_len)
    Ld = cfg.num_layers
    self_kv = jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (Ld,) + a.shape), one)
    hd = cfg.head_dim_
    cross = {
        "k": jnp.zeros((Ld, batch, cfg.encdec.encoder_seq_len,
                        cfg.num_kv_heads, hd), jnp.dtype(cfg.dtype)),
        "v": jnp.zeros((Ld, batch, cfg.encdec.encoder_seq_len,
                        cfg.num_kv_heads, hd), jnp.dtype(cfg.dtype)),
    }
    return {"self": self_kv, "cross": cross}
