"""RecurrentGemma / Griffin [arXiv:2402.19427]: RG-LRU recurrent blocks
interleaved 2:1 with local (sliding-window) attention, MQA.

Recurrence (per channel):
    r_t = sigmoid(x_t W_a + b_a)                      (recurrence gate)
    i_t = sigmoid(x_t W_x + b_x)                      (input gate)
    log a_t = -c * softplus(Λ) * r_t                  (c = 8)
    h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t ⊙ x_t)

Train/prefill uses ``jax.lax.associative_scan`` over the (a, b) pairs —
O(T) memory, O(T log T) work, parallel over devices; decode is a single
fused state update. A Pallas TPU kernel for the chunked scan lives in
``repro.kernels.rglru_scan`` (the XLA path here is its oracle).

Layer pattern: cfg.rglru.block_pattern (default (recurrent, recurrent,
attention)) cycled over cfg.num_layers. We scan over whole pattern periods
(HLO O(1) in depth) and unroll the remainder layers.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import layers as L

RGLRU_C = 8.0


# ---------------------------------------------------------------------------
# RG-LRU core
# ---------------------------------------------------------------------------


def rglru_scan(log_a, b, h0=None):
    """h_t = exp(log_a_t) * h_{t-1} + b_t via associative scan.

    log_a, b: (B, T, W). h0: optional (B, W) initial state.
    Returns (h (B,T,W), h_last (B,W)).
    """
    if h0 is not None:
        # fold h0 in as a step 0 with a=0 contribution
        b = b.at[:, 0].add(jnp.exp(log_a[:, 0]) * h0)

    def combine(x, y):
        la1, b1 = x
        la2, b2 = y
        return la1 + la2, jnp.exp(la2) * b1 + b2

    _, h = jax.lax.associative_scan(combine, (log_a, b), axis=1)
    return h, h[:, -1]


def rglru_step(log_a, b, h_prev):
    """Single decode step: (B, W) each."""
    h = jnp.exp(log_a) * h_prev + b
    return h


def init_rglru(key, cfg, width: int):
    """Gate weights are BLOCK-DIAGONAL over cfg.num_heads blocks, as in the
    official RecurrentGemma implementation (BlockDiagonalLinear) — also the
    sharding-friendly choice: the block dim shards over "model" with zero
    cross-shard contraction (EXPERIMENTS.md §Perf P1; the dense (W, W)
    variant costs an f32[B,T,W] all-reduce per gate per layer)."""
    pd = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 3)
    H = cfg.num_heads
    bw = width // H
    # Λ init so that a ∈ [0.9, 0.999] (paper's init)
    u = jax.random.uniform(ks[0], (width,), jnp.float32,
                           0.9 ** 2, 0.999 ** 2)
    lam = jnp.log(jnp.exp(-jnp.log(u) / (2 * RGLRU_C)) - 1.0)  # softplus^-1
    return {
        "lam": lam.astype(pd),
        "w_a": L.dense_init(ks[1], (H, bw, bw), pd),
        "b_a": jnp.zeros((width,), pd),
        "w_x": L.dense_init(ks[2], (H, bw, bw), pd),
        "b_x": jnp.zeros((width,), pd),
    }


def _block_diag_gate(x, w, b):
    """x (B,T,W) with W split into H blocks; w (H, bw, bw)."""
    B, T, W = x.shape
    H, bw, _ = w.shape
    xb = x.reshape(B, T, H, bw)
    y = jnp.einsum("bthk,hkj->bthj", xb, w)
    return y.reshape(B, T, W) + b


def rglru_apply(p, cfg, x, h0=None):
    """x: (B, T, W) -> (y, h_last). fp32 recurrence internals."""
    xf = x.astype(jnp.float32)
    r = jax.nn.sigmoid(_block_diag_gate(xf, p["w_a"].astype(jnp.float32),
                                        p["b_a"].astype(jnp.float32)))
    i = jax.nn.sigmoid(_block_diag_gate(xf, p["w_x"].astype(jnp.float32),
                                        p["b_x"].astype(jnp.float32)))
    log_a = -RGLRU_C * jax.nn.softplus(p["lam"].astype(jnp.float32)) * r
    gated = i * xf
    mult = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    b = mult * gated
    T = x.shape[1]
    if T == 1 and h0 is not None:
        h = rglru_step(log_a[:, 0], b[:, 0], h0)
        return h[:, None].astype(x.dtype), h
    y, h_last = rglru_scan(log_a, b, h0)
    return y.astype(x.dtype), h_last


# ---------------------------------------------------------------------------
# causal conv1d (depthwise, width w) with decode state
# ---------------------------------------------------------------------------


def init_conv1d(key, width: int, kernel: int, pd):
    return {
        "w": (jax.random.normal(key, (kernel, width), jnp.float32)
              / math.sqrt(kernel)).astype(pd),
        "b": jnp.zeros((width,), pd),
    }


def conv1d_apply(p, x, state=None):
    """Depthwise causal conv. x (B,T,W); state (B, kernel-1, W) history.

    Returns (y, new_state).
    """
    kernel = p["w"].shape[0]
    dt = x.dtype
    if state is None:
        state = jnp.zeros((x.shape[0], kernel - 1, x.shape[2]), dt)
    xp = jnp.concatenate([state.astype(dt), x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * p["w"][i].astype(dt)
            for i in range(kernel))
    y = y + p["b"].astype(dt)
    new_state = xp[:, -(kernel - 1):] if kernel > 1 else state
    return y, new_state


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------


def init_recurrent_block(key, cfg):
    W = cfg.rglru.lru_width or cfg.d_model
    pd = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 6)
    return {
        "norm": jnp.zeros((cfg.d_model,), pd),
        "w_branch_x": L.dense_init(ks[0], (cfg.d_model, W), pd),
        "w_branch_gate": L.dense_init(ks[1], (cfg.d_model, W), pd),
        "conv": init_conv1d(ks[2], W, cfg.rglru.conv1d_width, pd),
        "rglru": init_rglru(ks[3], cfg, W),
        "w_out": L.dense_init(ks[4], (W, cfg.d_model), pd),
        "mlp_norm": jnp.zeros((cfg.d_model,), pd),
        "mlp": L.init_mlp(ks[5], cfg),
    }


def recurrent_block(bp, cfg, x, state=None):
    """Griffin recurrent block. state: {'conv': ..., 'h': ...} or None."""
    dt = jnp.dtype(cfg.dtype)
    h = L.rms_norm(x, bp["norm"], cfg.norm_eps)
    gate = jax.nn.gelu(h @ bp["w_branch_gate"].astype(dt))
    u = h @ bp["w_branch_x"].astype(dt)
    conv_state = None if state is None else state["conv"]
    u, new_conv = conv1d_apply(bp["conv"], u, conv_state)
    h0 = None if state is None else state["h"]
    y, h_last = rglru_apply(bp["rglru"], cfg, u, h0)
    out = (y * gate) @ bp["w_out"].astype(dt)
    x = x + out
    hh = L.rms_norm(x, bp["mlp_norm"], cfg.norm_eps)
    x = x + L.mlp_block(bp["mlp"], cfg, hh)
    new_state = {"conv": new_conv, "h": h_last}
    return x, new_state


def init_attention_block(key, cfg):
    k1, k2 = jax.random.split(key)
    pd = jnp.dtype(cfg.param_dtype)
    return {
        "norm": jnp.zeros((cfg.d_model,), pd),
        "attn": L.init_attention(k1, cfg),
        "mlp_norm": jnp.zeros((cfg.d_model,), pd),
        "mlp": L.init_mlp(k2, cfg),
    }


def attention_block(bp, cfg, x, positions, cache=None, cache_index=None):
    h = L.rms_norm(x, bp["norm"], cfg.norm_eps)
    a, new_cache = L.attention_block(
        bp["attn"], cfg, h, positions, window=cfg.sliding_window,
        cache=cache, cache_index=cache_index)
    x = x + a
    hh = L.rms_norm(x, bp["mlp_norm"], cfg.norm_eps)
    x = x + L.mlp_block(bp["mlp"], cfg, hh)
    return x, new_cache


# ---------------------------------------------------------------------------
# full model
# ---------------------------------------------------------------------------


def _layer_types(cfg):
    pat = cfg.rglru.block_pattern
    return [pat[i % len(pat)] for i in range(cfg.num_layers)]


def _periods(cfg):
    """(full periods, remainder layer types)."""
    pat = cfg.rglru.block_pattern
    n_full = cfg.num_layers // len(pat)
    rem = _layer_types(cfg)[n_full * len(pat):]
    return n_full, rem


def init(key, cfg):
    assert cfg.rglru is not None
    pat = cfg.rglru.block_pattern
    n_full, rem = _periods(cfg)
    ks = jax.random.split(key, 4)
    pd = jnp.dtype(cfg.param_dtype)

    def init_period(k):
        kk = jax.random.split(k, len(pat))
        return tuple(
            init_recurrent_block(kk[j], cfg) if t == "recurrent"
            else init_attention_block(kk[j], cfg)
            for j, t in enumerate(pat))

    period_keys = jax.random.split(ks[0], max(n_full, 1))
    periods = jax.vmap(init_period)(period_keys) if n_full else None
    rem_keys = jax.random.split(ks[1], max(len(rem), 1))
    rem_blocks = tuple(
        init_recurrent_block(rem_keys[j], cfg) if t == "recurrent"
        else init_attention_block(rem_keys[j], cfg)
        for j, t in enumerate(rem))
    p = {
        "embed": L.dense_init(ks[2], (cfg.vocab_size, cfg.d_model), pd,
                              scale=1.0),
        "periods": periods,
        "rem": rem_blocks,
        "final_norm": jnp.zeros((cfg.d_model,), pd),
        "unembed": L.dense_init(ks[3], (cfg.d_model, cfg.vocab_size), pd),
    }
    return p


def init_cache(cfg, batch: int, seq_len: int):
    """Per-layer state: attention layers get SWA kv caches, recurrent layers
    get {'conv','h'} states. Grouped as (periods-stacked, remainder)."""
    pat = cfg.rglru.block_pattern
    n_full, rem = _periods(cfg)
    W = cfg.rglru.lru_width or cfg.d_model
    dt = jnp.dtype(cfg.dtype)

    def one(t):
        if t == "attention":
            return L.init_kv_cache(cfg, batch, seq_len,
                                   window=cfg.sliding_window)
        return {"conv": jnp.zeros((batch, cfg.rglru.conv1d_width - 1, W), dt),
                "h": jnp.zeros((batch, W), jnp.float32)}

    period = tuple(one(t) for t in pat)
    periods = jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (n_full,) + a.shape), period) \
        if n_full else None
    return {"periods": periods, "rem": tuple(one(t) for t in rem)}


def forward(params, cfg, tokens, *, positions=None, caches=None,
            cache_index=None, embeddings=None):
    dt = jnp.dtype(cfg.dtype)
    pat = cfg.rglru.block_pattern
    n_full, rem = _periods(cfg)
    x = (params["embed"][tokens] if embeddings is None else embeddings
         ).astype(dt)
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.arange(S)[None, :] + (
            0 if cache_index is None else cache_index)
        positions = jnp.broadcast_to(positions, (B, S))

    def period_fn(pp, x, pstate):
        new_states = []
        for j, t in enumerate(pat):
            bp = pp[j]
            st = None if pstate is None else pstate[j]
            if t == "recurrent":
                x, ns = recurrent_block(bp, cfg, x, st)
            else:
                x, ns = attention_block(bp, cfg, x, positions, st,
                                        cache_index)
            new_states.append(ns)
        return x, tuple(new_states)

    if cfg.remat:
        period_fn = L.checkpoint_fn(cfg)(period_fn)

    if n_full and cfg.unroll_layers:
        new_list = []
        for i in range(n_full):
            pp = jax.tree.map(lambda a: a[i], params["periods"])
            st = None if caches is None else jax.tree.map(
                lambda a: a[i], caches["periods"])
            x, ns = period_fn(pp, x, st)
            new_list.append(ns)
        new_periods = None if caches is None else jax.tree.map(
            lambda *xs: jnp.stack(xs), *new_list)
    elif n_full:
        if caches is None:
            def body(x, pp):
                y, _ = period_fn(pp, x, None)
                return y, None
            x, _ = jax.lax.scan(body, x, params["periods"])
            new_periods = None
        else:
            def body(x, inp):
                pp, st = inp
                return period_fn(pp, x, st)
            x, new_periods = jax.lax.scan(
                body, x, (params["periods"], caches["periods"]))
    else:
        new_periods = None

    new_rem = []
    for j, t in enumerate(rem):
        bp = params["rem"][j]
        st = None if caches is None else caches["rem"][j]
        if t == "recurrent":
            x, ns = recurrent_block(bp, cfg, x, st)
        else:
            x, ns = attention_block(bp, cfg, x, positions, st, cache_index)
        new_rem.append(ns)

    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = x @ params["unembed"].astype(dt)
    if cfg.logit_softcap > 0:
        logits = cfg.logit_softcap * jnp.tanh(
            logits.astype(jnp.float32) / cfg.logit_softcap).astype(dt)
    new_caches = None if caches is None else {
        "periods": new_periods, "rem": tuple(new_rem)}
    return logits, new_caches, jnp.float32(0.0)
