"""Modality frontend STUBS (the one allowed carve-out, DESIGN.md §3).

For the audio arch (whisper) and the VLM arch (chameleon) we do not
implement the mel+conv codec / VQ-VAE image tokenizer. Instead these
helpers produce the tensors such a frontend would emit, with the correct
shapes/dtypes — random for smoke tests, ShapeDtypeStruct for dry-runs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def audio_frame_embeddings(key, cfg, batch: int):
    """What the whisper conv frontend would emit: (B, T_enc, d) frames."""
    T = cfg.encdec.encoder_seq_len
    return jax.random.normal(key, (batch, T, cfg.d_model),
                             jnp.dtype(cfg.dtype)) * 0.02


def audio_frame_spec(cfg, batch: int):
    T = cfg.encdec.encoder_seq_len
    return jax.ShapeDtypeStruct((batch, T, cfg.d_model),
                                jnp.dtype(cfg.dtype))


def vlm_token_stream(key, cfg, batch: int, seq_len: int):
    """Chameleon early fusion: interleaved text + VQ image-code token ids.

    Image codes are ordinary vocabulary entries (the top 8192 ids by
    convention here); a real frontend would insert begin/end-image sentinels
    — for training purposes the stream is just ids in [0, vocab).
    """
    return jax.random.randint(key, (batch, seq_len), 0, cfg.vocab_size,
                              jnp.int32)
