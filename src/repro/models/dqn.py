"""The paper's Q-network: the DeepMind DQN model (Mnih et al. 2015) shape
— 5 trainable layers / ~1.3M params — adapted to the 40-landmark gridworld
state (a one-hot position vector standing in for the paper's RGB+TOF camera
observations; Sect. IV simplifies the control problem to the 2D grid).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L

STATE_DIM = 40      # 40 landmark positions (one-hot)
NUM_ACTIONS = 4     # F, B, L, R


def init(key, cfg):
    d = cfg.d_model
    ks = jax.random.split(key, cfg.num_layers)
    pd = jnp.dtype(cfg.param_dtype)
    dims = [STATE_DIM] + [d] * (cfg.num_layers - 1) + [NUM_ACTIONS]
    return {
        f"fc{i}": {
            "w": L.dense_init(ks[i], (dims[i], dims[i + 1]), pd),
            "b": jnp.zeros((dims[i + 1],), pd),
        }
        for i in range(cfg.num_layers)
    }


def forward(params, cfg, state, **_):
    """state: (B, 40) one-hot (or batched soft) -> q-values (B, 4)."""
    x = state.astype(jnp.float32)
    n = cfg.num_layers
    for i in range(n):
        x = x @ params[f"fc{i}"]["w"].astype(jnp.float32) \
            + params[f"fc{i}"]["b"].astype(jnp.float32)
        if i < n - 1:
            x = jax.nn.relu(x)
    return x, None, jnp.float32(0.0)
