"""Shared neural-net building blocks (pure functional JAX).

Conventions
-----------
* params are nested dicts of jnp arrays; every module has ``init_*`` and an
  apply function.
* activations computed in ``cfg.dtype`` (bf16 by default), params stored in
  ``cfg.param_dtype`` (f32), outputs of norms/softmax accumulated in f32.
* attention is O(block) memory via a lax.scan over kv chunks (flash-style
  online softmax) — this is both the XLA production path for long sequences
  and the oracle family for the Pallas kernel in ``repro.kernels``.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------


def dense_init(key, shape, dtype, scale: Optional[float] = None):
    """Truncated-normal fan-in init (what llama-family checkpoints look like)."""
    fan_in = shape[0] if len(shape) >= 2 else max(shape[0], 1)
    if len(shape) >= 3:  # (d, H, hd) style — fan-in is the first dim
        fan_in = shape[0]
    std = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -3.0, 3.0, shape, jnp.float32)
            * std).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def rms_norm(x, weight, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + weight.astype(jnp.float32))).astype(dt)


def layer_norm(x, weight, bias, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * weight.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# rotary position embeddings
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (np.arange(0, head_dim, 2, dtype=np.float32)
                            / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    hd = x.shape[-1]
    inv = jnp.asarray(rope_freqs(hd, theta))                    # (hd/2,)
    ang = positions[..., :, None].astype(jnp.float32) * inv     # (..., S, hd/2)
    ang = ang[..., None, :]                                     # (..., S, 1, hd/2)
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention — chunked flash-style (the XLA production path)
# ---------------------------------------------------------------------------

NEG_INF = -2.0 ** 30


def _mask_bias(q_pos, k_pos, causal: bool, window: int):
    """(q, k) additive bias from causal + sliding-window constraints."""
    ok = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if causal:
        ok &= k_pos[None, :] <= q_pos[:, None]
    if window > 0:
        ok &= k_pos[None, :] > q_pos[:, None] - window
    return jnp.where(ok, 0.0, NEG_INF)


def attention_reference(q, k, v, *, causal: bool = True, window: int = 0,
                        q_offset: int = 0, softcap: float = 0.0,
                        k_len: Optional[jnp.ndarray] = None):
    """Plain O(S^2)-memory attention. (B,S,H,hd)x(B,T,K,hd) -> (B,S,H,hd).

    GQA: H % K == 0; q head h attends kv head h // (H//K).
    ``k_len``: optional (B,) number of valid kv positions (decode caches).
    """
    B, S, H, hd = q.shape
    T, K = k.shape[1], k.shape[2]
    g = H // K
    # keep k/v in their storage dtype: upcasting the cache materializes an
    # f32 copy of the whole KV cache (hoisted out of the layer scan by XLA)
    # — accumulate in f32 via preferred_element_type instead.
    qf = (q.astype(jnp.float32) / math.sqrt(hd)).astype(q.dtype)
    qf = qf.reshape(B, S, K, g, hd)
    scores = jnp.einsum("bskgh,btkh->bkgst", qf, k,
                        preferred_element_type=jnp.float32)
    if softcap > 0:
        scores = softcap * jnp.tanh(scores / softcap)
    q_pos = q_offset + jnp.arange(S)
    k_pos = jnp.arange(T)
    bias = _mask_bias(q_pos, k_pos, causal, window)
    scores = scores + bias[None, None, None]
    if k_len is not None:
        valid = k_pos[None, :] < k_len[:, None]                 # (B, T)
        scores = jnp.where(valid[:, None, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkh->bskgh", probs.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, S, H, hd).astype(q.dtype)


def attention_chunked(q, k, v, *, causal: bool = True, window: int = 0,
                      q_offset: int = 0, softcap: float = 0.0,
                      kv_chunk: int = 1024, q_chunk: int = 1024):
    """Flash-style attention: lax.scan over kv chunks with online softmax.

    Peak live memory is O(q_chunk * kv_chunk) per (batch, head) instead of
    O(S*T). Exact (not approximate); matches ``attention_reference``.
    """
    B, S, H, hd = q.shape
    T, K = k.shape[1], k.shape[2]
    g = H // K
    scale = 1.0 / math.sqrt(hd)

    q_chunk = min(q_chunk, S)
    kv_chunk = min(kv_chunk, T)
    # pad S and T to multiples
    Sp = -(-S // q_chunk) * q_chunk
    Tp = -(-T // kv_chunk) * kv_chunk
    qp = jnp.pad(q, ((0, 0), (0, Sp - S), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, Tp - T), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, Tp - T), (0, 0), (0, 0)))

    nq, nk = Sp // q_chunk, Tp // kv_chunk
    qp = ((qp.reshape(B, nq, q_chunk, K, g, hd).astype(jnp.float32) * scale)
          .astype(q.dtype))
    kp = kp.reshape(B, nk, kv_chunk, K, hd)
    vp = vp.reshape(B, nk, kv_chunk, K, hd)

    q_pos_all = q_offset + jnp.arange(Sp).reshape(nq, q_chunk)
    k_pos_all = jnp.arange(Tp).reshape(nk, kv_chunk)
    k_valid_all = (jnp.arange(Tp) < T).reshape(nk, kv_chunk)

    def one_q_chunk(qc, q_pos):
        # qc: (B, q_chunk, K, g, hd)
        def body(carry, inp):
            acc, m, l = carry
            kc, vc, k_pos, k_valid = inp
            s = jnp.einsum("bqkgh,btkh->bkgqt", qc, kc,
                           preferred_element_type=jnp.float32)
            if softcap > 0:
                s = softcap * jnp.tanh(s / softcap)
            bias = _mask_bias(q_pos, k_pos, causal, window)
            bias = jnp.where(k_valid[None, :], bias, NEG_INF)
            s = s + bias[None, None, None]
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bkgqt,btkh->bkgqh", p.astype(vc.dtype), vc,
                preferred_element_type=jnp.float32)
            return (acc_new, m_new, l_new), None

        acc0 = jnp.zeros((B, K, g, q_chunk, hd), jnp.float32)
        m0 = jnp.full((B, K, g, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, K, g, q_chunk), jnp.float32)
        (acc, m, l), _ = jax.lax.scan(
            body, (acc0, m0, l0),
            (kp.swapaxes(0, 1), vp.swapaxes(0, 1), k_pos_all, k_valid_all))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return out.transpose(0, 3, 1, 2, 4)        # (B, q_chunk, K, g, hd)

    out = jax.vmap(one_q_chunk, in_axes=(1, 0), out_axes=1)(qp, q_pos_all)
    out = out.reshape(B, Sp, H, hd)[:, :S]
    return out.astype(q.dtype)


def attention(q, k, v, *, causal=True, window=0, q_offset=0, softcap=0.0,
              k_len=None, impl: str = "auto"):
    """Dispatch: small shapes -> reference einsum, long -> chunked scan."""
    S, T = q.shape[1], k.shape[1]
    if impl == "auto":
        impl = "chunked" if (S * T > 1024 * 2048 and k_len is None) else "ref"
    if impl == "chunked":
        return attention_chunked(q, k, v, causal=causal, window=window,
                                 q_offset=q_offset, softcap=softcap)
    return attention_reference(q, k, v, causal=causal, window=window,
                               q_offset=q_offset, softcap=softcap, k_len=k_len)


# ---------------------------------------------------------------------------
# attention module (projections + rope + cache handling)
# ---------------------------------------------------------------------------


def init_attention(key, cfg, d_model: Optional[int] = None):
    d = d_model or cfg.d_model
    hd = cfg.head_dim_
    ks = jax.random.split(key, 4)
    pd = jnp.dtype(cfg.param_dtype)
    p = {
        "wq": dense_init(ks[0], (d, cfg.num_heads, hd), pd),
        "wk": dense_init(ks[1], (d, cfg.num_kv_heads, hd), pd),
        "wv": dense_init(ks[2], (d, cfg.num_kv_heads, hd), pd),
        "wo": dense_init(ks[3], (cfg.num_heads, hd, d), pd,
                         scale=1.0 / math.sqrt(cfg.num_heads * hd)),
    }
    if cfg.use_qk_norm:
        p["q_norm"] = jnp.zeros((hd,), pd)
        p["k_norm"] = jnp.zeros((hd,), pd)
    return p


def attention_block(p, cfg, x, positions, *, window: int = 0,
                    cache=None, cache_index=None, impl: str = "auto",
                    cross_kv=None):
    """Self- (or cross-) attention with optional KV cache.

    cache: dict(k=(B, C, K, hd), v=(B, C, K, hd)); C == window for SWA
    (circular buffer, slot = position % C), else C == max seq (linear).
    cache_index: scalar int32 — number of tokens already in the cache.
    Prefill (S > 1) assumes cache_index == 0 (single-shot prefill); decode
    (S == 1) supports any index. Returns (out, new_cache).
    """
    dt = jnp.dtype(cfg.dtype)
    x = x.astype(dt)
    B, S, _ = x.shape
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    if cross_kv is not None:
        k, v = cross_kv
    else:
        k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(dt))
        v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(dt))
    if cfg.use_qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        if cross_kv is None:
            k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if cfg.rope_theta > 0:
        q = apply_rope(q, positions, cfg.rope_theta)
        if cross_kv is None:
            k = apply_rope(k, positions, cfg.rope_theta)

    def project_out(out):
        return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(dt))

    def constrain_prefill_attn(q, k, v):
        """Prefill (S > 1): keep the scores contraction on the HEADS axis —
        with a non-mesh-divisible kv-head count the cache is hd-sharded,
        and GSPMD otherwise back-propagates that layout into the fresh-kv
        attention, paying a partial-sum ALL-REDUCE of the full scores
        tensor per kv chunk (4.3 GB/layer/device for recurrentgemma
        prefill_32k — EXPERIMENTS.md §Perf P1). Constrain q to
        heads-over-model and fresh k/v to replicated; the single cache
        write reshard is ~30x cheaper."""
        from repro.sharding.context import data_axes, get_mesh
        mesh = get_mesh()
        if mesh is None or "model" not in mesh.shape:
            return q, k, v
        size = mesh.shape["model"]
        H, K = q.shape[2], k.shape[2]
        if K % size == 0 or H % size != 0:
            return q, k, v
        from jax.sharding import NamedSharding, PartitionSpec as P
        daxes = data_axes(mesh)
        bax = daxes if len(daxes) > 1 else (daxes[0] if daxes else None)
        bspec = bax if q.shape[0] % max(
            mesh.shape.get("data", 1) * mesh.shape.get("pod", 1), 1) == 0             else None
        qs = jax.lax.with_sharding_constraint(
            q, NamedSharding(mesh, P(bspec, None, "model", None)))
        ks_ = jax.lax.with_sharding_constraint(
            k, NamedSharding(mesh, P(bspec, None, None, None)))
        vs = jax.lax.with_sharding_constraint(
            v, NamedSharding(mesh, P(bspec, None, None, None)))
        return qs, ks_, vs

    def constrain_decode_q(q):
        """Decode (S == 1): align q with the cache's tensor-parallel layout
        (head_dim over 'model' when kv-heads aren't mesh-divisible) so the
        KV cache stays stationary — otherwise GSPMD reshards the whole
        cache every decode step (EXPERIMENTS.md §Perf P0)."""
        from repro.sharding.context import data_axes, get_mesh
        mesh = get_mesh()
        if mesh is None or "model" not in mesh.shape:
            return q
        size = mesh.shape["model"]
        K, hd = k.shape[2], q.shape[3]
        if K % size == 0 or hd % size != 0:
            return q          # cache is K-sharded (or unshardable)
        from jax.sharding import NamedSharding, PartitionSpec as P
        daxes = data_axes(mesh)
        spec = P(daxes if len(daxes) > 1 else daxes[0], None, None, "model")
        return jax.lax.with_sharding_constraint(q, NamedSharding(mesh, spec))

    if cross_kv is not None:
        out = attention_reference(q, k, v, causal=False, window=0,
                                  softcap=cfg.logit_softcap)
        return project_out(out), cache

    if cache is None:
        out = attention(q, k, v, causal=True, window=window,
                        softcap=cfg.logit_softcap, impl=impl)
        return project_out(out), None

    C = cache["k"].shape[1]
    idx = cache_index if cache_index is not None else jnp.int32(0)
    circular = window > 0 and C == window

    if circular:
        # write the last min(S, C) tokens at slot = position % C
        tail = min(S, C)
        p_tail = idx + (S - tail) + jnp.arange(tail)
        slots = jnp.mod(p_tail, C)
        ck = cache["k"].at[:, slots].set(k[:, S - tail:].astype(cache["k"].dtype))
        cv = cache["v"].at[:, slots].set(v[:, S - tail:].astype(cache["v"].dtype))
        new_cache = {"k": ck, "v": cv}
        if S > 1:
            # single-shot prefill: attention over the fresh sequence
            qc, kc, vc = constrain_prefill_attn(q, k, v)
            out = attention(qc, kc, vc, causal=True, window=window,
                            softcap=cfg.logit_softcap, impl=impl)
        else:
            # decode: every valid cache slot is an in-window past position
            kl = jnp.full((B,), jnp.minimum(idx + S, C), jnp.int32)
            out = attention_reference(constrain_decode_q(q), ck, cv,
                                      causal=False, window=0,
                                      softcap=cfg.logit_softcap, k_len=kl)
        return project_out(out), new_cache

    # linear buffer
    ck = jax.lax.dynamic_update_slice_in_dim(
        cache["k"], k.astype(cache["k"].dtype), idx, axis=1)
    cv = jax.lax.dynamic_update_slice_in_dim(
        cache["v"], v.astype(cache["v"].dtype), idx, axis=1)
    new_cache = {"k": ck, "v": cv}
    if S > 1:
        qc, kc, vc = constrain_prefill_attn(q, k, v)
        out = attention(qc, kc, vc, causal=True, window=window,
                        softcap=cfg.logit_softcap, impl=impl)
    else:
        kl = jnp.full((B,), idx + S, jnp.int32)
        out = attention_reference(constrain_decode_q(q), ck, cv,
                                  causal=True, window=window,
                                  q_offset=idx, softcap=cfg.logit_softcap,
                                  k_len=kl)
    return project_out(out), new_cache


def init_kv_cache(cfg, batch: int, seq_len: int, *, window: int = 0,
                  dtype=None):
    """Allocate a KV cache: full length, or the SWA window if smaller."""
    C = min(seq_len, window) if window > 0 else seq_len
    hd = cfg.head_dim_
    dt = jnp.dtype(dtype or cfg.dtype)
    z = jnp.zeros((batch, C, cfg.num_kv_heads, hd), dt)
    return {"k": z, "v": z}


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------


def init_mlp(key, cfg, d_ff: Optional[int] = None, d_model: Optional[int] = None):
    d = d_model or cfg.d_model
    f = d_ff or cfg.d_ff
    pd = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 3)
    if cfg.mlp_kind == "gated":
        return {
            "w_gate": dense_init(ks[0], (d, f), pd),
            "w_up": dense_init(ks[1], (d, f), pd),
            "w_down": dense_init(ks[2], (f, d), pd),
        }
    return {
        "w_up": dense_init(ks[0], (d, f), pd),
        "b_up": jnp.zeros((f,), pd),
        "w_down": dense_init(ks[1], (f, d), pd),
        "b_down": jnp.zeros((d,), pd),
    }


def _act(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu,
            "relu": jax.nn.relu}[name]


def mlp_block(p, cfg, x):
    dt = jnp.dtype(cfg.dtype)
    x = x.astype(dt)
    act = _act(cfg.act)
    if "w_gate" in p:
        h = act(x @ p["w_gate"].astype(dt)) * (x @ p["w_up"].astype(dt))
        return h @ p["w_down"].astype(dt)
    h = act(x @ p["w_up"].astype(dt) + p["b_up"].astype(dt))
    return h @ p["w_down"].astype(dt) + p["b_down"].astype(dt)


def checkpoint_fn(cfg):
    """jax.checkpoint partial honoring cfg.remat_policy."""
    import jax as _jax
    if cfg.remat_policy == "dots":
        return lambda f: _jax.checkpoint(
            f, policy=_jax.checkpoint_policies.dots_saveable)
    return _jax.checkpoint
